"""Aggregates the dry-run JSONs into the §Roofline table (per arch x shape
x mesh: three terms, bottleneck, useful-compute ratio). Run AFTER
``python -m repro.launch.dryrun --all [--multi-pod]``; exits gracefully
when no artifacts exist yet.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import Table

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def run(out_dir: str = "experiments"):
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    t = Table("roofline", ["arch", "shape", "mesh", "compute_ms",
                           "memory_ms", "collective_ms", "bottleneck",
                           "useful", "peak_GiB"])
    if not files:
        print("  (no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --all` first)")
        return t
    for f in files:
        with open(f) as fh:
            r = json.load(fh)
        t.add(r["arch"], r["shape"], r["mesh"],
              f"{r['t_compute']*1e3:.2f}", f"{r['t_memory']*1e3:.2f}",
              f"{r['t_collective']*1e3:.2f}", r["bottleneck"],
              f"{r['useful_ratio']:.3f}",
              f"{r.get('mem_peak', 0)/2**30:.2f}")
    t.emit_csv(f"{out_dir}/bench_roofline.csv")
    return t


if __name__ == "__main__":
    run()
