"""Shared benchmark plumbing: timed fit wrappers + CSV emit."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds of fn(*args) after warmup (results blocked on)."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


class Table:
    """Collects rows, prints aligned + writes CSV."""

    def __init__(self, name: str, columns: List[str]):
        self.name = name
        self.columns = columns
        self.rows: List[List] = []

    def add(self, *row):
        assert len(row) == len(self.columns)
        self.rows.append(list(row))
        print("  " + "  ".join(f"{v}" for v in row), flush=True)

    def emit_csv(self, path: str):
        import os
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            f.write(",".join(self.columns) + "\n")
            for row in self.rows:
                f.write(",".join(str(v) for v in row) + "\n")
        print(f"[{self.name}] wrote {path}")
