"""CI perf-regression gate: fresh BENCH_*.json vs committed baselines.

CI has uploaded BENCH_gibbs.json / BENCH_scaling.json as artifacts since
PR 2 without ever *looking* at them — the PR 2-4 wins (one-read sweep,
out-of-core footprint, fused speedup) were unprotected. This script is
the gate: the bench job writes fresh JSONs to ``--fresh-dir``, and this
compares them against the baselines committed at the repo root
(``--baseline-dir``), failing the job on

 - **>25% slowdown** (``--threshold``) in the paired timing metrics:
   the hot-path reference ms/iter, the ``reference_sweep_pair`` fused
   sweep time, and serving queries/sec. Wall-clock baselines are
   machine-class-sensitive: refresh the committed BENCH jsons in the PR
   whenever the runner class changes (or pass ``--timing-threshold`` to
   widen only the wall-clock envelope without touching the strict
   checks);
 - **the within-run fused-vs-three-pass pair inverting**: the measured
   ``fused_speedup`` must stay >= 1 — the one-read sweep must never be
   slower than the three-pass body it replaced. This is a same-machine
   same-run pair, so it holds regardless of how slow the runner is
   (the *magnitude* of the win swings ~1.2-1.8x with machine load,
   which is why it is gated on sign, not on the baseline value);
 - **the sparse-K scaling budget**: the ``k_sweep`` rows' within-run
   pair — a k_max=512 slab at K_active=8 must sweep within the payload's
   ``k_scaling_budget`` (1.3x) of the k_max=32 slab at K_active=8, on
   the fused and reference bodies alike (sweep cost is O(K_active), not
   O(k_max)). Same-machine same-run, so runner class cannot mask or
   fake it;
 - **any flip of an accounting invariant**: ``x_hbm_reads_per_sweep``
   must stay 1 on both fused paths, the interpret-mode megakernel smoke
   must stay ``chain_identical_to_reference``, every out-of-core leg
   must stay ``chain_identical_to_resident``, tiled footprint ratios
   must not grow AT ALL (they are analytic buffer accounting with zero
   run-to-run noise — no threshold applies), serving must stay
   ``soft_matches_loglik``, and the ``recovery`` row's fault-tolerance
   booleans (guardrail chain-neutrality, faulted-fit recovery,
   checkpoint/resume bitwise round trip) must all hold, the ``dist``
   leg's ``dist_chain_bitwise`` must stay True at every worker count
   (the multi-process coordinator/worker chain is bit-for-bit the
   single-process tiled chain — worker count is a wall-clock knob,
   never a chain knob), and its failover run must stay
   ``failover_chain_bitwise`` with at least one ``worker_failover``
   event actually logged (otherwise the kill never landed and the run
   proves nothing).

Stdlib-only on purpose: the gate job needs no jax install — it just
reads two directories of JSON.

    python benchmarks/check_regression.py --baseline-dir . --fresh-dir fresh
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional


class Gate:
    def __init__(self, threshold: float, timing_threshold: float):
        self.threshold = threshold          # strict/deterministic checks
        self.timing_threshold = timing_threshold   # wall-clock checks
        self.failures: List[str] = []
        self.checks = 0

    def _verdict(self, ok: bool, msg: str) -> None:
        self.checks += 1
        print(("  PASS  " if ok else "  FAIL  ") + msg)
        if not ok:
            self.failures.append(msg)

    def slower(self, name: str, fresh: Optional[float],
               base: Optional[float]) -> None:
        """Wall-clock metric (lower is better): fresh <= base * (1+t)."""
        if fresh is None or base is None:
            self._verdict(False, f"{name}: metric missing "
                                 f"(fresh={fresh}, baseline={base})")
            return
        limit = base * (1.0 + self.timing_threshold)
        self._verdict(
            fresh <= limit,
            f"{name}: {fresh:.3f} vs baseline {base:.3f} "
            f"(limit {limit:.3f}, {fresh / base - 1.0:+.1%} vs baseline)")

    def faster(self, name: str, fresh: Optional[float],
               base: Optional[float]) -> None:
        """Wall-clock rate (higher is better): fresh >= base / (1+t)."""
        if fresh is None or base is None:
            self._verdict(False, f"{name}: metric missing "
                                 f"(fresh={fresh}, baseline={base})")
            return
        limit = base / (1.0 + self.timing_threshold)
        self._verdict(
            fresh >= limit,
            f"{name}: {fresh:.1f} vs baseline {base:.1f} "
            f"(floor {limit:.1f}, {fresh / base - 1.0:+.1%} vs baseline)")

    def not_growing(self, name: str, fresh: Optional[float],
                    base: Optional[float]) -> None:
        """Deterministic accounting metric: ANY growth fails (tiny
        epsilon for float serialization only — no noise threshold)."""
        if fresh is None or base is None:
            self._verdict(False, f"{name}: metric missing "
                                 f"(fresh={fresh}, baseline={base})")
            return
        self._verdict(
            fresh <= base * (1.0 + 1e-6),
            f"{name}: {fresh:.4f} vs baseline {base:.4f} "
            "(deterministic — must not grow)")

    def invariant(self, name: str, ok: bool, detail: str = "") -> None:
        self._verdict(bool(ok), f"{name}{': ' + detail if detail else ''}")


def _row(payload: dict, key: str, value) -> Optional[dict]:
    for row in payload.get("results") or []:
        if row.get(key) == value:
            return row
    return None


def check_gibbs(gate: Gate, fresh: dict, base: dict) -> None:
    print("BENCH_gibbs.json:")
    reads = fresh.get("x_hbm_reads_per_sweep") or {}
    for path in ("fused_reference", "fused_pallas"):
        gate.invariant(f"x_hbm_reads_per_sweep[{path}] == 1",
                       reads.get(path) == 1, f"got {reads.get(path)}")
    smoke = _row(fresh, "path", "fused_interpret_smoke") or {}
    gate.invariant("megakernel chain_identical_to_reference",
                   smoke.get("chain_identical_to_reference") is True,
                   f"got {smoke.get('chain_identical_to_reference')}")
    f_ref, b_ref = (_row(fresh, "path", "reference"),
                    _row(base, "path", "reference"))
    gate.slower("hotpath reference ms_per_iter",
                (f_ref or {}).get("ms_per_iter"),
                (b_ref or {}).get("ms_per_iter"))
    f_pair, b_pair = (_row(fresh, "path", "reference_sweep_pair"),
                      _row(base, "path", "reference_sweep_pair"))
    gate.slower("reference_sweep_pair ms_per_sweep_fused",
                (f_pair or {}).get("ms_per_sweep_fused"),
                (b_pair or {}).get("ms_per_sweep_fused"))
    # the within-run pair: gated on SIGN, not magnitude — the one-read
    # body must never be slower than the three-pass body it replaced,
    # no matter how slow or loaded the runner is (the magnitude swings
    # ~1.2-1.8x with machine load even on one box)
    speedup = (f_pair or {}).get("fused_speedup")
    gate.invariant("reference_sweep_pair fused_speedup >= 1 "
                   "(one-read never slower than three-pass)",
                   speedup is not None and speedup >= 1.0,
                   f"got {speedup}")
    # sparse-K scaling (ISSUE 6): sweep cost tracks K_active, not k_max.
    # WITHIN-RUN pair — the k_max=512 slab at 8 live clusters vs the
    # k_max=32 slab at 8 live clusters, same machine same run, so the
    # gate holds regardless of runner class. Budget from the payload
    # (1.3x), applied to the fused AND reference bodies.
    def _krows(payload):
        return {(r.get("k_max"), r.get("k_active")): r
                for r in payload.get("results") or []
                if r.get("path") == "k_sweep"}
    budget = fresh.get("k_scaling_budget") or 1.3
    f_k, b_k = _krows(fresh), _krows(base)
    small, big = f_k.get((32, 8)), f_k.get((512, 8))
    for metric in ("ms_per_sweep_fused", "ms_per_sweep_reference"):
        sm, bg = (small or {}).get(metric), (big or {}).get(metric)
        if sm and bg:
            gate.invariant(
                f"k_sweep {metric} (512,8) within {budget}x of (32,8)",
                bg <= sm * budget, f"ratio {bg / sm:.3f}")
        else:
            gate.invariant(f"k_sweep rows present for {metric}", False,
                           f"missing (32,8)/(512,8) rows (got {sm}, {bg})")
    # paired vs baseline: every k_sweep row the baseline carries must not
    # slow down past the wall-clock envelope (baselines predating the
    # sparse-K grid simply have no rows to pair — nothing to gate)
    for key in sorted(b_k):
        frow = f_k.get(key)
        for metric in ("ms_per_sweep_fused", "ms_per_sweep_reference"):
            gate.slower(f"k_sweep[k_max={key[0]},K_active={key[1]}] "
                        f"{metric}",
                        (frow or {}).get(metric), b_k[key].get(metric))
    # fault-tolerance invariants (ISSUE 7): all within-run, read from the
    # FRESH payload only (no baseline pairing — they are booleans, and a
    # baseline predating the recovery leg must not mask them)
    rcv = _row(fresh, "path", "recovery") or {}
    gate.invariant("recovery guardrails_chain_neutral (clean fit bitwise "
                   "unchanged by NaN/divergence guardrails)",
                   rcv.get("guardrails_chain_neutral") is True,
                   f"got {rcv.get('guardrails_chain_neutral')}")
    gate.invariant("recovery faulted_fit_recovered (tiled fit under "
                   "injected transient faults completes, chain bitwise "
                   "clean, recoveries logged)",
                   rcv.get("faulted_fit_recovered") is True,
                   f"got {rcv.get('faulted_fit_recovered')} "
                   f"({rcv.get('n_injected_faults')} faults, "
                   f"{rcv.get('n_recovery_events')} events)")
    gate.invariant("recovery resume_bitwise (auto-checkpoint resume == "
                   "uninterrupted chain)",
                   rcv.get("resume_bitwise") is True,
                   f"got {rcv.get('resume_bitwise')}")


def check_scaling(gate: Gate, fresh: dict, base: dict) -> None:
    print("BENCH_scaling.json:")
    f_oo = (fresh.get("out_of_core") or {}).get("results") or []
    b_oo = (base.get("out_of_core") or {}).get("results") or []
    b_by_tile = {row.get("tile_size"): row for row in b_oo}
    if not f_oo:
        gate.invariant("out_of_core leg present", False, "no fresh rows")
    for row in f_oo:
        tile = row.get("tile_size")
        tag = f"tile_size={tile}"
        gate.invariant(f"oocore[{tag}] chain_identical_to_resident",
                       row.get("chain_identical_to_resident") is True,
                       f"got {row.get('chain_identical_to_resident')}")
        brow = b_by_tile.get(tile)
        if tile is not None:       # footprint ratio only meaningful tiled
            gate.not_growing(f"oocore[{tag}] resident_footprint_ratio",
                             row.get("resident_footprint_ratio"),
                             (brow or {}).get("resident_footprint_ratio"))
    # distributed invariants (ISSUE 9): all within-run, read from the
    # FRESH payload only — they are booleans comparing this run's
    # multi-process chains against this run's single-process baseline,
    # so a baseline predating the dist leg must not mask them
    f_dist = fresh.get("dist") or {}
    d_rows = [r for r in f_dist.get("results") or []
              if r.get("mode") == "distributed"]
    if not d_rows:
        gate.invariant("dist leg present", False, "no distributed rows")
    for row in d_rows:
        w = row.get("workers")
        gate.invariant(f"dist[workers={w}] dist_chain_bitwise "
                       "(multi-process chain == single-process chain)",
                       row.get("dist_chain_bitwise") is True,
                       f"got {row.get('dist_chain_bitwise')}")
        gate.invariant(f"dist[workers={w}] clean run has no failover "
                       "events",
                       row.get("n_failover_events") == 0,
                       f"got {row.get('n_failover_events')}")
    fo = f_dist.get("failover") or {}
    gate.invariant("dist failover_chain_bitwise (SIGKILL'd worker fails "
                   "over on the same bits)",
                   fo.get("failover_chain_bitwise") is True,
                   f"got {fo.get('failover_chain_bitwise')}")
    gate.invariant("dist failover logged >= 1 worker_failover event "
                   "(the kill actually landed)",
                   (fo.get("n_failover_events") or 0) >= 1,
                   f"got {fo.get('n_failover_events')}")


def _latency_row(payload: dict, engine, request_rows) -> Optional[dict]:
    for row in payload.get("results") or []:
        if (row.get("path") == "latency" and row.get("engine") == engine
                and row.get("request_rows") == request_rows):
            return row
    return None


def check_serve(gate: Gate, fresh: dict, base: dict) -> None:
    print("BENCH_serve.json:")
    inv = fresh.get("invariants") or {}
    gate.invariant("serve soft_matches_loglik",
                   inv.get("soft_matches_loglik") is True,
                   f"got {inv.get('soft_matches_loglik')}")
    # hot swap atomicity: always read from the FRESH payload — a stale
    # baseline must never vouch for this run's swap path
    gate.invariant("serve swap_staleness_bitwise",
                   inv.get("swap_staleness_bitwise") is True,
                   f"got {inv.get('swap_staleness_bitwise')}")
    # the ladder's acceptance criterion, as a within-run sign pair
    # (same machine, same run — runner class cannot mask or fake it):
    # a 256-row request through the multi-size ladder must beat the
    # old-style engine that pads it to 8192
    lad = _latency_row(fresh, "ladder", 256)
    pad = _latency_row(fresh, "padded_8192", 256)
    if lad is None or pad is None:
        gate.invariant("serve ladder vs padded latency rows present",
                       False, f"ladder={lad}, padded={pad}")
    else:
        gate.invariant(
            "serve ladder_p50_beats_padded (within-run, 256-row)",
            lad.get("p50_ms", float("inf")) < pad.get("p50_ms", 0.0),
            f"ladder p50 {lad.get('p50_ms')} ms vs padded "
            f"{pad.get('p50_ms')} ms")
    for brow in base.get("results") or []:
        if brow.get("path") == "latency":
            frow = _latency_row(fresh, brow.get("engine"),
                                brow.get("request_rows"))
            gate.slower(
                f"serve latency[{brow.get('engine')}, "
                f"req={brow.get('request_rows')}] p50_ms",
                (frow or {}).get("p50_ms"), brow.get("p50_ms"))
            continue
        batch = brow.get("batch_size")
        frow = _row(fresh, "batch_size", batch)
        gate.faster(f"serve[batch={batch}] queries_per_s",
                    (frow or {}).get("queries_per_s"),
                    brow.get("queries_per_s"))


CHECKS = {
    "BENCH_gibbs.json": check_gibbs,
    "BENCH_scaling.json": check_scaling,
    "BENCH_serve.json": check_serve,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir", default=".",
                    help="directory with the committed baseline JSONs")
    ap.add_argument("--fresh-dir", required=True,
                    help="directory with this run's freshly written JSONs")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="allowed fractional slowdown in paired metrics")
    ap.add_argument("--timing-threshold", type=float, default=None,
                    help="override the envelope for wall-clock metrics "
                         "only (ms/iter, queries/sec); defaults to "
                         "--threshold. Deterministic checks stay strict.")
    args = ap.parse_args(argv)

    gate = Gate(args.threshold,
                args.threshold if args.timing_threshold is None
                else args.timing_threshold)
    for name, check in CHECKS.items():
        fresh_path = os.path.join(args.fresh_dir, name)
        base_path = os.path.join(args.baseline_dir, name)
        if not os.path.exists(fresh_path):
            gate.invariant(f"{name} produced by the bench job", False,
                           f"missing {fresh_path}")
            continue
        if not os.path.exists(base_path):
            gate.invariant(f"{name} baseline committed", False,
                           f"missing {base_path}")
            continue
        with open(fresh_path) as f:
            fresh = json.load(f)
        with open(base_path) as f:
            base = json.load(f)
        check(gate, fresh, base)

    print(f"\n{gate.checks} checks, {len(gate.failures)} failures "
          f"(threshold {args.threshold:.0%})")
    if gate.failures:
        print("REGRESSION GATE FAILED:")
        for msg in gate.failures:
            print("  - " + msg)
        return 1
    print("regression gate: all clear")
    return 0


if __name__ == "__main__":
    sys.exit(main())
