"""DPMM serving: throughput, per-request latency percentiles, hot swap.

Fits a small DPGMM, round-trips it through the real checkpoint path
(core/checkpoint.py — so the bench exercises exactly what production
serving would load), then measures:

 - **throughput** (queries/sec) of ``DPMMEngine.query`` through
   single-size engines at several batch sizes, plus the
   sampled-assignment path — the PR-5 rows, schema unchanged so the
   committed baseline keeps pairing;
 - **per-request latency percentiles** (p50/p95/p99) for request sizes
   256/2048/8192 and a mixed-size trace, answered by (a) the ladder
   engine (``batch_sizes=(256, 2048, 8192)`` — each request routes to
   the smallest covering AOT step) and (b) the old-style single-8192
   engine that pads every request to 8192. The ladder's whole point is
   that a 256-row request stops paying the 8192 pad: the
   ``ladder_p50_beats_padded`` invariant pins p50(ladder, 256) strictly
   below p50(padded, 256) *within the same run* — machine class can't
   mask it.

Invariants in the JSON (gated by benchmarks/check_regression.py):

 - ``soft_matches_loglik`` — engine soft-assignment log-probs recomputed
   directly from ``family.loglik`` + renormalized log-weights agree to
   f32 ULPs; serving never drifts from the sampler's likelihood.
 - ``swap_staleness_bitwise`` — around ``engine.swap(ckpt_b)``, queries
   before the flip are bitwise a fresh checkpoint-A engine and queries
   after are bitwise a fresh checkpoint-B engine (and the epoch bumped):
   hot swap is atomic, never a blend.
 - ``ladder_p50_beats_padded`` — the acceptance criterion above.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import platform
import tempfile
import time

import numpy as np

SERVE_N, SERVE_D, SERVE_K = 20_000, 8, 8
BATCH_SIZES = (256, 2048, 8192)
N_QUERIES = 32_768
# requests per latency-trace leg, keyed by request size (smaller
# requests get more reps for stable percentiles)
LATENCY_REQS = {256: 40, 2048: 12, 8192: 6}
MIXED_TRACE = (256, 2048, 256, 256, 8192, 256, 2048, 256, 256, 2048,
               256, 8192, 256, 2048, 256, 256)


def _build_ckpts(iters: int, tmpdir: str):
    from repro.configs import DPMMConfig
    from repro.core.checkpoint import save_model
    from repro.core.sampler import DPMM
    from repro.data.synthetic import generate_gmm

    x, _ = generate_gmm(SERVE_N, SERVE_D, SERVE_K, seed=0, sep=8.0)
    cfg = DPMMConfig(alpha=10.0, iters=iters, k_max=32, burnout=5)
    result = DPMM(cfg).fit(x, n_chains=2).select_best()
    path_a = os.path.join(tmpdir, "bench_serve_ckpt.npz")
    save_model(path_a, result.state, "gaussian")
    # a second, different model for the hot-swap leg (shorter fit — it
    # only needs to be a valid state with different bits)
    cfg_b = dataclasses.replace(cfg, seed=1, iters=max(4, iters // 3))
    state_b = DPMM(cfg_b).fit(x).state
    path_b = os.path.join(tmpdir, "bench_serve_ckpt_b.npz")
    save_model(path_b, state_b, "gaussian")
    return path_a, path_b


def _soft_matches_loglik(engine, xq: np.ndarray) -> bool:
    """Recompute the soft assignment directly from family.loglik with
    eager jnp ops (same algorithm, different executable than the engine's
    compiled step) — must agree to f32 ULPs, labels exactly."""
    import jax.numpy as jnp
    from jax.scipy.special import logsumexp

    from repro.core.family import NEG_INF

    res = engine.query(xq)
    ll = engine.family.loglik(jnp.asarray(xq), engine.model.params)
    logits = jnp.where(engine.model.active[None, :],
                       ll + engine.logweights[None, :], NEG_INF)
    lp = np.asarray(logits - logsumexp(logits, axis=-1, keepdims=True))
    finite = np.isfinite(lp)
    return bool(
        np.allclose(res.logprobs[finite], lp[finite], rtol=1e-5, atol=1e-5)
        and np.array_equal(res.labels, np.asarray(logits).argmax(axis=1)))


def _bitwise(r1, r2) -> bool:
    return bool(np.array_equal(r1.labels, r2.labels)
                and np.array_equal(r1.logprobs, r2.logprobs)
                and np.array_equal(r1.log_predictive, r2.log_predictive))


def _swap_staleness_bitwise(ckpt_a: str, ckpt_b: str,
                            xq: np.ndarray) -> bool:
    """Hot swap atomicity: pre-swap answers are bitwise a fresh engine
    on checkpoint A, post-swap bitwise a fresh engine on B."""
    from repro.serve.dpmm import DPMMEngine, ServeConfig

    cfg = ServeConfig(batch_sizes=(256,))
    eng = DPMMEngine.from_checkpoint(ckpt_a, cfg)
    q = xq[:300]
    pre = eng.query(q)
    ref_a = DPMMEngine.from_checkpoint(ckpt_a, cfg).query(q)
    eng.swap(ckpt_b)
    post = eng.query(q)
    ref_b = DPMMEngine.from_checkpoint(ckpt_b, cfg).query(q)
    return (_bitwise(pre, ref_a) and _bitwise(post, ref_b)
            and post.model_epoch == pre.model_epoch + 1
            and not np.array_equal(pre.logprobs, post.logprobs))


def _requests(xq: np.ndarray, size: int, count: int):
    """``count`` consecutive ``size``-row slices, wrapping over xq."""
    out = []
    pos = 0
    for _ in range(count):
        if pos + size > xq.shape[0]:
            pos = 0
        out.append(xq[pos:pos + size])
        pos += size
    return out


def _percentiles(lat_s) -> dict:
    return {f"p{p}_ms": round(float(np.percentile(lat_s, p)) * 1e3, 3)
            for p in (50, 95, 99)}


def _latency_rows(engines: dict, xq: np.ndarray) -> list:
    """Per-request latency percentiles per engine, per request size and
    on the mixed trace — same request slices for every engine."""
    rows = []
    traces = [(size, _requests(xq, size, count))
              for size, count in sorted(LATENCY_REQS.items())]
    traces.append(("mixed", [q for size in MIXED_TRACE
                             for q in _requests(xq, size, 1)]))
    for name, engine in engines.items():
        for size in sorted(LATENCY_REQS):
            engine.query(xq[:size])                       # warm the route
        for size, reqs in traces:
            lat = []
            for q in reqs:
                t0 = time.perf_counter()
                engine.query(q)
                lat.append(time.perf_counter() - t0)
            row = {"path": "latency", "engine": name,
                   "request_rows": size, "n_requests": len(reqs),
                   **_percentiles(lat)}
            rows.append(row)
            print("  " + "  ".join(f"{k}={v}" for k, v in row.items()),
                  flush=True)
    return rows


def run(iters: int = 20, reps: int = 10,
        out_json: str = "BENCH_serve.json") -> dict:
    import jax

    from repro.serve.dpmm import DPMMEngine, ServeConfig

    rng = np.random.default_rng(1)
    xq = rng.standard_normal((N_QUERIES, SERVE_D)).astype(np.float32)

    with tempfile.TemporaryDirectory() as tmpdir:
        ckpt, ckpt_b = _build_ckpts(iters, tmpdir)
        rows = []
        invariant = None
        engines = {}
        for batch in BATCH_SIZES:
            t0 = time.perf_counter()
            engine = DPMMEngine.from_checkpoint(
                ckpt, ServeConfig(batch_sizes=(batch,)))
            build_s = time.perf_counter() - t0
            engines[batch] = engine
            if invariant is None:        # once; batch-size independent
                invariant = _soft_matches_loglik(engine, xq[:4096])
            engine.query(xq[:batch])                    # steady-state
            times = []
            for _ in range(reps):
                t0 = time.perf_counter()
                engine.query(xq)
                times.append(time.perf_counter() - t0)
            dt = float(np.median(times))
            t0 = time.perf_counter()
            engine.sample(xq, seed=0)
            dt_sample = time.perf_counter() - t0
            row = {
                "batch_size": batch,
                "n_queries": N_QUERIES,
                "queries_per_s": round(N_QUERIES / dt, 1),
                "ms_per_request": round(dt * 1e3, 3),
                "sampled_queries_per_s": round(N_QUERIES / dt_sample, 1),
                "engine_build_s": round(build_s, 3),
            }
            rows.append(row)
            print("  " + "  ".join(f"{k}={v}" for k, v in row.items()),
                  flush=True)

        # latency leg: the multi-size ladder vs the old-style engine
        # that pads every request to its single 8192 step. The ladder
        # shares its executables with the single-size engines above
        # (process-wide step table), so building it here is cheap.
        ladder = DPMMEngine.from_checkpoint(
            ckpt, ServeConfig(batch_sizes=BATCH_SIZES))
        rows += _latency_rows(
            {"ladder": ladder, "padded_8192": engines[BATCH_SIZES[-1]]},
            xq)
        lat = {(r["engine"], r["request_rows"]): r
               for r in rows if r.get("path") == "latency"}
        ladder_wins = bool(lat[("ladder", 256)]["p50_ms"]
                           < lat[("padded_8192", 256)]["p50_ms"])

        swap_ok = _swap_staleness_bitwise(ckpt, ckpt_b, xq)

    payload = {
        "bench": "serve",
        "backend": jax.default_backend(),
        "host": platform.platform(),
        "config": {"component": "gaussian", "fit_N": SERVE_N,
                   "d": SERVE_D, "K_true": SERVE_K, "k_max": 32,
                   "fit_iters": iters, "n_queries": N_QUERIES,
                   "ladder": list(BATCH_SIZES)},
        "results": rows,
        "invariants": {"soft_matches_loglik": invariant,
                       "engine_from_checkpoint": True,
                       "swap_staleness_bitwise": swap_ok,
                       "ladder_p50_beats_padded": ladder_wins},
    }
    with open(out_json, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"[bench_serve] wrote {out_json}")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=20,
                    help="fit iterations for the served model")
    ap.add_argument("--reps", type=int, default=10)
    ap.add_argument("--out-json", default="BENCH_serve.json")
    args = ap.parse_args(argv)
    run(iters=args.iters, reps=args.reps, out_json=args.out_json)


if __name__ == "__main__":
    main()
