"""DPMM serving throughput: queries/sec through the precompiled engine.

Fits a small DPGMM, round-trips it through the real checkpoint path
(core/checkpoint.py — so the bench exercises exactly what production
serving would load), then measures steady-state throughput of
``DPMMEngine.query`` at several batch sizes, plus the sampled-assignment
path. Persists BENCH_serve.json next to BENCH_gibbs.json /
BENCH_scaling.json so CI's regression gate (benchmarks/check_regression.py)
tracks serving perf per PR.

An accuracy invariant rides along: the engine's soft-assignment
log-probs are recomputed directly from ``family.loglik`` + the
renormalized log-weights and compared to f32 ULPs
(``soft_matches_loglik`` in the JSON) — the serving path must never
drift from the sampler's likelihood.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import tempfile
import time

import numpy as np

SERVE_N, SERVE_D, SERVE_K = 20_000, 8, 8
BATCH_SIZES = (256, 2048, 8192)
N_QUERIES = 32_768


def _build_engine_ckpt(iters: int, tmpdir: str) -> str:
    from repro.configs import DPMMConfig
    from repro.core.checkpoint import save_model
    from repro.core.sampler import DPMM
    from repro.data.synthetic import generate_gmm

    x, _ = generate_gmm(SERVE_N, SERVE_D, SERVE_K, seed=0, sep=8.0)
    cfg = DPMMConfig(alpha=10.0, iters=iters, k_max=32, burnout=5)
    result = DPMM(cfg).fit(x, n_chains=2).select_best()
    path = os.path.join(tmpdir, "bench_serve_ckpt.npz")
    save_model(path, result.state, "gaussian")
    return path


def _soft_matches_loglik(engine, xq: np.ndarray) -> bool:
    """Recompute the soft assignment directly from family.loglik with
    eager jnp ops (same algorithm, different executable than the engine's
    compiled step) — must agree to f32 ULPs, labels exactly."""
    import jax.numpy as jnp
    from jax.scipy.special import logsumexp

    from repro.core.family import NEG_INF

    res = engine.query(xq)
    ll = engine.family.loglik(jnp.asarray(xq), engine.model.params)
    logits = jnp.where(engine.model.active[None, :],
                       ll + engine.logweights[None, :], NEG_INF)
    lp = np.asarray(logits - logsumexp(logits, axis=-1, keepdims=True))
    finite = np.isfinite(lp)
    return bool(
        np.allclose(res.logprobs[finite], lp[finite], rtol=1e-5, atol=1e-5)
        and np.array_equal(res.labels, np.asarray(logits).argmax(axis=1)))


def run(iters: int = 20, reps: int = 10,
        out_json: str = "BENCH_serve.json") -> dict:
    import jax

    from repro.serve.dpmm import DPMMEngine

    rng = np.random.default_rng(1)
    xq = rng.standard_normal((N_QUERIES, SERVE_D)).astype(np.float32)

    with tempfile.TemporaryDirectory() as tmpdir:
        ckpt = _build_engine_ckpt(iters, tmpdir)
        rows = []
        invariant = None
        for batch in BATCH_SIZES:
            t0 = time.perf_counter()
            engine = DPMMEngine.from_checkpoint(ckpt, batch_size=batch)
            build_s = time.perf_counter() - t0
            if invariant is None:        # once; batch-size independent
                invariant = _soft_matches_loglik(engine, xq[:4096])
            engine.query(xq[:batch])                    # steady-state
            times = []
            for _ in range(reps):
                t0 = time.perf_counter()
                engine.query(xq)
                times.append(time.perf_counter() - t0)
            dt = float(np.median(times))
            t0 = time.perf_counter()
            engine.sample(xq, seed=0)
            dt_sample = time.perf_counter() - t0
            row = {
                "batch_size": batch,
                "n_queries": N_QUERIES,
                "queries_per_s": round(N_QUERIES / dt, 1),
                "ms_per_request": round(dt * 1e3, 3),
                "sampled_queries_per_s": round(N_QUERIES / dt_sample, 1),
                "engine_build_s": round(build_s, 3),
            }
            rows.append(row)
            print("  " + "  ".join(f"{k}={v}" for k, v in row.items()),
                  flush=True)

    payload = {
        "bench": "serve",
        "backend": jax.default_backend(),
        "host": platform.platform(),
        "config": {"component": "gaussian", "fit_N": SERVE_N,
                   "d": SERVE_D, "K_true": SERVE_K, "k_max": 32,
                   "fit_iters": iters, "n_queries": N_QUERIES},
        "results": rows,
        "invariants": {"soft_matches_loglik": invariant,
                       "engine_from_checkpoint": True},
    }
    with open(out_json, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"[bench_serve] wrote {out_json}")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=20,
                    help="fit iterations for the served model")
    ap.add_argument("--reps", type=int, default=10)
    ap.add_argument("--out-json", default="BENCH_serve.json")
    args = ap.parse_args(argv)
    run(iters=args.iters, reps=args.reps, out_json=args.out_json)


if __name__ == "__main__":
    main()
