"""Benchmark entry point — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Sections:
  gibbs      — Figs 4-7: DPGMM/DPMNMM time + NMI across (N, d, K)
  scaling    — §4.4/§4.5: O(N K d^2) runtime scaling + weak scaling
  kernels    — §4.2: two-kernel auto-selection crossover (C5)
  real_data  — Figs 8-9: real-shaped datasets (structural analogue)
  roofline   — §Roofline table from the dry-run artifacts
  serve      — DPMMEngine throughput (queries/sec -> BENCH_serve.json)
"""
from __future__ import annotations

import argparse
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweeps (hours)")
    ap.add_argument("--only", default="",
                    help="comma list: gibbs,scaling,kernels,real_data,"
                         "roofline,serve")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (bench_gibbs, bench_kernels, bench_real_data,
                            bench_roofline, bench_scaling, bench_serve)
    sections = [
        ("gibbs", lambda: bench_gibbs.run(full=args.full)),
        ("scaling", bench_scaling.run),
        ("kernels", bench_kernels.run),
        ("real_data", lambda: bench_real_data.run(quick=not args.full)),
        ("roofline", bench_roofline.run),
        ("serve", bench_serve.run),
    ]
    for name, fn in sections:
        if only and name not in only:
            continue
        print(f"\n=== {name} " + "=" * (68 - len(name)))
        t0 = time.time()
        fn()
        print(f"=== {name} done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
