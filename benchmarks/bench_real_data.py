"""Paper §5.3 analogue (Figs 8-9): DPMM on 'real-shaped' data.

The container is offline, so the mnist / fashion-mnist / ImageNet-100 /
20newsgroups tables are reproduced *structurally*: datasets with the same
(N, d, K) and PCA-like spectral decay (features = Gaussian blobs mixed
through a low-rank map + heavy-tail noise, counts = Zipfian topic draws for
the 20news analogue). Same pipeline, same metrics (NMI, wall time), same
comparison (DPGMM vs DPMNMM paths).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Table
from repro.configs import DPMMConfig
from repro.core.sampler import DPMM

DATASETS = [
    # name, N, d, K, kind  (paper's PCA dims)
    ("mnist-like", 60_000, 32, 10, "gaussian"),
    ("fashion-like", 60_000, 32, 10, "gaussian"),
    ("imagenet100-like", 125_000, 64, 100, "gaussian"),
    ("20news-like", 11_314, 512, 20, "multinomial"),   # d reduced 20k->512
]


def _pca_like_gaussian(n, d, k, seed):
    """Blobs through a random low-rank map with decaying spectrum (PCA-ish)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d)) * 3.0
    spectrum = 1.0 / np.sqrt(1 + np.arange(d))
    labels = rng.integers(0, k, n)
    x = centers[labels] + rng.normal(size=(n, d)) * spectrum
    return x.astype(np.float32), labels.astype(np.int32)


def _topic_like_counts(n, d, k, seed, length=120):
    rng = np.random.default_rng(seed)
    topics = rng.dirichlet(np.full(d, 0.05), size=k)     # sparse topics
    labels = rng.integers(0, k, n)
    x = np.stack([rng.multinomial(length, topics[j]) for j in labels])
    return x.astype(np.float32), labels.astype(np.int32)


def run(quick: bool = True, out_dir: str = "experiments"):
    t = Table("real_data", ["dataset", "N", "d", "K_true", "K_found",
                            "nmi", "s_total"])
    import time
    for name, n, d, k, kind in DATASETS:
        if quick:                              # CPU container budget
            n = min(n, 20_000)
        seed = abs(hash(name)) % 2 ** 16
        if kind == "gaussian":
            x, gt = _pca_like_gaussian(n, d, k, seed)
            cfg = DPMMConfig(alpha=10.0, iters=40, k_max=max(2 * k, 32),
                             burnout=5)
        else:
            x, gt = _topic_like_counts(n, d, k, seed)
            cfg = DPMMConfig(component="multinomial", alpha=10.0, iters=40,
                             k_max=max(2 * k, 32), burnout=5)
        t0 = time.time()
        r = DPMM(cfg).fit(x)
        t.add(name, n, d, k, r.k, f"{r.nmi(gt):.3f}",
              f"{time.time()-t0:.1f}")
    t.emit_csv(f"{out_dir}/bench_real_data.csv")
    return t


if __name__ == "__main__":
    run()
