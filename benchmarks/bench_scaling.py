"""Paper §4.4 complexity claim (C4): per-iteration time is O(N * K * T)
with T = d^2 (Gaussian) — verified by scaling one variable at a time —
and §4.5 memory O(d * N). Also the weak-scaling distribution claim: time
per iteration vs device count at fixed work per device.
"""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import Table
from repro.configs import DPMMConfig
from repro.core.distributed import make_data_mesh
from repro.core.sampler import DPMM
from repro.data.synthetic import generate_gmm


def _ms_per_iter(n, d, k_init, iters=12, mesh=None, k_max=32):
    x, _ = generate_gmm(n, d, max(k_init, 2), seed=0, sep=8.0)
    cfg = DPMMConfig(alpha=10.0, iters=iters, k_max=k_max,
                     burnout=iters + 1,              # pure Gibbs: isolate N*K*T
                     init_clusters=k_init)
    r = DPMM(cfg, mesh=mesh).fit(x)
    return float(np.mean(r.iter_times_s[2:]) * 1e3), r


def run(out_dir: str = "experiments"):
    t = Table("scaling", ["axis", "value", "ms_per_iter", "ratio_vs_prev"])
    prev = None
    for n in (10_000, 20_000, 40_000, 80_000):        # expect ~linear
        ms, _ = _ms_per_iter(n, 8, 8)
        t.add("N", n, f"{ms:.2f}", f"{ms/prev:.2f}" if prev else "-")
        prev = ms
    prev = None
    for d in (4, 8, 16, 32):                          # expect ~quadratic (T=d^2)
        ms, _ = _ms_per_iter(20_000, d, 8)
        t.add("d", d, f"{ms:.2f}", f"{ms/prev:.2f}" if prev else "-")
        prev = ms
    prev = None
    for k in (4, 8, 16, 32):                          # expect ~linear
        ms, _ = _ms_per_iter(20_000, 8, k, k_max=64)
        t.add("K", k, f"{ms:.2f}", f"{ms/prev:.2f}" if prev else "-")
        prev = ms
    # weak scaling across devices (fixed per-device N)
    n_dev = jax.device_count()
    per_dev = 20_000
    prev = None
    for nd in sorted({1, max(n_dev // 2, 1), n_dev}):
        ms, _ = _ms_per_iter(per_dev * nd, 8, 8, mesh=make_data_mesh(nd))
        t.add(f"devices(weak,{per_dev}/dev)", nd, f"{ms:.2f}",
              f"{ms/prev:.2f}" if prev else "-")
        prev = ms
    t.emit_csv(f"{out_dir}/bench_scaling.csv")
    return t


if __name__ == "__main__":
    run()
