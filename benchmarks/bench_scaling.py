"""Paper §4.4 complexity claim (C4): per-iteration time is O(N * K * T)
with T = d^2 (Gaussian) — verified by scaling one variable at a time —
and §4.5 memory O(d * N). Also the weak-scaling distribution claim: time
per iteration vs device count at fixed work per device.

Results persist to BENCH_scaling.json (same schema spirit as
BENCH_gibbs.json) so CI tracks the trajectory per PR. `--oocore` runs the
CI-friendly seconds-scale slice: the out-of-core leg (ms/iter and peak
device bytes vs `tile_size` at fixed N — peak memory falls roughly
linearly with tile size while ms/iter stays flat, because tiling only
changes *where* points wait, not what math runs; chains are bitwise
identical across planes, tests/test_tiled_parity.py) PLUS a small default
N-sweep so the `scaling` field records ms/iter vs N on every CI run, not
only under the full grid.
"""
from __future__ import annotations

import argparse
import json
import platform

import numpy as np

import jax

from benchmarks.common import Table
from repro.configs import DPMMConfig
from repro.core.distributed import make_data_mesh
from repro.core.sampler import DPMM
from repro.data.source import HostTiledSource
from repro.data.synthetic import generate_gmm

OOCORE_N, OOCORE_D, OOCORE_K = 60_000, 8, 8
OOCORE_TILES = (None, 16_384, 4_096, 1_024)   # None = resident baseline


def _ms_per_iter(n, d, k_init, iters=12, mesh=None, k_max=32):
    x, _ = generate_gmm(n, d, max(k_init, 2), seed=0, sep=8.0)
    cfg = DPMMConfig(alpha=10.0, iters=iters, k_max=k_max,
                     burnout=iters + 1,              # pure Gibbs: isolate N*K*T
                     init_clusters=k_init)
    r = DPMM(cfg, mesh=mesh).fit(x)
    return float(np.mean(r.iter_times_s[2:]) * 1e3), r


def run(out_dir: str = "experiments",
        out_json: str = "BENCH_scaling.json", oocore_iters: int = 12):
    t = Table("scaling", ["axis", "value", "ms_per_iter", "ratio_vs_prev"])
    rows = []

    def leg(axis, value, ms, prev):
        t.add(axis, value, f"{ms:.2f}", f"{ms/prev:.2f}" if prev else "-")
        rows.append({"axis": axis, "value": value, "ms_per_iter": ms})

    prev = None
    for n in (10_000, 20_000, 40_000, 80_000):        # expect ~linear
        ms, _ = _ms_per_iter(n, 8, 8)
        leg("N", n, ms, prev)
        prev = ms
    prev = None
    for d in (4, 8, 16, 32):                          # expect ~quadratic (T=d^2)
        ms, _ = _ms_per_iter(20_000, d, 8)
        leg("d", d, ms, prev)
        prev = ms
    prev = None
    for k in (4, 8, 16, 32):                          # expect ~linear
        ms, _ = _ms_per_iter(20_000, 8, k, k_max=64)
        leg("K", k, ms, prev)
        prev = ms
    # weak scaling across devices (fixed per-device N)
    n_dev = jax.device_count()
    per_dev = 20_000
    prev = None
    for nd in sorted({1, max(n_dev // 2, 1), n_dev}):
        ms, _ = _ms_per_iter(per_dev * nd, 8, 8, mesh=make_data_mesh(nd))
        leg(f"devices(weak,{per_dev}/dev)", nd, ms, prev)
        prev = ms
    t.emit_csv(f"{out_dir}/bench_scaling.csv")
    _write_json(out_json, scaling=rows,
                oocore=run_oocore(iters=oocore_iters),
                dist=run_dist(iters=oocore_iters))
    return t


SMOKE_NS = (10_000, 20_000, 40_000)


def run_scaling_smoke(iters: int = 10):
    """The CI-mode N-sweep: ms/iter vs N at fixed (d, K) — expect ~linear.

    A reduced slice of the full `run()` sweep so BENCH_scaling.json's
    `scaling` field is populated on every CI run (it used to be null
    outside the long-form grid).
    """
    rows = []
    prev = None
    for n in SMOKE_NS:
        ms, _ = _ms_per_iter(n, 8, 8, iters=iters)
        row = {"axis": "N", "value": n, "ms_per_iter": ms,
               "ratio_vs_prev": round(ms / prev, 3) if prev else None,
               "mode": "ci_smoke"}
        prev = ms
        rows.append(row)
        print("  " + "  ".join(f"{k}={v}" for k, v in row.items()),
              flush=True)
    return rows


def run_oocore(iters: int = 12, n: int = OOCORE_N, d: int = OOCORE_D):
    """The out-of-core leg: resident vs streamed tiles at fixed N.

    The point array lives host-side behind a ``HostTiledSource`` for the
    tiled legs; only O(k_max + tile) bytes are ever device-resident
    (``FitResult.device_bytes``), at ms/iter flat within noise — N is
    bounded by host storage, not device HBM. ``est_peak_bytes`` is the
    analytic accounting over persistent device buffers (the CPU backend
    reports no memory_stats); backends that measure also record
    ``peak_bytes_in_use``.
    """
    x, gt = generate_gmm(n, d, OOCORE_K, seed=0, sep=8.0)
    x = np.asarray(x, np.float32)
    rows = []
    resident_peak = None
    baseline = None
    for tile in OOCORE_TILES:
        cfg = DPMMConfig(alpha=10.0, iters=iters, k_max=32, burnout=4,
                         tile_size=tile)
        data = x if tile is None else HostTiledSource(x)
        r = DPMM(cfg).fit(data)
        ms = float(np.mean(r.iter_times_s[1:]) * 1e3)
        peak = r.device_bytes["est_peak_bytes"]
        if tile is None:
            resident_peak = peak
            baseline = r
        row = {
            "tile_size": tile,
            "mode": r.device_bytes["mode"],
            "ms_per_iter": ms,
            "est_peak_device_bytes": peak,
            "peak_bytes_in_use": r.device_bytes["peak_bytes_in_use"],
            # source is leg-accurate now: the resident baseline fit sets
            # the process RSS high-water mark, so the later tiled fits in
            # this same process report 'process_peak_rss_stale' plus their
            # own per-leg delta instead of re-claiming the resident peak
            "peak_bytes_source": r.device_bytes["peak_bytes_source"],
            "peak_rss_delta_bytes": r.device_bytes.get(
                "peak_rss_delta_bytes"),
            "resident_footprint_ratio": round(peak / resident_peak, 4),
            "K_found": r.k,
            "nmi": round(r.nmi(gt), 4),
            "chain_identical_to_resident": bool(
                np.array_equal(r.labels, baseline.labels)),
        }
        rows.append(row)
        print("  " + "  ".join(f"{k}={v}" for k, v in row.items()),
              flush=True)
    return {"config": {"component": "gaussian", "N": n, "d": d,
                       "K_true": OOCORE_K, "k_max": 32, "iters": iters},
            "results": rows}


DIST_N, DIST_D, DIST_ITERS = 20_000, 8, 8


def run_dist(iters: int = DIST_ITERS, n: int = DIST_N, d: int = DIST_D):
    """The elastic multi-process leg (repro.dist): ms/iter at workers in
    {1, 2} vs the single-process tiled fit, plus a failover run where
    worker 0 is SIGKILL'd mid-fit.

    Two invariants ride along, gated by check_regression.py:
    ``dist_chain_bitwise`` (every worker count reproduces the
    single-process chain bit-for-bit — worker count is a wall-clock
    knob, never a chain knob) and ``failover_chain_bitwise`` (the
    SIGKILL'd run completes via reassignment + respawn on the SAME
    bits, with the failover logged in FitResult.recoveries). At this
    CI scale the socket hop dominates, so ms/iter is reported for
    trajectory, not gated pairwise.
    """
    import os
    import signal
    import time as _time

    from repro.core.gibbs import STATS_BLOCK
    from repro.dist import DistHooks

    x, _ = generate_gmm(n, d, OOCORE_K, seed=0, sep=8.0)
    x = np.asarray(x, np.float32)
    base_kw = dict(alpha=10.0, iters=iters, k_max=32, burnout=4,
                   tile_size=STATS_BLOCK)
    baseline = DPMM(DPMMConfig(**base_kw),
                    mesh=make_data_mesh(1)).fit(HostTiledSource(x))
    base_ms = float(np.mean(baseline.iter_times_s[1:]) * 1e3)
    rows = [{"workers": 0, "mode": "single_process", "ms_per_iter": base_ms,
             "dist_chain_bitwise": True, "wall_s": None,
             "n_failover_events": 0}]
    print("  " + "  ".join(f"{k}={v}" for k, v in rows[0].items()),
          flush=True)

    def bitwise(r):
        return bool(np.array_equal(r.labels, baseline.labels) and all(
            np.array_equal(r.history[k], baseline.history[k])
            for k in baseline.history))

    for w in (1, 2):
        t0 = _time.time()
        r = DPMM(DPMMConfig(workers=w, **base_kw)).fit(x)
        row = {"workers": w, "mode": "distributed",
               "ms_per_iter": float(np.mean(r.iter_times_s[1:]) * 1e3),
               "dist_chain_bitwise": bitwise(r),
               "wall_s": round(_time.time() - t0, 2),
               "n_failover_events": len([e for e in r.recoveries
                                         if e["kind"] == "worker_failover"])}
        rows.append(row)
        print("  " + "  ".join(f"{k}={v}" for k, v in row.items()),
              flush=True)

    killed = []

    def killer(it, coord):
        if it == 2 and not killed:
            os.kill(coord.worker_pids()[0], signal.SIGKILL)
            killed.append(it)

    t0 = _time.time()
    r = DPMM(DPMMConfig(workers=2, **base_kw)).fit(
        x, dist_hooks=DistHooks(on_iteration=killer))
    failover = {
        "workers": 2, "mode": "distributed_failover",
        "ms_per_iter": float(np.mean(r.iter_times_s[1:]) * 1e3),
        "failover_chain_bitwise": bitwise(r),
        "failover_wall_s": round(_time.time() - t0, 2),
        "n_failover_events": len([e for e in r.recoveries
                                  if e["kind"] == "worker_failover"]),
        "reassignments": r.dist["reassignments"],
        "respawns": r.dist["respawns"],
    }
    print("  " + "  ".join(f"{k}={v}" for k, v in failover.items()),
          flush=True)
    return {"config": {"component": "gaussian", "N": n, "d": d,
                       "k_max": 32, "iters": iters,
                       "tile_size": STATS_BLOCK},
            "results": rows, "failover": failover}


def _write_json(out_json: str, scaling=None, oocore=None, dist=None):
    payload = {
        "bench": "scaling",
        "backend": jax.default_backend(),
        "host": platform.platform(),
        "scaling": scaling,
        "out_of_core": oocore,
        "dist": dist,
    }
    with open(out_json, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"[bench_scaling] wrote {out_json}")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--oocore", action="store_true",
                    help="only the out-of-core tile_size leg (CI-friendly)")
    ap.add_argument("--iters", type=int, default=12)
    ap.add_argument("--out-dir", default="experiments")
    ap.add_argument("--out-json", default="BENCH_scaling.json")
    args = ap.parse_args(argv)
    if args.oocore:
        _write_json(args.out_json,
                    scaling=run_scaling_smoke(iters=args.iters),
                    oocore=run_oocore(iters=args.iters),
                    dist=run_dist(iters=args.iters))
    else:
        run(out_dir=args.out_dir, out_json=args.out_json,
            oocore_iters=args.iters)


if __name__ == "__main__":
    main()
