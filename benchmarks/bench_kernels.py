"""Paper §4.2 (claim C5): the two-matmul-kernel auto-selection. Re-measures
the Pallas-vs-XLA crossover on THIS host (the paper measured 640k d*N on a
Quadro RTX 4000) and times the loglik / suffstats kernels vs their oracles.

On CPU the Pallas kernels run interpret=True (Python), so absolute numbers
are NOT TPU performance — the deliverable is the *mechanism* + the oracle
timings; on a real TPU the same script reports the true crossover.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import Table, time_fn
from repro.kernels import ops, ref


def run(out_dir: str = "experiments"):
    rng = np.random.default_rng(0)
    t = Table("kernels", ["kernel", "shape", "dN", "pallas_ms", "xla_ms",
                          "winner"])
    crossover = None
    for m, k in [(64, 64), (256, 256), (512, 512), (1024, 1024),
                 (2048, 2048)]:
        a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(k, m)), jnp.float32)
        tp = time_fn(ops.matmul_pallas, a, b) * 1e3
        tx = time_fn(jax.jit(ref.matmul), a, b) * 1e3
        winner = "pallas" if tp < tx else "xla"
        if winner == "xla" and crossover is None:
            crossover = m * k
        t.add("matmul", f"{m}x{k}", m * k, f"{tp:.2f}", f"{tx:.2f}", winner)
    print(f"  measured crossover (d*N) on this host: "
          f"{crossover or '>4.2M'} (paper: 640k on RTX 4000; "
          f"interpret-mode on CPU => XLA wins everywhere, as expected)")

    for n, k, d in [(2_000, 16, 16), (10_000, 32, 32)]:
        x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        mu = jnp.asarray(rng.normal(size=(k, d)), jnp.float32)
        f = jnp.asarray(rng.normal(size=(k, d, d)) * 0.2 + np.eye(d),
                        jnp.float32)
        ld = jnp.asarray(rng.normal(size=(k,)), jnp.float32)
        tp = time_fn(ops.loglik_pallas, x, mu, f, ld) * 1e3
        tx = time_fn(jax.jit(ref.loglik), x, mu, f, ld) * 1e3
        t.add("loglik", f"N{n}K{k}d{d}", n * d, f"{tp:.2f}", f"{tx:.2f}",
              "pallas" if tp < tx else "xla")
        resp = jnp.asarray(np.eye(k)[rng.integers(0, k, n)], jnp.float32)
        tp = time_fn(ops.suffstats_pallas, x, resp) * 1e3
        tx = time_fn(jax.jit(ref.suffstats), x, resp) * 1e3
        t.add("suffstats", f"N{n}K{k}d{d}", n * d, f"{tp:.2f}", f"{tx:.2f}",
              "pallas" if tp < tx else "xla")
    t.emit_csv(f"{out_dir}/bench_kernels.csv")
    return t


if __name__ == "__main__":
    run()
