"""Paper Figs 4 & 6 analogue: DPGMM / DPMNMM running time across (N, d, K).

The paper sweeps N in 1e3..1e6, d in 2..128, K in 4..32 over 100 iters x 10
repeats; a single CPU container gets a reduced-but-representative slice
(full sweep via --full). Reports per-iteration time and final NMI/K so both
the speed (Figs 4, 6) and accuracy (Figs 5, 7) tables come from one run.
`--smoke` runs a seconds-scale slice for CI: it reports ms/iter for the
chunked scan driver at the default `log_every` AND at `log_every=1`
(per-iteration host sync — the pre-scan-driver behaviour), so driver perf
regressions and host-sync overhead are both visible in the log.

`--hotpath` tracks the perf trajectory of the fused sweep: steady-state
ms/iter and peak memory (device `memory_stats()` where the backend reports
it, else process peak RSS — `peak_bytes_source` records which) for the jnp
reference path vs the fused Pallas path, persisted to BENCH_gibbs.json so
CI can track the numbers per PR. On non-TPU backends the *timed* Pallas
leg is skipped (interpret-mode Pallas executes the kernel body in Python —
not a performance measurement; `--force-fused` overrides), but two CPU-
runnable legs always execute: an interpret-mode smoke fit that runs the
one-read megakernel end-to-end and checks its chain bitwise against the
reference, and a paired jitted-sweep microbench of the one-read blocked
reference body vs the pre-fusion three-pass body at d>=16 (the
`x_hbm_reads_per_sweep` 3 -> 1 claim, measured). A fourth CPU-runnable
leg sweeps (k_max, K_active) over the sparse-K grid (`k_sweep` rows):
per-sweep time of the compacted fused and reference bodies under a
k_max=512 slab at K_active in {8, 32, 128, 512} vs the small-slab
anchor (32, 8) — the O(K_active)-not-O(k_max) claim, gated at 1.3x by
benchmarks/check_regression.py.
"""
from __future__ import annotations

import argparse
import json
import platform

import numpy as np

from benchmarks.common import Table
from repro.configs import DPMMConfig
from repro.core.sampler import DPMM
from repro.data.synthetic import generate_gmm, generate_mnmm

GAUSS_GRID = [            # (N, d, K)
    (1_000, 2, 4), (10_000, 2, 8), (10_000, 16, 8),
    (50_000, 2, 10), (50_000, 32, 8), (100_000, 8, 16),
]
MULT_GRID = [
    (1_000, 8, 4), (10_000, 32, 8), (50_000, 64, 8),
]
FULL_GAUSS_GRID = [(n, d, k) for n in (10**3, 10**4, 10**5, 10**6)
                   for d in (2, 8, 32, 128) for k in (4, 16)]


def run(full: bool = False, iters: int = 40, out_dir: str = "experiments"):
    t = Table("gibbs", ["component", "N", "d", "K_true", "iters",
                        "ms_per_iter", "K_found", "nmi"])
    grid = FULL_GAUSS_GRID if full else GAUSS_GRID
    for n, d, k in grid:
        x, gt = generate_gmm(n, d, k, seed=0, sep=8.0)
        cfg = DPMMConfig(alpha=10.0, iters=iters, k_max=64, burnout=5)
        r = DPMM(cfg).fit(x)
        ms = float(np.mean(r.iter_times_s[1:]) * 1e3)
        t.add("gaussian", n, d, k, iters, f"{ms:.1f}", r.k,
              f"{r.nmi(gt):.3f}")
    for n, d, k in (MULT_GRID if not full else
                    [(n, d, k) for n in (10**3, 10**4, 10**5)
                     for d in (8, 32, 128) for k in (4, 16) if d >= k]):
        x, gt = generate_mnmm(n, d, k, seed=0)
        cfg = DPMMConfig(component="multinomial", alpha=10.0, iters=iters,
                         k_max=64, burnout=5)
        r = DPMM(cfg).fit(x)
        ms = float(np.mean(r.iter_times_s[1:]) * 1e3)
        t.add("multinomial", n, d, k, iters, f"{ms:.1f}", r.k,
              f"{r.nmi(gt):.3f}")
    t.emit_csv(f"{out_dir}/bench_gibbs.csv")
    return t


def run_smoke(iters: int = 30) -> float:
    """CI canary: one small DPGMM fit, chunked vs per-iteration host sync."""
    n, d, k = 20_000, 2, 8
    x, gt = generate_gmm(n, d, k, seed=0, sep=8.0)

    def ms_per_iter(log_every: int) -> float:
        cfg = DPMMConfig(alpha=10.0, iters=iters, k_max=32, burnout=5,
                         log_every=log_every)
        r = DPMM(cfg).fit(x)
        # fit() compiles chunks ahead-of-time, outside the timed region, so
        # dropping the usual warm-up iteration is enough
        return float(np.mean(r.iter_times_s[1:]) * 1e3)

    ms_per_iter(10)   # process warm-up (allocator/thread pools), discarded
    ms_chunked = ms_per_iter(10)
    ms_synced = ms_per_iter(1)
    print(f"smoke N={n} d={d} K={k} iters={iters}: "
          f"{ms_chunked:.1f} ms/iter (log_every=10, scan driver)  vs  "
          f"{ms_synced:.1f} ms/iter (log_every=1, per-iter host sync; "
          f"overhead {ms_synced - ms_chunked:+.1f} ms/iter)")
    return ms_chunked


HOTPATH_N, HOTPATH_D, HOTPATH_K, HOTPATH_KMAX = 50_000, 16, 8, 32
_ROW_MARK = "HOTPATH_ROW "


def _hbm_intermediate_floats(n: int, k: int, d: int) -> dict:
    """Per-sweep HBM intermediates of the assignment + stats path (floats).

    Dominant terms, including the (N, *, d) pairwise-contraction
    intermediates XLA materializes for the three-operand sxx einsums:
    seed: (N,K) logits + Gumbel + (N,K,2) all-K sub-loglik + (N,K) resp +
    (N,K,2) subresp + ~3NKd einsum temporaries. reference (this PR):
    (N,K) logits (+Gumbel fused by XLA); the Gaussian additionally pays
    one (N,2K) one-hot and its ~2NKd sxx einsum temporary, while the
    linear families segment-sum with no dense responsibilities at all.
    fused: none — labels and stats stream out of VMEM tiles.
    """
    return {"seed": 7 * n * k + 3 * n * k * d,
            "reference_gaussian": n * k + 2 * n * k * d + 2 * n * k,
            "reference_linear": n * k,
            "fused": 0}


def _hotpath_leg(use_pallas: bool, iters: int) -> dict:
    """One measured leg; run in its OWN process so the process-lifetime
    memory peak (device memory_stats or RSS) is per-path, not a running
    max over whichever leg happened to run first. Within the process the
    warm-up fit still raises the RSS high-water mark before the timed
    fit, so the timed fit's RSS is recorded as a per-leg *delta* against
    a baseline taken after warm-up (``peak_rss_delta_bytes``) and the
    source field says whether the absolute number is leg-accurate
    (``process_peak_rss``) or inherited (``process_peak_rss_stale``)."""
    import jax

    from repro.core.sampler import _measured_peak, _rss_peak_bytes

    n, d, k = HOTPATH_N, HOTPATH_D, HOTPATH_K
    x, gt = generate_gmm(n, d, k, seed=0, sep=8.0)

    def fit():
        cfg = DPMMConfig(alpha=10.0, iters=iters, k_max=HOTPATH_KMAX,
                         burnout=5, use_pallas=use_pallas)
        return DPMM(cfg).fit(x)

    fit()                                # process warm-up, discarded...
    base, _ = _measured_peak()           # ...but it sets the same peak
    rss_before = _rss_peak_bytes()
    r = fit()
    peak, src = _measured_peak(rss_before)
    delta = (max(peak - rss_before, 0)
             if src.startswith("process_peak_rss") else None)
    row = {"path": "fused" if use_pallas else "reference",
           "backend": jax.default_backend(),
           "ms_per_iter": float(np.mean(r.iter_times_s[1:]) * 1e3),
           "K_found": r.k, "nmi": round(r.nmi(gt), 4),
           "peak_bytes_in_use": peak,
           "peak_bytes_source": src,
           "peak_rss_delta_bytes": delta,
           "warmup_peak_bytes_in_use": base}
    print(_ROW_MARK + json.dumps(row), flush=True)
    return row


def _hotpath_interp_smoke(iters: int) -> dict:
    """Tiny-N interpret-mode smoke leg: actually EXECUTES the one-read
    Pallas megakernel on this backend (interpret mode off-TPU) through a
    full fit and checks its chain bitwise against the jnp reference fit —
    so CI exercises the kernel path everywhere, while the timed fused leg
    stays TPU-only. Not a performance measurement."""
    import jax

    n, d, k = 2048, 8, 4
    x, gt = generate_gmm(n, d, k, seed=0, sep=8.0)

    def fit(use_pallas):
        cfg = DPMMConfig(alpha=10.0, iters=iters, k_max=16, burnout=3,
                         use_pallas=use_pallas)
        return DPMM(cfg).fit(x)

    fused = fit(True)
    ref = fit(False)
    # the CHAIN is bitwise: labels and the integer-derived history traces.
    # The "score" trace is a float32 diagnostic recomputed inside each
    # program; Pallas-vs-jnp programs fuse its log-marginal sum
    # differently, so it carries compilation-level ULPs (checked to
    # tolerance, not bit equality — same contract as cross-plane params).
    same = bool(
        np.array_equal(fused.labels, ref.labels)
        and all(np.array_equal(fused.history[key], ref.history[key])
                for key in fused.history if key != "score")
        and np.allclose(fused.history["score"], ref.history["score"],
                        rtol=1e-3, atol=1.0))
    row = {"path": "fused_interpret_smoke",
           "backend": jax.default_backend(),
           "N": n, "d": d, "iters": iters,
           "interpret_mode": jax.default_backend() != "tpu",
           "K_found": fused.k, "nmi": round(fused.nmi(gt), 4),
           "chain_identical_to_reference": same}
    print(_ROW_MARK + json.dumps(row), flush=True)
    return row


def _hotpath_sweep_pair(reps: int = 15) -> dict:
    """Paired jitted-sweep microbench at d>=16: the one-read blocked
    reference body vs the pre-fusion three-pass body (same chain, bitwise
    — tests/test_fused_sweep.py), isolating the HBM-traffic cut from
    fit-level noise. Runs on any backend."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.core import gibbs
    from repro.core.family import get_family
    from repro.core.sampler import _init_local

    n, d, k_max = HOTPATH_N, HOTPATH_D, HOTPATH_KMAX
    fam = get_family("gaussian")
    x, _ = generate_gmm(n, d, HOTPATH_K, seed=0, sep=8.0)
    x = jnp.asarray(x)
    valid = jnp.ones((n,), jnp.float32)
    cfg = DPMMConfig(alpha=10.0, init_clusters=HOTPATH_K, k_max=k_max)
    prior = fam.build_prior(cfg, x)
    model, point = _init_local(jax.random.key(0), x, valid, prior=prior,
                               family=fam, cfg=cfg, axes=(), k_max=k_max)
    gidx = jnp.arange(n, dtype=jnp.uint32)

    def make(fused):
        def sweep(m, xx, p):
            acc = gibbs.empty_substats(fam, k_max, d)
            return gibbs.sweep_tile(m, xx, p, gidx, acc, fam, fused=fused)
        return jax.jit(sweep).lower(model, x, point).compile()

    def median_ms(fn):
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(model, x, point))
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts) * 1e3)

    f3, ff = make(False), make(True)
    ms3, msf = median_ms(f3), median_ms(ff)
    row = {"path": "reference_sweep_pair", "backend": jax.default_backend(),
           "N": n, "d": d, "k_max": k_max,
           "ms_per_sweep_three_pass": ms3, "ms_per_sweep_fused": msf,
           "fused_speedup": round(ms3 / msf, 3)}
    print(_ROW_MARK + json.dumps(row), flush=True)
    return row


K_SWEEP_GRID = [      # (k_max, K_active)
    (32, 8), (512, 8), (512, 32), (512, 128), (512, 512)]


def _hotpath_k_sweep(reps: int = 15) -> dict:
    """Sparse-K scaling leg (ISSUE 6): per-sweep time vs K_active under a
    large k_max slab, for the fused one-read body AND the three-pass
    reference body, both run exactly as the fit driver runs them — the
    compaction plan built from the active mask, the compact-slab sweep
    tile, and the scatter back to the dense slab all inside the timed
    jitted unit. The claim under test: sweep cost is O(K_active), not
    O(k_max) — a k_max=512 slab with 8 live clusters must cost what a
    k_max=32 slab with 8 live clusters costs (the 1.3x acceptance gate in
    benchmarks/check_regression.py). The (512, 512) row is the saturated
    slab — compaction disabled by the schedule (k_compact >= k_max), the
    honest dense upper bound. Runs on any backend (jnp bodies)."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.core import gibbs
    from repro.core.family import get_family
    from repro.core.sampler import _init_local, _k_compact

    n, d = 20_000, 8
    fam = get_family("gaussian")
    x, _ = generate_gmm(n, d, 8, seed=0, sep=8.0)
    x = jnp.asarray(x)
    valid = jnp.ones((n,), jnp.float32)
    gidx = jnp.arange(n, dtype=jnp.uint32)
    rows = []
    for k_max, k_active in K_SWEEP_GRID:
        cfg = DPMMConfig(alpha=10.0, init_clusters=k_active, k_max=k_max)
        prior = fam.build_prior(cfg, x)
        model, point = _init_local(
            jax.random.key(0), x, valid, prior=prior, family=fam, cfg=cfg,
            axes=(), k_max=k_max)
        k_c = _k_compact(k_active, 1, k_max, cfg.k_block)

        def make(fused):
            def sweep1(m, xx, p):
                if k_c is None:                      # saturated: dense
                    acc = gibbs.empty_substats(fam, k_max, d)
                    return gibbs.sweep_tile(m, xx, p, gidx, acc, fam,
                                            fused=fused)
                plan = gibbs.compaction_plan(m.active, k_c)
                acc = gibbs.empty_substats(fam, k_c, d)
                pt, acc2 = gibbs.sweep_tile(m, xx, p, gidx, acc, fam,
                                            fused=fused, plan=plan,
                                            k_block=cfg.k_block)
                return pt, gibbs.compact_scatter(plan, k_max, acc2)
            return jax.jit(sweep1).lower(model, x, point).compile()

        def median_ms(fn):
            ts = []
            for _ in range(reps):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(model, x, point))
                ts.append(time.perf_counter() - t0)
            return float(np.median(ts) * 1e3)

        f3, ff = make(False), make(True)
        row = {"path": "k_sweep", "backend": jax.default_backend(),
               "N": n, "d": d, "k_max": k_max, "k_active": k_active,
               "k_compact": k_c,
               "ms_per_sweep_reference": median_ms(f3),
               "ms_per_sweep_fused": median_ms(ff)}
        rows.append(row)
        print(_ROW_MARK + json.dumps(row), flush=True)
    return rows


def _hotpath_recovery() -> dict:
    """Fault-tolerance invariants leg (ISSUE 7), CPU-runnable, seconds-
    scale. Asserts the three contracts the resilience layer makes and
    emits them as a gated row (benchmarks/check_regression.py):

     - ``guardrails_chain_neutral``: a clean fit with the NaN/divergence
       guardrails ON is bitwise the fit with them OFF (the health check
       is a separate jitted program — it must never perturb the chain);
     - ``faulted_fit_recovered``: a tiled fit under a seeded transient
       fault schedule (IOError + NaN tiles + short reads) completes,
       logs recoveries, and its chain is bitwise the clean fit's;
     - ``resume_bitwise``: kill-at-half + ``fit(resume=True)`` from the
       auto-checkpoint rotation reproduces the uninterrupted chain
       bitwise.
    """
    import os
    import tempfile

    import jax
    import jax.numpy as jnp

    from repro.data.faults import FaultInjectingSource
    from repro.data.source import HostTiledSource

    def raw(leaf):
        if jnp.issubdtype(jnp.asarray(leaf).dtype, jax.dtypes.prng_key):
            return np.asarray(jax.random.key_data(leaf))
        return np.asarray(leaf)

    def same_chain(a, b):
        return bool(np.array_equal(a.labels, b.labels) and all(
            np.array_equal(raw(x), raw(y)) for x, y in
            zip(jax.tree_util.tree_leaves(a.state),
                jax.tree_util.tree_leaves(b.state))))

    n, d, k = 4096, 8, 4
    x, _ = generate_gmm(n, d, k, seed=0, sep=8.0)
    x = np.asarray(x, np.float32)

    def fit_resident(iters, **kw):
        cfg = DPMMConfig(alpha=10.0, iters=iters, k_max=16, burnout=3,
                         log_every=4, **kw)
        return DPMM(cfg).fit(x)

    # 1. guardrail neutrality (resident driver, the golden-chain plane)
    r_on = fit_resident(12, guardrails=True)
    r_off = fit_resident(12, guardrails=False)
    neutral = same_chain(r_on, r_off) and not r_on.recoveries

    # 2. faulted tiled fit == clean tiled fit, with recoveries logged
    cfg_t = DPMMConfig(alpha=10.0, iters=8, k_max=16, burnout=3,
                       tile_size=512)
    clean = DPMM(cfg_t).fit(HostTiledSource(x))
    src = FaultInjectingSource(HostTiledSource(x), seed=7, p_io=0.05,
                               p_nan=0.04, p_short=0.04)
    faulted = DPMM(cfg_t).fit(src)
    recovered = (bool(src.injected) and bool(faulted.recoveries)
                 and same_chain(clean, faulted))

    # 3. checkpoint/resume round trip (interrupt at half, resume to end)
    with tempfile.TemporaryDirectory() as tmp:
        pref = os.path.join(tmp, "ck")
        cfg_ck = dict(checkpoint_path=pref, checkpoint_every=4)
        fit_resident(8, **cfg_ck)                      # "killed" at 8
        resumed = DPMM(DPMMConfig(alpha=10.0, iters=16, k_max=16,
                                  burnout=3, log_every=4, **cfg_ck)
                       ).fit(x, resume=True)
    full = fit_resident(16)
    resume_ok = same_chain(resumed, full)

    row = {"path": "recovery", "backend": jax.default_backend(),
           "N": n, "d": d,
           "guardrails_chain_neutral": neutral,
           "faulted_fit_recovered": recovered,
           "n_injected_faults": len(src.injected),
           "n_recovery_events": len(faulted.recoveries),
           "resume_bitwise": resume_ok}
    print(_ROW_MARK + json.dumps(row), flush=True)
    return row


def run_hotpath(iters: int = 30, out_path: str = "BENCH_gibbs.json",
                force_fused: bool = False) -> dict:
    """Reference vs fused steady-state ms/iter + peak memory -> JSON.

    Each path runs in a subprocess (see _hotpath_leg) so its peak device
    memory is isolated AND the parent never initializes JAX — on TPU the
    parent grabbing the device would force every child leg onto CPU. The
    backend is whatever the reference leg reports.
    """
    import os
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root] +
        ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))

    def leg(path_name: str) -> list:
        proc = subprocess.run(
            [sys.executable, os.path.abspath(__file__),
             "--_hotpath-leg", path_name, "--iters", str(iters)],
            capture_output=True, text=True, env=env, cwd=root)
        out = []
        for line in proc.stdout.splitlines():
            if line.startswith(_ROW_MARK):
                row = json.loads(line[len(_ROW_MARK):])
                print("  " + "  ".join(f"{k}={v}" for k, v in row.items()),
                      flush=True)
                out.append(row)
        if not out:
            raise RuntimeError(
                f"hotpath leg {path_name!r} produced no row:\n"
                f"{proc.stdout[-1000:]}\n{proc.stderr[-2000:]}")
        return out

    rows = leg("reference")
    backend = rows[0].get("backend", "unknown")
    if backend == "tpu" or force_fused:
        rows += leg("fused")
    else:
        rows.append({"path": "fused", "skipped":
                     f"interpret-mode Pallas on backend={backend!r} is "
                     "Python-speed; measure on TPU (or --force-fused)"})
    # CPU-runnable legs: megakernel executed end-to-end (interpret) with a
    # bitwise chain check, the paired one-read-vs-three-pass sweep, and
    # the sparse-K scaling grid (cost tracks K_active, not k_max)
    rows += leg("interp-smoke")
    rows += leg("sweep-pair")
    rows += leg("k-sweep")
    # fault-tolerance invariants (ISSUE 7): guardrail chain-neutrality,
    # faulted-fit recovery, checkpoint/resume bitwise round trip
    rows += leg("recovery")
    payload = {
        "bench": "gibbs_hotpath",
        "backend": backend,
        "host": platform.platform(),
        "config": {"component": "gaussian", "N": HOTPATH_N, "d": HOTPATH_D,
                   "K_true": HOTPATH_K, "k_max": HOTPATH_KMAX,
                   "iters": iters},
        "hbm_intermediate_floats_per_sweep": _hbm_intermediate_floats(
            HOTPATH_N, HOTPATH_KMAX, HOTPATH_D),
        # full passes of x streamed from HBM per sweep (steps e + f + the
        # suff-stat fold): the seed and the pre-PR-4 reference each read
        # every tile three times; the one-read bodies read it once on both
        # paths (enforced structurally by tests/test_fused_sweep.py)
        "x_hbm_reads_per_sweep": {"seed": 3, "pre_pr4_reference": 3,
                                  "fused_reference": 1, "fused_pallas": 1},
        # sparse-K acceptance (ISSUE 6): a k_max=512 slab at K_active=8
        # must sweep within this factor of a k_max=32 slab at K_active=8,
        # on the fused AND reference bodies (gated by check_regression.py
        # from the k_sweep rows — cost tracks K_active, not k_max)
        "k_scaling_budget": 1.3,
        "results": rows,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"[gibbs_hotpath] wrote {out_path}")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI slice instead of the paper grid")
    ap.add_argument("--hotpath", action="store_true",
                    help="reference-vs-fused sweep hot path -> "
                         "BENCH_gibbs.json (perf trajectory)")
    ap.add_argument("--force-fused", action="store_true",
                    help="run the fused leg of --hotpath even off-TPU "
                         "(interpret mode; plumbing check, not perf)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--out-dir", default="experiments")
    ap.add_argument("--out-json", default="BENCH_gibbs.json")
    ap.add_argument("--_hotpath-leg", dest="hotpath_leg", default=None,
                    choices=["reference", "fused", "interp-smoke",
                             "sweep-pair", "k-sweep", "recovery"],
                    help=argparse.SUPPRESS)
    args = ap.parse_args(argv)
    if args.hotpath_leg == "interp-smoke":
        _hotpath_interp_smoke(min(args.iters or 8, 8))
    elif args.hotpath_leg == "sweep-pair":
        _hotpath_sweep_pair()
    elif args.hotpath_leg == "k-sweep":
        _hotpath_k_sweep()
    elif args.hotpath_leg == "recovery":
        _hotpath_recovery()
    elif args.hotpath_leg:
        _hotpath_leg(args.hotpath_leg == "fused", args.iters or 30)
    elif args.hotpath:
        run_hotpath(args.iters or 30, out_path=args.out_json,
                    force_fused=args.force_fused)
    elif args.smoke:
        run_smoke(args.iters or 30)
    else:
        run(full=args.full, iters=args.iters or 40, out_dir=args.out_dir)


if __name__ == "__main__":
    main()
