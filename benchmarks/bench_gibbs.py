"""Paper Figs 4 & 6 analogue: DPGMM / DPMNMM running time across (N, d, K).

The paper sweeps N in 1e3..1e6, d in 2..128, K in 4..32 over 100 iters x 10
repeats; a single CPU container gets a reduced-but-representative slice
(full sweep via --full). Reports per-iteration time and final NMI/K so both
the speed (Figs 4, 6) and accuracy (Figs 5, 7) tables come from one run.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Table
from repro.configs import DPMMConfig
from repro.core.sampler import DPMM
from repro.data.synthetic import generate_gmm, generate_mnmm

GAUSS_GRID = [            # (N, d, K)
    (1_000, 2, 4), (10_000, 2, 8), (10_000, 16, 8),
    (50_000, 2, 10), (50_000, 32, 8), (100_000, 8, 16),
]
MULT_GRID = [
    (1_000, 8, 4), (10_000, 32, 8), (50_000, 64, 8),
]
FULL_GAUSS_GRID = [(n, d, k) for n in (10**3, 10**4, 10**5, 10**6)
                   for d in (2, 8, 32, 128) for k in (4, 16)]


def run(full: bool = False, iters: int = 40, out_dir: str = "experiments"):
    t = Table("gibbs", ["component", "N", "d", "K_true", "iters",
                        "ms_per_iter", "K_found", "nmi"])
    grid = FULL_GAUSS_GRID if full else GAUSS_GRID
    for n, d, k in grid:
        x, gt = generate_gmm(n, d, k, seed=0, sep=8.0)
        cfg = DPMMConfig(alpha=10.0, iters=iters, k_max=64, burnout=5)
        r = DPMM(cfg).fit(x)
        ms = float(np.mean(r.iter_times_s[1:]) * 1e3)
        t.add("gaussian", n, d, k, iters, f"{ms:.1f}", r.k,
              f"{r.nmi(gt):.3f}")
    for n, d, k in (MULT_GRID if not full else
                    [(n, d, k) for n in (10**3, 10**4, 10**5)
                     for d in (8, 32, 128) for k in (4, 16) if d >= k]):
        x, gt = generate_mnmm(n, d, k, seed=0)
        cfg = DPMMConfig(component="multinomial", alpha=10.0, iters=iters,
                         k_max=64, burnout=5)
        r = DPMM(cfg).fit(x)
        ms = float(np.mean(r.iter_times_s[1:]) * 1e3)
        t.add("multinomial", n, d, k, iters, f"{ms:.1f}", r.k,
              f"{r.nmi(gt):.3f}")
    t.emit_csv(f"{out_dir}/bench_gibbs.csv")
    return t


if __name__ == "__main__":
    run()
