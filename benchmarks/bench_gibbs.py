"""Paper Figs 4 & 6 analogue: DPGMM / DPMNMM running time across (N, d, K).

The paper sweeps N in 1e3..1e6, d in 2..128, K in 4..32 over 100 iters x 10
repeats; a single CPU container gets a reduced-but-representative slice
(full sweep via --full). Reports per-iteration time and final NMI/K so both
the speed (Figs 4, 6) and accuracy (Figs 5, 7) tables come from one run.
`--smoke` runs a seconds-scale slice for CI: it reports ms/iter for the
chunked scan driver at the default `log_every` AND at `log_every=1`
(per-iteration host sync — the pre-scan-driver behaviour), so driver perf
regressions and host-sync overhead are both visible in the log.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import Table
from repro.configs import DPMMConfig
from repro.core.sampler import DPMM
from repro.data.synthetic import generate_gmm, generate_mnmm

GAUSS_GRID = [            # (N, d, K)
    (1_000, 2, 4), (10_000, 2, 8), (10_000, 16, 8),
    (50_000, 2, 10), (50_000, 32, 8), (100_000, 8, 16),
]
MULT_GRID = [
    (1_000, 8, 4), (10_000, 32, 8), (50_000, 64, 8),
]
FULL_GAUSS_GRID = [(n, d, k) for n in (10**3, 10**4, 10**5, 10**6)
                   for d in (2, 8, 32, 128) for k in (4, 16)]


def run(full: bool = False, iters: int = 40, out_dir: str = "experiments"):
    t = Table("gibbs", ["component", "N", "d", "K_true", "iters",
                        "ms_per_iter", "K_found", "nmi"])
    grid = FULL_GAUSS_GRID if full else GAUSS_GRID
    for n, d, k in grid:
        x, gt = generate_gmm(n, d, k, seed=0, sep=8.0)
        cfg = DPMMConfig(alpha=10.0, iters=iters, k_max=64, burnout=5)
        r = DPMM(cfg).fit(x)
        ms = float(np.mean(r.iter_times_s[1:]) * 1e3)
        t.add("gaussian", n, d, k, iters, f"{ms:.1f}", r.k,
              f"{r.nmi(gt):.3f}")
    for n, d, k in (MULT_GRID if not full else
                    [(n, d, k) for n in (10**3, 10**4, 10**5)
                     for d in (8, 32, 128) for k in (4, 16) if d >= k]):
        x, gt = generate_mnmm(n, d, k, seed=0)
        cfg = DPMMConfig(component="multinomial", alpha=10.0, iters=iters,
                         k_max=64, burnout=5)
        r = DPMM(cfg).fit(x)
        ms = float(np.mean(r.iter_times_s[1:]) * 1e3)
        t.add("multinomial", n, d, k, iters, f"{ms:.1f}", r.k,
              f"{r.nmi(gt):.3f}")
    t.emit_csv(f"{out_dir}/bench_gibbs.csv")
    return t


def run_smoke(iters: int = 30) -> float:
    """CI canary: one small DPGMM fit, chunked vs per-iteration host sync."""
    n, d, k = 20_000, 2, 8
    x, gt = generate_gmm(n, d, k, seed=0, sep=8.0)

    def ms_per_iter(log_every: int) -> float:
        cfg = DPMMConfig(alpha=10.0, iters=iters, k_max=32, burnout=5,
                         log_every=log_every)
        r = DPMM(cfg).fit(x)
        # fit() compiles chunks ahead-of-time, outside the timed region, so
        # dropping the usual warm-up iteration is enough
        return float(np.mean(r.iter_times_s[1:]) * 1e3)

    ms_per_iter(10)   # process warm-up (allocator/thread pools), discarded
    ms_chunked = ms_per_iter(10)
    ms_synced = ms_per_iter(1)
    print(f"smoke N={n} d={d} K={k} iters={iters}: "
          f"{ms_chunked:.1f} ms/iter (log_every=10, scan driver)  vs  "
          f"{ms_synced:.1f} ms/iter (log_every=1, per-iter host sync; "
          f"overhead {ms_synced - ms_chunked:+.1f} ms/iter)")
    return ms_chunked


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI slice instead of the paper grid")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--out-dir", default="experiments")
    args = ap.parse_args(argv)
    if args.smoke:
        run_smoke(args.iters or 30)
    else:
        run(full=args.full, iters=args.iters or 40, out_dir=args.out_dir)


if __name__ == "__main__":
    main()
