"""Trip-count-aware FLOP / HBM-traffic / collective accounting from the
compiled (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` counts a while-loop body ONCE — useless for
scan-over-layers programs (verified: a 10-step scanned matmul reports 1/10
of the unrolled flops). This module walks the HLO call graph instead:

 - every computation's own dot flops:  2 * numel(result) * prod(contracted)
 - while bodies scaled by ``backend_config known_trip_count``
 - fusions/calls/conditionals recursed with multiplier 1
 - HBM-traffic proxy: per *top-level* instruction, result bytes + operand
   bytes (fusion internals live on-chip); free ops (tuple/gte/bitcast/
   parameter/constant) skipped
 - collective result bytes per opcode, same trip scaling
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id",
             # copy/convert are CPU-backend materializations of loop-carried
             # state and dot-input precision changes; the TPU compiler
             # donates/fuses them (verified: they dominate decode 'traffic'
             # by >10x while touching no new data — §Perf C3)
             "copy", "convert"}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:\S+))\s+"
    r"([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s*(?:\([^)]*\))?\s*->.*{")
_TRIP_RE = re.compile(r'known_trip_count[\\"={:]+n[\\"]*:[\\"]*(\d+)')
_CALLED_RE = re.compile(
    r"(?:calls|condition|body|to_apply)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_list(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _shape_list(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _numel(shape_str: str) -> int:
    total = 0
    for _, dims in _shape_list(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.flops = 0.0
        self.bytes = 0.0
        self.transcendentals = 0.0
        self.coll: Dict[str, float] = {}
        # (multiplier, [called computation names], count_bytes)
        self.calls: List[Tuple[float, List[str], bool]] = []


def parse_module(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Computation | None = None
    entry = None
    # per-computation name -> (bytes, dims) for operand lookups
    local_bytes: Dict[str, int] = {}
    local_dims: Dict[str, List[int]] = {}

    for raw in hlo.splitlines():
        line = raw.rstrip()
        # computation headers sit at column 0: `%name (args) -> type {`
        if ((line.startswith("%") or line.startswith("ENTRY"))
                and line.endswith("{") and "->" in line):
            tok = line.split()[1] if line.startswith("ENTRY") \
                else line.split()[0]
            name = tok.split("(")[0].lstrip("%")
            cur = comps.setdefault(name, Computation(name))
            if line.startswith("ENTRY"):
                entry = name
            local_bytes = {}
            local_dims = {}
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        iname, result_shape, opcode, rest = mi.groups()
        rbytes = _shape_bytes(result_shape)
        local_bytes[iname] = rbytes
        local_dims[iname] = _dims_of(result_shape)

        base = opcode[:-6] if opcode.endswith("-start") else opcode
        if opcode.endswith("-done"):
            continue

        # --- child computations ---------------------------------------
        mult = 1.0
        if base == "while":
            mt = _TRIP_RE.search(line)
            mult = float(mt.group(1)) if mt else 1.0
        called: List[str] = [m.group(1) for m in _CALLED_RE.finditer(line)]
        for m in _BRANCHES_RE.finditer(line):
            called.extend(c.strip().lstrip("%") for c in m.group(1).split(","))
        if called:
            # fusion bodies live on-chip: count their flops, not bytes
            cur.calls.append((mult, called, base != "fusion"))

        # --- flops ------------------------------------------------------
        if base == "dot":
            contracted = 1
            mcd = _CONTRACT_RE.search(line)
            if mcd:
                ops = _first_operands(rest)
                lhs_dims = local_dims.get(ops[0], []) if ops else []
                for ci in (int(x) for x in mcd.group(1).split(",") if x):
                    if ci < len(lhs_dims):
                        contracted *= lhs_dims[ci]
            cur.flops += 2.0 * _numel(result_shape) * contracted
        elif base in ("exponential", "tanh", "log", "rsqrt", "sqrt",
                      "power", "logistic"):
            cur.transcendentals += _numel(result_shape)

        # --- bytes (HBM-traffic proxy, top level only) -------------------
        if base not in _FREE_OPS:
            opn = _first_operands(rest)
            op_sizes = [local_bytes.get(o, 0) for o in opn]
            obytes = sum(op_sizes)
            if ("dynamic-update-slice" in iname
                    or "dynamic_update_slice" in iname
                    or base == "dynamic-update-slice"):
                # in-place update: only the written slice moves; the big
                # aliased buffer (result == largest operand) is free
                # (otherwise a 32k-token KV-cache write counts as a full
                # cache rewrite per decode step — §Perf C3 analyzer fix)
                big = max(op_sizes, default=0)
                cur.bytes += max(rbytes + obytes - big - min(rbytes, big),
                                 2 * (obytes - big))
            elif "slice" in iname or "gather" in iname.replace(
                    "all-gather", ""):
                # slice/gather-style ops touch only what they produce
                cur.bytes += rbytes + min(obytes, 2 * rbytes)
            else:
                cur.bytes += rbytes + obytes

        # --- collectives --------------------------------------------------
        if base in _COLLECTIVES:
            cur.coll[base] = cur.coll.get(base, 0.0) + rbytes

    comps["__entry__"] = comps.get(entry, Computation("none"))
    return comps


def _first_operands(rest: str) -> List[str]:
    """Operand names from the '(...)' argument list opening at `rest`."""
    depth = 1
    args = []
    buf = ""
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        if depth >= 1:
            buf += ch
    for part in buf.split(","):
        part = part.strip()
        if part.startswith("%"):
            args.append(part)
        else:
            m = re.match(r"^[\w\[\]{},.]*\s*(%[\w.\-]+)", part)
            if m:
                args.append(m.group(1))
    return args


def _dims_of(shape_str: str) -> List[int]:
    ms = _SHAPE_RE.search(shape_str)
    if not ms:
        return []
    return [int(d) for d in ms.group(2).split(",") if d]


class ModuleCosts:
    def __init__(self, flops: float, bytes_: float, coll: Dict[str, float],
                 transcendentals: float):
        self.flops = flops
        self.bytes = bytes_
        self.coll = coll
        self.transcendentals = transcendentals


def analyze_hlo(hlo: str) -> ModuleCosts:
    comps = parse_module(hlo)
    entry = comps["__entry__"]
    memo: Dict[str, Tuple[float, float, Dict[str, float], float]] = {}

    def total(name: str, seen=()) -> Tuple[float, float, Dict[str, float],
                                           float]:
        if name in memo:
            return memo[name]
        if name not in comps or name in seen:
            return (0.0, 0.0, {}, 0.0)
        c = comps[name]
        f, b, t = c.flops, c.bytes, c.transcendentals
        coll = dict(c.coll)
        for mult, called, count_bytes in c.calls:
            for ch in called:
                cf, cb, cc, ct = total(ch, seen + (name,))
                f += mult * cf
                b += mult * (cb if count_bytes else 0.0)
                t += mult * ct
                for k, v in cc.items():
                    coll[k] = coll.get(k, 0.0) + mult * v
        memo[name] = (f, b, coll, t)
        return memo[name]

    f, b, coll, t = total(entry.name)
    return ModuleCosts(f, b, coll, t)
