"""MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = *active* params.

N counts non-embedding parameters; MoE routed-expert weights are scaled by
``top_k / num_experts`` (only the routed-to experts do work per token).
Attention score/value FLOPs are excluded — the standard MFU convention —
which is exactly why ``useful_ratio`` drops for the 32k-context shapes
(the compiled HLO *does* pay the attention quadratic).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import transformer


def _tree_size(tree: Any) -> int:
    return sum(int(jnp.size(x)) if hasattr(x, "size") else 0
               for x in jax.tree.leaves(tree))


def active_params(cfg: ModelConfig) -> int:
    """Active non-embedding parameter count (analytic, from eval_shape)."""
    structs = jax.eval_shape(
        lambda k: transformer.init_params(k, cfg, jnp.bfloat16),
        jax.random.key(0))
    total = 0
    moe_scale = 1.0
    if cfg.moe is not None:
        moe_scale = cfg.moe.top_k / cfg.moe.num_experts

    def walk(tree):
        """Routed expert weights (moe/w_*) scale by top_k/E; routers and
        shared experts are always-on; embeddings are excluded."""
        nonlocal total
        if not isinstance(tree, dict):
            total += int(tree.size)
            return
        for k, v in tree.items():
            if k == "embed":
                continue
            if k == "moe":
                for kk, vv in v.items():
                    scale = moe_scale if kk.startswith("w_") else 1.0
                    for leaf in jax.tree.leaves(vv):
                        total += int(leaf.size * scale)
            else:
                walk(v)

    walk(structs)
    return total


def model_flops_per_device(cfg: ModelConfig, shape: InputShape,
                           chips: int) -> float:
    n = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        per_tok = 6.0                          # fwd 2 + bwd 4
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        per_tok = 2.0
    else:                                      # decode: one token per seq
        tokens = shape.global_batch * 1
        per_tok = 2.0
    return per_tok * n * tokens / chips
