"""Three-term roofline from a compiled (SPMD-partitioned) XLA module.

    compute    = FLOPs_per_device / PEAK_FLOPS
    memory     = bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW

FLOPs/bytes come from ``compiled.cost_analysis()`` (per-device, since the
compiled module is post-partitioning). collective_bytes is NOT in
cost_analysis: we parse ``compiled.as_text()`` and sum the *result* sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (async ``-start`` forms counted once).

MODEL_FLOPS = 6·N·D (dense; N = active params excluding embeddings) gives
the useful-compute ratio that catches remat/redundancy waste.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Dict, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# one shaped buffer: f32[128,256]{1,0} — captures (dtype, dims)
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
# an HLO instruction line: %name = <result-shape(s)> opcode(...)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-buffer bytes per collective opcode over the module."""
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        result_shape, opcode = m.groups()
        base = opcode
        if base.endswith("-start"):
            base = base[:-6]
        elif base.endswith("-done"):
            continue                      # counted at -start
        if base in _COLLECTIVES:
            out[base] += _shape_bytes(result_shape)
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes: Dict[str, int]
    model_flops: float               # 6*N_active*D (per device share)
    # memory_analysis fields (per device)
    mem_args: int = 0
    mem_output: int = 0
    mem_temp: int = 0
    mem_peak: int = 0

    @property
    def collective_total(self) -> int:
        return sum(self.coll_bytes.values())

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_total / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        if self.flops_per_device <= 0:
            return 0.0
        return self.model_flops / self.flops_per_device

    @property
    def step_time_bound(self) -> float:
        """Lower bound on step time: max of the three terms (no overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_ratio=self.useful_ratio,
                 collective_total=self.collective_total,
                 step_time_bound=self.step_time_bound)
        return d

    def row(self) -> str:
        return (f"{self.arch:>22s} {self.shape:>12s} {self.mesh:>9s} "
                f"{self.t_compute*1e3:10.2f} {self.t_memory*1e3:10.2f} "
                f"{self.t_collective*1e3:10.2f} {self.bottleneck:>10s} "
                f"{self.useful_ratio:8.3f}")


HEADER = (f"{'arch':>22s} {'shape':>12s} {'mesh':>9s} "
          f"{'compute_ms':>10s} {'memory_ms':>10s} {'coll_ms':>10s} "
          f"{'bottleneck':>10s} {'useful':>8s}")


def analyze(compiled, *, arch: str, shape: str, mesh_name: str, chips: int,
            model_flops: float) -> Roofline:
    """Roofline terms from the compiled module.

    FLOPs / bytes / collectives come from the trip-count-aware HLO walk
    (``hlo_costs``) — ``compiled.cost_analysis()`` counts while bodies once
    and is kept only as a cross-check field."""
    from repro.roofline.hlo_costs import analyze_hlo
    hlo = compiled.as_text()
    mc = analyze_hlo(hlo)
    flops = mc.flops
    byts = mc.bytes
    coll = {k: int(v) for k, v in mc.coll.items() if v}
    mem = compiled.memory_analysis()
    r = Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=byts,
        coll_bytes=coll, model_flops=model_flops,
        mem_args=getattr(mem, "argument_size_in_bytes", 0),
        mem_output=getattr(mem, "output_size_in_bytes", 0),
        mem_temp=getattr(mem, "temp_size_in_bytes", 0),
        mem_peak=getattr(mem, "peak_memory_in_bytes",
                         getattr(mem, "temp_size_in_bytes", 0)),
    )
    return r


def save_json(r: Roofline, path: str) -> None:
    import os
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(r.to_dict(), f, indent=1)
