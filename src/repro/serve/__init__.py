from repro.serve.engine import Generator, make_serve_step, serve_step  # noqa: F401

# DPMM serving lives in repro.serve.dpmm (DPMMEngine, ServeResult); it is
# intentionally NOT imported here so `import repro.serve` for the LM path
# does not pull in the sampler stack (and vice versa).
