"""Serving surfaces: the LM generator scaffold and the DPMM engine.

``from repro.serve import DPMMEngine, ServeConfig`` works without
eagerly importing the sampler stack into the LM serving path (and vice
versa): the DPMM names resolve lazily via module ``__getattr__`` on
first touch.
"""
from repro.serve.engine import Generator, make_serve_step, serve_step  # noqa: F401

_DPMM_EXPORTS = ("DPMMEngine", "ServeConfig", "ServeResult",
                 "InvalidQueryError", "PublishRejected")

__all__ = ["Generator", "make_serve_step", "serve_step", *_DPMM_EXPORTS]


def __getattr__(name):
    if name in _DPMM_EXPORTS:
        from repro.serve import dpmm
        return getattr(dpmm, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
