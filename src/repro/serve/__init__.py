from repro.serve.engine import Generator, make_serve_step, serve_step  # noqa: F401
