"""DPMMEngine: serve a fitted DPMM — the paper's model as a product.

The dirichletprocess-style consumption pattern: practitioners don't want
a trace, they want a fitted model they can *query*. A ``DPMMEngine``
wraps a final ``ModelState`` (usually ``FitResult.select_best().state``
from a multi-chain fit, or a checkpoint written by core/checkpoint.py)
and answers batched queries:

 - ``predict(x)``        — hard cluster assignment, argmax_k p(k | x)
 - ``predict_logprobs(x)`` — soft assignment: log p(k | x) over the K_max
   slots (inactive slots are -inf)
 - ``log_predictive(x)`` — log p(x) under the mixture posterior
   (the density ranking used e.g. for outlier scoring)
 - ``sample(x, seed)``   — a posterior *draw* of the assignment, reusing
   the sampler's fused assignment kernels (``family.assign`` — the exact
   Gumbel-argmax path the Gibbs sweep runs, counter-based on the query
   row index)

All of them run through ONE pre-compiled, fixed-batch-size jitted step:
queries are padded to ``batch_size`` rows and fed through the same
executable (AOT-compiled at engine construction — no query ever pays a
trace/compile), so serving latency is flat and predictable. The
likelihood is ``family.loglik`` — the same dispatch (Pallas
``loglik_fast`` on TPU, jnp reference elsewhere) the training sweep uses,
so served soft-assignment log-probs match the sampler's assignment logits
to the bit on the same backend.

Mixture weights: ``ModelState.logweights`` are the step-(a) Dirichlet
draw's log pi (already ~normalized over active slots + the alpha slot);
the engine renormalizes over *active* slots once at construction so
``predict_logprobs`` is a proper conditional and ``log_predictive``
integrates to 1.

Sparse-K serving: checkpoints carry the full (K_max, ...) slab, but a
fitted model typically has K_active << K_max live clusters. At engine
build the params/weights are gathered to the active set once (a pure
gather through ``gibbs.compaction_plan`` — active slots first, ascending)
and every query step runs O(N * K_active) work. Outputs are unchanged to
the bit: the compact logsumexp only drops exact-zero ``exp(-1e30 - max)``
terms, hard labels map back through ``slot_of_compact`` (ascending, so
first-max tie order is preserved), and the (N, K_max) soft output is the
compact one scattered into a ``NEG_INF`` background — float32
``NEG_INF - logpred`` rounds to ``NEG_INF`` exactly, which is what the
dense step computes for inactive slots.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import checkpoint as _checkpoint
from repro.core.checkpoint import load_model
from repro.core import gibbs
from repro.core.family import NEG_INF, ComponentFamily, get_family
from repro.core.state import ModelState
from repro.kernels import prng


class InvalidQueryError(ValueError):
    """A query batch failed validation (wrong rank/width, or non-finite
    values). Typed so servers can map it to a 4xx instead of treating it
    as an engine fault — a NaN row is a *client* bug, and letting it
    through would silently produce garbage scores (NaN propagates
    through loglik + logsumexp into every answer for that row)."""


class ServeResult(NamedTuple):
    """One batch of answers (rows past the query count are stripped)."""
    labels: np.ndarray        # (N,) int32 hard assignment
    logprobs: np.ndarray      # (N, K_max) float32 log p(k | x)
    log_predictive: np.ndarray  # (N,) float32 log p(x)


class DPMMEngine:
    """Precompiled query engine over a fitted ``ModelState``.

    ``model`` must be single-chain (no leading chain axis) — take
    ``FitResult.select_best().state`` first. ``batch_size`` fixes the
    compiled step's shape; arbitrary query counts are served by padding
    the ragged tail batch.
    """

    def __init__(self, model: ModelState,
                 family: Union[str, ComponentFamily],
                 batch_size: int = 2048, use_pallas: bool = False,
                 seed: int = 0, validate_queries: bool = True):
        self.family = (get_family(family) if isinstance(family, str)
                       else family)
        self.validate_queries = bool(validate_queries)
        if model.active.ndim != 1:
            raise ValueError(
                f"DPMMEngine expects a single-chain ModelState; got "
                f"active shape {tuple(model.active.shape)} — select a "
                "chain first (FitResult.select_best())")
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        self.model = model
        self.batch_size = int(batch_size)
        self.k_max = int(model.active.shape[0])
        self.d = int(self.family.cluster_means(model.stats).shape[-1])
        self._key = jax.random.key(seed)

        active = model.active
        logw = jnp.where(active, model.logweights, NEG_INF)
        # renormalize over active slots: p(k) must sum to 1 for the
        # predictive density (the sampler's logweights carry alpha-slot
        # mass that the restricted sweep never uses)
        logw = (logw - jax.scipy.special.logsumexp(
            jnp.where(active, logw, -jnp.inf))).astype(jnp.float32)
        self.logweights = logw

        # active-set compaction (see module docstring): one build-time
        # gather, O(K_active) per-query work, bit-identical answers
        self.k_active = max(1, int(np.asarray(
            jax.device_get(active)).sum()))
        comp = gibbs.compaction_plan(active, self.k_active)
        slots = comp.slot_of_compact            # (K_active,) ascending
        self.slots = np.asarray(jax.device_get(slots))
        params_c = gibbs.compact_gather(comp, model.params)
        active_c = jnp.take(active, slots)
        logw_c = jnp.take(logw, slots)
        k_max = self.k_max

        def step(x):
            ll = self.family.loglik(x, params_c, use_pallas=use_pallas)
            logits = jnp.where(active_c[None, :], ll + logw_c[None, :],
                               NEG_INF)
            logpred = jax.scipy.special.logsumexp(logits, axis=-1)
            logprobs = jnp.full((x.shape[0], k_max), NEG_INF, jnp.float32)
            logprobs = logprobs.at[:, slots].set(logits - logpred[:, None])
            return {
                "labels": jnp.take(
                    slots, jnp.argmax(logits, axis=-1)).astype(jnp.int32),
                "logprobs": logprobs,
                "log_predictive": logpred,
            }

        def sample_step(x, key_words, offset):
            # the sweep's step (e): argmax_k [loglik + log pi + Gumbel],
            # counter-based on the global row index — the fused
            # assign/assign_fast kernel path, verbatim. ``slots`` keeps
            # the Gumbel counters in dense slot space, so the draw is
            # bitwise the dense engine's draw.
            gidx = offset + jnp.arange(x.shape[0], dtype=jnp.uint32)
            z = self.family.assign(x, params_c, logw_c, active_c, gidx,
                                   key_words, use_pallas=use_pallas,
                                   slots=slots)
            return jnp.take(slots, z).astype(jnp.int32)

        shape = jax.ShapeDtypeStruct((self.batch_size, self.d),
                                     jnp.float32)
        u32 = jax.ShapeDtypeStruct((2,), jnp.uint32)
        off = jax.ShapeDtypeStruct((), jnp.uint32)
        # AOT-compile once; queries never trace
        self._step = jax.jit(step).lower(shape).compile()
        self._sample_step = jax.jit(sample_step).lower(
            shape, u32, off).compile()

    @classmethod
    def from_checkpoint(cls, path: str, batch_size: int = 2048,
                        use_pallas: bool = False, seed: int = 0,
                        validate_queries: bool = True) -> "DPMMEngine":
        """Load a core/checkpoint.py npz and build the engine.

        ``path`` may be a single checkpoint file OR an auto-checkpoint
        rotation prefix (``cfg.checkpoint_path`` of a fit with
        ``checkpoint_every`` set): when no file named ``path``(.npz)
        exists but rotation members do, the newest member that verifies
        (version, per-leaf CRC32, shapes) is served — a half-written or
        bit-flipped member falls back through the rotation instead of
        poisoning the engine. Raises ``CheckpointCorrupt`` /
        ``CheckpointNotFound`` (core/checkpoint.py) otherwise.
        """
        try:
            model, family = load_model(path)
        except _checkpoint.CheckpointNotFound:
            if not isinstance(path, str) or not _checkpoint.list_checkpoints(path):
                raise
            model, family, _member, _it = _checkpoint.latest_valid(path)
        return cls(model, family, batch_size=batch_size,
                   use_pallas=use_pallas, seed=seed,
                   validate_queries=validate_queries)

    # ------------------------------------------------------------------
    def _batches(self, x: np.ndarray):
        x = np.asarray(x, np.float32)
        if x.ndim != 2 or x.shape[1] != self.d:
            raise InvalidQueryError(f"queries must be (N, {self.d}), got "
                                    f"{x.shape}")
        if self.validate_queries and not np.isfinite(x).all():
            bad = np.flatnonzero(~np.isfinite(x).all(axis=1))
            raise InvalidQueryError(
                f"queries contain non-finite values in {bad.size} row(s), "
                f"first at row {int(bad[0])} — NaN/Inf inputs would "
                "produce NaN scores for those rows (pass "
                "validate_queries=False to the engine to skip this check)")
        n, b = x.shape[0], self.batch_size
        for start in range(0, n, b):
            block = x[start:start + b]
            if block.shape[0] < b:          # ragged tail: pad to shape
                block = np.concatenate(
                    [block, np.zeros((b - block.shape[0], self.d),
                                     np.float32)], axis=0)
            yield start, min(b, n - start), block

    def query(self, x: np.ndarray) -> ServeResult:
        """All three answers for (N, d) queries, batched through the
        precompiled step. N = 0 returns empty answers."""
        outs: Dict[str, list] = {"labels": [], "logprobs": [],
                                 "log_predictive": []}
        for _, used, block in self._batches(x):
            out = self._step(block)
            for k, v in out.items():
                outs[k].append(np.asarray(jax.device_get(v))[:used])
        if not outs["labels"]:
            return ServeResult(
                labels=np.zeros((0,), np.int32),
                logprobs=np.zeros((0, self.k_max), np.float32),
                log_predictive=np.zeros((0,), np.float32))
        return ServeResult(
            labels=np.concatenate(outs["labels"]),
            logprobs=np.concatenate(outs["logprobs"]),
            log_predictive=np.concatenate(outs["log_predictive"]))

    def predict(self, x: np.ndarray) -> np.ndarray:
        return self.query(x).labels

    def predict_logprobs(self, x: np.ndarray) -> np.ndarray:
        return self.query(x).logprobs

    def log_predictive(self, x: np.ndarray) -> np.ndarray:
        return self.query(x).log_predictive

    def sample(self, x: np.ndarray,
               seed: Optional[int] = None) -> np.ndarray:
        """Posterior assignment DRAW (not the argmax): the Gibbs sweep's
        Gumbel-argmax assignment over the fitted components. Each call
        advances the engine key unless ``seed`` pins it."""
        key = (jax.random.key(seed) if seed is not None else self._key)
        if seed is None:
            self._key = jax.random.fold_in(self._key, 1)
        words = prng.key_words(key)
        labels = [np.zeros((0,), np.int32)]
        for start, used, block in self._batches(x):
            out = self._sample_step(block, words, np.uint32(start))
            labels.append(np.asarray(jax.device_get(out))[:used])
        return np.concatenate(labels)
