"""Live DPMM serving: multi-size AOT dispatch, hot swap, online refinement.

The dirichletprocess-style consumption pattern: practitioners don't want
a trace, they want a fitted model they can *query*. A ``DPMMEngine``
wraps a ``ModelState`` (usually ``FitResult.select_best().state`` from a
multi-chain fit, or a checkpoint written by core/checkpoint.py) and
answers batched queries:

 - ``predict(x)``        — hard cluster assignment, argmax_k p(k | x)
 - ``predict_logprobs(x)`` — soft assignment: log p(k | x) over the K_max
   slots (inactive slots are -inf)
 - ``log_predictive(x)`` — log p(x) under the mixture posterior
   (the density ranking used e.g. for outlier scoring)
 - ``sample(x, seed)``   — a posterior *draw* of the assignment, reusing
   the sampler's fused assignment kernels (``family.assign`` — the exact
   Gumbel-argmax path the Gibbs sweep runs, counter-based on the query
   row index)

``query(x)`` composes all of them into one :class:`ServeResult` whose
``to_json()`` is the stable wire schema the CLI (launch/serve_dpmm.py)
emits — the Python API and the shell pipeline agree field for field.

The engine is configured by a :class:`ServeConfig` (validated like
``DPMMConfig``) and is a *live* system, not a frozen checkpoint:

**Multi-size AOT step table.** ``cfg.batch_sizes`` is an ascending
ladder (default 256/2048/8192). Every ladder size is AOT-compiled at
engine build — no query ever pays a trace — and each request routes to
the *smallest covering* step (requests longer than the largest step
consume largest-size chunks first, then one covering tail step:
``plan_route``). A 256-row request therefore runs the 256-row
executable instead of padding to 8192 — that pad was pure wasted
compute, and dropping it is what the latency-percentile bench
(benchmarks/bench_serve.py) records as the ladder's p50 win. Because a
request of n rows runs the exact executable a fixed-``batch_sizes=(b,)``
engine compiles for its covering size b, ragged dispatch is *bitwise*
invisible (tests/test_serve_live.py).

**Hot model swap.** ``engine.swap(path)`` loads a new checkpoint (single
file or rotation prefix — newest member that verifies), health-checks it
(``resilience.model_health``, ``cfg.guardrails``), warms every ladder
step off the serving path, then flips ONE snapshot reference atomically.
Queries read that reference once at entry, so a query issued before the
flip is answered bitwise by the old model and a query after it bitwise
by the new one — never a blend. Compiled steps take the model's compact
params/weights as runtime *operands* (not baked constants) keyed only on
shapes, so a swap that preserves shapes reuses the existing executables:
the flip costs an operand gather, never a compile on the serving path.

**Online refinement** (``cfg.refine``, opt-in). Served query batches are
buffered and ``engine.refine()`` folds them through the real sampler
micro-batch sweep (``gibbs.refine_sweep``: steps (a)-(f) on the batch +
an exponentially decayed suff-stat fold) into a *shadow* ModelState.
Every ``cfg.refine_publish_every`` healthy sweeps the shadow publishes
through the same atomic swap path; ``model_health`` gates every publish
and every swap — a poisoned batch (NaN/Inf stats) is rejected, the
shadow re-anchors to the served model, and a ``refine_rejected`` event
lands in ``engine.events`` instead of a poisoned model in production.
With ``refine=False`` the serving path is bit-for-bit the static
engine's (chain-neutrality, tested).

Mixture weights: ``ModelState.logweights`` are the step-(a) Dirichlet
draw's log pi; the engine renormalizes over *active* slots once per
snapshot so ``predict_logprobs`` is a proper conditional and
``log_predictive`` integrates to 1.

Sparse-K serving: checkpoints carry the full (K_max, ...) slab, but a
fitted model typically has K_active << K_max live clusters. At snapshot
build the params/weights are gathered to a compact slab (K_active
rounded up to a power of two, via ``gibbs.compaction_plan`` — active
slots first, ascending) and every query step runs O(N * K_c) work.
Outputs are unchanged to the bit: the compact logsumexp only drops
exact-zero ``exp(NEG_INF - max)`` terms, hard labels map back through
``slot_of_compact`` (ascending, so first-max tie order is preserved),
and the (N, K_max) soft output is the compact one scattered into a
``NEG_INF`` background — float32 ``NEG_INF - logpred`` rounds to
``NEG_INF`` exactly, which is what the dense step computes for inactive
slots.
"""
from __future__ import annotations

import dataclasses
import threading
import warnings
from typing import Any, Dict, List, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DPMMConfig
from repro.core import checkpoint as _checkpoint
from repro.core import gibbs, resilience
from repro.core.family import NEG_INF, ComponentFamily, get_family
from repro.core.state import ModelState
from repro.kernels import prng


class InvalidQueryError(ValueError):
    """A query batch failed validation (wrong rank/width, or non-finite
    values). Typed so servers can map it to a 4xx instead of treating it
    as an engine fault — a NaN row is a *client* bug, and letting it
    through would silently produce garbage scores (NaN propagates
    through loglik + logsumexp into every answer for that row)."""


class PublishRejected(RuntimeError):
    """A model swap or refinement publish failed the ``model_health``
    gate (non-finite stats/weights, degenerate clusters) and was NOT
    made live. The engine keeps serving the previous model; the event is
    also logged in ``engine.events``."""


# ---------------------------------------------------------------------------
# ServeConfig: the serving surface's one validated configuration object
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Knobs for :class:`DPMMEngine`, mirroring ``DPMMConfig``'s
    validated-``__post_init__`` style (invalid values fail at
    construction, not at first query).

    ``batch_sizes`` — ascending AOT ladder; every size is precompiled
    and each request routes to the smallest covering step.
    ``checkpoint_prefix`` — default source for ``engine.swap()`` (set
    automatically by ``from_checkpoint``).
    ``guardrails`` — run ``model_health`` before any swap/publish goes
    live.
    ``refine*`` — opt-in online refinement: micro-batch Gibbs sweeps
    over buffered query traffic into a shadow model (``refine_batch``
    rows per sweep, at most ``refine_buffer`` rows buffered, suff-stats
    folded as ``decay * old + batch``), published through the swap path
    every ``refine_publish_every`` healthy sweeps. ``refine_cfg``
    carries the sampler hyper-parameters (prior + alpha) — defaults to
    ``DPMMConfig()`` with the engine's component family.
    """
    batch_sizes: Tuple[int, ...] = (256, 2048, 8192)
    validate_queries: bool = True
    use_pallas: bool = False
    seed: int = 0
    checkpoint_prefix: Optional[str] = None
    guardrails: bool = True
    refine: bool = False
    refine_batch: int = 1024
    refine_buffer: int = 32768
    refine_decay: float = 0.9
    refine_publish_every: int = 1
    refine_cfg: Optional[DPMMConfig] = None

    def __post_init__(self):
        sizes = tuple(self.batch_sizes)
        if not sizes:
            raise ValueError("ServeConfig.batch_sizes must name at least "
                             "one AOT step size")
        for b in sizes:
            if isinstance(b, bool) or not isinstance(b, int) or b < 1:
                raise ValueError(
                    f"ServeConfig.batch_sizes entries must be positive "
                    f"ints, got {b!r}")
        if list(sizes) != sorted(set(sizes)):
            raise ValueError(
                f"ServeConfig.batch_sizes must be strictly ascending "
                f"(the routing walks smallest-covering-first), got {sizes}")
        object.__setattr__(self, "batch_sizes", sizes)

        def positive(name, value):
            if (isinstance(value, bool) or not isinstance(value, int)
                    or value <= 0):
                raise ValueError(f"ServeConfig.{name} must be a positive "
                                 f"int, got {value!r}")
        positive("refine_batch", self.refine_batch)
        positive("refine_buffer", self.refine_buffer)
        positive("refine_publish_every", self.refine_publish_every)
        if self.refine_buffer < self.refine_batch:
            raise ValueError(
                f"ServeConfig.refine_buffer ({self.refine_buffer}) must "
                f"hold at least one refine_batch ({self.refine_batch})")
        if not (0.0 <= float(self.refine_decay) < 1.0):
            raise ValueError(
                f"ServeConfig.refine_decay must be in [0, 1) — 1.0 would "
                f"grow stats without bound; got {self.refine_decay!r}")
        if (self.checkpoint_prefix is not None
                and not isinstance(self.checkpoint_prefix, str)):
            raise ValueError(
                f"ServeConfig.checkpoint_prefix must be a path string or "
                f"None, got {type(self.checkpoint_prefix).__name__}")


# ---------------------------------------------------------------------------
# ServeResult: the one result type every query surface composes into
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ServeResult:
    """One request's answers (rows past the query count are stripped).

    ``model_epoch`` identifies the served model generation (bumps on
    every swap/publish) — a client can detect mid-stream model changes
    without comparing floats. ``sampled_labels`` is filled only by
    ``query(..., sample=True)`` / ``engine.sample``.
    ``to_json()`` is the stable wire schema; the CLI emits exactly it.
    """
    labels: np.ndarray          # (N,) int32 hard assignment
    logprobs: np.ndarray        # (N, K_max) float32 log p(k | x)
    log_predictive: np.ndarray  # (N,) float32 log p(x)
    sampled_labels: Optional[np.ndarray]  # (N,) int32, or None
    family: str
    k_max: int
    model_epoch: int

    @property
    def n(self) -> int:
        return int(self.labels.shape[0])

    def cluster_counts(self) -> Dict[int, int]:
        counts = np.bincount(self.labels, minlength=self.k_max)
        return {int(k): int(counts[k]) for k in np.flatnonzero(counts)}

    def to_json(self, include_logprobs: bool = False) -> dict:
        """Stable JSON schema, shared verbatim by launch/serve_dpmm.py.
        ``logprobs`` is opt-in (it is N * K_max floats)."""
        out = {
            "n": self.n,
            "family": self.family,
            "k_max": self.k_max,
            "model_epoch": self.model_epoch,
            "labels": self.labels.tolist(),
            "log_predictive": self.log_predictive.tolist(),
            "sampled_labels": (None if self.sampled_labels is None
                               else self.sampled_labels.tolist()),
            "cluster_counts": {str(k): v
                               for k, v in self.cluster_counts().items()},
        }
        if include_logprobs:
            out["logprobs"] = self.logprobs.tolist()
        return out


# ---------------------------------------------------------------------------
# The AOT step table: executables keyed on shapes, model fed as operands
# ---------------------------------------------------------------------------
class _Operands(NamedTuple):
    """The compact-model operands every serving step consumes. These are
    runtime *arguments* to the compiled steps (never baked constants), so
    two models with the same shapes share executables — a swap/publish
    flips operands, not programs."""
    params: Any               # family params, compact (K_c, ...) slab
    logw: jax.Array           # (K_c,) renormalized log weights
    active: jax.Array         # (K_c,) bool
    slots: jax.Array          # (K_c,) int32 dense slot id of each row


def _query_fn(family: ComponentFamily, k_max: int, use_pallas: bool):
    def step(x, params, logw, active, slots):
        ll = family.loglik(x, params, use_pallas=use_pallas)
        logits = jnp.where(active[None, :], ll + logw[None, :], NEG_INF)
        logpred = jax.scipy.special.logsumexp(logits, axis=-1)
        logprobs = jnp.full((x.shape[0], k_max), NEG_INF, jnp.float32)
        logprobs = logprobs.at[:, slots].set(logits - logpred[:, None])
        return {
            "labels": jnp.take(
                slots, jnp.argmax(logits, axis=-1)).astype(jnp.int32),
            "logprobs": logprobs,
            "log_predictive": logpred,
        }
    return step


def _sample_fn(family: ComponentFamily, use_pallas: bool):
    def step(x, params, logw, active, slots, key_words, offset):
        # the sweep's step (e): argmax_k [loglik + log pi + Gumbel],
        # counter-based on the request row index — the fused
        # assign/assign_fast kernel path, verbatim. ``slots`` keeps the
        # Gumbel counters in dense slot space, so the draw is bitwise
        # the dense engine's AND invariant to how the request was
        # decomposed over ladder steps (counters depend on the row, not
        # the step).
        gidx = offset + jnp.arange(x.shape[0], dtype=jnp.uint32)
        z = family.assign(x, params, logw, active, gidx, key_words,
                          use_pallas=use_pallas,
                          slots=slots.astype(jnp.uint32))
        return jnp.take(slots, z).astype(jnp.int32)
    return step


class _StepTable:
    """Process-wide cache of AOT-compiled serving executables.

    Keyed on everything that determines the *program*: family, feature
    width, dense/compact slab widths, batch size, kernel path. Model
    values are operands, so every engine (and every swapped/published
    model) with the same shapes shares one executable — which is also
    what makes ragged-dispatch parity *bitwise*: the ladder engine and a
    fixed-batch engine literally run the same compiled step.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._compiled: Dict[tuple, Any] = {}

    def _get(self, key, build):
        with self._lock:
            hit = self._compiled.get(key)
            if hit is None:
                hit = self._compiled[key] = build()
            return hit

    @staticmethod
    def _sds(tree):
        return jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(jnp.shape(a),
                                           jnp.asarray(a).dtype), tree)

    def query_step(self, family, k_max: int, batch: int, d: int,
                   use_pallas: bool, ops: _Operands):
        key = ("q", family.name, k_max, batch, d, use_pallas,
               ops.slots.shape[0])
        x = jax.ShapeDtypeStruct((batch, d), jnp.float32)
        return self._get(key, lambda: jax.jit(
            _query_fn(family, k_max, use_pallas)
        ).lower(x, *self._sds(tuple(ops))).compile())

    def sample_step(self, family, k_max: int, batch: int, d: int,
                    use_pallas: bool, ops: _Operands):
        key = ("s", family.name, k_max, batch, d, use_pallas,
               ops.slots.shape[0])
        x = jax.ShapeDtypeStruct((batch, d), jnp.float32)
        u32 = jax.ShapeDtypeStruct((2,), jnp.uint32)
        off = jax.ShapeDtypeStruct((), jnp.uint32)
        return self._get(key, lambda: jax.jit(
            _sample_fn(family, use_pallas)
        ).lower(x, *self._sds(tuple(ops)), u32, off).compile())


_TABLE = _StepTable()


# ---------------------------------------------------------------------------
# Served snapshot: ONE immutable object per model generation
# ---------------------------------------------------------------------------
class _Served(NamedTuple):
    """Everything a query needs, bundled so the swap path can flip a
    single reference atomically: a query reads ``engine._served`` once
    at entry and sees exactly one model generation end to end."""
    model: ModelState
    family: ComponentFamily
    epoch: int
    k_max: int
    d: int
    k_active: int
    slots_np: np.ndarray        # (K_c,) dense slot ids, active first
    logweights: jax.Array       # (K_max,) renormalized dense log weights
    ops: _Operands
    steps: Dict[int, Any]       # batch size -> compiled query step
    sample_steps: Dict[int, Any]
    source: str


def _ceil_pow2(v: int) -> int:
    return 1 << max(0, (int(v) - 1).bit_length())


def _build_served(model: ModelState, family: ComponentFamily,
                  cfg: ServeConfig, epoch: int, source: str) -> _Served:
    """Gather the compact operands and warm every ladder step. Runs OFF
    the serving path (engine build, swap, publish) — by the time the
    snapshot is flipped live, every request size is compile-free."""
    if model.active.ndim != 1:
        raise ValueError(
            f"DPMMEngine expects a single-chain ModelState; got active "
            f"shape {tuple(model.active.shape)} — select a chain first "
            "(FitResult.select_best())")
    k_max = int(model.active.shape[0])
    d = int(family.cluster_means(model.stats).shape[-1])

    active = model.active
    logw = jnp.where(active, model.logweights, NEG_INF)
    # renormalize over active slots: p(k) must sum to 1 for the
    # predictive density (the sampler's logweights carry alpha-slot
    # mass that the restricted sweep never uses)
    logw = (logw - jax.scipy.special.logsumexp(
        jnp.where(active, logw, -jnp.inf))).astype(jnp.float32)

    k_active = max(1, int(np.asarray(jax.device_get(active)).sum()))
    # compact width is K_active rounded up to a power of two: pad rows
    # are inactive dense slots (masked to NEG_INF, bitwise inert), and
    # the bucketing means a refinement publish or swap whose live count
    # drifts within the bucket reuses the same executables
    k_c = min(k_max, _ceil_pow2(k_active))
    comp = gibbs.compaction_plan(active, k_c)
    slots = comp.slot_of_compact
    ops = _Operands(params=gibbs.compact_gather(comp, model.params),
                    logw=jnp.take(logw, slots),
                    active=jnp.take(active, slots),
                    slots=slots)
    # fits run under a shard_map mesh and leave NamedSharding on every
    # leaf; the AOT steps are compiled for plain single-device operands,
    # so commit the (tiny, O(K_c)) operand slab to one device here
    ops = jax.device_put(ops, jax.devices()[0])
    steps = {b: _TABLE.query_step(family, k_max, b, d, cfg.use_pallas, ops)
             for b in cfg.batch_sizes}
    samples = {b: _TABLE.sample_step(family, k_max, b, d, cfg.use_pallas,
                                     ops)
               for b in cfg.batch_sizes}
    return _Served(model=model, family=family, epoch=epoch, k_max=k_max,
                   d=d, k_active=k_active,
                   slots_np=np.asarray(jax.device_get(slots)),
                   logweights=logw, ops=ops, steps=steps,
                   sample_steps=samples, source=source)


def _traffic_prior(family: ComponentFamily, cfg: DPMMConfig,
                   model: ModelState):
    """Prior hyper-parameters for refinement sweeps. The fit derived its
    prior from the data column mean; at serve time the data is gone, but
    the count-weighted active cluster means reconstruct exactly
    ``sum_i x_i / N`` from the sufficient statistics."""
    means = family.cluster_means(model.stats)
    w = jnp.where(model.active, model.stats.n, 0.0)
    mean = ((w[:, None] * means).sum(axis=0)
            / jnp.maximum(w.sum(), 1e-6)).astype(jnp.float32)
    return family.build_prior(cfg, mean[None, :])


_LEGACY_KWARGS = ("batch_size", "use_pallas", "seed", "validate_queries")


def _coerce_cfg(cfg: Optional[ServeConfig], legacy: dict,
                where: str) -> ServeConfig:
    """One-release deprecation shim: map the PR-5 loose kwargs onto
    ``ServeConfig`` with a warning. Remove after the next release."""
    if not legacy:
        return cfg if cfg is not None else ServeConfig()
    unknown = sorted(set(legacy) - set(_LEGACY_KWARGS))
    if unknown:
        raise TypeError(f"{where}() got unexpected keyword argument(s) "
                        f"{unknown}")
    if cfg is not None:
        raise TypeError(
            f"{where}() got both a ServeConfig and legacy keyword "
            f"argument(s) {sorted(legacy)} — move them into the "
            "ServeConfig")
    warnings.warn(
        f"{where}({', '.join(sorted(legacy))}=...) is deprecated; pass a "
        "ServeConfig instead (batch_size=N becomes batch_sizes=(N,)). "
        "The keyword shim will be removed next release.",
        DeprecationWarning, stacklevel=3)
    fields: Dict[str, Any] = {}
    if "batch_size" in legacy:
        fields["batch_sizes"] = (int(legacy["batch_size"]),)
    for name in ("use_pallas", "seed", "validate_queries"):
        if name in legacy:
            fields[name] = legacy[name]
    return ServeConfig(**fields)


class DPMMEngine:
    """Live query engine over a fitted ``ModelState``.

    ``DPMMEngine(model, family, cfg)`` / ``DPMMEngine.from_checkpoint(
    path, cfg)`` with a :class:`ServeConfig`; the PR-5 loose kwargs
    (``batch_size=...`` etc.) still work behind a one-release
    ``DeprecationWarning`` shim. ``model`` must be single-chain (no
    leading chain axis) — take ``FitResult.select_best().state`` first.
    """

    def __init__(self, model: ModelState,
                 family: Union[str, ComponentFamily],
                 cfg: Optional[ServeConfig] = None, **legacy):
        self.cfg = _coerce_cfg(cfg, legacy, "DPMMEngine")
        fam = get_family(family) if isinstance(family, str) else family
        self._swap_lock = threading.Lock()   # serializes swap/publish
        self._key_lock = threading.Lock()
        self._key = jax.random.key(self.cfg.seed)
        self.events: List[dict] = []
        self._served = _build_served(model, fam, self.cfg, epoch=0,
                                     source="<memory>")
        # online refinement state (lazy; None until the first refine())
        self._refine_lock = threading.Lock()
        self._traffic: List[np.ndarray] = []
        self._traffic_rows = 0
        self._shadow: Optional[ModelState] = None
        self._refine_fn = None
        self._refine_prior = None
        self._since_publish = 0

    # -- construction ---------------------------------------------------
    @classmethod
    def from_checkpoint(cls, path: str, cfg: Optional[ServeConfig] = None,
                        **legacy) -> "DPMMEngine":
        """Load a core/checkpoint.py npz and build the engine.

        ``path`` may be a single checkpoint file OR an auto-checkpoint
        rotation prefix (``cfg.checkpoint_path`` of a fit with
        ``checkpoint_every`` set): the newest member that verifies
        (version, per-leaf CRC32, shapes) is served — a half-written or
        bit-flipped member falls back through the rotation instead of
        poisoning the engine (``core/checkpoint.resolve_model``). Raises
        ``CheckpointCorrupt`` / ``CheckpointNotFound`` otherwise.
        ``path`` becomes ``cfg.checkpoint_prefix`` (unless already set),
        so a bare ``engine.swap()`` re-reads the same rotation — the
        fit-keeps-checkpointing, engine-keeps-swapping loop.
        """
        cfg = _coerce_cfg(cfg, legacy, "DPMMEngine.from_checkpoint")
        model, family, resolved, _it = _checkpoint.resolve_model(path)
        if cfg.checkpoint_prefix is None:
            cfg = dataclasses.replace(cfg, checkpoint_prefix=path)
        eng = cls(model, family, cfg)
        eng._served = eng._served._replace(source=resolved)
        return eng

    # -- introspection (stable surface; snapshot-backed) ----------------
    @property
    def model(self) -> ModelState:
        return self._served.model

    @property
    def family(self) -> ComponentFamily:
        return self._served.family

    @property
    def epoch(self) -> int:
        """Served model generation; bumps on every swap/publish."""
        return self._served.epoch

    @property
    def k_max(self) -> int:
        return self._served.k_max

    @property
    def k_active(self) -> int:
        return self._served.k_active

    @property
    def d(self) -> int:
        return self._served.d

    @property
    def slots(self) -> np.ndarray:
        return self._served.slots_np

    @property
    def logweights(self) -> jax.Array:
        return self._served.logweights

    @property
    def batch_sizes(self) -> Tuple[int, ...]:
        return self.cfg.batch_sizes

    @property
    def batch_size(self) -> int:
        """Largest ladder step (PR-5 compat: the old single AOT size)."""
        return self.cfg.batch_sizes[-1]

    @property
    def validate_queries(self) -> bool:
        return self.cfg.validate_queries

    # -- routing ---------------------------------------------------------
    def plan_route(self, n: int) -> List[Tuple[int, int, int]]:
        """Ladder routing for an n-row request: ``(start, used,
        batch_size)`` segments. Requests no longer than the largest step
        run as ONE dispatch at the smallest covering size (a 256-row
        request never pays the 8192 pad); longer requests consume
        largest-size chunks, then one covering tail dispatch."""
        sizes = self.cfg.batch_sizes
        big = sizes[-1]
        segs: List[Tuple[int, int, int]] = []
        start = 0
        while n - start > big:
            segs.append((start, big, big))
            start += big
        if n - start > 0:
            rem = n - start
            segs.append((start, rem, next(b for b in sizes if b >= rem)))
        return segs

    # -- query path -------------------------------------------------------
    def _validated(self, x: np.ndarray, d: int) -> np.ndarray:
        x = np.asarray(x, np.float32)
        if x.ndim != 2 or x.shape[1] != d:
            raise InvalidQueryError(f"queries must be (N, {d}), got "
                                    f"{x.shape}")
        if self.cfg.validate_queries and not np.isfinite(x).all():
            bad = np.flatnonzero(~np.isfinite(x).all(axis=1))
            raise InvalidQueryError(
                f"queries contain non-finite values in {bad.size} row(s), "
                f"first at row {int(bad[0])} — NaN/Inf inputs would "
                "produce NaN scores for those rows (pass "
                "ServeConfig(validate_queries=False) to skip this check)")
        return x

    @staticmethod
    def _pad(block: np.ndarray, b: int, d: int) -> np.ndarray:
        if block.shape[0] == b:
            return block
        return np.concatenate(
            [block, np.zeros((b - block.shape[0], d), np.float32)], axis=0)

    def query(self, x: np.ndarray, sample: bool = False,
              seed: Optional[int] = None) -> ServeResult:
        """All answers for (N, d) queries through the AOT step table.
        N = 0 returns empty answers. ``sample=True`` additionally draws
        ``sampled_labels`` (see :meth:`sample`)."""
        served = self._served              # ONE snapshot for the request
        x = self._validated(x, served.d)
        self._record_traffic(x)
        outs: Dict[str, list] = {"labels": [], "logprobs": [],
                                 "log_predictive": []}
        for start, used, b in self.plan_route(x.shape[0]):
            out = served.steps[b](self._pad(x[start:start + used], b,
                                            served.d), *served.ops)
            for k, v in out.items():
                outs[k].append(np.asarray(jax.device_get(v))[:used])
        empty = not outs["labels"]
        return ServeResult(
            labels=(np.zeros((0,), np.int32) if empty
                    else np.concatenate(outs["labels"])),
            logprobs=(np.zeros((0, served.k_max), np.float32) if empty
                      else np.concatenate(outs["logprobs"])),
            log_predictive=(np.zeros((0,), np.float32) if empty
                            else np.concatenate(outs["log_predictive"])),
            sampled_labels=(self._sample(served, x, seed) if sample
                            else None),
            family=served.family.name, k_max=served.k_max,
            model_epoch=served.epoch)

    def predict(self, x: np.ndarray) -> np.ndarray:
        return self.query(x).labels

    def predict_logprobs(self, x: np.ndarray) -> np.ndarray:
        return self.query(x).logprobs

    def log_predictive(self, x: np.ndarray) -> np.ndarray:
        return self.query(x).log_predictive

    def sample(self, x: np.ndarray,
               seed: Optional[int] = None) -> np.ndarray:
        """Posterior assignment DRAW (not the argmax): the Gibbs sweep's
        Gumbel-argmax assignment over the served components. Each call
        advances the engine key unless ``seed`` pins it. Draws are
        counter-based on the request row index, so they are invariant to
        the ladder decomposition."""
        served = self._served
        x = self._validated(x, served.d)
        self._record_traffic(x)
        return self._sample(served, x, seed)

    def _sample(self, served: _Served, x: np.ndarray,
                seed: Optional[int]) -> np.ndarray:
        if seed is not None:
            key = jax.random.key(seed)
        else:
            with self._key_lock:
                key = self._key
                self._key = jax.random.fold_in(self._key, 1)
        words = prng.key_words(key)
        parts = [np.zeros((0,), np.int32)]
        for start, used, b in self.plan_route(x.shape[0]):
            out = served.sample_steps[b](
                self._pad(x[start:start + used], b, served.d),
                *served.ops, words, np.uint32(start))
            parts.append(np.asarray(jax.device_get(out))[:used])
        return np.concatenate(parts)

    # -- hot model swap ---------------------------------------------------
    def swap(self, path: Optional[str] = None) -> int:
        """Load a checkpoint (file or rotation prefix; defaults to
        ``cfg.checkpoint_prefix``), health-check it, AOT-warm every
        ladder step OFF the serving path, then flip atomically. Queries
        issued before the flip are answered bitwise by the old model,
        after it bitwise by the new one. Returns the new epoch. Raises
        :class:`PublishRejected` (old model keeps serving) if
        ``cfg.guardrails`` and the loaded state is unhealthy."""
        path = path if path is not None else self.cfg.checkpoint_prefix
        if path is None:
            raise ValueError(
                "swap() needs a checkpoint path: pass one or set "
                "ServeConfig.checkpoint_prefix (from_checkpoint sets it)")
        model, family, resolved, it = _checkpoint.resolve_model(path)
        return self._publish(model, family, source=resolved,
                             kind="model_swap", it=it)

    def _publish(self, model: ModelState, family: ComponentFamily,
                 source: str, kind: str, it: Optional[int] = None) -> int:
        """The one path a new model takes to production: health gate,
        off-path warmup, atomic flip, audit event."""
        if self.cfg.guardrails and not bool(jax.device_get(
                jax.jit(resilience.model_health)(model))):
            event = {"kind": f"{kind}_rejected", "source": source,
                     "detail": "model_health gate failed (non-finite "
                               "stats/weights or degenerate cluster)"}
            self.events.append(event)
            raise PublishRejected(
                f"{kind} from {source!r} rejected: model_health gate "
                "failed — the previous model keeps serving")
        with self._swap_lock:
            nxt = _build_served(model, family, self.cfg,
                                epoch=self._served.epoch + 1,
                                source=source)
            self._served = nxt             # THE atomic flip
            # the shadow chain re-anchors on whatever is now live
            self._shadow = None
            self._refine_fn = None
            self._refine_prior = None
            self._since_publish = 0
            self.events.append({"kind": kind, "epoch": nxt.epoch,
                                "source": source,
                                "it": (None if it is None else int(it))})
            return nxt.epoch

    # -- online refinement ------------------------------------------------
    def _record_traffic(self, x: np.ndarray) -> None:
        if not self.cfg.refine or x.shape[0] == 0:
            return
        with self._refine_lock:
            self._traffic.append(np.array(x, np.float32, copy=True))
            self._traffic_rows += x.shape[0]
            while (self._traffic_rows > self.cfg.refine_buffer
                   and len(self._traffic) > 1):
                self._traffic_rows -= self._traffic.pop(0).shape[0]
            if self._traffic_rows > self.cfg.refine_buffer:
                keep = self._traffic[0][-self.cfg.refine_buffer:]
                self._traffic = [keep]
                self._traffic_rows = keep.shape[0]

    def _refine_setup(self, served: _Served):
        """Lazy per-anchor refinement program: prior from the anchor
        model's stats, jitted sweep+health step (prior is an operand, so
        re-anchoring after a swap never re-traces)."""
        if self._refine_prior is None:
            dcfg = self.cfg.refine_cfg
            if dcfg is None:
                dcfg = DPMMConfig(component=served.family.name)
            elif dcfg.component != served.family.name:
                raise ValueError(
                    f"ServeConfig.refine_cfg.component "
                    f"({dcfg.component!r}) does not match the served "
                    f"family ({served.family.name!r})")
            self._refine_prior = _traffic_prior(served.family, dcfg,
                                                served.model)
            fam, cfg = served.family, self.cfg
            alpha = float(dcfg.alpha)

            def run(model, xb, valid, prior):
                m2, labels = gibbs.refine_sweep(
                    model, xb, valid, prior, fam, alpha,
                    decay=cfg.refine_decay, use_pallas=cfg.use_pallas)
                return m2, resilience.model_health(m2), labels
            self._refine_fn = jax.jit(run)
        return self._refine_fn, self._refine_prior

    def refine(self, x: Optional[np.ndarray] = None,
               publish: bool = True) -> dict:
        """Fold buffered query traffic (or an explicit ``x``) into the
        shadow model via micro-batch Gibbs sweeps, publishing every
        ``cfg.refine_publish_every`` healthy sweeps through the atomic
        swap path. Partial tail batches are padded with ``valid=0`` rows
        (stat-inert). An unhealthy sweep re-anchors the shadow to the
        served model and logs ``refine_rejected`` — poison never
        publishes. Returns a summary dict."""
        if not self.cfg.refine:
            raise ValueError("online refinement is disabled: construct "
                             "the engine with ServeConfig(refine=True)")
        served = self._served
        B, d = self.cfg.refine_batch, served.d
        with self._refine_lock:
            if x is not None:
                rows = self._validated_refine(x, d)
            else:
                rows = (np.concatenate(self._traffic)
                        if self._traffic else np.zeros((0, d), np.float32))
                self._traffic, self._traffic_rows = [], 0
        out = {"sweeps": 0, "rows": 0, "rejected": 0, "published": 0,
               "epoch": served.epoch}
        if rows.shape[0] == 0:
            return out
        step, prior = self._refine_setup(served)
        shadow = self._shadow if self._shadow is not None else served.model
        for start in range(0, rows.shape[0], B):
            used = min(B, rows.shape[0] - start)
            xb = self._pad(rows[start:start + used], B, d)
            valid = np.zeros((B,), np.float32)
            valid[:used] = 1.0
            shadow2, ok, _labels = step(shadow, jnp.asarray(xb),
                                        jnp.asarray(valid), prior)
            if not bool(jax.device_get(ok)):
                out["rejected"] += 1
                self.events.append({
                    "kind": "refine_rejected",
                    "rows": [int(start), int(start + used)],
                    "detail": "micro-batch sweep produced an unhealthy "
                              "model (non-finite stats); shadow "
                              "re-anchored to the served model"})
                shadow = self._served.model   # drop the poisoned chain
                self._since_publish = 0
                continue
            shadow = shadow2
            out["sweeps"] += 1
            out["rows"] += used
            self._since_publish += 1
            if publish and self._since_publish >= self.cfg.refine_publish_every:
                out["epoch"] = self._publish(
                    shadow, served.family, source="refine",
                    kind="refine_publish",
                    it=int(np.asarray(jax.device_get(shadow.it))))
                out["published"] += 1
                # _publish reset the anchor; keep sweeping from the
                # just-published chain
                self._shadow = shadow
                self._since_publish = 0
        self._shadow = shadow
        return out

    def _validated_refine(self, x: np.ndarray, d: int) -> np.ndarray:
        x = np.asarray(x, np.float32)
        if x.ndim != 2 or x.shape[1] != d:
            raise InvalidQueryError(
                f"refinement batches must be (N, {d}), got {x.shape}")
        return x
