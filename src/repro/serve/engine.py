"""Serving engine: batched cached decoding on the production mesh.

``make_serve_step`` builds the jit'd one-token step (the function the
decode_32k / long_500k dry-run shapes lower); ``Generator`` drives it for
real batched requests (examples/serve_lm.py).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models import decode as decode_mod
from repro.models import transformer
from repro.models.common import BATCH_AXES, ShardingPolicy


def serve_step(params, cache, tokens: jax.Array, rng: jax.Array, *,
               cfg: ModelConfig, policy: ShardingPolicy,
               window_override: bool, cache_len: int,
               temperature: float = 0.0
               ) -> Tuple[jax.Array, Any]:
    """One decode step + sampling: (B, 1) tokens -> (B, 1) next tokens."""
    logits, new_cache = decode_mod.decode_step(
        params, cache, tokens, cfg, policy,
        window_override=window_override, cache_len=cache_len)
    if temperature > 0.0:
        next_tok = jax.random.categorical(
            rng, logits[:, 0] / temperature, axis=-1)
    else:
        next_tok = jnp.argmax(logits[:, 0], axis=-1)
    return next_tok[:, None].astype(jnp.int32), new_cache


def serve_policy(mesh: Mesh, batch: int) -> ShardingPolicy:
    """Weight-stationary decode policy (§Perf C): the (B, 1, d) activations
    are REPLICATED — decode FLOPs are tiny, and batch-sharding the residual
    makes GSPMD resolve the batch-vs-FSDP 'data'-axis conflict by
    all-gathering the weights every step (measured 14.4 GiB/step on
    mistral-large decode_32k). Caches stay batch-sharded (they are the
    memory)."""
    data = 1
    for a in BATCH_AXES:
        if a in mesh.axis_names:
            data *= mesh.shape[a]
    return ShardingPolicy(batch_sharded=False,
                          seq_shard=False,
                          mesh_axes=tuple(mesh.axis_names),
                          mesh_sizes=tuple(mesh.shape.items()),
                          cache_batch_sharded=(batch % data == 0
                                               and batch >= data),
                          residual_d_shard=True)


def make_serve_step(mesh: Mesh, cfg: ModelConfig, shape: InputShape,
                    temperature: float = 0.0, donate: bool = True,
                    dtype=jnp.float32):
    """jit'd serve step for one (arch, decode shape) pair.

    ``long_500k`` forces the sliding-window serving variant for attention
    layers (``window_override``) — the sub-quadratic path (DESIGN §5).
    """
    from repro.launch.sharding import fix_specs, to_shard as _ts
    policy = serve_policy(mesh, shape.global_batch)
    window_override = (shape.seq_len > 32_768
                       and cfg.long_context == "sliding_window")
    param_structs = jax.eval_shape(
        lambda k: transformer.init_params(k, cfg, dtype), jax.random.key(0))
    cache_structs = jax.eval_shape(
        lambda: decode_mod.init_cache(cfg, shape.global_batch,
                                      shape.seq_len, dtype,
                                      window_override=(shape.seq_len > 32_768
                                      and cfg.long_context
                                      == "sliding_window")))
    pspecs = fix_specs(transformer.param_specs(cfg), param_structs, mesh)
    cspecs = fix_specs(decode_mod.cache_specs(cfg, policy), cache_structs,
                       mesh)
    to_shard = lambda tree: _ts(mesh, tree)
    b = tuple(a for a in BATCH_AXES if a in mesh.axis_names) \
        if policy.batch_sharded else None
    fn = functools.partial(
        serve_step, cfg=cfg, policy=policy,
        window_override=window_override, cache_len=shape.seq_len,
        temperature=temperature)
    return jax.jit(
        fn,
        in_shardings=(to_shard(pspecs), to_shard(cspecs),
                      NamedSharding(mesh, P(b, None)),
                      NamedSharding(mesh, P())),
        out_shardings=(NamedSharding(mesh, P(b, None)), to_shard(cspecs)),
        donate_argnums=(1,) if donate else ()), policy, window_override


class Generator:
    """Minimal batched generation loop over the jit'd serve step."""

    def __init__(self, mesh: Mesh, cfg: ModelConfig, shape: InputShape,
                 params, temperature: float = 0.0, dtype=jnp.float32):
        self.cfg, self.shape = cfg, shape
        self.step, self.policy, self.window_override = make_serve_step(
            mesh, cfg, shape, temperature, donate=False)
        self.params = params
        self.dtype = dtype

    def generate(self, prompts: jax.Array, steps: int,
                 seed: int = 0) -> jax.Array:
        """prompts: (B, P) int32 -> (B, P + steps) greedy/temp continuation.

        The prompt is consumed token-by-token (prefill via the decode path —
        adequate for the example; the prefill_32k dry-run shape exercises
        the real batched prefill)."""
        b, plen = prompts.shape
        cache = decode_mod.init_cache(
            self.cfg, b, self.shape.seq_len, self.dtype,
            window_override=self.window_override)
        out = [prompts]
        tok = prompts[:, :1]
        key = jax.random.key(seed)
        for t in range(plen + steps - 1):
            nxt, cache = self.step(self.params, cache, tok,
                                   jax.random.fold_in(key, t))
            if t + 1 < plen:
                tok = prompts[:, t + 1:t + 2]       # teacher-forced prefill
            else:
                tok = nxt
                out.append(nxt)
        return jnp.concatenate(out, axis=1)
