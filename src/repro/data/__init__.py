from repro.data.synthetic import generate_gmm, generate_mnmm  # noqa: F401
from repro.data.pipeline import TokenPipeline, lm_batches  # noqa: F401
