from repro.data.synthetic import generate_gmm, generate_mnmm  # noqa: F401
from repro.data.pipeline import TokenPipeline, lm_batches  # noqa: F401
from repro.data.source import (DataSource, HostTiledSource,  # noqa: F401
                               ResidentSource, as_source)
from repro.data.faults import FaultInjectingSource  # noqa: F401
