"""Synthetic dataset generators — the paper's ``data_generators`` (§5.1-5.2).

``generate_gmm``  : random Gaussian mixture (means ~ N(0, s^2 I), covariances
                    ~ scaled Wishart), mirrors the paper's DPGMM sweeps
                    (N in 1e3..1e6, d in 2..128, K in 4..32).
``generate_mnmm`` : random multinomial mixture (topic-like sparse
                    probability vectors), mirrors the DPMNMM sweeps.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def generate_gmm(n: int, d: int, k: int, seed: int = 0,
                 sep: float = 6.0) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (x (n,d) float32, labels (n,) int32)."""
    rng = np.random.default_rng(seed)
    means = rng.normal(0.0, sep, size=(k, d))
    # random SPD covariances with eigenvalues in [0.3, 1.3]
    covs = np.zeros((k, d, d))
    for j in range(k):
        q, _ = np.linalg.qr(rng.normal(size=(d, d)))
        eig = rng.uniform(0.3, 1.3, size=(d,))
        covs[j] = (q * eig) @ q.T
    weights = rng.dirichlet(np.full(k, 5.0))
    labels = rng.choice(k, size=n, p=weights).astype(np.int32)
    x = np.empty((n, d), np.float32)
    for j in range(k):
        idx = np.nonzero(labels == j)[0]
        if idx.size:
            l_chol = np.linalg.cholesky(covs[j])
            z = rng.normal(size=(idx.size, d))
            x[idx] = (means[j] + z @ l_chol.T).astype(np.float32)
    return x, labels


def generate_mnmm(n: int, d: int, k: int, seed: int = 0,
                  trials: int = 50, concentration: float = 0.2
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Multinomial mixture: each point is a count vector of `trials` draws."""
    rng = np.random.default_rng(seed)
    thetas = rng.dirichlet(np.full(d, concentration), size=k)
    weights = rng.dirichlet(np.full(k, 5.0))
    labels = rng.choice(k, size=n, p=weights).astype(np.int32)
    x = np.empty((n, d), np.float32)
    for j in range(k):
        idx = np.nonzero(labels == j)[0]
        if idx.size:
            x[idx] = rng.multinomial(trials, thetas[j], size=idx.size)
    return x, labels


def generate_pmm(n: int, d: int, k: int, seed: int = 0,
                 rate_scale: float = 20.0) -> Tuple[np.ndarray, np.ndarray]:
    """Poisson mixture: each cluster has per-feature rates ~ rate_scale*Dir."""
    rng = np.random.default_rng(seed)
    rates = rng.dirichlet(np.full(d, 0.5), size=k) * rate_scale * d
    weights = rng.dirichlet(np.full(k, 5.0))
    labels = rng.choice(k, size=n, p=weights).astype(np.int32)
    x = rng.poisson(rates[labels]).astype(np.float32)
    return x, labels
