"""Deterministic token pipeline for the LM training substrate.

Offline environment: we synthesize a reproducible corpus (a mixture of
Zipfian n-gram streams — enough structure that a small LM's loss visibly
drops) and serve fixed-shape (tokens, targets) batches, sharded over the
mesh's batch axes.
"""
from __future__ import annotations

from typing import Iterator, Optional, Tuple

import numpy as np


class TokenPipeline:
    """Zipfian Markov-chain corpus with deterministic batching."""

    def __init__(self, vocab_size: int, seed: int = 0, order_states: int = 64):
        self.vocab = vocab_size
        rng = np.random.default_rng(seed)
        self.n_states = order_states
        # sparse-ish transition structure: each state emits from a Zipf slice
        ranks = np.arange(1, vocab_size + 1)
        base = 1.0 / ranks ** 1.1
        self.emit = np.empty((order_states, vocab_size))
        for s in range(order_states):
            perm = rng.permutation(vocab_size)
            self.emit[s] = base[perm]
            self.emit[s] /= self.emit[s].sum()
        self.trans = rng.dirichlet(np.full(order_states, 0.3),
                                   size=order_states)
        self._rng = np.random.default_rng(seed + 1)
        self._state = 0

    def sample(self, n_tokens: int) -> np.ndarray:
        out = np.empty(n_tokens, np.int32)
        s = self._state
        for i in range(n_tokens):
            out[i] = self._rng.choice(self.vocab, p=self.emit[s])
            s = self._rng.choice(self.n_states, p=self.trans[s])
        self._state = s
        return out


def lm_batches(vocab_size: int, batch: int, seq: int, seed: int = 0,
               steps: Optional[int] = None
               ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Yields (tokens, targets) of shape (batch, seq), targets shifted by 1."""
    pipe = TokenPipeline(vocab_size, seed)
    i = 0
    while steps is None or i < steps:
        flat = pipe.sample(batch * (seq + 1)).reshape(batch, seq + 1)
        yield flat[:, :-1].copy(), flat[:, 1:].copy()
        i += 1
