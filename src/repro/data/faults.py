"""FaultInjectingSource: deterministic I/O chaos for any ``DataSource``.

The resilience layer (core/resilience.py, the drivers' retry/rollback
paths) is only trustworthy if it is *exercised* — this wrapper injects
the three fault classes a real streamed fit meets, on a schedule that is
deterministic and replayable:

 - ``io``        — ``read_block`` raises ``IOError`` (transient
   device/NFS fault);
 - ``nan``       — the returned tile has rows overwritten with NaN/Inf
   (a bit-flipped or torn buffer);
 - ``short``     — the returned tile is truncated (partial read);
 - ``hang``      — ``read_block`` sleeps ``hang_s`` seconds before
   returning clean data (a wedged disk / dead NFS mount / stuck worker;
   exercises the distributed coordinator's per-work deadlines);
 - ``slow_read`` — ``read_block`` sleeps ``slow_read_s`` seconds before
   returning clean data (a straggler, not a failure: short enough that
   deadlines must NOT fire and the chain must stay bitwise identical).

Faults key on the **read-call index**, not the row range: each
``read_block`` call increments a counter, and the fault decision for
call *i* is drawn from ``SeedSequence([seed, i])``. Two consequences,
both load-bearing for tests:

 1. the schedule is bit-reproducible for a given ``seed`` across runs
    and processes;
 2. faults are *transient by construction* — a retry of the same row
    range is a new call index, so the re-read sees a fresh (almost
    certainly clean) draw. A retried fit therefore recovers onto the
    EXACT clean chain: the data that reaches the device is unchanged.

``schedule`` pins faults explicitly (``{call_index: kind}``) for
directed tests — e.g. ``{0: "io"}`` faults the very first read, and
``dict.fromkeys(range(100), "io")`` exhausts any retry budget.

``resident()`` returns None on purpose: this source models a faulty
*streaming* path, so wrapping forces the tiled driver (the resident
fast path never re-reads and has nothing to retry). ``column_mean``
delegates to the inner source unfaulted — the prior's data-dependent
part is computed once before the fit and is not part of the streamed
iteration loop under test.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from repro.data.source import DataSource

# order matters: probabilities are folded cumulatively in this order, so
# appending new kinds keeps existing (p_io, p_nan, p_short) schedules —
# and therefore existing chaos-test chains — bit-identical
_KINDS = ("io", "nan", "short", "hang", "slow_read")


class FaultInjectingSource(DataSource):
    """Wrap ``inner`` with a seeded, deterministic fault schedule.

    Either give per-call probabilities (``p_io`` / ``p_nan`` /
    ``p_short`` / ``p_hang`` / ``p_slow_read``, drawn independently per
    read-call index from the seed) or an explicit ``schedule`` mapping
    call index -> fault kind. ``hang_s`` / ``slow_read_s`` set the two
    latency kinds' sleep durations (the *when* is seeded; the duration is
    a fixed, deterministic parameter so deadline tests are exact).
    ``max_faults`` bounds the total injections (None = unbounded).
    ``injected`` logs every injection for assertions.
    """

    def __init__(self, inner: DataSource, seed: int = 0,
                 p_io: float = 0.0, p_nan: float = 0.0,
                 p_short: float = 0.0, p_hang: float = 0.0,
                 p_slow_read: float = 0.0,
                 hang_s: float = 30.0, slow_read_s: float = 0.02,
                 schedule: Optional[Dict[int, str]] = None,
                 max_faults: Optional[int] = None):
        if schedule:
            bad = [k for k in schedule.values() if k not in _KINDS]
            if bad:
                raise ValueError(
                    f"unknown fault kind(s) {bad}; known: {_KINDS}")
        probs = (p_io, p_nan, p_short, p_hang, p_slow_read)
        if min(probs) < 0 or sum(probs) > 1:
            raise ValueError(
                "fault probabilities must be >= 0 and sum to <= 1, got "
                f"p_io={p_io} p_nan={p_nan} p_short={p_short} "
                f"p_hang={p_hang} p_slow_read={p_slow_read}")
        if hang_s < 0 or slow_read_s < 0:
            raise ValueError("hang_s/slow_read_s must be >= 0, got "
                             f"hang_s={hang_s} slow_read_s={slow_read_s}")
        self._inner = inner
        self.n, self.d = inner.n, inner.d
        self._seed = int(seed)
        self._p = probs
        self._hang_s = float(hang_s)
        self._slow_read_s = float(slow_read_s)
        self._schedule = dict(schedule) if schedule else None
        self._max_faults = max_faults
        self.calls = 0
        self.injected: List[dict] = []

    # -- DataSource protocol ------------------------------------------------
    def resident(self) -> None:
        return None                     # always stream (see module doc)

    def column_mean(self) -> np.ndarray:
        return self._inner.column_mean()

    def read_block(self, start: int, stop: int) -> np.ndarray:
        i = self.calls
        self.calls += 1
        kind = self._fault_for(i)
        if kind is None or (self._max_faults is not None
                            and len(self.injected) >= self._max_faults):
            return self._inner.read_block(start, stop)
        self.injected.append({"call": i, "kind": kind,
                              "rows": [int(start), int(stop)]})
        if kind == "io":
            raise IOError(
                f"injected I/O fault (read call {i}, "
                f"rows [{start}, {stop}))")
        if kind in ("hang", "slow_read"):
            # latency faults return CLEAN data after the sleep: the chain
            # must be unaffected — only wall clock (and, for hang, the
            # coordinator's deadline machinery) sees these
            time.sleep(self._hang_s if kind == "hang"
                       else self._slow_read_s)
            return self._inner.read_block(start, stop)
        rows = np.array(self._inner.read_block(start, stop))
        rng = self._rng(i)
        if kind == "nan":
            n_bad = max(1, rows.shape[0] // 64)
            bad = rng.choice(rows.shape[0], size=n_bad, replace=False)
            rows[bad] = np.where(rng.random(rows.shape[1]) < 0.5,
                                 np.nan, np.inf).astype(rows.dtype)
            return rows
        # short read: drop a nonzero tail
        cut = int(rng.integers(1, max(2, rows.shape[0])))
        return rows[:-cut] if rows.shape[0] else rows

    # -- schedule -----------------------------------------------------------
    def _rng(self, call: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self._seed, call]))

    def _fault_for(self, call: int) -> Optional[str]:
        if self._schedule is not None:
            return self._schedule.get(call)
        if not any(self._p):
            return None
        u = float(self._rng(call).random())
        acc = 0.0
        for kind, p in zip(_KINDS, self._p):
            acc += p
            if u < acc:
                return kind
        return None
