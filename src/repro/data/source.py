"""DataSource: where points live, decoupled from how the sampler sees them.

The paper's scaling claim (§4.3-4.5) is that only O(K·T) sufficient
statistics need to be globally visible per step — the points themselves
never have to fit in accelerator memory. A ``DataSource`` is the sampler's
window onto the points:

 - ``ResidentSource`` — the whole (N, d) float32 array, zero-copy when the
   input already is one. The fast path: ``DPMM.fit`` device-puts it once
   and runs the chunked on-device scan.
 - ``HostTiledSource`` — host-RAM or disk (np.memmap) backed points served
   as contiguous float32 row blocks. ``DPMM.fit`` streams them tile by
   tile with double-buffered ``jax.device_put``; device memory is
   O(K_max + tile_size), so N is bounded by host storage, not HBM.

Both serve rows through the same ``read_block`` contract (rows past N are
zero padding, exactly mirroring the resident plane's ``pad_to_multiple``
layout) and compute the prior's column mean with the same streamed
float64 pass — so resident and tiled fits see bitwise-identical inputs
everywhere and produce bitwise-identical chains.
"""
from __future__ import annotations

from typing import Optional, Union

import numpy as np

# Row-block size for host-side streaming passes (column mean). Fixed so the
# float64 partial-sum order — and the resulting prior — is identical no
# matter which source type serves the data.
_MEAN_BLOCK = 65_536


class DataSource:
    """Protocol: (n, d) float32 points served as contiguous row blocks."""

    n: int
    d: int

    def read_block(self, start: int, stop: int) -> np.ndarray:
        """(stop - start, d) float32 rows; rows at index >= n are zeros
        (the padded tail of the sharded layout)."""
        raise NotImplementedError

    def resident(self) -> Optional[np.ndarray]:
        """The full (n, d) float32 array if cheaply available (already in
        host RAM), else None — the driver then streams tiles."""
        return None

    def column_mean(self) -> np.ndarray:
        """(d,) float32 column mean — the prior's data-dependent part
        (e.g. the NIW/NIG location). Streamed in fixed blocks with float64
        partial sums so every source type produces the same bits."""
        if getattr(self, "_column_mean", None) is None:
            total = np.zeros((self.d,), np.float64)
            for start in range(0, self.n, _MEAN_BLOCK):
                block = self.read_block(start, min(start + _MEAN_BLOCK,
                                                   self.n))
                total += block.astype(np.float64).sum(axis=0)
            self._column_mean = (total / max(self.n, 1)).astype(np.float32)
        return self._column_mean


class ResidentSource(DataSource):
    """Points already materialized in host RAM; the zero-copy fast path."""

    def __init__(self, x: np.ndarray):
        x = np.asarray(x)
        if x.ndim != 2:
            raise ValueError(f"expected (N, d) points, got shape {x.shape}")
        self._x = x.astype(np.float32, copy=False)
        self.n, self.d = self._x.shape

    def resident(self) -> np.ndarray:
        return self._x

    def read_block(self, start: int, stop: int) -> np.ndarray:
        return _padded_rows(self._x, start, stop)


class HostTiledSource(DataSource):
    """Host/disk-backed points streamed tile-by-tile (out-of-core plane).

    ``x`` may be any 2-D array-like that supports row slicing without
    loading everything — typically an ``np.memmap`` (see ``from_npy``) —
    or a plain ndarray kept host-side on purpose (e.g. to bound device
    memory, or to test tiled-vs-resident parity).
    """

    def __init__(self, x):
        if getattr(x, "ndim", None) != 2:
            raise ValueError("HostTiledSource expects a 2-D row-sliceable "
                             f"array, got {type(x).__name__}")
        self._x = x
        self.n, self.d = int(x.shape[0]), int(x.shape[1])

    @classmethod
    def from_npy(cls, path: str) -> "HostTiledSource":
        """Memory-map an .npy file: N is bounded by disk, not RAM."""
        return cls(np.load(path, mmap_mode="r"))

    def read_block(self, start: int, stop: int) -> np.ndarray:
        return _padded_rows(self._x, start, stop)


def _padded_rows(x, start: int, stop: int) -> np.ndarray:
    """Rows [start, stop) of the zero-padded layout, cast to float32."""
    n = x.shape[0]
    lo, hi = min(start, n), min(stop, n)
    block = np.asarray(x[lo:hi], dtype=np.float32)
    if stop > n:
        block = np.concatenate(
            [block, np.zeros((stop - start - (hi - lo), x.shape[1]),
                             np.float32)], axis=0)
    return block


def as_source(x: Union[np.ndarray, DataSource]) -> DataSource:
    """np.ndarray -> ResidentSource; DataSource instances pass through."""
    if isinstance(x, DataSource):
        return x
    return ResidentSource(np.asarray(x))
