"""llama-3.2-vision-11b — VLM with interleaved cross-attention layers.

Backbone only; the ViT encoder + projector is stubbed per the carve-out:
``input_specs()`` provides precomputed patch embeddings (1601 tokens).
[hf:meta-llama/Llama-3.2-11B-Vision]
"""
from repro.configs.base import ModelConfig, ATTN, CROSS

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    arch_type="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500_000.0,
    # cross-attention block every 5th layer (8 of 40)
    pattern=(ATTN, ATTN, ATTN, CROSS, ATTN),
    vision_tokens=1601,
    act="silu",
    long_context="sliding_window",
    source="Llama 3.2 Vision [hf:meta-llama/Llama-3.2-11B-Vision]",
)
