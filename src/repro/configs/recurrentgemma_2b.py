"""recurrentgemma-2b — Griffin hybrid: RG-LRU + local attention, 1:2 [arXiv:2402.19427]."""
from repro.configs.base import ModelConfig, RGLRUConfig, RGLRU, LOCAL_ATTN

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    arch_type="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,             # MQA
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    tie_embeddings=True,   # gemma-family tied unembedding
    sliding_window=2048,
    # (rec, rec, attn) x 8 + (rec, rec) = 26 layers
    pattern=(RGLRU, RGLRU, LOCAL_ATTN),
    remainder=(RGLRU, RGLRU),
    rglru=RGLRUConfig(lru_width=2560, conv_kernel=4),
    act="gelu",
    long_context="native",      # recurrent state + bounded-window KV
    source="RecurrentGemma / Griffin [arXiv:2402.19427]",
)
