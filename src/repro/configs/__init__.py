"""Config registry: ``get_config("<arch-id>")`` and reduced smoke variants."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

from repro.configs.base import (  # noqa: F401
    ATTN, CROSS, LOCAL_ATTN, RGLRU, SSM,
    DPMMConfig, InputShape, MLAConfig, MoEConfig, ModelConfig, RGLRUConfig,
    SSMConfig, TrainConfig,
    INPUT_SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
)

_ARCH_MODULES: Dict[str, str] = {
    "granite-8b": "granite_8b",
    "starcoder2-7b": "starcoder2_7b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "mistral-large-123b": "mistral_large_123b",
    "whisper-medium": "whisper_medium",
    "gemma2-9b": "gemma2_9b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    cfg: ModelConfig = mod.CONFIG
    cfg.validate()
    return cfg


def first_k_dense(cfg: ModelConfig) -> int:
    """MoE archs may keep the first k FFNs dense (DeepSeek-V2)."""
    if cfg.name == "deepseek-v2-lite-16b":
        return 1
    return 0


def smoke_config(name: str) -> ModelConfig:
    """Reduced variant of the same family: 2 layers, d_model<=512, <=4 experts.

    Used by the per-arch CPU smoke tests; the full configs are exercised only
    via the dry-run (ShapeDtypeStruct, no allocation).
    """
    cfg = get_config(name)
    kinds = cfg.layer_kinds
    # keep one period of the pattern (or 2 layers) to preserve heterogeneity
    if cfg.pattern and len(cfg.pattern) <= 4:
        pattern = cfg.pattern
        n_layers = len(pattern)
        remainder: tuple = ()
    elif cfg.pattern:
        # long pattern: keep one layer of each distinct kind (e.g. VLM's
        # (attn x4, cross) -> (attn, cross)), preserving first-seen order
        pattern = tuple(dict.fromkeys(cfg.pattern))
        n_layers = len(pattern)
        remainder = ()
    else:
        pattern = tuple(kinds[:2]) or (ATTN, ATTN)
        n_layers = 2
        remainder = ()
    changes = dict(
        name=cfg.name + "-smoke",
        num_layers=n_layers,
        d_model=256,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=64,
        d_ff=512 if cfg.d_ff else 0,
        vocab_size=512,
        pattern=pattern,
        remainder=remainder,
        sliding_window=64,
        vision_tokens=16 if cfg.vision_tokens else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq=24 if cfg.encoder_layers else 0,
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, num_shared_experts=1, top_k=2,
            d_expert=128, d_shared=128)
        changes["d_ff"] = 512
    if cfg.mla is not None:
        changes["mla"] = MLAConfig(
            kv_lora_rank=64, q_lora_rank=0, rope_head_dim=32,
            nope_head_dim=32, v_head_dim=64)
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(cfg.ssm, state_dim=8)
    if cfg.rglru is not None:
        changes["rglru"] = dataclasses.replace(cfg.rglru, lru_width=256)
    out = dataclasses.replace(cfg, **changes)
    out.validate()
    return out
