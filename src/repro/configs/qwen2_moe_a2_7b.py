"""qwen2-moe-a2.7b — MoE: 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    arch_type="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,                  # per routed expert
    vocab_size=151936,
    head_dim=128,
    rope_theta=1_000_000.0,
    moe=MoEConfig(
        num_experts=60,
        num_shared_experts=4,
        top_k=4,
        d_expert=1408,
        d_shared=5632,          # 4 x 1408
    ),
    act="silu",
    long_context="sliding_window",
    source="Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B]",
)
