"""starcoder2-7b — dense GQA + RoPE code model [arXiv:2402.19173]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    arch_type="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    head_dim=128,
    rope_theta=1_000_000.0,
    act="gelu",
    gated_mlp=False,
    long_context="sliding_window",
    source="StarCoder2 [arXiv:2402.19173]",
)
