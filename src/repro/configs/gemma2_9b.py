"""gemma2-9b — local+global alternating attention, logit softcaps [arXiv:2408.00118]."""
from repro.configs.base import ModelConfig, ATTN, LOCAL_ATTN

CONFIG = ModelConfig(
    name="gemma2-9b",
    arch_type="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=256000,
    head_dim=256,
    sliding_window=4096,
    logit_softcap=50.0,
    final_softcap=30.0,
    tie_embeddings=True,
    pattern=(LOCAL_ATTN, ATTN),     # 21 repeats
    act="gelu",
    long_context="sliding_window",
    source="Gemma 2 [arXiv:2408.00118]",
)
