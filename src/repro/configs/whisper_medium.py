"""whisper-medium — encoder-decoder audio model [arXiv:2212.04356].

Backbone only; the mel-spectrogram + conv frontend is stubbed per the
carve-out: ``input_specs()`` provides precomputed frame embeddings
(1500 frames). 24 encoder + 24 decoder layers.

``long_500k`` is SKIPPED for this arch: the decoder is specified for <=448
target positions and cross-attends to a <=1500-frame encoder output; a 524k
decoder self-attention cache is architecturally meaningless (DESIGN §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    arch_type="audio",
    num_layers=24,              # decoder layers
    encoder_layers=24,
    encoder_seq=1500,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    head_dim=64,
    act="gelu",
    gated_mlp=False,
    long_context="none",
    source="Whisper [arXiv:2212.04356]",
)
