"""deepseek-v2-lite-16b — MLA (kv_lora=512) + MoE 64e top-6, 2 shared [arXiv:2405.04434].

Layer 0 uses a dense FFN (first_k_dense=1), layers 1..26 are MoE —
matching the published DeepSeek-V2-Lite layout (the assignment's
"2 shared + 160 routed" figure describes full V2; Lite has 64 routed,
consistent with the assignment's own "MoE 64e top-6").
"""
from repro.configs.base import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,            # unused with MLA
    d_ff=10944,                 # layer-0 dense FFN width
    vocab_size=102400,
    rope_theta=10_000.0,
    moe=MoEConfig(
        num_experts=64,
        num_shared_experts=2,
        top_k=6,
        d_expert=1408,
        d_shared=2816,          # 2 x 1408
    ),
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=0,
        rope_head_dim=64,
        nope_head_dim=128,
        v_head_dim=128,
    ),
    act="silu",
    long_context="sliding_window",
    source="DeepSeek-V2(-Lite) [arXiv:2405.04434]",
)
FIRST_K_DENSE = 1
