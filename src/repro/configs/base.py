"""Config system: model architectures, input shapes, DPMM hyperparameters.

Every assigned architecture is expressed as a ``ModelConfig``; the DPMM (the
paper's own workload) is a ``DPMMConfig``. Configs are plain frozen
dataclasses so they are hashable (usable as jit static args) and trivially
serializable.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer-pattern vocabulary (per-layer block kinds, see models/transformer.py)
# ---------------------------------------------------------------------------
ATTN = "attn"            # global self-attention block
LOCAL_ATTN = "local"     # sliding-window self-attention block
CROSS = "cross"          # self-attention + cross-attention block (VLM/enc-dec)
SSM = "ssm"              # Mamba-1 selective-SSM block
RGLRU = "rglru"          # RG-LRU (Griffin) recurrent block


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts sub-config (None on dense archs)."""
    num_experts: int                 # routed experts
    num_shared_experts: int          # always-on shared experts
    top_k: int
    d_expert: int                    # per-expert FFN hidden dim
    d_shared: int                    # shared-expert FFN hidden dim (total)
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2) sub-config."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0             # 0 => full-rank q projection
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 sub-config."""
    state_dim: int = 16
    conv_kernel: int = 4
    expand: int = 2
    dt_rank: int = 0                 # 0 => ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RG-LRU (RecurrentGemma) sub-config."""
    lru_width: int = 0               # 0 => d_model
    conv_kernel: int = 4
    block_width: int = 0             # reserved


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One assigned architecture. Defaults describe a vanilla dense LM."""
    name: str
    arch_type: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 => d_model // num_heads
    # Layer pattern: repeated `pattern` then `remainder`; len(pattern) *
    # repeats + len(remainder) == num_layers.  Empty pattern => all ATTN.
    pattern: Tuple[str, ...] = ()
    remainder: Tuple[str, ...] = ()
    # Attention details
    rope_theta: float = 10000.0
    sliding_window: int = 4096       # used by LOCAL_ATTN blocks
    logit_softcap: float = 0.0       # gemma2-style attn logit soft-capping
    final_softcap: float = 0.0       # gemma2-style final-logit soft-capping
    qk_norm: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    act: str = "silu"                # silu | gelu
    gated_mlp: bool = True           # SwiGLU-style gate (False: plain 2-mat)
    # Sub-configs (None when not applicable)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # Encoder-decoder (audio) / vision frontends.
    encoder_layers: int = 0          # >0 => enc-dec (whisper)
    encoder_seq: int = 0             # stubbed frontend output length
    vision_tokens: int = 0           # stubbed VLM patch-embedding count
    # Serving
    long_context: str = "none"       # none | sliding_window | native
    # Reference / provenance
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Fully expanded per-layer kind list (length == num_layers)."""
        if not self.pattern:
            kinds: Tuple[str, ...] = (ATTN,) * self.num_layers
        else:
            reps = (self.num_layers - len(self.remainder)) // len(self.pattern)
            kinds = tuple(self.pattern) * reps + tuple(self.remainder)
        assert len(kinds) == self.num_layers, (
            f"{self.name}: pattern does not tile num_layers "
            f"({len(kinds)} != {self.num_layers})")
        return kinds

    @property
    def pattern_repeats(self) -> int:
        if not self.pattern:
            return self.num_layers
        return (self.num_layers - len(self.remainder)) // len(self.pattern)

    def validate(self) -> None:
        assert self.d_model % self.num_heads == 0 or self.head_dim, self.name
        assert self.num_heads % self.num_kv_heads == 0 or self.mla, self.name
        _ = self.layer_kinds


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One assigned (seq_len, global_batch) workload."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclasses.dataclass(frozen=True)
class DPMMConfig:
    """Hyper-parameters for the paper's DPMM sampler.

    ``component`` names a ``ComponentFamily`` in the registry
    (``repro.core.family``): gaussian | diag_gaussian | multinomial |
    poisson out of the box; user families registered via
    ``register_family`` are addressable by name the same way.
    """
    component: str = "gaussian"       # core.family registry lookup key
    alpha: float = 10.0               # DP concentration
    # static capacity (see DESIGN §6) — or the string 'auto' (resident data
    # plane only): the slab starts at max(8, 2*init_clusters) slots and
    # doubles at scan-chunk boundaries whenever the live cluster count
    # crosses half the slab, capped at k_max_cap. k_max becomes a high-water
    # mark the sampler discovers, not an up-front planning decision. Growth
    # changes PRNG draw *shapes*, so an 'auto' chain is deterministic but
    # not bitwise a fixed-k_max chain — pin k_max for golden chains.
    k_max: object = 64                # int, or the string 'auto'
    k_max_cap: int = 4096             # growth ceiling for k_max='auto'
    init_clusters: int = 1
    iters: int = 100
    burnout: int = 15                 # no splits/merges before this iter
    log_every: int = 10               # scan-chunk size: iterations per
    #                                   jitted device call; the host syncs
    #                                   (history pull + timing) once per
    #                                   chunk, i.e. ceil(iters/log_every)
    #                                   times per fit() instead of per iter
    subreset_every: int = 10          # re-init sub-labels after this many
    #                                   consecutive rejected splits (escapes
    #                                   sub-cluster local modes; mirrors the
    #                                   reference implementation's reset)
    # NIW prior (gaussian); m is the data mean, Psi = niw_psi * I
    niw_kappa: float = 1.0
    niw_nu_extra: float = 3.0         # nu = d + nu_extra
    niw_psi: float = 1.0              # IW scale (cluster-scale, not data)
    # Dirichlet prior (multinomial)
    dir_alpha: float = 1.0
    # Gamma prior (poisson — the paper's suggested extra family, §3.4.3)
    gamma_a0: float = 1.0
    gamma_b0: float = 1.0
    # NIG prior (diag_gaussian); m is the data mean. Defaults mirror the
    # NIW prior at d=1 (a = nu/2, b = psi/2 with psi=1, nu=1+nu_extra)
    nig_kappa: float = 1.0
    nig_a0: float = 2.0
    nig_b0: float = 0.5
    # sparse-K sweeps: gather the K_active live clusters into a compact
    # slab before each sweep/move so per-iteration cost is O(K_active), not
    # O(k_max). Pure gather/scatter around an unchanged stat fold — chains
    # are bitwise identical to the dense-slab chains (tests/test_sparse_k).
    compact: bool = True
    k_block: int = 8                  # cluster-tile size the K-blocked
    #                                   kernels stream through VMEM; per-
    #                                   grid-step memory is O(k_block), so
    #                                   k_max no longer has to fit in VMEM
    # distribution
    shard_features: bool = False      # shard d over the model axis (high-d)
    use_pallas: bool = False          # swap in Pallas kernels (TPU)
    # data plane: None = resident (points device-resident, fastest); an int
    # streams points through tiles of ~this many rows per data shard
    # (rounded up to the suff-stat fold block) from the DataSource — device
    # memory becomes O(k_max + tile_size) and N is bounded by host storage.
    # Chains are bitwise identical across planes and tile sizes.
    tile_size: Optional[int] = None
    # ---- fault tolerance (see README "Fault tolerance") -------------------
    # auto-checkpointing: with checkpoint_path (a rotation PREFIX — members
    # are {prefix}-{it:08d}.npz, atomic + CRC-verified, newest
    # checkpoint_keep retained) and checkpoint_every (iterations; the
    # resident driver saves at the first chunk boundary past each
    # multiple), both drivers persist ModelState as they go and
    # fit(resume=True) continues from the newest member that VERIFIES —
    # bitwise equal to the uninterrupted chain.
    checkpoint_path: Optional[str] = None
    checkpoint_every: Optional[int] = None
    checkpoint_keep: int = 3
    # tile-stream retry (tiled driver): transient IOError/short-read —
    # and, with guard_tiles, NaN/Inf-row — faults on DataSource.read_block
    # are retried up to io_retries times with io_backoff_s exponential
    # backoff before failing loudly with tile provenance (TileReadError).
    io_retries: int = 3
    io_backoff_s: float = 0.05
    guard_tiles: bool = True
    # numerical guardrails: an O(K) on-device all-finite + degenerate-
    # cluster check over ModelState rides the existing chunk-boundary sync
    # (clean chains are bitwise unchanged — the check only READS state).
    # On failure the driver rolls back to the last healthy boundary with
    # the key advanced, at most max_recoveries times, then raises
    # DivergenceError. Every event lands in FitResult.recoveries.
    guardrails: bool = True
    max_recoveries: int = 3
    # ---- elastic multi-process sampling (repro.dist) ----------------------
    # workers=N spawns N local worker subprocesses, each owning a
    # contiguous STATS_BLOCK-aligned row-range shard of x behind the
    # DataSource protocol; a coordinator process keeps ModelState and the
    # O(K) steps and folds the workers' per-block substat partials in
    # fixed global order, so the distributed chain is bitwise identical
    # to the single-process tiled fit at ANY worker count. Workers
    # heartbeat every worker_heartbeat_s; a work item that misses
    # worker_deadline_s (hung read, wedged process) gets its worker
    # killed, its row-range reassigned to a survivor, and the worker
    # respawned at most max_worker_retries times per slot —
    # WorkerLostError fires only when no survivor can take the range.
    workers: Optional[int] = None
    worker_deadline_s: float = 120.0
    worker_heartbeat_s: float = 0.5
    max_worker_retries: int = 2
    seed: int = 0

    def __post_init__(self):
        def positive(name, value):
            import numbers
            if (isinstance(value, bool)
                    or not isinstance(value, numbers.Integral)
                    or value <= 0):
                raise ValueError(
                    f"DPMMConfig.{name} must be a positive int, got "
                    f"{value!r}")
        if self.k_max == "auto":
            if self.tile_size is not None:
                raise ValueError(
                    "DPMMConfig.k_max='auto' requires the resident data "
                    "plane (tile_size=None): the tiled driver re-traces "
                    "per iteration and has no chunk boundary to grow at")
            positive("k_max_cap", self.k_max_cap)
            cap = self.k_max_cap
        else:
            positive("k_max", self.k_max)
            cap = self.k_max
        positive("init_clusters", self.init_clusters)
        positive("log_every", self.log_every)
        positive("k_block", self.k_block)
        if self.tile_size is not None:
            positive("tile_size", self.tile_size)
        if self.init_clusters > cap:
            raise ValueError(
                f"DPMMConfig.init_clusters ({self.init_clusters}) exceeds "
                f"k_max ({cap}): the static capacity cannot hold "
                "the initial clusters")
        if self.iters < 0 or self.burnout < 0:
            raise ValueError(
                f"DPMMConfig.iters/burnout must be >= 0, got "
                f"iters={self.iters} burnout={self.burnout}")
        if self.checkpoint_every is not None:
            positive("checkpoint_every", self.checkpoint_every)
            if not self.checkpoint_path:
                raise ValueError(
                    "DPMMConfig.checkpoint_every is set but "
                    "checkpoint_path is not: auto-checkpointing needs a "
                    "rotation prefix to write to")
        positive("checkpoint_keep", self.checkpoint_keep)
        if self.io_retries < 0 or self.io_backoff_s < 0:
            raise ValueError(
                f"DPMMConfig.io_retries/io_backoff_s must be >= 0, got "
                f"{self.io_retries}/{self.io_backoff_s}")
        if self.max_recoveries < 0:
            raise ValueError(
                f"DPMMConfig.max_recoveries must be >= 0, got "
                f"{self.max_recoveries}")
        if self.workers is not None:
            positive("workers", self.workers)
            if self.k_max == "auto":
                raise ValueError(
                    "DPMMConfig.workers requires a fixed integer k_max: "
                    "the growable slab re-plans shapes mid-fit, which the "
                    "worker protocol does not ship")
            if self.shard_features:
                raise ValueError(
                    "DPMMConfig.workers does not compose with "
                    "shard_features yet: worker shards split rows, not "
                    "columns")
        if self.worker_deadline_s <= 0 or self.worker_heartbeat_s <= 0:
            raise ValueError(
                "DPMMConfig.worker_deadline_s/worker_heartbeat_s must be "
                f"> 0, got {self.worker_deadline_s}/"
                f"{self.worker_heartbeat_s}")
        if self.max_worker_retries < 0:
            raise ValueError(
                f"DPMMConfig.max_worker_retries must be >= 0, got "
                f"{self.max_worker_retries}")


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Optimizer / trainer knobs."""
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    loss_chunk: int = 1024            # vocab-chunked CE seq-chunk size
    remat: bool = True
    remat_policy: str = "full"        # full | dots (save matmul outputs)
    seed: int = 0
