"""Elastic multi-process distributed sampling (coordinator/worker shards).

``DPMMConfig.workers=N`` routes ``DPMM.fit`` through this package: a
coordinator process (repro.dist.coordinator) owns ModelState and every
O(K) step, N worker processes (repro.dist.worker) each own a
STATS_BLOCK-aligned row-range shard of x and stream the per-point tile
bodies over it, shipping per-block suff-stat partials back over a
framed, CRC-checked socket protocol (repro.dist.proto).

The package's contract, asserted in tests/test_dist.py and gated in CI:
the distributed chain is **bitwise identical** to the single-process
tiled fit at any worker count, including across worker SIGKILL / hang
failover (row ranges are reassigned to survivors and respawns; the fold
replay order never changes).
"""
from repro.dist.proto import ProtocolError
from repro.dist.coordinator import Coordinator, DistHooks, fit_distributed

__all__ = ["Coordinator", "DistHooks", "ProtocolError", "fit_distributed"]
