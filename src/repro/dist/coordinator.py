"""Coordinator for elastic multi-process distributed sampling.

The third fit driver (``DPMMConfig.workers=N``; dispatched from
``DPMM.fit``): one coordinator process owns ModelState and every O(K)
step — ``sweep_model``, the split/merge plan, ``finalize_substats``,
guardrails, auto-checkpointing — while N spawned worker processes each
own a contiguous, STATS_BLOCK-aligned row range of x behind the
``DataSource`` protocol and run the per-point tile bodies
(repro.dist.worker) on it.

**The bitwise-fold contract.** The single-process tiled driver folds
suff-stats strictly left-to-right over STATS_BLOCK blocks in global
point order, with the accumulator carried across tiles. Workers
therefore ship their substat partials *per block, unfolded*, and the
coordinator replays ``acc += p_block`` here, in fixed global block
order, on the host (same-width IEEE f32 adds — bit-identical to the
device fold). Two consequences, both load-bearing:

 1. the distributed chain is **bitwise identical** to the
    single-process tiled fit (pinned to a 1-device mesh, where the
    cross-shard psum is a no-op and the fold is fully sequential) at
    ANY worker count — worker count is a pure wall-clock knob;
 2. failover is bitwise-neutral by construction: any worker recomputes
    any block to the same bits (per-point randomness is counter-based
    on the global index; ModelState is broadcast losslessly via the
    checkpoint codec), so reassigning a dead worker's range changes
    nothing but wall clock.

**The failure model.** Workers heartbeat every ``worker_heartbeat_s``.
Per WORK item the coordinator arms a ``worker_deadline_s`` deadline.
A dead worker (SIGKILL, crash) surfaces as EOF/heartbeat loss on its
reader thread; a *hung* worker (wedged read, livelock) keeps
heartbeating but misses its deadline and is killed. Either way the
range is requeued to survivors, a ``worker_failover`` event is logged
into ``FitResult.recoveries``, and the slot is respawned (with
``RetryPolicy`` backoff) at most ``cfg.max_worker_retries`` times.
:class:`WorkerLostError` is raised only when work is pending, no worker
survives, and every respawn budget is spent. Shards are stateless —
labels recompute each sweep, ModelState lives here — so recovery needs
no worker-side state at all.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import queue
import socket
import subprocess
import sys
import tempfile
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.dist import proto

# Bound on spawn -> HELLO -> INIT -> warmup -> READY (covers a cold jax
# import plus every per-phase XLA compile on a loaded CI container; work
# deadlines stay tight because warmup pre-compiles the tile bodies).
READY_TIMEOUT_S = 600.0


class _HandshakeError(RuntimeError):
    """A worker failed to come up (died pre-HELLO, bad id, no READY)."""


@dataclasses.dataclass
class DistHooks:
    """Chaos/observability hooks for tests and benchmarks.

    ``worker_faults`` maps worker slot -> ``FaultInjectingSource``
    kwargs applied to that worker's shard view (respawns inherit them —
    a persistently faulty shard stays faulty). ``on_iteration`` runs on
    the coordinator at the top of every iteration with
    ``(absolute_iter, coordinator)`` — e.g. to SIGKILL a worker pid
    mid-fit."""
    worker_faults: Optional[Dict[int, dict]] = None
    on_iteration: Optional[Callable[[int, "Coordinator"], None]] = None


class _Worker:
    """Slot-side view of one worker process."""

    def __init__(self, slot: int):
        self.slot = slot
        self.proc: Optional[subprocess.Popen] = None
        self.conn: Optional[socket.socket] = None
        self.reader: Optional[threading.Thread] = None
        self.alive = False
        self.item: Optional[Tuple[int, int, int]] = None
        self.deadline: Optional[float] = None
        self.last_seen = 0.0
        self.respawns = 0
        # incarnation counter: bumped on every (re)connect. Reader-thread
        # messages carry the epoch they were read under, so anything a
        # dead incarnation left in the inbox (a buffered result, its own
        # EOF marker) cannot be misattributed to a respawned successor.
        self.epoch = 0

    @property
    def id(self) -> str:
        return f"w{self.slot}"


def shard_ranges(n: int, workers: int, stats_block: int
                 ) -> List[Tuple[int, int, int]]:
    """Static contiguous row ranges, one per worker slot, cut on the
    suff-stat block grid so every block is computed whole by exactly one
    worker: ``[(lo, hi, preferred_slot), ...]`` sorted by ``lo`` (the
    global fold order). Extra workers (more slots than blocks) get no
    range and serve purely as failover capacity."""
    nb = -(-n // stats_block)
    per = -(-nb // workers)
    ranges = []
    for w in range(workers):
        lo = min(w * per * stats_block, n)
        hi = min((w + 1) * per * stats_block, n)
        if lo < hi:
            ranges.append((lo, hi, w))
    return ranges


class Coordinator:
    """Worker-pool plumbing: spawn/handshake, scatter/gather with
    deadlines, failover, bounded respawn. The sampling logic lives in
    :func:`fit_distributed`."""

    def __init__(self, cfg, init_meta: dict, events: List[dict],
                 hooks: Optional[DistHooks] = None):
        self.cfg = cfg
        self.events = events
        self.hooks = hooks or DistHooks()
        self._init_meta = init_meta
        self._inbox: "queue.Queue" = queue.Queue()
        self._cur_phase: Optional[Tuple[dict, dict]] = None
        self.respawns_done = 0
        self.reassignments = 0
        # liveness window on the reader socket: several heartbeats must
        # go missing before an *idle* worker is declared dead
        self._liveness_s = max(10 * cfg.worker_heartbeat_s, 5.0)
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind(("127.0.0.1", 0))
        self._listener.listen(max(cfg.workers * 2, 8))
        self._port = self._listener.getsockname()[1]
        self.workers = [_Worker(s) for s in range(cfg.workers)]
        deadline = time.monotonic() + READY_TIMEOUT_S
        for w in self.workers:
            self._spawn(w)
        # accept in arrival order (workers import jax / warm up in
        # parallel), then confirm READY per slot
        todo = {w.id: w for w in self.workers}
        while todo:
            conn, wid = self._accept_hello(deadline)
            w = todo.pop(wid, None)
            if w is None:
                conn.close()
                continue
            w.conn = conn
            proto.send_msg(conn, "init", self._slot_init_meta(w.slot))
        for w in self.workers:
            self._wait_ready(w, deadline)
            self._online(w)

    # -- spawn / handshake --------------------------------------------------
    def worker_pids(self) -> List[Optional[int]]:
        return [w.proc.pid if w.proc is not None else None
                for w in self.workers]

    def _slot_init_meta(self, slot: int) -> dict:
        meta = dict(self._init_meta)
        faults = (self.hooks.worker_faults or {}).get(slot)
        if faults:
            meta["faults"] = faults
        return meta

    def _spawn(self, w: _Worker) -> None:
        import repro
        env = os.environ.copy()
        # repro is a namespace package (__file__ is None): resolve the
        # import root from __path__ so spawned workers find the same tree
        pkg_root = os.path.dirname(os.path.abspath(
            list(repro.__path__)[0]))
        env["PYTHONPATH"] = (pkg_root + os.pathsep
                             + env.get("PYTHONPATH", "")).rstrip(os.pathsep)
        w.proc = subprocess.Popen(
            [sys.executable, "-m", "repro.dist.worker",
             "--connect", f"127.0.0.1:{self._port}", "--id", w.id],
            env=env)

    def _accept_hello(self, deadline: float) -> Tuple[socket.socket, str]:
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise _HandshakeError("timed out waiting for a worker "
                                      "to connect")
            self._listener.settimeout(min(remaining, 5.0))
            try:
                conn, _addr = self._listener.accept()
            except socket.timeout:
                dead = [w.id for w in self.workers
                        if w.conn is None and w.proc is not None
                        and w.proc.poll() is not None]
                if dead:
                    raise _HandshakeError(
                        f"worker(s) {dead} exited before connecting "
                        "(startup crash)")
                continue
            conn.settimeout(self._liveness_s)
            try:
                kind, meta, _ = proto.recv_msg(conn)
            except (proto.ProtocolError, OSError):
                conn.close()
                continue
            if kind != "hello" or "id" not in meta:
                conn.close()
                continue
            return conn, str(meta["id"])

    def _wait_ready(self, w: _Worker, deadline: float) -> None:
        """Drain heartbeats until READY (warmup runs worker-side)."""
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise _HandshakeError(f"worker {w.id} never became ready")
            w.conn.settimeout(min(remaining, self._liveness_s))
            try:
                kind, meta, _ = proto.recv_msg(w.conn)
            except (proto.ProtocolError, OSError) as e:
                raise _HandshakeError(
                    f"worker {w.id} lost during startup "
                    f"({type(e).__name__}: {e})")
            if kind == "ready":
                return
            if kind == "error":
                raise _HandshakeError(
                    f"worker {w.id} failed during startup: "
                    f"{meta.get('detail', '')}")
            # heartbeats (and anything else) just keep the clock alive

    def _online(self, w: _Worker) -> None:
        w.conn.settimeout(self._liveness_s)
        w.last_seen = time.monotonic()
        w.alive = True
        w.epoch += 1
        w.reader = threading.Thread(target=self._reader,
                                    args=(w, w.conn, w.epoch),
                                    daemon=True)
        w.reader.start()

    def _reader(self, w: _Worker, conn: socket.socket, epoch: int) -> None:
        try:
            while True:
                kind, meta, arrays = proto.recv_msg(conn)
                if epoch == w.epoch:
                    w.last_seen = time.monotonic()
                if kind == "heartbeat":
                    continue
                self._inbox.put((w, epoch, kind, meta, arrays))
        except (proto.ProtocolError, OSError) as e:
            self._inbox.put((w, epoch, "__down__",
                             {"detail": f"{type(e).__name__}: {e}"}, {}))

    def _send(self, w: _Worker, kind: str, meta: Optional[dict] = None,
              arrays: Optional[dict] = None) -> bool:
        try:
            proto.send_msg(w.conn, kind, meta, arrays)
            return True
        except (OSError, proto.ProtocolError):
            return False

    # -- failover -----------------------------------------------------------
    def _lost(self, w: _Worker, detail: str,
              pending: Optional[List] = None) -> None:
        """Declare ``w`` lost: kill the process, requeue its work item,
        log the ``worker_failover`` event, and respawn within budget
        (RetryPolicy backoff). Idempotent per incarnation."""
        if not w.alive:
            return
        from repro.core.resilience import RetryPolicy
        w.alive = False
        item, w.item, w.deadline = w.item, None, None
        if w.proc is not None and w.proc.poll() is None:
            w.proc.kill()               # hung or half-dead: no niceties
        try:
            w.conn.close()
        except OSError:
            pass
        if item is not None and pending is not None:
            pending.append(item)
            self.reassignments += 1
        phase_meta = self._cur_phase[0] if self._cur_phase else {}
        will_respawn = w.respawns < self.cfg.max_worker_retries
        self.events.append({
            "kind": "worker_failover", "worker": w.slot,
            "iter": phase_meta.get("iter"),
            "phase": phase_meta.get("phase"),
            "rows": [int(item[0]), int(item[1])] if item else None,
            "respawn": will_respawn, "detail": detail})
        policy = RetryPolicy(max_retries=self.cfg.max_worker_retries,
                             backoff_s=self.cfg.io_backoff_s)
        t_stall = time.monotonic()
        while w.respawns < policy.max_retries:
            w.respawns += 1
            delay = policy.backoff_s * policy.backoff_mult ** (
                w.respawns - 1)
            if delay > 0:
                time.sleep(delay)
            try:
                self._respawn(w)
                self.respawns_done += 1
                break
            except _HandshakeError as e:
                self.events.append({
                    "kind": "worker_failover", "worker": w.slot,
                    "iter": phase_meta.get("iter"),
                    "phase": phase_meta.get("phase"), "rows": None,
                    "respawn": w.respawns < policy.max_retries,
                    "detail": f"respawn attempt {w.respawns} failed: {e}"})
        # else: budget spent — the slot stays dead; survivors absorb it.
        # The respawn handshake blocked the gather loop (worker warmup),
        # so credit the stall to every other in-flight deadline: those
        # workers' *compute* budget must not shrink because a peer died.
        stall = time.monotonic() - t_stall
        for o in self.workers:
            if o.alive and o.deadline is not None:
                o.deadline += stall

    def _respawn(self, w: _Worker) -> None:
        self._spawn(w)
        deadline = time.monotonic() + READY_TIMEOUT_S
        conn, wid = self._accept_hello(deadline)
        if wid != w.id:
            conn.close()
            raise _HandshakeError(
                f"respawned worker announced id {wid!r}, want {w.id!r}")
        w.conn = conn
        proto.send_msg(conn, "init", self._slot_init_meta(w.slot))
        self._wait_ready(w, deadline)
        if self._cur_phase is not None:
            proto.send_msg(conn, "phase", *self._cur_phase)
        self._online(w)

    # -- phase scatter/gather -----------------------------------------------
    def set_phase(self, meta: dict, arrays: dict) -> None:
        self._cur_phase = (meta, arrays)
        for w in self.workers:
            if w.alive and not self._send(w, "phase", meta, arrays):
                self._lost(w, "phase broadcast failed (connection lost)")

    def run_phase(self, meta: dict, arrays: dict,
                  items: List[Tuple[int, int, int]],
                  item_arrays: Optional[Callable[[int, int], dict]] = None
                  ) -> Dict[int, Tuple[dict, dict]]:
        """Broadcast the phase, scatter one WORK per row range, gather
        RESULTs with deadline/failover handling; returns ``{lo: (meta,
        arrays)}`` for every item. Raises :class:`WorkerLostError` when
        work remains and no worker can take it."""
        from repro.core.resilience import WorkerLostError
        self.set_phase(meta, arrays)
        pending = list(items)
        results: Dict[int, Tuple[dict, dict]] = {}
        while len(results) < len(items):
            self._assign(pending, item_arrays)
            if (len(results) < len(items)
                    and not any(w.alive for w in self.workers)):
                raise WorkerLostError(
                    f"distributed {meta.get('phase')} pass stalled: "
                    f"{len(items) - len(results)} row range(s) "
                    "unprocessed, no live workers, and every "
                    f"max_worker_retries={self.cfg.max_worker_retries} "
                    "respawn budget is spent. See .recoveries for the "
                    "failover log.", self.events)
            try:
                w, epoch, kind, m, arrs = self._inbox.get(timeout=0.05)
            except queue.Empty:
                pass
            else:
                if not w.alive or epoch != w.epoch:
                    pass            # stale message from a dead incarnation
                elif kind == "result":
                    if w.item is not None and int(m["lo"]) == w.item[0]:
                        results[int(m["lo"])] = (m, arrs)
                        w.item, w.deadline = None, None
                elif kind == "error":
                    self._lost(w, f"worker error: {m.get('detail', '')}",
                               pending)
                elif kind == "__down__":
                    self._lost(w, m.get("detail", "connection lost"),
                               pending)
            now = time.monotonic()
            for w in self.workers:
                if not w.alive:
                    continue
                if w.item is not None and now > w.deadline:
                    self._lost(w, f"work deadline "
                                  f"({self.cfg.worker_deadline_s}s) missed "
                                  f"for rows [{w.item[0]}, {w.item[1]}) — "
                                  "worker hung", pending)
                elif (w.item is None
                      and now - w.last_seen > self._liveness_s):
                    self._lost(w, "heartbeat lost while idle", pending)
        return results

    def _assign(self, pending: List,
                item_arrays: Optional[Callable[[int, int], dict]]) -> None:
        for w in self.workers:
            if not pending:
                return
            if not w.alive or w.item is not None:
                continue
            idx = next((i for i, it in enumerate(pending)
                        if it[2] == w.slot), 0)
            item = pending.pop(idx)
            lo, hi, _pref = item
            arrs = item_arrays(lo, hi) if item_arrays else {}
            if self._send(w, "work", {"lo": int(lo), "hi": int(hi)}, arrs):
                w.item = item
                w.deadline = time.monotonic() + self.cfg.worker_deadline_s
            else:
                pending.append(item)
                self._lost(w, "work send failed (connection lost)",
                           pending)

    # -- teardown -----------------------------------------------------------
    def shutdown(self) -> None:
        for w in self.workers:
            if w.conn is not None:
                try:
                    proto.send_msg(w.conn, "shutdown")
                except (OSError, proto.ProtocolError):
                    pass
        for w in self.workers:
            if w.proc is not None:
                try:
                    w.proc.wait(timeout=5)
                except subprocess.TimeoutExpired:
                    w.proc.kill()
                    w.proc.wait()
            if w.conn is not None:
                try:
                    w.conn.close()
                except OSError:
                    pass
            w.alive = False
        self._listener.close()


# ---------------------------------------------------------------------------
# The distributed fit driver (called from DPMM.fit via cfg.workers)
# ---------------------------------------------------------------------------
def _materialize(source) -> Tuple[str, Optional[str]]:
    """Resolve the .npy file workers will memmap: the source's own
    backing file when it has one, else a temp dump (returned as the
    cleanup path). Fault-injecting wrappers are unwrapped — worker-side
    faults are injected via DistHooks, not smuggled through the dump."""
    from repro.data.faults import FaultInjectingSource
    src = source
    while isinstance(src, FaultInjectingSource):
        src = src._inner
    backing = getattr(src, "_x", None)
    fname = getattr(backing, "filename", None)
    if fname and str(fname).endswith(".npy"):
        return str(fname), None
    x = src.resident()
    if x is None:
        x = np.concatenate([src.read_block(s, min(s + 65_536, src.n))
                            for s in range(0, src.n, 65_536)], axis=0)
    fd, path = tempfile.mkstemp(suffix=".npy", prefix="dpmm-dist-")
    os.close(fd)
    np.save(path, np.ascontiguousarray(
        np.asarray(x, np.float32)))
    return path, path


def fit_distributed(dpmm, source, iters: int, verbose: bool, *,
                    key=None, init_state=None,
                    hooks: Optional[DistHooks] = None):
    """Mirror of ``DPMM._fit_tiled``'s model-side loop with the tile
    streams replaced by coordinator phases. See the module docstring for
    the bitwise and failure contracts."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import checkpoint, gibbs, splitmerge
    from repro.core.distributed import (data_axes_of, make_data_mesh,
                                        n_data_shards, shard_map)
    from repro.core.family import state_partition_specs
    from repro.core.sampler import (_Recovery, _copy_state, _init_model,
                                    _k_compact, _move_key, _peak_fields,
                                    _recovery_rekey, _rss_peak_bytes,
                                    _summaries, _tree_bytes, model_health)

    cfg = dpmm.cfg
    family = dpmm.family
    if dpmm.mesh is not None and n_data_shards(dpmm.mesh) > 1:
        raise ValueError(
            "cfg.workers does not compose with a multi-device local mesh "
            "yet: worker shards replace local data sharding (the "
            "distributed fold is pinned to the 1-device layout)")
    SB = gibbs.STATS_BLOCK
    mesh = make_data_mesh(1)
    axes = data_axes_of(mesh)
    n, d = source.n, source.d
    if n >= 2 ** 32:
        raise ValueError(
            f"N={n} exceeds the uint32 global point-index space: "
            "counter-based draws would wrap and silently corrupt the "
            "chain")
    k_max = cfg.k_max
    prior = family.build_prior(cfg, source.column_mean()[None, :])
    rec = _Recovery(cfg, family.name, 0)
    rss0 = _rss_peak_bytes()
    if key is None:
        key = jax.random.key(cfg.seed)

    # ---- coordinator-side jitted constructions (identical jaxprs to
    # _fit_tiled at shards=1, n_chains=1 — same executables, same bits) --
    model_specs, _ = state_partition_specs(family, P(axes))
    rep = P()
    acc_shape = jax.eval_shape(
        lambda: gibbs.empty_substats(family, k_max, d))
    acc_specs = type(acc_shape)(**{
        f: P(*([axes] + [None] * getattr(acc_shape, f).ndim))
        for f in acc_shape._fields})
    acc_shardings = type(acc_shape)(**{
        f: NamedSharding(mesh, getattr(acc_specs, f))
        for f in acc_shape._fields})
    local = lambda acc: jax.tree.map(lambda v: v[0], acc)
    smap = functools.partial(shard_map, mesh=mesh)
    finalize_fn = jax.jit(smap(
        lambda acc: gibbs.finalize_substats(family, local(acc), axes,
                                            None),
        in_specs=(acc_specs,), out_specs=(rep, rep)))
    sweep_model_fn = jax.jit(functools.partial(
        gibbs.sweep_model, prior=prior, family=family, alpha=cfg.alpha))
    plan_fn = jax.jit(lambda m: splitmerge.plan_split_merge(
        _move_key(m), m, prior, family, cfg.alpha, cfg.subreset_every))
    advance_fn = jax.jit(
        lambda m: (m._replace(it=m.it + 1),
                   _summaries(m, prior, family, cfg.alpha)))
    set_stats_fn = jax.jit(
        lambda m, s, ss: m._replace(stats=s, substats=ss))
    apply_plan_fn = jax.jit(
        lambda m, plan, s, ss: m._replace(
            active=plan.merge.new_active, stuck=plan.stuck,
            stats=s, substats=ss))
    set_stats_comp_fn = jax.jit(
        lambda m, c, s, ss: m._replace(
            stats=gibbs.compact_scatter(c, k_max, s),
            substats=gibbs.compact_scatter(c, k_max, ss)))
    apply_plan_comp_fn = jax.jit(
        lambda m, plan, c, s, ss: m._replace(
            active=plan.merge.new_active, stuck=plan.stuck,
            stats=gibbs.compact_scatter(c, k_max, s),
            substats=gibbs.compact_scatter(c, k_max, ss)))
    comp_fns: Dict[int, Any] = {}

    def compact_plan_fn(k_c: int):
        if k_c not in comp_fns:
            comp_fns[k_c] = jax.jit(
                lambda act: gibbs.compaction_plan(act, k_c))
        return comp_fns[k_c]

    @functools.lru_cache(maxsize=None)
    def acc_template(k: int):
        shape_k = jax.eval_shape(
            lambda: gibbs.empty_substats(family, k, d))
        return [(getattr(shape_k, f).shape,
                 np.dtype(getattr(shape_k, f).dtype))
                for f in shape_k._fields], type(shape_k)

    # ---- shard layout + worker pool -----------------------------------
    it0 = int(jax.device_get(init_state.it)) if init_state is not None \
        else 0
    if init_state is not None:
        k0 = int(np.asarray(jax.device_get(init_state.active)).sum())
    else:
        k0 = cfg.init_clusters
    warm_k = {"sweep_k": [], "sm_k": [],
              "init": init_state is None,
              "sm": it0 + iters > cfg.burnout}
    if cfg.compact:
        kc = _k_compact(k0, 1, k_max, cfg.k_block)
        if kc is not None:
            warm_k["sweep_k"].append(int(kc))
        kc = _k_compact(k0, 2, k_max, cfg.k_block)
        if kc is not None:
            warm_k["sm_k"].append(int(kc))
    ranges = shard_ranges(n, cfg.workers, SB)
    data_path, tmp_path = _materialize(source)
    init_meta = {"cfg": dataclasses.asdict(cfg), "data_path": data_path,
                 "heartbeat_s": cfg.worker_heartbeat_s, "warm": warm_k}
    labels_h = np.zeros(n, np.int32)
    sublabels_h = np.zeros(n, np.int32)
    coord = Coordinator(cfg, init_meta, rec.events, hooks)

    def run_pass(phase: str, k_c: Optional[int], phase_arrays: dict,
                 need_labels: bool, iter_tag: int):
        """One scatter/gather pass + the host-side bitwise fold replay;
        returns ``finalize_fn``'s (stats, substats)."""
        meta = {"phase": phase, "iter": int(iter_tag),
                "k_c": None if k_c is None else int(k_c)}
        item_arrays = ((lambda lo, hi: {"labels": labels_h[lo:hi],
                                        "sublabels": sublabels_h[lo:hi]})
                       if need_labels else None)
        results = coord.run_phase(meta, phase_arrays, ranges, item_arrays)
        k_eff = k_max if k_c is None else k_c
        leaf_shapes, acc_type = acc_template(k_eff)
        acc_leaves = [np.zeros(shape, dtype)
                      for shape, dtype in leaf_shapes]
        for lo, hi, _pref in ranges:          # sorted: global fold order
            m, arrs = results[lo]
            labels_h[lo:hi] = arrs["labels"]
            sublabels_h[lo:hi] = arrs["sublabels"]
            for e in m.get("io_events", []):
                rec.events.append(dict(e, worker=m.get("worker")))
            nb = -(-(hi - lo) // SB)
            for i, (shape, _dt) in enumerate(leaf_shapes):
                part = arrs.get(f"p{i}")
                if part is None or part.shape != (nb,) + shape:
                    raise proto.ProtocolError(
                        f"worker partial p{i} for rows [{lo}, {hi}) has "
                        f"shape {None if part is None else part.shape}, "
                        f"want {(nb,) + shape} — shard out of sync")
            # the replayed fold: += in global block order, host-side
            # same-dtype IEEE adds — bit-identical to the device fold
            for b in range(nb):
                for i in range(len(acc_leaves)):
                    np.add(acc_leaves[i], arrs[f"p{i}"][b],
                           out=acc_leaves[i])
        acc = acc_type(**{
            f: leaf[None] for f, leaf in zip(acc_type._fields, acc_leaves)})
        return finalize_fn(jax.device_put(acc, acc_shardings))

    try:
        # ---- init / resume -------------------------------------------
        if init_state is not None:
            model = jax.device_put(_copy_state(init_state),
                                   NamedSharding(mesh, P()))
        else:
            stats0, _ = run_pass("init1", None, {}, False, it0)
            means0 = jax.jit(family.cluster_means)(stats0)
            v0 = jax.jit(lambda k: splitmerge.hyperplane_vecs(
                jax.random.fold_in(k, 1), k_max, d, jnp.float32))(key)
            stats, substats = run_pass(
                "init2", None, {"means0": np.asarray(means0),
                                "v0": np.asarray(v0)}, True, it0)
            model = jax.jit(lambda k, s, ss: _init_model(
                k, s, ss, prior=prior, family=family, cfg=cfg,
                k_max=k_max))(key, stats, substats)

        rec._last_saved = it0
        est_peak = 2 * _tree_bytes(model) + sum(
            int(np.prod(s)) * dt.itemsize
            for s, dt in acc_template(k_max)[0])
        health_fn = jax.jit(model_health) if cfg.guardrails else None
        snap = (jax.tree.map(jnp.copy, model), 0) if cfg.guardrails \
            else None
        hist_rows: List[Dict[str, np.ndarray]] = []
        times: List[float] = []
        it = 0
        while it < iters:
            t0 = time.perf_counter()
            if coord.hooks.on_iteration is not None:
                coord.hooks.on_iteration(it0 + it, coord)
            model = sweep_model_fn(model)
            k_c = (_k_compact(k0, 1, k_max, cfg.k_block)
                   if cfg.compact else None)
            model_blob = np.frombuffer(
                checkpoint.dumps_model(model, family.name), np.uint8)
            if k_c is None:
                stats_ss = run_pass("sweep", None, {"model": model_blob},
                                    False, it0 + it)
                model = set_stats_fn(model, *stats_ss)
            else:
                comp = compact_plan_fn(k_c)(model.active)
                stats_ss = run_pass(
                    "sweep", k_c,
                    {"model": model_blob,
                     "comp0": np.asarray(comp.slot_of_compact),
                     "comp1": np.asarray(comp.compact_of_slot)},
                    False, it0 + it)
                model = set_stats_comp_fn(model, comp, *stats_ss)
            if it0 + it >= cfg.burnout:
                plan = plan_fn(model)
                plan_arrays = proto.pack_tree(plan, "plan")
                k_c_sm = (_k_compact(k0, 2, k_max, cfg.k_block)
                          if cfg.compact else None)
                if k_c_sm is None:
                    stats_ss = run_pass("sm", None, plan_arrays, True,
                                        it0 + it)
                    model = apply_plan_fn(model, plan, *stats_ss)
                else:
                    comp = compact_plan_fn(k_c_sm)(plan.merge.new_active)
                    stats_ss = run_pass(
                        "sm", k_c_sm,
                        dict(plan_arrays,
                             comp0=np.asarray(comp.slot_of_compact),
                             comp1=np.asarray(comp.compact_of_slot)),
                        True, it0 + it)
                    model = apply_plan_comp_fn(model, plan, comp,
                                               *stats_ss)
            model, summary = advance_fn(model)
            if health_fn is not None:
                summary, healthy = jax.device_get(
                    (summary, health_fn(model)))
                healthy = bool(healthy)
            else:
                summary = jax.device_get(summary)
                healthy = True
            if not healthy:
                snap_model, snap_it = snap
                rec.rollback(it0 + it + 1, it0 + snap_it,
                             "non-finite/degenerate model state after "
                             "distributed iteration")
                model = _recovery_rekey(
                    jax.tree.map(jnp.copy, snap_model), rec.n_rollbacks)
                it = snap_it
                k0 = int(np.asarray(
                    jax.device_get(snap_model.active)).sum())
                continue
            k0 = int(np.max(np.asarray(summary["k"])))
            hist_rows.append(summary)
            times.append(time.perf_counter() - t0)
            it += 1
            if cfg.guardrails:
                snap = (jax.tree.map(jnp.copy, model), it)
            rec.maybe_checkpoint(model, it0 + it)
            if verbose:
                print(f"iter {it0 + it:4d}  K={summary['k']}  "
                      f"{times[-1] * 1e3:.1f} ms/iter  "
                      f"[{sum(1 for w in coord.workers if w.alive)}"
                      f"/{cfg.workers} workers]")
        rec.maybe_checkpoint(model, it0 + it, force=True)
    finally:
        coord.shutdown()
        if tmp_path is not None:
            try:
                os.unlink(tmp_path)
            except OSError:
                pass

    from repro.core.sampler import _HIST_KEYS
    history = {
        k: np.asarray([row[k] for row in hist_rows])
        for k in _HIST_KEYS} if hist_rows else {
        k: np.zeros((0,)) for k in _HIST_KEYS}
    device_bytes = {
        "mode": "distributed",
        "workers": cfg.workers,
        "est_peak_bytes": int(est_peak),
        **_peak_fields(rss0),
    }
    result = dpmm._result(model, labels_h.copy(), history, times,
                          device_bytes, 1, rec.events)
    result.dist = {
        "workers": cfg.workers,
        "shard_ranges": [[int(lo), int(hi)] for lo, hi, _ in ranges],
        "respawns": coord.respawns_done,
        "reassignments": coord.reassignments,
    }
    return result
