"""Wire protocol for the elastic multi-process sampler (repro.dist).

One frame = one message. The layout is deliberately boring:

    +--------+----------+------------+---------------------------+
    | b"DPMM" | crc32    | length     | npz payload (length bytes)|
    | 4 bytes | <I (LE)  | <Q (LE)    |                           |
    +--------+----------+------------+---------------------------+

The payload is a standard ``np.savez`` archive holding a ``__msg__``
uint8 leaf (UTF-8 JSON: ``{"kind": ..., "meta": {...}}``) plus any
number of ``a_<name>`` array leaves. Arrays travel as raw npy bytes —
lossless, which is what lets the coordinator ship ModelState / plans and
fold worker partials **bitwise**.

Failure handling is typed and total: a bad magic, a truncated header or
payload, an oversized length field, a CRC mismatch, or an unparseable
archive all raise :class:`ProtocolError` from ``recv_msg`` — never
garbage data, and never a hang (EOF surfaces immediately; callers that
need bounded waits set a socket timeout, which surfaces here as
``socket.timeout``/``OSError``). The CRC is checked before the payload
is parsed, so a bit-flipped frame is rejected without interpreting it.

``pack_tree`` / ``unpack_tree`` flatten a fixed-structure pytree (e.g. a
``SplitMergePlan``) to numbered array leaves and back; the receiver
supplies a structural template, so the wire carries no pickled code.
"""
from __future__ import annotations

import io
import json
import struct
import zlib
from typing import Any, Dict, Optional, Tuple

import numpy as np

MAGIC = b"DPMM"
_HEADER = struct.Struct("<4sIQ")          # magic, crc32, payload length
# Frames hold O(k_max * d) model state or O(blocks * k_c * d) partials —
# megabytes at most. The cap exists so a corrupted length field fails
# loudly instead of attempting a multi-GiB allocation.
MAX_FRAME_BYTES = 1 << 31


class ProtocolError(RuntimeError):
    """A frame failed validation (bad magic / truncation / EOF / CRC
    mismatch / unparseable payload). The connection is unusable after
    this — framing is lost — so callers treat it as peer loss."""


def _recv_exact(sock, n: int) -> bytes:
    """Read exactly ``n`` bytes or raise :class:`ProtocolError` on EOF /
    short stream (a killed peer closes mid-frame; that must never hang
    or return a partial buffer)."""
    chunks = []
    got = 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            raise ProtocolError(
                f"connection closed mid-frame: wanted {n} bytes, "
                f"got {got}")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def send_msg(sock, kind: str, meta: Optional[dict] = None,
             arrays: Optional[Dict[str, np.ndarray]] = None,
             lock=None) -> None:
    """Frame and send one message. ``lock`` (if given) serializes the
    ``sendall`` — the worker's heartbeat thread and main loop share one
    socket, and interleaved frames would corrupt the stream."""
    buf = io.BytesIO()
    msg = json.dumps({"kind": kind, "meta": meta or {}}).encode("utf-8")
    named = {f"a_{k}": np.asarray(v) for k, v in (arrays or {}).items()}
    np.savez(buf, __msg__=np.frombuffer(msg, np.uint8), **named)
    payload = buf.getvalue()
    if len(payload) > MAX_FRAME_BYTES:
        raise ProtocolError(
            f"refusing to send {len(payload)}-byte frame "
            f"(cap {MAX_FRAME_BYTES})")
    frame = _HEADER.pack(MAGIC, zlib.crc32(payload), len(payload)) + payload
    if lock is not None:
        with lock:
            sock.sendall(frame)
    else:
        sock.sendall(frame)


def recv_msg(sock) -> Tuple[str, dict, Dict[str, np.ndarray]]:
    """Receive one frame; returns ``(kind, meta, arrays)``. Raises
    :class:`ProtocolError` on any validation failure (see module doc)."""
    magic, crc, length = _HEADER.unpack(_recv_exact(sock, _HEADER.size))
    if magic != MAGIC:
        raise ProtocolError(
            f"bad frame magic {magic!r} (want {MAGIC!r}) — stream is "
            "desynchronized or the peer is not a repro.dist endpoint")
    if length > MAX_FRAME_BYTES:
        raise ProtocolError(
            f"frame length {length} exceeds cap {MAX_FRAME_BYTES} — "
            "corrupted header")
    payload = _recv_exact(sock, length)
    got_crc = zlib.crc32(payload)
    if got_crc != crc:
        raise ProtocolError(
            f"frame CRC mismatch: header says {crc:#010x}, payload "
            f"hashes to {got_crc:#010x} — bit flip or truncation in "
            "transit")
    try:
        with np.load(io.BytesIO(payload), allow_pickle=False) as z:
            msg = json.loads(bytes(np.asarray(z["__msg__"])).decode("utf-8"))
            arrays = {k[2:]: np.asarray(z[k]) for k in z.files
                      if k.startswith("a_")}
    except ProtocolError:
        raise
    except Exception as e:                      # zipfile/json/KeyError zoo
        raise ProtocolError(
            f"unparseable frame payload ({type(e).__name__}: {e})") from e
    kind = msg.get("kind")
    if not isinstance(kind, str):
        raise ProtocolError(f"frame __msg__ has no string 'kind': {msg!r}")
    return kind, msg.get("meta", {}), arrays


# ---------------------------------------------------------------------------
# Pytree <-> numbered array leaves (structure supplied by the receiver)
# ---------------------------------------------------------------------------
def pack_tree(tree: Any, prefix: str) -> Dict[str, np.ndarray]:
    """Flatten ``tree`` into ``{prefix}{i}`` host arrays in canonical
    (jax flatten) leaf order."""
    import jax
    return {f"{prefix}{i}": np.asarray(leaf)
            for i, leaf in enumerate(jax.tree_util.tree_leaves(tree))}


def unpack_tree(template: Any, arrays: Dict[str, np.ndarray],
                prefix: str) -> Any:
    """Rebuild a pytree shaped like ``template`` from ``pack_tree``
    leaves. Raises :class:`ProtocolError` if leaves are missing — a
    structurally wrong message must not reach a jitted function."""
    import jax
    treedef = jax.tree_util.tree_structure(template)
    try:
        leaves = [arrays[f"{prefix}{i}"]
                  for i in range(treedef.num_leaves)]
    except KeyError as e:
        raise ProtocolError(
            f"message is missing pytree leaf {e} for prefix "
            f"{prefix!r}") from e
    return jax.tree_util.tree_unflatten(treedef, leaves)
