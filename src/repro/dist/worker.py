"""Worker shard process for the elastic multi-process sampler.

A worker owns nothing but a row-range view of x behind the existing
``DataSource`` protocol (memmap via ``HostTiledSource.from_npy``) and a
socket to the coordinator. It is **stateless by design**: ModelState
lives on the coordinator, per-point labels are recomputed every sweep,
and each WORK message names an explicit row range — so a SIGKILL'd
worker's range can be re-streamed by any survivor (or a respawn) with a
bitwise-identical result.

Per WORK message the worker streams its range in STATS_BLOCK-aligned
read chunks (through ``read_block_checked``, so transient I/O faults
retry locally and the recovery events ride back to the coordinator's
``FitResult.recoveries``) and runs the phase's tile body **one
suff-stat block at a time**, shipping the per-block substat partials
unfolded. That per-block granularity is the bitwise contract: the
coordinator replays ``acc += p_block`` in fixed global block order, so
the fold's float-addition order is identical to the single-process
tiled driver no matter how many workers exist or which worker computed
which block (core/gibbs.py STATS_BLOCK fold).

The tile bodies here are the *same closure constructions* as
``DPMM._fit_tiled`` pinned to a 1-device mesh (the distributed driver's
mesh — see repro.dist.coordinator), at tile length == STATS_BLOCK. Tile
size is already proven bitwise-neutral repo-wide (tests/test_tiled_parity),
and at the comparison tile size the per-block programs are structurally
identical, so worker compute is bit-for-bit the single-process compute.

A daemon thread heartbeats every ``worker_heartbeat_s`` so the
coordinator can tell a *hung* worker (beats flowing, work deadline
missed) from a *dead* one (EOF). The worker exits when the coordinator
closes the socket or sends ``shutdown``.

Run as: ``python -m repro.dist.worker --connect 127.0.0.1:PORT --id w0``
"""
from __future__ import annotations

import argparse
import socket
import sys
import threading
import traceback
from typing import Dict, List, Optional

import numpy as np

from repro.dist import proto


def plan_template(k_max: int, d: int):
    """Structural ``SplitMergePlan`` dummy: correct leaf dtypes/shapes for
    wire unpacking (proto.unpack_tree) and for tracing the split/merge
    tile body during warmup. Values are never meaningful."""
    import jax.numpy as jnp
    from repro.core.splitmerge import (MergeDecision, SplitDecision,
                                       SplitMergePlan)
    b = jnp.zeros((k_max,), jnp.bool_)
    i = jnp.zeros((k_max,), jnp.int32)
    f = jnp.zeros((k_max, d), jnp.float32)
    return SplitMergePlan(
        split=SplitDecision(accept=b, dest=i, new_active=b),
        merge=MergeDecision(merged=b, into=i, side=i, new_active=b),
        means_split=f, means_merge=f, vecs_split=f, vecs_reset=f,
        reset=b, stuck=i)


class WorkerRuntime:
    """Shard-local compute: the tiled driver's per-tile jitted bodies on
    a 1-device mesh, invoked one STATS_BLOCK at a time."""

    def __init__(self, meta: dict, arrays: Dict[str, np.ndarray]):
        # jax imports live here (not module top) so `--help` and the
        # protocol layer stay import-light
        import functools
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.configs import DPMMConfig
        from repro.core import gibbs, splitmerge
        from repro.core.distributed import (data_axes_of, make_data_mesh,
                                            shard_map, tile_plan)
        from repro.core.family import get_family, state_partition_specs
        from repro.core.resilience import RetryPolicy, read_block_checked
        from repro.core.sampler import _init_labels
        from repro.core.state import PointState
        from repro.data.faults import FaultInjectingSource
        from repro.data.source import HostTiledSource

        self._gibbs = gibbs
        self._read_block_checked = read_block_checked
        self.STATS_BLOCK = gibbs.STATS_BLOCK

        cfg = DPMMConfig(**meta["cfg"])
        self.cfg = cfg
        family = get_family(cfg.component)
        self.family = family
        src = HostTiledSource.from_npy(meta["data_path"])
        faults = meta.get("faults")
        if faults:
            fa = dict(faults)
            if fa.get("schedule"):
                # JSON round-trip stringifies the call-index keys
                fa["schedule"] = {int(k): v
                                  for k, v in fa["schedule"].items()}
            src = FaultInjectingSource(src, **fa)
        self.source = src
        self.n, self.d = src.n, src.d
        k_max = cfg.k_max
        self.k_max = k_max
        n = self.n
        d = self.d

        mesh = make_data_mesh(1)
        axes = data_axes_of(mesh)
        prior = family.build_prior(cfg, src.column_mean()[None, :])
        n_local, tiles = tile_plan(n, 1, cfg.tile_size)
        self.n_local = n_local
        # read-chunk size: the tile plan's (STATS_BLOCK-aligned) tile
        self.chunk = max(self.STATS_BLOCK,
                         -(-tiles[0][1] // self.STATS_BLOCK)
                         * self.STATS_BLOCK)
        use_pallas = cfg.use_pallas
        feat_axis = None                    # shard_features gated off

        # ---- jitted tile bodies: the _fit_tiled constructions at
        # shards=1, n_chains=1 (cmap identity) --------------------------
        model_specs, _ = state_partition_specs(family, P(axes))
        x_spec = P(axes, feat_axis)
        rep = P()
        acc_shape = jax.eval_shape(
            lambda: gibbs.empty_substats(family, k_max, d))
        acc_specs = type(acc_shape)(**{
            f: P(*([axes] + [None] * getattr(acc_shape, f).ndim))
            for f in acc_shape._fields})
        acc_shardings = type(acc_shape)(**{
            f: NamedSharding(mesh, getattr(acc_specs, f))
            for f in acc_shape._fields})

        @functools.lru_cache(maxsize=None)
        def zeros_acc_k(k: int):
            shape_k = jax.eval_shape(
                lambda: gibbs.empty_substats(family, k, d))
            return jax.jit(
                lambda: type(shape_k)(**{
                    f: jnp.zeros((1,) + getattr(shape_k, f).shape,
                                 jnp.float32)
                    for f in shape_k._fields}),
                out_shardings=acc_shardings)

        self._zeros_acc_k = zeros_acc_k
        local = lambda acc: jax.tree.map(lambda v: v[0], acc)
        delocal = lambda acc: jax.tree.map(lambda v: v[None], acc)

        def tile_point(pt, off, length, x_t):
            lab, sub = pt
            gidx = gibbs.global_indices(n_local, axes, offset=off,
                                        length=length)
            valid = (gidx < jnp.uint32(n)).astype(x_t.dtype)
            return PointState(labels=lab, sublabels=sub, valid=valid), gidx

        def _sweep_tile(model, x_t, lab, sub, off, acc, comp=None):
            point, gidx = tile_point((lab, sub), off, x_t.shape[0], x_t)
            point, a = gibbs.sweep_tile(model, x_t, point, gidx,
                                        local(acc), family,
                                        use_pallas=use_pallas,
                                        feat_axis=feat_axis, plan=comp,
                                        k_block=cfg.k_block)
            return (point.labels, point.sublabels), delocal(a)

        def _sm_tile(plan, x_t, lab, sub, off, acc, comp=None):
            point, _ = tile_point((lab, sub), off, x_t.shape[0], x_t)
            point, a = splitmerge.split_merge_tile(
                plan, x_t, point, local(acc), family,
                use_pallas=use_pallas, feat_axis=feat_axis,
                compaction=comp)
            return (point.labels, point.sublabels), delocal(a)

        def _init1_tile(x_t, off, acc):
            gidx = gibbs.global_indices(n_local, axes, offset=off,
                                        length=x_t.shape[0])
            labels = _init_labels(gidx, cfg.init_clusters)
            valid = (gidx < jnp.uint32(n)).astype(x_t.dtype)
            a = gibbs.accumulate_substats(
                family, x_t, valid, labels, jnp.zeros_like(labels), k_max,
                local(acc), use_pallas)
            return (labels, jnp.zeros_like(labels)), delocal(a)

        def _init2_tile(means0, v0, x_t, lab, sub, off, acc):
            point, gidx = tile_point((lab, sub), off, x_t.shape[0], x_t)
            sublabels = splitmerge.hyperplane_bits(x_t, point.labels,
                                                   means0, v0, feat_axis)
            a = gibbs.accumulate_substats(
                family, x_t, point.valid, point.labels, sublabels, k_max,
                local(acc), use_pallas)
            return (point.labels, sublabels), delocal(a)

        def _sweep_tile_c(model, x_t, lab, sub, off, acc):
            return _sweep_tile(model, x_t, lab, sub, off, acc)

        def _sm_tile_c(plan, x_t, lab, sub, off, acc):
            return _sm_tile(plan, x_t, lab, sub, off, acc)

        def _sweep_tile_comp(model, x_t, lab, sub, off, comp, acc):
            return _sweep_tile(model, x_t, lab, sub, off, acc, comp)

        def _sm_tile_comp(plan, x_t, lab, sub, off, comp, acc):
            return _sm_tile(plan, x_t, lab, sub, off, acc, comp)

        lab_spec = P(axes)
        lab_specs = (lab_spec, lab_spec)
        smap = functools.partial(shard_map, mesh=mesh)
        self.sweep_tile_fn = jax.jit(smap(
            _sweep_tile_c, in_specs=(model_specs, x_spec, *lab_specs, rep,
                                     acc_specs),
            out_specs=(lab_specs, acc_specs)))
        comp_specs = gibbs.CompactionPlan(rep, rep)
        self.sweep_tile_comp_fn = jax.jit(smap(
            _sweep_tile_comp,
            in_specs=(model_specs, x_spec, *lab_specs, rep, comp_specs,
                      acc_specs),
            out_specs=(lab_specs, acc_specs)))
        self.plan_tpl = plan_template(k_max, d)
        plan_specs = jax.tree.map(lambda _: rep, self.plan_tpl)
        self.sm_tile_fn = jax.jit(smap(
            _sm_tile_c,
            in_specs=(plan_specs, x_spec, *lab_specs, rep, acc_specs),
            out_specs=(lab_specs, acc_specs)))
        self.sm_tile_comp_fn = jax.jit(smap(
            _sm_tile_comp,
            in_specs=(plan_specs, x_spec, *lab_specs, rep, comp_specs,
                      acc_specs),
            out_specs=(lab_specs, acc_specs)))
        self.init1_fn = jax.jit(smap(
            _init1_tile, in_specs=(x_spec, rep, acc_specs),
            out_specs=(lab_specs, acc_specs)))
        self.init2_fn = jax.jit(smap(
            _init2_tile, in_specs=(rep, rep, x_spec, *lab_specs, rep,
                                   acc_specs),
            out_specs=(lab_specs, acc_specs)))

        self.x_sharding = NamedSharding(mesh, x_spec)
        self.i32_sharding = NamedSharding(mesh, lab_spec)
        self._device_put = jax.device_put
        self._tree_leaves = jax.tree_util.tree_leaves
        self.retry = RetryPolicy(max_retries=cfg.io_retries,
                                 backoff_s=cfg.io_backoff_s,
                                 guard_nonfinite=cfg.guard_tiles)
        # phase context (set by PHASE messages)
        self._phase: Optional[str] = None
        self._model = None
        self._plan = None
        self._comp = None
        self._k_eff = k_max
        self._means0 = None
        self._v0 = None
        self._warm_meta = meta.get("warm") or {}

    # -- phase / work handling ---------------------------------------------
    def set_phase(self, meta: dict, arrays: Dict[str, np.ndarray]) -> None:
        from repro.core import checkpoint, gibbs
        phase = meta["phase"]
        self._phase = phase
        k_c = meta.get("k_c")
        self._k_eff = int(k_c) if k_c is not None else self.k_max
        if "comp0" in arrays:
            self._comp = gibbs.CompactionPlan(arrays["comp0"],
                                              arrays["comp1"])
        else:
            self._comp = None
        if phase == "sweep":
            self._model, _ = checkpoint.loads_model(
                arrays["model"].tobytes())
        elif phase == "sm":
            self._plan = proto.unpack_tree(self.plan_tpl, arrays, "plan")
        elif phase == "init2":
            self._means0 = arrays["means0"]
            self._v0 = arrays["v0"]
        elif phase != "init1":
            raise proto.ProtocolError(f"unknown phase {phase!r}")

    def _block(self, x_rows: np.ndarray, off: int,
               lab: np.ndarray, sub: np.ndarray):
        """One suff-stat block through the current phase's tile body;
        returns host (labels, sublabels, partial leaves) with the shard
        axis stripped."""
        x_t = self._device_put(x_rows, self.x_sharding)
        lab_t = self._device_put(lab, self.i32_sharding)
        sub_t = self._device_put(sub, self.i32_sharding)
        off_u = np.uint32(off)
        zeros = self._zeros_acc_k(self._k_eff)()
        if self._phase == "init1":
            (lab_o, sub_o), acc = self.init1_fn(x_t, off_u, zeros)
        elif self._phase == "init2":
            (lab_o, sub_o), acc = self.init2_fn(
                self._means0, self._v0, x_t, lab_t, sub_t, off_u, zeros)
        elif self._phase == "sweep":
            if self._comp is None:
                (lab_o, sub_o), acc = self.sweep_tile_fn(
                    self._model, x_t, lab_t, sub_t, off_u, zeros)
            else:
                (lab_o, sub_o), acc = self.sweep_tile_comp_fn(
                    self._model, x_t, lab_t, sub_t, off_u, self._comp,
                    zeros)
        elif self._phase == "sm":
            if self._comp is None:
                (lab_o, sub_o), acc = self.sm_tile_fn(
                    self._plan, x_t, lab_t, sub_t, off_u, zeros)
            else:
                (lab_o, sub_o), acc = self.sm_tile_comp_fn(
                    self._plan, x_t, lab_t, sub_t, off_u, self._comp,
                    zeros)
        else:
            raise proto.ProtocolError(
                f"WORK before PHASE (phase={self._phase!r})")
        return (np.asarray(lab_o), np.asarray(sub_o),
                [np.asarray(l)[0] for l in self._tree_leaves(acc)])

    def process(self, meta: dict, arrays: Dict[str, np.ndarray]):
        """Run the current phase over rows [lo, hi); returns the RESULT
        (meta, arrays): updated labels, stacked per-block partials, and
        any local I/O recovery events."""
        lo, hi = int(meta["lo"]), int(meta["hi"])
        SB = self.STATS_BLOCK
        labels = arrays.get("labels")
        sublabels = arrays.get("sublabels")
        if labels is None:
            # sweeps reassign labels from the model — inputs are unused
            # (the same contract that lets resume start from zeros)
            labels = np.zeros(hi - lo, np.int32)
            sublabels = np.zeros(hi - lo, np.int32)
        io_events: List[dict] = []
        lab_out = np.empty(hi - lo, np.int32)
        sub_out = np.empty(hi - lo, np.int32)
        parts: List[List[np.ndarray]] = []
        for c0 in range(lo, hi, self.chunk):
            c1 = min(c0 + self.chunk, hi)
            rows = self._read_block_checked(self.source, c0, c1,
                                            self.retry,
                                            on_event=io_events.append)
            for b0 in range(c0, c1, SB):
                b1 = min(b0 + SB, c1)
                lab_o, sub_o, p = self._block(
                    rows[b0 - c0:b1 - c0], b0,
                    labels[b0 - lo:b1 - lo], sublabels[b0 - lo:b1 - lo])
                lab_out[b0 - lo:b1 - lo] = lab_o
                sub_out[b0 - lo:b1 - lo] = sub_o
                parts.append(p)
        out_arrays = {"labels": lab_out, "sublabels": sub_out}
        for i in range(len(parts[0])):
            out_arrays[f"p{i}"] = np.stack([p[i] for p in parts])
        return ({"lo": lo, "hi": hi, "phase": self._phase,
                 "io_events": io_events}, out_arrays)

    # -- warmup -------------------------------------------------------------
    def warmup(self) -> None:
        """Pre-compile every (phase, tile length, k_eff) variant this fit
        can hit, so WORK deadlines bound *compute*, not XLA compilation —
        a hung read is then distinguishable from a cold jit cache."""
        import jax
        import jax.numpy as jnp
        from repro.core import gibbs
        from repro.core.sampler import _init_model

        wm = self._warm_meta
        SB = self.STATS_BLOCK
        lengths = sorted({min(SB, self.n)}
                         | ({self.n % SB} if self.n % SB else set()))
        substats = gibbs.empty_substats(self.family, self.k_max, self.d)
        stats = jax.tree.map(lambda a: jnp.sum(a, axis=1), substats)
        cfg = self.cfg
        prior = self.family.build_prior(
            cfg, self.source.column_mean()[None, :])
        model = _init_model(jax.random.key(0), stats, substats,
                            prior=prior, family=self.family, cfg=cfg,
                            k_max=self.k_max)
        plan = self.plan_tpl
        comps = {None: None}
        for k_c in set((wm.get("sweep_k") or [])
                       + (wm.get("sm_k") or [])):
            comps[int(k_c)] = gibbs.compaction_plan(model.active,
                                                    int(k_c))
        off_u = np.uint32(0)
        for length in lengths:
            x1 = np.ones((length, self.d), np.float32)
            lab = np.zeros((length,), np.int32)
            if wm.get("init", True):
                self.init1_fn(x1, off_u, self._zeros_acc_k(self.k_max)())
                self.init2_fn(np.zeros((self.k_max, self.d), np.float32),
                              np.ones((self.k_max, self.d), np.float32),
                              x1, lab, lab, off_u,
                              self._zeros_acc_k(self.k_max)())
            for k_c in [None] + [int(k) for k in (wm.get("sweep_k") or [])]:
                if k_c is None:
                    self.sweep_tile_fn(model, x1, lab, lab, off_u,
                                       self._zeros_acc_k(self.k_max)())
                else:
                    self.sweep_tile_comp_fn(model, x1, lab, lab, off_u,
                                            comps[k_c],
                                            self._zeros_acc_k(k_c)())
            if wm.get("sm", True):
                for k_c in [None] + [int(k)
                                     for k in (wm.get("sm_k") or [])]:
                    if k_c is None:
                        self.sm_tile_fn(plan, x1, lab, lab, off_u,
                                        self._zeros_acc_k(self.k_max)())
                    else:
                        self.sm_tile_comp_fn(plan, x1, lab, lab, off_u,
                                             comps[k_c],
                                             self._zeros_acc_k(k_c)())


# ---------------------------------------------------------------------------
# Process entry: HELLO -> INIT -> warmup -> READY -> {PHASE | WORK}* loop
# ---------------------------------------------------------------------------
def _heartbeat_loop(sock, lock, interval: float,
                    stop: threading.Event) -> None:
    while not stop.wait(interval):
        try:
            proto.send_msg(sock, "heartbeat", lock=lock)
        except OSError:
            return                      # coordinator gone; main loop exits


def run_worker(sock, worker_id: str) -> int:
    lock = threading.Lock()
    stop = threading.Event()
    hb = None
    try:
        proto.send_msg(sock, "hello", {"id": worker_id}, lock=lock)
        kind, meta, arrays = proto.recv_msg(sock)
        if kind != "init":
            raise proto.ProtocolError(f"expected init, got {kind!r}")
        hb = threading.Thread(
            target=_heartbeat_loop,
            args=(sock, lock, float(meta.get("heartbeat_s", 0.5)), stop),
            daemon=True)
        hb.start()
        rt = WorkerRuntime(meta, arrays)
        rt.warmup()
        proto.send_msg(sock, "ready", {"id": worker_id}, lock=lock)
        while True:
            kind, meta, arrays = proto.recv_msg(sock)
            if kind == "phase":
                rt.set_phase(meta, arrays)
            elif kind == "work":
                out_meta, out_arrays = rt.process(meta, arrays)
                out_meta["worker"] = worker_id
                proto.send_msg(sock, "result", out_meta, out_arrays,
                               lock=lock)
            elif kind == "shutdown":
                return 0
            # unknown kinds are ignored (forward compatibility)
    except (proto.ProtocolError, OSError):
        # coordinator died or the stream broke — nothing to clean up
        # (shards are stateless); exit nonzero so ps tells the story
        return 1
    except Exception:
        # compute-side failure (e.g. TileReadError past the retry
        # budget): tell the coordinator why before dying, so the
        # failover event — and a possible WorkerLostError — carry it
        try:
            proto.send_msg(sock, "error",
                           {"id": worker_id,
                            "detail": traceback.format_exc(limit=5)},
                           lock=lock)
        except OSError:
            pass
        return 2
    finally:
        stop.set()
        try:
            sock.close()
        except OSError:
            pass


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="repro.dist worker shard (spawned by the coordinator)")
    ap.add_argument("--connect", required=True,
                    help="coordinator host:port")
    ap.add_argument("--id", default="w?", help="worker slot id")
    args = ap.parse_args(argv)
    host, port = args.connect.rsplit(":", 1)
    sock = socket.create_connection((host, int(port)), timeout=60)
    sock.settimeout(None)
    return run_worker(sock, args.id)


if __name__ == "__main__":
    sys.exit(main())
