from repro.train.trainer import (TrainState, init_train_state,  # noqa: F401
                                 make_train_step, train_state_specs,
                                 train_step)
from repro.train import checkpoint, loss, optimizer  # noqa: F401
