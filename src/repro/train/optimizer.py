"""AdamW + cosine-with-warmup, as a pure pytree transformation.

No optax dependency (offline container): the update rule is standard
decoupled AdamW with global-norm gradient clipping. Optimizer state shards
exactly like the parameters (same PartitionSpec tree), so FSDP-sharded
params get FSDP-sharded moments for free.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class OptState(NamedTuple):
    step: jax.Array      # ()
    mu: Any              # first moment, like params
    nu: Any              # second moment, like params


def init_opt_state(params: Any) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def opt_state_specs(param_specs: Any) -> OptState:
    from jax.sharding import PartitionSpec as P
    return OptState(step=P(), mu=param_specs, nu=param_specs)


def lr_schedule(step: jax.Array, cfg: TrainConfig) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (0.1 + 0.9 * cos)   # decay to 10% of peak


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads: Any, opt: OptState, params: Any, cfg: TrainConfig
                 ) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(step, cfg)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                       # decoupled WD on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
            m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt.mu)
    flat_v = treedef.flatten_up_to(opt.nu)
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step=step, mu=new_m, nu=new_v), metrics
