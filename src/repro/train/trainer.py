"""Distributed train step: hidden_forward -> chunked CE -> AdamW.

``make_train_step`` returns a jit-able ``(state, batch) -> (state, metrics)``
with explicit in/out shardings so the same function serves the CPU smoke
tests (trivial mesh) and the 512-chip dry-run.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import transformer
from repro.models.common import BATCH_AXES, ShardingPolicy
from repro.train import optimizer as opt_mod
from repro.train.loss import chunked_ce_loss


class TrainState(NamedTuple):
    params: Any
    opt: opt_mod.OptState


def init_train_state(key: jax.Array, cfg: ModelConfig,
                     dtype=jnp.float32) -> TrainState:
    params = transformer.init_params(key, cfg, dtype)
    return TrainState(params=params, opt=opt_mod.init_opt_state(params))


def train_state_specs(cfg: ModelConfig, moe_strategy: str = "tensor"
                      ) -> TrainState:
    pspecs = transformer.param_specs(cfg, moe_strategy)
    return TrainState(params=pspecs, opt=opt_mod.opt_state_specs(pspecs))


def loss_fn(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            tcfg: TrainConfig, policy: ShardingPolicy,
            n_groups: int = 1, moe_strategy: str = "tensor"):
    memory = batch.get("memory")
    if cfg.encoder_layers:
        memory = transformer.encode(params, batch["frames"], cfg, policy,
                                    remat=tcfg.remat)
    hidden, aux = transformer.hidden_forward(
        params, batch["tokens"], cfg, policy, memory=memory,
        remat=tcfg.remat, n_groups=n_groups, moe_strategy=moe_strategy,
        remat_policy=tcfg.remat_policy)
    loss, metrics = chunked_ce_loss(hidden, batch["targets"],
                                    params["embed"], cfg, tcfg.loss_chunk)
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_loss * aux
        metrics["moe_aux"] = aux
    return loss, metrics


def train_step(state: TrainState, batch: Dict[str, jax.Array], *,
               cfg: ModelConfig, tcfg: TrainConfig, policy: ShardingPolicy,
               n_groups: int = 1, moe_strategy: str = "tensor",
               grad_specs: Optional[Any] = None
               ) -> Tuple[TrainState, Dict[str, jax.Array]]:
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    (_, metrics), grads = grad_fn(state.params, batch, cfg, tcfg, policy,
                                  n_groups, moe_strategy)
    if grad_specs is not None:
        # constrain grads to the param sharding (a NamedSharding tree) so
        # the data-parallel reduction lowers as reduce-scatter, not a full
        # all-reduce (FSDP semantics)
        grads = jax.tree.map(jax.lax.with_sharding_constraint,
                             grads, grad_specs)
    new_params, new_opt, opt_metrics = opt_mod.adamw_update(
        grads, state.opt, state.params, tcfg)
    metrics.update(opt_metrics)
    return TrainState(params=new_params, opt=new_opt), metrics


def batch_sharding(mesh: Mesh, cfg: ModelConfig,
                   policy: ShardingPolicy) -> Dict[str, P]:
    b = tuple(a for a in BATCH_AXES if a in mesh.axis_names) \
        if policy.batch_sharded else None
    spec = {"tokens": P(b, None), "targets": P(b, None)}
    if cfg.encoder_layers:
        spec["frames"] = P(b, None, None)
    if cfg.vision_tokens:
        spec["memory"] = P(b, None, None)
    return spec


def make_train_step(mesh: Mesh, cfg: ModelConfig, tcfg: TrainConfig,
                    policy: ShardingPolicy, n_groups: int = 1,
                    moe_strategy: str = "tensor", donate: bool = True):
    """jit'd train step with explicit in/out shardings for ``mesh``."""
    sspecs = train_state_specs(cfg, moe_strategy)
    bspecs = batch_sharding(mesh, cfg, policy)
    to_shard = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda s: isinstance(s, P))
    fn = functools.partial(train_step, cfg=cfg, tcfg=tcfg, policy=policy,
                           n_groups=n_groups, moe_strategy=moe_strategy)
    return jax.jit(
        fn,
        in_shardings=(to_shard(sspecs), to_shard(bspecs)),
        out_shardings=(to_shard(sspecs),
                       NamedSharding(mesh, P())),
        donate_argnums=(0,) if donate else ())
