"""Sequence-chunked cross-entropy.

The (B, S, V) logits tensor is never materialized: the final hidden states
are split into ``loss_chunk``-sized sequence chunks and each chunk's logits
+ log-softmax + gather live only inside one ``lax.scan`` step (with the
256k-vocab configs this is the difference between ~33 GB and ~30 MB of live
logits per device).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common


def chunked_ce_loss(hidden: jax.Array, targets: jax.Array, embed_params,
                    cfg: ModelConfig, chunk: int = 1024
                    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """hidden: (B, S, d) final hidden states; targets: (B, S) int32.

    Returns (mean loss, metrics). Positions with target < 0 are masked.
    """
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    n = s // chunk
    hs = hidden.reshape(b, n, chunk, d).swapaxes(0, 1)     # (n, B, c, d)
    ts = targets.reshape(b, n, chunk).swapaxes(0, 1)       # (n, B, c)

    def step(carry, inp):
        tot, cnt, correct = carry
        h, t = inp
        logits = common.unembed(h, embed_params, cfg.final_softcap)
        logz = jax.nn.logsumexp(logits, axis=-1)
        # masked-sum instead of take_along_axis: a gather over the vocab-
        # sharded dim forces an all-gather of the logits chunk; the masked
        # reduction stays sharded and psums a (B, chunk) scalar field
        # (EXPERIMENTS §Perf, A3)
        v_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
        tsel = jnp.maximum(t, 0)[..., None]
        tgt = jnp.sum(jnp.where(v_iota == tsel, logits, 0.0), axis=-1)
        mask = (t >= 0).astype(jnp.float32)
        nll = (logz - tgt) * mask
        hit = (jnp.argmax(logits, axis=-1) == t).astype(jnp.float32) * mask
        return (tot + jnp.sum(nll), cnt + jnp.sum(mask),
                correct + jnp.sum(hit)), None

    (tot, cnt, correct), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32),) * 3, (hs, ts))
    loss = tot / jnp.maximum(cnt, 1.0)
    return loss, {"loss": loss, "accuracy": correct / jnp.maximum(cnt, 1.0),
                  "tokens": cnt}
