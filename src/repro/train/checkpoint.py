"""Flat-npz pytree checkpointing (offline container: no orbax).

Pytrees are flattened to ``path/sep/joined/key -> array`` entries in a
single compressed ``.npz``; restore rebuilds into the *structure* of a
reference pytree (so restored arrays land on whatever sharding the caller's
reference tree prescribes via ``device_put``).
"""
from __future__ import annotations

import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

SEP = "//"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez_compressed(path, **_flatten(tree))


def restore(path: str, like: Any) -> Any:
    """Restore into the structure (and dtypes) of ``like``."""
    with np.load(path if path.endswith(".npz") else path + ".npz") as data:
        flat = {k: data[k] for k in data.files}
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_k, ref in paths:
        key = SEP.join(_path_str(p) for p in path_k)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = jnp.asarray(flat[key], dtype=ref.dtype)
        if arr.shape != ref.shape:
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != {ref.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)
