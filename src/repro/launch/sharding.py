"""Mesh-aware sharding-spec fix-up, shared by the dry-run spec builders and
the serving engine.

``fix_specs`` makes *intended* PartitionSpec trees legal for a concrete
mesh: axes absent from the mesh are dropped (e.g. ``pod`` on a single pod),
entries whose dim is not divisible by their axes are replicated (e.g. 8 KV
heads on a 16-way ``model`` axis), and — optionally — parameters gain a
``data``-axis FSDP sharding on their largest free divisible dim.
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axes_of(entry) -> Tuple[str, ...]:
    if entry is None:
        return ()
    return entry if isinstance(entry, tuple) else (entry,)


def fix_specs(specs, structs, mesh: Mesh, *, fsdp: bool = False,
              fsdp_axes: Tuple[str, ...] = ("data",)):
    """Drop illegal entries; optionally add FSDP (DESIGN §4).

    Embedding tables are excluded from FSDP: they are already model-sharded
    and small per device, and FSDP on the vocab dim turns the token gather
    into a full (B, S, d) all-gather (measured -1.6 GiB/step on granite
    train_4k; EXPERIMENTS §Perf A4)."""
    fs = tuple(a for a in fsdp_axes if a in mesh.axis_names)
    fsize = 1
    for a in fs:
        fsize *= mesh.shape[a]

    def keyed_fix(path, spec, struct):
        if any(getattr(p, "key", None) == "embed" for p in path):
            return fix(spec, struct, no_fsdp=True)
        return fix(spec, struct)

    def fix(spec, struct, no_fsdp: bool = False):
        if not isinstance(spec, P):
            return spec
        shape = struct.shape
        entries = list(spec) + [None] * (len(shape) - len(spec))
        for i, e in enumerate(entries):
            axes = tuple(a for a in _axes_of(e) if a in mesh.axis_names)
            entries[i] = (axes if len(axes) > 1 else
                          (axes[0] if axes else None))
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if size > 1 and shape[i] % size:
                entries[i] = None
        if fsdp and not no_fsdp and fs and fsize > 1:
            used = {a for e in entries for a in _axes_of(e)}
            if not used & set(fs):
                cands = [i for i, e in enumerate(entries)
                         if e is None and shape[i] % fsize == 0
                         and shape[i] >= 2 * fsize]
                if cands:
                    i = max(cands, key=lambda j: shape[j])
                    entries[i] = fs if len(fs) > 1 else fs[0]
        return P(*entries)

    return jax.tree_util.tree_map_with_path(
        keyed_fix, specs, structs, is_leaf=lambda s: isinstance(s, P))


def to_shard(mesh: Mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda s: isinstance(s, P))
