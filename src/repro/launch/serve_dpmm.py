"""DPMM serving driver — query a fitted model from the command line.

    # 1. fit + checkpoint (sample_dpmm writes the npz):
    PYTHONPATH=src python -m repro.launch.sample_dpmm \
        --n 100000 --d 8 --k 10 --iters 100 --n-chains 4 \
        --checkpoint-path model.npz
    # 2. serve queries against it:
    PYTHONPATH=src python -m repro.launch.serve_dpmm \
        --checkpoint model.npz --queries q.npy --result-path out.json

``--checkpoint`` accepts a single npz OR an auto-checkpoint rotation
prefix (the newest verifying member serves). ``--batch-sizes`` is the
AOT ladder — every size precompiles at startup and each request routes
to the smallest covering step (serve/dpmm.py).

The JSON written to ``--result-path`` is exactly
``ServeResult.to_json()`` — the CLI and the Python API emit the same
schema, field for field. With ``--bench`` it instead reports
steady-state throughput plus per-request latency percentiles through
the ladder. Without ``--queries`` a synthetic batch matching the
checkpoint's feature dim is drawn — a smoke mode for CI and demos.
"""
from __future__ import annotations

import argparse
import json
import time
import warnings

import numpy as np


def _parse_sizes(text: str):
    try:
        return tuple(int(tok) for tok in text.split(",") if tok.strip())
    except ValueError:
        raise SystemExit(f"--batch-sizes expects comma-separated ints, "
                         f"got {text!r}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--checkpoint", required=True,
                    help="ModelState npz (or rotation prefix) written by "
                         "core/checkpoint.py")
    ap.add_argument("--queries", default="",
                    help=".npy (N, d) query rows; default: synthetic")
    ap.add_argument("--n", type=int, default=10_000,
                    help="synthetic query count when --queries is unset")
    ap.add_argument("--batch-sizes", "--batch_sizes", default="",
                    help="comma-separated ascending AOT ladder, e.g. "
                         "256,2048,8192 (ServeConfig default when unset)")
    ap.add_argument("--batch-size", "--batch_size", type=int, default=None,
                    help="DEPRECATED: single AOT size; use --batch-sizes")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--sample", action="store_true",
                    help="also draw a sampled (Gumbel) assignment per row")
    ap.add_argument("--include-logprobs", action="store_true",
                    help="include the (N, K_max) soft assignment in the "
                         "result JSON")
    ap.add_argument("--result-path", "--result_path", default="")
    ap.add_argument("--bench", action="store_true",
                    help="measure throughput/latency instead of dumping "
                         "answers")
    ap.add_argument("--bench-reps", type=int, default=20)
    args = ap.parse_args(argv)

    from repro.serve.dpmm import DPMMEngine, ServeConfig

    fields = {"use_pallas": args.use_pallas, "seed": args.seed}
    if args.batch_size is not None:
        if args.batch_sizes:
            raise SystemExit("pass --batch-sizes OR --batch-size, not both")
        warnings.warn("--batch-size is deprecated; use --batch-sizes",
                      DeprecationWarning)
        fields["batch_sizes"] = (args.batch_size,)
    elif args.batch_sizes:
        fields["batch_sizes"] = _parse_sizes(args.batch_sizes)
    cfg = ServeConfig(**fields)

    t0 = time.time()
    engine = DPMMEngine.from_checkpoint(args.checkpoint, cfg)
    print(f"engine up in {time.time() - t0:.2f}s: "
          f"family={engine.family.name} d={engine.d} k_max={engine.k_max} "
          f"ladder={engine.batch_sizes} (all steps precompiled)")

    if args.queries:
        xq = np.asarray(np.load(args.queries), np.float32)
    else:
        rng = np.random.default_rng(args.seed)
        xq = rng.standard_normal((args.n, engine.d)).astype(np.float32)
        print(f"no --queries: serving {args.n} synthetic rows")

    if args.bench:
        engine.query(xq[: engine.batch_sizes[0]])    # warm (already AOT)
        lat = []
        t0 = time.perf_counter()
        for _ in range(args.bench_reps):
            t1 = time.perf_counter()
            engine.query(xq)
            lat.append(time.perf_counter() - t1)
        dt = (time.perf_counter() - t0) / args.bench_reps
        qps = xq.shape[0] / dt
        p50, p95, p99 = (float(np.percentile(lat, p) * 1e3)
                         for p in (50, 95, 99))
        print(f"throughput: {qps:,.0f} queries/s "
              f"({dt * 1e3:.2f} ms per {xq.shape[0]}-row request; "
              f"p50={p50:.2f} p95={p95:.2f} p99={p99:.2f} ms)")
        return

    t0 = time.perf_counter()
    res = engine.query(xq, sample=args.sample, seed=args.seed)
    dt = time.perf_counter() - t0
    print(f"served {xq.shape[0]} queries in {dt * 1e3:.1f} ms "
          f"({xq.shape[0] / dt:,.0f} q/s): "
          f"{len(res.cluster_counts())} clusters hit, "
          f"mean log p(x) = {res.log_predictive.mean():.3f}")
    if args.result_path:
        with open(args.result_path, "w") as f:
            json.dump(res.to_json(include_logprobs=args.include_logprobs),
                      f)
        print(f"wrote {args.result_path}")


if __name__ == "__main__":
    main()
