"""DPMM serving driver — query a fitted model from the command line.

    # 1. fit + checkpoint (sample_dpmm writes the npz):
    PYTHONPATH=src python -m repro.launch.sample_dpmm \
        --n 100000 --d 8 --k 10 --iters 100 --n-chains 4 \
        --checkpoint-path model.npz
    # 2. serve queries against it:
    PYTHONPATH=src python -m repro.launch.serve_dpmm \
        --checkpoint model.npz --queries q.npy --result-path out.json

Answers per query row: hard cluster label, per-cluster log-probabilities
(soft assignment), and the log predictive density (outlier score). With
``--bench`` it instead reports steady-state throughput (queries/sec)
through the engine's precompiled fixed-batch step. Without ``--queries``
a synthetic batch matching the checkpoint's feature dim is drawn — a
smoke mode for CI and demos.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--checkpoint", required=True,
                    help="ModelState npz written by core/checkpoint.py "
                         "(e.g. sample_dpmm --checkpoint-path)")
    ap.add_argument("--queries", default="",
                    help=".npy (N, d) query rows; default: synthetic")
    ap.add_argument("--n", type=int, default=10_000,
                    help="synthetic query count when --queries is unset")
    ap.add_argument("--batch-size", "--batch_size", type=int, default=2048)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--sample", action="store_true",
                    help="also draw a sampled (Gumbel) assignment per row")
    ap.add_argument("--result-path", "--result_path", default="")
    ap.add_argument("--bench", action="store_true",
                    help="measure throughput instead of dumping answers")
    ap.add_argument("--bench-reps", type=int, default=20)
    args = ap.parse_args(argv)

    from repro.serve.dpmm import DPMMEngine

    t0 = time.time()
    engine = DPMMEngine.from_checkpoint(
        args.checkpoint, batch_size=args.batch_size,
        use_pallas=args.use_pallas, seed=args.seed)
    print(f"engine up in {time.time() - t0:.2f}s: family={engine.family.name} "
          f"d={engine.d} k_max={engine.k_max} batch={engine.batch_size} "
          f"(step precompiled)")

    if args.queries:
        xq = np.asarray(np.load(args.queries), np.float32)
    else:
        rng = np.random.default_rng(args.seed)
        xq = rng.standard_normal((args.n, engine.d)).astype(np.float32)
        print(f"no --queries: serving {args.n} synthetic rows")

    if args.bench:
        engine.query(xq[: args.batch_size])          # warm (already AOT)
        t0 = time.perf_counter()
        for _ in range(args.bench_reps):
            engine.query(xq)
        dt = (time.perf_counter() - t0) / args.bench_reps
        qps = xq.shape[0] / dt
        print(f"throughput: {qps:,.0f} queries/s "
              f"({dt * 1e3:.2f} ms per {xq.shape[0]}-row request)")
        return

    t0 = time.perf_counter()
    res = engine.query(xq)
    dt = time.perf_counter() - t0
    counts = np.bincount(res.labels, minlength=engine.k_max)
    used = np.flatnonzero(counts)
    print(f"served {xq.shape[0]} queries in {dt * 1e3:.1f} ms "
          f"({xq.shape[0] / dt:,.0f} q/s): {used.size} clusters hit, "
          f"mean log p(x) = {res.log_predictive.mean():.3f}")
    out = {
        "labels": res.labels.tolist(),
        "log_predictive": res.log_predictive.tolist(),
        "cluster_counts": {int(k): int(counts[k]) for k in used},
        "family": engine.family.name,
        "k_max": engine.k_max,
    }
    if args.sample:
        out["sampled_labels"] = engine.sample(xq, seed=args.seed).tolist()
    if args.result_path:
        with open(args.result_path, "w") as f:
            json.dump(out, f)
        print(f"wrote {args.result_path}")


if __name__ == "__main__":
    main()
