"""Production meshes (TPU v5e): single-pod 16x16 and 2-pod 2x16x16.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS *before* any jax init; tests see the
plain 1-device CPU).
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import Mesh

# TPU v5e hardware constants (per chip) — the roofline denominators.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link (~per axis neighbor)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over real local devices (tests / examples)."""
    devs = np.array(jax.devices()[:data * model]).reshape(data, model)
    return Mesh(devs, axis_names=("data", "model"))


def mesh_chips(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
