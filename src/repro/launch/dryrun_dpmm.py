"""Production-mesh dry-run for the paper's OWN workload: one distributed
DPMM iteration (restricted Gibbs + split/merge) over N points sharded
across 256 / 512 chips.

    PYTHONPATH=src python -m repro.launch.dryrun_dpmm [--n 1000000] [--d 64]
        [--multi-pod] [--shard-features]

Verifies structurally (C3): every collective is O(K_max * T) suff-stats /
scalars — the O(N d / chips) point shard never crosses the wire — and
reports the three roofline terms for the sweep.
"""
# placeholder devices BEFORE any jax import (see dryrun.py)
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse
import functools
import json

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import DPMMConfig
from repro.core.distributed import shard_map
from repro.core.family import get_family, state_partition_specs
from repro.core.sampler import dpmm_step
from repro.core.state import ModelState, PointState
from repro.launch.mesh import make_production_mesh, mesh_chips
from repro.roofline.analysis import analyze, save_json

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1_000_000)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--k-max", type=int, default=64)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--shard-features", action="store_true",
                    help="shard d over 'model' (multinomial component "
                         "only: the Gaussian full-covariance Mahalanobis "
                         "is not feature-separable — DESIGN §10)")
    ap.add_argument("--out-dir", default=OUT_DIR)
    args = ap.parse_args(argv)

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    mesh_name = "x".join(str(mesh.shape[a]) for a in mesh.axis_names)
    chips = mesh_chips(mesh)
    axes = tuple(a for a in mesh.axis_names if a != "model")
    n_data_shards = 1
    for a in axes:
        n_data_shards *= mesh.shape[a]
    n_local = -(-args.n // n_data_shards)
    n = n_local * n_data_shards

    # --shard-features => multinomial family (the paper's 20newsgroups
    # d=20,000 regime; Gaussian full-covariance is not feature-separable)
    family = get_family("multinomial" if args.shard_features else "gaussian")
    feat_axis = "model" if args.shard_features else None
    cfg = DPMMConfig(alpha=10.0, k_max=args.k_max, burnout=0,
                     component=family.name,
                     shard_features=args.shard_features)
    prior = family.build_prior(cfg, jnp.zeros((1, args.d), jnp.float32))
    kwargs = dict(prior=prior, family=family, cfg=cfg, axes=axes,
                  k_max=cfg.k_max, feat_axis=feat_axis)

    shard_spec = P(axes)
    x_spec = P(axes, feat_axis)
    state_specs = state_partition_specs(family, shard_spec)

    # abstract state/input (ShapeDtypeStruct only — no allocation): the
    # family's own empty_stats/expected_params give the per-family shapes
    k = args.k_max
    d = args.d
    f32 = jnp.float32
    stats_s = jax.eval_shape(lambda: family.empty_stats((k,), d))
    substats_s = jax.eval_shape(lambda: family.empty_stats((k, 2), d))
    params_s = jax.eval_shape(family.expected_params, prior, stats_s)
    subparams_s = jax.eval_shape(family.expected_params, prior, substats_s)
    model = ModelState(
        key=jax.eval_shape(lambda: jax.random.key(0)),
        it=jax.ShapeDtypeStruct((), jnp.int32),
        active=jax.ShapeDtypeStruct((k,), bool),
        logweights=jax.ShapeDtypeStruct((k,), f32),
        sub_logweights=jax.ShapeDtypeStruct((k, 2), f32),
        stuck=jax.ShapeDtypeStruct((k,), jnp.int32),
        params=params_s,
        subparams=subparams_s,
        stats=stats_s,
        substats=substats_s)
    point = PointState(
        labels=jax.ShapeDtypeStruct((n,), jnp.int32),
        sublabels=jax.ShapeDtypeStruct((n,), jnp.int32),
        valid=jax.ShapeDtypeStruct((n,), f32))
    xs = jax.ShapeDtypeStruct((n, d), f32)

    step = jax.jit(shard_map(
        functools.partial(dpmm_step, **kwargs), mesh=mesh,
        in_specs=(*state_specs, x_spec),
        out_specs=state_specs))
    with mesh:
        lowered = step.lower(model, point, xs)
        compiled = lowered.compile()

    # MODEL_FLOPS: the O(N K T) loglik/suffstat passes (T = d^2 Gaussian,
    # T = d multinomial — paper §4.4) + the O(K^2 d^3) all-pairs merge
    # marginals for Gaussian (they dominate when N/chips < K*d)
    gaussian = family.name == "gaussian"
    t_term = d * d if gaussian else d
    model_flops = (8.0 * n * args.k_max * t_term / chips
                   + (args.k_max ** 2 / 2 * d ** 3 / 3 if gaussian
                      else 0.0))
    r = analyze(compiled,
                arch=f"dpmm-{family.name}",
                shape=f"N{args.n}_d{d}_K{args.k_max}"
                      + ("_featshard" if args.shard_features else ""),
                mesh_name=mesh_name, chips=chips, model_flops=model_flops)
    mem = compiled.memory_analysis()
    print(f"--- DPMM N={n} d={d} K_max={args.k_max} on {mesh_name} "
          f"({'feature-sharded' if args.shard_features else 'replicated-d'})")
    print(f"    memory: args={r.mem_args/2**30:.2f}GiB "
          f"temp={r.mem_temp/2**30:.2f}GiB")
    print(f"    flops/dev={r.flops_per_device:.3e} "
          f"bytes/dev={r.bytes_per_device:.3e}")
    print(f"    collectives: " + ", ".join(
        f"{kk}={v/2**20:.2f}MiB" for kk, v in r.coll_bytes.items() if v))
    print(f"    roofline: compute={r.t_compute*1e3:.3f}ms "
          f"memory={r.t_memory*1e3:.3f}ms "
          f"collective={r.t_collective*1e3:.3f}ms -> {r.bottleneck}-bound, "
          f"useful={r.useful_ratio:.3f}")
    # C3 structural check: total collective volume must be O(K d^2), not O(N d)
    suffstat_bytes = args.k_max * (1 + d + d * d) * 4 * 3 * 2 * 10
    shard_bytes = n // n_data_shards * d * 4
    total_coll = r.collective_total
    verdict = ("OK (<< shard)" if total_coll < shard_bytes else
               "suff-stats exceed the shard (high-d regime: K*d^2 > "
               "N_local*d; no point data moves — see EXPERIMENTS)")
    print(f"    C3 check: collective/step = {total_coll/2**20:.2f} MiB; "
          f"point shard = {shard_bytes/2**20:.2f} MiB; {verdict}")
    save_json(r, os.path.join(
        args.out_dir, f"dpmm__{r.shape}__{mesh_name}.json"))


if __name__ == "__main__":
    main()
