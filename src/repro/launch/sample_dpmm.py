"""DPMM sampling driver — the paper's §3.4 command-line entry point.

    PYTHONPATH=src python -m repro.launch.sample_dpmm \
        --n 100000 --d 2 --k 10 --alpha 10 --iters 100 [--prior-type \
        Multinomial] [--params-path params.json] [--result-path out.json]

Mirrors the reference CLI: ``--params_path`` JSON overrides hyperparams
(alpha, k_max, burnout, ...); the result JSON carries predicted labels,
weights, NMI and per-iteration running times — the same fields the paper's
result file documents (§3.4.3).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np

from repro.configs import DPMMConfig
from repro.core.family import available_families
from repro.core.sampler import DPMM
from repro.data.synthetic import generate_gmm, generate_mnmm, generate_pmm

# reference-CLI aliases on top of the registry's canonical names
_PRIOR_ALIASES = {"gaussian": "gaussian", "multinomial": "multinomial",
                  "poisson": "poisson", "diaggaussian": "diag_gaussian"}


def _component_of(prior_type: str) -> str:
    name = prior_type.lower()
    name = _PRIOR_ALIASES.get(name, name)
    if name not in available_families():
        raise SystemExit(
            f"unknown --prior-type {prior_type!r}; known: "
            f"{', '.join(available_families())} (or reference-CLI aliases "
            f"{', '.join(sorted(_PRIOR_ALIASES))})")
    return name


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--d", type=int, default=2)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--alpha", type=float, default=10.0)
    ap.add_argument("--iters", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prior-type", "--prior_type", default="Gaussian",
                    help="component family: any registry name "
                         "(gaussian, diag_gaussian, multinomial, poisson) "
                         "or the reference CLI's capitalized aliases")
    ap.add_argument("--data-path", default="", help=".npy (N, d) input; "
                    "with --tile-size it is memory-mapped, never fully "
                    "loaded (out-of-core)")
    ap.add_argument("--params-path", "--params_path", default="")
    ap.add_argument("--result-path", "--result_path", default="")
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--tile-size", "--tile_size", type=int, default=None,
                    help="stream points through tiles of this many rows "
                         "per shard (out-of-core data plane; device memory "
                         "becomes O(k_max + tile_size)). Default: resident")
    ap.add_argument("--n-chains", "--n_chains", type=int, default=1,
                    help="parallel MCMC chains sharing one device copy of "
                         "x; the result (and checkpoint) is the best-"
                         "scoring chain, with split-R-hat printed")
    ap.add_argument("--checkpoint-path", "--checkpoint_path", default="",
                    help="write the fitted ModelState npz here "
                         "(core/checkpoint.py; servable via "
                         "repro.launch.serve_dpmm). With "
                         "--checkpoint-every it is the auto-checkpoint "
                         "rotation prefix instead")
    ap.add_argument("--checkpoint-every", "--checkpoint_every", type=int,
                    default=None,
                    help="auto-checkpoint the fit every this many "
                         "iterations to the --checkpoint-path rotation "
                         "(atomic, CRC-verified, last-"
                         "`DPMMConfig.checkpoint_keep` members kept)")
    ap.add_argument("--resume", action="store_true",
                    help="resume a killed fit from the newest VERIFYING "
                         "member of the --checkpoint-path rotation; "
                         "--iters is the total target, so only the "
                         "remaining iterations run. No checkpoint yet "
                         "means a fresh fit — rerunning the same "
                         "command until it finishes is safe")
    ap.add_argument("--workers", type=int, default=None,
                    help="elastic multi-process sampling: spawn this many "
                         "worker shard processes (repro.dist), each "
                         "streaming a row range of x; the chain is "
                         "bitwise identical to the single-process fit at "
                         "any worker count, and SIGKILL'd/hung workers "
                         "fail over to survivors. Composes with "
                         "--tile-size/--checkpoint-every/--resume")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    overrides = {}
    if args.params_path:
        with open(args.params_path) as f:
            overrides = json.load(f)
    cfg = DPMMConfig(
        component=_component_of(args.prior_type),
        alpha=overrides.get("alpha", args.alpha),
        iters=overrides.get("iters", args.iters),
        k_max=overrides.get("k_max", 64),
        burnout=overrides.get("burnout", 15),
        log_every=overrides.get("log_every", 10),
        use_pallas=args.use_pallas or overrides.get("use_pallas", False),
        tile_size=(args.tile_size if args.tile_size is not None
                   else overrides.get("tile_size")),
        checkpoint_path=(args.checkpoint_path or None),
        checkpoint_every=args.checkpoint_every,
        workers=(args.workers if args.workers is not None
                 else overrides.get("workers")),
        seed=args.seed,
    )
    if (args.resume or args.checkpoint_every) and not args.checkpoint_path:
        raise SystemExit("--resume/--checkpoint-every need "
                         "--checkpoint-path (the rotation prefix)")

    if args.data_path:
        if cfg.tile_size is not None:
            from repro.data.source import HostTiledSource
            x = HostTiledSource.from_npy(args.data_path)
        else:
            x = np.load(args.data_path)
        gt = None
    elif cfg.component in ("gaussian", "diag_gaussian"):
        x, gt = generate_gmm(args.n, args.d, args.k, seed=args.seed)
    elif cfg.component == "poisson":
        x, gt = generate_pmm(args.n, args.d, args.k, seed=args.seed)
    else:
        x, gt = generate_mnmm(args.n, args.d, args.k, seed=args.seed)

    from repro.data.source import as_source
    source = as_source(x)
    print(f"DPMM fit: N={source.n} d={source.d} component="
          f"{cfg.component} alpha={cfg.alpha} iters={cfg.iters} "
          f"tile_size={cfg.tile_size}"
          + (f" workers={cfg.workers}" if cfg.workers else ""))
    t0 = time.time()
    model = DPMM(cfg)
    result = model.fit(source, verbose=args.verbose,
                       n_chains=args.n_chains, resume=args.resume)
    wall = time.time() - t0
    if result.recoveries:
        kinds = sorted({e["kind"] for e in result.recoveries})
        print(f"recovered from {len(result.recoveries)} fault event(s) "
              f"({', '.join(kinds)}) — see FitResult.recoveries")
    if result.n_chains > 1:
        try:
            rhats = {k: round(v, 3) for k, v in result.rhats().items()}
        except ValueError:          # too few iterations for split-R-hat
            rhats = "n/a (needs >= 4 iters)"
        print(f"chains: scores={np.round(np.asarray(result.score), 2)} "
              f"rhat={rhats}")
        result = result.select_best()
    nmi = result.nmi(gt) if gt is not None else float("nan")
    print(f"done in {wall:.1f}s: K={result.k} NMI={nmi:.4f} "
          f"mean iter {np.mean(result.iter_times_s[1:])*1e3:.1f} ms")
    if args.checkpoint_path and not args.checkpoint_every:
        from repro.core.checkpoint import save_model
        path = save_model(args.checkpoint_path, result.state,
                          cfg.component)
        print(f"wrote checkpoint {path}")
    elif args.checkpoint_every:
        # the fit already wrote the final rotation member (atomic,
        # CRC-verified); point the operator at it
        from repro.core.checkpoint import list_checkpoints
        members = list_checkpoints(cfg.checkpoint_path)
        if members:
            print(f"final checkpoint {members[0][1]}")
    mem = result.device_bytes or {}
    print(f"device memory [{mem.get('mode')}]: "
          f"est_peak={mem.get('est_peak_bytes', 0)/2**20:.2f} MiB"
          + (f"  measured_peak={mem['peak_bytes_in_use']/2**20:.2f} MiB"
             if mem.get("peak_bytes_in_use") else ""))

    if args.result_path:
        weights = np.exp(np.asarray(result.state.logweights))
        active = np.asarray(result.state.active)
        out = {
            "labels": result.labels.tolist(),
            "weights": weights[active].tolist(),
            "k": result.k,
            "nmi": nmi,
            "iter_times_s": result.iter_times_s,
            "device_bytes": result.device_bytes,
            "config": dataclasses.asdict(cfg),
            # distributed fits: per-worker shard ranges + failover
            # tallies, and the full recovery event log
            "dist": result.dist,
            "recoveries": result.recoveries,
        }
        with open(args.result_path, "w") as f:
            json.dump(out, f)
        print(f"wrote {args.result_path}")


if __name__ == "__main__":
    main()
