"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) and emit
roofline terms. THE proof that the distribution config is coherent.

Usage (PYTHONPATH=src):
    python -m repro.launch.dryrun --arch granite-8b --shape train_4k
    python -m repro.launch.dryrun --all                    # 16x16, 40 pairs
    python -m repro.launch.dryrun --all --multi-pod        # 2x16x16
    python -m repro.launch.dryrun --arch ... --moe-strategy expert

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json and a
summary table on stdout (EXPERIMENTS.md §Dry-run / §Roofline read these).
"""
# The 512 placeholder devices MUST be configured before ANY jax import —
# jax locks the device count on first init. Do not move these lines.
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch.mesh import make_production_mesh, mesh_chips
from repro.launch.specs import step_spec
from repro.roofline.analysis import HEADER, analyze, save_json
from repro.roofline.model_flops import model_flops_per_device

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def skip_reason(arch: str, shape_name: str) -> str:
    """DESIGN §5 skips: whisper has no 524k decode."""
    if arch == "whisper-medium" and shape_name == "long_500k":
        return ("decoder is specified for <=448 positions with a <=1500-"
                "frame encoder; a 524k self-attn cache is architecturally "
                "meaningless (DESIGN §5)")
    return ""


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            moe_strategy: str = "tensor", save: bool = True,
            verbose: bool = True, out_dir: str = OUT_DIR):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(mesh.shape[a]) for a in mesh.axis_names)
    chips = mesh_chips(mesh)

    t0 = time.time()
    spec = step_spec(cfg, shape, mesh, moe_strategy=moe_strategy)
    with mesh:
        lowered = jax.jit(
            spec.fn,
            in_shardings=spec.in_shardings,
            out_shardings=spec.out_shardings,
            donate_argnums=spec.donate,
        ).lower(*spec.args)
        compiled = lowered.compile()
    t1 = time.time()

    r = analyze(compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
                chips=chips,
                model_flops=model_flops_per_device(cfg, shape, chips))
    if verbose:
        mem = compiled.memory_analysis()
        print(f"--- {arch} x {shape_name} on {mesh_name} "
              f"({spec.meta['kind']}, compile {t1-t0:.1f}s)")
        print(f"    memory_analysis: args={r.mem_args/2**30:.2f}GiB "
              f"out={r.mem_output/2**30:.2f}GiB "
              f"temp={r.mem_temp/2**30:.2f}GiB "
              f"peak={r.mem_peak/2**30:.2f}GiB per device")
        print(f"    cost_analysis: flops/dev={r.flops_per_device:.3e} "
              f"bytes/dev={r.bytes_per_device:.3e}")
        print(f"    collectives: " + ", ".join(
            f"{k}={v/2**20:.1f}MiB" for k, v in r.coll_bytes.items() if v))
        print(f"    roofline: compute={r.t_compute*1e3:.2f}ms "
              f"memory={r.t_memory*1e3:.2f}ms "
              f"collective={r.t_collective*1e3:.2f}ms "
              f"-> {r.bottleneck}-bound, useful={r.useful_ratio:.3f}")
    if save:
        suffix = "" if moe_strategy == "tensor" else f"__{moe_strategy}"
        save_json(r, os.path.join(
            out_dir, f"{arch}__{shape_name}__{mesh_name}{suffix}.json"))
    return r


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--moe-strategy", default="tensor",
                    choices=("tensor", "expert"))
    ap.add_argument("--no-save", action="store_true")
    ap.add_argument("--out-dir", default=OUT_DIR)
    args = ap.parse_args(argv)

    pairs = []
    if args.all:
        for a in ARCH_IDS:
            for s in INPUT_SHAPES:
                pairs.append((a, s))
    elif args.arch and args.shape:
        pairs.append((args.arch, args.shape))
    elif args.arch:
        pairs.extend((args.arch, s) for s in INPUT_SHAPES)
    else:
        ap.error("need --arch [--shape] or --all")

    rows, failures, skips = [], [], []
    for arch, shape_name in pairs:
        reason = skip_reason(arch, shape_name)
        if reason:
            skips.append((arch, shape_name, reason))
            print(f"--- SKIP {arch} x {shape_name}: {reason}")
            continue
        try:
            r = run_one(arch, shape_name, multi_pod=args.multi_pod,
                        moe_strategy=args.moe_strategy,
                        save=not args.no_save, out_dir=args.out_dir)
            rows.append(r)
        except Exception as e:  # noqa: BLE001 — report all failures at end
            traceback.print_exc()
            failures.append((arch, shape_name, repr(e)))

    print("\n" + HEADER)
    for r in rows:
        print(r.row())
    if skips:
        print(f"\n{len(skips)} documented skip(s).")
    if failures:
        print(f"\n{len(failures)} FAILURE(S):")
        for a, s, e in failures:
            print(f"  {a} x {s}: {e}")
        sys.exit(1)
    print(f"\nall {len(rows)} dry-runs compiled OK")


if __name__ == "__main__":
    main()
