"""Abstract input/state specs (ShapeDtypeStruct) for lowering — the dry-run
never allocates a real tensor.

``step_spec(cfg, shape, mesh)`` returns everything needed to
``jit(fn).lower(...)`` one (architecture x input shape) pair:
the step callable, abstract args, and in/out shardings.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig, TrainConfig
from repro.launch.sharding import fix_specs
from repro.models import decode as decode_mod
from repro.models import transformer
from repro.models.common import BATCH_AXES, ShardingPolicy
from repro.serve.engine import serve_policy, serve_step
from repro.train import trainer
from repro.train.loss import chunked_ce_loss


def _mesh_batch_shards(mesh: Mesh) -> int:
    n = 1
    for a in BATCH_AXES:
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def _abstract(tree, shardings):
    """Attach shardings to ShapeDtypeStructs (lower() consumes these)."""
    return jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
        tree, shardings)


def _to_shard(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda s: isinstance(s, P))


def _subset_structs(structs, specs):
    """Project a struct tree onto the (possibly smaller) spec-tree shape."""
    if isinstance(specs, dict):
        return {k: _subset_structs(structs[k], v) for k, v in specs.items()}
    return structs


class StepSpec(NamedTuple):
    fn: Any                  # the function to jit
    args: Tuple              # abstract args (with shardings attached)
    in_shardings: Any
    out_shardings: Any
    donate: Tuple[int, ...]
    policy: ShardingPolicy
    meta: Dict[str, Any]


def train_policy(mesh: Mesh, shape: InputShape) -> ShardingPolicy:
    return ShardingPolicy(
        batch_sharded=shape.global_batch % _mesh_batch_shards(mesh) == 0,
        seq_shard="model" in mesh.axis_names,
        mesh_axes=tuple(mesh.axis_names),
        mesh_sizes=tuple(mesh.shape.items()))


def _batch_structs(cfg: ModelConfig, shape: InputShape, dtype,
                   seq: Optional[int] = None) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = shape.global_batch, seq or shape.seq_len
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
             "targets": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.encoder_layers:
        batch["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), dtype)
    if cfg.vision_tokens:
        batch["memory"] = jax.ShapeDtypeStruct(
            (b, cfg.vision_tokens, cfg.d_model), dtype)
    return batch


def train_spec(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
               tcfg: Optional[TrainConfig] = None, dtype=jnp.bfloat16,
               moe_strategy: str = "tensor") -> StepSpec:
    tcfg = tcfg or TrainConfig()
    policy = train_policy(mesh, shape)
    n_groups = _mesh_batch_shards(mesh) * mesh.shape.get("model", 1)
    state_structs = jax.eval_shape(
        lambda k: trainer.init_train_state(k, cfg, dtype),
        jax.random.key(0))
    batch = _batch_structs(cfg, shape, dtype)
    raw_s = fix_specs(trainer.train_state_specs(cfg, moe_strategy),
                      state_structs, mesh, fsdp=True)
    raw_b = fix_specs(trainer.batch_sharding(mesh, cfg, policy), batch, mesh)
    sspecs = _to_shard(mesh, raw_s)
    bspecs = _to_shard(mesh, raw_b)
    fn = functools.partial(trainer.train_step, cfg=cfg, tcfg=tcfg,
                           policy=policy, n_groups=n_groups,
                           moe_strategy=moe_strategy,
                           grad_specs=sspecs.params)
    return StepSpec(
        fn=fn,
        args=(_abstract(state_structs, sspecs), _abstract(batch, bspecs)),
        in_shardings=(sspecs, bspecs),
        out_shardings=(sspecs, NamedSharding(mesh, P())),
        donate=(0,),
        policy=policy,
        meta={"kind": "train", "n_groups": n_groups})


def prefill_step(params, batch, *, cfg, policy, tcfg, n_groups=1):
    """Prefill: full-sequence forward -> last-position logits (B, V).

    Serving-realistic: the (B, S, V) logits tensor is never materialized;
    the chunked-CE helper scores the sequence (perplexity servers do this)
    and the final position's logits come from one (B, d) unembed."""
    memory = batch.get("memory")
    if cfg.encoder_layers:
        memory = transformer.encode(params, batch["frames"], cfg, policy,
                                    remat=False)
    hidden, _ = transformer.hidden_forward(
        params, batch["tokens"], cfg, policy, memory=memory, remat=False,
        n_groups=n_groups)
    from repro.models import common as mcommon
    last_logits = mcommon.unembed(hidden[:, -1], params["embed"],
                                  cfg.final_softcap)
    loss, _ = chunked_ce_loss(hidden, batch["targets"], params["embed"],
                              cfg, tcfg.loss_chunk)
    return last_logits, loss


def prefill_spec(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                 dtype=jnp.bfloat16) -> StepSpec:
    tcfg = TrainConfig()
    policy = train_policy(mesh, shape)
    n_groups = _mesh_batch_shards(mesh) * mesh.shape.get("model", 1)
    param_structs = jax.eval_shape(
        lambda k: transformer.init_params(k, cfg, dtype), jax.random.key(0))
    batch = _batch_structs(cfg, shape, dtype)
    pspecs = _to_shard(mesh, fix_specs(transformer.param_specs(cfg),
                                       param_structs, mesh, fsdp=True))
    bspecs = _to_shard(mesh, fix_specs(
        trainer.batch_sharding(mesh, cfg, policy), batch, mesh))
    fn = functools.partial(prefill_step, cfg=cfg, policy=policy, tcfg=tcfg,
                           n_groups=n_groups)
    b = tuple(a for a in BATCH_AXES if a in mesh.axis_names) \
        if policy.batch_sharded else None
    v_ax = "model" if cfg.vocab_size % mesh.shape.get("model", 1) == 0 \
        else None
    return StepSpec(
        fn=fn,
        args=(_abstract(param_structs, pspecs), _abstract(batch, bspecs)),
        in_shardings=(pspecs, bspecs),
        out_shardings=(NamedSharding(mesh, P(b, v_ax)),
                       NamedSharding(mesh, P())),
        donate=(),
        policy=policy,
        meta={"kind": "prefill", "n_groups": n_groups})


def decode_spec(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                dtype=jnp.bfloat16) -> StepSpec:
    policy = serve_policy(mesh, shape.global_batch)
    window_override = (shape.seq_len > 32_768
                       and cfg.long_context == "sliding_window")
    b_count = shape.global_batch
    param_structs = jax.eval_shape(
        lambda k: transformer.init_params(k, cfg, dtype), jax.random.key(0))
    cache_structs = jax.eval_shape(
        lambda: decode_mod.init_cache(cfg, b_count, shape.seq_len, dtype,
                                      window_override=window_override))
    if cfg.vision_tokens or cfg.encoder_layers:
        mem_len = cfg.vision_tokens or cfg.encoder_seq
        mem = jax.ShapeDtypeStruct((b_count, mem_len, cfg.d_model), dtype)
        cache_structs = jax.eval_shape(
            lambda p, c, m: decode_mod.prefill_cross(p, c, m, cfg),
            param_structs, cache_structs, mem)
    pspecs = _to_shard(mesh, fix_specs(transformer.param_specs(cfg),
                                       param_structs, mesh, fsdp=True))
    raw_c = decode_mod.cache_specs(cfg, policy)
    cspecs = _to_shard(mesh, jax.tree.map(
        lambda s_, st: fix_specs(s_, st, mesh),
        raw_c, _subset_structs(cache_structs, raw_c),
        is_leaf=lambda s_: isinstance(s_, P)))
    # cross-cache entries ('xkv') were added by prefill_cross: extend specs
    cspecs = _fill_missing_specs(mesh, cache_structs, cspecs, policy)
    b = tuple(a for a in BATCH_AXES if a in mesh.axis_names) \
        if policy.batch_sharded else None
    tok = jax.ShapeDtypeStruct((b_count, 1), jnp.int32)
    fn = functools.partial(
        serve_step, cfg=cfg, policy=policy,
        window_override=window_override, cache_len=shape.seq_len,
        temperature=0.0)
    tok_shard = NamedSharding(mesh, P(b, None))
    rep = NamedSharding(mesh, P())
    key_struct = jax.eval_shape(lambda: jax.random.key(0))
    return StepSpec(
        fn=fn,
        args=(_abstract(param_structs, pspecs),
              _abstract(cache_structs, cspecs),
              jax.ShapeDtypeStruct(tok.shape, tok.dtype, sharding=tok_shard),
              jax.ShapeDtypeStruct(key_struct.shape, key_struct.dtype,
                                   sharding=rep)),
        in_shardings=(pspecs, cspecs, tok_shard, rep),
        out_shardings=(tok_shard, cspecs),
        donate=(1,),
        policy=policy,
        meta={"kind": "decode", "window_override": window_override})


def _fill_missing_specs(mesh: Mesh, structs, specs, policy: ShardingPolicy):
    """Cache trees gain cross-KV ('xkv') entries after prefill_cross; give
    those a (batch, mem_seq, heads->model, hd) sharding and keep the rest."""
    b = policy.cache_batch_axes

    def xkv_spec(struct):
        # (B, S_mem, H, hd) or stacked (L, B, S_mem, H, hd)
        stacked = len(struct.shape) == 5
        base = [b, None, "model", None]
        if stacked:
            base = [None] + base
        raw = P(*base)
        return NamedSharding(mesh, fix_specs(raw, struct, mesh))

    def walk(st, sp):
        if isinstance(st, dict):
            sp = sp if isinstance(sp, dict) else {}
            out = {}
            for k, v in st.items():
                if k in sp:
                    out[k] = walk(v, sp[k])
                elif k == "xkv":
                    out[k] = jax.tree.map(xkv_spec, v)
                else:
                    out[k] = jax.tree.map(
                        lambda _: NamedSharding(mesh, P()), v)
            return out
        return sp

    return walk(structs, specs)


def step_spec(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
              dtype=jnp.bfloat16, moe_strategy: str = "tensor") -> StepSpec:
    if shape.kind == "train":
        return train_spec(cfg, shape, mesh, dtype=dtype,
                          moe_strategy=moe_strategy)
    if shape.kind == "prefill":
        return prefill_spec(cfg, shape, mesh, dtype=dtype)
    return decode_spec(cfg, shape, mesh, dtype=dtype)
