"""LM training driver (CPU-runnable at smoke scale; dry-run at full scale).

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b --smoke \
        --steps 100 --batch 8 --seq 128

``--smoke`` swaps in the reduced config (2 layers, d_model 256) so a real
optimization run fits this container; without it the full config is
expected to be launched on the production mesh (see dryrun.py for the
lowering proof).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, TrainConfig, get_config, smoke_config
from repro.data.pipeline import lm_batches
from repro.launch.mesh import make_host_mesh
from repro.models.common import ShardingPolicy
from repro.train import checkpoint, init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="granite-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--save", type=str, default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TrainConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                       total_steps=args.steps, loss_chunk=min(args.seq, 512))
    mesh = make_host_mesh(data=len(jax.devices()), model=1)
    policy = ShardingPolicy(
        batch_sharded=args.batch % mesh.shape["data"] == 0,
        seq_shard=False, mesh_axes=tuple(mesh.axis_names),
        mesh_sizes=tuple(mesh.shape.items()))

    state = init_train_state(jax.random.key(args.seed), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"devices={len(jax.devices())}")

    step_fn = make_train_step(mesh, cfg, tcfg, policy, donate=True)
    gen = lm_batches(cfg.vocab_size, args.batch, args.seq, seed=args.seed)

    t0 = time.time()
    for step in range(args.steps):
        toks, tgts = next(gen)
        batch = {"tokens": jnp.asarray(toks), "targets": jnp.asarray(tgts)}
        if cfg.encoder_layers:
            batch["frames"] = jnp.asarray(np.random.default_rng(step).normal(
                0, 0.02, (args.batch, cfg.encoder_seq, cfg.d_model)),
                jnp.float32)
        if cfg.vision_tokens:
            batch["memory"] = jnp.asarray(np.random.default_rng(step).normal(
                0, 0.02, (args.batch, cfg.vision_tokens, cfg.d_model)),
                jnp.float32)
        state, metrics = step_fn(state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"acc={float(metrics['accuracy']):.3f} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"({(time.time()-t0)/(step+1)*1e3:.0f} ms/step)")
    if args.save:
        checkpoint.save(args.save, state.params)
        print(f"saved params to {args.save}")


if __name__ == "__main__":
    main()
