"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

K/V are generated from a low-rank latent ``c = x @ W_dkv`` of width
``kv_lora_rank`` plus a single shared RoPE key ``k_r``; the decode cache
stores only ``(c, k_r)`` — (512 + 64) floats/token instead of
``2 * H * head_dim`` — an ~8x cache compression.

Decode uses the *absorbed* form: ``q_nope @ W_uk`` is folded into the query
so attention scores contract directly against the latent cache; the
per-head K matrix is never materialized at serving time.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import (KeyGen, MODEL_AXIS, ShardingPolicy,
                                 apply_rope, dense_init)
from repro.models.attention import NEG_INF, _blockwise_attn


def init_mla(kg: KeyGen, cfg: ModelConfig, dtype) -> Dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk_dim = m.nope_head_dim + m.rope_head_dim
    p = {
        "wq": dense_init(kg(), (d, h, qk_dim), dtype, in_axis=0),
        "w_dkv": dense_init(kg(), (d, m.kv_lora_rank), dtype, in_axis=0),
        "w_kr": dense_init(kg(), (d, m.rope_head_dim), dtype, in_axis=0),
        "kv_norm": common.init_rmsnorm(m.kv_lora_rank, dtype),
        "w_uk": dense_init(
            kg(), (m.kv_lora_rank, h, m.nope_head_dim), dtype, in_axis=0),
        "w_uv": dense_init(
            kg(), (m.kv_lora_rank, h, m.v_head_dim), dtype, in_axis=0),
        "wo": dense_init(kg(), (h, m.v_head_dim, d), dtype, in_axis=1),
    }
    return p


def spec_mla(cfg: ModelConfig) -> Dict:
    return {
        "wq": P(None, MODEL_AXIS, None),
        "w_dkv": P(None, None),
        "w_kr": P(None, None),
        "kv_norm": common.spec_rmsnorm(),
        "w_uk": P(None, MODEL_AXIS, None),
        "w_uv": P(None, MODEL_AXIS, None),
        "wo": P(MODEL_AXIS, None, None),
    }


def _latent(x: jax.Array, p: Dict, cfg: ModelConfig, positions: jax.Array
            ) -> Tuple[jax.Array, jax.Array]:
    """Compressed KV latent c: (B, S, r) and shared RoPE key (B, S, rd)."""
    c = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    c = common.rmsnorm(c, p["kv_norm"], cfg.norm_eps)
    k_r = jnp.einsum("bsd,dr->bsr", x, p["w_kr"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    k_r = apply_rope(k_r, positions, cfg.rope_theta)
    return c, k_r


def mla_attention(x: jax.Array, p: Dict, cfg: ModelConfig,
                  policy: ShardingPolicy) -> jax.Array:
    """Full-sequence MLA (train / prefill). Materializes per-head K/V, which
    is the faithful (and prefill-optimal) form; decode uses absorption."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    pos = jnp.arange(s)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)

    c, k_r = _latent(x, p, cfg, pos)
    k_nope = jnp.einsum("bsr,rhk->bshk", c, p["w_uk"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsr,rhk->bshk", c, p["w_uv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k_rope = jnp.broadcast_to(k_r[:, :, None, :], (b, s, h, m.rope_head_dim))

    qq = jnp.concatenate([q_nope, q_rope], axis=-1)
    kk = jnp.concatenate([k_nope, k_rope], axis=-1)
    qq = policy.constrain(qq, policy.inner())
    kk = policy.constrain(kk, policy.inner())
    # MLA scales by the *full* qk dim (nope + rope)
    out = _blockwise_attn(qq, kk, v, causal=True, window=0, cap=0.0,
                          policy=policy)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


def init_mla_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype
                   ) -> Dict[str, jax.Array]:
    m = cfg.mla
    return {"c": jnp.zeros((batch, cache_len, m.kv_lora_rank), dtype),
            "k_r": jnp.zeros((batch, cache_len, m.rope_head_dim), dtype)}


def spec_mla_cache(policy: ShardingPolicy) -> Dict[str, P]:
    b = policy.cache_batch_axes
    return {"c": P(b, MODEL_AXIS, None), "k_r": P(b, MODEL_AXIS, None)}


def decode_mla_attention(x: jax.Array, cache: Dict, pos: jax.Array, p: Dict,
                         cfg: ModelConfig, policy: ShardingPolicy
                         ) -> Tuple[jax.Array, Dict]:
    """Absorbed-form one-token decode. x: (B, 1, d)."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.num_heads
    cache_len = cache["c"].shape[1]

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    q_nope, q_rope = jnp.split(q[:, 0], [m.nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope[:, None], pos[None], cfg.rope_theta)[:, 0]

    c_new, kr_new = _latent(x, p, cfg, pos[None])
    c = jax.lax.dynamic_update_slice(
        cache["c"], c_new.astype(cache["c"].dtype), (0, pos, 0))
    k_r = jax.lax.dynamic_update_slice(
        cache["k_r"], kr_new.astype(cache["k_r"].dtype), (0, pos, 0))
    new_cache = {"c": c, "k_r": k_r}

    # absorption: q_nope (B,H,nk) x W_uk (r,H,nk) -> q_lat (B,H,r)
    q_lat = jnp.einsum("bhk,rhk->bhr", q_nope, p["w_uk"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    s = (jnp.einsum("bhr,btr->bht", q_lat, c,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhr,btr->bht", q_rope, k_r,
                      preferred_element_type=jnp.float32)) * scale
    idx = jnp.arange(cache_len)[None, None, :]
    s = jnp.where(idx <= pos, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    # weighted latent, then decompress through W_uv (absorbed on the out side)
    lat = jnp.einsum("bht,btr->bhr", w.astype(c.dtype), c,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = jnp.einsum("bhr,rhk->bhk", lat, p["w_uv"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    y = jnp.einsum("bhk,hkd->bd", out, p["wo"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return y[:, None, :], new_cache
