"""Shared model-building blocks: norms, MLPs, RoPE, embeddings, sharding.

Conventions used across the zoo:
 - Parameters are nested dicts of jax.Arrays; every ``init_*`` function has a
   matching ``spec_*`` function returning an *identically-shaped* pytree of
   ``PartitionSpec`` leaves (asserted in tests/test_zoo_specs.py).
 - Mesh axes: ``data`` (+ optional ``pod``) shard batch; ``model`` shards
   heads / d_ff / vocab / experts (tensor parallelism). The residual stream
   is sequence-sharded over ``model`` between blocks (Megatron-SP style) —
   see ``seq_shard``.
 - All matmuls accumulate in float32 (``preferred_element_type``); params and
   activations are bf16 under the production configs, f32 in CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

# Mesh-axis vocabulary (see launch/mesh.py).
BATCH_AXES = ("pod", "data")     # axes that shard batch (pod absent => data)
MODEL_AXIS = "model"


def batch_spec(shardable: bool = True):
    """Partition entry for a batch dim; None when batch < axis size."""
    return BATCH_AXES if shardable else None


def constrain(x: jax.Array, spec: P) -> jax.Array:
    """with_sharding_constraint that is a no-op outside jit/mesh contexts."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------
def dense_init(key: jax.Array, shape: Tuple[int, ...], dtype,
               in_axis: int = -2) -> jax.Array:
    """LeCun-normal (fan-in) init — standard for transformer projections."""
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape, jnp.float32)
            * (1.0 / jnp.sqrt(fan_in))).astype(dtype)


def embed_init(key: jax.Array, shape: Tuple[int, ...], dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


class KeyGen:
    """Deterministic named key stream (avoids manual split bookkeeping)."""

    def __init__(self, key: jax.Array):
        self._key = key
        self._i = 0

    def __call__(self) -> jax.Array:
        self._i += 1
        return jax.random.fold_in(self._key, self._i)


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------
def init_rmsnorm(d: int, dtype) -> Dict[str, jax.Array]:
    return {"scale": jnp.zeros((d,), dtype)}     # gemma-style (1 + scale)


def spec_rmsnorm() -> Dict[str, P]:
    return {"scale": P(None)}


def rmsnorm(x: jax.Array, p: Dict[str, jax.Array], eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + p["scale"].astype(jnp.float32))
    return out.astype(dt)


def init_layernorm(d: int, dtype) -> Dict[str, jax.Array]:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def spec_layernorm() -> Dict[str, P]:
    return {"scale": P(None), "bias": P(None)}


def layernorm(x: jax.Array, p: Dict[str, jax.Array], eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = ((xf - mu) * jax.lax.rsqrt(var + eps)
           * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32))
    return out.astype(dt)


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """gemma2 logit soft-capping: cap * tanh(x / cap)."""
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# MLP (gated SwiGLU-style or plain 2-matrix)
# ---------------------------------------------------------------------------
def init_mlp(kg: KeyGen, d_model: int, d_ff: int, gated: bool, dtype):
    p = {"up": dense_init(kg(), (d_model, d_ff), dtype),
         "down": dense_init(kg(), (d_ff, d_model), dtype)}
    if gated:
        p["gate"] = dense_init(kg(), (d_model, d_ff), dtype)
    return p


def spec_mlp(gated: bool):
    p = {"up": P(None, MODEL_AXIS), "down": P(MODEL_AXIS, None)}
    if gated:
        p["gate"] = P(None, MODEL_AXIS)
    return p


def mlp(x: jax.Array, p: Dict[str, jax.Array], act: str) -> jax.Array:
    f = activation(act)
    h = jnp.einsum("...d,df->...f", x, p["up"],
                   preferred_element_type=jnp.float32)
    if "gate" in p:
        g = jnp.einsum("...d,df->...f", x, p["gate"],
                       preferred_element_type=jnp.float32)
        h = f(g) * h
    else:
        h = f(h)
    h = h.astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, p["down"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    i = jnp.arange(0, head_dim, 2, dtype=jnp.float32)
    return 1.0 / (theta ** (i / head_dim))          # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd) or (..., S, hd); positions: broadcastable (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                   # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    if x.ndim >= ang.ndim + 2:                      # head axis present
        ang = ang[..., None, :]                     # (..., S, 1, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def init_embed(kg: KeyGen, vocab: int, d_model: int, tie: bool, dtype):
    p = {"tok": embed_init(kg(), (vocab, d_model), dtype)}
    if not tie:
        p["head"] = dense_init(kg(), (d_model, vocab), dtype)
    return p


def spec_embed(tie: bool):
    # untied: shard the table on d_model — the token gather then reads local
    # d-slices (no vocab all-gather; §Perf A3). Tied tables stay vocab-
    # sharded so the unembed contraction keeps its d dim replicated.
    if tie:
        return {"tok": P(MODEL_AXIS, None)}
    return {"tok": P(None, MODEL_AXIS), "head": P(None, MODEL_AXIS)}


def embed(tokens: jax.Array, p: Dict[str, jax.Array]) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0)


def unembed(x: jax.Array, p: Dict[str, jax.Array],
            final_cap: float = 0.0) -> jax.Array:
    w = p.get("head")
    if w is None:
        w = p["tok"].T
    logits = jnp.einsum("...d,dv->...v", x, w,
                        preferred_element_type=jnp.float32)
    return softcap(logits, final_cap)


# ---------------------------------------------------------------------------
# Residual-stream sharding policy
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """How activations are sharded for a given (mesh, input shape).

    ``batch_sharded``: batch dim >= product of batch axes.
    ``seq_shard``: sequence-shard the residual stream over ``model``
    (Megatron-SP); turned off for decode single-token steps.
    ``mesh_axes``: axis names present in the target mesh — entries naming
    absent axes are dropped so constraints never silently no-op.
    """
    batch_sharded: bool = True
    seq_shard: bool = True
    mesh_axes: Tuple[str, ...] = ("data", "model")
    # ((axis, size), ...) for divisibility-aware constraints; empty = skip
    mesh_sizes: Tuple[Tuple[str, int], ...] = ()
    # caches may stay batch-sharded even when activations are replicated
    # (weight-stationary decode, §Perf C): None = follow batch_sharded
    cache_batch_sharded: Optional[bool] = None
    # decode residual: shard d_model over 'data' to MATCH the weights'
    # FSDP dim — contractions become local partials + tiny activation
    # psums instead of per-step weight all-gathers (§Perf C2)
    residual_d_shard: bool = False

    @property
    def batch_axes(self) -> Optional[Tuple[str, ...]]:
        axes = tuple(a for a in BATCH_AXES if a in self.mesh_axes)
        return axes or None

    @property
    def cache_batch_axes(self) -> Optional[Tuple[str, ...]]:
        sharded = (self.batch_sharded if self.cache_batch_sharded is None
                   else self.cache_batch_sharded)
        return self.batch_axes if sharded else None

    @property
    def model_axis(self) -> Optional[str]:
        return MODEL_AXIS if MODEL_AXIS in self.mesh_axes else None

    def residual(self) -> P:
        b = self.batch_axes if self.batch_sharded else None
        s = self.model_axis if self.seq_shard else None
        d = ("data" if self.residual_d_shard and "data" in self.mesh_axes
             else None)
        return P(b, s, d)

    def inner(self) -> P:
        """Within attention/MLP: batch on data, heads/ff on model."""
        b = self.batch_axes if self.batch_sharded else None
        return P(b, None, self.model_axis)

    def fit(self, spec: P, shape: Tuple[int, ...]) -> P:
        """Drop spec entries whose dim is not divisible on this mesh."""
        if not self.mesh_sizes:
            return spec
        sizes = dict(self.mesh_sizes)
        entries = list(spec) + [None] * (len(shape) - len(spec))
        for i, e in enumerate(entries):
            axes = (e,) if isinstance(e, str) else (e or ())
            n = 1
            for a in axes:
                n *= sizes.get(a, 1)
            if n > 1 and shape[i] % n:
                entries[i] = None
        return P(*entries)

    def constrain(self, x: jax.Array, spec: P) -> jax.Array:
        return constrain(x, self.fit(spec, x.shape))


FULL_POLICY = ShardingPolicy()
