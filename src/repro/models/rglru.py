"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Griffin's recurrent block: two input linears (recurrent branch + GeLU gate
branch); the recurrent branch passes a short causal conv then the Real-Gated
LRU:
    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    a_t = exp(-c * softplus(L) * r_t)     (per-channel decay, c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * x_t)
Linear in h => associative scan for train/prefill, O(1) state for decode.
Channel dim sharded over ``model``; the scan is channelwise (no comms).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import KeyGen, MODEL_AXIS, dense_init

RGLRU_C = 8.0


def width(cfg: ModelConfig) -> int:
    return cfg.rglru.lru_width or cfg.d_model


def init_rglru(kg: KeyGen, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    w = width(cfg)
    k = cfg.rglru.conv_kernel
    # Lambda init so the decay a^c spreads over [0.9, 0.999]
    u = jax.random.uniform(kg(), (w,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2.0 * RGLRU_C)))
    return {
        "in_x": dense_init(kg(), (d, w), dtype, in_axis=0),
        "in_gate": dense_init(kg(), (d, w), dtype, in_axis=0),
        "conv_w": (jax.random.normal(kg(), (k, w), jnp.float32)
                   * (1.0 / math.sqrt(k))).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": dense_init(kg(), (w, w), dtype, in_axis=0),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": dense_init(kg(), (w, w), dtype, in_axis=0),
        "b_i": jnp.zeros((w,), jnp.float32),
        "lam": lam,
        "out_proj": dense_init(kg(), (w, d), dtype, in_axis=0),
    }


def spec_rglru(cfg: ModelConfig) -> Dict:
    return {
        "in_x": P(None, MODEL_AXIS),
        "in_gate": P(None, MODEL_AXIS),
        "conv_w": P(None, MODEL_AXIS),
        "conv_b": P(MODEL_AXIS),
        "w_a": P(None, MODEL_AXIS),
        "b_a": P(MODEL_AXIS),
        "w_i": P(None, MODEL_AXIS),
        "b_i": P(MODEL_AXIS),
        "lam": P(MODEL_AXIS),
        "out_proj": P(MODEL_AXIS, None),
    }


def _gates(xb: jax.Array, p: Dict):
    """Decay a_t and gated input for the LRU. xb: (B, S, w)."""
    r = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", xb, p["w_a"],
                   preferred_element_type=jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", xb, p["w_i"],
                   preferred_element_type=jnp.float32) + p["b_i"])
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    drive = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * xb.astype(jnp.float32))
    return a, drive


def _conv(x: jax.Array, p: Dict, state: jax.Array | None, k: int):
    if state is None:
        x_pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        x_pad = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(x_pad[:, i:i + x.shape[1]] * p["conv_w"][i] for i in range(k))
    tail = x_pad[:, x_pad.shape[1] - (k - 1):]
    return out + p["conv_b"], tail


def rglru_block(x: jax.Array, p: Dict, cfg: ModelConfig, policy) -> jax.Array:
    """Full-sequence Griffin recurrent block. x: (B, S, d)."""
    xb = jnp.einsum("bsd,dw->bsw", x, p["in_x"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    gate = jnp.einsum("bsd,dw->bsw", x, p["in_gate"],
                      preferred_element_type=jnp.float32)
    xb = policy.constrain(xb, policy.inner())
    xb, _ = _conv(xb, p, None, cfg.rglru.conv_kernel)
    a, drive = _gates(xb, p)

    def combine(u, v):
        (au, hu), (av, hv) = u, v
        return au * av, hv + av * hu

    _, h = jax.lax.associative_scan(combine, (a, drive), axis=1)
    y = (h * jax.nn.gelu(gate, approximate=True)).astype(x.dtype)
    return jnp.einsum("bsw,wd->bsd", y, p["out_proj"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype) -> Dict:
    w = width(cfg)
    k = cfg.rglru.conv_kernel
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, k - 1, w), dtype)}


def spec_rglru_cache(policy) -> Dict:
    b = policy.cache_batch_axes
    return {"h": P(b, MODEL_AXIS), "conv": P(b, None, MODEL_AXIS)}


def decode_rglru_block(x: jax.Array, cache: Dict, p: Dict, cfg: ModelConfig,
                       policy) -> Tuple[jax.Array, Dict]:
    """One-token step. x: (B, 1, d)."""
    xb = jnp.einsum("bsd,dw->bsw", x, p["in_x"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    gate = jnp.einsum("bsd,dw->bsw", x, p["in_gate"],
                      preferred_element_type=jnp.float32)
    xb, tail = _conv(xb, p, cache["conv"], cfg.rglru.conv_kernel)
    a, drive = _gates(xb, p)
    h = a[:, 0] * cache["h"] + drive[:, 0]
    y = (h[:, None] * jax.nn.gelu(gate, approximate=True)).astype(x.dtype)
    out = jnp.einsum("bsw,wd->bsd", y, p["out_proj"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, {"h": h, "conv": tail.astype(cache["conv"].dtype)}
