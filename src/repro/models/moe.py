"""Mixture-of-Experts FFN: shared experts + routed top-k with capacity.

GShard/Switch-style dispatch: tokens are viewed as (G, T_g, d) groups, each
group routes independently with a static per-group capacity
``C = ceil(T_g * top_k / E * capacity_factor)`` (overflow drops, standard).
Dispatch/combine are one-hot einsums — MXU-friendly, and the same masked
matmul pattern as the paper's suff-stats kernel.

Two sharding strategies (the hillclimb lever, DESIGN §2):
 - ``tensor``: expert weights sharded over ``model`` on d_ff; every device
   holds a slice of EVERY expert; communication = the TP psum.
 - ``expert``: experts sharded over ``model``; tokens move to their experts;
   communication = GSPMD-inserted all-to-alls on the (G, E, C, d) tensors.

Experts are padded to a multiple of the model-axis size; padding experts are
masked out of the router softmax so they never receive tokens.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import KeyGen, MODEL_AXIS, dense_init


def padded_experts(cfg: ModelConfig, pad_to: int = 16) -> int:
    e = cfg.moe.num_experts
    return int(math.ceil(e / pad_to) * pad_to)


def init_moe(kg: KeyGen, cfg: ModelConfig, dtype) -> Dict:
    m = cfg.moe
    d = cfg.d_model
    e = padded_experts(cfg)
    p = {
        "router": dense_init(kg(), (d, e), dtype, in_axis=0),
        "w_up": dense_init(kg(), (e, d, m.d_expert), dtype, in_axis=1),
        "w_gate": dense_init(kg(), (e, d, m.d_expert), dtype, in_axis=1),
        "w_down": dense_init(kg(), (e, m.d_expert, d), dtype, in_axis=1),
    }
    if m.num_shared_experts:
        p["shared"] = common.init_mlp(kg, d, m.d_shared, True, dtype)
    return p


def spec_moe(cfg: ModelConfig, strategy: str = "tensor") -> Dict:
    if strategy == "tensor":
        w = {"w_up": P(None, None, MODEL_AXIS),
             "w_gate": P(None, None, MODEL_AXIS),
             "w_down": P(None, MODEL_AXIS, None)}
    elif strategy == "expert":
        w = {"w_up": P(MODEL_AXIS, None, None),
             "w_gate": P(MODEL_AXIS, None, None),
             "w_down": P(MODEL_AXIS, None, None)}
    else:
        raise ValueError(strategy)
    p = {"router": P(None, None), **w}
    if cfg.moe.num_shared_experts:
        p["shared"] = common.spec_mlp(True)
    return p


def _capacity(tokens_per_group: int, e: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(math.ceil(tokens_per_group * m.top_k / e * m.capacity_factor))
    return max(c, m.top_k)


def route(x2d: jax.Array, p: Dict, cfg: ModelConfig
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Router for (T, d) tokens -> (weights (T, k), experts (T, k), aux)."""
    m = cfg.moe
    e = p["router"].shape[1]
    logits = jnp.einsum("td,de->te", x2d, p["router"],
                        preferred_element_type=jnp.float32)
    # mask padding experts out of the softmax
    mask = jnp.arange(e) < m.num_experts
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, m.top_k)            # (T, k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss
    density = jnp.mean(
        jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0)
    density_prob = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_prob) * (m.num_experts ** 2) / m.top_k
    return w.astype(x2d.dtype), idx, aux


def moe_ffn(x: jax.Array, p: Dict, cfg: ModelConfig, *, n_groups: int = 1,
            strategy: str = "tensor") -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss). Shared + routed experts."""
    m = cfg.moe
    b, s, d = x.shape
    e = padded_experts(cfg)
    t = b * s
    g = n_groups
    while t % g:
        g -= 1                                         # largest divisor <= g
    tg = t // g
    cap = _capacity(tg, m.num_experts, cfg)

    xf = x.reshape(t, d)
    weights, idx, aux = route(xf, p, cfg)

    xg = xf.reshape(g, tg, d)
    idx_g = idx.reshape(g, tg, m.top_k)
    w_g = weights.reshape(g, tg, m.top_k)

    # position of each (token, k) among the tokens routed to the same expert
    onehot = jax.nn.one_hot(idx_g, e, dtype=jnp.int32)      # (g, tg, k, E)
    flat = onehot.reshape(g, tg * m.top_k, e)
    pos = jnp.cumsum(flat, axis=1) - 1                      # (g, tg*k, E)
    pos = jnp.sum(pos * flat, axis=-1).reshape(g, tg, m.top_k)
    keep = pos < cap
    w_kept = jnp.where(keep, w_g, 0.0)

    # dispatch: (g, tg, k) one-hots -> (g, tg, E, C) combine/dispatch masks
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap,
                            dtype=x.dtype)                  # (g, tg, k, C)
    exp_oh = onehot.astype(x.dtype)                         # (g, tg, k, E)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", exp_oh, pos_oh,
                         w_kept.astype(x.dtype))
    dispatch = (combine > 0).astype(x.dtype)

    buf = jnp.einsum("gtec,gtd->gecd", dispatch, xg,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    if strategy == "expert":
        # tokens move to their experts: GSPMD lowers this resharding of the
        # (g, E, C, d) buffer onto the expert-sharded axis as an all-to-all
        buf = common.constrain(buf, P(None, MODEL_AXIS, None, None))
    up = jnp.einsum("gecd,edf->gecf", buf, p["w_up"],
                    preferred_element_type=jnp.float32)
    gate = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"],
                      preferred_element_type=jnp.float32)
    h = (jax.nn.silu(gate) * up).astype(x.dtype)
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["w_down"],
                         preferred_element_type=jnp.float32).astype(x.dtype)
    routed = jnp.einsum("gtec,gecd->gtd", combine, out_buf,
                        preferred_element_type=jnp.float32).astype(x.dtype)
    out = routed.reshape(b, s, d)

    if m.num_shared_experts:
        out = out + common.mlp(x, p["shared"], cfg.act)
    return out, aux.astype(jnp.float32)
