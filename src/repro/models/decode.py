"""Cached one-token decode across heterogeneous layer stacks.

Cache layout mirrors the param layout (first / blocks / rem): scanned
pattern positions carry a ``(repeats, ...)`` stacked cache so the decode
step is a single ``lax.scan`` zipping (params, cache) -> (params, new cache).

Cache sizing policy (DESIGN §5):
 - full-attention layers get a ``cache_len``-token KV cache, sequence dim
   sharded over ``model`` (split-KV / flash-decoding);
 - sliding-window layers get a ``min(window, cache_len)`` ring buffer;
 - ``window_override=True`` (the long_500k serving variant) forces EVERY
   attention layer onto the ring buffer — the documented sub-quadratic path
   for dense archs at 524k context;
 - SSM / RG-LRU layers carry O(1) recurrent state;
 - MLA layers cache the compressed (c, k_r) latent;
 - cross-attention K/V (VLM vision tokens, whisper encoder output) is
   precomputed once per request by ``prefill_cross``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import (ATTN, CROSS, LOCAL_ATTN, RGLRU, SSM,
                                ModelConfig)
from repro.models import attention, common, mla, rglru, ssm
from repro.models.common import MODEL_AXIS, ShardingPolicy
from repro.models.transformer import (ENCDEC, _norms, apply_block, layout)


def _attn_cache_len(kind: str, cfg: ModelConfig, cache_len: int,
                    window_override: bool) -> int:
    if kind == LOCAL_ATTN or window_override:
        return min(cfg.sliding_window, cache_len)
    return cache_len


def _is_local(kind: str, cfg: ModelConfig, cache_len: int,
              window_override: bool) -> bool:
    return _attn_cache_len(kind, cfg, cache_len, window_override) < cache_len


# ---------------------------------------------------------------------------
# Cache init / specs
# ---------------------------------------------------------------------------
def init_block_cache(kind: str, cfg: ModelConfig, batch: int, cache_len: int,
                     dtype, window_override: bool) -> Dict:
    if kind in (ATTN, LOCAL_ATTN, ENCDEC):
        if cfg.mla is not None:
            return {"kv": mla.init_mla_cache(cfg, batch, cache_len, dtype)}
        ln = _attn_cache_len(kind, cfg, cache_len, window_override)
        return {"kv": attention.init_kv_cache(cfg, batch, ln, dtype)}
    if kind == CROSS:
        return {}                      # filled by prefill_cross
    if kind == SSM:
        return {"ssm": ssm.init_ssm_cache(cfg, batch, dtype)}
    if kind == RGLRU:
        return {"rec": rglru.init_rglru_cache(cfg, batch, dtype)}
    raise ValueError(kind)


def spec_block_cache(kind: str, cfg: ModelConfig, policy: ShardingPolicy
                     ) -> Dict:
    if kind in (ATTN, LOCAL_ATTN, ENCDEC):
        if cfg.mla is not None:
            return {"kv": mla.spec_mla_cache(policy)}
        return {"kv": attention.spec_kv_cache(policy)}
    if kind == CROSS:
        return {}       # xkv is added by prefill_cross (specs follow suit)
    if kind == SSM:
        return {"ssm": ssm.spec_ssm_cache(policy)}
    if kind == RGLRU:
        return {"rec": rglru.spec_rglru_cache(policy)}
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype,
               window_override: bool = False) -> Dict:
    lay = layout(cfg)

    def stack(n, make):
        leaves = [make(i) for i in range(n)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *leaves)

    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if lay.first:
        cache["first"] = {
            f"{i}_{k}": init_block_cache(k, cfg, batch, cache_len, dtype,
                                         window_override)
            for i, k in enumerate(lay.first)}
    cache["blocks"] = {
        f"{i}_{k}": stack(lay.repeats,
                          lambda _i: init_block_cache(
                              k, cfg, batch, cache_len, dtype,
                              window_override))
        for i, k in enumerate(lay.period)}
    if lay.remainder:
        cache["rem"] = {
            f"{i}_{k}": init_block_cache(k, cfg, batch, cache_len, dtype,
                                         window_override)
            for i, k in enumerate(lay.remainder)}
    return cache


def cache_specs(cfg: ModelConfig, policy: ShardingPolicy) -> Dict:
    lay = layout(cfg)

    def stacked(tree):
        return jax.tree.map(lambda s: P(None, *s), tree,
                            is_leaf=lambda s: isinstance(s, P))

    specs: Dict[str, Any] = {"pos": P()}
    if lay.first:
        specs["first"] = {
            f"{i}_{k}": spec_block_cache(k, cfg, policy)
            for i, k in enumerate(lay.first)}
    specs["blocks"] = {
        f"{i}_{k}": stacked(spec_block_cache(k, cfg, policy))
        for i, k in enumerate(lay.period)}
    if lay.remainder:
        specs["rem"] = {
            f"{i}_{k}": spec_block_cache(k, cfg, policy)
            for i, k in enumerate(lay.remainder)}
    return specs


def prefill_cross(params: Dict, cache: Dict, memory: jax.Array,
                  cfg: ModelConfig) -> Dict:
    """Precompute cross-attention K/V from (B, S_mem, d) memory."""
    lay = layout(cfg)
    cache = dict(cache)

    def fill(block_params, kind):
        if kind == CROSS or kind == ENCDEC:
            return {"xkv": attention.init_cross_cache(
                cfg, memory, block_params["xattn"])}
        return None

    blocks = dict(cache["blocks"])
    for i, k in enumerate(lay.period):
        key = f"{i}_{k}"
        if k in (CROSS, ENCDEC):
            bp = params["blocks"][key]
            xkv = jax.vmap(
                lambda p: attention.init_cross_cache(cfg, memory,
                                                     p["xattn"]))(bp)
            merged = dict(jax.tree.map(lambda x: x, blocks[key])) \
                if blocks[key] else {}
            merged["xkv"] = xkv
            blocks[key] = merged
    cache["blocks"] = blocks
    for sect, kinds in (("first", lay.first), ("rem", lay.remainder)):
        if not kinds or sect not in cache:
            continue
        d = dict(cache[sect])
        for i, k in enumerate(kinds):
            if k in (CROSS, ENCDEC):
                merged = dict(d[f"{i}_{k}"])
                merged["xkv"] = attention.init_cross_cache(
                    cfg, memory, params[sect][f"{i}_{k}"]["xattn"])
                d[f"{i}_{k}"] = merged
        cache[sect] = d
    return cache


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------
def decode_block(x: jax.Array, bcache: Dict, p: Dict, kind: str,
                 cfg: ModelConfig, policy: ShardingPolicy, pos: jax.Array,
                 window_override: bool, cache_len: int
                 ) -> Tuple[jax.Array, Dict]:
    _, _, norm = _norms(cfg)
    new_cache: Dict[str, Any] = dict(bcache)
    h = norm(x, p["ln1"], cfg.norm_eps)
    if kind in (ATTN, LOCAL_ATTN, ENCDEC):
        if cfg.mla is not None:
            y, kv = mla.decode_mla_attention(h, bcache["kv"], pos, p["attn"],
                                             cfg, policy)
        else:
            y, kv = attention.decode_self_attention(
                h, bcache["kv"], pos, p["attn"], cfg, policy,
                local=_is_local(kind, cfg, cache_len, window_override))
        new_cache["kv"] = kv
        x = x + y
    elif kind == CROSS:
        y = attention.decode_cross_attention(h, bcache["xkv"], p["xattn"],
                                             cfg)
        x = x + jnp.tanh(p["xgate"].astype(jnp.float32)).astype(x.dtype) * y
    elif kind == SSM:
        y, st = ssm.decode_ssm_block(h, bcache["ssm"], p["ssm"], cfg, policy)
        new_cache["ssm"] = st
        return policy.constrain(x + y, policy.residual()), new_cache
    elif kind == RGLRU:
        y, st = rglru.decode_rglru_block(h, bcache["rec"], p["rec"], cfg,
                                         policy)
        new_cache["rec"] = st
        x = x + y
    if kind == ENCDEC:
        h = norm(x, p["lnx"], cfg.norm_eps)
        x = x + attention.decode_cross_attention(h, bcache["xkv"],
                                                 p["xattn"], cfg)
    x = policy.constrain(x, policy.residual())
    h = norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        from repro.models import moe as moe_mod
        y, _ = moe_mod.moe_ffn(h, p["moe"], cfg)
    else:
        y = common.mlp(h, p["mlp"], cfg.act)
    return policy.constrain(x + y, policy.residual()), new_cache


def decode_step(params: Dict, cache: Dict, tokens: jax.Array,
                cfg: ModelConfig, policy: ShardingPolicy,
                window_override: bool = False, cache_len: int = 0
                ) -> Tuple[jax.Array, Dict]:
    """tokens: (B, 1) -> (logits (B, 1, V), new cache). pos from cache."""
    _, _, norm = _norms(cfg)
    lay = layout(cfg)
    pos = cache["pos"]
    x = common.embed(tokens, params["embed"])
    if cfg.arch_type == "audio":
        d = cfg.d_model
        i = jnp.arange(0, d, 2, dtype=jnp.float32)
        ang = pos.astype(jnp.float32) / (10000.0 ** (i / d))
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None]
        x = x + pe.astype(x.dtype)
    x = policy.constrain(x, policy.residual())
    new_cache: Dict[str, Any] = {"pos": pos + 1}

    if lay.first:
        sec = {}
        for i, kind in enumerate(lay.first):
            key = f"{i}_{kind}"
            x, bc = decode_block(x, cache["first"][key],
                                 params["first"][key], kind, cfg, policy,
                                 pos, window_override, cache_len)
            sec[key] = bc
        new_cache["first"] = sec

    period_keys = [f"{i}_{k}" for i, k in enumerate(lay.period)]

    def body(carry, inp):
        h = carry
        lp, lc = inp
        out_c = {}
        for pk in period_keys:
            kind = pk.split("_", 1)[1]
            h, bc = decode_block(h, lc[pk], lp[pk], kind, cfg, policy, pos,
                                 window_override, cache_len)
            out_c[pk] = bc
        return h, out_c

    x, blocks_cache = jax.lax.scan(
        body, x, (params["blocks"],
                  {k: cache["blocks"][k] for k in period_keys}))
    new_cache["blocks"] = blocks_cache

    if lay.remainder:
        sec = {}
        for i, kind in enumerate(lay.remainder):
            key = f"{i}_{kind}"
            x, bc = decode_block(x, cache["rem"][key], params["rem"][key],
                                 kind, cfg, policy, pos, window_override,
                                 cache_len)
            sec[key] = bc
        new_cache["rem"] = sec

    x = norm(x, params["final_norm"], cfg.norm_eps)
    logits = common.unembed(x, params["embed"], cfg.final_softcap)
    return logits, new_cache
