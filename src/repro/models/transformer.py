"""Model assembly: pattern-driven layer stacks for all 10 architectures.

A config's ``pattern`` (e.g. gemma2's ``(local, attn)``, Griffin's
``(rglru, rglru, local)``, the VLM's ``(attn x3, cross, attn)``) is scanned
``repeats`` times with *stacked* parameters — one ``lax.scan`` over the
period keeps compile time and HLO size flat in depth. ``remainder`` layers
(and MoE models' leading dense-FFN layers) run unscanned.

Three entry points:
  ``forward``      — full-sequence (train / prefill) -> logits (+ MoE aux)
  ``encode``       — whisper encoder over stubbed frame embeddings
  ``decode_step``  — one-token cached decode across heterogeneous caches
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import (ATTN, CROSS, LOCAL_ATTN, RGLRU, SSM,
                                ModelConfig)
from repro.configs import first_k_dense
from repro.models import attention, common, mla, moe, rglru, ssm
from repro.models.common import KeyGen, MODEL_AXIS, ShardingPolicy

ENCDEC = "encdec"          # whisper decoder layer: self-attn + cross-attn


# ---------------------------------------------------------------------------
# Layout: (first, period, repeats, remainder)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Layout:
    first: Tuple[str, ...]       # unscanned leading layers (dense FFN)
    period: Tuple[str, ...]      # scanned pattern
    repeats: int
    remainder: Tuple[str, ...]   # unscanned trailing layers


def layout(cfg: ModelConfig) -> Layout:
    if cfg.encoder_layers:
        kinds: Tuple[str, ...] = (ENCDEC,) * cfg.num_layers
    else:
        kinds = cfg.layer_kinds
    fk = first_k_dense(cfg)
    first = kinds[:fk]
    rest = kinds[fk:]
    if cfg.pattern and not cfg.encoder_layers:
        period = cfg.pattern
        remainder = cfg.remainder
    else:
        period = (rest[0],)
        remainder = ()
    repeats = (len(rest) - len(remainder)) // len(period)
    assert repeats * len(period) + len(remainder) + fk == cfg.num_layers
    return Layout(first, period, repeats, remainder)


def _norms(cfg: ModelConfig):
    """(init, spec, apply) — whisper uses LayerNorm, the rest RMSNorm."""
    if cfg.arch_type == "audio":
        return (common.init_layernorm, common.spec_layernorm,
                common.layernorm)
    return (lambda d, dt: common.init_rmsnorm(d, dt),
            common.spec_rmsnorm, common.rmsnorm)


def _uses_mla(cfg: ModelConfig) -> bool:
    return cfg.mla is not None


def _layer_is_moe(cfg: ModelConfig, dense_ffn: bool) -> bool:
    return cfg.moe is not None and not dense_ffn


# ---------------------------------------------------------------------------
# One block: params / specs / apply / decode
# ---------------------------------------------------------------------------
def init_block(kg: KeyGen, kind: str, cfg: ModelConfig, dtype,
               dense_ffn: bool = False) -> Dict:
    ninit, _, _ = _norms(cfg)
    d = cfg.d_model
    p: Dict[str, Any] = {"ln1": ninit(d, dtype)}
    if kind in (ATTN, LOCAL_ATTN, ENCDEC):
        p["attn"] = (mla.init_mla(kg, cfg, dtype) if _uses_mla(cfg)
                     else attention.init_attn(kg, cfg, dtype))
    elif kind == CROSS:
        p["xattn"] = attention.init_attn(kg, cfg, dtype)
        p["xgate"] = jnp.zeros((), dtype)     # llama3.2-style tanh gate
    elif kind == SSM:
        p["ssm"] = ssm.init_ssm(kg, cfg, dtype)
        return p                              # mamba block subsumes the FFN
    elif kind == RGLRU:
        p["rec"] = rglru.init_rglru(kg, cfg, dtype)
    else:
        raise ValueError(kind)
    if kind == ENCDEC:
        p["lnx"] = ninit(d, dtype)
        p["xattn"] = attention.init_attn(kg, cfg, dtype)
    p["ln2"] = ninit(d, dtype)
    if _layer_is_moe(cfg, dense_ffn):
        p["moe"] = moe.init_moe(kg, cfg, dtype)
    else:
        p["mlp"] = common.init_mlp(kg, d, cfg.d_ff, cfg.gated_mlp, dtype)
    return p


def spec_block(kind: str, cfg: ModelConfig, dense_ffn: bool = False,
               moe_strategy: str = "tensor") -> Dict:
    _, nspec, _ = _norms(cfg)
    p: Dict[str, Any] = {"ln1": nspec()}
    if kind in (ATTN, LOCAL_ATTN, ENCDEC):
        p["attn"] = (mla.spec_mla(cfg) if _uses_mla(cfg)
                     else attention.spec_attn(cfg))
    elif kind == CROSS:
        p["xattn"] = attention.spec_attn(cfg)
        p["xgate"] = P()
    elif kind == SSM:
        p["ssm"] = ssm.spec_ssm(cfg)
        return p
    elif kind == RGLRU:
        p["rec"] = rglru.spec_rglru(cfg)
    if kind == ENCDEC:
        p["lnx"] = nspec()
        p["xattn"] = attention.spec_attn(cfg)
    p["ln2"] = nspec()
    if _layer_is_moe(cfg, dense_ffn):
        p["moe"] = moe.spec_moe(cfg, moe_strategy)
    else:
        p["mlp"] = common.spec_mlp(cfg.gated_mlp)
    return p


def apply_block(x: jax.Array, p: Dict, kind: str, cfg: ModelConfig,
                policy: ShardingPolicy, memory: Optional[jax.Array],
                *, causal: bool = True, n_groups: int = 1,
                moe_strategy: str = "tensor") -> Tuple[jax.Array, jax.Array]:
    """Pre-norm residual block. Returns (x, moe_aux)."""
    _, _, norm = _norms(cfg)
    aux = jnp.zeros((), jnp.float32)
    h = norm(x, p["ln1"], cfg.norm_eps)
    if kind in (ATTN, LOCAL_ATTN, ENCDEC):
        if _uses_mla(cfg):
            y = mla.mla_attention(h, p["attn"], cfg, policy)
        else:
            y = attention.self_attention(
                h, p["attn"], cfg, policy, local=(kind == LOCAL_ATTN),
                causal=causal)
        # constrain the row-parallel output BEFORE the residual add: the
        # TP contraction then lowers as reduce-scatter onto the seq-sharded
        # residual, not a full (B, S, d) all-reduce (EXPERIMENTS §Perf, A2)
        y = policy.constrain(y, policy.residual())
        x = x + y
    elif kind == CROSS:
        y = attention.cross_attention(h, memory, p["xattn"], cfg, policy)
        x = x + jnp.tanh(p["xgate"].astype(jnp.float32)).astype(x.dtype) * y
    elif kind == SSM:
        return x + ssm.ssm_block(h, p["ssm"], cfg, policy), aux
    elif kind == RGLRU:
        x = x + rglru.rglru_block(h, p["rec"], cfg, policy)
    if kind == ENCDEC:
        h = norm(x, p["lnx"], cfg.norm_eps)
        x = x + attention.cross_attention(h, memory, p["xattn"], cfg, policy)
    x = policy.constrain(x, policy.residual())
    h = norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, aux = moe.moe_ffn(h, p["moe"], cfg, n_groups=n_groups,
                             strategy=moe_strategy)
    else:
        y = common.mlp(h, p["mlp"], cfg.act)
    y = policy.constrain(y, policy.residual())    # RS, not AR (§Perf A2)
    x = x + y
    return policy.constrain(x, policy.residual()), aux


# ---------------------------------------------------------------------------
# Whole-model params / specs
# ---------------------------------------------------------------------------
def init_params(key: jax.Array, cfg: ModelConfig,
                dtype=jnp.float32) -> Dict:
    kg = KeyGen(key)
    lay = layout(cfg)
    ninit, _, _ = _norms(cfg)
    d = cfg.d_model

    def stack(n: int, make):
        leaves = [make(i) for i in range(n)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *leaves)

    params: Dict[str, Any] = {
        "embed": common.init_embed(kg, cfg.vocab_size, d,
                                   cfg.tie_embeddings, dtype),
        "final_norm": ninit(d, dtype),
    }
    if lay.first:
        params["first"] = {
            f"{i}_{k}": init_block(kg, k, cfg, dtype, dense_ffn=True)
            for i, k in enumerate(lay.first)}
    params["blocks"] = {
        f"{i}_{k}": stack(lay.repeats,
                          lambda _i: init_block(kg, k, cfg, dtype))
        for i, k in enumerate(lay.period)}
    if lay.remainder:
        params["rem"] = {
            f"{i}_{k}": init_block(kg, k, cfg, dtype)
            for i, k in enumerate(lay.remainder)}
    if cfg.encoder_layers:
        params["encoder"] = {
            "blocks": stack(cfg.encoder_layers,
                            lambda _i: init_block(kg, ATTN, cfg, dtype,
                                                  dense_ffn=True)),
            "final_norm": ninit(d, dtype),
        }
    return params


def param_specs(cfg: ModelConfig, moe_strategy: str = "tensor") -> Dict:
    lay = layout(cfg)
    _, nspec, _ = _norms(cfg)

    def stacked(spec_tree):
        return jax.tree.map(
            lambda s: P(None, *s), spec_tree,
            is_leaf=lambda s: isinstance(s, P))

    specs: Dict[str, Any] = {
        "embed": common.spec_embed(cfg.tie_embeddings),
        "final_norm": nspec(),
    }
    if lay.first:
        specs["first"] = {
            f"{i}_{k}": spec_block(k, cfg, dense_ffn=True,
                                   moe_strategy=moe_strategy)
            for i, k in enumerate(lay.first)}
    specs["blocks"] = {
        f"{i}_{k}": stacked(spec_block(k, cfg, moe_strategy=moe_strategy))
        for i, k in enumerate(lay.period)}
    if lay.remainder:
        specs["rem"] = {
            f"{i}_{k}": spec_block(k, cfg, moe_strategy=moe_strategy)
            for i, k in enumerate(lay.remainder)}
    if cfg.encoder_layers:
        specs["encoder"] = {
            "blocks": stacked(spec_block(ATTN, cfg, dense_ffn=True)),
            "final_norm": nspec(),
        }
    return specs


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------
def _sin_positions(seq: int, d: int, dtype) -> jax.Array:
    """Sinusoidal absolute positions (whisper encoder/decoder stub)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    i = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (i / d))
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return pe.astype(dtype)


def encode(params: Dict, frames: jax.Array, cfg: ModelConfig,
           policy: ShardingPolicy, remat: bool = True) -> jax.Array:
    """Whisper encoder over stubbed (B, S_enc, d) frame embeddings."""
    _, _, norm = _norms(cfg)
    x = frames + _sin_positions(frames.shape[1], cfg.d_model, frames.dtype)
    x = policy.constrain(x, policy.residual())

    def body(carry, lp):
        h, _ = apply_block(carry, lp, ATTN, cfg, policy, None, causal=False)
        return h, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
    return norm(x, params["encoder"]["final_norm"], cfg.norm_eps)


def _remat(body, remat, remat_policy: str = "full"):
    """Wrap a scan body in jax.checkpoint with the configured policy.

    'dots' saves matmul outputs (no recompute of projections in the
    backward pass — trades activation memory for the remat re-gather +
    recompute; §Perf A5)."""
    if not remat:
        return body
    if remat_policy == "dots":
        return jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(body)


def forward(params: Dict, tokens: jax.Array, cfg: ModelConfig,
            policy: ShardingPolicy, memory: Optional[jax.Array] = None,
            remat: bool = True, n_groups: int = 1,
            moe_strategy: str = "tensor",
            remat_policy: str = "full") -> Tuple[jax.Array, jax.Array]:
    """tokens: (B, S) -> (logits (B, S, V) f32, moe_aux ())."""
    _, _, norm = _norms(cfg)
    lay = layout(cfg)
    x = common.embed(tokens, params["embed"])
    if cfg.arch_type == "audio":
        x = x + _sin_positions(x.shape[1], cfg.d_model, x.dtype)
    x = policy.constrain(x, policy.residual())
    aux = jnp.zeros((), jnp.float32)
    kw = dict(n_groups=n_groups, moe_strategy=moe_strategy)

    for i, kind in enumerate(lay.first):
        x, a = apply_block(x, params["first"][f"{i}_{kind}"], kind, cfg,
                           policy, memory, **kw)
        aux = aux + a

    period_keys = [f"{i}_{k}" for i, k in enumerate(lay.period)]

    def body(carry, layer_params):
        h, acc = carry
        for pk in period_keys:
            kind = pk.split("_", 1)[1]
            h, a = apply_block(h, layer_params[pk], kind, cfg, policy,
                               memory, **kw)
            acc = acc + a
        return (h, acc), None

    body = _remat(body, remat, remat_policy)
    (x, aux), _ = jax.lax.scan(body, (x, aux), params["blocks"])

    for i, kind in enumerate(lay.remainder):
        x, a = apply_block(x, params["rem"][f"{i}_{kind}"], kind, cfg,
                           policy, memory, **kw)
        aux = aux + a

    x = norm(x, params["final_norm"], cfg.norm_eps)
    logits = common.unembed(x, params["embed"], cfg.final_softcap)
    return logits, aux


def hidden_forward(params: Dict, tokens: jax.Array, cfg: ModelConfig,
                   policy: ShardingPolicy,
                   memory: Optional[jax.Array] = None,
                   remat: bool = True, n_groups: int = 1,
                   moe_strategy: str = "tensor",
                   remat_policy: str = "full"
                   ) -> Tuple[jax.Array, jax.Array]:
    """Final hidden states (B, S, d) + MoE aux — the train-step forward
    (logits stay chunked in the loss) and the DPMM embedding example."""
    _, _, norm = _norms(cfg)
    lay = layout(cfg)
    x = common.embed(tokens, params["embed"])
    if cfg.arch_type == "audio":
        x = x + _sin_positions(x.shape[1], cfg.d_model, x.dtype)
    x = policy.constrain(x, policy.residual())
    aux = jnp.zeros((), jnp.float32)
    kw = dict(n_groups=n_groups, moe_strategy=moe_strategy)
    period_keys = [f"{i}_{k}" for i, k in enumerate(lay.period)]

    def body(carry, layer_params):
        h, acc = carry
        for pk in period_keys:
            kind = pk.split("_", 1)[1]
            h, a = apply_block(h, layer_params[pk], kind, cfg, policy,
                               memory, **kw)
            acc = acc + a
        return (h, acc), None

    body = _remat(body, remat, remat_policy)
    for i, kind in enumerate(lay.first):
        x, a = apply_block(x, params["first"][f"{i}_{kind}"], kind, cfg,
                           policy, memory, **kw)
        aux = aux + a
    (x, aux), _ = jax.lax.scan(body, (x, aux), params["blocks"])
    for i, kind in enumerate(lay.remainder):
        x, a = apply_block(x, params["rem"][f"{i}_{kind}"], kind, cfg,
                           policy, memory, **kw)
        aux = aux + a
    return norm(x, params["final_norm"], cfg.norm_eps), aux
