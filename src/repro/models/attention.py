"""Attention blocks: GQA self-attention (RoPE, sliding window, soft-cap),
cross-attention, and split-KV cached decoding.

Memory discipline:
 - prefill/train attention is *blockwise over KV chunks* (online softmax via
   ``lax.scan``) so the (S, S) score matrix never materializes — the pure-JAX
   analogue of flash attention, and the form that lowers/compiles for 32k
   sequences on the production mesh;
 - decode attends one query token against a cache whose *sequence dim is
   sharded over the ``model`` axis* (flash-decoding / split-KV): the softmax
   max/sum and the weighted-value contraction reduce over the sharded dim,
   which GSPMD turns into the psum pair.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import (KeyGen, MODEL_AXIS, ShardingPolicy,
                                 apply_rope, dense_init, softcap)

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------
def init_attn(kg: KeyGen, cfg: ModelConfig, dtype,
              kv_d_model: Optional[int] = None) -> Dict:
    """GQA projection params. ``kv_d_model``: source dim for K/V (cross)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    kvd = kv_d_model or d
    p = {
        "wq": dense_init(kg(), (d, h, hd), dtype, in_axis=0),
        "wk": dense_init(kg(), (kvd, kv, hd), dtype, in_axis=0),
        "wv": dense_init(kg(), (kvd, kv, hd), dtype, in_axis=0),
        "wo": dense_init(kg(), (h, hd, d), dtype, in_axis=1),
    }
    if cfg.qk_norm:
        p["q_norm"] = common.init_rmsnorm(hd, dtype)
        p["k_norm"] = common.init_rmsnorm(hd, dtype)
    return p


def spec_attn(cfg: ModelConfig) -> Dict:
    p = {
        "wq": P(None, MODEL_AXIS, None),
        "wk": P(None, MODEL_AXIS, None),
        "wv": P(None, MODEL_AXIS, None),
        "wo": P(MODEL_AXIS, None, None),
    }
    if cfg.qk_norm:
        p["q_norm"] = common.spec_rmsnorm()
        p["k_norm"] = common.spec_rmsnorm()
    return p


def _project_qkv(x: jax.Array, kv_src: jax.Array, p: Dict, cfg: ModelConfig
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("btd,dhk->bthk", kv_src, p["wk"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("btd,dhk->bthk", kv_src, p["wv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if cfg.qk_norm:
        q = common.rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = common.rmsnorm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


# ---------------------------------------------------------------------------
# Blockwise (online-softmax) attention — the prefill/train path
# ---------------------------------------------------------------------------
def _blockwise_attn(q: jax.Array, k: jax.Array, v: jax.Array,
                    *, causal: bool, window: int, cap: float,
                    q_offset: jax.Array | int = 0,
                    kv_chunk: int = 1024,
                    policy=None) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd). Returns (B, Sq, H, hd).

    Scans over KV chunks keeping (out_acc, row_max, row_sum) — the score
    matrix lives only one (Sq, kv_chunk) block at a time.

    Heads stay ONE flat axis throughout (K/V repeated to H inside the
    step): a (kvh, group) split makes GSPMD factor the 16-way model axis
    as {kvh x group} and flip-flop against the seq sharding — measured as
    'involuntary full rematerialization' + ~4 GiB/layer of extra
    all-gather/all-reduce on granite train_4k (EXPERIMENTS §Perf, A1).
    """
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    hdv = v.shape[-1]                      # may differ from hd (MLA)
    group = h // kvh
    scale = hd ** -0.5
    kv_chunk = min(kv_chunk, skv)
    n_chunks = -(-skv // kv_chunk)
    pad = n_chunks * kv_chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, kv_chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, kv_chunk, kvh, hdv).transpose(1, 0, 2, 3, 4)

    q_pos = (jnp.arange(sq) + q_offset)[None, :, None, None]
    inner = None
    if policy is not None:
        from jax.sharding import PartitionSpec as P
        bspec = policy.batch_axes if policy.batch_sharded else None
        inner = P(bspec, None, policy.model_axis, None)

    def _c(x):
        return policy.constrain(x, inner) if policy is not None else x

    def step(carry, inp):
        out, m, l = carry
        ci, kb, vb = inp                       # kb/vb: (B, C, KV, hd)
        if group > 1:                          # GQA: repeat KV to H heads
            kb = jnp.repeat(kb, group, axis=2)
            vb = jnp.repeat(vb, group, axis=2)
        s = jnp.einsum("bqhk,bchk->bqhc", q, kb,
                       preferred_element_type=jnp.float32) * scale
        s = softcap(s, cap)
        kv_pos = (ci * kv_chunk
                  + jnp.arange(kv_chunk))[None, None, None, :]
        mask = kv_pos < skv                    # padding
        if causal:
            mask &= kv_pos <= q_pos
        if window:
            mask &= kv_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = _c(jnp.maximum(m, jnp.max(s, axis=-1)))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = _c(l * corr + jnp.sum(p, axis=-1))
        pv = jnp.einsum("bqhc,bchk->bqhk", p.astype(vb.dtype), vb,
                        preferred_element_type=jnp.float32)
        out = _c(out * corr[..., None] + pv)
        return (out, m_new, l_new), None

    out0 = _c(jnp.zeros((b, sq, h, hdv), jnp.float32))
    m0 = _c(jnp.full((b, sq, h), NEG_INF, jnp.float32))
    l0 = _c(jnp.zeros((b, sq, h), jnp.float32))
    (out, _, l), _ = jax.lax.scan(
        step, (out0, m0, l0), (jnp.arange(n_chunks), kc, vc))
    out = out / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype)


def self_attention(x: jax.Array, p: Dict, cfg: ModelConfig,
                   policy: ShardingPolicy, *, local: bool,
                   causal: bool = True, positions=None) -> jax.Array:
    """Full-sequence self-attention (train / prefill). x: (B, S, d)."""
    q, k, v = _project_qkv(x, x, p, cfg)
    pos = jnp.arange(x.shape[1]) if positions is None else positions
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    q = policy.constrain(q, policy.inner())
    k = policy.constrain(k, policy.inner())
    v = policy.constrain(v, policy.inner())
    window = cfg.sliding_window if local else 0
    out = _blockwise_attn(q, k, v, causal=causal, window=window,
                          cap=cfg.logit_softcap, policy=policy)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


def cross_attention(x: jax.Array, memory: jax.Array, p: Dict,
                    cfg: ModelConfig, policy: ShardingPolicy) -> jax.Array:
    """x: (B, S, d) queries; memory: (B, S_mem, d_mem) keys/values."""
    q, k, v = _project_qkv(x, memory, p, cfg)
    q = policy.constrain(q, policy.inner())
    out = _blockwise_attn(q, k, v, causal=False, window=0, cap=0.0,
                          policy=policy)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


# ---------------------------------------------------------------------------
# Cached decode — split-KV over the model axis
# ---------------------------------------------------------------------------
def init_kv_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype
                  ) -> Dict[str, jax.Array]:
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {"k": jnp.zeros((batch, cache_len, kv, hd), dtype),
            "v": jnp.zeros((batch, cache_len, kv, hd), dtype)}


def spec_kv_cache(policy: ShardingPolicy) -> Dict[str, P]:
    b = policy.cache_batch_axes
    # sequence dim sharded over model => flash-decoding split-KV
    return {"k": P(b, MODEL_AXIS, None, None),
            "v": P(b, MODEL_AXIS, None, None)}


def decode_self_attention(x: jax.Array, cache: Dict, pos: jax.Array, p: Dict,
                          cfg: ModelConfig, policy: ShardingPolicy, *,
                          local: bool) -> Tuple[jax.Array, Dict]:
    """One-token decode. x: (B, 1, d); cache k/v: (B, L, KV, hd); pos: ().

    For ``local`` (sliding-window) layers the cache is a ring buffer of
    length ``window`` — the 524k-context configs never materialize a 524k
    cache for windowed layers.
    """
    b, _, d = x.shape
    cache_len = cache["k"].shape[1]
    q, k_new, v_new = _project_qkv(x, x, p, cfg)
    q = apply_rope(q, pos[None], cfg.rope_theta)
    k_new = apply_rope(k_new, pos[None], cfg.rope_theta)

    slot = jnp.mod(pos, cache_len)        # ring semantics (identity if full)
    k = jax.lax.dynamic_update_slice(
        cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(
        cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
    new_cache = {"k": k, "v": v}

    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    h = cfg.num_heads
    group = h // kvh
    qg = q.reshape(b, kvh, group, hd)
    s = jnp.einsum("bhgk,bthk->bhgt", qg, k,
                   preferred_element_type=jnp.float32) * hd ** -0.5
    s = softcap(s, cfg.logit_softcap)
    # valid-position mask: prefix until the cache wraps, then every slot
    # holds one of the last `cache_len` tokens (ring; local layers only —
    # full-attention caches are sized so pos < cache_len always).
    idx = jnp.arange(cache_len)[None, None, None, :]
    valid = (idx <= pos) | (jnp.asarray(pos) >= cache_len)
    s = jnp.where(valid, s, NEG_INF)
    # softmax + value contraction reduce over the model-sharded t dim
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgt,bthk->bhgk", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, h, hd).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return y, new_cache


def init_cross_cache(cfg: ModelConfig, memory: jax.Array, p: Dict
                     ) -> Dict[str, jax.Array]:
    """Precompute cross-attention K/V once per request (decode)."""
    k = jnp.einsum("btd,dhk->bthk", memory, p["wk"],
                   preferred_element_type=jnp.float32).astype(memory.dtype)
    v = jnp.einsum("btd,dhk->bthk", memory, p["wv"],
                   preferred_element_type=jnp.float32).astype(memory.dtype)
    if cfg.qk_norm:
        k = common.rmsnorm(k, p["k_norm"], cfg.norm_eps)
    return {"k": k, "v": v}


def decode_cross_attention(x: jax.Array, cross_cache: Dict, p: Dict,
                           cfg: ModelConfig) -> jax.Array:
    b = x.shape[0]
    kvh, hd, h = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.num_heads
    group = h // kvh
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if cfg.qk_norm:
        q = common.rmsnorm(q, p["q_norm"], cfg.norm_eps)
    qg = q.reshape(b, kvh, group, hd)
    s = jnp.einsum("bhgk,bthk->bhgt", qg, cross_cache["k"],
                   preferred_element_type=jnp.float32) * hd ** -0.5
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgt,bthk->bhgk", w.astype(x.dtype), cross_cache["v"],
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, h, hd).astype(x.dtype)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"],
                      preferred_element_type=jnp.float32).astype(x.dtype)
