"""Model zoo: pattern-driven transformer/SSM/hybrid stacks (DESIGN §3).

Public surface:
    transformer.init_params / param_specs / forward / encode
    decode.init_cache / cache_specs / prefill_cross / decode_step
"""
from repro.models import (attention, common, decode, mla, moe, rglru, ssm,
                          transformer)  # noqa: F401
