"""Mamba-1 selective SSM block (falcon-mamba, arXiv:2410.05355 / 2312.00752).

The selective scan is a *linear* recurrence in h:
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t * x_t,   y_t = C_t . h_t + D x_t
so training/prefill uses ``jax.lax.associative_scan`` over the sequence
(log-depth, TPU-friendly) and decode carries an O(1) (B, d_inner, n) state —
this is what makes ``long_500k`` native for this arch (DESIGN §5).

The channel dimension ``d_inner`` is sharded over ``model``; the recurrence
is elementwise in channels so the scan needs NO cross-device communication —
the paper's 'ship statistics, not data' discipline applied to channels.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import KeyGen, MODEL_AXIS, dense_init


def dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    dt_rank = s.dt_rank or math.ceil(cfg.d_model / 16)
    return d_inner, dt_rank, s.state_dim


def init_ssm(kg: KeyGen, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    d_inner, dt_rank, n = dims(cfg)
    conv_k = cfg.ssm.conv_kernel
    # S4D-real initialization for A
    a_init = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32),
                              (d_inner, n))
    dt = jnp.exp(jax.random.uniform(kg(), (d_inner,), jnp.float32)
                 * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    inv_softplus = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "in_proj": dense_init(kg(), (d, 2 * d_inner), dtype, in_axis=0),
        "conv_w": (jax.random.normal(kg(), (conv_k, d_inner), jnp.float32)
                   * (1.0 / math.sqrt(conv_k))).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": dense_init(kg(), (d_inner, dt_rank + 2 * n), dtype,
                             in_axis=0),
        "dt_proj": dense_init(kg(), (dt_rank, d_inner), dtype, in_axis=0),
        "dt_bias": inv_softplus.astype(jnp.float32),
        "a_log": jnp.log(a_init),                     # f32 master copy
        "d_skip": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(kg(), (d_inner, d), dtype, in_axis=0),
    }


def spec_ssm(cfg: ModelConfig) -> Dict:
    return {
        "in_proj": P(None, MODEL_AXIS),
        "conv_w": P(None, MODEL_AXIS),
        "conv_b": P(MODEL_AXIS),
        "x_proj": P(MODEL_AXIS, None),
        "dt_proj": P(None, MODEL_AXIS),
        "dt_bias": P(MODEL_AXIS),
        "a_log": P(MODEL_AXIS, None),
        "d_skip": P(MODEL_AXIS),
        "out_proj": P(MODEL_AXIS, None),
    }


def _ssm_inner(xz: jax.Array, p: Dict, cfg: ModelConfig,
               conv_state: jax.Array | None = None):
    """Everything after in_proj. xz: (B, S, 2*d_inner)."""
    d_inner, dt_rank, n = dims(cfg)
    x, z = jnp.split(xz, 2, axis=-1)                  # (B, S, di)

    # causal depthwise conv over seq
    k = cfg.ssm.conv_kernel
    if conv_state is None:
        x_pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        x_pad = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    x_conv = sum(x_pad[:, i:i + x.shape[1]] * p["conv_w"][i]
                 for i in range(k))
    x_conv = jax.nn.silu(x_conv + p["conv_b"])

    proj = jnp.einsum("bsd,dr->bsr", x_conv, p["x_proj"],
                      preferred_element_type=jnp.float32)
    dt_low, b_mat, c_mat = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_low.astype(x.dtype), p["dt_proj"],
                   preferred_element_type=jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])                          # (di, n)
    decay = jnp.exp(dt[..., None] * a)                # (B, S, di, n)
    drive = (dt * x_conv.astype(jnp.float32))[..., None] * b_mat[:, :, None, :]
    new_tail = x_pad[:, x_pad.shape[1] - (k - 1):]    # next conv state
    return x_conv, z, decay, drive, c_mat, new_tail


def ssm_block(x: jax.Array, p: Dict, cfg: ModelConfig,
              policy) -> jax.Array:
    """Full-sequence selective scan. x: (B, S, d) -> (B, S, d)."""
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    xz = policy.constrain(xz, policy.inner())
    x_conv, z, decay, drive, c_mat, _ = _ssm_inner(xz, p, cfg)

    # h_t = decay_t * h_{t-1} + drive_t  — associative over S
    def combine(a, b):
        (da, ha), (db, hb) = a, b
        return da * db, hb + db * ha

    _, h = jax.lax.associative_scan(combine, (decay, drive), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", h, c_mat,
                   preferred_element_type=jnp.float32)
    y = y + p["d_skip"] * x_conv.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> Dict:
    d_inner, _, n = dims(cfg)
    k = cfg.ssm.conv_kernel
    return {"h": jnp.zeros((batch, d_inner, n), jnp.float32),
            "conv": jnp.zeros((batch, k - 1, d_inner), dtype)}


def spec_ssm_cache(policy) -> Dict:
    b = policy.cache_batch_axes
    return {"h": P(b, MODEL_AXIS, None), "conv": P(b, None, MODEL_AXIS)}


def decode_ssm_block(x: jax.Array, cache: Dict, p: Dict, cfg: ModelConfig,
                     policy) -> Tuple[jax.Array, Dict]:
    """One-token step. x: (B, 1, d); cache: {'h': (B, di, n), 'conv': ...}."""
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    x_conv, z, decay, drive, c_mat, tail = _ssm_inner(
        xz, p, cfg, conv_state=cache["conv"])
    h = decay[:, 0] * cache["h"] + drive[:, 0]        # (B, di, n)
    y = jnp.einsum("bdn,bn->bd", h, c_mat[:, 0],
                   preferred_element_type=jnp.float32)
    y = y + p["d_skip"] * x_conv[:, 0].astype(jnp.float32)
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out[:, None], {"h": h, "conv": tail.astype(cache["conv"].dtype)}
