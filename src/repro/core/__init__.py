# The paper's primary contribution: distributed sub-cluster split/merge
# DPMM sampling. See DESIGN.md §2-§6 for the TPU adaptation.
from repro.core.family import (ComponentFamily, available_families,  # noqa: F401
                               get_family, register_family)
from repro.core.sampler import DPMM, FitResult, dpmm_step  # noqa: F401
from repro.core.state import ModelState, PointState  # noqa: F401
