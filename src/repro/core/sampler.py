"""Top-level distributed DPMM sampler — the paper's `fit` entry point.

Composition per iteration (paper §4.1):
    restricted Gibbs sweep  ->  splits  ->  merges  ->  stats consistency
with splits/merges gated by ``burnout``. Observation models are
``ComponentFamily`` instances looked up from the registry (core/family.py)
by ``cfg.component`` — the sampler never inspects param/stat pytrees
itself.

Two data planes share every sampling body (core/gibbs.py,
core/splitmerge.py — the split is model-side O(K) math vs per-point tile
bodies):

 - **Resident** (``cfg.tile_size is None`` and the source is resident):
   points are device-resident; ``cfg.log_every`` iterations run inside one
   jitted, buffer-donated ``lax.scan`` chunk that carries the
   (ModelState, PointState) pair and collects ``summarize()`` history on
   device, so the host blocks once per chunk — no O(iters) round-trips.
 - **Tiled / out-of-core** (``cfg.tile_size`` set, or a non-resident
   ``DataSource``): only ModelState persists on device. Points stream
   through fixed-size tiles pulled from the ``DataSource``
   (data/source.py) with double-buffered ``jax.device_put``; per-point
   labels live in host arrays and ride along with their tile. Device
   memory is O(K_max + tile), so N is bounded by host storage, not HBM.

Because per-point randomness is counter-based on the *global* point index
and suff-stats fold in fixed STATS_BLOCK-aligned blocks (core/gibbs.py),
the two planes produce bitwise-identical chains — tile size, like shard
count, is a pure performance knob.

Example (paper §3.4.1 analogue):
    >>> from repro.core.sampler import DPMM
    >>> from repro.configs import DPMMConfig
    >>> model = DPMM(DPMMConfig(alpha=10., iters=100))
    >>> result = model.fit(x)          # x: (N, d) np.ndarray or DataSource
    >>> result.labels, result.k, result.nmi(gt)
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import DPMMConfig
from repro.core import gibbs, splitmerge
from repro.core.distributed import (data_axes_of, make_data_mesh,
                                    n_data_shards, shard_map, shard_points,
                                    tile_plan)
from repro.core.family import (ComponentFamily, get_family,
                               state_partition_specs)
from repro.core.metrics import ari, nmi
from repro.core.state import ModelState, PointState
from repro.data.source import DataSource, as_source

_HIST_KEYS = ("k", "max_cluster", "min_cluster")


def _init_local(key, x, valid, *, prior, family, cfg, axes, k_max,
                feat_axis=None) -> Tuple[ModelState, PointState]:
    """Initial state (runs under shard_map), whole shard as one tile."""
    n_local = x.shape[0]
    gidx = gibbs.global_indices(n_local, axes)
    labels = _init_labels(gidx, cfg.init_clusters)
    # first pass for cluster means, then hyperplane sub-label init
    stats0, _ = gibbs.compute_stats(
        family, x, valid, labels, jnp.zeros_like(labels), k_max, axes,
        feat_axis, cfg.use_pallas)
    means0 = family.cluster_means(stats0)
    v0 = splitmerge.hyperplane_vecs(
        jax.random.fold_in(key, 1), k_max, means0.shape[1], x.dtype)
    sublabels = splitmerge.hyperplane_bits(x, labels, means0, v0, feat_axis)
    stats, substats = gibbs.compute_stats(
        family, x, valid, labels, sublabels, k_max, axes, feat_axis,
        cfg.use_pallas)
    return (_init_model(key, stats, substats, prior=prior, family=family,
                        cfg=cfg, k_max=k_max),
            PointState(labels=labels, sublabels=sublabels, valid=valid))


def _init_labels(gidx: jax.Array, init_clusters: int) -> jax.Array:
    return (gidx % jnp.uint32(init_clusters)).astype(jnp.int32)


def _init_model(key, stats, substats, *, prior, family, cfg,
                k_max) -> ModelState:
    """Replicated O(K) half of initialization, given the initial stats."""
    active = jnp.arange(k_max) < cfg.init_clusters
    params = family.expected_params(prior, stats)
    subparams = family.expected_params(prior, substats)
    # strong dtypes: weak-typed leaves would force a second trace/compile of
    # the chunk fn on its own (strongly-typed) output state
    logw = jnp.where(active, -jnp.log(float(cfg.init_clusters)),
                     gibbs.NEG_INF).astype(jnp.float32)
    sublogw = jnp.full((k_max, 2), jnp.log(0.5), dtype=jnp.float32)
    return ModelState(
        key=key, it=jnp.zeros((), jnp.int32), active=active,
        logweights=logw, sub_logweights=sublogw,
        stuck=jnp.zeros((k_max,), jnp.int32), params=params,
        subparams=subparams, stats=stats, substats=substats)


def _move_key(model: ModelState) -> jax.Array:
    """Per-iteration split/merge key (negative fold: disjoint from the
    sweep's fold_in(key, it) stream)."""
    return jax.random.fold_in(model.key, -(model.it + 1))


def _split_merge(model: ModelState, point: PointState, x, *, prior, family,
                 cfg, axes, k_max, feat_axis=None
                 ) -> Tuple[ModelState, PointState]:
    """Resident split/merge: plan (O(K)), one whole-shard tile, finalize."""
    plan = splitmerge.plan_split_merge(
        _move_key(model), model, prior, family, cfg.alpha,
        cfg.subreset_every)
    acc = gibbs.empty_substats(family, k_max, x.shape[-1])
    point, acc = splitmerge.split_merge_tile(
        plan, x, point, acc, family, use_pallas=cfg.use_pallas,
        feat_axis=feat_axis)
    # consistency pass (paper §4.4: 'processing accepted splits/merges
    # requires updating the sufficient statistics', O(N/G) + one psum)
    stats3, substats3 = gibbs.finalize_substats(family, acc, axes, feat_axis)
    model = model._replace(active=plan.merge.new_active, stuck=plan.stuck,
                           stats=stats3, substats=substats3)
    return model, point


def dpmm_step(model: ModelState, point: PointState, x, *, prior, family,
              cfg, axes, k_max, feat_axis=None
              ) -> Tuple[ModelState, PointState]:
    """One full iteration; designed to run under shard_map."""
    model, point = gibbs.sweep(model, point, x, prior, family, cfg.alpha,
                               axes, use_pallas=cfg.use_pallas,
                               feat_axis=feat_axis)
    model, point = jax.lax.cond(
        model.it >= cfg.burnout,
        lambda mp: _split_merge(*mp, x, prior=prior, family=family,
                                cfg=cfg, axes=axes, k_max=k_max,
                                feat_axis=feat_axis),
        lambda mp: mp,
        (model, point))
    return model._replace(it=model.it + 1), point


def _tree_bytes(tree: Any) -> int:
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(tree)
               if hasattr(l, "shape"))


@dataclasses.dataclass
class FitResult:
    state: ModelState            # final replicated model-side state
    labels: np.ndarray           # (N,) cluster assignments (unpadded)
    k: int
    history: Dict[str, np.ndarray]
    iter_times_s: List[float]
    # accounting of what the fit kept device-resident (see README
    # 'Memory model'): est_peak_bytes is the analytic per-run peak over
    # persistent device buffers; peak_bytes_in_use is the measured peak —
    # device.memory_stats() where the backend reports it, else the
    # process's peak RSS — with its origin in peak_bytes_source.
    device_bytes: Optional[Dict[str, Any]] = None

    def nmi(self, true_labels: np.ndarray, n_true: Optional[int] = None):
        n_true = n_true or int(true_labels.max()) + 1
        k_max = int(self.state.active.shape[0])
        return float(nmi(jnp.asarray(true_labels),
                         jnp.asarray(self.labels), n_true, k_max))

    def ari(self, true_labels: np.ndarray, n_true: Optional[int] = None):
        n_true = n_true or int(true_labels.max()) + 1
        k_max = int(self.state.active.shape[0])
        return float(ari(jnp.asarray(true_labels),
                         jnp.asarray(self.labels), n_true, k_max))


def _measured_peak() -> Tuple[Optional[int], str]:
    """(peak bytes, source): the backend's ``peak_bytes_in_use`` where
    ``device.memory_stats()`` reports it (TPU/GPU), else the process's
    peak RSS (``ru_maxrss``; on CPU the 'device' IS host memory) — so
    memory claims are measurable everywhere. RSS is a process-lifetime
    high-water mark that includes host-side buffers and cannot be reset
    between fits; the source is recorded next to the number so consumers
    (FitResult.device_bytes, BENCH_*.json) can tell which they got.
    """
    stats = jax.local_devices()[0].memory_stats() or {}
    peak = stats.get("peak_bytes_in_use")
    if peak is not None:
        return int(peak), "device.memory_stats"
    try:
        import resource
        rss_kib = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        return int(rss_kib) * 1024, "process_peak_rss"
    except Exception:                         # non-POSIX: no measurement
        return None, "unavailable"


class DPMM:
    """Distributed DPMM with sub-cluster splits (paper [1] + this paper)."""

    def __init__(self, cfg: DPMMConfig, mesh: Optional[Mesh] = None):
        self.cfg = cfg
        self.mesh = mesh
        self.family: ComponentFamily = get_family(cfg.component)

    def fit(self, x, iters: Optional[int] = None,
            verbose: bool = False) -> FitResult:
        """Fit to ``x``: an (N, d) array (resident fast path) or any
        ``DataSource`` (e.g. ``HostTiledSource`` over an np.memmap for
        out-of-core data). ``cfg.tile_size`` forces the tiled plane even
        for resident arrays — chains are bitwise identical either way."""
        source = as_source(x)
        iters = iters if iters is not None else self.cfg.iters
        if self.cfg.tile_size is None and source.resident() is not None:
            return self._fit_resident(source, iters, verbose)
        return self._fit_tiled(source, iters, verbose)

    def _setup(self, source: DataSource):
        cfg = self.cfg
        family = self.family
        mesh = self.mesh if self.mesh is not None else make_data_mesh()
        axes = data_axes_of(mesh)
        # the prior's data-dependent part is the column mean, computed
        # once by the source's canonical streaming pass — identical for
        # resident and out-of-core modes (data/source.py)
        prior = family.build_prior(cfg, source.column_mean()[None, :])
        want_feat_shard = cfg.shard_features and family.feature_shardable
        feat_axis = ("model" if (want_feat_shard
                                 and "model" in mesh.axis_names)
                     else None)
        kwargs = dict(prior=prior, family=family, cfg=cfg, axes=axes,
                      k_max=cfg.k_max, feat_axis=feat_axis)
        return mesh, axes, feat_axis, kwargs

    # ------------------------------------------------------------------
    # Resident plane: device-resident points, chunked on-device scan
    # ------------------------------------------------------------------
    def _fit_resident(self, source: DataSource, iters: int,
                      verbose: bool) -> FitResult:
        cfg = self.cfg
        mesh, axes, feat_axis, kwargs = self._setup(source)
        x = source.resident()
        n = x.shape[0]
        # non-separable families keep features replicated even when
        # shard_features is requested (family.feature_shardable contract)
        xs, valid = shard_points(mesh, x, feat_axis is not None)
        shard_spec = P(axes)
        x_in_spec = P(axes, feat_axis)
        rep = P()
        state_specs = state_partition_specs(self.family, shard_spec)

        init = jax.jit(shard_map(
            functools.partial(_init_local, **kwargs), mesh=mesh,
            in_specs=(rep, x_in_spec, shard_spec), out_specs=state_specs))

        def make_chunk(length: int):
            """`length` iterations in one jitted call, history on device.

            The scan carries the (model, point) state pair; per-step
            host-visible output is only the O(1) ``summarize()`` scalars.
            State buffers are donated, so chunk i+1 reuses chunk i's
            memory.
            """
            def run(model, point, x):
                def body(mp, _):
                    m, p = dpmm_step(*mp, x, **kwargs)
                    return (m, p), m.summarize()
                return jax.lax.scan(body, (model, point), None,
                                    length=length)
            hist_specs = {k: rep for k in _HIST_KEYS}
            return jax.jit(
                shard_map(run, mesh=mesh,
                          in_specs=(*state_specs, x_in_spec),
                          out_specs=(state_specs, hist_specs)),
                donate_argnums=(0, 1))

        key = jax.random.key(cfg.seed)
        model, point = init(key, xs, valid)

        chunk = max(1, cfg.log_every)
        lengths = [chunk] * (iters // chunk)
        if iters % chunk:
            lengths.append(iters % chunk)   # one shorter trailing chunk
        chunk_fns: Dict[int, Any] = {}
        hist_chunks: List[Dict[str, np.ndarray]] = []
        times: List[float] = []
        done = 0
        for length in lengths:
            if length not in chunk_fns:
                # AOT-compile outside the timed region so jit compile time
                # (seconds) never contaminates iter_times_s / benchmarks.
                # At most two compiles per fit: `log_every` + one trailing
                # remainder length.
                chunk_fns[length] = make_chunk(length).lower(
                    model, point, xs).compile()
            t0 = time.perf_counter()
            (model, point), hist = chunk_fns[length](model, point, xs)
            hist = jax.device_get(hist)       # the one host sync per chunk
            dt = time.perf_counter() - t0
            times.extend([dt / length] * length)
            hist_chunks.append(hist)
            done += length
            if verbose:
                print(f"iter {done:4d}  K={int(hist['k'][-1])}  "
                      f"{dt / length * 1e3:.1f} ms/iter")
        history = {
            k: (np.concatenate([h[k] for h in hist_chunks])
                if hist_chunks else np.zeros((0,)))
            for k in _HIST_KEYS}
        labels = np.asarray(jax.device_get(point.labels))[:n]
        peak, peak_src = _measured_peak()
        device_bytes = {
            "mode": "resident",
            "est_peak_bytes": (_tree_bytes(xs) + _tree_bytes(valid)
                               + 2 * _tree_bytes(point)
                               + 2 * _tree_bytes(model)),
            "peak_bytes_in_use": peak,
            "peak_bytes_source": peak_src,
        }
        return FitResult(
            state=model, labels=labels, k=int(model.k_hat),
            history=history, iter_times_s=times, device_bytes=device_bytes)

    # ------------------------------------------------------------------
    # Tiled plane: out-of-core points streamed under a resident ModelState
    # ------------------------------------------------------------------
    def _fit_tiled(self, source: DataSource, iters: int,
                   verbose: bool) -> FitResult:
        cfg = self.cfg
        family = self.family
        mesh, axes, feat_axis, kwargs = self._setup(source)
        prior = kwargs["prior"]
        k_max = cfg.k_max
        n, d = source.n, source.d
        shards = n_data_shards(mesh)
        n_local, tiles = tile_plan(n, shards, cfg.tile_size)
        if shards * n_local >= 2 ** 32:
            # >=, not >: at exactly 2**32 rows jnp.uint32(n) wraps to 0 in
            # the tile validity mask, which would silently zero all stats
            raise ValueError(
                f"N={n} ({shards * n_local} rows padded) exceeds the "
                "uint32 global point-index space: counter-based draws "
                "would wrap and silently corrupt the chain. Shard the fit "
                "across processes, or widen kernels/prng counters to "
                "uint64 first.")
        use_pallas = cfg.use_pallas

        model_specs, _ = state_partition_specs(family, P(axes))
        x_spec = P(axes, feat_axis)
        rep = P()

        # ---- the per-shard suff-stat accumulator: leading shard axis ----
        # built at full feature width; feature-sliced fields are sharded
        # over the model axis so each device's local slice matches the
        # local width its stats_from_labels partials produce
        acc_shape = jax.eval_shape(
            lambda: gibbs.empty_substats(family, k_max, d))
        feat_fields = set(family.feature_stat_fields if feat_axis else ())

        def leaf_spec(field, leaf):
            dims = [axes] + [None] * leaf.ndim
            if field in feat_fields:
                dims[-1] = feat_axis
            return P(*dims)

        acc_specs = type(acc_shape)(**{
            f: leaf_spec(f, getattr(acc_shape, f))
            for f in acc_shape._fields})

        zeros_acc = jax.jit(
            lambda: type(acc_shape)(**{
                f: jnp.zeros((shards,) + getattr(acc_shape, f).shape,
                             jnp.float32)
                for f in acc_shape._fields}),
            out_shardings=type(acc_shape)(**{
                f: NamedSharding(mesh, getattr(acc_specs, f))
                for f in acc_shape._fields}))

        local = lambda acc: jax.tree.map(lambda v: v[0], acc)
        delocal = lambda acc: jax.tree.map(lambda v: v[None], acc)

        # ---- host-side point state and tile transfer ------------------
        labels_h = np.zeros((shards * n_local,), np.int32)
        sublabels_h = np.zeros((shards * n_local,), np.int32)
        x_sharding = NamedSharding(mesh, x_spec)
        i32_sharding = NamedSharding(mesh, P(axes))

        def put_x_tile(off: int, length: int):
            rows = np.concatenate(
                [source.read_block(s * n_local + off,
                                   s * n_local + off + length)
                 for s in range(shards)], axis=0)
            return jax.device_put(rows, x_sharding)

        def put_label_tile(host, off: int, length: int):
            rows = np.concatenate(
                [host[s * n_local + off:s * n_local + off + length]
                 for s in range(shards)])
            return jax.device_put(rows, i32_sharding)

        def write_back(host, off: int, length: int, tile_out):
            rows = np.asarray(jax.device_get(tile_out))
            for s in range(shards):
                host[s * n_local + off:s * n_local + off + length] = (
                    rows[s * length:(s + 1) * length])

        def stream(pass_fn, carry, point_pass: bool):
            """Run ``pass_fn`` over all tiles with double-buffered
            device_put: tile i+1's transfer is issued right after tile i's
            compute is dispatched (dispatch is async), so it overlaps."""
            def load(i):
                off, length = tiles[i]
                xt = put_x_tile(off, length)
                pt = (put_label_tile(labels_h, off, length),
                      put_label_tile(sublabels_h, off, length)
                      ) if point_pass else None
                return xt, pt
            buf = load(0)
            for i, (off, length) in enumerate(tiles):
                xt, pt = buf
                out, carry = pass_fn(i, off, length, xt, pt, carry)
                if i + 1 < len(tiles):
                    buf = load(i + 1)       # overlaps the dispatched compute
                if out is not None:
                    lab_t, sub_t = out
                    write_back(labels_h, off, length, lab_t)
                    write_back(sublabels_h, off, length, sub_t)
            return carry

        # ---- jitted bodies (compiled once per distinct tile length) ----
        def tile_point(pt, off, length, x_t):
            lab, sub = pt
            gidx = gibbs.global_indices(n_local, axes, offset=off,
                                        length=length)
            valid = (gidx < jnp.uint32(n)).astype(x_t.dtype)
            return PointState(labels=lab, sublabels=sub, valid=valid), gidx

        def _sweep_tile(model, x_t, lab, sub, off, acc):
            point, gidx = tile_point((lab, sub), off, x_t.shape[0], x_t)
            point, a = gibbs.sweep_tile(model, x_t, point, gidx, local(acc),
                                        family, use_pallas=use_pallas,
                                        feat_axis=feat_axis)
            return (point.labels, point.sublabels), delocal(a)

        def _sm_tile(plan, x_t, lab, sub, off, acc):
            point, _ = tile_point((lab, sub), off, x_t.shape[0], x_t)
            point, a = splitmerge.split_merge_tile(
                plan, x_t, point, local(acc), family,
                use_pallas=use_pallas, feat_axis=feat_axis)
            return (point.labels, point.sublabels), delocal(a)

        def _init1_tile(x_t, off, acc):
            gidx = gibbs.global_indices(n_local, axes, offset=off,
                                        length=x_t.shape[0])
            labels = _init_labels(gidx, cfg.init_clusters)
            valid = (gidx < jnp.uint32(n)).astype(x_t.dtype)
            a = gibbs.accumulate_substats(
                family, x_t, valid, labels, jnp.zeros_like(labels), k_max,
                local(acc), use_pallas)
            return (labels, jnp.zeros_like(labels)), delocal(a)

        def _init2_tile(means0, v0, x_t, lab, sub, off, acc):
            point, gidx = tile_point((lab, sub), off, x_t.shape[0], x_t)
            sublabels = splitmerge.hyperplane_bits(x_t, point.labels,
                                                   means0, v0, feat_axis)
            a = gibbs.accumulate_substats(
                family, x_t, point.valid, point.labels, sublabels, k_max,
                local(acc), use_pallas)
            return (point.labels, sublabels), delocal(a)

        def _finalize(acc):
            return gibbs.finalize_substats(family, local(acc), axes,
                                           feat_axis)

        lab_specs = (P(axes), P(axes))
        smap = functools.partial(shard_map, mesh=mesh)
        sweep_tile_fn = jax.jit(smap(
            _sweep_tile, in_specs=(model_specs, x_spec, *lab_specs, rep,
                                   acc_specs),
            out_specs=(lab_specs, acc_specs)))
        sm_tile_fn = None     # built lazily: needs the plan's pytree specs
        finalize_fn = jax.jit(smap(
            _finalize, in_specs=(acc_specs,), out_specs=(rep, rep)))
        init1_fn = jax.jit(smap(
            _init1_tile, in_specs=(x_spec, rep, acc_specs),
            out_specs=(lab_specs, acc_specs)))

        sweep_model_fn = jax.jit(functools.partial(
            gibbs.sweep_model, prior=prior, family=family, alpha=cfg.alpha))
        plan_fn = jax.jit(lambda m: splitmerge.plan_split_merge(
            _move_key(m), m, prior, family, cfg.alpha, cfg.subreset_every))
        advance_fn = jax.jit(
            lambda m: (m._replace(it=m.it + 1), m.summarize()))

        # ---- initialization: two streamed passes ----------------------
        key = jax.random.key(cfg.seed)
        acc = zeros_acc()
        acc = stream(
            lambda i, off, length, xt, pt, a:
                init1_fn(xt, np.uint32(off), a),
            acc, point_pass=False)
        stats0, _ = finalize_fn(acc)
        means0 = jax.jit(family.cluster_means)(stats0)
        v0 = jax.jit(functools.partial(
            splitmerge.hyperplane_vecs, k_max=k_max, d=d,
            dtype=jnp.float32))(jax.random.fold_in(key, 1))
        _init2 = jax.jit(smap(
            _init2_tile, in_specs=(rep, rep, x_spec, *lab_specs, rep,
                                   acc_specs),
            out_specs=(lab_specs, acc_specs)))
        acc = zeros_acc()
        acc = stream(
            lambda i, off, length, xt, pt, a:
                _init2(means0, v0, xt, *pt, np.uint32(off), a),
            acc, point_pass=True)
        stats, substats = finalize_fn(acc)
        model = jax.jit(functools.partial(
            _init_model, prior=prior, family=family, cfg=cfg,
            k_max=k_max))(key, stats, substats)

        # ---- iteration loop: ModelState is the only persistent state ---
        set_stats_fn = jax.jit(
            lambda m, s, ss: m._replace(stats=s, substats=ss))
        apply_plan_fn = jax.jit(
            lambda m, plan, s, ss: m._replace(
                active=plan.merge.new_active, stuck=plan.stuck,
                stats=s, substats=ss))

        hist_rows: List[Dict[str, np.ndarray]] = []
        times: List[float] = []
        # persistent device buffers: double-buffered (x + label) tiles,
        # the model (x2: pre/post update), and the suff-stat accumulator
        tile_bytes = max(
            length * (d * 4 + 2 * 4) * shards for _, length in tiles)
        est_peak = (2 * _tree_bytes(model) + _tree_bytes(zeros_acc())
                    + 2 * tile_bytes)
        for it in range(iters):
            t0 = time.perf_counter()
            model = sweep_model_fn(model)
            acc = zeros_acc()
            acc = stream(
                lambda i, off, length, xt, pt, a:
                    sweep_tile_fn(model, xt, *pt, np.uint32(off), a),
                acc, point_pass=True)
            model = set_stats_fn(model, *finalize_fn(acc))
            if it >= cfg.burnout:
                plan = plan_fn(model)
                if sm_tile_fn is None:
                    plan_specs = jax.tree.map(lambda _: rep, plan)
                    sm_tile_fn = jax.jit(smap(
                        _sm_tile,
                        in_specs=(plan_specs, x_spec, *lab_specs, rep,
                                  acc_specs),
                        out_specs=(lab_specs, acc_specs)))
                acc = zeros_acc()
                acc = stream(
                    lambda i, off, length, xt, pt, a:
                        sm_tile_fn(plan, xt, *pt, np.uint32(off), a),
                    acc, point_pass=True)
                model = apply_plan_fn(model, plan, *finalize_fn(acc))
            model, summary = advance_fn(model)
            summary = jax.device_get(summary)
            hist_rows.append(summary)
            times.append(time.perf_counter() - t0)
            if verbose:
                print(f"iter {it + 1:4d}  K={int(summary['k'])}  "
                      f"{times[-1] * 1e3:.1f} ms/iter")

        history = {
            k: np.asarray([row[k] for row in hist_rows])
            for k in _HIST_KEYS} if hist_rows else {
            k: np.zeros((0,)) for k in _HIST_KEYS}
        peak, peak_src = _measured_peak()
        device_bytes = {
            "mode": "tiled",
            "tile_size": tiles[0][1],
            "est_peak_bytes": int(est_peak),
            "peak_bytes_in_use": peak,
            "peak_bytes_source": peak_src,
        }
        return FitResult(
            state=model, labels=labels_h[:n].copy(), k=int(model.k_hat),
            history=history, iter_times_s=times, device_bytes=device_bytes)
