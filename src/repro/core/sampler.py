"""Top-level distributed DPMM sampler — the paper's `fit` entry point.

Composition per iteration (paper §4.1):
    restricted Gibbs sweep  ->  splits  ->  merges  ->  stats consistency
with splits/merges gated by ``burnout``. Iterations run inside a single
``shard_map`` over the mesh's data axes; the only cross-device
communication is the psum of sufficient statistics (paper §4.3).

Observation models are ``ComponentFamily`` instances looked up from the
registry (core/family.py) by ``cfg.component`` — the sampler never inspects
param/stat pytrees itself.

The driver is a *chunked on-device scan*: ``cfg.log_every`` iterations of
``dpmm_step`` run inside one jitted, buffer-donated ``lax.scan`` call that
collects ``state.summarize()`` history on device, so the host blocks once
per chunk (``ceil(iters / log_every)`` syncs total) instead of once per
iteration — no O(iters) host round-trips in the hot loop.

Example (paper §3.4.1 analogue):
    >>> from repro.core.sampler import DPMM
    >>> from repro.configs import DPMMConfig
    >>> model = DPMM(DPMMConfig(alpha=10., iters=100))
    >>> result = model.fit(x)          # x: (N, d) np.ndarray
    >>> result.labels, result.k, result.nmi(gt)
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import DPMMConfig
from repro.core import gibbs, splitmerge
from repro.core.distributed import (data_axes_of, make_data_mesh,
                                    shard_map, shard_points)
from repro.core.family import (ComponentFamily, get_family,
                               state_partition_specs)
from repro.core.metrics import ari, nmi
from repro.core.state import DPMMState

_HIST_KEYS = ("k", "max_cluster", "min_cluster")


def _init_local(key, x, valid, *, prior, family, cfg, axes, k_max,
                feat_axis=None):
    """Initial state (runs under shard_map)."""
    n_local = x.shape[0]
    gidx = gibbs.global_indices(n_local, axes)
    labels = (gidx % jnp.uint32(cfg.init_clusters)).astype(jnp.int32)
    # first pass for cluster means, then hyperplane sub-label init
    stats0, _ = gibbs.compute_stats(
        family, x, valid, labels, jnp.zeros_like(labels), k_max, axes,
        feat_axis, cfg.use_pallas)
    sublabels = splitmerge.hyperplane_bits(
        jax.random.fold_in(key, 1), x, labels, family.cluster_means(stats0),
        feat_axis)
    stats, substats = gibbs.compute_stats(
        family, x, valid, labels, sublabels, k_max, axes, feat_axis,
        cfg.use_pallas)
    active = jnp.arange(k_max) < cfg.init_clusters
    params = family.expected_params(prior, stats)
    subparams = family.expected_params(prior, substats)
    # strong dtypes: weak-typed leaves would force a second trace/compile of
    # the chunk fn on its own (strongly-typed) output state
    logw = jnp.where(active, -jnp.log(float(cfg.init_clusters)),
                     gibbs.NEG_INF).astype(jnp.float32)
    sublogw = jnp.full((k_max, 2), jnp.log(0.5), dtype=jnp.float32)
    return DPMMState(
        key=key, it=jnp.zeros((), jnp.int32), active=active,
        logweights=logw, sub_logweights=sublogw,
        stuck=jnp.zeros((k_max,), jnp.int32), params=params,
        subparams=subparams, stats=stats, substats=substats,
        labels=labels, sublabels=sublabels)


def _split_merge(state: DPMMState, x, valid, *, prior, family, cfg, axes,
                 k_max, feat_axis=None) -> DPMMState:
    key = jax.random.fold_in(state.key, -(state.it + 1))
    k_s, k_m, k_b = jax.random.split(key, 3)

    dec_s = splitmerge.propose_splits(k_s, state, prior, family, cfg.alpha)
    stats1 = splitmerge.apply_split_to_stats(
        family, state.stats, state.substats, dec_s)
    # provisional relabel (moves r-halves to their new slots) ...
    labels_mid = jnp.where(
        dec_s.accept[state.labels] & (state.sublabels == 1),
        dec_s.dest[state.labels], state.labels).astype(jnp.int32)
    # ... then hyperplane sub-label init around the *post-split* means
    bits = splitmerge.hyperplane_bits(
        k_b, x, labels_mid, family.cluster_means(stats1), feat_axis)
    labels1, sublabels1 = splitmerge.relabel_after_split(
        state.labels, state.sublabels, dec_s, bits)

    dec_m = splitmerge.propose_merges(
        k_m, dec_s.new_active, stats1, prior, family, cfg.alpha)
    labels2, sublabels2 = splitmerge.relabel_after_merge(
        labels1, sublabels1, dec_m)

    # sub-cluster reset: clusters whose split keeps being rejected re-draw
    # their sub-labels from a fresh hyperplane (escapes sub-Gibbs local
    # modes; the reference DPMMSubClusters does the same). The MH target is
    # untouched — sub-labels are auxiliary proposal state.
    stuck = jnp.where(dec_s.accept | dec_m.merged | ~state.active,
                      0, state.stuck + 1)
    reset = stuck >= cfg.subreset_every
    stuck = jnp.where(reset, 0, stuck).astype(jnp.int32)
    stats2 = splitmerge.apply_merge_to_stats(stats1, dec_m)
    bits2 = splitmerge.hyperplane_bits(
        jax.random.fold_in(k_b, 1), x, labels2, family.cluster_means(stats2),
        feat_axis)
    sublabels2 = jnp.where(reset[labels2], bits2, sublabels2)

    # consistency pass: recompute stats AND substats from the new labels
    # (paper §4.4: 'processing accepted splits/merges requires updating the
    # sufficient statistics', O(N/G) + one psum) — same label-indexed
    # fused/reference stats path as the sweep (family.stats_from_labels)
    stats3, substats3 = gibbs.compute_stats(
        family, x, valid, labels2, sublabels2, k_max, axes, feat_axis,
        cfg.use_pallas)
    return state._replace(
        active=dec_m.new_active, stuck=stuck, stats=stats3,
        substats=substats3, labels=labels2, sublabels=sublabels2)


def dpmm_step(state: DPMMState, x, valid, *, prior, family, cfg, axes,
              k_max, feat_axis=None) -> DPMMState:
    """One full iteration; designed to run under shard_map."""
    state = gibbs.sweep(state, x, valid, prior, family, cfg.alpha, axes,
                        use_pallas=cfg.use_pallas, feat_axis=feat_axis)
    state = jax.lax.cond(
        state.it >= cfg.burnout,
        lambda s: _split_merge(s, x, valid, prior=prior, family=family,
                               cfg=cfg, axes=axes, k_max=k_max,
                               feat_axis=feat_axis),
        lambda s: s,
        state)
    return state._replace(it=state.it + 1)


@dataclasses.dataclass
class FitResult:
    state: DPMMState
    labels: np.ndarray           # (N,) cluster assignments (unpadded)
    k: int
    history: Dict[str, np.ndarray]
    iter_times_s: List[float]

    def nmi(self, true_labels: np.ndarray, n_true: Optional[int] = None):
        n_true = n_true or int(true_labels.max()) + 1
        k_max = int(self.state.active.shape[0])
        return float(nmi(jnp.asarray(true_labels),
                         jnp.asarray(self.labels), n_true, k_max))

    def ari(self, true_labels: np.ndarray, n_true: Optional[int] = None):
        n_true = n_true or int(true_labels.max()) + 1
        k_max = int(self.state.active.shape[0])
        return float(ari(jnp.asarray(true_labels),
                         jnp.asarray(self.labels), n_true, k_max))


class DPMM:
    """Distributed DPMM with sub-cluster splits (paper [1] + this paper)."""

    def __init__(self, cfg: DPMMConfig, mesh: Optional[Mesh] = None):
        self.cfg = cfg
        self.mesh = mesh
        self.family: ComponentFamily = get_family(cfg.component)

    def fit(self, x: np.ndarray, iters: Optional[int] = None,
            verbose: bool = False) -> FitResult:
        cfg = self.cfg
        family = self.family
        iters = iters if iters is not None else cfg.iters
        mesh = self.mesh if self.mesh is not None else make_data_mesh()
        axes = data_axes_of(mesh)
        prior = family.build_prior(cfg, x)
        n = x.shape[0]
        # non-separable families keep features replicated even when
        # shard_features is requested (family.feature_shardable contract)
        want_feat_shard = cfg.shard_features and family.feature_shardable
        xs, valid = shard_points(mesh, np.asarray(x, np.float32),
                                 want_feat_shard)
        feat_axis = ("model" if (want_feat_shard
                                 and "model" in mesh.axis_names)
                     else None)
        kwargs = dict(prior=prior, family=family, cfg=cfg, axes=axes,
                      k_max=cfg.k_max, feat_axis=feat_axis)
        shard_spec = P(axes)
        x_in_spec = P(axes, feat_axis)
        rep = P()
        state_specs = state_partition_specs(family, shard_spec)

        init = jax.jit(shard_map(
            functools.partial(_init_local, **kwargs), mesh=mesh,
            in_specs=(rep, x_in_spec, shard_spec), out_specs=state_specs))

        def make_chunk(length: int):
            """`length` iterations in one jitted call, history on device.

            The scan carries the full sampler state; per-step host-visible
            output is only the O(1) ``summarize()`` scalars. State buffers
            are donated, so chunk i+1 reuses chunk i's memory.
            """
            def run(state, x, valid):
                def body(s, _):
                    s = dpmm_step(s, x, valid, **kwargs)
                    return s, s.summarize()
                return jax.lax.scan(body, state, None, length=length)
            hist_specs = {k: rep for k in _HIST_KEYS}
            return jax.jit(
                shard_map(run, mesh=mesh,
                          in_specs=(state_specs, x_in_spec, shard_spec),
                          out_specs=(state_specs, hist_specs)),
                donate_argnums=(0,))

        key = jax.random.key(cfg.seed)
        state = init(key, xs, valid)

        chunk = max(1, cfg.log_every)
        lengths = [chunk] * (iters // chunk)
        if iters % chunk:
            lengths.append(iters % chunk)   # one shorter trailing chunk
        chunk_fns: Dict[int, Any] = {}
        hist_chunks: List[Dict[str, np.ndarray]] = []
        times: List[float] = []
        done = 0
        for length in lengths:
            if length not in chunk_fns:
                # AOT-compile outside the timed region so jit compile time
                # (seconds) never contaminates iter_times_s / benchmarks.
                # At most two compiles per fit: `log_every` + one trailing
                # remainder length.
                chunk_fns[length] = make_chunk(length).lower(
                    state, xs, valid).compile()
            t0 = time.perf_counter()
            state, hist = chunk_fns[length](state, xs, valid)
            hist = jax.device_get(hist)       # the one host sync per chunk
            dt = time.perf_counter() - t0
            times.extend([dt / length] * length)
            hist_chunks.append(hist)
            done += length
            if verbose:
                print(f"iter {done:4d}  K={int(hist['k'][-1])}  "
                      f"{dt / length * 1e3:.1f} ms/iter")
        history = {
            k: (np.concatenate([h[k] for h in hist_chunks])
                if hist_chunks else np.zeros((0,)))
            for k in _HIST_KEYS}
        labels = np.asarray(jax.device_get(state.labels))[:n]
        return FitResult(
            state=state, labels=labels, k=int(state.k_hat),
            history=history, iter_times_s=times)
