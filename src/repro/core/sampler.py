"""Top-level distributed DPMM sampler — the paper's `fit` entry point.

Composition per iteration (paper §4.1):
    restricted Gibbs sweep  ->  splits  ->  merges  ->  stats consistency
with splits/merges gated by ``burnout``. The whole iteration runs inside a
single ``shard_map`` over the mesh's data axes; the only communication is
the psum of sufficient statistics (paper §4.3).

Example (paper §3.4.1 analogue):
    >>> from repro.core.sampler import DPMM
    >>> from repro.configs import DPMMConfig
    >>> model = DPMM(DPMMConfig(alpha=10., iters=100))
    >>> result = model.fit(x)          # x: (N, d) np.ndarray
    >>> result.labels, result.k, result.nmi(gt)
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import DPMMConfig
from repro.core import gibbs, multinomial, niw, poisson, splitmerge
from repro.core.distributed import data_axes_of, make_data_mesh, shard_points
from repro.core.metrics import ari, nmi
from repro.core.state import DPMMState


def component_module(name: str):
    if name == "gaussian":
        return niw
    if name == "multinomial":
        return multinomial
    if name == "poisson":
        return poisson
    raise ValueError(f"unknown component {name!r}")


def _cluster_means(comp, stats):
    first = stats.sx if hasattr(stats, "sx") else stats.counts
    return first / jnp.maximum(stats.n[..., None], 1.0)


def _init_local(key, x, valid, *, prior, comp, cfg, axes, k_max,
                feat_axis=None):
    """Initial state (runs under shard_map)."""
    n_local = x.shape[0]
    gidx = gibbs.global_indices(n_local, axes)
    labels = (gidx % jnp.uint32(cfg.init_clusters)).astype(jnp.int32)
    # first pass for cluster means, then hyperplane sub-label init
    stats0, _ = gibbs.compute_stats(
        comp, x, valid, labels, jnp.zeros_like(labels), k_max, axes,
        feat_axis)
    sublabels = splitmerge.hyperplane_bits(
        jax.random.fold_in(key, 1), x, labels, _cluster_means(comp, stats0),
        feat_axis)
    stats, substats = gibbs.compute_stats(
        comp, x, valid, labels, sublabels, k_max, axes, feat_axis)
    active = jnp.arange(k_max) < cfg.init_clusters
    params = comp.expected_params(prior, stats)
    subparams = comp.expected_params(prior, substats)
    logw = jnp.where(active, -jnp.log(float(cfg.init_clusters)), gibbs.NEG_INF)
    sublogw = jnp.full((k_max, 2), jnp.log(0.5))
    return DPMMState(
        key=key, it=jnp.zeros((), jnp.int32), active=active,
        logweights=logw, sub_logweights=sublogw,
        stuck=jnp.zeros((k_max,), jnp.int32), params=params,
        subparams=subparams, stats=stats, substats=substats,
        labels=labels, sublabels=sublabels)


def _split_merge(state: DPMMState, x, valid, *, prior, comp, cfg, axes,
                 k_max, feat_axis=None) -> DPMMState:
    key = jax.random.fold_in(state.key, -(state.it + 1))
    k_s, k_m, k_b = jax.random.split(key, 3)

    dec_s = splitmerge.propose_splits(k_s, state, prior, comp, cfg.alpha)
    stats1 = splitmerge.apply_split_to_stats(
        comp, state.stats, state.substats, dec_s)
    # provisional relabel (moves r-halves to their new slots) ...
    labels_mid = jnp.where(
        dec_s.accept[state.labels] & (state.sublabels == 1),
        dec_s.dest[state.labels], state.labels).astype(jnp.int32)
    # ... then hyperplane sub-label init around the *post-split* means
    bits = splitmerge.hyperplane_bits(
        k_b, x, labels_mid, _cluster_means(comp, stats1), feat_axis)
    labels1, sublabels1 = splitmerge.relabel_after_split(
        state.labels, state.sublabels, dec_s, bits)

    dec_m = splitmerge.propose_merges(
        k_m, dec_s.new_active, stats1, prior, comp, comp.add_stats, cfg.alpha)
    labels2, sublabels2 = splitmerge.relabel_after_merge(
        labels1, sublabels1, dec_m)

    # sub-cluster reset: clusters whose split keeps being rejected re-draw
    # their sub-labels from a fresh hyperplane (escapes sub-Gibbs local
    # modes; the reference DPMMSubClusters does the same). The MH target is
    # untouched — sub-labels are auxiliary proposal state.
    stuck = jnp.where(dec_s.accept | dec_m.merged | ~state.active,
                      0, state.stuck + 1)
    reset = stuck >= cfg.subreset_every
    stuck = jnp.where(reset, 0, stuck).astype(jnp.int32)
    stats2 = splitmerge.apply_merge_to_stats(stats1, dec_m)
    bits2 = splitmerge.hyperplane_bits(
        jax.random.fold_in(k_b, 1), x, labels2, _cluster_means(comp, stats2),
        feat_axis)
    sublabels2 = jnp.where(reset[labels2], bits2, sublabels2)

    # consistency pass: recompute stats AND substats from the new labels
    # (paper §4.4: 'processing accepted splits/merges requires updating the
    # sufficient statistics', O(N/G) + one psum)
    stats3, substats3 = gibbs.compute_stats(
        comp, x, valid, labels2, sublabels2, k_max, axes, feat_axis)
    return state._replace(
        active=dec_m.new_active, stuck=stuck, stats=stats3,
        substats=substats3, labels=labels2, sublabels=sublabels2)


def dpmm_step(state: DPMMState, x, valid, *, prior, comp, cfg, axes,
              k_max, feat_axis=None) -> DPMMState:
    """One full iteration; designed to run under shard_map."""
    state = gibbs.sweep(state, x, valid, prior, comp, cfg.alpha, axes,
                        use_pallas=cfg.use_pallas, feat_axis=feat_axis)
    state = jax.lax.cond(
        state.it >= cfg.burnout,
        lambda s: _split_merge(s, x, valid, prior=prior, comp=comp, cfg=cfg,
                               axes=axes, k_max=k_max, feat_axis=feat_axis),
        lambda s: s,
        state)
    return state._replace(it=state.it + 1)


@dataclasses.dataclass
class FitResult:
    state: DPMMState
    labels: np.ndarray           # (N,) cluster assignments (unpadded)
    k: int
    history: Dict[str, np.ndarray]
    iter_times_s: List[float]

    def nmi(self, true_labels: np.ndarray, n_true: Optional[int] = None):
        n_true = n_true or int(true_labels.max()) + 1
        k_max = int(self.state.active.shape[0])
        return float(nmi(jnp.asarray(true_labels),
                         jnp.asarray(self.labels), n_true, k_max))

    def ari(self, true_labels: np.ndarray, n_true: Optional[int] = None):
        n_true = n_true or int(true_labels.max()) + 1
        k_max = int(self.state.active.shape[0])
        return float(ari(jnp.asarray(true_labels),
                         jnp.asarray(self.labels), n_true, k_max))


class DPMM:
    """Distributed DPMM with sub-cluster splits (paper [1] + this paper)."""

    def __init__(self, cfg: DPMMConfig, mesh: Optional[Mesh] = None):
        self.cfg = cfg
        self.mesh = mesh
        self.comp = component_module(cfg.component)

    def _build_prior(self, x: np.ndarray):
        cfg = self.cfg
        if cfg.component == "gaussian":
            mean = jnp.asarray(x.mean(axis=0), jnp.float32)
            psi_diag = jnp.full((x.shape[1],), cfg.niw_psi, jnp.float32)
            return niw.default_prior(
                mean, psi_diag, cfg.niw_kappa, x.shape[1] + cfg.niw_nu_extra)
        if cfg.component == "poisson":
            return poisson.default_prior(x.shape[1], cfg.gamma_a0,
                                         cfg.gamma_b0)
        return multinomial.default_prior(x.shape[1], cfg.dir_alpha)

    def fit(self, x: np.ndarray, iters: Optional[int] = None,
            verbose: bool = False) -> FitResult:
        cfg = self.cfg
        iters = iters if iters is not None else cfg.iters
        mesh = self.mesh if self.mesh is not None else make_data_mesh()
        axes = data_axes_of(mesh)
        prior = self._build_prior(x)
        n = x.shape[0]
        xs, valid = shard_points(mesh, np.asarray(x, np.float32),
                                 cfg.shard_features)

        feat_axis = ("model" if (cfg.shard_features
                                 and "model" in mesh.axis_names
                                 and cfg.component in ("multinomial",
                                                       "poisson"))
                     else None)
        kwargs = dict(prior=prior, comp=self.comp, cfg=cfg, axes=axes,
                      k_max=cfg.k_max, feat_axis=feat_axis)
        shard_spec = P(axes)
        x_in_spec = P(axes, feat_axis)
        rep = P()
        state_specs = DPMMState(
            key=rep, it=rep, active=rep, logweights=rep, sub_logweights=rep,
            stuck=rep,
            params=jax.tree.map(lambda _: rep, _param_struct(self.comp)),
            subparams=jax.tree.map(lambda _: rep, _param_struct(self.comp)),
            stats=jax.tree.map(lambda _: rep, _stats_struct(self.comp)),
            substats=jax.tree.map(lambda _: rep, _stats_struct(self.comp)),
            labels=shard_spec, sublabels=shard_spec)

        init = jax.jit(jax.shard_map(
            functools.partial(_init_local, **kwargs), mesh=mesh,
            in_specs=(rep, x_in_spec, shard_spec), out_specs=state_specs,
            check_vma=False))
        step = jax.jit(jax.shard_map(
            functools.partial(dpmm_step, **kwargs), mesh=mesh,
            in_specs=(state_specs, x_in_spec, shard_spec),
            out_specs=state_specs, check_vma=False))

        key = jax.random.key(cfg.seed)
        state = init(key, xs, valid)
        hist_k, times = [], []
        for it in range(iters):
            t0 = time.perf_counter()
            state = step(state, xs, valid)
            k_now = int(state.k_hat)  # blocks; also per-iter timing
            times.append(time.perf_counter() - t0)
            hist_k.append(k_now)
            if verbose and (it % 10 == 0 or it == iters - 1):
                print(f"iter {it:4d}  K={k_now}  {times[-1]*1e3:.1f} ms")
        labels = np.asarray(jax.device_get(state.labels))[:n]
        return FitResult(
            state=state, labels=labels, k=int(state.k_hat),
            history={"k": np.array(hist_k)}, iter_times_s=times)


def _param_struct(comp):
    if comp is niw:
        return niw.GaussParams(mu=0, chol_prec=0, logdet_prec=0)
    if comp is poisson:
        return poisson.PoisParams(log_rate=0)
    return multinomial.MultParams(logtheta=0)


def _stats_struct(comp):
    if comp is niw:
        return niw.GaussStats(n=0, sx=0, sxx=0)
    if comp is poisson:
        return poisson.PoisStats(n=0, sx=0)
    return multinomial.MultStats(n=0, counts=0)
