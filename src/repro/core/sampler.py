"""Top-level distributed DPMM sampler — the paper's `fit` entry point.

Composition per iteration (paper §4.1):
    restricted Gibbs sweep  ->  splits  ->  merges  ->  stats consistency
with splits/merges gated by ``burnout``. Observation models are
``ComponentFamily`` instances looked up from the registry (core/family.py)
by ``cfg.component`` — the sampler never inspects param/stat pytrees
itself.

Two data planes share every sampling body (core/gibbs.py,
core/splitmerge.py — the split is model-side O(K) math vs per-point tile
bodies):

 - **Resident** (``cfg.tile_size is None`` and the source is resident):
   points are device-resident; ``cfg.log_every`` iterations run inside one
   jitted, buffer-donated ``lax.scan`` chunk that carries the
   (ModelState, PointState) pair and collects ``summarize()`` history on
   device, so the host blocks once per chunk — no O(iters) round-trips.
 - **Tiled / out-of-core** (``cfg.tile_size`` set, or a non-resident
   ``DataSource``): only ModelState persists on device. Points stream
   through fixed-size tiles pulled from the ``DataSource``
   (data/source.py) with double-buffered ``jax.device_put``; per-point
   labels live in host arrays and ride along with their tile. Device
   memory is O(K_max + tile), so N is bounded by host storage, not HBM.

Because per-point randomness is counter-based on the *global* point index
and suff-stats fold in fixed STATS_BLOCK-aligned blocks (core/gibbs.py),
the two planes produce bitwise-identical chains — tile size, like shard
count, is a pure performance knob.

**Multi-chain fits** (``fit(..., n_chains=C)``): both drivers carry an
optional leading *chain axis* on the (ModelState, PointState) pair. The C
chains run inside the same jitted chunk via ``jax.lax.map`` over that
axis, sharing ONE device-resident copy of x (the points are closed over,
never duplicated per chain, and in tiled mode each streamed tile is
uploaded once and consumed by every chain) and syncing with the host once
per chunk total — not once per chain. ``lax.map`` (not ``vmap``) is the
batching transform on purpose: it traces the *identical* unbatched chain
body per slice, so chain c of an ``n_chains=C`` fit is **bitwise
identical** to an independent single-chain fit with
``key=fold_in(key(seed), c)`` — vmap's batched reductions reassociate
float additions and break the repo's bitwise-chain contract (measured:
ULP drift in stats by iteration 1). Cross-chain diagnostics ride on the
result: ``FitResult.rhat`` (split-R-hat over history traces),
``FitResult.select_best`` (max posterior ``score``), and per-chain views
via ``FitResult.chain(c)``.

Example (paper §3.4.1 analogue):
    >>> from repro.core.sampler import DPMM
    >>> from repro.configs import DPMMConfig
    >>> model = DPMM(DPMMConfig(alpha=10., iters=100))
    >>> result = model.fit(x)          # x: (N, d) np.ndarray or DataSource
    >>> result.labels, result.k, result.nmi(gt)
    >>> best = model.fit(x, n_chains=4).select_best()   # parallel chains
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.special import gammaln
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import DPMMConfig
from repro.core import checkpoint as _checkpoint
from repro.core import gibbs, splitmerge
from repro.core.distributed import (data_axes_of, make_data_mesh,
                                    n_data_shards, shard_map, shard_points,
                                    tile_plan)
from repro.core.family import (ComponentFamily, get_family,
                               state_partition_specs)
from repro.core.metrics import ari, nmi
from repro.core.resilience import (DivergenceError, RetryPolicy,
                                   model_health, read_block_checked)
from repro.core.state import ModelState, PointState, grow_model
from repro.data.source import DataSource, as_source

_HIST_KEYS = ("k", "max_cluster", "min_cluster", "score")

# Rollback key stream: fold_in values >= 2**30 are disjoint from both
# per-iteration streams (the sweep folds it in [0, iters), split/merge
# folds -(it+1)), so a recovered chain never collides with the clean one.
_RECOVERY_FOLD = (1 << 30) + 1337


def _recovery_rekey(model: ModelState, n_rollback: int) -> ModelState:
    """Advance the chain key after a divergence rollback: replaying the
    exact (key, it) stream that just diverged would be futile when the
    divergence is state-dependent, so each rollback folds a reserved
    counter into the key. Multi-chain keys advance per chain (vmap over
    the (C,) key axis — integer math, exact)."""
    fold = _RECOVERY_FOLD + n_rollback

    def f(k):
        return jax.random.fold_in(k, fold)
    key = model.key
    return model._replace(key=f(key) if key.ndim == 0 else jax.vmap(f)(key))


class _Recovery:
    """Shared per-fit bookkeeping for auto-checkpointing and divergence
    rollback (both drivers). ``events`` becomes ``FitResult.recoveries``;
    it also collects the tile-read retry events the streaming path
    reports (core/resilience.read_block_checked)."""

    def __init__(self, cfg: DPMMConfig, family_name: str, it_base: int):
        self.cfg = cfg
        self.events: List[dict] = []
        self.n_rollbacks = 0
        self._family = family_name
        self._last_saved = it_base

    def maybe_checkpoint(self, model: ModelState, it_abs: int,
                         force: bool = False) -> None:
        """Save a rotation member when ``checkpoint_every`` iterations
        have passed since the last save (the resident driver calls this
        at chunk boundaries, so saves land on the first boundary past
        each multiple). ``force`` saves the final state regardless of
        cadence (but never duplicates an already-saved iteration)."""
        cfg = self.cfg
        if not (cfg.checkpoint_path and cfg.checkpoint_every):
            return
        due = it_abs - self._last_saved >= cfg.checkpoint_every
        if (force and it_abs > self._last_saved) or due:
            _checkpoint.save_checkpoint(cfg.checkpoint_path, model,
                                        self._family, it_abs,
                                        keep=cfg.checkpoint_keep)
            self._last_saved = it_abs

    def rollback(self, it_abs: int, restored_it: int, detail: str) -> None:
        """Record a divergence rollback; raise once the budget is spent
        (carrying the full event log for the post-mortem)."""
        self.n_rollbacks += 1
        self.events.append({"kind": "divergence_rollback",
                            "iter": int(it_abs),
                            "restored_it": int(restored_it),
                            "rollback": self.n_rollbacks,
                            "detail": detail})
        if self.n_rollbacks > self.cfg.max_recoveries:
            raise DivergenceError(
                f"chain state went non-finite/degenerate at iteration "
                f"{it_abs} and rollback did not recover it within "
                f"max_recoveries={self.cfg.max_recoveries} attempts — "
                "the divergence is persistent (non-finite input data, or "
                "a numerically hostile configuration). See .recoveries "
                "for the event log.", self.events)


def chain_score(model: ModelState, prior, family, alpha: float) -> jax.Array:
    """Collapsed log posterior density of the chain's clustering (up to a
    data-independent constant): the CRP EPPF plus the per-cluster marginal
    likelihoods, ``sum_k [log alpha + lgamma(N_k) + log m(prior, S_k)]``
    over active clusters. O(K) — no per-point input. This is the ranking
    used by ``FitResult.select_best`` and the 'score' history trace R-hat
    diagnoses (inactive slots are masked BEFORE the sum, so their
    unnormalized stats never contribute NaNs)."""
    logm = family.log_marginal(prior, model.stats)
    act = model.active
    occ = jnp.where(act, jnp.maximum(model.stats.n, 1.0), 1.0)
    return (jnp.sum(jnp.where(act, logm, 0.0))
            + model.k_hat.astype(jnp.float32) * jnp.log(jnp.float32(alpha))
            + jnp.sum(jnp.where(act, gammaln(occ), 0.0))
            ).astype(jnp.float32)


def _summaries(model: ModelState, prior, family, alpha: float) -> dict:
    """Per-step history row: the replicated scalar diagnostics plus the
    posterior 'score' trace (chain_score)."""
    s = model.summarize()
    s["score"] = chain_score(model, prior, family, alpha)
    return s


def _chain_keys(key: jax.Array, n_chains: int) -> jax.Array:
    """(C,) per-chain base keys: ``fold_in(key, c)``. vmap over the
    integer chain ids is exact (threefry is integer math), so chain c's
    key is bit-for-bit the key an independent single-chain fit gets from
    ``fold_in(key, c)``."""
    return jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        key, jnp.arange(n_chains))


def _ceil_pow2(v: int) -> int:
    return 1 << max(0, (int(v) - 1).bit_length())


def _k_compact(k_hat: int, headroom: int, k_slab: int,
               k_block: int) -> Optional[int]:
    """Static compact-slab size for the sparse-K sweep: covers
    ``headroom * k_hat`` live clusters (headroom 1 when K cannot change
    during the pass — tiled sweeps; 2 when splits may double it — resident
    chunks and split/merge folds), rounded up to a power of two so the
    number of distinct compiled shapes is O(log K) per fit. ``None`` when
    the compact slab would not beat the dense one."""
    kc = max(k_block, _ceil_pow2(headroom * max(1, k_hat)))
    return None if kc >= k_slab else kc


def _chain_map(f):
    """lax.map ``f`` over a leading chain axis of every argument — the
    multi-chain batching transform. The mapped body is the *same traced
    jaxpr* as the unbatched one, which is what keeps per-chain results
    bitwise identical to independent single-chain fits (vmap would batch
    the float reductions and reassociate them)."""
    return lambda *args: jax.lax.map(lambda s: f(*s), args)


def _init_local(key, x, valid, *, prior, family, cfg, axes, k_max,
                feat_axis=None) -> Tuple[ModelState, PointState]:
    """Initial state (runs under shard_map), whole shard as one tile."""
    n_local = x.shape[0]
    gidx = gibbs.global_indices(n_local, axes)
    labels = _init_labels(gidx, cfg.init_clusters)
    # first pass for cluster means, then hyperplane sub-label init
    stats0, _ = gibbs.compute_stats(
        family, x, valid, labels, jnp.zeros_like(labels), k_max, axes,
        feat_axis, cfg.use_pallas)
    means0 = family.cluster_means(stats0)
    v0 = splitmerge.hyperplane_vecs(
        jax.random.fold_in(key, 1), k_max, means0.shape[1], x.dtype)
    sublabels = splitmerge.hyperplane_bits(x, labels, means0, v0, feat_axis)
    stats, substats = gibbs.compute_stats(
        family, x, valid, labels, sublabels, k_max, axes, feat_axis,
        cfg.use_pallas)
    return (_init_model(key, stats, substats, prior=prior, family=family,
                        cfg=cfg, k_max=k_max),
            PointState(labels=labels, sublabels=sublabels, valid=valid))


def _init_labels(gidx: jax.Array, init_clusters: int) -> jax.Array:
    return (gidx % jnp.uint32(init_clusters)).astype(jnp.int32)


def _init_model(key, stats, substats, *, prior, family, cfg,
                k_max) -> ModelState:
    """Replicated O(K) half of initialization, given the initial stats."""
    active = jnp.arange(k_max) < cfg.init_clusters
    params = family.expected_params(prior, stats)
    subparams = family.expected_params(prior, substats)
    # strong dtypes: weak-typed leaves would force a second trace/compile of
    # the chunk fn on its own (strongly-typed) output state
    logw = jnp.where(active, -jnp.log(float(cfg.init_clusters)),
                     gibbs.NEG_INF).astype(jnp.float32)
    sublogw = jnp.full((k_max, 2), jnp.log(0.5), dtype=jnp.float32)
    return ModelState(
        key=key, it=jnp.zeros((), jnp.int32), active=active,
        logweights=logw, sub_logweights=sublogw,
        stuck=jnp.zeros((k_max,), jnp.int32), params=params,
        subparams=subparams, stats=stats, substats=substats)


def _move_key(model: ModelState) -> jax.Array:
    """Per-iteration split/merge key (negative fold: disjoint from the
    sweep's fold_in(key, it) stream)."""
    return jax.random.fold_in(model.key, -(model.it + 1))


def _split_merge(model: ModelState, point: PointState, x, *, prior, family,
                 cfg, axes, k_max, feat_axis=None, k_compact=None
                 ) -> Tuple[ModelState, PointState]:
    """Resident split/merge: plan (O(K)), one whole-shard tile, finalize.

    With ``k_compact`` set, the consistency suff-stat fold runs on a
    compact slab sized for the *post-move* active set — splits at most
    double K per move, so ``min(k_max, 2 * k_compact)`` rows suffice —
    and the finalized stats scatter back to the dense slab (bitwise the
    dense fold). A ``lax.cond`` falls back to the dense fold whenever the
    post-move live count outgrew the bound (possible mid-chunk, where
    ``k_compact`` was sized from a chunk-old k_hat)."""
    plan = splitmerge.plan_split_merge(
        _move_key(model), model, prior, family, cfg.alpha,
        cfg.subreset_every)

    def run(comp):
        k_eff = k_max if comp is None else comp.slot_of_compact.shape[0]
        acc = gibbs.empty_substats(family, k_eff, x.shape[-1])
        point2, acc2 = splitmerge.split_merge_tile(
            plan, x, point, acc, family, use_pallas=cfg.use_pallas,
            feat_axis=feat_axis, compaction=comp)
        # consistency pass (paper §4.4: 'processing accepted splits/merges
        # requires updating the sufficient statistics', O(N/G) + one psum)
        stats3, substats3 = gibbs.finalize_substats(family, acc2, axes,
                                                    feat_axis)
        if comp is not None:
            stats3 = gibbs.compact_scatter(comp, k_max, stats3)
            substats3 = gibbs.compact_scatter(comp, k_max, substats3)
        return (model._replace(active=plan.merge.new_active,
                               stuck=plan.stuck, stats=stats3,
                               substats=substats3), point2)

    k_c_sm = None if k_compact is None else min(k_max, 2 * k_compact)
    if k_c_sm is None or k_c_sm >= k_max:
        return run(None)
    comp = gibbs.compaction_plan(plan.merge.new_active, k_c_sm)
    n_new = jnp.sum(plan.merge.new_active.astype(jnp.int32))
    return jax.lax.cond(n_new <= k_c_sm, lambda: run(comp),
                        lambda: run(None))


def dpmm_step(model: ModelState, point: PointState, x, *, prior, family,
              cfg, axes, k_max, feat_axis=None, k_compact=None
              ) -> Tuple[ModelState, PointState]:
    """One full iteration; designed to run under shard_map. ``k_compact``
    (static) turns on active-set compaction for the sweep and the
    split/merge stat fold — O(N * K_active) per-point work instead of
    O(N * k_max), bitwise the dense iteration (core/gibbs.py)."""
    model, point = gibbs.sweep(model, point, x, prior, family, cfg.alpha,
                               axes, use_pallas=cfg.use_pallas,
                               feat_axis=feat_axis, k_compact=k_compact,
                               k_block=cfg.k_block)
    model, point = jax.lax.cond(
        model.it >= cfg.burnout,
        lambda mp: _split_merge(*mp, x, prior=prior, family=family,
                                cfg=cfg, axes=axes, k_max=k_max,
                                feat_axis=feat_axis, k_compact=k_compact),
        lambda mp: mp,
        (model, point))
    return model._replace(it=model.it + 1), point


def _peak_fields(rss_baseline: Optional[int]) -> Dict[str, Any]:
    """The measured-peak entries of ``FitResult.device_bytes``. When the
    measurement is the RSS fallback, also record the high-water *delta*
    over this fit (``peak_rss_delta_bytes``) — the leg-accurate number
    when several fits share one process (a later fit that never exceeds
    an earlier one's peak reports delta 0 and source
    ``process_peak_rss_stale`` instead of silently re-reporting the old
    peak as its own)."""
    peak, src = _measured_peak(rss_baseline)
    fields: Dict[str, Any] = {"peak_bytes_in_use": peak,
                              "peak_bytes_source": src}
    if src.startswith("process_peak_rss") and rss_baseline is not None:
        fields["peak_rss_delta_bytes"] = max(int(peak) - rss_baseline, 0)
    return fields


def _copy_state(state: ModelState) -> ModelState:
    """Fresh buffers for a caller-provided init_state: the resident
    chunk donates its state arguments, and without the copy the FIRST
    chunk would delete the caller's (possibly checkpoint-loaded) arrays
    out from under them — resuming twice from one state would crash."""
    return jax.tree.map(jnp.copy, state)


def _tree_bytes(tree: Any) -> int:
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(tree)
               if hasattr(l, "shape"))


@dataclasses.dataclass
class FitResult:
    """Result of ``DPMM.fit``. With ``n_chains=1`` (default) every field
    is per-run; with C > 1 the state/labels/history carry a leading chain
    axis ((C, ...) state leaves, (C, N) labels, (C, iters) traces), ``k``
    is the best-scoring chain's cluster count, and the cross-chain views
    are ``chain(c)`` / ``select_best()`` / ``rhat(key)``."""
    state: ModelState            # final replicated model-side state
    labels: np.ndarray           # (N,) cluster assignments (unpadded)
    k: int
    history: Dict[str, np.ndarray]
    iter_times_s: List[float]
    # accounting of what the fit kept device-resident (see README
    # 'Memory model'): est_peak_bytes is the analytic per-run peak over
    # persistent device buffers; peak_bytes_in_use is the measured peak —
    # device.memory_stats() where the backend reports it, else the
    # process's peak RSS — with its origin in peak_bytes_source.
    device_bytes: Optional[Dict[str, Any]] = None
    n_chains: int = 1
    # final chain_score per chain: scalar (C=1) or (C,) — the
    # select_best ranking; the full trace is history["score"]
    score: Any = None
    # resilience event log: tile-read retries ('tile_read_fault'),
    # recovered retries ('io_retry'), divergence rollbacks
    # ('divergence_rollback'), and distributed worker failovers
    # ('worker_failover') the fit survived. Empty for a clean fit. NOT
    # part of ``history`` on purpose — the golden-chain fingerprints
    # hash history, and recoveries are operational metadata, not chain
    # state.
    recoveries: List[dict] = dataclasses.field(default_factory=list)
    # distributed-fit metadata (cfg.workers set): worker count, the
    # per-worker shard row ranges, and respawn/reassignment tallies.
    # None for single-process fits.
    dist: Optional[Dict[str, Any]] = None

    def chain(self, c: int) -> "FitResult":
        """Single-chain view of chain ``c`` (bitwise — pure slicing)."""
        if self.n_chains == 1:
            if c != 0:
                raise IndexError(f"single-chain result has no chain {c}")
            return self
        state_c = jax.tree.map(lambda v: v[c], self.state)
        return FitResult(
            state=state_c, labels=self.labels[c],
            k=int(np.asarray(state_c.active).sum()),
            history={k: np.asarray(v[c]) for k, v in self.history.items()},
            iter_times_s=self.iter_times_s,
            device_bytes=self.device_bytes, n_chains=1,
            score=float(np.asarray(self.score)[c]),
            recoveries=self.recoveries)

    def select_best(self) -> "FitResult":
        """The chain with the highest final posterior ``score``
        (core/sampler.chain_score) — what a practitioner consumes."""
        if self.n_chains == 1:
            return self
        return self.chain(int(np.argmax(np.asarray(self.score))))

    def rhat(self, key: str = "score") -> float:
        """Split-R-hat (Gelman et al.) over the per-chain history traces
        of ``key`` ('score' or 'k' are the useful ones). Values near 1
        mean the chains agree; > ~1.1 means they found different modes —
        run longer or take ``select_best()`` with a grain of salt."""
        if self.n_chains < 2:
            raise ValueError("rhat needs n_chains >= 2")
        trace = np.asarray(self.history[key], np.float64)   # (C, T)
        half = trace.shape[1] // 2
        if half < 2:
            raise ValueError("rhat needs >= 4 recorded iterations")
        x = np.concatenate([trace[:, :half], trace[:, half:2 * half]])
        n = x.shape[1]
        w = x.var(axis=1, ddof=1).mean()
        b = n * x.mean(axis=1).var(ddof=1)
        if w <= 0.0:
            return 1.0 if b <= 0.0 else float("inf")
        return float(np.sqrt(((n - 1) / n * w + b / n) / w))

    def rhats(self) -> Dict[str, float]:
        return {key: self.rhat(key) for key in ("k", "score")}

    def nmi(self, true_labels: np.ndarray, n_true: Optional[int] = None):
        if self.n_chains > 1:
            return self.select_best().nmi(true_labels, n_true)
        n_true = n_true or int(true_labels.max()) + 1
        k_max = int(self.state.active.shape[0])
        return float(nmi(jnp.asarray(true_labels),
                         jnp.asarray(self.labels), n_true, k_max))

    def ari(self, true_labels: np.ndarray, n_true: Optional[int] = None):
        if self.n_chains > 1:
            return self.select_best().ari(true_labels, n_true)
        n_true = n_true or int(true_labels.max()) + 1
        k_max = int(self.state.active.shape[0])
        return float(ari(jnp.asarray(true_labels),
                         jnp.asarray(self.labels), n_true, k_max))


def _rss_peak_bytes() -> Optional[int]:
    """Process-lifetime peak RSS in bytes (``ru_maxrss``), or None where
    unmeasurable (non-POSIX)."""
    try:
        import resource
        return int(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss) * 1024
    except Exception:
        return None


def _measured_peak(rss_baseline: Optional[int] = None
                   ) -> Tuple[Optional[int], str]:
    """(peak bytes, source): the backend's ``peak_bytes_in_use`` where
    ``device.memory_stats()`` reports it (TPU/GPU), else the process's
    peak RSS (``ru_maxrss``; on CPU the 'device' IS host memory) — so
    memory claims are measurable everywhere. RSS is a process-lifetime
    high-water mark that includes host-side buffers and cannot be reset
    between fits, so a leg that runs after a larger allocation in the same
    process would silently report that *earlier* peak as its own. Callers
    that measure a leg pass ``rss_baseline`` (``_rss_peak_bytes()`` taken
    at leg start); when the high-water mark did not move during the leg
    the source is reported as ``process_peak_rss_stale`` — the number is a
    ceiling inherited from earlier work, not this leg's footprint.
    """
    stats = jax.local_devices()[0].memory_stats() or {}
    peak = stats.get("peak_bytes_in_use")
    if peak is not None:
        return int(peak), "device.memory_stats"
    rss = _rss_peak_bytes()
    if rss is None:                           # non-POSIX: no measurement
        return None, "unavailable"
    if rss_baseline is not None and rss <= rss_baseline:
        return rss, "process_peak_rss_stale"
    return rss, "process_peak_rss"


class DPMM:
    """Distributed DPMM with sub-cluster splits (paper [1] + this paper)."""

    def __init__(self, cfg: DPMMConfig, mesh: Optional[Mesh] = None):
        self.cfg = cfg
        self.mesh = mesh
        self.family: ComponentFamily = get_family(cfg.component)

    def fit(self, x, iters: Optional[int] = None, verbose: bool = False,
            *, n_chains: int = 1, key: Optional[jax.Array] = None,
            init_state: Optional[ModelState] = None,
            resume: bool = False, dist_hooks: Any = None) -> FitResult:
        """Fit to ``x``: an (N, d) array (resident fast path) or any
        ``DataSource`` (e.g. ``HostTiledSource`` over an np.memmap for
        out-of-core data). ``cfg.tile_size`` forces the tiled plane even
        for resident arrays — chains are bitwise identical either way.

        ``n_chains=C`` runs C parallel MCMC chains inside the same jitted
        chunks, sharing one device copy of x; chain c is bitwise the
        single-chain fit with ``key=fold_in(key, c)`` (see module
        docstring). ``key`` overrides ``jax.random.key(cfg.seed)``.
        ``init_state`` resumes from a checkpointed ``ModelState``
        (core/checkpoint.py) and runs ``iters`` MORE iterations; because
        every per-point quantity is recomputed from the model each sweep
        and all randomness derives from ``(state.key, state.it)``, the
        resumed chain is bitwise the uninterrupted one.

        ``resume=True`` picks up a killed fit from the auto-checkpoint
        rotation at ``cfg.checkpoint_path`` (requires it): the newest
        member that *verifies* (version, CRCs, leaf shapes) is loaded —
        corrupt members fall back through the rotation — and ``iters``
        is treated as the TOTAL iteration target, so the fit runs only
        the remaining ``iters - it_checkpoint`` iterations. With no
        checkpoint on disk yet it is a fresh fit, which is what makes
        blind ``fit(resume=True)`` re-runs idempotent-ish: run, crash,
        rerun until done. Mutually exclusive with ``init_state``.

        ``cfg.workers=N`` routes the fit through the elastic
        multi-process driver (repro.dist): N worker processes each
        stream a row-range shard while this process keeps the model.
        The chain is bitwise identical to the single-process tiled fit
        at any worker count, including across worker failover.
        ``dist_hooks`` (a ``repro.dist.DistHooks``) injects worker-side
        faults / iteration callbacks for chaos tests. Resume and
        init_state compose unchanged — they are resolved here, before
        the driver dispatch.
        """
        source = as_source(x)
        iters = iters if iters is not None else self.cfg.iters
        if n_chains < 1:
            raise ValueError(f"n_chains must be >= 1, got {n_chains}")
        if key is None:
            key = jax.random.key(self.cfg.seed)
        if resume:
            if init_state is not None:
                raise ValueError(
                    "pass either resume=True (load from "
                    "cfg.checkpoint_path) or init_state, not both")
            if not self.cfg.checkpoint_path:
                raise ValueError(
                    "fit(resume=True) needs cfg.checkpoint_path — the "
                    "rotation prefix auto-checkpointing saved to")
            try:
                loaded, fam, _path, it_ckpt = _checkpoint.latest_valid(
                    self.cfg.checkpoint_path)
            except _checkpoint.CheckpointNotFound:
                loaded = None           # nothing saved yet: fresh fit
            if loaded is not None:
                if fam.name != self.family.name:
                    raise ValueError(
                        f"checkpoint at {self.cfg.checkpoint_path} holds "
                        f"a '{fam.name}' model but cfg.component is "
                        f"'{self.family.name}'")
                init_state = loaded
                iters = max(0, iters - it_ckpt)
        if init_state is not None:
            # k_max='auto': the checkpoint's slab size IS the resumed
            # starting capacity, so only the chain axis is validated
            k_chk = (init_state.active.shape[-1]
                     if self.cfg.k_max == "auto" else self.cfg.k_max)
            want = ((n_chains, k_chk) if n_chains > 1 else (k_chk,))
            got = tuple(init_state.active.shape)
            if got != want:
                raise ValueError(
                    f"init_state.active has shape {got}, expected {want} "
                    f"for n_chains={n_chains}, k_max={self.cfg.k_max} — "
                    "checkpoint/config/chain-count mismatch")
        if self.cfg.workers:
            return self._fit_distributed(source, iters, verbose,
                                         n_chains=n_chains, key=key,
                                         init_state=init_state,
                                         dist_hooks=dist_hooks)
        if self.cfg.tile_size is None and source.resident() is not None:
            return self._fit_resident(source, iters, verbose,
                                      n_chains=n_chains, key=key,
                                      init_state=init_state)
        return self._fit_tiled(source, iters, verbose, n_chains=n_chains,
                               key=key, init_state=init_state)

    def _fit_distributed(self, source: DataSource, iters: int,
                         verbose: bool, n_chains: int = 1,
                         key: Optional[jax.Array] = None,
                         init_state: Optional[ModelState] = None,
                         dist_hooks: Any = None) -> FitResult:
        """Third fit driver: coordinator/worker shards (repro.dist).
        Lazy import — single-process fits never touch the subprocess /
        socket machinery."""
        if n_chains != 1:
            raise ValueError(
                "cfg.workers does not compose with n_chains > 1 yet: "
                "chain batching rides the tile bodies, which the "
                "distributed driver runs per worker shard. Run one "
                "distributed fit per chain key instead.")
        from repro.dist.coordinator import fit_distributed
        return fit_distributed(self, source, iters, verbose, key=key,
                               init_state=init_state, hooks=dist_hooks)

    def _setup(self, source: DataSource):
        cfg = self.cfg
        family = self.family
        mesh = self.mesh if self.mesh is not None else make_data_mesh()
        axes = data_axes_of(mesh)
        # the prior's data-dependent part is the column mean, computed
        # once by the source's canonical streaming pass — identical for
        # resident and out-of-core modes (data/source.py)
        prior = family.build_prior(cfg, source.column_mean()[None, :])
        want_feat_shard = cfg.shard_features and family.feature_shardable
        feat_axis = ("model" if (want_feat_shard
                                 and "model" in mesh.axis_names)
                     else None)
        kwargs = dict(prior=prior, family=family, cfg=cfg, axes=axes,
                      k_max=cfg.k_max, feat_axis=feat_axis)
        return mesh, axes, feat_axis, kwargs

    # ------------------------------------------------------------------
    # Resident plane: device-resident points, chunked on-device scan
    # ------------------------------------------------------------------
    def _fit_resident(self, source: DataSource, iters: int, verbose: bool,
                      n_chains: int = 1, key: Optional[jax.Array] = None,
                      init_state: Optional[ModelState] = None) -> FitResult:
        cfg = self.cfg
        multi = n_chains > 1
        mesh, axes, feat_axis, kwargs = self._setup(source)
        prior, family = kwargs["prior"], kwargs["family"]
        # slab capacity: fixed k_max, or the 'auto' growth schedule — start
        # small and double at chunk boundaries when the live count crosses
        # half the slab, so k_max is a discovered high-water mark
        auto = cfg.k_max == "auto"
        if init_state is not None:
            k_slab = int(init_state.active.shape[-1])
        elif auto:
            k_slab = min(cfg.k_max_cap, max(8, 2 * cfg.init_clusters))
        else:
            k_slab = cfg.k_max
        k_cap = cfg.k_max_cap if auto else k_slab
        kwargs["k_max"] = k_slab
        x = source.resident()
        n = x.shape[0]
        # non-separable families keep features replicated even when
        # shard_features is requested (family.feature_shardable contract)
        xs, valid = shard_points(mesh, x, feat_axis is not None)
        shard_spec = P(axes)
        x_in_spec = P(axes, feat_axis)
        rep = P()
        model_specs, point_specs = state_partition_specs(self.family,
                                                         shard_spec)
        if multi:
            # chain axis leads every per-point leaf; replicated O(K)
            # leaves keep P() (rank-agnostic)
            point_specs = jax.tree.map(lambda _: P(None, axes), point_specs)
        state_specs = (model_specs, point_specs)

        def init_body(keys, x, valid):
            if multi:
                return jax.lax.map(
                    lambda k: _init_local(k, x, valid, **kwargs), keys)
            return _init_local(keys, x, valid, **kwargs)

        init = jax.jit(shard_map(
            init_body, mesh=mesh,
            in_specs=(rep, x_in_spec, shard_spec), out_specs=state_specs))

        def make_chunk(length: int, k_c: Optional[int]):
            """`length` iterations in one jitted call, history on device.

            The scan carries the (model, point) state pair; per-step
            host-visible output is only the O(1) ``_summaries()`` scalars
            (per chain when C > 1 — the C chains run under ``lax.map``
            INSIDE the scan body, sharing the closed-over x). State
            buffers are donated, so chunk i+1 reuses chunk i's memory.
            ``k_c`` (static) is the compact-slab size for every iteration
            of the chunk; the in-step ``lax.cond`` (core/gibbs.py) falls
            back to the dense slab if mid-chunk splits outgrow it.
            """
            def one(m, p, x):
                m, p = dpmm_step(m, p, x, k_compact=k_c, **kwargs)
                return (m, p), _summaries(m, prior, family, cfg.alpha)

            def run(model, point, x):
                def body(mp, _):
                    if multi:
                        return jax.lax.map(lambda s: one(*s, x), mp)
                    return one(*mp, x)
                return jax.lax.scan(body, (model, point), None,
                                    length=length)
            hist_specs = {k: rep for k in _HIST_KEYS}
            return jax.jit(
                shard_map(run, mesh=mesh,
                          in_specs=(*state_specs, x_in_spec),
                          out_specs=(state_specs, hist_specs)),
                donate_argnums=(0, 1))

        rss0 = _rss_peak_bytes()
        # fresh PointState from the validity mask alone: zeros for labels
        # are fine — every sweep recomputes them from the model. Used on
        # resume (no point in the checkpoint) AND on divergence rollback
        # (the donated chunk consumed the diverged point's buffers).
        mk_point = jax.jit(shard_map(
            lambda v: PointState(
                labels=jnp.zeros(((n_chains,) if multi else ())
                                 + v.shape, jnp.int32),
                sublabels=jnp.zeros(((n_chains,) if multi else ())
                                    + v.shape, jnp.int32),
                valid=(jnp.broadcast_to(v, (n_chains,) + v.shape)
                       if multi else v)),
            mesh=mesh, in_specs=(shard_spec,), out_specs=point_specs))
        if init_state is not None:
            model = jax.device_put(_copy_state(init_state),
                                   NamedSharding(mesh, P()))
            point = mk_point(valid)
            it_base = int(np.asarray(
                jax.device_get(init_state.it)).reshape(-1)[0])
        else:
            keys = _chain_keys(key, n_chains) if multi else key
            model, point = init(keys, xs, valid)
            it_base = 0

        chunk = max(1, cfg.log_every)
        chunk_fns: Dict[Any, Any] = {}
        hist_chunks: List[Dict[str, np.ndarray]] = []
        times: List[float] = []
        done = 0
        # guardrails: the health verdict is a SEPARATE tiny jitted program
        # over the O(K) model state — never fused into the chunk, so the
        # chunk's compiled artifact (and the chain it computes) is bitwise
        # identical with guardrails on or off; the verdict rides the
        # existing per-chunk device_get (zero extra host syncs)
        health_fn = jax.jit(model_health) if cfg.guardrails else None
        rec = _Recovery(cfg, self.family.name, it_base)
        # rollback anchor: device-side copy of the last healthy boundary
        # (model, done, k_slab) — kept on device because typed PRNG keys
        # round-trip poorly and the copy is O(K), not O(N)
        snap = ((jax.tree.map(jnp.copy, model), 0, k_slab)
                if cfg.guardrails else None)
        # last known live cluster count (max over chains) — sizes the next
        # chunk's compact slab and drives the 'auto' growth schedule; the
        # host learns it for free from the chunk history it pulls anyway
        if init_state is not None:
            k0 = int(np.max(np.asarray(
                jax.device_get(init_state.active)).sum(axis=-1)))
        else:
            k0 = cfg.init_clusters
        while done < iters:
            length = min(chunk, iters - done)
            if auto and 2 * k0 > k_slab and k_slab < k_cap:
                while 2 * k0 > k_slab and k_slab < k_cap:
                    k_slab = min(k_cap, 2 * k_slab)
                # chunk-boundary growth: pad the slab, re-replicate, and
                # let the next AOT compile re-donate the grown buffers
                model = jax.device_put(grow_model(model, k_slab),
                                       NamedSharding(mesh, P()))
                kwargs["k_max"] = k_slab
            k_c = (_k_compact(k0, 2, k_slab, cfg.k_block)
                   if cfg.compact else None)
            fkey = (length, k_slab, k_c)
            if fkey not in chunk_fns:
                # AOT-compile outside the timed region so jit compile time
                # (seconds) never contaminates iter_times_s / benchmarks.
                # O(log K) compiles per fit: `log_every` + one trailing
                # remainder length, times the pow2 compact/slab sizes.
                chunk_fns[fkey] = make_chunk(length, k_c).lower(
                    model, point, xs).compile()
            t0 = time.perf_counter()
            (model, point), hist = chunk_fns[fkey](model, point, xs)
            if health_fn is not None:
                # one sync pulls the chunk history AND the health verdict
                hist, healthy = jax.device_get((hist, health_fn(model)))
                healthy = bool(healthy)
            else:
                hist = jax.device_get(hist)   # the one host sync per chunk
                healthy = True
            dt = time.perf_counter() - t0
            if not healthy:
                snap_model, snap_done, snap_slab = snap
                rec.rollback(it_base + done + length, it_base + snap_done,
                             "non-finite/degenerate model state after "
                             "resident chunk")
                # restore the anchor (fresh copy: the anchor itself must
                # survive a possible second rollback), advance the key so
                # the replay takes a different trajectory, rebuild point
                model = _recovery_rekey(
                    jax.tree.map(jnp.copy, snap_model), rec.n_rollbacks)
                done = snap_done
                if k_slab != snap_slab:       # undo post-anchor slab growth
                    k_slab = snap_slab
                    kwargs["k_max"] = k_slab
                point = mk_point(valid)
                k0 = int(np.max(np.asarray(
                    jax.device_get(snap_model.active)).sum(axis=-1)))
                continue                      # failed chunk leaves no
                                              # hist/times rows behind
            times.extend([dt / length] * length)
            hist_chunks.append(hist)
            k0 = int(np.max(np.asarray(hist["k"][-1])))
            done += length
            if cfg.guardrails:
                snap = (jax.tree.map(jnp.copy, model), done, k_slab)
            rec.maybe_checkpoint(model, it_base + done)
            if verbose:
                ks = np.asarray(hist["k"][-1]).reshape(-1).tolist()
                print(f"iter {it_base + done:4d}  "
                      f"K={ks if len(ks) > 1 else ks[0]}  "
                      f"{dt / length * 1e3:.1f} ms/iter")
        rec.maybe_checkpoint(model, it_base + done, force=True)
        history = {
            k: (np.concatenate([h[k] for h in hist_chunks])
                if hist_chunks else np.zeros((0,) + ((n_chains,) if multi
                                                     else ())))
            for k in _HIST_KEYS}
        if multi:
            # (iters, C) per-step stacks -> (C, iters) per-chain traces
            history = {k: np.ascontiguousarray(v.T)
                       for k, v in history.items()}
        labels = np.asarray(jax.device_get(point.labels))[..., :n]
        device_bytes = {
            "mode": "resident",
            "est_peak_bytes": (_tree_bytes(xs) + _tree_bytes(valid)
                               + 2 * _tree_bytes(point)
                               + 2 * _tree_bytes(model)),
            **_peak_fields(rss0),
        }
        return self._result(model, labels, history, times, device_bytes,
                            n_chains, rec.events)

    def _result(self, model: ModelState, labels, history, times,
                device_bytes, n_chains: int,
                recoveries: Optional[List[dict]] = None) -> FitResult:
        """Assemble a FitResult; for C > 1, ``k`` is the best chain's."""
        recoveries = recoveries or []
        if n_chains == 1:
            score = (float(history["score"][-1])
                     if history["score"].size else None)
            return FitResult(state=model, labels=labels,
                             k=int(model.k_hat), history=history,
                             iter_times_s=times, device_bytes=device_bytes,
                             score=score, recoveries=recoveries)
        score = (np.asarray(history["score"][:, -1])
                 if history["score"].size
                 else np.zeros((n_chains,), np.float32))
        best = int(np.argmax(score))
        return FitResult(state=model, labels=labels,
                         k=int(np.asarray(model.active[best]).sum()),
                         history=history, iter_times_s=times,
                         device_bytes=device_bytes, n_chains=n_chains,
                         score=score, recoveries=recoveries)

    # ------------------------------------------------------------------
    # Tiled plane: out-of-core points streamed under a resident ModelState
    # ------------------------------------------------------------------
    def _fit_tiled(self, source: DataSource, iters: int, verbose: bool,
                   n_chains: int = 1, key: Optional[jax.Array] = None,
                   init_state: Optional[ModelState] = None) -> FitResult:
        cfg = self.cfg
        family = self.family
        multi = n_chains > 1
        mesh, axes, feat_axis, kwargs = self._setup(source)
        prior = kwargs["prior"]
        if cfg.k_max == "auto":
            raise ValueError(
                "k_max='auto' requires the resident data plane: the tiled "
                "driver has no scan-chunk boundary to grow the slab at. "
                "Pass an integer k_max for tiled/out-of-core fits.")
        k_max = cfg.k_max
        n, d = source.n, source.d
        shards = n_data_shards(mesh)
        # chain batching: replicated O(K) model math and per-tile bodies
        # lax.map over the leading chain axis (bitwise per chain; see
        # module docstring) — identity when C == 1
        cmap = _chain_map if multi else (lambda f: f)
        cshape = (n_chains,) if multi else ()
        n_local, tiles = tile_plan(n, shards, cfg.tile_size)
        if shards * n_local >= 2 ** 32:
            # >=, not >: at exactly 2**32 rows jnp.uint32(n) wraps to 0 in
            # the tile validity mask, which would silently zero all stats
            raise ValueError(
                f"N={n} ({shards * n_local} rows padded) exceeds the "
                "uint32 global point-index space: counter-based draws "
                "would wrap and silently corrupt the chain. Shard the fit "
                "across processes, or widen kernels/prng counters to "
                "uint64 first.")
        use_pallas = cfg.use_pallas

        model_specs, _ = state_partition_specs(family, P(axes))
        x_spec = P(axes, feat_axis)
        rep = P()

        # ---- the per-shard suff-stat accumulator: leading shard axis ----
        # built at full feature width; feature-sliced fields are sharded
        # over the model axis so each device's local slice matches the
        # local width its stats_from_labels partials produce
        acc_shape = jax.eval_shape(
            lambda: gibbs.empty_substats(family, k_max, d))
        feat_fields = set(family.feature_stat_fields if feat_axis else ())

        def leaf_spec(field, leaf):
            dims = ([None] if multi else []) + [axes] + [None] * leaf.ndim
            if field in feat_fields:
                dims[-1] = feat_axis
            return P(*dims)

        # specs depend only on field name and rank, so ONE spec tree (and
        # sharding tree) serves the dense k_max accumulator and every
        # compact k_c-row accumulator alike
        acc_specs = type(acc_shape)(**{
            f: leaf_spec(f, getattr(acc_shape, f))
            for f in acc_shape._fields})
        acc_shardings = type(acc_shape)(**{
            f: NamedSharding(mesh, getattr(acc_specs, f))
            for f in acc_shape._fields})

        @functools.lru_cache(maxsize=None)
        def zeros_acc_k(k: int):
            shape_k = jax.eval_shape(
                lambda: gibbs.empty_substats(family, k, d))
            return jax.jit(
                lambda: type(shape_k)(**{
                    f: jnp.zeros(cshape + (shards,)
                                 + getattr(shape_k, f).shape, jnp.float32)
                    for f in shape_k._fields}),
                out_shardings=acc_shardings)

        zeros_acc = zeros_acc_k(k_max)

        local = lambda acc: jax.tree.map(lambda v: v[0], acc)
        delocal = lambda acc: jax.tree.map(lambda v: v[None], acc)

        # ---- host-side point state and tile transfer ------------------
        # chain axis (when C > 1) leads the host label arrays and every
        # label tile; x tiles carry NO chain axis — one upload per tile,
        # consumed by all chains
        labels_h = np.zeros(cshape + (shards * n_local,), np.int32)
        sublabels_h = np.zeros(cshape + (shards * n_local,), np.int32)
        x_sharding = NamedSharding(mesh, x_spec)
        lab_spec = P(None, axes) if multi else P(axes)
        i32_sharding = NamedSharding(mesh, lab_spec)

        # every streamed read goes through the bounded retry path
        # (core/resilience.py): transient IOError/short-read/NaN-tile
        # faults re-read (the retried data is identical, so the chain is
        # bitwise untouched); persistent faults raise TileReadError with
        # tile provenance. Retry events land in FitResult.recoveries.
        retry = RetryPolicy(max_retries=cfg.io_retries,
                            backoff_s=cfg.io_backoff_s,
                            guard_nonfinite=cfg.guard_tiles)
        rec = _Recovery(cfg, family.name, 0)    # it_base fixed after init

        def put_x_tile(off: int, length: int):
            rows = np.concatenate(
                [read_block_checked(source, s * n_local + off,
                                    s * n_local + off + length, retry,
                                    on_event=rec.events.append)
                 for s in range(shards)], axis=0)
            return jax.device_put(rows, x_sharding)

        def put_label_tile(host, off: int, length: int):
            rows = np.concatenate(
                [host[..., s * n_local + off:s * n_local + off + length]
                 for s in range(shards)], axis=-1)
            return jax.device_put(rows, i32_sharding)

        def write_back(host, off: int, length: int, tile_out):
            rows = np.asarray(jax.device_get(tile_out))
            for s in range(shards):
                host[..., s * n_local + off:s * n_local + off + length] = (
                    rows[..., s * length:(s + 1) * length])

        def stream(pass_fn, carry, point_pass: bool):
            """Run ``pass_fn`` over all tiles with double-buffered
            device_put: tile i+1's transfer is issued right after tile i's
            compute is dispatched (dispatch is async), so it overlaps."""
            def load(i):
                off, length = tiles[i]
                xt = put_x_tile(off, length)
                pt = (put_label_tile(labels_h, off, length),
                      put_label_tile(sublabels_h, off, length)
                      ) if point_pass else None
                return xt, pt
            buf = load(0)
            for i, (off, length) in enumerate(tiles):
                xt, pt = buf
                out, carry = pass_fn(i, off, length, xt, pt, carry)
                if i + 1 < len(tiles):
                    buf = load(i + 1)       # overlaps the dispatched compute
                if out is not None:
                    lab_t, sub_t = out
                    write_back(labels_h, off, length, lab_t)
                    write_back(sublabels_h, off, length, sub_t)
            return carry

        # ---- jitted bodies (compiled once per distinct tile length) ----
        def tile_point(pt, off, length, x_t):
            lab, sub = pt
            gidx = gibbs.global_indices(n_local, axes, offset=off,
                                        length=length)
            valid = (gidx < jnp.uint32(n)).astype(x_t.dtype)
            return PointState(labels=lab, sublabels=sub, valid=valid), gidx

        def _sweep_tile(model, x_t, lab, sub, off, acc, comp=None):
            point, gidx = tile_point((lab, sub), off, x_t.shape[0], x_t)
            point, a = gibbs.sweep_tile(model, x_t, point, gidx, local(acc),
                                        family, use_pallas=use_pallas,
                                        feat_axis=feat_axis, plan=comp,
                                        k_block=cfg.k_block)
            return (point.labels, point.sublabels), delocal(a)

        def _sm_tile(plan, x_t, lab, sub, off, acc, comp=None):
            point, _ = tile_point((lab, sub), off, x_t.shape[0], x_t)
            point, a = splitmerge.split_merge_tile(
                plan, x_t, point, local(acc), family,
                use_pallas=use_pallas, feat_axis=feat_axis,
                compaction=comp)
            return (point.labels, point.sublabels), delocal(a)

        def _init1_tile(x_t, off, acc):
            gidx = gibbs.global_indices(n_local, axes, offset=off,
                                        length=x_t.shape[0])
            labels = _init_labels(gidx, cfg.init_clusters)
            valid = (gidx < jnp.uint32(n)).astype(x_t.dtype)
            a = gibbs.accumulate_substats(
                family, x_t, valid, labels, jnp.zeros_like(labels), k_max,
                local(acc), use_pallas)
            return (labels, jnp.zeros_like(labels)), delocal(a)

        def _init2_tile(means0, v0, x_t, lab, sub, off, acc):
            point, gidx = tile_point((lab, sub), off, x_t.shape[0], x_t)
            sublabels = splitmerge.hyperplane_bits(x_t, point.labels,
                                                   means0, v0, feat_axis)
            a = gibbs.accumulate_substats(
                family, x_t, point.valid, point.labels, sublabels, k_max,
                local(acc), use_pallas)
            return (point.labels, sublabels), delocal(a)

        def _finalize(acc):
            return gibbs.finalize_substats(family, local(acc), axes,
                                           feat_axis)

        # chain-mapped wrappers: per-chain tile/model bodies are the exact
        # single-chain bodies; x_t and the tile offset are closed over
        # (shared across chains — one upload, C consumers)
        def _sweep_tile_c(model, x_t, lab, sub, off, acc):
            return cmap(lambda m, l, s, a: _sweep_tile(m, x_t, l, s, off,
                                                       a))(model, lab, sub,
                                                           acc)

        def _sm_tile_c(plan, x_t, lab, sub, off, acc):
            return cmap(lambda pl, l, s, a: _sm_tile(pl, x_t, l, s, off,
                                                     a))(plan, lab, sub,
                                                         acc)

        # compacted variants: the per-chain CompactionPlan rides along as
        # a replicated operand; acc is the compact k_c-row accumulator
        def _sweep_tile_comp(model, x_t, lab, sub, off, comp, acc):
            return cmap(lambda m, l, s, c, a: _sweep_tile(
                m, x_t, l, s, off, a, c))(model, lab, sub, comp, acc)

        def _sm_tile_comp(plan, x_t, lab, sub, off, comp, acc):
            return cmap(lambda pl, l, s, c, a: _sm_tile(
                pl, x_t, l, s, off, a, c))(plan, lab, sub, comp, acc)

        def _init1_c(x_t, off, acc):
            return cmap(lambda a: _init1_tile(x_t, off, a))(acc)

        def _init2_c(means0, v0, x_t, lab, sub, off, acc):
            return cmap(lambda mn, v, l, s, a: _init2_tile(
                mn, v, x_t, l, s, off, a))(means0, v0, lab, sub, acc)

        lab_specs = (lab_spec, lab_spec)
        smap = functools.partial(shard_map, mesh=mesh)
        sweep_tile_fn = jax.jit(smap(
            _sweep_tile_c, in_specs=(model_specs, x_spec, *lab_specs, rep,
                                     acc_specs),
            out_specs=(lab_specs, acc_specs)))
        comp_specs = gibbs.CompactionPlan(rep, rep)
        sweep_tile_comp_fn = jax.jit(smap(
            _sweep_tile_comp,
            in_specs=(model_specs, x_spec, *lab_specs, rep, comp_specs,
                      acc_specs),
            out_specs=(lab_specs, acc_specs)))
        sm_tile_fn = None     # built lazily: needs the plan's pytree specs
        sm_tile_comp_fn = None
        finalize_fn = jax.jit(smap(
            cmap(_finalize), in_specs=(acc_specs,), out_specs=(rep, rep)))
        init1_fn = jax.jit(smap(
            _init1_c, in_specs=(x_spec, rep, acc_specs),
            out_specs=(lab_specs, acc_specs)))

        sweep_model_fn = jax.jit(cmap(functools.partial(
            gibbs.sweep_model, prior=prior, family=family,
            alpha=cfg.alpha)))
        plan_fn = jax.jit(cmap(lambda m: splitmerge.plan_split_merge(
            _move_key(m), m, prior, family, cfg.alpha,
            cfg.subreset_every)))
        advance_fn = jax.jit(cmap(
            lambda m: (m._replace(it=m.it + 1),
                       _summaries(m, prior, family, cfg.alpha))))

        rss0 = _rss_peak_bytes()
        keys = _chain_keys(key, n_chains) if multi else key
        if init_state is not None:
            # resume: the model is the whole chain state (labels are
            # recomputed from it every sweep), so the two init passes are
            # skipped and host labels start zeroed
            model = jax.device_put(_copy_state(init_state),
                                   NamedSharding(mesh, P()))
        else:
            # ---- initialization: two streamed passes ------------------
            acc = zeros_acc()
            acc = stream(
                lambda i, off, length, xt, pt, a:
                    init1_fn(xt, np.uint32(off), a),
                acc, point_pass=False)
            stats0, _ = finalize_fn(acc)
            means0 = jax.jit(cmap(family.cluster_means))(stats0)
            v0 = jax.jit(cmap(lambda k: splitmerge.hyperplane_vecs(
                jax.random.fold_in(k, 1), k_max, d, jnp.float32)))(keys)
            _init2 = jax.jit(smap(
                _init2_c, in_specs=(rep, rep, x_spec, *lab_specs, rep,
                                    acc_specs),
                out_specs=(lab_specs, acc_specs)))
            acc = zeros_acc()
            acc = stream(
                lambda i, off, length, xt, pt, a:
                    _init2(means0, v0, xt, *pt, np.uint32(off), a),
                acc, point_pass=True)
            stats, substats = finalize_fn(acc)
            model = jax.jit(cmap(lambda k, s, ss: _init_model(
                k, s, ss, prior=prior, family=family, cfg=cfg,
                k_max=k_max)))(keys, stats, substats)

        # ---- iteration loop: ModelState is the only persistent state ---
        set_stats_fn = jax.jit(cmap(
            lambda m, s, ss: m._replace(stats=s, substats=ss)))
        apply_plan_fn = jax.jit(cmap(
            lambda m, plan, s, ss: m._replace(
                active=plan.merge.new_active, stuck=plan.stuck,
                stats=s, substats=ss)))
        # compacted variants: scatter the finalized compact stats back to
        # the dense slab (pure scatter — bitwise the dense-fold stats)
        set_stats_comp_fn = jax.jit(cmap(
            lambda m, c, s, ss: m._replace(
                stats=gibbs.compact_scatter(c, k_max, s),
                substats=gibbs.compact_scatter(c, k_max, ss))))
        apply_plan_comp_fn = jax.jit(cmap(
            lambda m, plan, c, s, ss: m._replace(
                active=plan.merge.new_active, stuck=plan.stuck,
                stats=gibbs.compact_scatter(c, k_max, s),
                substats=gibbs.compact_scatter(c, k_max, ss))))
        comp_fns: Dict[int, Any] = {}

        def compact_plan_fn(k_c: int):
            if k_c not in comp_fns:
                comp_fns[k_c] = jax.jit(cmap(
                    lambda act: gibbs.compaction_plan(act, k_c)))
            return comp_fns[k_c]

        hist_rows: List[Dict[str, np.ndarray]] = []
        times: List[float] = []
        # persistent device buffers: double-buffered (x + label) tiles
        # (labels carry the chain axis; x is shared), the model (x2:
        # pre/post update), and the suff-stat accumulator
        tile_bytes = max(
            length * (d * 4 + n_chains * 2 * 4) * shards
            for _, length in tiles)
        est_peak = (2 * _tree_bytes(model) + _tree_bytes(zeros_acc())
                    + 2 * tile_bytes)
        # the split/merge gate runs on the TRUE iteration number (resume:
        # model.it > 0), matching the resident driver's model.it cond
        it0 = int(jax.device_get(model.it[0] if multi else model.it))
        rec._last_saved = it0           # checkpoint cadence counts from here
        # exact live cluster count (max over chains): known on host from
        # the per-iteration summary pull, so the tiled compact slab needs
        # no lax.cond fallback — sweeps cannot change K mid-pass, and the
        # split/merge fold is bounded by 2*k (splits at most double K)
        if init_state is not None:
            k0 = int(np.max(np.asarray(
                jax.device_get(init_state.active)).sum(axis=-1)))
        else:
            k0 = cfg.init_clusters
        # guardrails: same contract as the resident driver — separate
        # jitted verdict, pulled with the summary the loop syncs anyway.
        # Rollback restores the last healthy model; the stale host label
        # arrays are harmless (sweeps recompute labels from the model).
        health_fn = jax.jit(model_health) if cfg.guardrails else None
        snap = (jax.tree.map(jnp.copy, model), 0) if cfg.guardrails else None
        it = 0
        while it < iters:
            t0 = time.perf_counter()
            model = sweep_model_fn(model)
            k_c = (_k_compact(k0, 1, k_max, cfg.k_block)
                   if cfg.compact else None)
            if k_c is None:
                acc = stream(
                    lambda i, off, length, xt, pt, a:
                        sweep_tile_fn(model, xt, *pt, np.uint32(off), a),
                    zeros_acc(), point_pass=True)
                model = set_stats_fn(model, *finalize_fn(acc))
            else:
                comp = compact_plan_fn(k_c)(model.active)
                acc = stream(
                    lambda i, off, length, xt, pt, a:
                        sweep_tile_comp_fn(model, xt, *pt, np.uint32(off),
                                           comp, a),
                    zeros_acc_k(k_c)(), point_pass=True)
                model = set_stats_comp_fn(model, comp, *finalize_fn(acc))
            if it0 + it >= cfg.burnout:
                plan = plan_fn(model)
                if sm_tile_fn is None:
                    plan_specs = jax.tree.map(lambda _: rep, plan)
                    sm_tile_fn = jax.jit(smap(
                        _sm_tile_c,
                        in_specs=(plan_specs, x_spec, *lab_specs, rep,
                                  acc_specs),
                        out_specs=(lab_specs, acc_specs)))
                    sm_tile_comp_fn = jax.jit(smap(
                        _sm_tile_comp,
                        in_specs=(plan_specs, x_spec, *lab_specs, rep,
                                  comp_specs, acc_specs),
                        out_specs=(lab_specs, acc_specs)))
                k_c_sm = (_k_compact(k0, 2, k_max, cfg.k_block)
                          if cfg.compact else None)
                if k_c_sm is None:
                    acc = stream(
                        lambda i, off, length, xt, pt, a:
                            sm_tile_fn(plan, xt, *pt, np.uint32(off), a),
                        zeros_acc(), point_pass=True)
                    model = apply_plan_fn(model, plan, *finalize_fn(acc))
                else:
                    comp = compact_plan_fn(k_c_sm)(plan.merge.new_active)
                    acc = stream(
                        lambda i, off, length, xt, pt, a:
                            sm_tile_comp_fn(plan, xt, *pt, np.uint32(off),
                                            comp, a),
                        zeros_acc_k(k_c_sm)(), point_pass=True)
                    model = apply_plan_comp_fn(model, plan, comp,
                                               *finalize_fn(acc))
            model, summary = advance_fn(model)
            if health_fn is not None:
                summary, healthy = jax.device_get(
                    (summary, health_fn(model)))
                healthy = bool(healthy)
            else:
                summary = jax.device_get(summary)
                healthy = True
            if not healthy:
                snap_model, snap_it = snap
                rec.rollback(it0 + it + 1, it0 + snap_it,
                             "non-finite/degenerate model state after "
                             "tiled iteration")
                model = _recovery_rekey(
                    jax.tree.map(jnp.copy, snap_model), rec.n_rollbacks)
                it = snap_it
                k0 = int(np.max(np.asarray(
                    jax.device_get(snap_model.active)).sum(axis=-1)))
                continue            # diverged iteration leaves no rows
            k0 = int(np.max(np.asarray(summary["k"])))
            hist_rows.append(summary)
            times.append(time.perf_counter() - t0)
            it += 1
            if cfg.guardrails:
                snap = (jax.tree.map(jnp.copy, model), it)
            rec.maybe_checkpoint(model, it0 + it)
            if verbose:
                ks = np.asarray(summary["k"]).reshape(-1).tolist()
                print(f"iter {it0 + it:4d}  "
                      f"K={ks if len(ks) > 1 else ks[0]}  "
                      f"{times[-1] * 1e3:.1f} ms/iter")
        rec.maybe_checkpoint(model, it0 + it, force=True)

        history = {
            k: np.asarray([row[k] for row in hist_rows])
            for k in _HIST_KEYS} if hist_rows else {
            k: np.zeros((0,) + cshape) for k in _HIST_KEYS}
        if multi:
            history = {k: np.ascontiguousarray(v.T)
                       for k, v in history.items()}
        device_bytes = {
            "mode": "tiled",
            "tile_size": tiles[0][1],
            "est_peak_bytes": int(est_peak),
            **_peak_fields(rss0),
        }
        return self._result(model, labels_h[..., :n].copy(), history,
                            times, device_bytes, n_chains, rec.events)
