"""DPMM sampler state: a static-capacity pytree (DESIGN §6).

Chang & Fisher III's chain has unbounded K; under XLA every per-cluster
tensor is ``(K_max, ...)`` with an ``active`` mask. Sub-cluster quantities
carry an extra axis of size 2 (l/r), mirroring the paper's augmented space
(§2.3): every cluster k owns sub-clusters (k,l) and (k,r).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class DPMMState(NamedTuple):
    key: jax.Array            # PRNG key (replicated)
    it: jax.Array             # iteration counter ()
    active: jax.Array         # (K,) bool
    logweights: jax.Array     # (K,) log pi_k (-inf when inactive)
    sub_logweights: jax.Array  # (K, 2) log pi_bar_{k,{l,r}}
    stuck: jax.Array          # (K,) int32 sweeps since last accepted split
    params: Any               # component params, batch (K,)
    subparams: Any            # component params, batch (K, 2)
    stats: Any                # component suff-stats, batch (K,)
    substats: Any             # component suff-stats, batch (K, 2)
    labels: jax.Array         # (N_local,) int32  -- data-sharded
    sublabels: jax.Array      # (N_local,) int32 in {0, 1} -- data-sharded

    @property
    def k_hat(self) -> jax.Array:
        return jnp.sum(self.active.astype(jnp.int32))

    def summarize(self) -> dict:
        """Replicated scalar diagnostics, collected on-device per step by
        the chunked scan driver (core/sampler.py) so the host syncs once
        per chunk instead of once per iteration."""
        return {
            "k": self.k_hat,
            "max_cluster": jnp.max(
                jnp.where(self.active, self.stats.n, 0.0)),
            "min_cluster": jnp.min(
                jnp.where(self.active, self.stats.n, jnp.inf)),
        }


def summarize(state: DPMMState) -> dict:
    """Replicated scalar diagnostics for logging / history scans."""
    return state.summarize()
