"""DPMM sampler state, split along the paper's data plane (DESIGN §6).

Chang & Fisher III's chain has unbounded K; under XLA every per-cluster
tensor is ``(K_max, ...)`` with an ``active`` mask. Sub-cluster quantities
carry an extra axis of size 2 (l/r), mirroring the paper's augmented space
(§2.3): every cluster k owns sub-clusters (k,l) and (k,r).

The state is split into the two pieces the paper's §4.3 distribution story
actually distinguishes:

 - ``ModelState`` — everything O(K_max): weights, params, sufficient
   statistics, the PRNG key and iteration counter. Replicated on every
   device; this is the *only* state the iteration loop has to carry, and
   the only state that ever crosses the wire (as the psum of stats).
 - ``PointState`` — everything O(N): labels, sub-labels and the padding
   mask. Sharded over the data axes, and in tiled/out-of-core mode it
   lives with its tile on the host (data/source.py): only the current
   tile's slice is ever device-resident.

Per-point randomness is counter-based on the *global* point index
(kernels/prng.py), so any (model, point-tile) pairing reproduces the same
chain regardless of sharding or tiling.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.assign import NEG_INF


class ModelState(NamedTuple):
    """Replicated O(K_max) model-side state."""
    key: jax.Array            # PRNG key (replicated)
    it: jax.Array             # iteration counter ()
    active: jax.Array         # (K,) bool
    logweights: jax.Array     # (K,) log pi_k (-inf when inactive)
    sub_logweights: jax.Array  # (K, 2) log pi_bar_{k,{l,r}}
    stuck: jax.Array          # (K,) int32 sweeps since last accepted split
    params: Any               # component params, batch (K,)
    subparams: Any            # component params, batch (K, 2)
    stats: Any                # component suff-stats, batch (K,)
    substats: Any             # component suff-stats, batch (K, 2)

    @property
    def k_hat(self) -> jax.Array:
        return jnp.sum(self.active.astype(jnp.int32))

    def summarize(self) -> dict:
        """Replicated scalar diagnostics, collected per step by the drivers
        (core/sampler.py): on device by the resident chunked scan (one host
        sync per chunk), on host once per iteration by the tiled driver."""
        return {
            "k": self.k_hat,
            "max_cluster": jnp.max(
                jnp.where(self.active, self.stats.n, 0.0)),
            "min_cluster": jnp.min(
                jnp.where(self.active, self.stats.n, jnp.inf)),
        }


class PointState(NamedTuple):
    """Sharded O(N) per-point state; in tiled mode, one tile's slice."""
    labels: jax.Array         # (N_local,) int32
    sublabels: jax.Array      # (N_local,) int32 in {0, 1}
    valid: jax.Array          # (N_local,) float32 padding mask


def summarize(model: ModelState) -> dict:
    """Replicated scalar diagnostics for logging / history scans."""
    return model.summarize()


def grow_model(model: ModelState, new_k: int) -> ModelState:
    """Pad every O(K) leaf of ``model`` to a ``new_k``-slot slab — the
    ``k_max='auto'`` growth hook (core/sampler.py, resident plane).

    New slots arrive exactly as a dense chain's inactive slots look:
    inactive, log-zero weights, zero stuck counters and zero stats/params.
    Since ``sweep_model`` regenerates weights and params from the stats
    every iteration, the zero-padded params are overwritten before any
    point reads them. Growth happens only at scan-chunk boundaries, where
    the driver re-AOTs the chunk on the new shapes and re-donates the
    buffers. Handles both the single-chain (K, ...) and multi-chain
    (C, K, ...) leaf layouts (the K axis always follows the chain axis).
    """
    old_k = model.active.shape[-1]
    if new_k < old_k:
        raise ValueError(f"grow_model: cannot shrink {old_k} -> {new_k}")
    if new_k == old_k:
        return model
    k_axis = model.active.ndim - 1     # 0 single-chain, 1 multi-chain

    def pad(a, value=0):
        widths = [(0, 0)] * a.ndim
        widths[k_axis] = (0, new_k - old_k)
        return jnp.pad(a, widths, constant_values=value)

    zeros = lambda tree: jax.tree.map(pad, tree)
    return model._replace(
        active=pad(model.active, False),
        logweights=pad(model.logweights, NEG_INF),
        sub_logweights=pad(model.sub_logweights, math.log(0.5)),
        stuck=pad(model.stuck),
        params=zeros(model.params), subparams=zeros(model.subparams),
        stats=zeros(model.stats), substats=zeros(model.substats))
