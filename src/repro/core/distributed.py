"""Distribution plumbing for the DPMM sampler.

Mirrors the paper's §4.3: points, labels, and sub-labels live on their
owning shard ('the data never moves'); per-cluster parameters and
sufficient statistics are replicated, with a single psum per suff-stat
pass. Works on any mesh whose data axes partition N; the ``model`` axis
(when present and ``shard_features`` is on) shards the feature dimension of
the multinomial likelihood (DESIGN §2).
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:                                  # jax >= 0.6: top-level, 'check_vma'
    from jax import shard_map as _shard_map
    _CHECK_KW = "check_vma"
except ImportError:                   # jax 0.4/0.5: experimental, 'check_rep'
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, mesh: Mesh, in_specs, out_specs):
    """Version-portable ``shard_map`` with replication checking disabled
    (our out_specs mix replicated per-cluster state with sharded labels,
    which the checker cannot verify across psum/all_gather)."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: False})


def make_data_mesh(num_devices: Optional[int] = None) -> Mesh:
    """1-D mesh over all (or the first n) local devices, axis 'data'."""
    devs = jax.devices()
    if num_devices is not None:
        devs = devs[:num_devices]
    return Mesh(np.array(devs), axis_names=("data",))


def data_axes_of(mesh: Mesh) -> Tuple[str, ...]:
    """Axes that partition points: every axis except 'model'."""
    return tuple(a for a in mesh.axis_names if a != "model")


def n_data_shards(mesh: Mesh) -> int:
    """Number of shards the data axes partition points into."""
    return int(np.prod([mesh.shape[a] for a in data_axes_of(mesh)],
                       dtype=np.int64))


def tile_plan(n: int, n_shards: int, tile_size: Optional[int]
              ) -> Tuple[int, Sequence[Tuple[int, int]]]:
    """Per-shard tile layout for the streamed data plane.

    Returns ``(n_local, [(offset, length), ...])``: every data shard holds
    exactly ``n_local = ceil(n / n_shards)`` rows (the same padded layout
    ``shard_points`` produces for the resident plane, so global point
    indices — and therefore chains — match bitwise across planes), cut
    into tiles at STATS_BLOCK-aligned offsets. Alignment keeps the
    suff-stat block fold's float addition order identical for every tile
    size (core/gibbs.py); only the shard's ragged tail tile may be
    non-multiple. ``tile_size`` is rounded up to the alignment; ``None``
    picks a default sized for streaming (64 blocks).
    """
    from repro.core.gibbs import STATS_BLOCK
    n_local = -(-n // n_shards)
    if tile_size is None:
        tile_size = 64 * STATS_BLOCK
    tile = -(-tile_size // STATS_BLOCK) * STATS_BLOCK
    tile = min(tile, n_local)
    tiles = [(off, min(tile, n_local - off))
             for off in range(0, n_local, tile)]
    return n_local, tiles


def pad_to_multiple(x: np.ndarray, multiple: int):
    """Pad axis 0 to a multiple; returns (padded, valid_mask)."""
    n = x.shape[0]
    target = int(math.ceil(n / multiple) * multiple)
    valid = np.zeros((target,), np.float32)
    valid[:n] = 1.0
    if target == n:
        return x, valid
    pad = np.zeros((target - n,) + x.shape[1:], x.dtype)
    return np.concatenate([x, pad], axis=0), valid


def shard_points(mesh: Mesh, x: np.ndarray, shard_features: bool = False):
    """Place (N, d) points on the mesh; returns (x_sharded, valid_sharded)."""
    axes = data_axes_of(mesh)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    x_p, valid = pad_to_multiple(np.asarray(x), n_shards)
    feat = "model" if (shard_features and "model" in mesh.axis_names) else None
    xs = jax.device_put(x_p, NamedSharding(mesh, P(axes, feat)))
    vs = jax.device_put(valid, NamedSharding(mesh, P(axes)))
    return xs, vs


def replicated(mesh: Mesh, tree):
    return jax.device_put(tree, NamedSharding(mesh, P()))
