"""Normal-Inverse-Gamma conjugate component (diagonal-covariance Gaussian).

The fourth registered family (core/family.py): per-feature independent
Gaussians with conjugate NIG priors,

    tau_j ~ Gamma(a0, b0),   mu_j | tau_j ~ N(m_j, 1 / (kappa tau_j)),

i.e. the d=1 NIW specialized per coordinate. Unlike the full-covariance
Gaussian (core/niw.py), every quantity here — sufficient statistics,
log-likelihood, marginal — is a *sum over features*, so this family is
feature-separable: it supports the paper's high-d feature-sharded regime
(`shard_features=True`, DESIGN §10) that the full-covariance Mahalanobis
cannot. Cost per point is O(K d) instead of O(K d^2), making it the
scalable choice when d is large and off-diagonal structure is ignorable.

All functions are batched over an arbitrary leading cluster shape ``B``
(``(K,)`` for clusters, ``(K, 2)`` for sub-clusters), like the other
families.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.scipy.special import gammaln

LOG_2PI = 1.8378770664093453


class NIGPrior(NamedTuple):
    """Per-feature NIG hyper-parameters lambda = (m, kappa, a0, b0)."""
    m: jax.Array          # (d,) prior mean per feature
    kappa: jax.Array      # () mean-precision scaling
    a0: jax.Array         # () Gamma shape of the precision
    b0: jax.Array         # () Gamma rate of the precision


class DiagStats(NamedTuple):
    """Diagonal sufficient statistics: (n, sum x, sum x^2)."""
    n: jax.Array          # (*B,)
    sx: jax.Array         # (*B, d)
    sxx: jax.Array        # (*B, d)  -- per-feature, not the (d, d) outer


class DiagParams(NamedTuple):
    mu: jax.Array         # (*B, d)
    log_prec: jax.Array   # (*B, d)  log tau per feature


def default_prior(x_mean: jax.Array, kappa: float, a0: float,
                  b0: float) -> NIGPrior:
    """Weak prior centered on the data mean; (a0, b0) set the cluster scale
    (the d=1 NIW correspondence: a = nu/2, b = psi/2 — so a0=2, b0=0.5
    mirrors niw.default_prior's psi=1, nu=d+3 at d=1)."""
    dtype = x_mean.dtype
    return NIGPrior(m=x_mean, kappa=jnp.asarray(kappa, dtype),
                    a0=jnp.asarray(a0, dtype), b0=jnp.asarray(b0, dtype))


def build_prior(cfg, x) -> NIGPrior:
    """Family hook (core/family.py): prior from config + data."""
    mean = jnp.asarray(x.mean(axis=0), jnp.float32)
    return default_prior(mean, cfg.nig_kappa, cfg.nig_a0, cfg.nig_b0)


def param_struct() -> DiagParams:
    """Pytree template (leaves are placeholders) for spec-mapping."""
    return DiagParams(mu=0, log_prec=0)


def stats_struct() -> DiagStats:
    return DiagStats(n=0, sx=0, sxx=0)


def empty_stats(batch_shape: tuple, d: int, dtype=jnp.float32) -> DiagStats:
    return DiagStats(n=jnp.zeros(batch_shape, dtype),
                     sx=jnp.zeros(batch_shape + (d,), dtype),
                     sxx=jnp.zeros(batch_shape + (d,), dtype))


def stats_from_points(x: jax.Array, resp: jax.Array) -> DiagStats:
    n = jnp.sum(resp, axis=0)
    bshape = resp.shape[1:]
    r2 = resp.reshape(resp.shape[0], -1)
    sx = jnp.einsum("nb,nd->bd", r2, x)
    sxx = jnp.einsum("nb,nd->bd", r2, x * x)
    d = x.shape[-1]
    return DiagStats(n=n, sx=sx.reshape(bshape + (d,)),
                     sxx=sxx.reshape(bshape + (d,)))


def add_stats(a: DiagStats, b: DiagStats) -> DiagStats:
    return DiagStats(a.n + b.n, a.sx + b.sx, a.sxx + b.sxx)


def stats_from_labels(x: jax.Array, valid: jax.Array, labels: jax.Array,
                      sublabels: jax.Array, k_max: int) -> DiagStats:
    """(k_max, 2)-batched sub-cluster stats via segment-sum on the stacked
    [x, x^2] moments (no dense responsibilities; core/labelstats.py —
    same feature stacking as the family's Pallas fast path)."""
    from repro.core.labelstats import moments_from_labels
    d = x.shape[-1]
    n2, sf2 = moments_from_labels(jnp.concatenate([x, x * x], axis=-1),
                                  valid, labels, sublabels, k_max)
    return DiagStats(n=n2, sx=sf2[..., :d], sxx=sf2[..., d:])


def _pack_linear(params: DiagParams, d: int):
    """(w, const) of the expanded-quadratic linear form (cf. ``loglik``)."""
    prec = jnp.exp(params.log_prec)
    w = jnp.concatenate([prec * params.mu, -0.5 * prec], axis=-1)
    const = (0.5 * jnp.sum(params.log_prec, axis=-1)
             - 0.5 * jnp.sum(prec * params.mu * params.mu, axis=-1)
             - 0.5 * d * LOG_2PI)
    return w, const


def assign_pack(x: jax.Array, params: DiagParams):
    """Linear-likelihood packing for the fused assignment kernels:
    expanding (x - mu)^2 turns the quadratic into
    [x, x^2] @ [prec*mu, -prec/2]_b + const_b (cf. ``loglik``)."""
    feats = jnp.concatenate([x, x * x], axis=-1)
    return (feats,) + _pack_linear(params, x.shape[-1])


def sweep_pack(x: jax.Array, params: DiagParams, subparams: DiagParams):
    """One-read sweep packing (kernels/sweep.py): the [x, x^2] feature
    block is computed ONCE and shared by steps (e)/(f) and the stat fold
    (it is exactly the moment feature map of ``stats_from_labels``)."""
    feats = jnp.concatenate([x, x * x], axis=-1)
    d = x.shape[-1]
    return (feats,) + _pack_linear(params, d) + _pack_linear(subparams, d)


def stats_from_moments(n2: jax.Array, sf2: jax.Array) -> DiagStats:
    """Sub-cluster stats from the fused sweep's folded [x, x^2] moments."""
    d = sf2.shape[-1] // 2
    return DiagStats(n=n2, sx=sf2[..., :d], sxx=sf2[..., d:])


def posterior(prior: NIGPrior, stats: DiagStats):
    """NIG posterior hyper-parameters, per feature (the d=1 NIW update)."""
    kappa_n = prior.kappa + stats.n                          # (*B,)
    m_n = (prior.kappa * prior.m + stats.sx) / kappa_n[..., None]
    a_n = prior.a0 + 0.5 * stats.n                           # (*B,)
    # b_n = b0 + (sxx + kappa m^2 - kappa_n m_n^2) / 2  (1-d Psi update)
    b_n = prior.b0 + 0.5 * (stats.sxx + prior.kappa * prior.m ** 2
                            - kappa_n[..., None] * m_n ** 2)
    b_n = jnp.maximum(b_n, 1e-10)
    return m_n, kappa_n, a_n, b_n


def log_marginal(prior: NIGPrior, stats: DiagStats) -> jax.Array:
    """log f_x(C; lambda): product of per-feature NIG marginals.

    Per feature: Gamma(a_n)/Gamma(a0) * b0^a0 / b_n^a_n * sqrt(k/k_n)
    * (2 pi)^{-n/2}; summed over j (Murphy 2007 eq. 266 at d=1).
    """
    d = prior.m.shape[-1]
    m_n, kappa_n, a_n, b_n = posterior(prior, stats)
    del m_n
    per_feature = (gammaln(a_n)[..., None] - gammaln(prior.a0)
                   + prior.a0 * jnp.log(prior.b0)
                   - a_n[..., None] * jnp.log(b_n))
    return (jnp.sum(per_feature, axis=-1)
            + 0.5 * d * (jnp.log(prior.kappa) - jnp.log(kappa_n))
            - 0.5 * stats.n * d * LOG_2PI)


def sample_posterior(key: jax.Array, prior: NIGPrior,
                     stats: DiagStats) -> DiagParams:
    """(mu_j, tau_j) ~ NIG posterior, batched; O(K d) — no Cholesky."""
    m_n, kappa_n, a_n, b_n = posterior(prior, stats)
    k_t, k_m = jax.random.split(key)
    g = jnp.maximum(
        jax.random.gamma(k_t, jnp.broadcast_to(a_n[..., None], b_n.shape)),
        1e-30)
    log_prec = jnp.log(g) - jnp.log(b_n)                     # tau ~ G(a_n,b_n)
    z = jax.random.normal(k_m, m_n.shape, dtype=m_n.dtype)
    sd = jnp.exp(-0.5 * log_prec) / jnp.sqrt(kappa_n)[..., None]
    return DiagParams(mu=m_n + z * sd, log_prec=log_prec)


def expected_params(prior: NIGPrior, stats: DiagStats) -> DiagParams:
    m_n, kappa_n, a_n, b_n = posterior(prior, stats)
    del kappa_n
    return DiagParams(mu=m_n,
                      log_prec=jnp.log(a_n)[..., None] - jnp.log(b_n))


def loglik(x: jax.Array, params: DiagParams, matmul=None) -> jax.Array:
    """sum_j log N(x_j; mu_bj, 1/tau_bj) -> (N, *B), as two matmuls.

    Expanding (x - mu)^2 = x^2 - 2 x mu + mu^2 turns the quadratic into
    x^2 @ tau^T - 2 x @ (tau mu)^T + const_b — the same (N, d) x (d, B)
    matmul shape as the multinomial hot spot, and fully feature-separable
    (each term is a sum over j, so sharded slices psum correctly).

    ``matmul`` swaps the (N, d) x (d, B) contraction implementation (the
    family fast path passes the auto-selected kernel, kernels/ops.py).
    """
    mm = matmul if matmul is not None else jnp.matmul
    d = x.shape[-1]
    bshape = params.mu.shape[:-1]
    mu = params.mu.reshape(-1, d)
    prec = jnp.exp(params.log_prec.reshape(-1, d))
    quad = mm(x * x, prec.T) - 2.0 * mm(x, (prec * mu).T)
    const = (0.5 * jnp.sum(params.log_prec.reshape(-1, d), axis=-1)
             - 0.5 * jnp.sum(prec * mu * mu, axis=-1)
             - 0.5 * d * LOG_2PI)
    out = const[None, :] - 0.5 * quad
    return out.reshape((x.shape[0],) + bshape)
