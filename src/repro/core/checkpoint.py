"""ModelState checkpointing: npz round-trip with bitwise-resume parity.

The ``ModelState`` *is* the whole chain state: every per-point quantity
(labels, sub-labels) is recomputed from the model at the start of each
sweep, and all randomness derives from ``(state.key, state.it)`` via
``fold_in`` — so checkpointing the O(K_max) model alone is enough to
resume a fit bitwise-identically (``DPMM.fit(x, iters, init_state=m)``;
verified in tests/test_multichain.py). A multi-chain state (leading chain
axis on every leaf, ``fit(..., n_chains=C)``) round-trips the same way.

Format: a plain ``np.savez`` archive — one entry per pytree leaf in
flatten order, plus metadata (format version, family name, PRNG impl).
The pytree *structure* is not serialized; it is rebuilt from the family's
``param_struct``/``stats_struct`` templates, so a checkpoint is portable
across processes and jax versions as long as the family definition
matches (the leaf count is checked and mismatches fail loudly). The PRNG
key is stored as its raw ``key_data`` words and re-wrapped on load —
typed key arrays are not npz-serializable.

This is also the hand-off format to the serving path: ``DPMMEngine``
(serve/dpmm.py) loads a checkpoint and answers queries from it.
"""
from __future__ import annotations

import io
from typing import Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.family import ComponentFamily, get_family
from repro.core.state import ModelState

FORMAT_VERSION = 1
_META = ("__version__", "__family__", "__impl__")


def _template(family: ComponentFamily) -> ModelState:
    """A placeholder ModelState with the family's exact pytree structure
    (leaf values are irrelevant — only the treedef is used)."""
    return ModelState(
        key=0, it=0, active=0, logweights=0, sub_logweights=0, stuck=0,
        params=family.param_struct(), subparams=family.param_struct(),
        stats=family.stats_struct(), substats=family.stats_struct())


def _key_impl(key: jax.Array) -> str:
    try:
        return str(jax.random.key_impl(key))
    except Exception:
        return "threefry2x32"


def save_model(path: Union[str, io.IOBase], model: ModelState,
               family: Union[str, ComponentFamily]) -> None:
    """Write ``model`` (single- or multi-chain) to ``path`` as npz."""
    name = family if isinstance(family, str) else family.name
    get_family(name)                     # fail early on unknown family
    raw = model._replace(key=jax.random.key_data(model.key))
    leaves, _ = jax.tree_util.tree_flatten(raw)
    arrs = {f"leaf_{i:04d}": np.asarray(jax.device_get(leaf))
            for i, leaf in enumerate(leaves)}
    np.savez(path, __version__=np.int64(FORMAT_VERSION),
             __family__=np.str_(name),
             __impl__=np.str_(_key_impl(model.key)), **arrs)


def load_model(path: Union[str, io.IOBase]
               ) -> Tuple[ModelState, ComponentFamily]:
    """Read a checkpoint; returns ``(model, family)``. Leaves come back
    bit-for-bit (npz stores raw array bytes)."""
    with np.load(path, allow_pickle=False) as z:
        version = int(z["__version__"])
        if version > FORMAT_VERSION:
            raise ValueError(
                f"checkpoint format v{version} is newer than this code "
                f"(v{FORMAT_VERSION})")
        family = get_family(str(z["__family__"]))
        impl = str(z["__impl__"])
        treedef = jax.tree_util.tree_structure(_template(family))
        names = sorted(k for k in z.files if k not in _META)
        if len(names) != treedef.num_leaves:
            raise ValueError(
                f"checkpoint has {len(names)} leaves but family "
                f"{family.name!r} expects {treedef.num_leaves} — family "
                "definition drifted since this checkpoint was written")
        leaves = [jnp.asarray(z[k]) for k in names]
    model = jax.tree_util.tree_unflatten(treedef, leaves)
    return model._replace(
        key=jax.random.wrap_key_data(model.key, impl=impl)), family
