"""ModelState checkpointing: atomic, checksummed npz with bitwise resume.

The ``ModelState`` *is* the whole chain state: every per-point quantity
(labels, sub-labels) is recomputed from the model at the start of each
sweep, and all randomness derives from ``(state.key, state.it)`` via
``fold_in`` — so checkpointing the O(K_max) model alone is enough to
resume a fit bitwise-identically (``DPMM.fit(x, iters, init_state=m)``;
verified in tests/test_multichain.py). A multi-chain state (leading chain
axis on every leaf, ``fit(..., n_chains=C)``) round-trips the same way.

Format: a plain ``np.savez`` archive — one entry per pytree leaf in
flatten order, plus metadata (format version, family name, PRNG impl, and
since v2 a per-leaf CRC32 vector). The pytree *structure* is not
serialized; it is rebuilt from the family's ``param_struct`` /
``stats_struct`` templates, so a checkpoint is portable across processes
and jax versions as long as the family definition matches (leaf count AND
leaf shapes are validated — mismatches fail loudly). The PRNG key is
stored as its raw ``key_data`` words and re-wrapped on load — typed key
arrays are not npz-serializable.

Durability (a long fit must survive its own checkpoint writes):

 - **Atomic writes.** ``save_model`` writes to a same-directory temp
   file, fsyncs it, and ``os.replace``s it into place — a crash or
   SIGKILL mid-write can never leave a half-written file under the final
   name, only a stale ``*.tmp-*`` to garbage-collect.
 - **Verified reads.** Every leaf's CRC32 is stored in the archive and
   re-checked by ``load_model``; a truncated, bit-flipped, or otherwise
   unreadable checkpoint raises a typed :class:`CheckpointCorrupt`
   instead of handing back garbage state.
 - **Rotation + latest-valid resolution.** ``save_checkpoint`` writes
   ``{prefix}-{it:08d}.npz`` and keeps the newest ``keep`` members;
   ``latest_valid`` walks the rotation newest-first and returns the first
   member that *verifies*, so one corrupt file costs one checkpoint
   interval, not the fit.

This is also the hand-off format to the serving path: ``DPMMEngine``
(serve/dpmm.py) loads a checkpoint — checksums verified — and answers
queries from it.
"""
from __future__ import annotations

import glob
import io
import os
import re
import struct
import zipfile
import zlib
from typing import List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.family import ComponentFamily, get_family
from repro.core.state import ModelState

FORMAT_VERSION = 2
_META = ("__version__", "__family__", "__impl__", "__crc__")
# errors np.load / zipfile raise on truncated or garbled archives — all of
# them mean "this file is not a readable checkpoint"
_READ_ERRORS = (OSError, EOFError, ValueError, KeyError,
                zipfile.BadZipFile, struct.error)


class CheckpointCorrupt(RuntimeError):
    """A checkpoint file exists but fails verification: unreadable npz,
    CRC mismatch, missing/extra leaves, or leaf shapes inconsistent with
    the family template. Never returned as state — always raised."""


class CheckpointNotFound(FileNotFoundError):
    """No checkpoint (or no *valid* checkpoint in a rotation) at the
    requested path/prefix."""


def _template(family: ComponentFamily) -> ModelState:
    """A placeholder ModelState with the family's exact pytree structure
    (leaf values are irrelevant — only the treedef is used)."""
    return ModelState(
        key=0, it=0, active=0, logweights=0, sub_logweights=0, stuck=0,
        params=family.param_struct(), subparams=family.param_struct(),
        stats=family.stats_struct(), substats=family.stats_struct())


def _key_impl(key: jax.Array) -> str:
    """PRNG impl name for the metadata entry. The only legitimate
    fallback is a jax too old to expose ``key_impl`` (or a raw uint32
    key that has no impl to report) — anything else propagates."""
    try:
        impl_fn = jax.random.key_impl
    except AttributeError:            # jax predates jax.random.key_impl
        return "threefry2x32"
    try:
        return str(impl_fn(key))
    except TypeError:                 # raw (non-typed) key array
        return "threefry2x32"


def normalize_path(path: str) -> str:
    """The one place the ``.npz`` suffix is normalized: ``np.savez``
    silently appends ``.npz`` to bare paths, so ``save_model('ckpt')``
    used to write ``ckpt.npz`` that ``load_model('ckpt')`` could not
    find. Both spellings now resolve to the same file."""
    return path if path.endswith(".npz") else path + ".npz"


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _model_to_arrays(model: ModelState, name: str) -> dict:
    raw = model._replace(key=jax.random.key_data(model.key))
    leaves, _ = jax.tree_util.tree_flatten(raw)
    arrs = {f"leaf_{i:04d}": np.asarray(jax.device_get(leaf))
            for i, leaf in enumerate(leaves)}
    crcs = np.asarray([_crc(arrs[k]) for k in sorted(arrs)], np.uint32)
    return dict(__version__=np.int64(FORMAT_VERSION),
                __family__=np.str_(name),
                __impl__=np.str_(_key_impl(model.key)),
                __crc__=crcs, **arrs)


def save_model(path: Union[str, io.IOBase], model: ModelState,
               family: Union[str, ComponentFamily]) -> Optional[str]:
    """Write ``model`` (single- or multi-chain) to ``path`` as npz.

    String paths are normalized to the ``.npz`` suffix and written
    atomically: temp file in the same directory, fsync, ``os.replace``.
    Returns the final path (None for file objects, which are written
    directly — no atomicity is possible on a caller-owned stream).
    """
    name = family if isinstance(family, str) else family.name
    get_family(name)                     # fail early on unknown family
    entries = _model_to_arrays(model, name)
    if not isinstance(path, str):
        np.savez(path, **entries)
        return None
    final = normalize_path(path)
    tmp = f"{final}.tmp-{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **entries)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    _fsync_dir(os.path.dirname(final) or ".")
    return final


def _fsync_dir(dirname: str) -> None:
    """Best-effort directory fsync so the rename itself is durable."""
    try:
        fd = os.open(dirname, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _validate_shapes(model: ModelState, family: ComponentFamily,
                     where: str) -> None:
    """Leaf-*shape* validation against the ModelState layout conventions:
    every leaf must agree on the (optional chain, K) leading axes, so a
    single- vs multi-chain mix (or a tampered leaf) fails with a clear
    message instead of surfacing as a shape error deep inside fit()."""
    active = np.asarray(model.active)
    if active.ndim not in (1, 2):
        raise CheckpointCorrupt(
            f"{where}: 'active' has rank {active.ndim} "
            f"(shape {tuple(active.shape)}); expected (K,) single-chain "
            "or (C, K) multi-chain")
    base = tuple(active.shape)           # (K,) or (C, K)
    chain = base[:-1]                    # () or (C,)

    def check(name, leaf, want, exact):
        got = tuple(np.asarray(leaf).shape)
        lead = got[:len(want)]
        ok = got == want if exact else lead == want
        if not ok:
            raise CheckpointCorrupt(
                f"{where}: leaf {name!r} has shape {got}, expected "
                f"{'exactly' if exact else 'leading dims'} {want} to "
                f"match active {base} — single- vs multi-chain mismatch, "
                "or a checkpoint written by a drifted family definition")

    check("it", model.it, chain, exact=True)
    check("key", model.key, chain, exact=False)   # + trailing impl words
    check("logweights", model.logweights, base, exact=True)
    check("stuck", model.stuck, base, exact=True)
    check("sub_logweights", model.sub_logweights, base + (2,), exact=True)
    for group, extra in (("params", ()), ("stats", ()),
                         ("subparams", (2,)), ("substats", (2,))):
        tree = getattr(model, group)
        for i, leaf in enumerate(jax.tree_util.tree_leaves(tree)):
            check(f"{group}[{i}]", leaf, base + extra, exact=False)


def load_model(path: Union[str, io.IOBase]
               ) -> Tuple[ModelState, ComponentFamily]:
    """Read and *verify* a checkpoint; returns ``(model, family)``.
    Leaves come back bit-for-bit (npz stores raw array bytes; every
    leaf's CRC32 is re-checked). Raises :class:`CheckpointNotFound` if
    the file does not exist and :class:`CheckpointCorrupt` on any
    verification failure — never garbage state."""
    where = path if isinstance(path, str) else "<stream>"
    if isinstance(path, str):
        path = normalize_path(path) if (not os.path.exists(path)
                                        and os.path.exists(
                                            normalize_path(path))) else path
        if not os.path.exists(path):
            raise CheckpointNotFound(
                f"no checkpoint at {where!r} (or {normalize_path(where)!r})")
        where = path
    try:
        with np.load(path, allow_pickle=False) as z:
            version = int(z["__version__"])
            if version > FORMAT_VERSION:
                raise CheckpointCorrupt(
                    f"{where}: checkpoint format v{version} is newer than "
                    f"this code (v{FORMAT_VERSION})")
            family = get_family(str(z["__family__"]))
            impl = str(z["__impl__"])
            treedef = jax.tree_util.tree_structure(_template(family))
            names = sorted(k for k in z.files if k not in _META)
            if len(names) != treedef.num_leaves:
                raise CheckpointCorrupt(
                    f"{where}: checkpoint has {len(names)} leaves but "
                    f"family {family.name!r} expects {treedef.num_leaves} "
                    "— family definition drifted since this checkpoint "
                    "was written")
            arrs = [z[k] for k in names]   # forces the (CRC-checked) read
            if version >= 2:
                crcs = np.asarray(z["__crc__"])
                if crcs.shape != (len(names),):
                    raise CheckpointCorrupt(
                        f"{where}: __crc__ has shape {crcs.shape}, "
                        f"expected ({len(names)},)")
                for name, arr, want in zip(names, arrs, crcs):
                    got = _crc(arr)
                    if got != int(want):
                        raise CheckpointCorrupt(
                            f"{where}: CRC mismatch on {name}: stored "
                            f"{int(want):#010x}, recomputed {got:#010x} — "
                            "the file was truncated or bit-flipped on "
                            "disk")
    except CheckpointCorrupt:
        raise
    except _READ_ERRORS as e:
        raise CheckpointCorrupt(
            f"{where}: unreadable checkpoint archive "
            f"({type(e).__name__}: {e})") from e
    model = jax.tree_util.tree_unflatten(treedef,
                                         [jnp.asarray(a) for a in arrs])
    _validate_shapes(model, family, str(where))
    return model._replace(
        key=jax.random.wrap_key_data(model.key, impl=impl)), family


def dumps_model(model: ModelState, component: str) -> bytes:
    """Serialize ``model`` to checkpoint-format bytes (CRC'd npz).

    The in-memory twin of :func:`save_model` — used by the distributed
    driver (repro.dist) to ship ModelState over the wire each sweep with
    the exact on-disk guarantees: raw array bytes (lossless, so the
    worker sees the coordinator's model bit-for-bit), per-leaf CRC32,
    and typed-PRNG-key round-tripping via :func:`loads_model`."""
    buf = io.BytesIO()
    save_model(buf, model, component)
    return buf.getvalue()


def loads_model(data: bytes) -> Tuple[ModelState, ComponentFamily]:
    """Inverse of :func:`dumps_model`; verifies CRCs like
    :func:`load_model` and raises :class:`CheckpointCorrupt` on any
    truncation or bit flip."""
    return load_model(io.BytesIO(data))


# ---------------------------------------------------------------------------
# Rotation: {prefix}-{it:08d}.npz members, newest-valid resolution
# ---------------------------------------------------------------------------
_ROT_RE = re.compile(r"-(\d{8})\.npz$")


def checkpoint_member(prefix: str, it: int) -> str:
    return f"{prefix}-{int(it):08d}.npz"


def list_checkpoints(prefix: str) -> List[Tuple[int, str]]:
    """All rotation members under ``prefix``, newest (highest it) first."""
    out = []
    for p in glob.glob(glob.escape(prefix) + "-" + "[0-9]" * 8 + ".npz"):
        m = _ROT_RE.search(p)
        if m:
            out.append((int(m.group(1)), p))
    return sorted(out, reverse=True)


def save_checkpoint(prefix: str, model: ModelState,
                    family: Union[str, ComponentFamily], it: int,
                    keep: int = 3) -> str:
    """Atomically write rotation member ``{prefix}-{it:08d}.npz`` and
    prune members beyond the newest ``keep`` (the write lands before any
    prune, so the rotation never transits through an empty state)."""
    if keep < 1:
        raise ValueError(f"keep must be >= 1, got {keep}")
    path = save_model(checkpoint_member(prefix, it), model, family)
    for _, old in list_checkpoints(prefix)[keep:]:
        try:
            os.unlink(old)
        except OSError:
            pass
    return path


def latest_valid(prefix: str
                 ) -> Tuple[ModelState, ComponentFamily, str, int]:
    """Newest rotation member that *verifies*: walks ``{prefix}-*.npz``
    newest-first, skipping corrupt members (one bad file costs one
    checkpoint interval, not the fit). Returns
    ``(model, family, path, it)``; raises :class:`CheckpointNotFound`
    when the rotation is empty or nothing verifies."""
    members = list_checkpoints(prefix)
    corrupt = []
    for it, path in members:
        try:
            model, family = load_model(path)
        except CheckpointCorrupt as e:
            corrupt.append(str(e))
            continue
        return model, family, path, it
    if corrupt:
        raise CheckpointNotFound(
            f"no valid checkpoint under prefix {prefix!r}: all "
            f"{len(corrupt)} member(s) failed verification — "
            + "; ".join(corrupt))
    raise CheckpointNotFound(
        f"no checkpoint members matching {prefix!r}-########.npz")


def resolve_model(path: str
                  ) -> Tuple[ModelState, ComponentFamily, str, int]:
    """Load a model from ``path`` interpreted as EITHER a single
    checkpoint file or an auto-checkpoint rotation prefix — the one
    resolution rule shared by the serving layer's ``from_checkpoint``
    and ``engine.swap`` (serve/dpmm.py), so both accept exactly what a
    fit writes (``checkpoint_path``) without the caller knowing which
    flavor it was.

    A plain file loads directly; otherwise the newest rotation member
    that *verifies* is used (:func:`latest_valid` — a torn or corrupt
    newest member falls back through the rotation). Returns
    ``(model, family, resolved_path, it)`` where ``resolved_path`` is
    the actual file served and ``it`` its iteration counter. Raises
    :class:`CheckpointCorrupt` for a named file that fails verification
    (refusing to serve garbage beats guessing) and
    :class:`CheckpointNotFound` when neither interpretation matches.
    """
    try:
        model, family = load_model(path)
    except CheckpointNotFound:
        if not list_checkpoints(path):
            raise
        return latest_valid(path)
    it = int(np.max(np.asarray(jax.device_get(model.it))))
    resolved = path if os.path.exists(path) else normalize_path(path)
    return model, family, resolved, it
