"""Normal-Inverse-Wishart conjugate component (Gaussian observations).

Implements the per-cluster math of the sub-cluster sampler (paper §2.3, §4):
sufficient statistics, posterior-parameter computation, posterior sampling
(Bartlett decomposition), point log-likelihoods, and the log marginal
likelihood used in the split/merge Hastings ratios (paper eqs. 12, 20, 21).

All functions are written for a *batch of clusters*: stats carry an
arbitrary leading shape ``B`` (``(K,)`` for clusters, ``(K, 2)`` for
sub-clusters) so one code path serves both.

Numerical conventions:
 - we store the Cholesky factor of the *precision* ``chol_prec`` (lower),
   so the likelihood is a whitening matmul (MXU-friendly: this is exactly
   the paper's `dcolwise_dot_all` hot spot), and
 - ``logdet_prec = log det Sigma^{-1} = 2 sum(log diag(chol_prec))``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.scipy.special import gammaln, multigammaln

LOG_2PI = 1.8378770664093453


class NIWPrior(NamedTuple):
    """Hyper-parameters (paper eq. 9): lambda = (m, Psi, kappa, nu)."""
    m: jax.Array          # (d,)
    psi: jax.Array        # (d, d) SPD scale matrix
    kappa: jax.Array      # ()
    nu: jax.Array         # ()


class GaussStats(NamedTuple):
    """Sufficient statistics of a point set: (n, sum x, sum x x^T)."""
    n: jax.Array          # (*B,)
    sx: jax.Array         # (*B, d)
    sxx: jax.Array        # (*B, d, d)


class GaussParams(NamedTuple):
    mu: jax.Array         # (*B, d)
    chol_prec: jax.Array  # (*B, d, d) lower Cholesky of Sigma^{-1}
    logdet_prec: jax.Array  # (*B,)


def default_prior(x_mean: jax.Array, psi_diag: jax.Array, kappa: float,
                  nu: float) -> NIWPrior:
    """Weak prior centered on the data mean (paper Example 3).

    ``psi_diag`` sets the IW scale; the reference DPMMSubClusters examples
    use Psi ~ I (cluster-scale, NOT data-scale — a data-covariance Psi
    strongly favors few large clusters, see paper Example 3).
    """
    d = x_mean.shape[-1]
    psi = jnp.eye(d, dtype=x_mean.dtype) * jnp.maximum(psi_diag, 1e-6)
    return NIWPrior(m=x_mean, psi=psi, kappa=jnp.asarray(kappa, x_mean.dtype),
                    nu=jnp.asarray(nu, x_mean.dtype))


def build_prior(cfg, x) -> NIWPrior:
    """Family hook (core/family.py): prior from config + data."""
    mean = jnp.asarray(x.mean(axis=0), jnp.float32)
    psi_diag = jnp.full((x.shape[1],), cfg.niw_psi, jnp.float32)
    return default_prior(mean, psi_diag, cfg.niw_kappa,
                         x.shape[1] + cfg.niw_nu_extra)


def param_struct() -> GaussParams:
    """Pytree template (leaves are placeholders) for spec-mapping."""
    return GaussParams(mu=0, chol_prec=0, logdet_prec=0)


def stats_struct() -> GaussStats:
    return GaussStats(n=0, sx=0, sxx=0)


def empty_stats(batch_shape: tuple, d: int, dtype=jnp.float32) -> GaussStats:
    return GaussStats(
        n=jnp.zeros(batch_shape, dtype),
        sx=jnp.zeros(batch_shape + (d,), dtype),
        sxx=jnp.zeros(batch_shape + (d, d), dtype),
    )


def _outer_flat(x: jax.Array) -> jax.Array:
    """(N, d) -> (N, d*d) flattened per-point outer products, materialized
    EXPLICITLY so the second-moment fold is the two-operand contraction
    ``resp^T @ xx`` whatever the segment-axis width. Folding the 3-operand
    ``ns,nd,ne->sde`` einsum directly lets XLA pick a width-dependent
    fused lowering whose reduction bits differ between small and large
    segment counts — which would break the sparse-K contract (the compact
    K_active-width fold must be bitwise the dense k_max-width fold, see
    core/gibbs.compaction_plan). At large widths XLA's own lowering IS
    this two-step, so dense-slab chains keep their exact bits."""
    return (x[:, :, None] * x[:, None, :]).reshape(x.shape[0], -1)


def stats_from_points(x: jax.Array, resp: jax.Array) -> GaussStats:
    """Stats under a (soft/hard) assignment matrix.

    x: (N, d); resp: (N, *B) one-hot-ish weights. Returns stats with batch
    shape B. These are the masked matmuls the Pallas suffstats kernel
    implements on TPU (kernels/suffstats.py); this is the jnp path.
    """
    n = jnp.sum(resp, axis=0)
    bshape = resp.shape[1:]
    r2 = resp.reshape(resp.shape[0], -1)           # (N, prod(B))
    sx = jnp.einsum("nb,nd->bd", r2, x)
    sxx = jnp.einsum("nb,nX->bX", r2, _outer_flat(x))
    d = x.shape[-1]
    return GaussStats(n=n, sx=sx.reshape(bshape + (d,)),
                      sxx=sxx.reshape(bshape + (d, d)))


def add_stats(a: GaussStats, b: GaussStats) -> GaussStats:
    return GaussStats(a.n + b.n, a.sx + b.sx, a.sxx + b.sxx)


def stats_from_labels(x: jax.Array, valid: jax.Array, labels: jax.Array,
                      sublabels: jax.Array, k_max: int) -> GaussStats:
    """(k_max, 2)-batched sub-cluster stats straight from int labels.

    One (N, 2K) one-hot over segments s = 2*label + sublabel replaces the
    old resp (N, K) + subresp (N, K, 2) pair — cluster stats are the fold
    over the sub axis (core/gibbs.compute_stats), so clusters and
    sub-clusters come from ONE einsum pass. The second-moment fold needs
    the one-hot operand (sxx is a masked x^T x — there is no segment-sum
    form that avoids per-point outer products); those outer products are
    materialized explicitly (``_outer_flat``) so the fold is a
    width-oblivious two-operand gemm — required for sparse-K compaction
    to be bitwise (see _outer_flat). The (N, d, d) temporary is bounded:
    this runs per STATS_BLOCK block inside the one-read sweep, and the
    real fix is the Pallas kernel (kernels/suffstats.py), which builds
    the one-hot per tile in VMEM and accumulates sxx without any HBM
    temporary. This is the jnp oracle / non-TPU path.
    """
    seg = labels * 2 + sublabels
    r2 = (jax.nn.one_hot(seg, 2 * k_max, dtype=x.dtype)
          * valid.astype(x.dtype)[:, None])          # (N, 2K)
    n2 = jnp.sum(r2, axis=0)
    sx2 = jnp.einsum("ns,nd->sd", r2, x)
    sxx2 = jnp.einsum("ns,nX->sX", r2, _outer_flat(x))
    d = x.shape[-1]
    return GaussStats(n=n2.reshape(k_max, 2),
                      sx=sx2.reshape(k_max, 2, d),
                      sxx=sxx2.reshape(k_max, 2, d, d))


def sweep_pack(params: GaussParams, subparams: GaussParams):
    """One-read sweep packing (kernels/sweep.py): the Gaussian megakernel
    takes the raw whitening fields — (K, d[,d]) cluster params and the
    (K, 2, d[,d]) sub-cluster block — with x itself as the resident
    feature block (the stat fold consumes the same x for its moments)."""
    return (params.mu, params.chol_prec, params.logdet_prec,
            subparams.mu, subparams.chol_prec, subparams.logdet_prec)


def stats_from_moments(n2: jax.Array, sx2: jax.Array,
                       sxx2: jax.Array) -> GaussStats:
    """Sub-cluster stats from the fused sweep's folded moment partials."""
    return GaussStats(n=n2, sx=sx2, sxx=sxx2)


def posterior(prior: NIWPrior, stats: GaussStats):
    """NIW posterior hyper-parameters given sufficient statistics."""
    n = stats.n[..., None]
    kappa_n = prior.kappa + stats.n
    nu_n = prior.nu + stats.n
    m_n = (prior.kappa * prior.m + stats.sx) / kappa_n[..., None]
    # Psi_n = Psi + sum xx^T + kappa m m^T - kappa_n m_n m_n^T
    psi_n = (prior.psi + stats.sxx
             + prior.kappa * jnp.einsum("...d,...e->...de", prior.m, prior.m)
             - kappa_n[..., None, None]
             * jnp.einsum("...d,...e->...de", m_n, m_n))
    # symmetrize for numerical safety
    psi_n = 0.5 * (psi_n + jnp.swapaxes(psi_n, -1, -2))
    del n
    return m_n, psi_n, kappa_n, nu_n


def _log_z(psi: jax.Array, kappa: jax.Array, nu: jax.Array, d: int):
    """log of the NIW normalizer (terms that do not cancel in ratios)."""
    sign, logdet = jnp.linalg.slogdet(psi)
    del sign
    return (-0.5 * nu * logdet - 0.5 * d * jnp.log(kappa)
            + multigammaln(0.5 * nu, d) + 0.5 * nu * d * jnp.log(2.0))


def log_marginal(prior: NIWPrior, stats: GaussStats) -> jax.Array:
    """log f_x(C; lambda): marginal likelihood of the point set (paper eq. 13).

    Murphy (2007) eq. 266:  pi^{-nd/2} * Z(post) / Z(prior).
    """
    d = prior.m.shape[-1]
    m_n, psi_n, kappa_n, nu_n = posterior(prior, stats)
    del m_n
    prior_z = _log_z(prior.psi, prior.kappa, prior.nu, d)
    post_z = _log_z(psi_n, kappa_n, nu_n, d)
    # (2 pi)^{-nd/2} from the Gaussian likelihood; its 2^{-nd/2} cancels the
    # IW normalizers' 2^{nu d/2} growth leaving Murphy's pi^{-nd/2} form.
    # (Verified against quadrature + the student-t chain rule in
    # tests/test_conjugates.py; the constant cancels inside every Hastings
    # ratio, so it only matters for standalone marginals.)
    return post_z - prior_z - 0.5 * stats.n * d * jnp.log(2.0 * jnp.pi)


def sample_posterior(key: jax.Array, prior: NIWPrior,
                     stats: GaussStats) -> GaussParams:
    """Sample (mu, Sigma) ~ NIW posterior, batched over leading dims.

    Uses the Bartlett decomposition of the Wishart for Sigma^{-1}:
        Sigma^{-1} = (L A)(L A)^T,  L = chol(Psi_n^{-1}),
    so the returned ``chol_prec`` feeds the whitening likelihood directly.
    """
    m_n, psi_n, kappa_n, nu_n = posterior(prior, stats)
    d = prior.m.shape[-1]
    bshape = stats.n.shape

    k_a, k_b, k_mu = jax.random.split(key, 3)
    # Bartlett factor A: diag sqrt(chi2(nu - i)), strict lower N(0,1)
    i = jnp.arange(d, dtype=m_n.dtype)
    df = jnp.maximum(nu_n[..., None] - i, 1e-3)             # (*B, d)
    chi = 2.0 * jax.random.gamma(k_a, 0.5 * df)             # chi2(df)
    a_diag = jnp.sqrt(chi)
    normals = jax.random.normal(k_b, bshape + (d, d), dtype=m_n.dtype)
    tril = jnp.tril(normals, k=-1)
    a_mat = tril + jnp.einsum(
        "...d,de->...de", a_diag, jnp.eye(d, dtype=m_n.dtype))
    # L = chol(Psi_n^{-1}) computed via chol(Psi_n):  Psi_n = C C^T
    #  => Psi_n^{-1} = C^{-T} C^{-1}; chol(Psi_n^{-1}) = C^{-T} (upper-tri
    # transpose trick). We use solve_triangular against C^T.
    eye = jnp.broadcast_to(jnp.eye(d, dtype=m_n.dtype), psi_n.shape)
    jitter = 1e-5 * jnp.trace(psi_n, axis1=-2, axis2=-1)[..., None, None] / d
    c = jnp.linalg.cholesky(psi_n + jitter * eye)
    # l_inv_t = C^{-T}: solve C^T X = I  (upper triangular system)
    l = jax.scipy.linalg.solve_triangular(
        jnp.swapaxes(c, -1, -2), eye, lower=False)          # = C^{-T}
    chol_prec_full = l @ a_mat                              # (*B, d, d)
    # chol_prec_full is lower-triangular only if l is; C^{-T} is upper... so
    # (L A) is not triangular. We only need Sigma^{-1} = F F^T with any F, and
    # logdet from the triangular pieces:
    logdet_prec = (2.0 * jnp.sum(jnp.log(jnp.abs(a_diag)), axis=-1)
                   - 2.0 * jnp.sum(
                       jnp.log(jnp.diagonal(c, axis1=-2, axis2=-1)), axis=-1))
    # mu | Sigma ~ N(m_n, Sigma / kappa_n):
    #   mu = m_n + F^{-T} z / sqrt(kappa_n) with Sigma^{-1} = F F^T
    z = jax.random.normal(k_mu, bshape + (d,), dtype=m_n.dtype)
    # Solve F^T u = z  =>  u = F^{-T} z ; F is dense -> use linalg.solve on
    # F^T (d small; batched). Cost O(K d^3), the paper's 'sample params' step.
    u = jnp.linalg.solve(
        jnp.swapaxes(chol_prec_full, -1, -2), z[..., None])[..., 0]
    mu = m_n + u / jnp.sqrt(kappa_n)[..., None]
    return GaussParams(mu=mu, chol_prec=chol_prec_full,
                       logdet_prec=logdet_prec)


def expected_params(prior: NIWPrior, stats: GaussStats) -> GaussParams:
    """Posterior-mean parameters (deterministic; used for init/debug)."""
    m_n, psi_n, kappa_n, nu_n = posterior(prior, stats)
    d = prior.m.shape[-1]
    sigma = psi_n / jnp.maximum(nu_n - d - 1.0, 1.0)[..., None, None]
    eye = jnp.broadcast_to(jnp.eye(d, dtype=m_n.dtype), sigma.shape)
    c = jnp.linalg.cholesky(sigma + 1e-6 * eye)
    f = jax.scipy.linalg.solve_triangular(
        jnp.swapaxes(c, -1, -2), eye, lower=False)
    logdet_prec = -2.0 * jnp.sum(
        jnp.log(jnp.diagonal(c, axis1=-2, axis2=-1)), axis=-1)
    return GaussParams(mu=m_n, chol_prec=f, logdet_prec=logdet_prec)


def loglik(x: jax.Array, params: GaussParams) -> jax.Array:
    """log N(x; mu_b, Sigma_b) for all points x (N,d) and clusters b (*B,).

    Returns (N, *B). This is the O(N K d^2) hot spot; the TPU path is
    kernels/loglik.py, this jnp version is its oracle and the dry-run path.
    """
    # y = F^T (x - mu)  with Sigma^{-1} = F F^T
    diff = x[:, None, :] - params.mu.reshape(1, -1, params.mu.shape[-1])
    f = params.chol_prec.reshape(-1, *params.chol_prec.shape[-2:])
    y = jnp.einsum("nbd,bde->nbe", diff, f)
    maha = jnp.sum(y * y, axis=-1)
    d = x.shape[-1]
    out = 0.5 * (params.logdet_prec.reshape(1, -1) - maha) - 0.5 * d * LOG_2PI
    return out.reshape((x.shape[0],) + params.mu.shape[:-1])
