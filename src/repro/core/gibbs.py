"""Restricted Gibbs sweep (paper §4.1 steps a-f), shard_map-ready.

The sweep runs *inside* ``shard_map``: points/labels are local shards, all
per-cluster quantities are replicated. The only cross-device communication
is the ``psum`` of sufficient statistics at the end of the sweep — the
paper's 'we never transfer data; only sufficient statistics and parameters'
property (§4.3).

Per-point randomness is a counter-based Threefry draw keyed on the *global*
point index (kernels/prng.py), so chains are bitwise identical under any
sharding (DESIGN §2, assumption 3) AND identical between the fused Pallas
assignment kernels and the jnp reference path.

The hot path itself lives behind the ``ComponentFamily`` dispatch
(core/family.py): ``family.assign`` (step e), ``family.sub_assign``
(step f, own-cluster only) and ``family.stats_from_labels``. This module
never materializes dense responsibilities or an (N, K, 2) sub-cluster
log-likelihood — step (f) costs O(N T), not O(N K T), on every path.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core.family import NEG_INF  # noqa: F401  (re-export: sampler)
from repro.core.state import DPMMState
from repro.kernels import prng


def psum_tree(tree: Any, axes: Tuple[str, ...]):
    if not axes:
        return tree
    return jax.tree.map(lambda a: jax.lax.psum(a, axes), tree)


def global_indices(n_local: int, axes: Tuple[str, ...]) -> jax.Array:
    """Global point indices of this shard (0..N-1 ordering over the mesh).

    Assumes every data shard holds exactly ``n_local`` points —
    ``distributed.shard_points`` guarantees it by padding N up to a multiple
    of the data-shard count — so this shard's offset is simply
    ``axis_index(axes) * n_local``.
    """
    base = jnp.arange(n_local, dtype=jnp.uint32)
    if not axes:
        return base
    idx = jax.lax.axis_index(axes)  # linearized index over the given axes
    return idx.astype(jnp.uint32) * jnp.uint32(n_local) + base


def sample_weights(key: jax.Array, active: jax.Array, nk: jax.Array,
                   alpha: float) -> jax.Array:
    """Step (a): (pi_1..pi_K, pi~) ~ Dir(N_1..N_K, alpha); returns log pi.

    Inactive slots get -inf. The alpha-slot mass is sampled but unused by the
    *restricted* sampler (it never assigns to a new cluster) — it only
    rescales, and the assignment softmax renormalizes anyway; we keep it for
    faithfulness to eq. (14).
    """
    k = active.shape[0]
    conc = jnp.where(active, jnp.maximum(nk, 1e-2), 1.0)
    g = jax.random.gamma(key, jnp.concatenate(
        [conc, jnp.array([alpha], conc.dtype)]))
    g = jnp.maximum(g, 1e-30)
    total = jnp.sum(jnp.where(jnp.append(active, True), g, 0.0))
    logpi = jnp.log(g[:k]) - jnp.log(total)
    return jnp.where(active, logpi, NEG_INF)


def sample_subweights(key: jax.Array, active: jax.Array, nkl: jax.Array,
                      nkr: jax.Array, alpha: float) -> jax.Array:
    """Step (b): (pi_kl, pi_kr) ~ Dir(N_kl + a/2, N_kr + a/2) per cluster."""
    ga = jax.random.gamma(key, jnp.stack(
        [nkl + alpha / 2.0, nkr + alpha / 2.0], axis=-1))
    ga = jnp.maximum(ga, 1e-30)
    logw = jnp.log(ga) - jnp.log(jnp.sum(ga, axis=-1, keepdims=True))
    return jnp.where(active[:, None], logw, jnp.log(0.5))


def compute_stats(family, x: jax.Array, valid: jax.Array, labels: jax.Array,
                  sublabels: jax.Array, k_max: int,
                  axes: Tuple[str, ...], feat_axis=None,
                  use_pallas: bool = False):
    """Suff-stats of clusters and sub-clusters from (sharded) labels + psum.

    This is the paper's 3-step suff-stat update (§4.4): label-indexed local
    accumulation (the Pallas suffstats kernels on TPU; segment-sum /
    one-hot einsum otherwise — family.stats_from_labels), then ONE
    cross-shard psum of the (K, 2, ...) sub-cluster stats. Cluster stats
    are the exact fold of the sub-cluster stats over the l/r axis (every
    point belongs to exactly one sub-cluster of its cluster), computed
    *after* the psum — so the wire carries O(K * T) floats once, half of
    what psumming clusters and sub-clusters separately moved.

    ``feat_axis``: the feature dim of x is additionally sharded over this
    mesh axis (high-d mode, DESIGN §10): the family's feature-sliced stats
    fields are all-gathered along features after the data-axis psum — still
    O(K * d). Only ``family.feature_shardable`` families support this.
    """
    substats = family.stats_from_labels(x, valid, labels, sublabels, k_max,
                                        use_pallas=use_pallas)
    substats = psum_tree(substats, axes)
    if feat_axis is not None:
        substats = family.gather_feature_stats(substats, feat_axis)
    stats = jax.tree.map(lambda a: jnp.sum(a, axis=1), substats)
    return stats, substats


def sweep(state: DPMMState, x: jax.Array, valid: jax.Array, prior, family,
          alpha: float, axes: Tuple[str, ...],
          use_pallas: bool = False, feat_axis=None) -> DPMMState:
    """One restricted Gibbs sweep (steps a-f). Runs under shard_map."""
    key = jax.random.fold_in(state.key, state.it)
    k_w, k_sw, k_p, k_sp, k_z, k_zb = jax.random.split(key, 6)

    # (a) cluster weights  (b) sub-cluster weights
    logw = sample_weights(k_w, state.active, state.stats.n, alpha)
    sublogw = sample_subweights(
        k_sw, state.active, state.substats.n[:, 0], state.substats.n[:, 1],
        alpha)

    # (c) cluster params  (d) sub-cluster params  — replicated O(K d^3)
    params = family.sample_posterior(k_p, prior, state.stats)
    subparams = family.sample_posterior(k_sp, prior, state.substats)

    # (e) cluster assignments: z_i ~ pi_k f(x_i; theta_k)  over *existing* k
    # — the O(N K T) hot spot, fused through the family dispatch
    gidx = global_indices(x.shape[0], axes)
    labels = family.assign(x, params, logw, state.active, gidx,
                           prng.key_words(k_z), use_pallas=use_pallas,
                           feat_axis=feat_axis)

    # (f) sub-cluster assignments under the point's OWN cluster only: O(N T)
    sublabels = family.sub_assign(x, subparams, sublogw, labels, gidx,
                                  prng.key_words(k_zb),
                                  use_pallas=use_pallas, feat_axis=feat_axis)

    # suff-stats + the one cross-shard reduction
    stats, substats = compute_stats(
        family, x, valid, labels, sublabels, state.active.shape[0], axes,
        feat_axis, use_pallas)

    return state._replace(
        logweights=logw, sub_logweights=sublogw, params=params,
        subparams=subparams, stats=stats, substats=substats,
        labels=labels, sublabels=sublabels)
