"""Restricted Gibbs sweep (paper §4.1 steps a-f), shard_map- and tile-ready.

The sweep is split along the model/point state boundary (core/state.py):

 - ``sweep_model`` — steps (a)-(d): replicated O(K) weight/parameter
   resampling from the current sufficient statistics.
 - ``sweep_tile`` — steps (e)/(f) plus suff-stat accumulation for one
   contiguous tile of points. Per-point randomness is a counter-based
   Threefry draw keyed on the *global* point index (kernels/prng.py), so
   the tile decomposition is a pure performance knob: resident (one tile =
   the whole local shard), out-of-core streamed tiles, and any data
   sharding all produce bitwise-identical chains.
 - ``finalize_substats`` — the ONE cross-device reduction: a psum of the
   (K, 2, ...) sub-cluster stats (paper §4.3: 'we never transfer data;
   only sufficient statistics and parameters').

Sufficient statistics are *additive*, so tiles fold partial stats into a
running accumulator. To make the fold bitwise-independent of the tile size,
every path accumulates in fixed ``STATS_BLOCK``-point blocks, left to
right in global point order: any tile size that is a multiple of
``STATS_BLOCK`` (the driver rounds — data/source.py) produces the exact
same float addition sequence as the resident single-tile pass.

The hot path itself lives behind the ``ComponentFamily`` dispatch
(core/family.py): ``family.sweep`` runs steps (e) + (f) + the stat fold in
ONE pass over the tile (Pallas megakernel, kernels/sweep.py, or the
blocked scan reference), so each tile of x is read from HBM exactly once
per sweep. This module never materializes dense responsibilities or an
(N, K, 2) sub-cluster log-likelihood — step (f) costs O(N T), not
O(N K T), on every path.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.family import NEG_INF  # noqa: F401  (re-export: sampler)
from repro.core.family import fold_blocked
from repro.core.state import ModelState, PointState
from repro.kernels import prng
# Granularity of the suff-stat fold (canonical home: kernels/sweep.py,
# where the one-read megakernels emit per-block stat partials). Tiles are
# STATS_BLOCK-aligned (except a shard's ragged tail), so the accumulation
# order — and therefore every float in the chain — is identical for all
# tile sizes, including the resident whole-shard "tile". Changing this
# constant changes chains.
from repro.kernels.sweep import STATS_BLOCK  # noqa: F401  (re-exported)


def psum_tree(tree: Any, axes: Tuple[str, ...]):
    if not axes:
        return tree
    return jax.tree.map(lambda a: jax.lax.psum(a, axes), tree)


def global_indices(n_local: int, axes: Tuple[str, ...],
                   offset: Any = 0, length: Optional[int] = None
                   ) -> jax.Array:
    """Global point indices of a tile of this shard (0..N-1 over the mesh).

    Assumes every data shard holds exactly ``n_local`` points —
    ``distributed.shard_points`` / the tiled layout guarantee it by padding
    N up to a multiple of the data-shard count — so this shard's base is
    simply ``axis_index(axes) * n_local``. ``offset``/``length`` select a
    tile of the shard (``offset`` may be a traced scalar so tile functions
    compile once per tile *length*, not per tile).
    """
    length = n_local if length is None else length
    base = jnp.uint32(offset) + jnp.arange(length, dtype=jnp.uint32)
    if not axes:
        return base
    idx = jax.lax.axis_index(axes)  # linearized index over the given axes
    return idx.astype(jnp.uint32) * jnp.uint32(n_local) + base


def sample_weights(key: jax.Array, active: jax.Array, nk: jax.Array,
                   alpha: float) -> jax.Array:
    """Step (a): (pi_1..pi_K, pi~) ~ Dir(N_1..N_K, alpha); returns log pi.

    Inactive slots get -inf. The alpha-slot mass is sampled but unused by the
    *restricted* sampler (it never assigns to a new cluster) — it only
    rescales, and the assignment softmax renormalizes anyway; we keep it for
    faithfulness to eq. (14).
    """
    k = active.shape[0]
    conc = jnp.where(active, jnp.maximum(nk, 1e-2), 1.0)
    g = jax.random.gamma(key, jnp.concatenate(
        [conc, jnp.array([alpha], conc.dtype)]))
    g = jnp.maximum(g, 1e-30)
    total = jnp.sum(jnp.where(jnp.append(active, True), g, 0.0))
    logpi = jnp.log(g[:k]) - jnp.log(total)
    return jnp.where(active, logpi, NEG_INF)


def sample_subweights(key: jax.Array, active: jax.Array, nkl: jax.Array,
                      nkr: jax.Array, alpha: float) -> jax.Array:
    """Step (b): (pi_kl, pi_kr) ~ Dir(N_kl + a/2, N_kr + a/2) per cluster."""
    ga = jax.random.gamma(key, jnp.stack(
        [nkl + alpha / 2.0, nkr + alpha / 2.0], axis=-1))
    ga = jnp.maximum(ga, 1e-30)
    logw = jnp.log(ga) - jnp.log(jnp.sum(ga, axis=-1, keepdims=True))
    return jnp.where(active[:, None], logw, jnp.log(0.5))


# ---------------------------------------------------------------------------
# Active-set compaction: sweep cost O(K_active), not O(k_max)
# ---------------------------------------------------------------------------
class CompactionPlan(NamedTuple):
    """Gather/scatter index pair between the dense ``k_max`` slab and a
    compact ``K_active``-sized slab.

    ``slot_of_compact``: (k_c,) int32 — dense slot id of each compact row,
    active slots first in ascending slot order (a stable sort), then
    inactive pad slots. Because the order is the slot order, first-max
    argmax ties resolve identically on both slabs, and because the Gumbel
    counters are the SLOT ids (not the compact positions), the compacted
    sweep is a pure gather/scatter around arithmetic that is bitwise the
    dense sweep's.

    ``compact_of_slot``: (k_max,) int32 — inverse map (compact position of
    each dense slot; positions >= k_c for slots outside the plan).
    """
    slot_of_compact: jax.Array
    compact_of_slot: jax.Array


def compaction_plan(active: jax.Array, k_c: int) -> CompactionPlan:
    """Build the compact<->dense index pair from the active mask.

    ``k_c`` (static) must be >= the number of active slots for the compact
    sweep to be exact — callers either know k_hat (tiled driver, host
    loop) or guard with ``lax.cond`` on ``k_hat <= k_c`` (resident chunks,
    where K may grow mid-chunk via splits).
    """
    order = jnp.argsort(jnp.logical_not(active), stable=True
                        ).astype(jnp.int32)
    return CompactionPlan(slot_of_compact=order[:k_c],
                          compact_of_slot=jnp.argsort(order
                                                      ).astype(jnp.int32))


def compact_gather(plan: CompactionPlan, tree: Any) -> Any:
    """Gather the compact rows of a (k_max, ...)-leading pytree."""
    return jax.tree.map(lambda a: jnp.take(a, plan.slot_of_compact, axis=0),
                        tree)


def compact_scatter(plan: CompactionPlan, k_max: int, tree: Any) -> Any:
    """Scatter a compact (k_c, ...)-leading pytree back onto the dense
    slab. Slots outside the plan get zeros — exactly what the dense sweep
    computes for inactive slots (no points ever assign to them), so the
    scattered stats are bitwise the dense-slab stats."""
    return jax.tree.map(
        lambda a: jnp.zeros((k_max,) + a.shape[1:], a.dtype
                            ).at[plan.slot_of_compact].set(a), tree)


# ---------------------------------------------------------------------------
# Tile-foldable suff-stat accumulation
# ---------------------------------------------------------------------------
def empty_substats(family, k_max: int, d: int):
    """Zero (k_max, 2)-batched sub-cluster stats accumulator (local
    feature width ``d`` — the slice width in feature-sharded mode)."""
    return family.empty_stats((k_max, 2), d)


def accumulate_substats(family, x: jax.Array, valid: jax.Array,
                        labels: jax.Array, sublabels: jax.Array,
                        k_max: int, acc, use_pallas: bool = False):
    """Fold this tile's sub-cluster stat partials into ``acc``.

    Partials are computed per STATS_BLOCK-point block and added left to
    right in point order, so the float addition sequence — hence every bit
    of the resulting stats — is invariant to how points are tiled, as long
    as tile boundaries are STATS_BLOCK-aligned (the last tile of a shard
    may be ragged; its trailing partial block folds last either way).

    Delegates to ``family.fold_blocked`` — the ONE implementation of the
    chain-critical blocked fold (the labels here are already known, so
    the per-block body is the identity) — rather than duplicating its
    scan/tail logic.
    """
    _, _, acc = fold_blocked(family, k_max,
                             lambda xb, vb, lb, sb: (lb, sb),
                             x, valid, (labels, sublabels), acc,
                             use_pallas=use_pallas)
    return acc


def finalize_substats(family, substats, axes: Tuple[str, ...],
                      feat_axis=None):
    """psum the folded sub-cluster stats, then derive cluster stats.

    This is the paper's 3-step suff-stat update (§4.4): label-indexed local
    accumulation, then ONE cross-shard psum of the (K, 2, ...) sub-cluster
    stats. Cluster stats are the exact fold of the sub-cluster stats over
    the l/r axis (every point belongs to exactly one sub-cluster of its
    cluster), computed *after* the psum — so the wire carries O(K * T)
    floats once, half of what psumming clusters and sub-clusters separately
    would move.

    ``feat_axis``: the feature dim of x is additionally sharded over this
    mesh axis (high-d mode, DESIGN §10): the family's feature-sliced stats
    fields are all-gathered along features after the data-axis psum — still
    O(K * d). Only ``family.feature_shardable`` families support this.
    """
    substats = psum_tree(substats, axes)
    if feat_axis is not None:
        substats = family.gather_feature_stats(substats, feat_axis)
    stats = jax.tree.map(lambda a: jnp.sum(a, axis=1), substats)
    return stats, substats


def compute_stats(family, x: jax.Array, valid: jax.Array, labels: jax.Array,
                  sublabels: jax.Array, k_max: int,
                  axes: Tuple[str, ...], feat_axis=None,
                  use_pallas: bool = False):
    """Suff-stats of clusters and sub-clusters from (sharded) labels + psum
    — the whole-shard (single-tile) composition of the accumulate/finalize
    pair above."""
    acc = empty_substats(family, k_max, x.shape[-1])
    acc = accumulate_substats(family, x, valid, labels, sublabels, k_max,
                              acc, use_pallas)
    return finalize_substats(family, acc, axes, feat_axis)


# ---------------------------------------------------------------------------
# The sweep, split into model-side and tile-side halves
# ---------------------------------------------------------------------------
def sweep_keys(model: ModelState):
    """The six per-sweep keys, derived from (key, it) only — so the tiled
    driver's separate model/tile calls reconstruct the exact keys the
    resident fused sweep uses."""
    key = jax.random.fold_in(model.key, model.it)
    return jax.random.split(key, 6)   # k_w, k_sw, k_p, k_sp, k_z, k_zb


def sweep_model(model: ModelState, prior, family, alpha: float
                ) -> ModelState:
    """Steps (a)-(d): replicated O(K) weights + params resampling."""
    k_w, k_sw, k_p, k_sp, _, _ = sweep_keys(model)
    logw = sample_weights(k_w, model.active, model.stats.n, alpha)
    sublogw = sample_subweights(
        k_sw, model.active, model.substats.n[:, 0], model.substats.n[:, 1],
        alpha)
    params = family.sample_posterior(k_p, prior, model.stats)
    subparams = family.sample_posterior(k_sp, prior, model.substats)
    return model._replace(logweights=logw, sub_logweights=sublogw,
                          params=params, subparams=subparams)


def sweep_tile(model: ModelState, x: jax.Array, point: PointState,
               gidx: jax.Array, acc, family,
               use_pallas: bool = False, feat_axis=None, *,
               fused: bool = True, plan: Optional[CompactionPlan] = None,
               k_block: Optional[int] = None) -> Tuple[PointState, Any]:
    """Steps (e)/(f) + suff-stat fold for one tile of points, reading each
    block of x from HBM exactly ONCE (``ComponentFamily.sweep``: the
    Pallas megakernel or the blocked scan reference — e, f, and the stat
    partial all run while the block is resident).

    ``gidx`` carries the tile's global point indices; all randomness is
    counter-based on them, so this body is oblivious to which tile (or
    shard) it is running on. ``fused=False`` runs the pre-fusion
    three-pass body — kept as the parity oracle (tests/benchmarks): both
    produce bitwise-identical chains, the fused body just streams x once
    instead of three times.

    ``plan`` (optional): the active-set compaction. The tile runs on the
    gathered K_active-row slab — O(N K_active) work instead of
    O(N k_max) — with the dense SLOT ids as Gumbel counters; ``acc`` must
    then be compact-shaped (``empty_substats(family, k_c, d)``) and the
    caller scatters the finalized stats back (``compact_scatter``).
    Returned labels are ALWAYS dense slot ids, plan or not, so everything
    downstream (split/merge, scoring, serving) is oblivious to
    compaction. ``k_block`` tunes the megakernel's streamed cluster tile.
    """
    _, _, _, _, k_z, k_zb = sweep_keys(model)
    if plan is None:
        k_eff = model.active.shape[0]
        params, subparams = model.params, model.subparams
        logw, sublogw = model.logweights, model.sub_logweights
        active, slots = model.active, None
    else:
        k_eff = plan.slot_of_compact.shape[0]
        params = compact_gather(plan, model.params)
        subparams = compact_gather(plan, model.subparams)
        logw = compact_gather(plan, model.logweights)
        sublogw = compact_gather(plan, model.sub_logweights)
        active = compact_gather(plan, model.active)
        slots = plan.slot_of_compact.astype(jnp.uint32)

    if not fused:
        # (e) cluster assignments over *existing* k — pass 1 over x
        labels = family.assign(x, params, logw, active, gidx,
                               prng.key_words(k_z), use_pallas=use_pallas,
                               feat_axis=feat_axis, slots=slots)
        # (f) sub-assignment under the OWN cluster only — pass 2 over x
        sublabels = family.sub_assign(
            x, subparams, sublogw, labels, gidx, prng.key_words(k_zb),
            use_pallas=use_pallas, feat_axis=feat_axis)
        # suff-stat fold — pass 3 over x
        acc = accumulate_substats(family, x, point.valid, labels,
                                  sublabels, k_eff, acc, use_pallas)
    else:
        labels, sublabels, acc = family.sweep(
            x, point.valid, params, subparams, logw, sublogw, active, gidx,
            prng.key_words(k_z), prng.key_words(k_zb), k_eff, acc,
            use_pallas=use_pallas, feat_axis=feat_axis, slots=slots,
            k_block=k_block)
    if plan is not None:       # compact positions -> dense slot ids
        labels = jnp.take(plan.slot_of_compact, labels)
    return point._replace(labels=labels, sublabels=sublabels), acc


def sweep(model: ModelState, point: PointState, x: jax.Array, prior, family,
          alpha: float, axes: Tuple[str, ...],
          use_pallas: bool = False, feat_axis=None, *,
          k_compact: Optional[int] = None,
          k_block: Optional[int] = None
          ) -> Tuple[ModelState, PointState]:
    """One restricted Gibbs sweep (steps a-f), whole shard as a single
    tile. Runs under shard_map; the resident driver's hot loop.

    ``k_compact`` (static): run the tile on a compacted K_active slab of
    this size. The model-side steps (a)-(d) stay dense (their RNG draw
    shapes depend on k_max), a ``CompactionPlan`` is emitted from the
    post-resample active mask, and the finalized stats scatter back to
    the dense slab — bitwise the dense sweep. If the live cluster count
    exceeds ``k_compact`` (mid-chunk splits), a ``lax.cond`` falls back
    to the dense-slab tile.
    """
    model = sweep_model(model, prior, family, alpha)
    gidx = global_indices(x.shape[0], axes)
    k_max = model.active.shape[0]

    def run(plan):
        k_eff = k_max if plan is None else plan.slot_of_compact.shape[0]
        acc = empty_substats(family, k_eff, x.shape[-1])
        point2, acc = sweep_tile(model, x, point, gidx, acc, family,
                                 use_pallas=use_pallas,
                                 feat_axis=feat_axis, plan=plan,
                                 k_block=k_block)
        stats, substats = finalize_substats(family, acc, axes, feat_axis)
        if plan is not None:
            stats = compact_scatter(plan, k_max, stats)
            substats = compact_scatter(plan, k_max, substats)
        return model._replace(stats=stats, substats=substats), point2

    if k_compact is None or k_compact >= k_max:
        return run(None)
    plan = compaction_plan(model.active, k_compact)
    return jax.lax.cond(model.k_hat <= k_compact,
                        lambda: run(plan), lambda: run(None))


def refine_sweep(model: ModelState, x: jax.Array, valid: jax.Array,
                 prior, family, alpha: float, *, decay: float,
                 use_pallas: bool = False,
                 k_block: Optional[int] = None
                 ) -> Tuple[ModelState, jax.Array]:
    """One ONLINE micro-batch sweep: steps (a)-(f) on a batch of fresh
    points, folded into the model as an exponentially decayed suff-stat
    update — the serving layer's refinement body (serve/dpmm.py).

    The fit's sweep recomputes stats from ALL points each iteration; at
    serve time the training set is gone and the batch is a stream sample,
    so instead of replacing the stats we blend:

        stats <- decay * stats + batch_stats

    i.e. the posterior tracks an exponentially weighted window of
    traffic (effective mass ~ batch / (1 - decay)), and the model drifts
    toward the live distribution instead of jumping to whatever the last
    micro-batch looked like. Steps (a)-(d) are the standard O(K)
    resample (so weights/params stay posterior draws under the blended
    stats), steps (e)/(f) run the real ``sweep_tile`` body on the batch
    (``valid`` masks padded rows out of the fold). The active set is
    FIXED — no split/merge proposals on traffic; refinement tracks
    drift within the discovered clusters, a swap installs new structure.

    Per-point randomness is counter-based on the batch row index, and
    the (key, it) pair drives the sweep keys exactly like a fit
    iteration — ``it`` advances per refinement sweep, so successive
    micro-batches draw fresh randomness.

    Returns ``(model, labels)`` — labels in dense slot space.
    """
    model = sweep_model(model, prior, family, alpha)
    k_max = model.active.shape[0]
    gidx = jnp.arange(x.shape[0], dtype=jnp.uint32)
    point = PointState(labels=jnp.zeros((x.shape[0],), jnp.int32),
                       sublabels=jnp.zeros((x.shape[0],), jnp.int32),
                       valid=valid.astype(jnp.float32))
    acc = empty_substats(family, k_max, x.shape[-1])
    point, acc = sweep_tile(model, x, point, gidx, acc, family,
                            use_pallas=use_pallas, k_block=k_block)
    batch_stats, batch_substats = finalize_substats(family, acc, ())
    w = jnp.float32(decay)
    blend = lambda old, new: jax.tree.map(
        lambda o, b: (w * o + b).astype(o.dtype), old, new)
    return model._replace(stats=blend(model.stats, batch_stats),
                          substats=blend(model.substats, batch_substats),
                          it=model.it + 1), point.labels
