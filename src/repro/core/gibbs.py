"""Restricted Gibbs sweep (paper §4.1 steps a-f), shard_map-ready.

The sweep runs *inside* ``shard_map``: points/labels are local shards, all
per-cluster quantities are replicated. The only cross-device communication
is the ``psum`` of sufficient statistics at the end of the sweep — the
paper's 'we never transfer data; only sufficient statistics and parameters'
property (§4.3).

Per-point randomness derives from ``fold_in(key, global_index)`` so chains
are bitwise identical under any sharding (DESIGN §2, assumption 3).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core.state import DPMMState

NEG_INF = -1e30


def psum_tree(tree: Any, axes: Tuple[str, ...]):
    if not axes:
        return tree
    return jax.tree.map(lambda a: jax.lax.psum(a, axes), tree)


def global_indices(n_local: int, axes: Tuple[str, ...]) -> jax.Array:
    """Global point indices of this shard (0..N-1 ordering over the mesh).

    Assumes every data shard holds exactly ``n_local`` points —
    ``distributed.shard_points`` guarantees it by padding N up to a multiple
    of the data-shard count — so this shard's offset is simply
    ``axis_index(axes) * n_local``.
    """
    base = jnp.arange(n_local, dtype=jnp.uint32)
    if not axes:
        return base
    idx = jax.lax.axis_index(axes)  # linearized index over the given axes
    return idx.astype(jnp.uint32) * jnp.uint32(n_local) + base


def _per_point_gumbel(key: jax.Array, gidx: jax.Array, k: int) -> jax.Array:
    """(N_local, k) Gumbel noise, keyed by *global* point index."""
    def one(i):
        return jax.random.gumbel(jax.random.fold_in(key, i), (k,))
    return jax.vmap(one)(gidx)


def _per_point_bit(key: jax.Array, gidx: jax.Array) -> jax.Array:
    def one(i):
        return jax.random.bernoulli(jax.random.fold_in(key, i))
    return jax.vmap(one)(gidx).astype(jnp.int32)


def sample_weights(key: jax.Array, active: jax.Array, nk: jax.Array,
                   alpha: float) -> jax.Array:
    """Step (a): (pi_1..pi_K, pi~) ~ Dir(N_1..N_K, alpha); returns log pi.

    Inactive slots get -inf. The alpha-slot mass is sampled but unused by the
    *restricted* sampler (it never assigns to a new cluster) — it only
    rescales, and the assignment softmax renormalizes anyway; we keep it for
    faithfulness to eq. (14).
    """
    k = active.shape[0]
    conc = jnp.where(active, jnp.maximum(nk, 1e-2), 1.0)
    g = jax.random.gamma(key, jnp.concatenate(
        [conc, jnp.array([alpha], conc.dtype)]))
    g = jnp.maximum(g, 1e-30)
    total = jnp.sum(jnp.where(jnp.append(active, True), g, 0.0))
    logpi = jnp.log(g[:k]) - jnp.log(total)
    return jnp.where(active, logpi, NEG_INF)


def sample_subweights(key: jax.Array, active: jax.Array, nkl: jax.Array,
                      nkr: jax.Array, alpha: float) -> jax.Array:
    """Step (b): (pi_kl, pi_kr) ~ Dir(N_kl + a/2, N_kr + a/2) per cluster."""
    ga = jax.random.gamma(key, jnp.stack(
        [nkl + alpha / 2.0, nkr + alpha / 2.0], axis=-1))
    ga = jnp.maximum(ga, 1e-30)
    logw = jnp.log(ga) - jnp.log(jnp.sum(ga, axis=-1, keepdims=True))
    return jnp.where(active[:, None], logw, jnp.log(0.5))


def compute_stats(family, x: jax.Array, valid: jax.Array, labels: jax.Array,
                  sublabels: jax.Array, k_max: int,
                  axes: Tuple[str, ...], feat_axis=None):
    """Suff-stats of clusters and sub-clusters from (sharded) labels + psum.

    This is the paper's 3-step suff-stat update (§4.4): local accumulation
    (the Pallas suffstats kernel on TPU; one-hot matmuls here), then a
    cross-shard aggregation that moves only O(K * T) floats.

    ``feat_axis``: the feature dim of x is additionally sharded over this
    mesh axis (high-d mode, DESIGN §10): the family's feature-sliced stats
    fields are all-gathered along features after the data-axis psum — still
    O(K * d). Only ``family.feature_shardable`` families support this.
    """
    resp = jax.nn.one_hot(labels, k_max, dtype=x.dtype) * valid[:, None]
    sub = jax.nn.one_hot(sublabels, 2, dtype=x.dtype)
    subresp = resp[:, :, None] * sub[:, None, :]
    stats = family.stats_from_points(x, resp)
    substats = family.stats_from_points(x, subresp)
    stats, substats = psum_tree((stats, substats), axes)
    if feat_axis is not None:
        stats = family.gather_feature_stats(stats, feat_axis)
        substats = family.gather_feature_stats(substats, feat_axis)
    return stats, substats


def _loglik(family, x, params, use_pallas: bool, feat_axis=None):
    """The O(N K T) hot spot — Pallas kernel on TPU when enabled (§4.2).

    With ``feat_axis`` the feature-separable likelihoods (multinomial,
    Poisson, diag-Gaussian) run on local feature slices and psum the
    (N_local, K) partials — the paper's d=20,000 20newsgroups regime
    without ever replicating x's features."""
    if feat_axis is not None:
        return family.loglik_sharded(x, params, feat_axis)
    return family.loglik(x, params, use_pallas=use_pallas)


def sweep(state: DPMMState, x: jax.Array, valid: jax.Array, prior, family,
          alpha: float, axes: Tuple[str, ...],
          use_pallas: bool = False, feat_axis=None) -> DPMMState:
    """One restricted Gibbs sweep (steps a-f). Runs under shard_map."""
    k_max = state.active.shape[0]
    key = jax.random.fold_in(state.key, state.it)
    k_w, k_sw, k_p, k_sp, k_z, k_zb = jax.random.split(key, 6)

    # (a) cluster weights  (b) sub-cluster weights
    logw = sample_weights(k_w, state.active, state.stats.n, alpha)
    sublogw = sample_subweights(
        k_sw, state.active, state.substats.n[:, 0], state.substats.n[:, 1],
        alpha)

    # (c) cluster params  (d) sub-cluster params  — replicated O(K d^3)
    params = family.sample_posterior(k_p, prior, state.stats)
    subparams = family.sample_posterior(k_sp, prior, state.substats)

    # (e) cluster assignments: z_i ~ pi_k f(x_i; theta_k)  over *existing* k
    gidx = global_indices(x.shape[0], axes)
    ll = _loglik(family, x, params, use_pallas, feat_axis)  # (N, K) hot spot
    logits = ll + logw[None, :]
    logits = jnp.where(state.active[None, :], logits, NEG_INF)
    labels = jnp.argmax(
        logits + _per_point_gumbel(k_z, gidx, k_max), axis=-1
    ).astype(jnp.int32)

    # (f) sub-cluster assignments under the point's own cluster
    subll = _loglik(family, x, subparams, False, feat_axis)  # (N, K, 2)
    own = jnp.take_along_axis(
        subll, labels[:, None, None].astype(jnp.int32), axis=1)[:, 0, :]
    sublogits = own + sublogw[labels]
    sublabels = jnp.argmax(
        sublogits + _per_point_gumbel(k_zb, gidx, 2), axis=-1
    ).astype(jnp.int32)

    # suff-stats + the one cross-shard reduction
    stats, substats = compute_stats(
        family, x, valid, labels, sublabels, k_max, axes, feat_axis)

    return state._replace(
        logweights=logw, sub_logweights=sublogw, params=params,
        subparams=subparams, stats=stats, substats=substats,
        labels=labels, sublabels=sublabels)
