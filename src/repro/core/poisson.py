"""Gamma-Poisson conjugate component — the paper's suggested extension
('it can be easily adapted to other component distributions, e.g., Poisson,
as long as they belong to an exponential family', §3.4.3).

Points are count vectors x in N^d with independent Poisson(lambda_j) rates
per feature; the conjugate prior is Gamma(a0, b0) per rate. Per-point
log(x_ij!) terms are dropped everywhere: label-independent, they cancel in
the assignment softmax and in every split/merge Hastings ratio (same
argument as the multinomial coefficient, core/multinomial.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.scipy.special import gammaln


class PoisPrior(NamedTuple):
    a0: jax.Array         # () Gamma shape
    b0: jax.Array         # () Gamma rate
    d: int


class PoisStats(NamedTuple):
    n: jax.Array          # (*B,) number of points
    sx: jax.Array         # (*B, d) summed counts


class PoisParams(NamedTuple):
    log_rate: jax.Array   # (*B, d)


def default_prior(d: int, a0: float = 1.0, b0: float = 1.0,
                  dtype=jnp.float32) -> PoisPrior:
    return PoisPrior(a0=jnp.asarray(a0, dtype), b0=jnp.asarray(b0, dtype),
                     d=d)


def build_prior(cfg, x) -> PoisPrior:
    """Family hook (core/family.py): prior from config + data."""
    return default_prior(x.shape[1], cfg.gamma_a0, cfg.gamma_b0)


def param_struct() -> PoisParams:
    """Pytree template (leaves are placeholders) for spec-mapping."""
    return PoisParams(log_rate=0)


def stats_struct() -> PoisStats:
    return PoisStats(n=0, sx=0)


def empty_stats(batch_shape: tuple, d: int, dtype=jnp.float32) -> PoisStats:
    return PoisStats(n=jnp.zeros(batch_shape, dtype),
                     sx=jnp.zeros(batch_shape + (d,), dtype))


def stats_from_points(x: jax.Array, resp: jax.Array) -> PoisStats:
    n = jnp.sum(resp, axis=0)
    bshape = resp.shape[1:]
    r2 = resp.reshape(resp.shape[0], -1)
    sx = jnp.einsum("nb,nd->bd", r2, x)
    return PoisStats(n=n, sx=sx.reshape(bshape + (x.shape[-1],)))


def add_stats(a: PoisStats, b: PoisStats) -> PoisStats:
    return PoisStats(a.n + b.n, a.sx + b.sx)


def stats_from_labels(x: jax.Array, valid: jax.Array, labels: jax.Array,
                      sublabels: jax.Array, k_max: int) -> PoisStats:
    """(k_max, 2)-batched sub-cluster stats via segment-sum (no dense
    responsibilities; core/labelstats.py)."""
    from repro.core.labelstats import moments_from_labels
    n2, sx2 = moments_from_labels(x, valid, labels, sublabels, k_max)
    return PoisStats(n=n2, sx=sx2)


def assign_pack(x: jax.Array, params: PoisParams):
    """Linear-likelihood packing for the fused assignment kernels:
    loglik(x)_b = x @ log(lambda_b) - sum_j lambda_bj."""
    return (x, params.log_rate,
            -jnp.sum(jnp.exp(params.log_rate), axis=-1))


def sweep_pack(x: jax.Array, params: PoisParams, subparams: PoisParams):
    """One-read sweep packing (kernels/sweep.py): x is both the assign
    feature block and the stat feature map."""
    feats, w, const = assign_pack(x, params)
    _, subw, subconst = assign_pack(x, subparams)
    return feats, w, const, subw, subconst


def stats_from_moments(n2: jax.Array, sf2: jax.Array) -> PoisStats:
    """Sub-cluster stats from the fused sweep's folded moments."""
    return PoisStats(n=n2, sx=sf2)


def log_marginal(prior: PoisPrior, stats: PoisStats) -> jax.Array:
    """Negative-binomial marginal (log x! terms dropped):

    log m(C) = sum_j [ a0 log b0 - log G(a0)
                       + log G(a0 + S_j) - (a0 + S_j) log(b0 + n) ]
    """
    a_n = prior.a0 + stats.sx                              # (*B, d)
    b_n = prior.b0 + stats.n[..., None]
    return jnp.sum(prior.a0 * jnp.log(prior.b0) - gammaln(prior.a0)
                   + gammaln(a_n) - a_n * jnp.log(b_n), axis=-1)


def sample_posterior(key: jax.Array, prior: PoisPrior,
                     stats: PoisStats) -> PoisParams:
    """lambda_j ~ Gamma(a0 + S_j, b0 + n), batched; returns log lambda."""
    a_n = prior.a0 + stats.sx
    b_n = prior.b0 + stats.n[..., None]
    g = jnp.maximum(jax.random.gamma(key, a_n), 1e-30)
    return PoisParams(log_rate=jnp.log(g) - jnp.log(b_n))


def expected_params(prior: PoisPrior, stats: PoisStats) -> PoisParams:
    a_n = prior.a0 + stats.sx
    b_n = prior.b0 + stats.n[..., None]
    return PoisParams(log_rate=jnp.log(a_n) - jnp.log(b_n))


def loglik(x: jax.Array, params: PoisParams) -> jax.Array:
    """sum_j [x_ij log lambda_bj - lambda_bj] -> (N, *B); log x! dropped.

    The x @ log(lambda)^T term is the same matmul hot spot as the
    multinomial component (kernels/matmul.py serves it on TPU)."""
    lr = params.log_rate.reshape(-1, params.log_rate.shape[-1])
    out = x @ lr.T - jnp.sum(jnp.exp(lr), axis=-1)[None, :]
    return out.reshape((x.shape[0],) + params.log_rate.shape[:-1])
