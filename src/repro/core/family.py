"""ComponentFamily: the one dispatch layer for all likelihood families.

The sampler skeleton (restricted Gibbs + sub-cluster splits/merges) is
observation-model-agnostic — the paper's central modularity claim: 'it can
be easily adapted to other component distributions ... as long as they
belong to an exponential family' (§3.4.3). A ``ComponentFamily`` bundles
everything the skeleton needs from an observation model:

 - conjugate math: ``stats_from_points`` / ``add_stats`` / ``log_marginal``
   / ``sample_posterior`` / ``expected_params`` / ``loglik``,
 - pytree *templates* (``param_struct`` / ``stats_struct``) used to build
   replicated PartitionSpecs without knowing field names,
 - an optional Pallas/accelerated ``loglik_fast`` path (paper §4.2),
 - the feature-sharding contract (DESIGN §10): ``feature_shardable``
   families declare which stats fields carry a feature axis
   (``feature_stat_fields``, all-gathered after the data-axis psum) and how
   to slice their params to a local feature block (``slice_params``), and
 - ``build_prior(cfg, x)``: config + data -> prior hyper-parameters.

``core/gibbs.py``, ``core/sampler.py`` and ``core/splitmerge.py`` dispatch
*only* through this interface — no ``hasattr``/``getattr`` probing of
param/stat pytrees anywhere in the sampler.

Registering a new family::

    from repro.core.family import ComponentFamily, register_family
    register_family(ComponentFamily(name="my_family", ...))
    # then DPMMConfig(component="my_family") just works.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import diag_gaussian, multinomial, niw, poisson
from repro.core.state import DPMMState


@dataclasses.dataclass(frozen=True)
class ComponentFamily:
    """One observation model behind the fixed sampler interface."""
    name: str
    # pytree templates (placeholder leaves) for building PartitionSpecs
    param_struct: Callable[[], Any]
    stats_struct: Callable[[], Any]
    # conjugate math (see core/niw.py for the reference semantics)
    build_prior: Callable[[Any, Any], Any]          # (cfg, x) -> prior
    empty_stats: Callable[..., Any]                 # (batch_shape, d) -> stats
    stats_from_points: Callable[[jax.Array, jax.Array], Any]
    add_stats: Callable[[Any, Any], Any]
    log_marginal: Callable[[Any, Any], jax.Array]   # (prior, stats) -> (*B,)
    sample_posterior: Callable[[jax.Array, Any, Any], Any]
    expected_params: Callable[[Any, Any], Any]
    loglik_ref: Callable[[jax.Array, Any], jax.Array]  # (x, params) -> (N,*B)
    # optional accelerated loglik (Pallas on TPU; paper §4.2 'Kernel #1/#2')
    loglik_fast: Optional[Callable[[jax.Array, Any], jax.Array]] = None
    # feature-sharding contract (DESIGN §10); shardable families' loglik and
    # stats must be sums over features so local slices psum/gather correctly
    feature_shardable: bool = False
    feature_stat_fields: Tuple[str, ...] = ()
    slice_params: Optional[Callable[[Any, Any, int], Any]] = None
    # stats field holding the first moment (sum x) — cluster means read it
    mean_field: str = "sx"

    def loglik(self, x: jax.Array, params: Any,
               use_pallas: bool = False) -> jax.Array:
        """(N, *B) point log-likelihoods; Pallas fast path when available."""
        if use_pallas and self.loglik_fast is not None:
            return self.loglik_fast(x, params)
        return self.loglik_ref(x, params)

    def loglik_sharded(self, x: jax.Array, params: Any,
                       feat_axis: str) -> jax.Array:
        """Feature-sharded loglik: local params slice + psum over features.

        ``x`` holds this shard's feature block (paper's d=20,000 regime —
        the feature dim never replicates); params are full-d replicated.
        """
        self._require_shardable()
        i = jax.lax.axis_index(feat_axis)
        dl = x.shape[1]
        partial = self.loglik_ref(x, self.slice_params(params, i * dl, dl))
        return jax.lax.psum(partial, feat_axis)

    def gather_feature_stats(self, stats: Any, feat_axis: str) -> Any:
        """All-gather feature-sliced stats fields to full d (still O(K d))."""
        self._require_shardable()
        gather = lambda c: jax.lax.all_gather(c, feat_axis, axis=c.ndim - 1,
                                              tiled=True)
        return stats._replace(**{f: gather(getattr(stats, f))
                                 for f in self.feature_stat_fields})

    def cluster_means(self, stats: Any) -> jax.Array:
        """(*B, d) empirical cluster means from the first-moment field."""
        first = getattr(stats, self.mean_field)
        return first / jnp.maximum(stats.n[..., None], 1.0)

    def _require_shardable(self) -> None:
        if not self.feature_shardable:
            raise ValueError(
                f"component family {self.name!r} is not feature-separable: "
                "its likelihood/stats are not sums over independent "
                "features (e.g. the full-covariance Gaussian Mahalanobis), "
                "so shard_features is unsupported — use a shardable family "
                f"({', '.join(shardable_families())}) for the high-d path")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, ComponentFamily] = {}


def register_family(family: ComponentFamily) -> ComponentFamily:
    if family.name in _REGISTRY:
        raise ValueError(f"component family {family.name!r} already "
                         "registered")
    if family.feature_shardable and (not family.feature_stat_fields
                                     or family.slice_params is None):
        raise ValueError(f"{family.name!r}: feature_shardable families must "
                         "set feature_stat_fields and slice_params")
    _REGISTRY[family.name] = family
    return family


def get_family(name: str) -> ComponentFamily:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown component family {name!r}; registered: "
                         f"{', '.join(available_families())}") from None


def available_families() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def shardable_families() -> Tuple[str, ...]:
    return tuple(n for n in available_families()
                 if _REGISTRY[n].feature_shardable)


def state_partition_specs(family: ComponentFamily,
                          shard_spec: P) -> DPMMState:
    """shard_map specs for a DPMMState: labels on the data axes, everything
    per-cluster replicated (paper §4.3: only stats/params are global)."""
    rep = P()
    rep_tree = lambda struct: jax.tree.map(lambda _: rep, struct)
    return DPMMState(
        key=rep, it=rep, active=rep, logweights=rep, sub_logweights=rep,
        stuck=rep,
        params=rep_tree(family.param_struct()),
        subparams=rep_tree(family.param_struct()),
        stats=rep_tree(family.stats_struct()),
        substats=rep_tree(family.stats_struct()),
        labels=shard_spec, sublabels=shard_spec)


# ---------------------------------------------------------------------------
# Built-in families
# ---------------------------------------------------------------------------
def _module_family(mod, **kw) -> ComponentFamily:
    return ComponentFamily(
        param_struct=mod.param_struct, stats_struct=mod.stats_struct,
        build_prior=mod.build_prior, empty_stats=mod.empty_stats,
        stats_from_points=mod.stats_from_points, add_stats=mod.add_stats,
        log_marginal=mod.log_marginal, sample_posterior=mod.sample_posterior,
        expected_params=mod.expected_params, loglik_ref=mod.loglik, **kw)


def _slice_last(arr: jax.Array, start, size: int) -> jax.Array:
    return jax.lax.dynamic_slice_in_dim(arr, start, size, axis=-1)


def _gauss_loglik_fast(x: jax.Array, params) -> jax.Array:
    # Pallas whitening-matmul kernel; sub-cluster params (K, 2, ...) fall
    # back to the jnp path (the kernel grid is 2-D over clusters)
    if params.mu.ndim != 2:
        return niw.loglik(x, params)
    from repro.kernels import ops
    return ops.gauss_loglik(x, params, True)


def _diag_gauss_loglik_fast(x: jax.Array, params) -> jax.Array:
    if params.mu.ndim != 2:
        return diag_gaussian.loglik(x, params)
    from repro.kernels import ops
    return ops.diag_gauss_loglik(x, params, True)


GAUSSIAN = register_family(_module_family(
    niw, name="gaussian", loglik_fast=_gauss_loglik_fast,
    feature_shardable=False, mean_field="sx"))

MULTINOMIAL = register_family(_module_family(
    multinomial, name="multinomial",
    feature_shardable=True, feature_stat_fields=("counts",),
    slice_params=lambda p, s, n: multinomial.MultParams(
        logtheta=_slice_last(p.logtheta, s, n)),
    mean_field="counts"))

POISSON = register_family(_module_family(
    poisson, name="poisson",
    feature_shardable=True, feature_stat_fields=("sx",),
    slice_params=lambda p, s, n: poisson.PoisParams(
        log_rate=_slice_last(p.log_rate, s, n)),
    mean_field="sx"))

DIAG_GAUSSIAN = register_family(_module_family(
    diag_gaussian, name="diag_gaussian",
    loglik_fast=_diag_gauss_loglik_fast,
    feature_shardable=True, feature_stat_fields=("sx", "sxx"),
    slice_params=lambda p, s, n: diag_gaussian.DiagParams(
        mu=_slice_last(p.mu, s, n), log_prec=_slice_last(p.log_prec, s, n)),
    mean_field="sx"))
