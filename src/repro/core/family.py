"""ComponentFamily: the one dispatch layer for all likelihood families.

The sampler skeleton (restricted Gibbs + sub-cluster splits/merges) is
observation-model-agnostic — the paper's central modularity claim: 'it can
be easily adapted to other component distributions ... as long as they
belong to an exponential family' (§3.4.3). A ``ComponentFamily`` bundles
everything the skeleton needs from an observation model:

 - conjugate math: ``stats_from_points`` / ``add_stats`` / ``log_marginal``
   / ``sample_posterior`` / ``expected_params`` / ``loglik``,
 - pytree *templates* (``param_struct`` / ``stats_struct``) used to build
   replicated PartitionSpecs without knowing field names,
 - an optional Pallas/accelerated ``loglik_fast`` path (paper §4.2),
 - the fused sweep hot path (paper §4.1e/§4.4 "Kernel #1/#2"): ``assign``
   (step e), ``sub_assign`` (step f, own-cluster only) and
   ``stats_from_labels`` dispatch between streaming Pallas kernels
   (``assign_fast`` / ``assign_pack`` / ``sub_assign_fast`` /
   ``labels_stats_fast``, kernels/assign.py + kernels/suffstats.py) and
   jnp reference fallbacks (``labels_stats_ref``, chunked own-cluster
   gather) — neither path materializes an (N, K, 2) sub-cluster loglik or
   a dense (N, K, 2) responsibility tensor,
 - the ONE-READ sweep (``sweep`` dispatch): steps (e) + (f) + the
   suff-stat fold run while each point block is resident, so a sweep
   reads every tile of x from HBM exactly once. ``sweep_fast`` is the
   per-family Pallas megakernel hook (kernels/sweep.py, packed via the
   modules' ``sweep_pack``); ``sweep_ref`` is the blocked jnp scan — both
   fold stat partials per STATS_BLOCK left-to-right and reproduce the
   three-pass chain bitwise,
 - the feature-sharding contract (DESIGN §10): ``feature_shardable``
   families declare which stats fields carry a feature axis
   (``feature_stat_fields``, all-gathered after the data-axis psum) and how
   to slice their params to a local feature block (``slice_params``), and
 - ``build_prior(cfg, x)``: config + data -> prior hyper-parameters.
   ``DPMM.fit`` passes the (1, d) *column-mean summary row* from the
   ``DataSource`` (computed by one canonical streaming pass so resident
   and out-of-core fits build bitwise-identical priors) — family hooks may
   read ``x.shape[1]`` and ``x.mean(axis=0)`` but must not assume all N
   rows are present.

``core/gibbs.py``, ``core/sampler.py`` and ``core/splitmerge.py`` dispatch
*only* through this interface — no ``hasattr``/``getattr`` probing of
param/stat pytrees anywhere in the sampler.

Registering a new family::

    from repro.core.family import ComponentFamily, register_family
    register_family(ComponentFamily(name="my_family", ...))
    # then DPMMConfig(component="my_family") just works.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import diag_gaussian, multinomial, niw, poisson
from repro.core.state import ModelState, PointState
from repro.kernels import prng
# the inactive-cluster assignment mask — single-sourced from the fused
# kernels so reference and in-kernel masking can never drift
from repro.kernels.assign import NEG_INF  # noqa: F401  (re-exported)
# granularity of the suff-stat fold (canonical home: kernels/sweep.py;
# core/gibbs.py re-exports it) — the one-read blocked passes below fold
# stat partials per STATS_BLOCK points, left to right in point order
from repro.kernels.sweep import STATS_BLOCK


def _add_tree(a: Any, b: Any) -> Any:
    return jax.tree.map(jnp.add, a, b)


def fold_blocked(family: "ComponentFamily", k_max: int, body, x: jax.Array,
                 valid: jax.Array, extras: Tuple, acc,
                 use_pallas: bool = False, label_map=None):
    """Run a per-point ``body`` over fixed STATS_BLOCK point blocks and
    fold each block's sub-cluster stat partial into ``acc`` — the one-read
    pass shape shared by the fused sweep (``ComponentFamily.sweep_ref``)
    and the fused split/merge apply (``splitmerge.split_merge_tile``).

    ``body(x_blk, valid_blk, *extras_blk) -> (labels_blk, sublabels_blk)``
    runs while the block is resident; its labels feed the stat partial
    immediately, so each block of ``x`` is consumed exactly once per pass
    (one ``lax.scan`` body — nothing re-reads x afterwards). Partials are
    added left to right in global point order, per STATS_BLOCK — the exact
    float addition sequence of ``gibbs.accumulate_substats`` — so chains
    stay bitwise identical to the three-pass formulation on every plane,
    tile size, and sharding. Only a shard's ragged tail (< STATS_BLOCK)
    runs outside the scan; it folds last either way.

    ``label_map`` (optional, (k_dense,) int32) re-indexes labels before
    the stat fold only — the returned labels stay in ``body``'s space.
    The active-set compaction uses it to fold a dense-slab relabel pass
    into a compact (k_max = K_active) ``acc``: per-segment sums are
    unchanged (same points, same order), so the scattered-back stats are
    bitwise the dense fold's.
    """
    n = x.shape[0]
    nb, rem = divmod(n, STATS_BLOCK)
    outs = []
    stat_lab = ((lambda lab: lab) if label_map is None
                else (lambda lab: label_map[lab]))
    if nb:
        blk = lambda a: a[:nb * STATS_BLOCK].reshape(
            (nb, STATS_BLOCK) + a.shape[1:])

        def step(a, args):
            xb, vb = args[0], args[1]
            lab, sub = body(xb, vb, *args[2:])
            p = family.stats_from_labels(xb, vb, stat_lab(lab), sub, k_max,
                                         use_pallas=use_pallas)
            return _add_tree(a, p), (lab, sub)

        acc, (labs, subs) = jax.lax.scan(
            step, acc, (blk(x), blk(valid)) + tuple(blk(e) for e in extras))
        outs.append((labs.reshape(-1), subs.reshape(-1)))
    if rem:
        tail = slice(nb * STATS_BLOCK, None)
        xb, vb = x[tail], valid[tail]
        lab, sub = body(xb, vb, *(e[tail] for e in extras))
        p = family.stats_from_labels(xb, vb, stat_lab(lab), sub, k_max,
                                     use_pallas=use_pallas)
        acc = _add_tree(acc, p)
        outs.append((lab, sub))
    if len(outs) == 1:
        labels, sublabels = outs[0]
    else:
        labels = jnp.concatenate([o[0] for o in outs])
        sublabels = jnp.concatenate([o[1] for o in outs])
    return labels, sublabels, acc


@dataclasses.dataclass(frozen=True)
class ComponentFamily:
    """One observation model behind the fixed sampler interface."""
    name: str
    # pytree templates (placeholder leaves) for building PartitionSpecs
    param_struct: Callable[[], Any]
    stats_struct: Callable[[], Any]
    # conjugate math (see core/niw.py for the reference semantics)
    build_prior: Callable[[Any, Any], Any]          # (cfg, x) -> prior
    empty_stats: Callable[..., Any]                 # (batch_shape, d) -> stats
    stats_from_points: Callable[[jax.Array, jax.Array], Any]
    add_stats: Callable[[Any, Any], Any]
    log_marginal: Callable[[Any, Any], jax.Array]   # (prior, stats) -> (*B,)
    sample_posterior: Callable[[jax.Array, Any, Any], Any]
    expected_params: Callable[[Any, Any], Any]
    loglik_ref: Callable[[jax.Array, Any], jax.Array]  # (x, params) -> (N,*B)
    # label-indexed suff-stats: (x, valid, labels, sublabels, k_max) ->
    # (k_max, 2)-batched sub-cluster stats (cluster stats are the sub fold,
    # core/gibbs.compute_stats). ``_ref`` is the jnp path (segment-sum /
    # one-hot einsum); ``_fast`` the Pallas kernel, returning None when the
    # problem falls outside the kernel's VMEM envelope.
    labels_stats_ref: Callable[..., Any] = None
    labels_stats_fast: Optional[Callable[..., Any]] = None
    # fused assignment (steps e/f). ``assign_pack`` expresses a linear
    # likelihood loglik(x)_b = feats @ w_b + const_b so one shared kernel
    # serves every such family; non-linear families provide dedicated
    # ``assign_fast`` / ``sub_assign_fast`` kernels instead. All return
    # None outside their guard so the caller can fall back.
    assign_pack: Optional[Callable[[jax.Array, Any], Tuple]] = None
    assign_fast: Optional[Callable[..., Optional[jax.Array]]] = None
    sub_assign_fast: Optional[Callable[..., Optional[jax.Array]]] = None
    # one-read fused sweep (steps e + f + stat fold in ONE pass over x,
    # kernels/sweep.py): returns (labels, sublabels, per-STATS_BLOCK stat
    # partials) or None outside the kernel's VMEM envelope; the ``sweep``
    # dispatch method folds the partials and falls back to ``sweep_ref``
    # (the blocked jnp scan) when absent/guarded out.
    sweep_fast: Optional[Callable[..., Optional[Tuple]]] = None
    # optional accelerated loglik (Pallas on TPU; paper §4.2 'Kernel #1/#2')
    loglik_fast: Optional[Callable[[jax.Array, Any], jax.Array]] = None
    # feature-sharding contract (DESIGN §10); shardable families' loglik and
    # stats must be sums over features so local slices psum/gather correctly
    feature_shardable: bool = False
    feature_stat_fields: Tuple[str, ...] = ()
    slice_params: Optional[Callable[[Any, Any, int], Any]] = None
    # stats field holding the first moment (sum x) — cluster means read it
    mean_field: str = "sx"

    def loglik(self, x: jax.Array, params: Any,
               use_pallas: bool = False) -> jax.Array:
        """(N, *B) point log-likelihoods; Pallas fast path when available."""
        if use_pallas and self.loglik_fast is not None:
            return self.loglik_fast(x, params)
        return self.loglik_ref(x, params)

    # -- one-read fused sweep (steps e + f + stat fold, ONE pass over x) --
    def sweep(self, x: jax.Array, valid: jax.Array, params: Any,
              subparams: Any, logw: jax.Array, sublogw: jax.Array,
              active: jax.Array, gidx: jax.Array, key_z: jax.Array,
              key_zb: jax.Array, k_max: int, acc,
              use_pallas: bool = False, feat_axis=None, slots=None,
              k_block: Optional[int] = None
              ) -> Tuple[jax.Array, jax.Array, Any]:
        """Steps (e)+(f)+suff-stat fold with x consumed exactly once.

        Dispatch: the ``sweep_fast`` megakernel (Pallas, kernels/sweep.py)
        when available and inside its VMEM envelope, else ``sweep_ref``
        (one ``lax.scan`` over STATS_BLOCK blocks running assign /
        sub_assign / stats_from_labels while the block is resident). Both
        paths fold stat partials per STATS_BLOCK left-to-right and draw
        noise from the counter-based PRNG, so they produce the same chain
        as the pre-fusion three-pass formulation, bit for bit.

        ``params``/``logw``/... may be a COMPACT slab (K_active rows
        gathered from the dense k_max slab — core/gibbs.py's compaction);
        ``slots`` then carries the (K,) uint32 dense slot ids so the
        Gumbel counters — hence the chain — are bitwise the dense slab's.
        ``k_block`` overrides the streamed cluster-tile size of the
        megakernel. Returns ``(labels, sublabels, acc')`` with labels in
        COMPACT positions (the caller maps them back through the plan).

        ``key_z``/``key_zb``: raw (2,) uint32 key words
        (``prng.key_words``).
        """
        if use_pallas and feat_axis is None and self.sweep_fast is not None:
            out = self.sweep_fast(x, valid, params, subparams, logw,
                                  sublogw, active, gidx, key_z, key_zb,
                                  k_max, slots=slots, k_block=k_block)
            if out is not None:
                labels, sublabels, partials = out
                acc, _ = jax.lax.scan(
                    lambda a, p: (_add_tree(a, p), None), acc, partials)
                return labels, sublabels, acc
        return self.sweep_ref(x, valid, params, subparams, logw, sublogw,
                              active, gidx, key_z, key_zb, k_max, acc,
                              use_pallas=use_pallas, feat_axis=feat_axis,
                              slots=slots)

    def sweep_ref(self, x: jax.Array, valid: jax.Array, params: Any,
                  subparams: Any, logw: jax.Array, sublogw: jax.Array,
                  active: jax.Array, gidx: jax.Array, key_z: jax.Array,
                  key_zb: jax.Array, k_max: int, acc,
                  use_pallas: bool = False, feat_axis=None, slots=None
                  ) -> Tuple[jax.Array, jax.Array, Any]:
        """Blocked one-read sweep reference: e + f + stat fold per
        STATS_BLOCK block inside one scan body. Per-block math is exactly
        ``assign``/``sub_assign``/``stats_from_labels`` (counter-based
        noise, same op order), so the chain matches the three-pass body
        bitwise while x streams through the scan once. Accepts the same
        compact-slab + ``slots`` calling convention as ``sweep``."""
        def body(xb, vb, gb):
            del vb                      # assignment ignores the pad mask
            lab = self.assign(xb, params, logw, active, gb, key_z,
                              use_pallas=use_pallas, feat_axis=feat_axis,
                              slots=slots)
            sub = self.sub_assign(xb, subparams, sublogw, lab, gb, key_zb,
                                  use_pallas=use_pallas,
                                  feat_axis=feat_axis)
            return lab, sub

        return fold_blocked(self, k_max, body, x, valid, (gidx,), acc,
                            use_pallas=use_pallas)

    # -- fused sweep hot path (steps e/f + suff-stats) --------------------
    def assign(self, x: jax.Array, params: Any, logw: jax.Array,
               active: jax.Array, gidx: jax.Array, key_data: jax.Array,
               use_pallas: bool = False, feat_axis=None,
               slots=None) -> jax.Array:
        """Step (e): z_i = argmax_k [loglik + log pi_k + Gumbel] -> (N,).

        The Gumbel noise is the counter-based Threefry draw of
        kernels/prng.py keyed on (key, global index, cluster) — identical
        bits in the fused kernel and in this reference path, so both
        sample the same chain. The cluster counter is the dense-slab SLOT
        id: ``slots`` (default ``arange(K)``) lets a compacted caller pass
        the gathered ids so compact and dense slabs draw identical noise.
        With ``use_pallas`` the streaming kernel (kernels/assign.py) runs
        the whole step in VMEM tiles and the (N, K) logits/Gumbel matrices
        never exist in HBM; otherwise this reference materializes the
        (N, K) logits once (and nothing else).
        """
        if use_pallas and feat_axis is None:
            fused = self._assign_fused(x, params, logw, active, gidx,
                                       key_data, slots)
            if fused is not None:
                return fused
        ll = (self.loglik_sharded(x, params, feat_axis)
              if feat_axis is not None
              else self.loglik(x, params, use_pallas=use_pallas))
        logits = ll + logw[None, :]
        logits = jnp.where(active[None, :], logits, NEG_INF)
        cid = (jnp.arange(logw.shape[0], dtype=jnp.uint32)
               if slots is None else slots.astype(jnp.uint32))
        logits = logits + prng.gumbel(key_data, gidx[:, None], cid[None, :])
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def _assign_fused(self, x, params, logw, active, gidx, key_data,
                      slots=None):
        from repro.kernels import ops
        if self.assign_fast is not None:
            return self.assign_fast(x, params, logw, active, gidx, key_data,
                                    slots)
        if self.assign_pack is not None:
            feats, w, const = self.assign_pack(x, params)
            return ops.assign_linear_pallas(feats, w, const, logw, active,
                                            gidx, key_data, slots)
        return None

    def sub_assign(self, x: jax.Array, subparams: Any, sublogw: jax.Array,
                   labels: jax.Array, gidx: jax.Array, key_data: jax.Array,
                   use_pallas: bool = False, feat_axis=None,
                   chunk: Optional[int] = None) -> jax.Array:
        """Step (f): sub-label under the point's OWN cluster only -> (N,).

        Evaluates the sub-cluster log-likelihood for 2 sub-clusters per
        point instead of all 2K — the O(N K T) -> O(N T) cut. The fused
        kernels gather the (K, 2, ...) sub-params in VMEM; this reference
        gathers them per ``chunk`` points under ``lax.map`` so the largest
        jnp intermediate is (chunk, 2, ...) — never (N, K, 2). ``chunk``
        defaults to a memory-budgeted size (all N at once when the gathered
        params are small — e.g. any linear family or a low-d Gaussian — so
        the scan and its per-step overhead disappear entirely).
        """
        if use_pallas and feat_axis is None:
            fused = self._sub_assign_fused(x, subparams, sublogw, labels,
                                           gidx, key_data)
            if fused is not None:
                return fused
        own = self._own_subloglik(x, subparams, labels, feat_axis, chunk)
        t = own + sublogw[labels]
        cid = jnp.arange(2, dtype=jnp.uint32)
        t = t + prng.gumbel(key_data, gidx[:, None], cid[None, :])
        return jnp.argmax(t, axis=-1).astype(jnp.int32)

    def _sub_assign_fused(self, x, subparams, sublogw, labels, gidx,
                          key_data):
        from repro.kernels import ops
        if self.sub_assign_fast is not None:
            return self.sub_assign_fast(x, subparams, sublogw, labels,
                                        gidx, key_data)
        if self.assign_pack is not None:
            feats, w, const = self.assign_pack(x, subparams)
            return ops.sub_assign_linear_pallas(feats, w, const, sublogw,
                                                labels, gidx, key_data)
        return None

    # cap on the gathered (chunk, 2, ...) sub-params intermediate (floats):
    # 32M floats = 128 MiB — far below the dense (N, K, 2, ...) it replaces
    _SUB_GATHER_BUDGET = 32 * 1024 * 1024

    def _own_subloglik(self, x, subparams, labels, feat_axis,
                       chunk: Optional[int]) -> jax.Array:
        """(N, 2) own-cluster sub-loglik via chunked gather (jnp path)."""
        n = x.shape[0]
        if chunk is None:
            per_point = sum(math.prod(leaf.shape[1:])
                            for leaf in jax.tree_util.tree_leaves(subparams))
            chunk = max(512, self._SUB_GATHER_BUDGET // max(per_point, 1))
        chunk = min(chunk, n)
        pad = (-n) % chunk
        xp = jnp.pad(x, ((0, pad), (0, 0)))
        lp = jnp.pad(labels, (0, pad))
        if feat_axis is not None:
            # x is a feature slice; sub-params are full-d replicated —
            # slice the gathered params to the local block and psum the
            # (N, 2) partials once at the end (O(N) wire bytes, not O(N K))
            blk = jax.lax.axis_index(feat_axis) * x.shape[1]

        def body(args):
            xc, lc = args
            pc = jax.tree.map(lambda p: p[lc], subparams)   # (chunk, 2, ..)
            if feat_axis is not None:
                pc = self.slice_params(pc, blk, x.shape[1])
            one = lambda xi, pi: self.loglik_ref(xi[None], pi)[0]
            return jax.vmap(one)(xc, pc)                     # (chunk, 2)

        if xp.shape[0] == chunk:        # one chunk: no scan wrapper at all
            out = body((xp, lp))[:n]
        else:
            out = jax.lax.map(body, (xp.reshape(-1, chunk, x.shape[1]),
                                     lp.reshape(-1, chunk)))
            out = out.reshape(-1, 2)[:n]
        if feat_axis is not None:
            out = jax.lax.psum(out, feat_axis)
        return out

    def stats_from_labels(self, x: jax.Array, valid: jax.Array,
                          labels: jax.Array, sublabels: jax.Array,
                          k_max: int, use_pallas: bool = False) -> Any:
        """(k_max, 2)-batched sub-cluster stats straight from int labels;
        cluster stats are the fold over the sub axis (gibbs.compute_stats).
        No dense (N, K, 2) responsibility tensor on either path."""
        if use_pallas and self.labels_stats_fast is not None:
            out = self.labels_stats_fast(x, valid, labels, sublabels, k_max)
            if out is not None:
                return out
        if self.labels_stats_ref is not None:
            return self.labels_stats_ref(x, valid, labels, sublabels, k_max)
        # back-compat for user families registered without a label-indexed
        # path: dense (N, 2K) one-hot through stats_from_points (all four
        # built-ins provide labels_stats_ref and never take this branch)
        seg = labels * 2 + sublabels
        r2 = (jax.nn.one_hot(seg, 2 * k_max, dtype=x.dtype)
              * valid.astype(x.dtype)[:, None])
        flat = self.stats_from_points(x, r2)
        return jax.tree.map(
            lambda a: a.reshape((k_max, 2) + a.shape[1:]), flat)

    def loglik_sharded(self, x: jax.Array, params: Any,
                       feat_axis: str) -> jax.Array:
        """Feature-sharded loglik: local params slice + psum over features.

        ``x`` holds this shard's feature block (paper's d=20,000 regime —
        the feature dim never replicates); params are full-d replicated.
        """
        self._require_shardable()
        i = jax.lax.axis_index(feat_axis)
        dl = x.shape[1]
        partial = self.loglik_ref(x, self.slice_params(params, i * dl, dl))
        return jax.lax.psum(partial, feat_axis)

    def gather_feature_stats(self, stats: Any, feat_axis: str) -> Any:
        """All-gather feature-sliced stats fields to full d (still O(K d))."""
        self._require_shardable()
        gather = lambda c: jax.lax.all_gather(c, feat_axis, axis=c.ndim - 1,
                                              tiled=True)
        return stats._replace(**{f: gather(getattr(stats, f))
                                 for f in self.feature_stat_fields})

    def cluster_means(self, stats: Any) -> jax.Array:
        """(*B, d) empirical cluster means from the first-moment field."""
        first = getattr(stats, self.mean_field)
        return first / jnp.maximum(stats.n[..., None], 1.0)

    def _require_shardable(self) -> None:
        if not self.feature_shardable:
            raise ValueError(
                f"component family {self.name!r} is not feature-separable: "
                "its likelihood/stats are not sums over independent "
                "features (e.g. the full-covariance Gaussian Mahalanobis), "
                "so shard_features is unsupported — use a shardable family "
                f"({', '.join(shardable_families())}) for the high-d path")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, ComponentFamily] = {}


def register_family(family: ComponentFamily) -> ComponentFamily:
    if family.name in _REGISTRY:
        raise ValueError(f"component family {family.name!r} already "
                         "registered")
    if family.feature_shardable and (not family.feature_stat_fields
                                     or family.slice_params is None):
        raise ValueError(f"{family.name!r}: feature_shardable families must "
                         "set feature_stat_fields and slice_params")
    _REGISTRY[family.name] = family
    return family


def get_family(name: str) -> ComponentFamily:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown component family {name!r}; registered: "
                         f"{', '.join(available_families())}") from None


def available_families() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def shardable_families() -> Tuple[str, ...]:
    return tuple(n for n in available_families()
                 if _REGISTRY[n].feature_shardable)


def state_partition_specs(family: ComponentFamily, shard_spec: P
                          ) -> Tuple[ModelState, PointState]:
    """shard_map specs for the (ModelState, PointState) pair: per-point
    state on the data axes, everything per-cluster replicated (paper §4.3:
    only stats/params are global)."""
    rep = P()
    rep_tree = lambda struct: jax.tree.map(lambda _: rep, struct)
    model = ModelState(
        key=rep, it=rep, active=rep, logweights=rep, sub_logweights=rep,
        stuck=rep,
        params=rep_tree(family.param_struct()),
        subparams=rep_tree(family.param_struct()),
        stats=rep_tree(family.stats_struct()),
        substats=rep_tree(family.stats_struct()))
    point = PointState(labels=shard_spec, sublabels=shard_spec,
                       valid=shard_spec)
    return model, point


# ---------------------------------------------------------------------------
# Built-in families
# ---------------------------------------------------------------------------
def _module_family(mod, **kw) -> ComponentFamily:
    kw.setdefault("labels_stats_ref", mod.stats_from_labels)
    if hasattr(mod, "assign_pack"):
        kw.setdefault("assign_pack", mod.assign_pack)
    return ComponentFamily(
        param_struct=mod.param_struct, stats_struct=mod.stats_struct,
        build_prior=mod.build_prior, empty_stats=mod.empty_stats,
        stats_from_points=mod.stats_from_points, add_stats=mod.add_stats,
        log_marginal=mod.log_marginal, sample_posterior=mod.sample_posterior,
        expected_params=mod.expected_params, loglik_ref=mod.loglik, **kw)


def _slice_last(arr: jax.Array, start, size: int) -> jax.Array:
    return jax.lax.dynamic_slice_in_dim(arr, start, size, axis=-1)


def _gauss_loglik_fast(x: jax.Array, params) -> jax.Array:
    # Pallas whitening-matmul kernel; sub-cluster params (K, 2, ...) fall
    # back to the jnp path (the kernel grid is 2-D over clusters)
    if params.mu.ndim != 2:
        return niw.loglik(x, params)
    from repro.kernels import ops
    return ops.gauss_loglik(x, params, True)


def _diag_gauss_loglik_fast(x: jax.Array, params) -> jax.Array:
    if params.mu.ndim != 2:
        return diag_gaussian.loglik(x, params)
    from repro.kernels import ops
    return ops.diag_gauss_loglik(x, params, True)


def _gauss_assign_fast(x, params, logw, active, gidx, key_data, slots=None):
    if params.mu.ndim != 2:
        return None
    from repro.kernels import ops
    return ops.assign_gauss_pallas(x, params.mu, params.chol_prec,
                                   params.logdet_prec, logw, active, gidx,
                                   key_data, slots)


def _gauss_sub_assign_fast(x, subparams, sublogw, labels, gidx, key_data):
    if subparams.mu.ndim != 3:                        # expect (K, 2, d)
        return None
    from repro.kernels import ops
    return ops.sub_assign_gauss_pallas(x, subparams.mu, subparams.chol_prec,
                                       subparams.logdet_prec, sublogw,
                                       labels, gidx, key_data)


def _gauss_labels_stats_fast(x, valid, labels, sublabels, k_max):
    from repro.kernels import ops
    out = ops.suffstats_labels_pallas(x, labels, sublabels, valid, k_max)
    return None if out is None else niw.GaussStats(*out)


def _linear_sweep_fast(mod):
    """One-read megakernel hook for linear-likelihood families: the
    module's ``sweep_pack`` builds the shared feature block once; its
    ``stats_from_moments`` unpacks the folded (nsb, K, 2, d') moment
    partials into the family's stats pytree."""
    def hook(x, valid, params, subparams, logw, sublogw, active, gidx,
             key_z, key_zb, k_max, slots=None, k_block=None):
        from repro.kernels import ops
        feats, w, const, subw, subconst = mod.sweep_pack(x, params,
                                                         subparams)
        out = ops.sweep_linear_pallas(feats, w, const, logw, active, subw,
                                      subconst, sublogw, valid, gidx,
                                      key_z, key_zb, slots,
                                      k_block=k_block or ops.K_BLOCK)
        if out is None:
            return None
        labels, sublabels, n2, sf2 = out
        return labels, sublabels, mod.stats_from_moments(n2, sf2)
    return hook


def _gauss_sweep_fast(x, valid, params, subparams, logw, sublogw, active,
                      gidx, key_z, key_zb, k_max, slots=None, k_block=None):
    if params.mu.ndim != 2 or subparams.mu.ndim != 3:
        return None
    from repro.kernels import ops
    mu, f, ld, smu, sf, sld = niw.sweep_pack(params, subparams)
    out = ops.sweep_gauss_pallas(x, mu, f, ld, logw, active, smu, sf, sld,
                                 sublogw, valid, gidx, key_z, key_zb, slots,
                                 k_block=k_block or ops.K_BLOCK)
    if out is None:
        return None
    labels, sublabels, n2, sx2, sxx2 = out
    return labels, sublabels, niw.stats_from_moments(n2, sx2, sxx2)


def _moments_labels_fast(feats, valid, labels, sublabels, k_max):
    from repro.kernels import ops
    return ops.moments_labels_pallas(feats, labels, sublabels, valid, k_max)


def _mult_labels_stats_fast(x, valid, labels, sublabels, k_max):
    out = _moments_labels_fast(x, valid, labels, sublabels, k_max)
    return None if out is None else multinomial.MultStats(n=out[0],
                                                          counts=out[1])


def _pois_labels_stats_fast(x, valid, labels, sublabels, k_max):
    out = _moments_labels_fast(x, valid, labels, sublabels, k_max)
    return None if out is None else poisson.PoisStats(n=out[0], sx=out[1])


def _diag_labels_stats_fast(x, valid, labels, sublabels, k_max):
    out = _moments_labels_fast(jnp.concatenate([x, x * x], axis=-1),
                               valid, labels, sublabels, k_max)
    if out is None:
        return None
    d = x.shape[-1]
    return diag_gaussian.DiagStats(n=out[0], sx=out[1][..., :d],
                                   sxx=out[1][..., d:])


GAUSSIAN = register_family(_module_family(
    niw, name="gaussian", loglik_fast=_gauss_loglik_fast,
    assign_fast=_gauss_assign_fast, sub_assign_fast=_gauss_sub_assign_fast,
    labels_stats_fast=_gauss_labels_stats_fast,
    sweep_fast=_gauss_sweep_fast,
    feature_shardable=False, mean_field="sx"))

MULTINOMIAL = register_family(_module_family(
    multinomial, name="multinomial",
    labels_stats_fast=_mult_labels_stats_fast,
    sweep_fast=_linear_sweep_fast(multinomial),
    feature_shardable=True, feature_stat_fields=("counts",),
    slice_params=lambda p, s, n: multinomial.MultParams(
        logtheta=_slice_last(p.logtheta, s, n)),
    mean_field="counts"))

POISSON = register_family(_module_family(
    poisson, name="poisson",
    labels_stats_fast=_pois_labels_stats_fast,
    sweep_fast=_linear_sweep_fast(poisson),
    feature_shardable=True, feature_stat_fields=("sx",),
    slice_params=lambda p, s, n: poisson.PoisParams(
        log_rate=_slice_last(p.log_rate, s, n)),
    mean_field="sx"))

DIAG_GAUSSIAN = register_family(_module_family(
    diag_gaussian, name="diag_gaussian",
    loglik_fast=_diag_gauss_loglik_fast,
    labels_stats_fast=_diag_labels_stats_fast,
    sweep_fast=_linear_sweep_fast(diag_gaussian),
    feature_shardable=True, feature_stat_fields=("sx", "sxx"),
    slice_params=lambda p, s, n: diag_gaussian.DiagParams(
        mu=_slice_last(p.mu, s, n), log_prec=_slice_last(p.log_prec, s, n)),
    mean_field="sx"))
