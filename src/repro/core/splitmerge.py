"""Metropolis-Hastings split/merge moves (paper §2.3, §4.1) on the
static-capacity state.

Splits: every active cluster proposes splitting into its two sub-clusters
(eq. 20); accepted clusters take a free slot chosen by a prefix-sum slot
allocator. Splits that would exceed K_max are deterministically rejected
(DESIGN §6).

Merges: active clusters are paired by a *random disjoint matching*
(permutation pairing), which also enforces the paper's §4.3 caveat that no
more than two clusters may merge simultaneously; accepted pairs merge with
the old clusters becoming the l/r sub-clusters of the merged one (eq. 21).

The move is split along the model/point boundary (core/state.py):
``plan_split_merge`` does ALL decision math — replicated O(K), no per-point
input beyond the sufficient statistics — and packs the result into a
``SplitMergePlan``; ``split_merge_tile`` applies the plan to one tile of
points (label rewrites + hyperplane sub-label re-init + suff-stat fold).
The resident path runs the tile body once over the whole local shard; the
tiled driver streams it. The post-move stats consistency pass runs through
the same label-indexed ``family.stats_from_labels`` block fold as the sweep
— splits/merges never materialize dense responsibilities either.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.scipy.special import gammaln

from repro.core.family import fold_blocked
from repro.core.gibbs import accumulate_substats


class SplitDecision(NamedTuple):
    accept: jax.Array       # (K,) bool — cluster k splits
    dest: jax.Array         # (K,) int32 — slot for the r-half of cluster k
    new_active: jax.Array   # (K,) bool


class MergeDecision(NamedTuple):
    merged: jax.Array       # (K,) bool — cluster participates in a merge
    into: jax.Array         # (K,) int32 — destination cluster (identity if not)
    side: jax.Array         # (K,) int32 — 0 if kept cluster, 1 if absorbed
    new_active: jax.Array   # (K,) bool


class SplitMergePlan(NamedTuple):
    """Everything a point tile needs to apply one split/merge move:
    the two decisions plus the replicated O(K d) hyperplane geometry.
    Computed once per iteration by ``plan_split_merge``."""
    split: SplitDecision
    merge: MergeDecision
    means_split: jax.Array   # (K, d) cluster means after splits (stats1)
    means_merge: jax.Array   # (K, d) cluster means after merges (stats2)
    vecs_split: jax.Array    # (K, d) hyperplane normals for split re-init
    vecs_reset: jax.Array    # (K, d) hyperplane normals for stuck reset
    reset: jax.Array         # (K,) bool — re-draw sub-labels this iter
    stuck: jax.Array         # (K,) int32 — updated stuck counters


def log_hastings_split(prior, family, stats, substats, alpha: float):
    """log H_split per cluster (paper eq. 12 / 20)."""
    n = stats.n
    nl = substats.n[..., 0]
    nr = substats.n[..., 1]
    logm_c = family.log_marginal(prior, stats)
    logm_sub = family.log_marginal(prior, substats)
    return (jnp.log(alpha)
            + gammaln(jnp.maximum(nl, 1e-6)) + logm_sub[..., 0]
            + gammaln(jnp.maximum(nr, 1e-6)) + logm_sub[..., 1]
            - gammaln(jnp.maximum(n, 1e-6)) - logm_c)


def propose_splits(key: jax.Array, active: jax.Array, stats, substats,
                   prior, family, alpha: float) -> SplitDecision:
    k_max = active.shape[0]
    # NOTE(chain regression): this used to be `k_h, = jax.random.split(key,
    # 1)` — a one-way split where every other key derivation in the sampler
    # uses fold_in. Normalizing to fold_in changes the uniform draws below,
    # so split decisions — and therefore whole chains — differ from
    # pre-tiled-data-plane versions for the same seed. Tests assert
    # seed-relative properties (NMI/K ranges, run-vs-run bitwise equality),
    # not golden labels, so none carry stale goldens.
    k_h = jax.random.fold_in(key, 0)
    log_h = log_hastings_split(prior, family, stats, substats, alpha)
    nl = substats.n[:, 0]
    nr = substats.n[:, 1]
    valid = active & (nl >= 1.0) & (nr >= 1.0)
    u = jax.random.uniform(k_h, (k_max,), minval=1e-12)
    accept = valid & (jnp.log(u) < log_h)

    # prefix-sum slot allocation over free slots
    free = ~active
    priority = jnp.where(free, jnp.arange(k_max), k_max + jnp.arange(k_max))
    free_order = jnp.argsort(priority)              # free slot ids first
    rank = jnp.cumsum(accept.astype(jnp.int32)) - 1
    num_free = jnp.sum(free.astype(jnp.int32))
    accept = accept & (rank < num_free)             # K_max ceiling: reject
    dest = free_order[jnp.clip(rank, 0, k_max - 1)]
    dest = jnp.where(accept, dest, jnp.arange(k_max))

    new_active = active | jax.ops.segment_sum(
        accept.astype(jnp.int32), dest, num_segments=k_max).astype(bool)
    return SplitDecision(accept=accept, dest=dest.astype(jnp.int32),
                         new_active=new_active)


def apply_split_to_stats(family, stats, substats, dec: SplitDecision):
    """stats[k] <- substats[k,l]; stats[dest] <- substats[k,r] (analytic)."""
    def upd(full, sub):
        # sub: (K, 2, ...) ; full: (K, ...)
        left = sub[:, 0]
        right = sub[:, 1]
        shape = (-1,) + (1,) * (full.ndim - 1)
        acc = dec.accept.reshape(shape)
        kept = jnp.where(acc, left, full)
        # scatter right halves into their destination slots
        moved = jax.ops.segment_sum(
            jnp.where(acc, right, jnp.zeros_like(right)),
            dec.dest, num_segments=full.shape[0])
        dest_mask = jax.ops.segment_sum(
            dec.accept.astype(jnp.int32), dec.dest,
            num_segments=full.shape[0]).astype(bool).reshape(shape)
        return jnp.where(dest_mask, moved, kept)
    return jax.tree.map(upd, stats, substats)


def log_hastings_merge(prior, family, stats_a, stats_b, alpha: float):
    """log H_merge for pairs (paper eq. 21)."""
    n1 = stats_a.n
    n2 = stats_b.n
    merged = family.add_stats(stats_a, stats_b)
    logm_1 = family.log_marginal(prior, stats_a)
    logm_2 = family.log_marginal(prior, stats_b)
    logm_m = family.log_marginal(prior, merged)
    a = jnp.asarray(alpha, n1.dtype)
    return (gammaln(jnp.maximum(n1 + n2, 1e-6)) - jnp.log(a)
            - gammaln(jnp.maximum(n1, 1e-6)) - gammaln(jnp.maximum(n2, 1e-6))
            + logm_m - logm_1 - logm_2
            + gammaln(a) - gammaln(a + n1 + n2)
            + gammaln(a / 2 + n1) + gammaln(a / 2 + n2)
            - 2.0 * gammaln(a / 2))


def _pair_log_h(prior, family, stats, alpha: float,
                first: jax.Array, second: jax.Array,
                chunk: int = 256) -> jax.Array:
    """log H_merge for a list of (first, second) pairs, chunk-mapped so the
    merged (d, d) suff-stats never materialize for all pairs at once."""
    n_pairs = first.shape[0]
    pad = (-n_pairs) % chunk
    fi = jnp.concatenate([first, jnp.zeros((pad,), first.dtype)])
    se = jnp.concatenate([second, jnp.zeros((pad,), second.dtype)])

    def body(pair_idx):
        a = jax.tree.map(lambda s: s[pair_idx[0]], stats)
        b = jax.tree.map(lambda s: s[pair_idx[1]], stats)
        return log_hastings_merge(prior, family, a, b, alpha)

    out = jax.lax.map(jax.vmap(body),
                      (fi.reshape(-1, chunk), se.reshape(-1, chunk)))
    return out.reshape(-1)[:n_pairs]


def propose_merges(key: jax.Array, active: jax.Array, stats, prior, family,
                   alpha: float) -> MergeDecision:
    """All-pairs merge proposals (paper §4.1: 'for all pairs k1, k2').

    Every unordered active pair draws its own MH acceptance (eq. 21); the
    accepted set is thinned to a *disjoint matching* by descending-log-H
    priority — enforcing the paper's §4.3 caveat that no three clusters may
    merge into one in a single step.
    """
    k_max = active.shape[0]
    iu, ju = jnp.triu_indices(k_max, k=1)            # (P,) all pairs i<j
    pair_valid = active[iu] & active[ju]
    log_h = _pair_log_h(prior, family, stats, alpha, iu, ju)
    u = jax.random.uniform(key, iu.shape, minval=1e-12)
    accept = pair_valid & (jnp.log(u) < log_h)       # (P,)

    # disjoint thinning: walk pairs in descending log_h, keep a pair only if
    # neither endpoint was already claimed by a better pair.
    order = jnp.argsort(jnp.where(accept, -log_h, jnp.inf))

    def body(p, carry):
        taken, keep = carry
        pid = order[p]
        a, b = iu[pid], ju[pid]
        ok = accept[pid] & ~taken[a] & ~taken[b]
        taken = taken.at[a].set(taken[a] | ok).at[b].set(taken[b] | ok)
        keep = keep.at[pid].set(ok)
        return taken, keep

    taken0 = jnp.zeros((k_max,), bool)
    keep0 = jnp.zeros(iu.shape, bool)
    _, keep = jax.lax.fori_loop(0, iu.shape[0], body, (taken0, keep0))

    # into[j] = i for the (unique, by the matching) kept pair owning j as
    # its second endpoint. NOT a .at[ju].set scatter: ju holds every pair's
    # second endpoint so indices repeat, and scatter order with duplicate
    # indices is implementation-defined — a kept pair's destination could
    # be clobbered by a later non-kept identity update, stranding the
    # absorbed cluster's points on an inactive slot. segment_sum of the
    # (at most one) kept delta per endpoint is order-free.
    delta = jax.ops.segment_sum(
        jnp.where(keep, iu.astype(jnp.int32) - ju.astype(jnp.int32), 0),
        ju, num_segments=k_max)
    into = (jnp.arange(k_max, dtype=jnp.int32) + delta).astype(jnp.int32)
    merged = jnp.zeros((k_max,), bool)
    merged = merged.at[iu].max(keep)
    merged = merged.at[ju].max(keep)
    side = jnp.zeros((k_max,), jnp.int32)
    side = side.at[ju].max(keep.astype(jnp.int32))
    new_active = active & ~(jnp.zeros((k_max,), bool).at[ju].max(keep))
    return MergeDecision(merged=merged, into=into, side=side,
                         new_active=new_active)


def apply_merge_to_stats(stats, dec: MergeDecision):
    """stats[into[b]] += stats[b]; stats[b] <- 0 for absorbed b."""
    def upd(s):
        shape = (-1,) + (1,) * (s.ndim - 1)
        absorbed = (dec.side == 1).reshape(shape)
        contrib = jnp.where(absorbed, s, jnp.zeros_like(s))
        moved = jax.ops.segment_sum(contrib, dec.into,
                                    num_segments=s.shape[0])
        return jnp.where(absorbed, jnp.zeros_like(s), s + moved)
    return jax.tree.map(upd, stats)


def hyperplane_vecs(key: jax.Array, k_max: int, d: int,
                    dtype=jnp.float32) -> jax.Array:
    """(K, d) random unit normals — the replicated half of the hyperplane
    sub-label init, drawn once per move so every tile slices the same
    geometry."""
    v = jax.random.normal(key, (k_max, d), dtype=dtype)
    return v / jnp.linalg.norm(v, axis=-1, keepdims=True)


def hyperplane_bits(x: jax.Array, labels: jax.Array, means: jax.Array,
                    v: jax.Array, feat_axis=None) -> jax.Array:
    """Sub-label init by a random hyperplane through each cluster's mean.

    Newly-born clusters get 'two new sub-clusters'; a hyperplane split is a
    valid (auxiliary-variable) initialization that starts the sub-cluster
    Gibbs from a *separable* configuration, so split proposals become
    acceptable in O(10) sweeps instead of O(100) (EXPERIMENTS §Paper-claims
    ablation). The MH correction (eq. 20) is unchanged. Pure per-point given
    the replicated (means, v) — tile/shard oblivious.
    """
    if feat_axis is not None:
        # x holds a local feature slice; means/v are full-d (replicated,
        # same on every shard). Slice them and psum the projection.
        i = jax.lax.axis_index(feat_axis)
        dl = x.shape[1]
        means = jax.lax.dynamic_slice_in_dim(means, i * dl, dl, axis=-1)
        v = jax.lax.dynamic_slice_in_dim(v, i * dl, dl, axis=-1)
        proj = jax.lax.psum(
            jnp.sum((x - means[labels]) * v[labels], axis=-1), feat_axis)
    else:
        proj = jnp.sum((x - means[labels]) * v[labels], axis=-1)
    return (proj > 0).astype(jnp.int32)


def relabel_after_split(labels: jax.Array, sublabels: jax.Array,
                        dec: SplitDecision, new_bits: jax.Array
                        ) -> Tuple[jax.Array, jax.Array]:
    """Points of split cluster k with zbar=r move to dest; fresh sub-labels
    for both halves (the newly-born clusters get two new sub-clusters)."""
    was_split = dec.accept[labels]
    z = jnp.where(was_split & (sublabels == 1), dec.dest[labels], labels)
    zb = jnp.where(was_split, new_bits, sublabels)
    return z.astype(jnp.int32), zb.astype(jnp.int32)


def relabel_after_merge(labels: jax.Array, sublabels: jax.Array,
                        dec: MergeDecision) -> Tuple[jax.Array, jax.Array]:
    """Merged pair (a,b) -> a; old clusters become the l/r sub-clusters."""
    was_merged = dec.merged[labels]
    zb = jnp.where(was_merged, dec.side[labels], sublabels)
    z = dec.into[labels]
    return z.astype(jnp.int32), zb.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Model-side plan / tile-side apply
# ---------------------------------------------------------------------------
def plan_split_merge(key: jax.Array, model, prior, family, alpha: float,
                     subreset_every: int) -> SplitMergePlan:
    """All split/merge decision math — replicated O(K), zero per-point
    input. ``key`` is the per-iteration move key (sampler derives it from
    (model.key, model.it))."""
    k_s, k_m, k_b = jax.random.split(key, 3)

    dec_s = propose_splits(k_s, model.active, model.stats, model.substats,
                           prior, family, alpha)
    stats1 = apply_split_to_stats(family, model.stats, model.substats, dec_s)
    dec_m = propose_merges(k_m, dec_s.new_active, stats1, prior, family,
                           alpha)

    # sub-cluster reset: clusters whose split keeps being rejected re-draw
    # their sub-labels from a fresh hyperplane (escapes sub-Gibbs local
    # modes; the reference DPMMSubClusters does the same). The MH target is
    # untouched — sub-labels are auxiliary proposal state.
    stuck = jnp.where(dec_s.accept | dec_m.merged | ~model.active,
                      0, model.stuck + 1)
    reset = stuck >= subreset_every
    stuck = jnp.where(reset, 0, stuck).astype(jnp.int32)
    stats2 = apply_merge_to_stats(stats1, dec_m)

    means1 = family.cluster_means(stats1)
    k_max, d = means1.shape
    return SplitMergePlan(
        split=dec_s, merge=dec_m,
        means_split=means1, means_merge=family.cluster_means(stats2),
        vecs_split=hyperplane_vecs(k_b, k_max, d, means1.dtype),
        vecs_reset=hyperplane_vecs(jax.random.fold_in(k_b, 1), k_max, d,
                                   means1.dtype),
        reset=reset, stuck=stuck)


def _apply_plan_block(plan: SplitMergePlan, x: jax.Array,
                      labels: jax.Array, sublabels: jax.Array, feat_axis):
    """The per-point relabel + hyperplane math of one planned move, on one
    resident block of points — shared by the fused and three-pass tiles."""
    # provisional relabel (moves r-halves to their new slots) ...
    labels_mid = jnp.where(
        plan.split.accept[labels] & (sublabels == 1),
        plan.split.dest[labels], labels).astype(jnp.int32)
    # ... then hyperplane sub-label init around the *post-split* means
    bits = hyperplane_bits(x, labels_mid, plan.means_split, plan.vecs_split,
                           feat_axis)
    labels1, sublabels1 = relabel_after_split(labels, sublabels, plan.split,
                                              bits)
    labels2, sublabels2 = relabel_after_merge(labels1, sublabels1,
                                              plan.merge)
    bits2 = hyperplane_bits(x, labels2, plan.means_merge, plan.vecs_reset,
                            feat_axis)
    sublabels2 = jnp.where(plan.reset[labels2], bits2, sublabels2)
    return labels2, sublabels2


def split_merge_tile(plan: SplitMergePlan, x: jax.Array, point, acc,
                     family, use_pallas: bool = False, feat_axis=None, *,
                     fused: bool = True, compaction=None):
    """Apply a planned move to one tile of points: relabels, both
    hyperplane sub-label re-inits, AND the consistency suff-stat fold
    (paper §4.4: 'processing accepted splits/merges requires updating the
    sufficient statistics') run per STATS_BLOCK block while the block is
    resident — one read of x per move, the same one-read pass shape as
    the fused sweep (``family.fold_blocked``). ``fused=False`` keeps the
    pre-fusion whole-tile-then-fold body as the parity oracle; chains are
    bitwise identical either way.

    With ``compaction`` (a ``gibbs.CompactionPlan`` built from the
    *post-move* active set), the stat fold runs on a compact
    O(K_active)-row ``acc`` — labels are re-indexed through
    ``compact_of_slot`` for the fold only, and the returned labels stay in
    dense slot space. Each compact row receives exactly the same adds in
    the same order as its dense slot, so the folded partials are bitwise
    the dense partials (the caller scatters them back to the full slab).
    """
    k_max = plan.reset.shape[0]
    if compaction is None:
        k_stat, label_map = k_max, None
    else:
        k_stat = compaction.slot_of_compact.shape[0]
        label_map = compaction.compact_of_slot
    labels, sublabels = point.labels, point.sublabels
    if not fused:
        labels2, sublabels2 = _apply_plan_block(plan, x, labels, sublabels,
                                                feat_axis)
        stat_lab = labels2 if label_map is None else label_map[labels2]
        acc = accumulate_substats(family, x, point.valid, stat_lab,
                                  sublabels2, k_stat, acc, use_pallas)
        return point._replace(labels=labels2, sublabels=sublabels2), acc

    def body(xb, vb, lb, sb):
        del vb                        # relabel math ignores the pad mask
        return _apply_plan_block(plan, xb, lb, sb, feat_axis)

    labels2, sublabels2, acc = fold_blocked(
        family, k_stat, body, x, point.valid, (labels, sublabels), acc,
        use_pallas=use_pallas, label_map=label_map)
    return point._replace(labels=labels2, sublabels=sublabels2), acc
