"""Shared segment-sum moments-from-labels for feature-separable families.

The jnp reference path of ``stats_from_labels`` is identical for every
family whose sufficient statistics are first moments of some per-point
feature map (multinomial: x, poisson: x, diag-Gaussian: [x, x^2]): scatter
each point's features into segment s = 2*label + sublabel, with invalid
(padding) points routed to a sacrificial segment that is sliced off. No
dense (N, K) / (N, K, 2) responsibility tensor ever exists. This mirrors
the families' shared Pallas fast path (kernels/suffstats.moments_labels),
which builds the equivalent one-hot per tile in VMEM instead.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def moments_from_labels(feats: jax.Array, valid: jax.Array,
                        labels: jax.Array, sublabels: jax.Array,
                        k_max: int) -> Tuple[jax.Array, jax.Array]:
    """feats: (N, d') -> (n (k_max, 2), sf (k_max, 2, d'))."""
    s = 2 * k_max
    seg = jnp.where(valid, labels * 2 + sublabels, s)
    n2 = jax.ops.segment_sum(valid.astype(feats.dtype), seg,
                             num_segments=s + 1)[:s]
    sf2 = jax.ops.segment_sum(feats, seg, num_segments=s + 1)[:s]
    return (n2.reshape(k_max, 2),
            sf2.reshape(k_max, 2, feats.shape[-1]))
