"""Clustering metrics: NMI (paper's accuracy metric), ARI, cluster counts."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _entropy(p: jax.Array) -> jax.Array:
    p = jnp.where(p > 0, p, 1.0)
    return -jnp.sum(p * jnp.log(p))


def contingency(true: jax.Array, pred: jax.Array, n_true: int, n_pred: int,
                weights=None) -> jax.Array:
    w = jnp.ones_like(true, dtype=jnp.float32) if weights is None else weights
    ot = jax.nn.one_hot(true, n_true, dtype=jnp.float32) * w[:, None]
    op = jax.nn.one_hot(pred, n_pred, dtype=jnp.float32)
    return ot.T @ op


def nmi(true: jax.Array, pred: jax.Array, n_true: int, n_pred: int,
        weights=None) -> jax.Array:
    """Normalized mutual information (arithmetic normalization, as sklearn).

    The paper reports NMI for every experiment (Figs 5, 7, 9).
    """
    c = contingency(true, pred, n_true, n_pred, weights)
    n = jnp.sum(c)
    pij = c / n
    pi = jnp.sum(pij, axis=1)
    pj = jnp.sum(pij, axis=0)
    outer = pi[:, None] * pj[None, :]
    mask = pij > 0
    mi = jnp.sum(jnp.where(mask, pij * (jnp.log(jnp.where(mask, pij, 1.0))
                                        - jnp.log(jnp.where(mask, outer, 1.0))),
                           0.0))
    hu = _entropy(pi)
    hv = _entropy(pj)
    denom = 0.5 * (hu + hv)
    return jnp.where(denom > 0, mi / denom, 1.0)


def ari(true: jax.Array, pred: jax.Array, n_true: int, n_pred: int,
        weights=None) -> jax.Array:
    """Adjusted Rand index (extra beyond the paper; useful cross-check)."""
    c = contingency(true, pred, n_true, n_pred, weights)
    n = jnp.sum(c)

    def comb2(x):
        return x * (x - 1.0) / 2.0

    sum_ij = jnp.sum(comb2(c))
    a = jnp.sum(comb2(jnp.sum(c, axis=1)))
    b = jnp.sum(comb2(jnp.sum(c, axis=0)))
    expected = a * b / comb2(n)
    max_index = 0.5 * (a + b)
    return jnp.where(max_index > expected,
                     (sum_ij - expected) / (max_index - expected), 0.0)
