"""Dirichlet-Multinomial conjugate component (count/discrete observations).

Covers the paper's DPMNMM experiments (§5.2, 20newsgroups §5.3). Points are
count vectors ``x_i in N^d`` (e.g. bag-of-words). The prior over component
parameters is Dir(alpha0 * 1_d).

The per-point multinomial coefficient log(n_i! / prod_j x_ij!) is dropped
everywhere: it is label-independent, so it cancels in the assignment
softmax and appears exactly once in both numerator and denominator of every
split/merge Hastings ratio (each point belongs to exactly one of C_l/C_r and
to C). See DESIGN §6.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.scipy.special import gammaln


class MultPrior(NamedTuple):
    alpha0: jax.Array     # () symmetric Dirichlet concentration
    d: int


class MultStats(NamedTuple):
    n: jax.Array          # (*B,) number of points
    counts: jax.Array     # (*B, d) summed count vectors


class MultParams(NamedTuple):
    logtheta: jax.Array   # (*B, d)


def default_prior(d: int, alpha0: float, dtype=jnp.float32) -> MultPrior:
    return MultPrior(alpha0=jnp.asarray(alpha0, dtype), d=d)


def build_prior(cfg, x) -> MultPrior:
    """Family hook (core/family.py): prior from config + data."""
    return default_prior(x.shape[1], cfg.dir_alpha)


def param_struct() -> MultParams:
    """Pytree template (leaves are placeholders) for spec-mapping."""
    return MultParams(logtheta=0)


def stats_struct() -> MultStats:
    return MultStats(n=0, counts=0)


def empty_stats(batch_shape: tuple, d: int, dtype=jnp.float32) -> MultStats:
    return MultStats(n=jnp.zeros(batch_shape, dtype),
                     counts=jnp.zeros(batch_shape + (d,), dtype))


def stats_from_points(x: jax.Array, resp: jax.Array) -> MultStats:
    n = jnp.sum(resp, axis=0)
    bshape = resp.shape[1:]
    r2 = resp.reshape(resp.shape[0], -1)
    counts = jnp.einsum("nb,nd->bd", r2, x)
    return MultStats(n=n, counts=counts.reshape(bshape + (x.shape[-1],)))


def add_stats(a: MultStats, b: MultStats) -> MultStats:
    return MultStats(a.n + b.n, a.counts + b.counts)


def stats_from_labels(x: jax.Array, valid: jax.Array, labels: jax.Array,
                      sublabels: jax.Array, k_max: int) -> MultStats:
    """(k_max, 2)-batched sub-cluster stats via segment-sum — no dense
    responsibility tensor (core/labelstats.py). Cluster stats are the
    fold over the sub axis (gibbs.compute_stats)."""
    from repro.core.labelstats import moments_from_labels
    n2, counts2 = moments_from_labels(x, valid, labels, sublabels, k_max)
    return MultStats(n=n2, counts=counts2)


def assign_pack(x: jax.Array, params: MultParams):
    """Linear-likelihood packing for the fused assignment kernels
    (kernels/assign.py): loglik(x)_b = feats @ w_b + const_b."""
    return x, params.logtheta, jnp.zeros(params.logtheta.shape[:-1],
                                         x.dtype)


def sweep_pack(x: jax.Array, params: MultParams, subparams: MultParams):
    """One-read sweep packing (kernels/sweep.py): the shared feature block
    (here x itself — it is also the stat feature map) plus the (K, d') and
    (K, 2, d') linear forms for steps (e)/(f)."""
    feats, w, const = assign_pack(x, params)
    _, subw, subconst = assign_pack(x, subparams)
    return feats, w, const, subw, subconst


def stats_from_moments(n2: jax.Array, sf2: jax.Array) -> MultStats:
    """Sub-cluster stats from the fused sweep's folded moments: the stat
    features are x itself, so the moment sums ARE the counts."""
    return MultStats(n=n2, counts=sf2)


def log_marginal(prior: MultPrior, stats: MultStats) -> jax.Array:
    """Dirichlet-multinomial marginal (multinomial coefficients dropped).

    log m(C) = log G(A) - log G(A + M) + sum_j [log G(a0 + c_j) - log G(a0)]
    with A = d * a0, M = sum_j c_j.
    """
    a0 = prior.alpha0
    a_tot = prior.d * a0
    m_tot = jnp.sum(stats.counts, axis=-1)
    return (gammaln(a_tot) - gammaln(a_tot + m_tot)
            + jnp.sum(gammaln(a0 + stats.counts) - gammaln(a0), axis=-1))


def sample_posterior(key: jax.Array, prior: MultPrior,
                     stats: MultStats) -> MultParams:
    """theta ~ Dir(alpha0 + counts), batched; returns log theta."""
    conc = prior.alpha0 + stats.counts
    g = jax.random.gamma(key, conc)
    g = jnp.maximum(g, 1e-30)
    logtheta = jnp.log(g) - jnp.log(jnp.sum(g, axis=-1, keepdims=True))
    return MultParams(logtheta=logtheta)


def expected_params(prior: MultPrior, stats: MultStats) -> MultParams:
    conc = prior.alpha0 + stats.counts
    logtheta = jnp.log(conc) - jnp.log(jnp.sum(conc, axis=-1, keepdims=True))
    return MultParams(logtheta=logtheta)


def loglik(x: jax.Array, params: MultParams) -> jax.Array:
    """sum_j x_ij log theta_bj for all points/clusters -> (N, *B).

    A pure (N,d) x (d, B) matmul: the paper's 'Kernel #1 vs #2' auto-selected
    matmul (kernels/matmul.py) serves this on TPU.
    """
    lt = params.logtheta.reshape(-1, params.logtheta.shape[-1])
    out = x @ lt.T
    return out.reshape((x.shape[0],) + params.logtheta.shape[:-1])
