"""Resilience layer for long fits: tile-read retry, health checks, typed
failures.

A large DPMM fit is a *long* fit — the out-of-core driver streams memmap
tiles for hours, and one flipped bit or transient ``EIO`` used to kill
the chain (or worse, silently poison it: a NaN anywhere in ``ModelState``
propagates through every subsequent sweep). This module holds the three
primitives the drivers (core/sampler.py) compose into fault tolerance:

 - :class:`RetryPolicy` + :func:`read_block_checked` — bounded
   retry-with-backoff around ``DataSource.read_block``. Transient
   ``IOError``/``OSError``, short reads, and (``guard_nonfinite``)
   NaN/Inf rows are treated as retryable tile faults; exhaustion raises
   :class:`TileReadError` *with tile provenance* (row range, attempt
   count, last failure) so a dead disk region is diagnosable from the
   traceback alone.
 - :func:`model_health` — an O(K) on-device all-finite + degenerate-
   cluster check over ``ModelState``. It reads state the drivers already
   sync (stats, weights), adds no host round-trip of its own (its scalar
   verdict rides the existing chunk-boundary ``device_get``), and never
   touches the chain — clean fits stay bitwise identical with the check
   on or off.
 - :class:`DivergenceError` — raised when rollback cannot save the fit
   (no healthy state to roll back to more than ``max_recoveries`` times);
   carries the ``recoveries`` log for post-mortems.

Fault *injection* for testing all of the above lives in data/faults.py.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.state import ModelState


class TileReadError(RuntimeError):
    """A streamed tile read failed past the retry budget. The message
    carries full provenance: global row range, attempts, last failure."""


class DivergenceError(RuntimeError):
    """The chain diverged (non-finite state / degenerate clusters) and
    rollback could not recover it within ``max_recoveries`` attempts.
    ``recoveries`` holds the per-event log (same records as
    ``FitResult.recoveries``)."""

    def __init__(self, message: str, recoveries: Optional[List[dict]] = None):
        super().__init__(message)
        self.recoveries: List[dict] = list(recoveries or [])


class WorkerLostError(RuntimeError):
    """A distributed worker shard was lost (died, hung past its deadline,
    or was killed) and failover could not finish the fit: no surviving
    worker was available and the ``max_worker_retries`` respawn budget
    was exhausted. ``recoveries`` holds the per-event log — including the
    ``worker_failover`` records leading up to the failure — for
    post-mortems (same records as ``FitResult.recoveries``)."""

    def __init__(self, message: str, recoveries: Optional[List[dict]] = None):
        super().__init__(message)
        self.recoveries: List[dict] = list(recoveries or [])


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry-with-backoff for streamed tile reads.

    ``max_retries`` is the number of *re*-attempts after the first try
    (so ``max_retries=3`` means at most 4 reads of the block). Backoff
    sleeps ``backoff_s * backoff_mult**i`` before retry i — transient
    faults (NFS hiccup, loaded disk) get breathing room, while the bound
    keeps a dead source from hanging the fit. ``guard_nonfinite`` treats
    NaN/Inf rows in a tile as a retryable fault too: a re-read of a
    bit-flipped buffer is clean, and a *persistently* non-finite tile
    (really-broken data) fails loudly instead of poisoning the chain.
    """
    max_retries: int = 3
    backoff_s: float = 0.05
    backoff_mult: float = 2.0
    guard_nonfinite: bool = True


def read_block_checked(source, start: int, stop: int,
                       policy: RetryPolicy,
                       on_event: Optional[Callable[[dict], None]] = None
                       ) -> np.ndarray:
    """``source.read_block(start, stop)`` under ``policy``.

    Validates every read: row count must match (short reads retry) and,
    with ``policy.guard_nonfinite``, all values must be finite. Each
    failed attempt is reported to ``on_event`` (the drivers append these
    records to ``FitResult.recoveries``); exhaustion raises
    :class:`TileReadError` with the tile's provenance.
    """
    want = stop - start
    last = "no attempt made"
    for attempt in range(policy.max_retries + 1):
        if attempt:
            delay = policy.backoff_s * policy.backoff_mult ** (attempt - 1)
            if delay > 0:
                time.sleep(delay)
        try:
            rows = source.read_block(start, stop)
        except (IOError, OSError) as e:
            last = f"{type(e).__name__}: {e}"
        else:
            if rows.shape[0] != want:
                last = (f"short read: got {rows.shape[0]} rows, "
                        f"want {want}")
            elif (policy.guard_nonfinite
                  and not np.isfinite(rows).all()):
                bad = np.flatnonzero(~np.isfinite(rows).all(axis=1))
                last = (f"non-finite values in {bad.size} row(s), first "
                        f"at global row {start + int(bad[0])}")
            else:
                if attempt and on_event is not None:
                    # recovered after retries: leave an audit trail, not
                    # just the per-attempt fault records (a fit that only
                    # succeeded on re-reads should say so in recoveries)
                    on_event({"kind": "io_retry",
                              "rows": [int(start), int(stop)],
                              "attempts": attempt + 1,
                              "detail": f"recovered after {attempt} "
                                        f"retr{'y' if attempt == 1 else 'ies'}"
                                        f"; last failure: {last}"})
                return rows
        if on_event is not None:
            on_event({"kind": "tile_read_fault",
                      "rows": [int(start), int(stop)],
                      "attempt": attempt + 1, "detail": last})
    raise TileReadError(
        f"read_block rows [{start}, {stop}) failed after "
        f"{policy.max_retries + 1} attempt(s); last failure: {last}")


def model_health(model: ModelState) -> jax.Array:
    """Scalar bool: is this ``ModelState`` numerically sane?

    Checks (all O(K) reductions over replicated state — no per-point
    work, and purely *reads* the model, so the chain is untouched):

     - every sufficient-statistic leaf (stats + substats) is finite on
       *active* slots — a NaN/Inf data row poisons the stat fold of the
       cluster that owns it, so this is the earliest on-device detection
       point. Inactive slots are ignored: no point folds into them, and
       they are re-zeroed on activation, so garbage there cannot reach
       the chain;
     - ``logweights`` are finite on *active* slots (inactive slots are
       legitimately at the NEG_INF floor);
     - no degenerate cluster: active slots have non-negative counts
       (a negative ``n`` means a corrupted fold, not a small cluster).

    A multi-chain model (leading chain axis) reduces over all chains —
    one unhealthy chain fails the whole state, and rollback restores all
    chains together (they share the jitted chunk).
    """
    active = model.active

    def finite_on_active(leaf):
        # stats leaves are active.shape + extra dims, substats leaves
        # active.shape + (2,) + extra — one right-padded mask fits both
        mask = active.reshape(
            active.shape + (1,) * (leaf.ndim - active.ndim))
        return jnp.isfinite(jnp.where(mask, leaf, 0.0)).all()

    checks = [finite_on_active(leaf)
              for leaf in jax.tree_util.tree_leaves((model.stats,
                                                     model.substats))
              if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating)]
    checks.append(jnp.isfinite(
        jnp.where(model.active, model.logweights, 0.0)).all())
    checks.append(jnp.all(
        jnp.where(model.active, model.stats.n, 0.0) >= 0.0))
    return functools.reduce(jnp.logical_and, checks)
