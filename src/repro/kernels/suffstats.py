"""Pallas TPU kernel for per-cluster sufficient statistics — the paper's
per-stream suff-stat accumulation (§4.4, 3-step update), as masked matmuls.

Given points x (N, d) and responsibilities resp (N, K) (one-hot labels, or
label x sub-label products for the sub-cluster stats):
    n_k  = sum_i r_ik          (K,)
    sx_k = sum_i r_ik x_i      (K, d)     = resp^T @ x        (MXU)
    sxx_k = sum_i r_ik x_i x_i^T (K,d,d)  = batched (d,bn)@(bn,d) per k

Tiling: grid (K/bk, N/bn) with the N axis innermost and *revisited*: the
output tiles (bk,), (bk, d), (bk, d, d) stay resident in VMEM and
accumulate across N steps — the TPU analogue of the paper's per-stream
partial sums, with the cross-device psum happening outside the kernel.
VMEM (bk=8, bn=128, d<=128): x 64k + resp 4k + sxx 512k + masked 512k f32.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _suffstats_kernel(x_ref, r_ref, n_ref, sx_ref, sxx_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        n_ref[...] = jnp.zeros_like(n_ref)
        sx_ref[...] = jnp.zeros_like(sx_ref)
        sxx_ref[...] = jnp.zeros_like(sxx_ref)

    x = x_ref[...]                                   # (bn, d)
    r = r_ref[...]                                   # (bn, bk)
    n_ref[...] += jnp.sum(r, axis=0)
    sx_ref[...] += jnp.dot(r.T, x, preferred_element_type=jnp.float32)
    # masked points per cluster: (bk, bn, d), then batched x^T x on the MXU
    xw = r.T[:, :, None] * x[None, :, :]             # (bk, bn, d)
    sxx_ref[...] += jax.lax.dot_general(
        xw.transpose(0, 2, 1), jnp.broadcast_to(x, (r.shape[1],) + x.shape),
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)          # (bk, d, d)


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def suffstats(x: jax.Array, resp: jax.Array, *, bn: int = 128, bk: int = 8,
              interpret: bool = False
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (N, d); resp: (N, K) -> (n (K,), sx (K, d), sxx (K, d, d))."""
    n_pts, d = x.shape
    k = resp.shape[1]
    bn = min(bn, n_pts) or 1
    bk = min(bk, k) or 1
    pn, pk = (-n_pts) % bn, (-k) % bk
    if pn:
        x = jnp.pad(x, ((0, pn), (0, 0)))
        resp = jnp.pad(resp, ((0, pn), (0, 0)))
    if pk:
        resp = jnp.pad(resp, ((0, 0), (0, pk)))
    gk, gn = resp.shape[1] // bk, x.shape[0] // bn

    n_out, sx, sxx = pl.pallas_call(
        _suffstats_kernel,
        grid=(gk, gn),                       # N innermost: accumulation
        in_specs=[
            pl.BlockSpec((bn, d), lambda j, i: (i, 0)),
            pl.BlockSpec((bn, bk), lambda j, i: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bk,), lambda j, i: (j,)),
            pl.BlockSpec((bk, d), lambda j, i: (j, 0)),
            pl.BlockSpec((bk, d, d), lambda j, i: (j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((resp.shape[1],), jnp.float32),
            jax.ShapeDtypeStruct((resp.shape[1], d), jnp.float32),
            jax.ShapeDtypeStruct((resp.shape[1], d, d), jnp.float32),
        ],
        interpret=interpret,
    )(x, resp)
    return n_out[:k], sx[:k], sxx[:k]
