"""Pallas TPU kernels for per-cluster sufficient statistics — the paper's
per-stream suff-stat accumulation (§4.4, 3-step update), as masked matmuls.

Two generations of kernel live here:

``suffstats`` (dense responsibilities)
    Given points x (N, d) and responsibilities resp (N, K) (one-hot labels,
    or label x sub-label products for the sub-cluster stats):
        n_k  = sum_i r_ik          (K,)
        sx_k = sum_i r_ik x_i      (K, d)     = resp^T @ x        (MXU)
        sxx_k = sum_i r_ik x_i x_i^T (K,d,d)  = batched (d,bn)@(bn,d) per k
    The caller must materialize resp in HBM — kept as the dense oracle.

``suffstats_labels`` / ``moments_labels`` (label-indexed, the hot path)
    Take int32 ``labels``/``sublabels``/``valid`` directly and build the
    one-hot *per tile in VMEM* over segments s = 2*label + sublabel, so no
    (N, K) or (N, K, 2) responsibility tensor ever exists in HBM. One pass
    over x yields the (K, 2, ...) sub-cluster stats; cluster stats are the
    fold over the sub axis (core/gibbs.compute_stats). ``moments_labels``
    is the first-moment-only variant serving the feature-separable families
    (multinomial / poisson / diag-Gaussian via stacked [x, x^2] features).

Tiling: grid (S/bk, N/bn) with the N axis innermost and *revisited*: the
output tiles stay resident in VMEM and accumulate across N steps — the TPU
analogue of the paper's per-stream partial sums, with the cross-device psum
happening outside the kernel.
VMEM (bk=8, bn=128, d<=128): x 64k + resp 4k + sxx 512k + masked 512k f32.
``MAX_KERNEL_D`` guards that budget: the (bk, d, d) output tile and the
(bk, bn, d) masked intermediate grow as d^2 / d, so d > 128 would blow the
~16 MiB VMEM; callers (kernels/ops.py) fall back to the jnp reference
(kernels/ref.py or the families' segment-sum paths) above it.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref

# VMEM ceiling for the feature dimension (see module docstring); above it
# every entry point here returns the jnp reference result instead. This is
# THE canonical kernel-d guard: loglik.py and ops.py import it from here.
MAX_KERNEL_D = 128


def _suffstats_kernel(x_ref, r_ref, n_ref, sx_ref, sxx_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        n_ref[...] = jnp.zeros_like(n_ref)
        sx_ref[...] = jnp.zeros_like(sx_ref)
        sxx_ref[...] = jnp.zeros_like(sxx_ref)

    x = x_ref[...]                                   # (bn, d)
    r = r_ref[...]                                   # (bn, bk)
    n_ref[...] += jnp.sum(r, axis=0)
    sx_ref[...] += jnp.dot(r.T, x, preferred_element_type=jnp.float32)
    # masked points per cluster: (bk, bn, d), then batched x^T x on the MXU
    xw = r.T[:, :, None] * x[None, :, :]             # (bk, bn, d)
    sxx_ref[...] += jax.lax.dot_general(
        xw.transpose(0, 2, 1), jnp.broadcast_to(x, (r.shape[1],) + x.shape),
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)          # (bk, d, d)


def _tile_resp(lab_ref, sub_ref, val_ref, j: int, bk: int) -> jax.Array:
    """(bn, bk) one-hot over segments s = 2*label + sublabel, in VMEM."""
    seg = lab_ref[...] * 2 + sub_ref[...]            # (bn,)
    col = (jnp.int32(j * bk)
           + jax.lax.broadcasted_iota(jnp.int32, (seg.shape[0], bk), 1))
    return ((seg[:, None] == col).astype(jnp.float32)
            * val_ref[...][:, None])


def _suffstats_labels_kernel(x_ref, lab_ref, sub_ref, val_ref,
                             n_ref, sx_ref, sxx_ref):
    r_ref = _tile_resp(lab_ref, sub_ref, val_ref, pl.program_id(0),
                       n_ref.shape[0])

    @pl.when(pl.program_id(1) == 0)
    def _init():
        n_ref[...] = jnp.zeros_like(n_ref)
        sx_ref[...] = jnp.zeros_like(sx_ref)
        sxx_ref[...] = jnp.zeros_like(sxx_ref)

    x = x_ref[...]
    r = r_ref
    n_ref[...] += jnp.sum(r, axis=0)
    sx_ref[...] += jnp.dot(r.T, x, preferred_element_type=jnp.float32)
    xw = r.T[:, :, None] * x[None, :, :]
    sxx_ref[...] += jax.lax.dot_general(
        xw.transpose(0, 2, 1), jnp.broadcast_to(x, (r.shape[1],) + x.shape),
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)


def _moments_labels_kernel(x_ref, lab_ref, sub_ref, val_ref, n_ref, sx_ref):
    r = _tile_resp(lab_ref, sub_ref, val_ref, pl.program_id(0),
                   n_ref.shape[0])

    @pl.when(pl.program_id(1) == 0)
    def _init():
        n_ref[...] = jnp.zeros_like(n_ref)
        sx_ref[...] = jnp.zeros_like(sx_ref)

    n_ref[...] += jnp.sum(r, axis=0)
    sx_ref[...] += jnp.dot(r.T, x_ref[...],
                           preferred_element_type=jnp.float32)


def _pad_points(arrs, bn: int):
    n = arrs[0].shape[0]
    pn = (-n) % bn
    if not pn:
        return arrs
    out = []
    for a in arrs:
        widths = [(0, pn)] + [(0, 0)] * (a.ndim - 1)
        out.append(jnp.pad(a, widths))
    return out


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def suffstats(x: jax.Array, resp: jax.Array, *, bn: int = 128, bk: int = 8,
              interpret: bool = False
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (N, d); resp: (N, K) -> (n (K,), sx (K, d), sxx (K, d, d))."""
    n_pts, d = x.shape
    if d > MAX_KERNEL_D:                 # documented VMEM guard: jnp path
        return ref.suffstats(x, resp)
    k = resp.shape[1]
    bn = min(bn, n_pts) or 1
    bk = min(bk, k) or 1
    pn, pk = (-n_pts) % bn, (-k) % bk
    if pn:
        x = jnp.pad(x, ((0, pn), (0, 0)))
        resp = jnp.pad(resp, ((0, pn), (0, 0)))
    if pk:
        resp = jnp.pad(resp, ((0, 0), (0, pk)))
    gk, gn = resp.shape[1] // bk, x.shape[0] // bn

    n_out, sx, sxx = pl.pallas_call(
        _suffstats_kernel,
        grid=(gk, gn),                       # N innermost: accumulation
        in_specs=[
            pl.BlockSpec((bn, d), lambda j, i: (i, 0)),
            pl.BlockSpec((bn, bk), lambda j, i: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bk,), lambda j, i: (j,)),
            pl.BlockSpec((bk, d), lambda j, i: (j, 0)),
            pl.BlockSpec((bk, d, d), lambda j, i: (j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((resp.shape[1],), jnp.float32),
            jax.ShapeDtypeStruct((resp.shape[1], d), jnp.float32),
            jax.ShapeDtypeStruct((resp.shape[1], d, d), jnp.float32),
        ],
        interpret=interpret,
    )(x, resp)
    return n_out[:k], sx[:k], sxx[:k]


@functools.partial(jax.jit,
                   static_argnames=("k", "bn", "bk", "interpret"))
def suffstats_labels(x: jax.Array, labels: jax.Array, sublabels: jax.Array,
                     valid: jax.Array, k: int, *, bn: int = 128,
                     bk: int = 8, interpret: bool = False
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Label-indexed sub-cluster stats; one-hot never leaves VMEM.

    x: (N, d); labels/sublabels: (N,) int32; valid: (N,) bool ->
    (n (k, 2), sx (k, 2, d), sxx (k, 2, d, d)).
    """
    n_pts, d = x.shape
    assert d <= MAX_KERNEL_D, (
        f"suffstats_labels: d={d} exceeds the VMEM budget "
        f"(MAX_KERNEL_D={MAX_KERNEL_D}); use the family's segment-sum "
        "reference path (kernels/ops.py guards this)")
    s = 2 * k
    bn = min(bn, n_pts) or 1
    bk = min(bk, s)
    x, labels, sublabels, valid = _pad_points(
        (x, labels, sublabels, jnp.asarray(valid, jnp.float32)), bn)
    ps = (-s) % bk
    gk, gn = (s + ps) // bk, x.shape[0] // bn

    n2, sx2, sxx2 = pl.pallas_call(
        _suffstats_labels_kernel,
        grid=(gk, gn),
        in_specs=[
            pl.BlockSpec((bn, d), lambda j, i: (i, 0)),
            pl.BlockSpec((bn,), lambda j, i: (i,)),
            pl.BlockSpec((bn,), lambda j, i: (i,)),
            pl.BlockSpec((bn,), lambda j, i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bk,), lambda j, i: (j,)),
            pl.BlockSpec((bk, d), lambda j, i: (j, 0)),
            pl.BlockSpec((bk, d, d), lambda j, i: (j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s + ps,), jnp.float32),
            jax.ShapeDtypeStruct((s + ps, d), jnp.float32),
            jax.ShapeDtypeStruct((s + ps, d, d), jnp.float32),
        ],
        interpret=interpret,
    )(x, labels, sublabels, valid)
    return (n2[:s].reshape(k, 2), sx2[:s].reshape(k, 2, d),
            sxx2[:s].reshape(k, 2, d, d))


@functools.partial(jax.jit,
                   static_argnames=("k", "bn", "bk", "interpret"))
def moments_labels(feats: jax.Array, labels: jax.Array,
                   sublabels: jax.Array, valid: jax.Array, k: int, *,
                   bn: int = 128, bk: int = 8, interpret: bool = False
                   ) -> Tuple[jax.Array, jax.Array]:
    """Label-indexed first moments for the feature-separable families.

    feats: (N, d') per-point features (x, or [x, x^2] stacked) ->
    (n (k, 2), sf (k, 2, d')).
    """
    n_pts, dp = feats.shape
    assert dp <= 2 * MAX_KERNEL_D, (
        f"moments_labels: d'={dp} exceeds the VMEM budget; use the "
        "family's segment-sum reference path (kernels/ops.py guards this)")
    s = 2 * k
    bn = min(bn, n_pts) or 1
    bk = min(bk, s)
    feats, labels, sublabels, valid = _pad_points(
        (feats, labels, sublabels, jnp.asarray(valid, jnp.float32)), bn)
    ps = (-s) % bk
    gk, gn = (s + ps) // bk, feats.shape[0] // bn

    n2, sf2 = pl.pallas_call(
        _moments_labels_kernel,
        grid=(gk, gn),
        in_specs=[
            pl.BlockSpec((bn, dp), lambda j, i: (i, 0)),
            pl.BlockSpec((bn,), lambda j, i: (i,)),
            pl.BlockSpec((bn,), lambda j, i: (i,)),
            pl.BlockSpec((bn,), lambda j, i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bk,), lambda j, i: (j,)),
            pl.BlockSpec((bk, dp), lambda j, i: (j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s + ps,), jnp.float32),
            jax.ShapeDtypeStruct((s + ps, dp), jnp.float32),
        ],
        interpret=interpret,
    )(feats, labels, sublabels, valid)
    return n2[:s].reshape(k, 2), sf2[:s].reshape(k, 2, dp)
