"""Pallas TPU blocked matmul — the paper's 'Kernel #1' (§4.2).

The paper ships two CUDA matmul kernels and auto-selects by the d x N
problem size (native kernel below 640k elements, cuBLAS above). The TPU
analogue: this explicit-VMEM blocked kernel (wins on small/skinny problems
where XLA's generic dot pays layout/padding overhead) vs ``jnp.dot`` (XLA,
wins at scale). ``ops.matmul_auto`` reproduces the size-based dispatch.

Tiling: grid (M/bm, N/bn, K/bk); A-tile (bm, bk) and B-tile (bk, bn) live
in VMEM; the f32 accumulator tile (bm, bn) is revisited across the K grid
dim (K is the innermost, sequential axis). All tile dims are MXU-aligned
multiples of 128 by default.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128,
           bk: int = 128, interpret: bool = False) -> jax.Array:
    """(M, K) @ (K, N) -> (M, N) f32. Pads every dim to its tile size."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = min(bm, m) or 1, min(bn, n) or 1, min(bk, k) or 1
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    gm, gn, gk = a.shape[0] // bm, b.shape[1] // bn, a.shape[1] // bk

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, h: (i, h)),
            pl.BlockSpec((bk, bn), lambda i, j, h: (h, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, h: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a.shape[0], b.shape[1]),
                                       jnp.float32),
        interpret=interpret,
    )(a, b)
    return out[:m, :n]
