"""Counter-based Threefry-2x32 — one Gumbel formula for both sweep paths.

The fused assignment kernels (kernels/assign.py) cannot call
``jax.random.gumbel(fold_in(key, i), (k,))`` per point: typed-key plumbing
does not exist inside a Pallas kernel body, and the reference sweep must
produce *bitwise-identical* noise so fused and reference paths sample the
same chain. So per-(point, cluster) noise is defined here once, as a pure
counter-based function of ``(key, global_index, cluster_index)``:

    bits = threefry2x32(key, counter=(global_index, cluster_index))
    u    = (bits >> 8 + 0.5) * 2^-24            # (0, 1) strictly
    g    = -log(-log(u))                        # standard Gumbel

``threefry2x32`` is the standard 20-round Threefry-2x32 block cipher — the
same PRNG JAX's default implementation uses — written in plain ``jnp``
uint32 ops (add/xor/rotate), so the identical expression traces inside a
Pallas kernel body (interpret mode *is* jnp; on TPU it lowers to VPU
integer ops) and in the jnp reference sweep. Keying per *global* point
index preserves the sharding-invariance property (DESIGN §2, assumption 3):
chains are bitwise identical under any data sharding.

Everything broadcasts: pass ``c0 = gidx[:, None]`` and ``c1`` a cluster
iota to draw an (N, K) tile/matrix in one call.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

# Threefry-2x32 rotation schedule (Salmon et al. 2011, Random123).
_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))
_PARITY = 0x1BD11BDA  # key-schedule parity constant


def _rotl(x: jax.Array, r: int) -> jax.Array:
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def threefry2x32(k0: jax.Array, k1: jax.Array, c0: jax.Array,
                 c1: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """20-round Threefry-2x32 of counter (c0, c1) under key (k0, k1).

    All inputs uint32 (arrays broadcast); returns two uint32 blocks.
    Matches ``jax._src.prng.threefry_2x32`` bit-for-bit.
    """
    k0 = k0.astype(jnp.uint32)
    k1 = k1.astype(jnp.uint32)
    x0 = c0.astype(jnp.uint32) + k0
    x1 = c1.astype(jnp.uint32) + k1
    ks = (k0, k1, k0 ^ k1 ^ jnp.uint32(_PARITY))
    for i in range(5):
        for r in _ROTATIONS[i % 2]:
            x0 = x0 + x1
            x1 = _rotl(x1, r) ^ x0
        x0 = x0 + ks[(i + 1) % 3]
        x1 = x1 + ks[(i + 2) % 3] + jnp.uint32(i + 1)
    return x0, x1


def uniform01(bits: jax.Array) -> jax.Array:
    """uint32 bits -> f32 uniform strictly inside (0, 1).

    Uses the top 24 bits at bin centers: u = (bits>>8 + 0.5) / 2^24, so
    u in [2^-25, 1 - 2^-25] and log(u), log(-log(u)) are always finite.
    """
    top = (bits >> jnp.uint32(8)).astype(jnp.float32)
    return (top + 0.5) * jnp.float32(1.0 / (1 << 24))


def gumbel(key_data: jax.Array, c0: jax.Array, c1: jax.Array) -> jax.Array:
    """Standard Gumbel noise keyed by counters (c0, c1); broadcasts.

    ``key_data``: (2,) uint32 raw key words (``jax.random.key_data``).
    """
    b0, _ = threefry2x32(key_data[0], key_data[1], c0, c1)
    return -jnp.log(-jnp.log(uniform01(b0)))


def key_words(key: jax.Array) -> jax.Array:
    """Typed PRNG key -> (2,) uint32 words for the counter-based draws."""
    data = jax.random.key_data(key).reshape(-1)
    return data[:2].astype(jnp.uint32)
