"""Pallas TPU kernels for the fused assignment steps (e) and (f).

The paper's GPU implementation wins by *fusing* the assignment hot path
(§4.1e, §4.4 "Kernel #1/#2"): likelihood, prior weight, categorical noise
and the argmax all happen per streaming tile, so the (N, K) logit and noise
matrices never round-trip through global memory. These kernels are the TPU
analogue — a flash-attention-style running (max, argmax) over cluster
tiles:

``assign_linear`` / ``assign_gauss``  (step e)
    grid (N/bn, K/bk) with the *cluster* axis innermost; the only VMEM
    state carried across cluster tiles is a (bn,) running best value and
    best index. Per tile the kernel computes loglik + logpi + Gumbel
    (counter-based Threefry keyed on the global point index —
    kernels/prng.py, bitwise-identical to the reference sweep) and folds it
    into the running pair. Labels come out directly: the (N, K) logits and
    Gumbel tensors never exist in HBM.

``sub_assign_linear`` / ``sub_assign_gauss``  (step f)
    grid (N/bn,); the whole (K, 2, ...) sub-cluster parameter block sits in
    VMEM and each point *gathers its own cluster's* parameters, so the
    sub-cluster likelihood is evaluated for 2 sub-clusters per point
    instead of all 2K — the O(N K T) -> O(N T) cut. The linear-family
    kernel gathers via a one-hot matmul (MXU-served, exact: one-hot rows
    add 0.0 terms); the Gaussian kernel gathers (K, 2, d, d) Cholesky
    factors with a vector ``take`` (interpret-validated; the ops.py
    dispatcher guards the VMEM budget and falls back to the chunked jnp
    reference where Mosaic gather support is in doubt).

Families plug in via two shapes of likelihood:
 - *linear*: loglik(x)_k = feats @ w_k + const_k  (multinomial, poisson,
   diag-Gaussian — see the families' ``assign_pack`` hooks), and
 - *Gaussian*: the whitening Mahalanobis form of kernels/loglik.py.

All kernels mirror the reference sweep's op order exactly
(ll + logpi, mask, + Gumbel, first-max argmax), so interpret-mode labels
match the jnp path bitwise except on exact floating-point argmax ties
(probability ~0 under continuous Gumbel noise).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import prng

LOG_2PI = 1.8378770664093453
# Inactive-cluster mask, canonical: core.family imports it from here so the
# constant baked into the kernels' tile masking can never drift from the
# reference sweep's.
NEG_INF = -1e30


def _pad_dim(a: jax.Array, axis: int, pad: int, value=0) -> jax.Array:
    if not pad:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def _fold_best(j, bk, total, best_ref, lab_ref):
    """Fold a (bn, bk) logit tile into the running (max, argmax) pair."""
    tile_best = jnp.max(total, axis=1)
    tile_arg = (jnp.argmax(total, axis=1).astype(jnp.int32)
                + jnp.int32(j * bk))
    improve = tile_best > best_ref[...]  # strict: keep FIRST max, like argmax
    lab_ref[...] = jnp.where(improve, tile_arg, lab_ref[...])
    best_ref[...] = jnp.where(improve, tile_best, best_ref[...])


# ---------------------------------------------------------------------------
# Step (e): cluster assignment
# ---------------------------------------------------------------------------
def _assign_linear_kernel(feats_ref, w_ref, const_ref, logw_ref, act_ref,
                          slot_ref, gidx_ref, key_ref, best_ref, lab_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best_ref[...] = jnp.full_like(best_ref, NEG_INF)
        lab_ref[...] = jnp.zeros_like(lab_ref)

    bk = w_ref.shape[0]
    ll = (jnp.dot(feats_ref[...], w_ref[...].T,
                  preferred_element_type=jnp.float32)
          + const_ref[...][None, :])                  # (bn, bk) loglik tile
    t = ll + logw_ref[...][None, :]
    t = jnp.where(act_ref[...][None, :] != 0, t, NEG_INF)
    # Gumbel counter = the cluster's SLOT id (== its compact position on the
    # dense slab), so compacted slabs draw the exact noise of the full slab
    cid = jnp.broadcast_to(slot_ref[...][None, :], t.shape)
    t = t + prng.gumbel(key_ref[...], gidx_ref[...][:, None], cid)
    _fold_best(j, bk, t, best_ref, lab_ref)


def _assign_gauss_kernel(x_ref, mu_ref, f_ref, ld_ref, logw_ref, act_ref,
                         slot_ref, gidx_ref, key_ref, best_ref, lab_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best_ref[...] = jnp.full_like(best_ref, NEG_INF)
        lab_ref[...] = jnp.zeros_like(lab_ref)

    x = x_ref[...]                                    # (bn, d)
    bk, d = mu_ref.shape
    diff = x[:, None, :] - mu_ref[...][None, :, :]    # (bn, bk, d)
    # whitening y = diff @ F_k, batched over the bk clusters (MXU) — same
    # contraction order as kernels/loglik.py / core/niw.py, so the loglik
    # matches the reference bitwise on CPU interpret mode
    y = jax.lax.dot_general(
        diff.transpose(1, 0, 2), f_ref[...],
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)           # (bk, bn, d)
    maha = jnp.sum(y * y, axis=-1)                    # (bk, bn)
    ll = (0.5 * (ld_ref[...][:, None] - maha) - 0.5 * d * LOG_2PI).T
    t = ll + logw_ref[...][None, :]
    t = jnp.where(act_ref[...][None, :] != 0, t, NEG_INF)
    cid = jnp.broadcast_to(slot_ref[...][None, :], t.shape)
    t = t + prng.gumbel(key_ref[...], gidx_ref[...][:, None], cid)
    _fold_best(j, bk, t, best_ref, lab_ref)


@functools.partial(jax.jit,
                   static_argnames=("bn", "bk", "interpret"))
def assign_linear(feats: jax.Array, w: jax.Array, const: jax.Array,
                  logw: jax.Array, active: jax.Array, gidx: jax.Array,
                  key_data: jax.Array, slots: jax.Array = None, *,
                  bn: int = 128, bk: int = 8,
                  interpret: bool = False) -> jax.Array:
    """Fused step (e) for linear-likelihood families -> (N,) int32 labels.

    feats: (N, d'); w: (K, d'); const/logw: (K,); active: (K,) bool;
    gidx: (N,) uint32 global point indices; key_data: (2,) uint32.
    ``slots``: (K,) uint32 dense-slab slot ids used as Gumbel counters
    (defaults to ``arange(K)`` — the dense identity); a compacted caller
    passes the gathered slot ids so labels stay bitwise the dense sweep's.
    """
    n, dp = feats.shape
    k = w.shape[0]
    if slots is None:
        slots = jnp.arange(k, dtype=jnp.uint32)
    bn = min(bn, n) or 1
    bk = min(bk, k) or 1
    pn, pk = (-n) % bn, (-k) % bk
    feats = _pad_dim(feats, 0, pn)
    gidx = _pad_dim(gidx, 0, pn)
    w = _pad_dim(w, 0, pk)
    const = _pad_dim(const, 0, pk)
    logw = _pad_dim(logw, 0, pk)
    active = _pad_dim(active.astype(jnp.int32), 0, pk)  # pad slots inactive
    slots = _pad_dim(slots.astype(jnp.uint32), 0, pk)
    gn, gk = feats.shape[0] // bn, w.shape[0] // bk

    _, labels = pl.pallas_call(
        _assign_linear_kernel,
        grid=(gn, gk),                       # K innermost: running argmax
        in_specs=[
            pl.BlockSpec((bn, dp), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, dp), lambda i, j: (j, 0)),
            pl.BlockSpec((bk,), lambda i, j: (j,)),
            pl.BlockSpec((bk,), lambda i, j: (j,)),
            pl.BlockSpec((bk,), lambda i, j: (j,)),
            pl.BlockSpec((bk,), lambda i, j: (j,)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((2,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i, j: (i,)),   # revisited over j
            pl.BlockSpec((bn,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((feats.shape[0],), jnp.float32),
            jax.ShapeDtypeStruct((feats.shape[0],), jnp.int32),
        ],
        interpret=interpret,
    )(feats, w, const, logw, active, slots, gidx, key_data)
    return labels[:n]


@functools.partial(jax.jit,
                   static_argnames=("bn", "bk", "interpret"))
def assign_gauss(x: jax.Array, mu: jax.Array, chol_prec: jax.Array,
                 logdet_prec: jax.Array, logw: jax.Array,
                 active: jax.Array, gidx: jax.Array, key_data: jax.Array,
                 slots: jax.Array = None, *, bn: int = 128, bk: int = 8,
                 interpret: bool = False) -> jax.Array:
    """Fused step (e) for the full-covariance Gaussian -> (N,) labels."""
    n, d = x.shape
    k = mu.shape[0]
    if slots is None:
        slots = jnp.arange(k, dtype=jnp.uint32)
    bn = min(bn, n) or 1
    bk = min(bk, k) or 1
    pn, pk = (-n) % bn, (-k) % bk
    x = _pad_dim(x, 0, pn)
    gidx = _pad_dim(gidx, 0, pn)
    mu = _pad_dim(mu, 0, pk)
    if pk:
        eye = jnp.broadcast_to(jnp.eye(d, dtype=chol_prec.dtype),
                               (pk, d, d))
        chol_prec = jnp.concatenate([chol_prec, eye], axis=0)
    logdet_prec = _pad_dim(logdet_prec, 0, pk)
    logw = _pad_dim(logw, 0, pk)
    active = _pad_dim(active.astype(jnp.int32), 0, pk)
    slots = _pad_dim(slots.astype(jnp.uint32), 0, pk)
    gn, gk = x.shape[0] // bn, mu.shape[0] // bk

    _, labels = pl.pallas_call(
        _assign_gauss_kernel,
        grid=(gn, gk),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bk, d, d), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((bk,), lambda i, j: (j,)),
            pl.BlockSpec((bk,), lambda i, j: (j,)),
            pl.BlockSpec((bk,), lambda i, j: (j,)),
            pl.BlockSpec((bk,), lambda i, j: (j,)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((2,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((x.shape[0],), jnp.float32),
            jax.ShapeDtypeStruct((x.shape[0],), jnp.int32),
        ],
        interpret=interpret,
    )(x, mu, chol_prec, logdet_prec, logw, active, slots, gidx, key_data)
    return labels[:n]


# ---------------------------------------------------------------------------
# Step (f): own-cluster sub-assignment
# ---------------------------------------------------------------------------
def _sub_assign_linear_kernel(feats_ref, w_ref, const_ref, sublogw_ref,
                              lab_ref, gidx_ref, key_ref, out_ref):
    feats = feats_ref[...]                             # (bn, dp)
    k, _, dp = w_ref.shape
    lab = lab_ref[...]
    # gather each point's own (2, dp) sub-params via a one-hot matmul: the
    # MXU-served gather (exact — off rows contribute 0.0 * w)
    onehot = (lab[:, None]
              == jax.lax.broadcasted_iota(jnp.int32, (lab.shape[0], k), 1)
              ).astype(jnp.float32)                    # (bn, K)
    own_w = jnp.dot(onehot, w_ref[...].reshape(k, 2 * dp),
                    preferred_element_type=jnp.float32).reshape(-1, 2, dp)
    own_const = jnp.dot(onehot, const_ref[...],
                        preferred_element_type=jnp.float32)     # (bn, 2)
    own_logw = jnp.dot(onehot, sublogw_ref[...],
                       preferred_element_type=jnp.float32)      # (bn, 2)
    ll = jnp.einsum("nd,nsd->ns", feats, own_w,
                    preferred_element_type=jnp.float32) + own_const
    t = ll + own_logw
    cid = jax.lax.broadcasted_iota(jnp.uint32, t.shape, 1)
    t = t + prng.gumbel(key_ref[...], gidx_ref[...][:, None], cid)
    out_ref[...] = jnp.argmax(t, axis=1).astype(jnp.int32)


def _sub_assign_gauss_kernel(x_ref, mu_ref, f_ref, ld_ref, sublogw_ref,
                             lab_ref, gidx_ref, key_ref, out_ref):
    x = x_ref[...]                                     # (bn, d)
    d = x.shape[1]
    lab = lab_ref[...]
    # vector gather of the own-cluster sub-params (no K-fold FLOPs at all);
    # interpret mode executes this as jnp.take — ops.py guards the TPU path
    mu_own = jnp.take(mu_ref[...], lab, axis=0)        # (bn, 2, d)
    f_own = jnp.take(f_ref[...], lab, axis=0)          # (bn, 2, d, d)
    ld_own = jnp.take(ld_ref[...], lab, axis=0)        # (bn, 2)
    logw_own = jnp.take(sublogw_ref[...], lab, axis=0)
    diff = x[:, None, :] - mu_own                      # (bn, 2, d)
    y = jnp.einsum("nsd,nsde->nse", diff, f_own,
                   preferred_element_type=jnp.float32)
    maha = jnp.sum(y * y, axis=-1)                     # (bn, 2)
    ll = 0.5 * (ld_own - maha) - 0.5 * d * LOG_2PI
    t = ll + logw_own
    cid = jax.lax.broadcasted_iota(jnp.uint32, t.shape, 1)
    t = t + prng.gumbel(key_ref[...], gidx_ref[...][:, None], cid)
    out_ref[...] = jnp.argmax(t, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def sub_assign_linear(feats: jax.Array, w: jax.Array, const: jax.Array,
                      sublogw: jax.Array, labels: jax.Array,
                      gidx: jax.Array, key_data: jax.Array, *,
                      bn: int = 128, interpret: bool = False) -> jax.Array:
    """Fused step (f) for linear families -> (N,) int32 sub-labels.

    feats: (N, d'); w: (K, 2, d'); const/sublogw: (K, 2); labels: (N,).
    """
    n, dp = feats.shape
    bn = min(bn, n) or 1
    pn = (-n) % bn
    feats = _pad_dim(feats, 0, pn)
    labels = _pad_dim(labels, 0, pn)
    gidx = _pad_dim(gidx, 0, pn)
    k = w.shape[0]
    gn = feats.shape[0] // bn

    out = pl.pallas_call(
        _sub_assign_linear_kernel,
        grid=(gn,),
        in_specs=[
            pl.BlockSpec((bn, dp), lambda i: (i, 0)),
            pl.BlockSpec((k, 2, dp), lambda i: (0, 0, 0)),  # resident VMEM
            pl.BlockSpec((k, 2), lambda i: (0, 0)),
            pl.BlockSpec((k, 2), lambda i: (0, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((feats.shape[0],), jnp.int32),
        interpret=interpret,
    )(feats, w, const, sublogw, labels, gidx, key_data)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def sub_assign_gauss(x: jax.Array, mu: jax.Array, chol_prec: jax.Array,
                     logdet_prec: jax.Array, sublogw: jax.Array,
                     labels: jax.Array, gidx: jax.Array,
                     key_data: jax.Array, *, bn: int = 32,
                     interpret: bool = False) -> jax.Array:
    """Fused step (f) for the Gaussian -> (N,) int32 sub-labels.

    x: (N, d); mu: (K, 2, d); chol_prec: (K, 2, d, d); logdet/sublogw:
    (K, 2). ``bn`` is small: the gathered (bn, 2, d, d) factors live in
    VMEM next to the resident (K, 2, d, d) block.
    """
    n, d = x.shape
    bn = min(bn, n) or 1
    pn = (-n) % bn
    x = _pad_dim(x, 0, pn)
    labels = _pad_dim(labels, 0, pn)
    gidx = _pad_dim(gidx, 0, pn)
    k = mu.shape[0]
    gn = x.shape[0] // bn

    out = pl.pallas_call(
        _sub_assign_gauss_kernel,
        grid=(gn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((k, 2, d), lambda i: (0, 0, 0)),
            pl.BlockSpec((k, 2, d, d), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((k, 2), lambda i: (0, 0)),
            pl.BlockSpec((k, 2), lambda i: (0, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0],), jnp.int32),
        interpret=interpret,
    )(x, mu, chol_prec, logdet_prec, sublogw, labels, gidx, key_data)
    return out[:n]
