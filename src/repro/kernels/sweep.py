"""Pallas TPU megakernels for the ONE-READ fused sweep (steps e + f +
suff-stat fold in a single pass over x), K-BLOCKED so only a (bk, ...)
cluster tile is ever VMEM-resident.

After the assignment fusion (kernels/assign.py) and the label-indexed
suff-stats (kernels/suffstats.py), the sweep was still three separate
passes over the point tile — step (e), step (f), and the stat fold each
streamed every byte of ``x`` (or its ``assign_pack`` features) from HBM
once per iteration, and the linear families recomputed the feature
transform in each pass. These kernels collapse the three into one
``pallas_call`` whose only large operand is ``x``: while a point block is
resident in VMEM it is

 1. assigned (step e: loglik + log pi + counter-based Threefry Gumbel,
    a flash-attention-style running argmax over *streamed* (bk, ...)
    cluster tiles — never the full (K, ...) slab),
 2. sub-assigned under its OWN cluster only (step f: one-hot MXU gather /
    vector ``take`` of the owning K-block's (bk, 2, ...) sub-params), and
 3. folded into per-(point-block, K-block) stat partial tiles

— labels, sub-labels, and the stat partials stream out; the block of
``x`` is never touched again. HBM traffic per sweep stays at one read of
x, and VMEM per grid step is O(bn + bk): K (and d) are bounded by HBM,
not by an all-K-resident VMEM budget.

Grid layout: ``(gn, 2, gk)`` — point blocks outermost, then a 2-step
*phase* axis, then K-blocks innermost. Phase 0 streams the gk cluster
tiles through the running (max, argmax) pair exactly like
``kernels/assign.py`` (strict ``>`` keeps the FIRST max, so the fold is
bitwise the full argmax). Phase 1 revisits the gk tiles to sub-assign and
fold stats for the points each tile OWNS (label in [j*bk, (j+1)*bk)) —
each (i, j) stat tile is written exactly once, and the label/sub-label
output blocks are revisited only consecutively (all phases of one point
block), which is the Pallas TPU revolving-buffer contract.

The stat partials come out per (point block, K block); the *caller* folds
them into per-``STATS_BLOCK`` partials with a left-to-right add chain
starting from +0.0 — the exact float addition sequence the previous
all-K-resident kernel ran in VMEM (zero-init then ``+=`` per point
block), so chains are bitwise unchanged. Partials are then folded
left-to-right by core/family.py as before.

Cluster identity: every kernel takes a ``slots`` operand — the (K,)
uint32 dense-slab slot ids, used as the Gumbel counters. A compacted
caller (core/gibbs.py's active-set compaction) passes the gathered slot
ids so the noise — hence the chain — is bitwise the dense slab's; dense
callers pass ``arange(K)``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import prng
from repro.kernels.assign import LOG_2PI, NEG_INF, _fold_best, _pad_dim

# Granularity of the suff-stat fold — the system-wide contract (re-exported
# by core/gibbs.py): partial stats are produced per STATS_BLOCK points and
# added left to right in global point order on EVERY path, so the float
# addition sequence — hence every bit of the chain — is invariant to tile
# size and sharding. Changing this constant changes chains.
STATS_BLOCK = 1024

# Default cluster-tile size streamed through VMEM (bk): mirrors
# kernels/assign.py's step-(e) tiling.
K_BLOCK = 8


def _pad_points(arrs, bn: int):
    out = []
    for a in arrs:
        out.append(_pad_dim(a, 0, (-a.shape[0]) % bn))
    return out


def _fold_stats(a: jax.Array, spb: int) -> jax.Array:
    """(gn, ...) per-point-block partials -> (nsb, ...) per-STATS_BLOCK.

    Left-to-right adds from +0.0 in point-block order: the exact chain the
    old in-kernel accumulator ran (zero-init at each stats-block boundary,
    then one ``+=`` per point block), so the per-STATS_BLOCK partials are
    bitwise unchanged. Ragged trailing blocks are padded with zero rows
    (x + 0.0 == x after a +0.0 start, so padding is a no-op bitwise).
    """
    gn = a.shape[0]
    nsb = -(-gn // spb)
    a = _pad_dim(a, 0, nsb * spb - gn)
    a = a.reshape((nsb, spb) + a.shape[1:])
    out = jnp.zeros((nsb,) + a.shape[2:], a.dtype)
    for t in range(spb):
        out = out + a[:, t]
    return out


def _seg_onehot_block(loc, sub, valid, s: int):
    """(bn, 2*bk) one-hot over the K-block's segments 2*loc + sub.

    ``loc`` is the block-local label; rows owned by other K-blocks fall
    outside [0, s) and contribute all-zero rows, so the per-column sums
    are exactly the full-width one-hot's columns for this block.
    """
    seg = loc * 2 + sub
    col = jax.lax.broadcasted_iota(jnp.int32, (seg.shape[0], s), 1)
    return (seg[:, None] == col).astype(jnp.float32) * valid[:, None]


# ---------------------------------------------------------------------------
# Linear-likelihood families (multinomial / poisson / diag-Gaussian):
# the stat features ARE the assign_pack features (x, or [x, x^2]), so the
# whole sweep shares one resident feature block.
# ---------------------------------------------------------------------------
def _sweep_linear_kernel(feats_ref, w_ref, const_ref, logw_ref, act_ref,
                         slot_ref, subw_ref, subconst_ref, sublogw_ref,
                         valid_ref, gidx_ref, kz_ref, kzb_ref,
                         best_ref, lab_ref, sub_ref, n_ref, sf_ref):
    p = pl.program_id(1)
    j = pl.program_id(2)
    bk = w_ref.shape[0]
    feats = feats_ref[...]                               # the ONE x read
    gidx = gidx_ref[...]

    @pl.when((p == 0) & (j == 0))
    def _init():
        best_ref[...] = jnp.full_like(best_ref, NEG_INF)
        lab_ref[...] = jnp.zeros_like(lab_ref)
        sub_ref[...] = jnp.zeros_like(sub_ref)

    @pl.when(p == 0)
    def _assign():
        # step (e) on one streamed cluster tile: same op order as
        # kernels/assign._assign_linear_kernel (ll + logpi, mask, + Gumbel,
        # strict first-max fold) — bitwise the full argmax.
        ll = (jnp.dot(feats, w_ref[...].T,
                      preferred_element_type=jnp.float32)
              + const_ref[...][None, :])
        t = ll + logw_ref[...][None, :]
        t = jnp.where(act_ref[...][None, :] != 0, t, NEG_INF)
        cid = jnp.broadcast_to(slot_ref[...][None, :], t.shape)
        t = t + prng.gumbel(kz_ref[...], gidx[:, None], cid)
        _fold_best(j, bk, t, best_ref, lab_ref)

    @pl.when(p == 1)
    def _sub_and_stats():
        # step (f) + stat fold for the points THIS K-block owns
        lab = lab_ref[...]
        loc = lab - j * bk                               # block-local label
        in_blk = (loc >= 0) & (loc < bk)
        dp = feats.shape[1]
        onehot = (loc[:, None]
                  == jax.lax.broadcasted_iota(jnp.int32,
                                              (lab.shape[0], bk), 1)
                  ).astype(jnp.float32)                  # 0 rows off-block
        own_w = jnp.dot(onehot, subw_ref[...].reshape(bk, 2 * dp),
                        preferred_element_type=jnp.float32
                        ).reshape(-1, 2, dp)
        own_const = jnp.dot(onehot, subconst_ref[...],
                            preferred_element_type=jnp.float32)
        own_logw = jnp.dot(onehot, sublogw_ref[...],
                           preferred_element_type=jnp.float32)
        ll = jnp.einsum("nd,nsd->ns", feats, own_w,
                        preferred_element_type=jnp.float32) + own_const
        t = ll + own_logw
        cid = jax.lax.broadcasted_iota(jnp.uint32, t.shape, 1)
        t = t + prng.gumbel(kzb_ref[...], gidx[:, None], cid)
        sub = jnp.argmax(t, axis=1).astype(jnp.int32)
        sub = jnp.where(in_blk, sub, sub_ref[...])
        sub_ref[...] = sub
        r = _seg_onehot_block(loc, sub, valid_ref[...], n_ref.shape[1])
        n_ref[...] = jnp.sum(r, axis=0)[None, :]
        sf_ref[...] = jnp.dot(r.T, feats,
                              preferred_element_type=jnp.float32)[None]


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def sweep_linear(feats: jax.Array, w: jax.Array, const: jax.Array,
                 logw: jax.Array, active: jax.Array, subw: jax.Array,
                 subconst: jax.Array, sublogw: jax.Array, valid: jax.Array,
                 gidx: jax.Array, key_z: jax.Array, key_zb: jax.Array,
                 slots: jax.Array = None, *, bn: int = 128,
                 bk: int = K_BLOCK, interpret: bool = False
                 ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One-read, K-blocked fused sweep for linear-likelihood families.

    feats: (N, d') assign_pack features (shared by steps e/f AND the stat
    fold); w: (K, d'); const/logw: (K,); active: (K,) bool/int;
    subw: (K, 2, d'); subconst/sublogw: (K, 2); valid: (N,); gidx: (N,)
    uint32; key_z/key_zb: (2,) uint32; slots: (K,) uint32 dense-slab slot
    ids for the Gumbel counters (default ``arange(K)``).

    Returns ``(labels (N,), sublabels (N,), n2 (nsb, K, 2),
    sf2 (nsb, K, 2, d'))`` where the trailing pair are per-STATS_BLOCK
    stat partials to be folded left-to-right by the caller. Only a
    (bk, ...) cluster tile is VMEM-resident at any grid step.
    """
    assert STATS_BLOCK % bn == 0, "bn must divide the stats fold block"
    n, dp = feats.shape
    k = w.shape[0]
    if slots is None:
        slots = jnp.arange(k, dtype=jnp.uint32)
    bk = min(bk, k) or 1
    feats, valid, gidx = _pad_points(
        (feats, jnp.asarray(valid, jnp.float32),
         gidx.astype(jnp.uint32)), bn)
    pk = (-k) % bk
    w = _pad_dim(w, 0, pk)
    const = _pad_dim(const, 0, pk)
    logw = _pad_dim(logw, 0, pk)
    active = _pad_dim(active.astype(jnp.int32), 0, pk)   # pad slots inactive
    slots = _pad_dim(slots.astype(jnp.uint32), 0, pk)
    subw = _pad_dim(subw, 0, pk)
    subconst = _pad_dim(subconst, 0, pk)
    sublogw = _pad_dim(sublogw, 0, pk)
    k_pad = w.shape[0]
    s = 2 * k_pad
    sb = 2 * bk
    gn = feats.shape[0] // bn
    gk = k_pad // bk
    spb = STATS_BLOCK // bn
    nsb = -(-gn // spb)

    _, labels, sublabels, n2, sf2 = pl.pallas_call(
        _sweep_linear_kernel,
        grid=(gn, 2, gk),             # phase then K innermost, sequential
        in_specs=[
            pl.BlockSpec((bn, dp), lambda i, p, j: (i, 0)),
            pl.BlockSpec((bk, dp), lambda i, p, j: (j, 0)),   # streamed tile
            pl.BlockSpec((bk,), lambda i, p, j: (j,)),
            pl.BlockSpec((bk,), lambda i, p, j: (j,)),
            pl.BlockSpec((bk,), lambda i, p, j: (j,)),
            pl.BlockSpec((bk,), lambda i, p, j: (j,)),
            pl.BlockSpec((bk, 2, dp), lambda i, p, j: (j, 0, 0)),
            pl.BlockSpec((bk, 2), lambda i, p, j: (j, 0)),
            pl.BlockSpec((bk, 2), lambda i, p, j: (j, 0)),
            pl.BlockSpec((bn,), lambda i, p, j: (i,)),
            pl.BlockSpec((bn,), lambda i, p, j: (i,)),
            pl.BlockSpec((2,), lambda i, p, j: (0,)),
            pl.BlockSpec((2,), lambda i, p, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i, p, j: (i,)),   # revisited (i fixed)
            pl.BlockSpec((bn,), lambda i, p, j: (i,)),
            pl.BlockSpec((bn,), lambda i, p, j: (i,)),
            # held at (i, 0) through phase 0, then single-visit (i, j)
            pl.BlockSpec((1, sb), lambda i, p, j: (i, j * p)),
            pl.BlockSpec((1, sb, dp), lambda i, p, j: (i, j * p, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((feats.shape[0],), jnp.float32),
            jax.ShapeDtypeStruct((feats.shape[0],), jnp.int32),
            jax.ShapeDtypeStruct((feats.shape[0],), jnp.int32),
            jax.ShapeDtypeStruct((gn, s), jnp.float32),
            jax.ShapeDtypeStruct((gn, s, dp), jnp.float32),
        ],
        interpret=interpret,
    )(feats, w, const, logw, active, slots, subw, subconst, sublogw,
      valid, gidx, key_z, key_zb)
    n2 = _fold_stats(n2, spb).reshape(nsb, k_pad, 2)[:, :k]
    sf2 = _fold_stats(sf2, spb).reshape(nsb, k_pad, 2, dp)[:, :k]
    return labels[:n], sublabels[:n], n2, sf2


# ---------------------------------------------------------------------------
# Full-covariance Gaussian: whitening-Mahalanobis assignment, vector-gather
# sub-assignment, second-moment stat fold — one resident x block, streamed
# (bk, d, d) Cholesky tiles.
# ---------------------------------------------------------------------------
def _sweep_gauss_kernel(x_ref, mu_ref, f_ref, ld_ref, logw_ref, act_ref,
                        slot_ref, smu_ref, sfchol_ref, sld_ref, sublogw_ref,
                        valid_ref, gidx_ref, kz_ref, kzb_ref,
                        best_ref, lab_ref, sub_ref, n_ref, sx_ref, sxx_ref):
    p = pl.program_id(1)
    j = pl.program_id(2)
    bk, d = mu_ref.shape
    x = x_ref[...]                                       # the ONE x read
    gidx = gidx_ref[...]

    @pl.when((p == 0) & (j == 0))
    def _init():
        best_ref[...] = jnp.full_like(best_ref, NEG_INF)
        lab_ref[...] = jnp.zeros_like(lab_ref)
        sub_ref[...] = jnp.zeros_like(sub_ref)

    @pl.when(p == 0)
    def _assign():
        # step (e): mirror of kernels/assign._assign_gauss_kernel on one
        # streamed (bk, d, d) Cholesky tile
        diff = x[:, None, :] - mu_ref[...][None, :, :]   # (bn, bk, d)
        y = jax.lax.dot_general(
            diff.transpose(1, 0, 2), f_ref[...],
            dimension_numbers=(((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)          # (bk, bn, d)
        maha = jnp.sum(y * y, axis=-1)                   # (bk, bn)
        ll = (0.5 * (ld_ref[...][:, None] - maha) - 0.5 * d * LOG_2PI).T
        t = ll + logw_ref[...][None, :]
        t = jnp.where(act_ref[...][None, :] != 0, t, NEG_INF)
        cid = jnp.broadcast_to(slot_ref[...][None, :], t.shape)
        t = t + prng.gumbel(kz_ref[...], gidx[:, None], cid)
        _fold_best(j, bk, t, best_ref, lab_ref)

    @pl.when(p == 1)
    def _sub_and_stats():
        # step (f): mirror of kernels/assign._sub_assign_gauss_kernel,
        # gathering from the owning K-block only (clipped local label;
        # off-block rows gather garbage that the in_blk mask discards)
        lab = lab_ref[...]
        loc = lab - j * bk
        in_blk = (loc >= 0) & (loc < bk)
        locc = jnp.clip(loc, 0, bk - 1)
        mu_own = jnp.take(smu_ref[...], locc, axis=0)    # (bn, 2, d)
        f_own = jnp.take(sfchol_ref[...], locc, axis=0)  # (bn, 2, d, d)
        ld_own = jnp.take(sld_ref[...], locc, axis=0)    # (bn, 2)
        logw_own = jnp.take(sublogw_ref[...], locc, axis=0)
        diff2 = x[:, None, :] - mu_own
        y2 = jnp.einsum("nsd,nsde->nse", diff2, f_own,
                        preferred_element_type=jnp.float32)
        maha2 = jnp.sum(y2 * y2, axis=-1)
        ll2 = 0.5 * (ld_own - maha2) - 0.5 * d * LOG_2PI
        t2 = ll2 + logw_own
        cid2 = jax.lax.broadcasted_iota(jnp.uint32, t2.shape, 1)
        t2 = t2 + prng.gumbel(kzb_ref[...], gidx[:, None], cid2)
        sub = jnp.argmax(t2, axis=1).astype(jnp.int32)
        sub = jnp.where(in_blk, sub, sub_ref[...])
        sub_ref[...] = sub

        # stat fold: mirror of kernels/suffstats._suffstats_labels_kernel
        # restricted to this K-block's 2*bk segments
        r = _seg_onehot_block(loc, sub, valid_ref[...], n_ref.shape[1])
        n_ref[...] = jnp.sum(r, axis=0)[None, :]
        sx_ref[...] = jnp.dot(r.T, x,
                              preferred_element_type=jnp.float32)[None]
        xw = r.T[:, :, None] * x[None, :, :]             # (2bk, bn, d)
        sxx_ref[...] = jax.lax.dot_general(
            xw.transpose(0, 2, 1),
            jnp.broadcast_to(x, (r.shape[1],) + x.shape),
            dimension_numbers=(((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)[None]


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def sweep_gauss(x: jax.Array, mu: jax.Array, chol_prec: jax.Array,
                logdet_prec: jax.Array, logw: jax.Array, active: jax.Array,
                sub_mu: jax.Array, sub_chol_prec: jax.Array,
                sub_logdet_prec: jax.Array, sublogw: jax.Array,
                valid: jax.Array, gidx: jax.Array, key_z: jax.Array,
                key_zb: jax.Array, slots: jax.Array = None, *,
                bn: int = 128, bk: int = K_BLOCK, interpret: bool = False
                ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array,
                           jax.Array]:
    """One-read, K-blocked fused sweep for the full-covariance Gaussian.

    x: (N, d); mu: (K, d); chol_prec: (K, d, d); logdet_prec/logw: (K,);
    sub_*: the (K, 2, ...) sub-cluster analogues; valid: (N,);
    gidx: (N,) uint32; slots: (K,) uint32 slot-id Gumbel counters.
    Returns ``(labels, sublabels, n2 (nsb, K, 2), sx2 (nsb, K, 2, d),
    sxx2 (nsb, K, 2, d, d))`` with per-STATS_BLOCK stat partials. Only a
    (bk, d, d) cluster tile is VMEM-resident at any grid step.
    """
    assert STATS_BLOCK % bn == 0, "bn must divide the stats fold block"
    n, d = x.shape
    k = mu.shape[0]
    if slots is None:
        slots = jnp.arange(k, dtype=jnp.uint32)
    bk = min(bk, k) or 1
    x, valid, gidx = _pad_points(
        (x, jnp.asarray(valid, jnp.float32), gidx.astype(jnp.uint32)), bn)
    pk = (-k) % bk
    mu = _pad_dim(mu, 0, pk)
    chol_prec = _pad_dim(chol_prec, 0, pk)
    logdet_prec = _pad_dim(logdet_prec, 0, pk)
    logw = _pad_dim(logw, 0, pk)
    active = _pad_dim(active.astype(jnp.int32), 0, pk)
    slots = _pad_dim(slots.astype(jnp.uint32), 0, pk)
    sub_mu = _pad_dim(sub_mu, 0, pk)
    sub_chol_prec = _pad_dim(sub_chol_prec, 0, pk)
    sub_logdet_prec = _pad_dim(sub_logdet_prec, 0, pk)
    sublogw = _pad_dim(sublogw, 0, pk)
    k_pad = mu.shape[0]
    s = 2 * k_pad
    sb = 2 * bk
    gn = x.shape[0] // bn
    gk = k_pad // bk
    spb = STATS_BLOCK // bn
    nsb = -(-gn // spb)

    _, labels, sublabels, n2, sx2, sxx2 = pl.pallas_call(
        _sweep_gauss_kernel,
        grid=(gn, 2, gk),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, p, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, p, j: (j, 0)),
            pl.BlockSpec((bk, d, d), lambda i, p, j: (j, 0, 0)),
            pl.BlockSpec((bk,), lambda i, p, j: (j,)),
            pl.BlockSpec((bk,), lambda i, p, j: (j,)),
            pl.BlockSpec((bk,), lambda i, p, j: (j,)),
            pl.BlockSpec((bk,), lambda i, p, j: (j,)),
            pl.BlockSpec((bk, 2, d), lambda i, p, j: (j, 0, 0)),
            pl.BlockSpec((bk, 2, d, d), lambda i, p, j: (j, 0, 0, 0)),
            pl.BlockSpec((bk, 2), lambda i, p, j: (j, 0)),
            pl.BlockSpec((bk, 2), lambda i, p, j: (j, 0)),
            pl.BlockSpec((bn,), lambda i, p, j: (i,)),
            pl.BlockSpec((bn,), lambda i, p, j: (i,)),
            pl.BlockSpec((2,), lambda i, p, j: (0,)),
            pl.BlockSpec((2,), lambda i, p, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i, p, j: (i,)),
            pl.BlockSpec((bn,), lambda i, p, j: (i,)),
            pl.BlockSpec((bn,), lambda i, p, j: (i,)),
            pl.BlockSpec((1, sb), lambda i, p, j: (i, j * p)),
            pl.BlockSpec((1, sb, d), lambda i, p, j: (i, j * p, 0)),
            pl.BlockSpec((1, sb, d, d), lambda i, p, j: (i, j * p, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((x.shape[0],), jnp.float32),
            jax.ShapeDtypeStruct((x.shape[0],), jnp.int32),
            jax.ShapeDtypeStruct((x.shape[0],), jnp.int32),
            jax.ShapeDtypeStruct((gn, s), jnp.float32),
            jax.ShapeDtypeStruct((gn, s, d), jnp.float32),
            jax.ShapeDtypeStruct((gn, s, d, d), jnp.float32),
        ],
        interpret=interpret,
    )(x, mu, chol_prec, logdet_prec, logw, active, slots, sub_mu,
      sub_chol_prec, sub_logdet_prec, sublogw, valid, gidx, key_z, key_zb)
    n2 = _fold_stats(n2, spb).reshape(nsb, k_pad, 2)[:, :k]
    sx2 = _fold_stats(sx2, spb).reshape(nsb, k_pad, 2, d)[:, :k]
    sxx2 = _fold_stats(sxx2, spb).reshape(nsb, k_pad, 2, d, d)[:, :k]
    return labels[:n], sublabels[:n], n2, sx2, sxx2
