"""Pallas TPU megakernels for the ONE-READ fused sweep (steps e + f +
suff-stat fold in a single pass over x).

After the assignment fusion (kernels/assign.py) and the label-indexed
suff-stats (kernels/suffstats.py), the sweep was still three separate
passes over the point tile — step (e), step (f), and the stat fold each
streamed every byte of ``x`` (or its ``assign_pack`` features) from HBM
once per iteration, and the linear families recomputed the feature
transform in each pass. These kernels collapse the three into one
``pallas_call`` whose only large operand is ``x``: while a point block is
resident in VMEM it is

 1. assigned (step e: loglik + log pi + counter-based Threefry Gumbel,
    running argmax over the *resident* (K, ...) parameter block),
 2. sub-assigned under its OWN cluster only (step f: one-hot MXU gather /
    vector ``take`` of the (K, 2, ...) sub-params), and
 3. folded into the sub-cluster stat accumulators held in VMEM

— labels, sub-labels, and the folded stat partials stream out; the block
of ``x`` is never touched again. HBM traffic per sweep drops from three
reads of x to one.

The stat accumulators are emitted as per-``STATS_BLOCK`` partial blocks
(out tiles revisited for the ``STATS_BLOCK/bn`` grid steps inside each
stats block, re-initialized at each block boundary), NOT as one grand
total: the caller folds the partials left-to-right, which reproduces the
exact float addition sequence of the reference fold
(``core/gibbs.accumulate_substats``) for every tile size and sharding —
the bitwise-chain contract extends to the megakernels.

Every arithmetic expression mirrors the corresponding three-pass kernel
(``assign_linear``/``assign_gauss``, ``sub_assign_*``,
``suffstats_labels``/``moments_labels``) op for op, so interpret-mode
chains match the three-pass Pallas chains bitwise.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import prng
from repro.kernels.assign import LOG_2PI, NEG_INF, _pad_dim

# Granularity of the suff-stat fold — the system-wide contract (re-exported
# by core/gibbs.py): partial stats are produced per STATS_BLOCK points and
# added left to right in global point order on EVERY path, so the float
# addition sequence — hence every bit of the chain — is invariant to tile
# size and sharding. Changing this constant changes chains.
STATS_BLOCK = 1024


def _pad_points(arrs, bn: int):
    out = []
    for a in arrs:
        out.append(_pad_dim(a, 0, (-a.shape[0]) % bn))
    return out


def _assign_block(feats, w, const, logw, active, gidx, kz):
    """Step (e) on a resident block: (bn,) labels, linear-likelihood form.

    Same op order as kernels/assign._assign_linear_kernel (ll + logpi,
    mask, + Gumbel, first-max argmax) with the full (K, d') weight block
    resident instead of streamed cluster tiles — per-element arithmetic
    is identical, so interpret-mode labels match bitwise.
    """
    ll = (jnp.dot(feats, w.T, preferred_element_type=jnp.float32)
          + const[None, :])
    t = ll + logw[None, :]
    t = jnp.where(active[None, :] != 0, t, NEG_INF)
    cid = jax.lax.broadcasted_iota(jnp.uint32, t.shape, 1)
    t = t + prng.gumbel(kz, gidx[:, None], cid)
    return jnp.argmax(t, axis=1).astype(jnp.int32)


def _sub_assign_block(feats, subw, subconst, sublogw, lab, gidx, kzb):
    """Step (f) on a resident block: one-hot MXU gather of the own-cluster
    (2, d') sub-params — mirrors kernels/assign._sub_assign_linear_kernel."""
    k, _, dp = subw.shape
    onehot = (lab[:, None]
              == jax.lax.broadcasted_iota(jnp.int32, (lab.shape[0], k), 1)
              ).astype(jnp.float32)
    own_w = jnp.dot(onehot, subw.reshape(k, 2 * dp),
                    preferred_element_type=jnp.float32).reshape(-1, 2, dp)
    own_const = jnp.dot(onehot, subconst,
                        preferred_element_type=jnp.float32)
    own_logw = jnp.dot(onehot, sublogw,
                       preferred_element_type=jnp.float32)
    ll = jnp.einsum("nd,nsd->ns", feats, own_w,
                    preferred_element_type=jnp.float32) + own_const
    t = ll + own_logw
    cid = jax.lax.broadcasted_iota(jnp.uint32, t.shape, 1)
    t = t + prng.gumbel(kzb, gidx[:, None], cid)
    return jnp.argmax(t, axis=1).astype(jnp.int32)


def _seg_onehot(lab, sub, valid, s: int):
    """(bn, 2K) one-hot over segments s = 2*label + sublabel, in VMEM —
    mirrors kernels/suffstats._tile_resp with the full segment range."""
    seg = lab * 2 + sub
    col = jax.lax.broadcasted_iota(jnp.int32, (seg.shape[0], s), 1)
    return (seg[:, None] == col).astype(jnp.float32) * valid[:, None]


# ---------------------------------------------------------------------------
# Linear-likelihood families (multinomial / poisson / diag-Gaussian):
# the stat features ARE the assign_pack features (x, or [x, x^2]), so the
# whole sweep shares one resident feature block.
# ---------------------------------------------------------------------------
def _sweep_linear_kernel(spb, feats_ref, w_ref, const_ref, logw_ref,
                         act_ref, subw_ref, subconst_ref, sublogw_ref,
                         valid_ref, gidx_ref, kz_ref, kzb_ref,
                         lab_ref, sub_ref, n_ref, sf_ref):
    i = pl.program_id(0)

    @pl.when(i % spb == 0)
    def _init():                    # new STATS_BLOCK: fresh partial
        n_ref[...] = jnp.zeros_like(n_ref)
        sf_ref[...] = jnp.zeros_like(sf_ref)

    feats = feats_ref[...]                               # the ONE x read
    gidx = gidx_ref[...]
    lab = _assign_block(feats, w_ref[...], const_ref[...], logw_ref[...],
                        act_ref[...], gidx, kz_ref[...])
    sub = _sub_assign_block(feats, subw_ref[...], subconst_ref[...],
                            sublogw_ref[...], lab, gidx, kzb_ref[...])
    lab_ref[...] = lab
    sub_ref[...] = sub
    r = _seg_onehot(lab, sub, valid_ref[...], n_ref.shape[1])
    n_ref[...] += jnp.sum(r, axis=0)[None, :]
    sf_ref[...] += jnp.dot(r.T, feats,
                           preferred_element_type=jnp.float32)[None]


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def sweep_linear(feats: jax.Array, w: jax.Array, const: jax.Array,
                 logw: jax.Array, active: jax.Array, subw: jax.Array,
                 subconst: jax.Array, sublogw: jax.Array, valid: jax.Array,
                 gidx: jax.Array, key_z: jax.Array, key_zb: jax.Array, *,
                 bn: int = 128, interpret: bool = False
                 ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One-read fused sweep for linear-likelihood families.

    feats: (N, d') assign_pack features (shared by steps e/f AND the stat
    fold); w: (K, d'); const/logw: (K,); active: (K,) bool/int;
    subw: (K, 2, d'); subconst/sublogw: (K, 2); valid: (N,); gidx: (N,)
    uint32; key_z/key_zb: (2,) uint32.

    Returns ``(labels (N,), sublabels (N,), n2 (nsb, K, 2),
    sf2 (nsb, K, 2, d'))`` where the trailing pair are per-STATS_BLOCK
    stat partials to be folded left-to-right by the caller.
    """
    assert STATS_BLOCK % bn == 0, "bn must divide the stats fold block"
    n, dp = feats.shape
    k = w.shape[0]
    s = 2 * k
    feats, valid, gidx = _pad_points(
        (feats, jnp.asarray(valid, jnp.float32),
         gidx.astype(jnp.uint32)), bn)
    gn = feats.shape[0] // bn
    spb = STATS_BLOCK // bn
    nsb = -(-gn // spb)
    active = active.astype(jnp.int32)

    labels, sublabels, n2, sf2 = pl.pallas_call(
        functools.partial(_sweep_linear_kernel, spb),
        grid=(gn,),                      # sequential: partials fold in order
        in_specs=[
            pl.BlockSpec((bn, dp), lambda i: (i, 0)),
            pl.BlockSpec((k, dp), lambda i: (0, 0)),     # resident VMEM
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k, 2, dp), lambda i: (0, 0, 0)),
            pl.BlockSpec((k, 2), lambda i: (0, 0)),
            pl.BlockSpec((k, 2), lambda i: (0, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            # revisited for the spb steps inside each stats block
            pl.BlockSpec((1, s), lambda i: (i // spb, 0)),
            pl.BlockSpec((1, s, dp), lambda i: (i // spb, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((feats.shape[0],), jnp.int32),
            jax.ShapeDtypeStruct((feats.shape[0],), jnp.int32),
            jax.ShapeDtypeStruct((nsb, s), jnp.float32),
            jax.ShapeDtypeStruct((nsb, s, dp), jnp.float32),
        ],
        interpret=interpret,
    )(feats, w, const, logw, active, subw, subconst, sublogw, valid, gidx,
      key_z, key_zb)
    return (labels[:n], sublabels[:n], n2.reshape(nsb, k, 2),
            sf2.reshape(nsb, k, 2, dp))


# ---------------------------------------------------------------------------
# Full-covariance Gaussian: whitening-Mahalanobis assignment, vector-gather
# sub-assignment, second-moment stat fold — one resident x block.
# ---------------------------------------------------------------------------
def _sweep_gauss_kernel(spb, x_ref, mu_ref, f_ref, ld_ref, logw_ref,
                        act_ref, smu_ref, sfchol_ref, sld_ref, sublogw_ref,
                        valid_ref, gidx_ref, kz_ref, kzb_ref,
                        lab_ref, sub_ref, n_ref, sx_ref, sxx_ref):
    i = pl.program_id(0)

    @pl.when(i % spb == 0)
    def _init():
        n_ref[...] = jnp.zeros_like(n_ref)
        sx_ref[...] = jnp.zeros_like(sx_ref)
        sxx_ref[...] = jnp.zeros_like(sxx_ref)

    x = x_ref[...]                                       # the ONE x read
    gidx = gidx_ref[...]
    k, d = mu_ref.shape

    # step (e): mirror of kernels/assign._assign_gauss_kernel with the
    # full (K, d, d) Cholesky block resident
    diff = x[:, None, :] - mu_ref[...][None, :, :]       # (bn, K, d)
    y = jax.lax.dot_general(
        diff.transpose(1, 0, 2), f_ref[...],
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)              # (K, bn, d)
    maha = jnp.sum(y * y, axis=-1)                       # (K, bn)
    ll = (0.5 * (ld_ref[...][:, None] - maha) - 0.5 * d * LOG_2PI).T
    t = ll + logw_ref[...][None, :]
    t = jnp.where(act_ref[...][None, :] != 0, t, NEG_INF)
    cid = jax.lax.broadcasted_iota(jnp.uint32, t.shape, 1)
    t = t + prng.gumbel(kz_ref[...], gidx[:, None], cid)
    lab = jnp.argmax(t, axis=1).astype(jnp.int32)

    # step (f): mirror of kernels/assign._sub_assign_gauss_kernel
    mu_own = jnp.take(smu_ref[...], lab, axis=0)         # (bn, 2, d)
    f_own = jnp.take(sfchol_ref[...], lab, axis=0)       # (bn, 2, d, d)
    ld_own = jnp.take(sld_ref[...], lab, axis=0)         # (bn, 2)
    logw_own = jnp.take(sublogw_ref[...], lab, axis=0)
    diff2 = x[:, None, :] - mu_own
    y2 = jnp.einsum("nsd,nsde->nse", diff2, f_own,
                    preferred_element_type=jnp.float32)
    maha2 = jnp.sum(y2 * y2, axis=-1)
    ll2 = 0.5 * (ld_own - maha2) - 0.5 * d * LOG_2PI
    t2 = ll2 + logw_own
    cid2 = jax.lax.broadcasted_iota(jnp.uint32, t2.shape, 1)
    t2 = t2 + prng.gumbel(kzb_ref[...], gidx[:, None], cid2)
    sub = jnp.argmax(t2, axis=1).astype(jnp.int32)
    lab_ref[...] = lab
    sub_ref[...] = sub

    # stat fold: mirror of kernels/suffstats._suffstats_labels_kernel
    r = _seg_onehot(lab, sub, valid_ref[...], n_ref.shape[1])
    n_ref[...] += jnp.sum(r, axis=0)[None, :]
    sx_ref[...] += jnp.dot(r.T, x,
                           preferred_element_type=jnp.float32)[None]
    xw = r.T[:, :, None] * x[None, :, :]                 # (2K, bn, d)
    sxx_ref[...] += jax.lax.dot_general(
        xw.transpose(0, 2, 1), jnp.broadcast_to(x, (r.shape[1],) + x.shape),
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)[None]


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def sweep_gauss(x: jax.Array, mu: jax.Array, chol_prec: jax.Array,
                logdet_prec: jax.Array, logw: jax.Array, active: jax.Array,
                sub_mu: jax.Array, sub_chol_prec: jax.Array,
                sub_logdet_prec: jax.Array, sublogw: jax.Array,
                valid: jax.Array, gidx: jax.Array, key_z: jax.Array,
                key_zb: jax.Array, *, bn: int = 128,
                interpret: bool = False
                ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array,
                           jax.Array]:
    """One-read fused sweep for the full-covariance Gaussian.

    x: (N, d); mu: (K, d); chol_prec: (K, d, d); logdet_prec/logw: (K,);
    sub_*: the (K, 2, ...) sub-cluster analogues; valid: (N,);
    gidx: (N,) uint32. Returns ``(labels, sublabels, n2 (nsb, K, 2),
    sx2 (nsb, K, 2, d), sxx2 (nsb, K, 2, d, d))`` with per-STATS_BLOCK
    stat partials.
    """
    assert STATS_BLOCK % bn == 0, "bn must divide the stats fold block"
    n, d = x.shape
    k = mu.shape[0]
    s = 2 * k
    x, valid, gidx = _pad_points(
        (x, jnp.asarray(valid, jnp.float32), gidx.astype(jnp.uint32)), bn)
    gn = x.shape[0] // bn
    spb = STATS_BLOCK // bn
    nsb = -(-gn // spb)
    active = active.astype(jnp.int32)

    labels, sublabels, n2, sx2, sxx2 = pl.pallas_call(
        functools.partial(_sweep_gauss_kernel, spb),
        grid=(gn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k, d, d), lambda i: (0, 0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k, 2, d), lambda i: (0, 0, 0)),
            pl.BlockSpec((k, 2, d, d), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((k, 2), lambda i: (0, 0)),
            pl.BlockSpec((k, 2), lambda i: (0, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((1, s), lambda i: (i // spb, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i // spb, 0, 0)),
            pl.BlockSpec((1, s, d, d), lambda i: (i // spb, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((x.shape[0],), jnp.int32),
            jax.ShapeDtypeStruct((x.shape[0],), jnp.int32),
            jax.ShapeDtypeStruct((nsb, s), jnp.float32),
            jax.ShapeDtypeStruct((nsb, s, d), jnp.float32),
            jax.ShapeDtypeStruct((nsb, s, d, d), jnp.float32),
        ],
        interpret=interpret,
    )(x, mu, chol_prec, logdet_prec, logw, active, sub_mu, sub_chol_prec,
      sub_logdet_prec, sublogw, valid, gidx, key_z, key_zb)
    return (labels[:n], sublabels[:n], n2.reshape(nsb, k, 2),
            sx2.reshape(nsb, k, 2, d), sxx2.reshape(nsb, k, 2, d, d))
