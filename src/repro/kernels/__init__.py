"""Pallas TPU kernels for the paper's compute hot spots (DESIGN §7):

    loglik.py     — (N, K) Gaussian log-likelihood (`dcolwise_dot_all`)
    suffstats.py  — per-cluster sufficient statistics (masked matmuls)
    assign.py     — fused assignment steps (e)/(f) (flash-style argmax)
    sweep.py      — ONE-READ sweep megakernels: e + f + stat fold per
                    resident block; x touches HBM once per sweep (also
                    the canonical home of STATS_BLOCK, the fold unit)
    prng.py       — counter-based Threefry-2x32 (bitwise = jax PRNG)
    matmul.py     — blocked matmul ('Kernel #1'; ops.matmul_auto = the
                    paper's d*N size-based auto-selection vs XLA dot)

``ops`` holds the jit'd wrappers, ``ref`` the pure-jnp oracles that the
kernel tests sweep against (interpret=True on CPU, Mosaic on TPU).
"""
from repro.kernels import ops, ref  # noqa: F401
