"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are also the *dry-run* path: Mosaic kernels cannot lower for the CPU
backend and ``interpret=True`` HLO would poison the roofline terms, so
``use_pallas=False`` (the off-TPU default) routes here (DESIGN §7).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

LOG_2PI = 1.8378770664093453


def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """(M, K) @ (K, N) in f32 accumulation."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def loglik(x: jax.Array, mu: jax.Array, chol_prec: jax.Array,
           logdet_prec: jax.Array) -> jax.Array:
    """Gaussian log-likelihoods (N, K) from whitening factors.

    x: (N, d); mu: (K, d); chol_prec F: (K, d, d) with Sigma^-1 = F F^T;
    logdet_prec: (K,). The paper's `dcolwise_dot_all` hot spot.
    """
    diff = x[:, None, :] - mu[None, :, :]                  # (N, K, d)
    y = jnp.einsum("nkd,kde->nke", diff, chol_prec,
                   preferred_element_type=jnp.float32)
    maha = jnp.sum(y * y, axis=-1)
    d = x.shape[-1]
    return (0.5 * (logdet_prec[None, :] - maha)
            - 0.5 * d * LOG_2PI).astype(jnp.float32)


def suffstats(x: jax.Array, resp: jax.Array):
    """Per-cluster sufficient statistics from one-hot-ish responsibilities.

    x: (N, d); resp: (N, K). Returns (n (K,), sx (K, d), sxx (K, d, d)) —
    the paper's per-stream accumulation, as masked matmuls.
    """
    n = jnp.sum(resp, axis=0)
    sx = jnp.einsum("nk,nd->kd", resp, x,
                    preferred_element_type=jnp.float32)
    sxx = jnp.einsum("nk,nd,ne->kde", resp, x, x,
                     preferred_element_type=jnp.float32)
    return n.astype(jnp.float32), sx, sxx
