"""Pallas TPU kernel for the Gaussian log-likelihood matrix (N, K) — the
paper's `dcolwise_dot_all_kernel` + per-stream likelihood hot spot (§4.1e).

For each (point-tile, cluster-tile): diff = x - mu (bn, bk, d) broadcast in
VMEM, whitening y = diff @ F_k on the MXU (batched over the bk clusters),
row-reduce ||y||^2 on the VPU. O(N K d^2) FLOPs — the dominant term of the
paper's complexity O(N K T / G) with T = d^2.

Tiling: grid (N/bn, K/bk); VMEM per step =
    x (bn, d) + mu/F (bk d + bk d^2) + diff/y (2 bn bk d) + out (bn, bk)
with bn=128, bk=8, d<=128 that is ~1.6 MiB — well inside the ~16 MiB VMEM.
``MAX_KERNEL_D`` makes the d<=128 assumption explicit: the per-step VMEM
footprint grows as bk*d^2 + 2*bn*bk*d, so beyond 128 the tile no longer
fits the budget and ``loglik`` falls back to the jnp reference
(kernels/ref.py) instead of silently blowing VMEM at Mosaic compile time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref
from repro.kernels.suffstats import MAX_KERNEL_D  # shared VMEM ceiling

LOG_2PI = 1.8378770664093453


def _loglik_kernel(x_ref, mu_ref, f_ref, ld_ref, o_ref):
    x = x_ref[...]                               # (bn, d)
    mu = mu_ref[...]                             # (bk, d)
    f = f_ref[...]                               # (bk, d, d)
    ld = ld_ref[...]                             # (bk,)
    d = x.shape[-1]
    diff = x[:, None, :] - mu[None, :, :]        # (bn, bk, d)
    # batched whitening matmul on the MXU: (bk, bn, d) @ (bk, d, d)
    y = jax.lax.dot_general(
        diff.transpose(1, 0, 2), f,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)      # (bk, bn, d)
    maha = jnp.sum(y * y, axis=-1)               # (bk, bn)
    o_ref[...] = (0.5 * (ld[:, None] - maha)
                  - 0.5 * d * LOG_2PI).T.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def loglik(x: jax.Array, mu: jax.Array, chol_prec: jax.Array,
           logdet_prec: jax.Array, *, bn: int = 128, bk: int = 8,
           interpret: bool = False) -> jax.Array:
    """x: (N, d); mu: (K, d); chol_prec: (K, d, d); logdet: (K,) -> (N, K)."""
    n, d = x.shape
    if d > MAX_KERNEL_D:                 # documented VMEM guard: jnp path
        return ref.loglik(x, mu, chol_prec, logdet_prec)
    k = mu.shape[0]
    bn = min(bn, n) or 1
    bk = min(bk, k) or 1
    pn, pk = (-n) % bn, (-k) % bk
    if pn:
        x = jnp.pad(x, ((0, pn), (0, 0)))
    if pk:
        mu = jnp.pad(mu, ((0, pk), (0, 0)))
        eye = jnp.broadcast_to(jnp.eye(d, dtype=chol_prec.dtype),
                               (pk, d, d))
        chol_prec = jnp.concatenate([chol_prec, eye], axis=0)
        logdet_prec = jnp.pad(logdet_prec, (0, pk))
    gn, gk = x.shape[0] // bn, mu.shape[0] // bk

    out = pl.pallas_call(
        _loglik_kernel,
        grid=(gn, gk),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bk, d, d), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((bk,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], mu.shape[0]),
                                       jnp.float32),
        interpret=interpret,
    )(x, mu, chol_prec, logdet_prec)
    return out[:n, :k]
