"""jit'd kernel wrappers + the paper's run-time kernel auto-selection (§4.2).

The paper picks between two CUDA matmul kernels by the d x N problem size
(crossover measured at 640,000 on a Quadro RTX 4000, overridable by the
user). We reproduce the mechanism: ``matmul_auto`` dispatches between the
Pallas blocked kernel and XLA's dot at ``MATMUL_CROSSOVER`` elements, and
the crossover for *this* host is re-measured by benchmarks/bench_kernels.py
(EXPERIMENTS §Perf).

On CPU (this container) the Pallas kernels run in ``interpret=True`` mode —
the kernel body executes in Python for correctness validation; on TPU the
same ``pl.pallas_call`` lowers through Mosaic.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import assign as _assign
from repro.kernels import loglik as _loglik
from repro.kernels import matmul as _matmul
from repro.kernels import ref
from repro.kernels import suffstats as _suffstats
from repro.kernels import sweep as _sweep

# the paper's measured CUDA crossover; bench_kernels re-measures per host
MATMUL_CROSSOVER = 640_000

# shared VMEM ceiling on the feature dim (kernels/loglik.py, suffstats.py)
MAX_KERNEL_D = _suffstats.MAX_KERNEL_D

# VMEM budget for the resident (K, 2, ...) sub-cluster parameter block of
# the fused sub-assignment kernels (kernels/assign.py) — Cholesky factors
# for the Gaussian, packed weights (+ the per-tile (bn, K) one-hot used for
# the MXU gather) for the linear families. Only the three-pass step-(f)
# kernels still hold an all-K block; the megakernels stream K-blocks.
SUB_PARAMS_VMEM_BYTES = 8 * 1024 * 1024

# Per-GRID-STEP VMEM budget for the K-blocked kernels (assign + megakernel
# sweeps): only a (bn, ...) point block and a (bk, ...) cluster tile are
# resident at once, so the guard scales with bk — NOT with K — and the
# effective K and d ceilings are set by HBM, not VMEM. This replaces the
# old blanket ``MAX_KERNEL_D``/all-K-resident guards for those kernels.
KERNEL_BLOCK_VMEM_BYTES = 8 * 1024 * 1024

# Default streamed cluster-tile size (see kernels/sweep.py)
K_BLOCK = _sweep.K_BLOCK


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def matmul_pallas(a: jax.Array, b: jax.Array, **kw) -> jax.Array:
    return _matmul.matmul(a, b, interpret=_interpret(), **kw)


def matmul_auto(a: jax.Array, b: jax.Array,
                crossover: int = MATMUL_CROSSOVER) -> jax.Array:
    """Size-dispatched matmul: Pallas ('Kernel #1') below the crossover,
    XLA dot ('Kernel #2') above — the paper's auto-selection, sizes are
    static at trace time so the dispatch costs nothing at run time."""
    size = a.shape[0] * a.shape[1]                 # the paper's d*N measure
    if size < crossover:
        return matmul_pallas(a, b)
    return ref.matmul(a, b)


def loglik_pallas(x: jax.Array, mu: jax.Array, chol_prec: jax.Array,
                  logdet_prec: jax.Array, **kw) -> jax.Array:
    return _loglik.loglik(x, mu, chol_prec, logdet_prec,
                          interpret=_interpret(), **kw)


def suffstats_pallas(x: jax.Array, resp: jax.Array, **kw
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    return _suffstats.suffstats(x, resp, interpret=_interpret(), **kw)


def gauss_loglik(x: jax.Array, params, use_pallas: bool) -> jax.Array:
    """Gaussian family fast path (core/family.py): (N, K) log-likelihoods
    from a batched GaussParams pytree (core/niw.py)."""
    if use_pallas:
        return loglik_pallas(x, params.mu, params.chol_prec,
                             params.logdet_prec)
    return ref.loglik(x, params.mu, params.chol_prec, params.logdet_prec)


# ---------------------------------------------------------------------------
# Fused assignment (steps e/f) + label-indexed suff-stats (kernels/assign.py,
# kernels/suffstats.py). Every wrapper returns ``None`` when the problem
# falls outside the kernel's documented VMEM envelope, and the caller
# (core/family.py dispatch) runs the jnp reference path instead.
# ---------------------------------------------------------------------------
def assign_linear_pallas(feats, w, const, logw, active, gidx, key_data,
                         slots=None, k_block: int = K_BLOCK
                         ) -> Optional[jax.Array]:
    bn, bk = 128, k_block
    # per grid step: (bn, d') feats + (bk, d') weight tile + (bn, bk) logits
    step = (bn * feats.shape[1] + bk * feats.shape[1] + 3 * bn * bk) * 4
    if step > KERNEL_BLOCK_VMEM_BYTES:
        return None
    return _assign.assign_linear(feats, w, const, logw, active, gidx,
                                 key_data, slots, bk=bk,
                                 interpret=_interpret())


def assign_gauss_pallas(x, mu, chol_prec, logdet_prec, logw, active, gidx,
                        key_data, slots=None, k_block: int = K_BLOCK
                        ) -> Optional[jax.Array]:
    bn, bk, d = 128, k_block, x.shape[1]
    # per grid step: (bn, d) x + (bk, d, d) Cholesky tile + (bn, bk, d)
    # whitened diffs (x2 for the transpose staging)
    step = (bn * d + bk * d * d + 2 * bn * bk * d + 3 * bn * bk) * 4
    if step > KERNEL_BLOCK_VMEM_BYTES:
        return None
    return _assign.assign_gauss(x, mu, chol_prec, logdet_prec, logw,
                                active, gidx, key_data, slots, bk=bk,
                                interpret=_interpret())


def sub_assign_linear_pallas(feats, w, const, sublogw, labels, gidx,
                             key_data) -> Optional[jax.Array]:
    resident = (w.size + 128 * w.shape[0]) * 4   # (K,2,d') block + one-hot
    if feats.shape[1] > 2 * MAX_KERNEL_D or resident > SUB_PARAMS_VMEM_BYTES:
        return None
    return _assign.sub_assign_linear(feats, w, const, sublogw, labels,
                                     gidx, key_data,
                                     interpret=_interpret())


def sub_assign_gauss_pallas(x, mu, chol_prec, logdet_prec, sublogw, labels,
                            gidx, key_data) -> Optional[jax.Array]:
    d = x.shape[1]
    if d > MAX_KERNEL_D or chol_prec.size * 4 > SUB_PARAMS_VMEM_BYTES:
        return None
    return _assign.sub_assign_gauss(x, mu, chol_prec, logdet_prec, sublogw,
                                    labels, gidx, key_data,
                                    interpret=_interpret())


def sweep_linear_pallas(feats, w, const, logw, active, subw, subconst,
                        sublogw, valid, gidx, key_z, key_zb, slots=None,
                        k_block: int = K_BLOCK):
    """One-read, K-blocked fused sweep (kernels/sweep.py) for linear
    families.

    Returns ``(labels, sublabels, n2, sf2)`` with per-STATS_BLOCK stat
    partials, or ``None`` outside the per-K-block VMEM envelope (caller
    falls back to the blocked jnp reference). Only a (bk, ...) cluster
    tile is resident per grid step, so the guard is independent of K.
    """
    bn, bk, dp = 128, k_block, feats.shape[1]
    # per grid step: (bn, d') feats, (bk, d') + (bk, 2, d') weight tiles,
    # the (bn, bk) one-hot / (bn, 2bk) segment one-hot, the (2bk, d') stat
    # partial tile and the (bn, 2, d') gathered sub-weights
    step = (bn * dp + 3 * bk * dp + 5 * bn * bk + 2 * bk * dp
            + 2 * bn * dp) * 4
    if step > KERNEL_BLOCK_VMEM_BYTES:
        return None
    return _sweep.sweep_linear(feats, w, const, logw, active, subw,
                               subconst, sublogw, valid, gidx, key_z,
                               key_zb, slots, bk=bk,
                               interpret=_interpret())


def sweep_gauss_pallas(x, mu, chol_prec, logdet_prec, logw, active, sub_mu,
                       sub_chol_prec, sub_logdet_prec, sublogw, valid, gidx,
                       key_z, key_zb, slots=None, k_block: int = K_BLOCK):
    """One-read, K-blocked fused sweep for the full-covariance Gaussian,
    or ``None`` outside the per-K-block VMEM envelope."""
    bn, bk, d = 128, k_block, x.shape[1]
    # per grid step: (bk, d, d) + (bk, 2, d, d) Cholesky tiles, the
    # gathered (bn, 2, d, d) factors, (bn, bk, d) diffs (x2 staging) and
    # the (2bk, d, d) stat partial tile
    step = (bn * d + 3 * bk * d * d + 2 * bn * d * d + 2 * bn * bk * d
            + 2 * bk * d * d + 5 * bn * bk) * 4
    if step > KERNEL_BLOCK_VMEM_BYTES:
        return None
    return _sweep.sweep_gauss(x, mu, chol_prec, logdet_prec, logw, active,
                              sub_mu, sub_chol_prec, sub_logdet_prec,
                              sublogw, valid, gidx, key_z, key_zb, slots,
                              bk=bk, interpret=_interpret())


def suffstats_labels_pallas(x, labels, sublabels, valid, k: int):
    if x.shape[1] > MAX_KERNEL_D:
        return None
    return _suffstats.suffstats_labels(x, labels, sublabels, valid, k,
                                       interpret=_interpret())


def moments_labels_pallas(feats, labels, sublabels, valid, k: int):
    if feats.shape[1] > 2 * MAX_KERNEL_D:
        return None
    return _suffstats.moments_labels(feats, labels, sublabels, valid, k,
                                     interpret=_interpret())


def diag_gauss_loglik(x: jax.Array, params, use_pallas: bool) -> jax.Array:
    """diag_gaussian family fast path: the quadratic expands into two
    (N, d) x (d, K) matmuls served by the paper's auto-selected matmul
    kernel (§4.2) — same hot-spot shape as the multinomial likelihood."""
    from repro.core import diag_gaussian
    return diag_gaussian.loglik(
        x, params, matmul=matmul_auto if use_pallas else ref.matmul)
