"""Quickstart — the paper's §3.4.1/§3.4.4 example, JAX edition.

Generates a synthetic GMM dataset (N=1e5, d=2, K=10 — the paper's own
quickstart numbers), fits a DPMM without knowing K, and reports NMI +
per-iteration timings.

    PYTHONPATH=src python examples/quickstart.py [--n 100000]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.configs import DPMMConfig
from repro.core.sampler import DPMM
from repro.data.synthetic import generate_gmm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--d", type=int, default=2)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--iters", type=int, default=100)
    args = ap.parse_args()

    print(f"generating GMM data: N={args.n} d={args.d} K={args.k}")
    x, gt = generate_gmm(args.n, args.d, args.k, seed=0, sep=12.0)

    # the paper's quickstart: fit without knowing K (alpha=10, 100 iters)
    model = DPMM(DPMMConfig(alpha=10.0, iters=args.iters, k_max=64,
                            burnout=5))
    t0 = time.time()
    result = model.fit(x, verbose=True)
    wall = time.time() - t0

    print(f"\nfit done in {wall:.1f}s "
          f"({np.mean(result.iter_times_s[1:])*1e3:.1f} ms/iter steady)")
    print(f"K found: {result.k} (true {args.k})")
    print(f"NMI:     {result.nmi(gt):.4f}")
    print(f"K history: {result.history['k'][:20]} ...")


if __name__ == "__main__":
    main()
