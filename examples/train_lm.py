"""End-to-end driver: train a ~100M-param granite-family model for a few
hundred steps on the synthetic corpus and watch the loss drop — the
'train a ~100M model' deliverable, runnable on this CPU container.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, get_config
from repro.data.pipeline import lm_batches
from repro.launch.mesh import make_host_mesh
from repro.models.common import ShardingPolicy
from repro.train import checkpoint, init_train_state, make_train_step


def hundred_m_config():
    """granite-8b family scaled to ~100M params (12 layers, d=768)."""
    base = get_config("granite-8b")
    return dataclasses.replace(
        base, name="granite-100m", num_layers=12, d_model=768, num_heads=12,
        num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=8192)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--save", default="")
    args = ap.parse_args()

    cfg = hundred_m_config()
    tcfg = TrainConfig(lr=6e-4, warmup_steps=30, total_steps=args.steps,
                       loss_chunk=128)
    mesh = make_host_mesh(data=len(jax.devices()), model=1)
    policy = ShardingPolicy(
        batch_sharded=args.batch % mesh.shape["data"] == 0,
        seq_shard=False, mesh_axes=tuple(mesh.axis_names),
        mesh_sizes=tuple(mesh.shape.items()))

    state = init_train_state(jax.random.key(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"{cfg.name}: {n_params/1e6:.1f}M params, "
          f"{len(jax.devices())} device(s), {args.steps} steps")

    step_fn = make_train_step(mesh, cfg, tcfg, policy)
    gen = lm_batches(cfg.vocab_size, args.batch, args.seq, seed=0)
    t0, losses = time.time(), []
    for step in range(args.steps):
        toks, tgts = next(gen)
        state, m = step_fn(state, {"tokens": jnp.asarray(toks),
                                   "targets": jnp.asarray(tgts)})
        losses.append(float(m["loss"]))
        if step % 20 == 0 or step == args.steps - 1:
            tok_s = args.batch * args.seq * (step + 1) / (time.time() - t0)
            print(f"step {step:4d} loss={losses[-1]:.4f} "
                  f"acc={float(m['accuracy']):.3f} "
                  f"lr={float(m['lr']):.2e} ({tok_s:,.0f} tok/s)")
    print(f"\nloss: {np.mean(losses[:10]):.3f} -> {np.mean(losses[-10:]):.3f}"
          f" over {args.steps} steps")
    if args.save:
        checkpoint.save(args.save, state.params)
        print(f"saved {args.save}")


if __name__ == "__main__":
    main()
