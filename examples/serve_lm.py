"""Serving example: batched cached decoding through the serving engine —
the decode-shape path the dry-run lowers at 32k/524k, at container scale.

    PYTHONPATH=src python examples/serve_lm.py
"""
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import INPUT_SHAPES, smoke_config
from repro.launch.mesh import make_host_mesh
from repro.models import transformer
from repro.serve.engine import Generator


def main():
    cfg = smoke_config("gemma2-9b")           # local+global pattern + caps
    shape = dataclasses.replace(INPUT_SHAPES["decode_32k"], seq_len=256,
                                global_batch=4)
    mesh = make_host_mesh(data=1, model=1)
    params = transformer.init_params(jax.random.key(0), cfg)
    gen = Generator(mesh, cfg, shape, params, temperature=0.8)

    prompts = jax.random.randint(jax.random.key(1), (4, 8), 0,
                                 cfg.vocab_size)
    print(f"{cfg.name}: batch={prompts.shape[0]} prompt_len=8, "
          f"cache_len={shape.seq_len}")
    t0 = time.time()
    out = gen.generate(prompts, steps=48, seed=0)
    dt = time.time() - t0
    n_new = 4 * 48
    print(f"generated {out.shape} in {dt:.1f}s "
          f"({n_new/dt:.1f} tok/s batched)")
    for b in range(2):
        print(f"  seq {b}: {out[b, :20].tolist()} ...")
    # greedy rerun determinism
    gen0 = Generator(mesh, cfg, shape, params, temperature=0.0)
    a = gen0.generate(prompts, steps=16)
    b = gen0.generate(prompts, steps=16)
    assert bool((a == b).all()), "greedy decode must be deterministic"
    print("greedy decode deterministic ✓")


if __name__ == "__main__":
    main()
