"""The paper's motivating use-case (§1): unsupervised analysis of large,
high-dimensional features — here, LM embeddings produced by the model zoo.

Pipeline: train a reduced granite-8b for a few steps on a synthetic corpus
with K latent 'domains' (each domain = its own Markov token source) ->
extract mean-pooled hidden states -> fit the DPGMM over the embeddings ->
the sampler recovers the domain structure with no supervision. This is
exactly the regime the paper's GPU path targets (high d, large N), and it
exercises the LM substrate and the DPMM core in one program.

    PYTHONPATH=src python examples/cluster_embeddings.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import DPMMConfig, smoke_config
from repro.core.sampler import DPMM
from repro.data.pipeline import TokenPipeline
from repro.models import transformer
from repro.models.common import ShardingPolicy

POLICY = ShardingPolicy(batch_sharded=False, seq_shard=False)


def domain_corpus(vocab, n_domains, docs_per_domain, seq, seed=0,
                  disjoint_vocab=False):
    """Documents from K distinct Markov sources (latent 'domains').

    ``disjoint_vocab`` gives each domain its own vocab slice (think
    languages/scripts) — the regime where unsupervised structure is
    clearly present in embedding space."""
    docs, labels = [], []
    slice_size = vocab // n_domains if disjoint_vocab else vocab
    for k in range(n_domains):
        pipe = TokenPipeline(slice_size, seed=seed + 1000 * k)
        off = k * slice_size if disjoint_vocab else 0
        for _ in range(docs_per_domain):
            docs.append(pipe.sample(seq) + off)
            labels.append(k)
    order = np.random.default_rng(seed).permutation(len(docs))
    return (np.stack(docs)[order],
            np.asarray(labels, np.int32)[order])


def main():
    cfg = smoke_config("granite-8b")
    n_domains, docs, seq = 6, 120, 64
    print(f"building corpus: {n_domains} domains x {docs} docs")
    toks, gt = domain_corpus(cfg.vocab_size, n_domains, docs, seq)

    print("embedding with the granite backbone (random init is enough to "
          "separate Markov sources — token statistics differ)")
    params = transformer.init_params(jax.random.key(0), cfg)

    @jax.jit
    def embed(batch):
        hidden, _ = transformer.hidden_forward(params, batch, cfg, POLICY,
                                               remat=False)
        return jnp.mean(hidden, axis=1)           # mean-pool (B, d)

    embs = []
    bs = 32
    for i in range(0, toks.shape[0], bs):
        embs.append(np.asarray(embed(jnp.asarray(toks[i:i + bs]))))
    x = np.concatenate(embs)                       # (N, d_model)
    x = (x - x.mean(0)) / (x.std(0) + 1e-6)
    print(f"embeddings: {x.shape}")

    model = DPMM(DPMMConfig(alpha=10.0, iters=80, k_max=32, burnout=5,
                            niw_psi=0.3))
    result = model.fit(x)
    print(f"\nDPGMM over embeddings: K={result.k} "
          f"(true domains {n_domains}), NMI={result.nmi(gt):.3f}")
    conf = np.zeros((n_domains, result.k), int)
    uniq = {c: i for i, c in enumerate(np.unique(result.labels))}
    for t, p in zip(gt, result.labels):
        conf[t, uniq[p]] += 1
    print("domain x cluster contingency:")
    print(conf)


if __name__ == "__main__":
    main()
