"""End-to-end sampler behaviour — the paper's Figs 1-2 claims (C1, C2):
correct K recovery and high NMI on synthetic DPGMM/DPMNMM data, same
hyperparameters across datasets."""
import numpy as np
import pytest

from repro.configs import DPMMConfig
from repro.core.sampler import DPMM
from repro.data.synthetic import generate_gmm, generate_mnmm

CFG = DPMMConfig(alpha=10.0, iters=80, k_max=32, burnout=5)


def test_gmm_recovers_k_and_nmi():
    """Fig 2 analogue: 6 well-separated Gaussians, K and NMI recovered."""
    x, gt = generate_gmm(5000, 2, 6, seed=1, sep=12.0)
    r = DPMM(CFG).fit(x)
    assert r.nmi(gt) > 0.9, (r.k, r.nmi(gt))
    assert 4 <= r.k <= 10, r.k


def test_gmm_20_clusters_same_hyperparams():
    """Fig 1 analogue: 20 clusters detected with the SAME hyperparameters."""
    x, gt = generate_gmm(8000, 2, 20, seed=0, sep=25.0)
    r = DPMM(CFG).fit(x, iters=120)
    assert r.nmi(gt) > 0.9, (r.k, r.nmi(gt))
    assert 14 <= r.k <= 28, r.k


def test_gmm_higher_dim():
    x, gt = generate_gmm(4000, 16, 5, seed=2, sep=4.0)
    r = DPMM(CFG).fit(x)
    assert r.nmi(gt) > 0.9, (r.k, r.nmi(gt))


def test_mnmm_recovers_structure():
    """DPMNMM (paper §5.2): multinomial components."""
    x, gt = generate_mnmm(4000, 32, 8, seed=0)
    cfg = DPMMConfig(component="multinomial", alpha=10.0, iters=80,
                     k_max=32, burnout=5)
    r = DPMM(cfg).fit(x)
    assert r.nmi(gt) > 0.9, (r.k, r.nmi(gt))
    assert 6 <= r.k <= 12, r.k


def test_k_max_ceiling_is_respected():
    """Splits that would exceed K_max are rejected (DESIGN §6), the chain
    keeps running and labels stay within capacity."""
    x, gt = generate_gmm(2000, 2, 12, seed=3, sep=20.0)
    cfg = DPMMConfig(alpha=10.0, iters=40, k_max=8, burnout=3)
    r = DPMM(cfg).fit(x)
    assert r.k <= 8
    assert r.labels.max() < 8
    assert np.isfinite(r.nmi(gt))


def test_pallas_path_identical_chain():
    """C5 support: the Pallas loglik kernel swaps in without changing the
    chain (bitwise-identical labels)."""
    x, gt = generate_gmm(1500, 4, 4, seed=0, sep=10.0)
    cfg = DPMMConfig(alpha=10.0, iters=25, k_max=16, burnout=5)
    r1 = DPMM(cfg).fit(x)
    r2 = DPMM(
        DPMMConfig(alpha=10.0, iters=25, k_max=16, burnout=5,
                   use_pallas=True)).fit(x)
    assert np.array_equal(r1.labels, r2.labels)


def test_history_monotone_burnin():
    """No splits/merges before burnout: K stays at init_clusters."""
    x, _ = generate_gmm(1000, 2, 4, seed=4, sep=10.0)
    cfg = DPMMConfig(alpha=10.0, iters=10, k_max=16, burnout=10,
                     init_clusters=2)
    r = DPMM(cfg).fit(x)
    assert (r.history["k"] == 2).all()
