"""Sparse-K sweeps (ISSUE 6): active-set compaction + K-blocked
megakernels make per-iteration cost O(K_active) and lift the all-K-in-VMEM
ceiling — as a PURE performance change.

 - tile-level parity: ``gibbs.sweep_tile`` on a compacted slab (with the
   K-blocked kernel at two block sizes) vs the dense slab, BITWISE
   (labels, sublabels, scattered stats) for all 4 families on both the
   jnp reference and Pallas (interpret) paths;
 - full-fit parity: ``compact=True`` fits (the default) are bitwise
   ``compact=False`` fits on the resident AND tiled planes, all families;
 - the k_max >= 512 acceptance fit: a compacted K-blocked megakernel fit
   under a 512-slot slab matches the dense-slab jnp reference at every
   iteration (labels + history; score to the cross-path float tolerance);
 - the structural sparse-K guarantee: the megakernel's cluster-parameter
   operands are (k_block, ...)-tiled in the pallas_call grid — no
   (k_max, ...)-resident block exists, so VMEM per grid step is O(bk);
 - the ``k_max='auto'`` growth hook and its config validation.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import DPMMConfig
from repro.core import gibbs
from repro.core.family import available_families, get_family
from repro.core.gibbs import STATS_BLOCK
from repro.core.sampler import DPMM, _init_local
from repro.data.synthetic import generate_gmm, generate_mnmm, generate_pmm

ALL = available_families()
K_BLOCKS = (4, 8)


def _data(name, n, d=5, k=4):
    if name in ("gaussian", "diag_gaussian"):
        return generate_gmm(n, d, k, seed=0, sep=8.0)[0]
    if name == "poisson":
        return generate_pmm(n, d, k, seed=0)[0]
    return generate_mnmm(n, max(d, k), k, seed=0)[0]


def _state(name, n, d=5, k_max=12, init_clusters=4):
    fam = get_family(name)
    x = jnp.asarray(_data(name, n, d))
    valid = jnp.ones((n,), jnp.float32)
    cfg = DPMMConfig(component=name, init_clusters=init_clusters,
                     k_max=k_max)
    prior = fam.build_prior(cfg, x)
    model, point = _init_local(jax.random.key(0), x, valid, prior=prior,
                               family=fam, cfg=cfg, axes=(), k_max=k_max)
    return fam, x, model, point, prior


def _run_tile(fam, x, model, point, use_pallas, plan=None, k_block=None):
    k = (model.active.shape[0] if plan is None
         else plan.slot_of_compact.shape[0])
    gidx = jnp.arange(x.shape[0], dtype=jnp.uint32)
    acc = gibbs.empty_substats(fam, k, x.shape[1])
    fn = jax.jit(lambda m, xx, p, g, a: gibbs.sweep_tile(
        m, xx, p, g, a, fam, use_pallas=use_pallas, plan=plan,
        k_block=k_block))
    point2, acc2 = fn(model, x, point, gidx, acc)
    if plan is not None:     # back to the dense slab for comparison
        acc2 = gibbs.compact_scatter(plan, model.active.shape[0], acc2)
    return jax.tree.map(np.asarray, (point2, acc2))


def _assert_tree_equal(a, b, what):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert np.array_equal(np.asarray(la), np.asarray(lb)), (
            f"{what}: stat leaves differ")


# ---------------------------------------------------------------------------
# tile-level: compacted K-blocked sweep == dense-slab sweep, bitwise
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("k_block", K_BLOCKS)
@pytest.mark.parametrize("name", ALL)
def test_compact_tile_matches_dense_reference(name, k_block):
    """jnp path: the compacted sweep_tile (gather -> sweep -> scatter,
    slot-id Gumbel counters) reproduces the dense-slab sweep bitwise."""
    fam, x, model, point, _ = _state(name, STATS_BLOCK + 452)
    plan = gibbs.compaction_plan(model.active, 6)       # k_hat = 4 <= 6
    pd, ad = _run_tile(fam, x, model, point, use_pallas=False)
    pc, ac = _run_tile(fam, x, model, point, use_pallas=False, plan=plan,
                       k_block=k_block)
    np.testing.assert_array_equal(pc.labels, pd.labels)
    np.testing.assert_array_equal(pc.sublabels, pd.sublabels)
    _assert_tree_equal(ac, ad, f"{name} bk={k_block} reference")


@pytest.mark.parametrize("k_block", K_BLOCKS)
@pytest.mark.parametrize("name", ALL)
def test_compact_tile_matches_dense_pallas(name, k_block):
    """Pallas (interpret) path: the compacted K-blocked megakernel —
    streaming (k_block, ...) cluster tiles with a running argmax carry —
    reproduces the dense-slab megakernel bitwise."""
    fam, x, model, point, _ = _state(name, STATS_BLOCK + 452)
    plan = gibbs.compaction_plan(model.active, 6)
    pd, ad = _run_tile(fam, x, model, point, use_pallas=True)
    pc, ac = _run_tile(fam, x, model, point, use_pallas=True, plan=plan,
                       k_block=k_block)
    np.testing.assert_array_equal(pc.labels, pd.labels)
    np.testing.assert_array_equal(pc.sublabels, pd.sublabels)
    _assert_tree_equal(ac, ad, f"{name} bk={k_block} pallas")


# ---------------------------------------------------------------------------
# full-fit parity: compact=True (default) == compact=False, both planes
# ---------------------------------------------------------------------------
def _cfg(name, **kw):
    return DPMMConfig(component=name, alpha=10.0, iters=14, k_max=16,
                      burnout=4, **kw)


def _assert_fit_bitwise(a, b, what):
    assert np.array_equal(a.labels, b.labels), f"{what}: labels differ"
    for key in a.history:
        assert np.array_equal(a.history[key], b.history[key]), (
            f"{what}: history[{key}] differs")
    for field in ("stats", "substats"):
        _assert_tree_equal(getattr(a.state, field),
                           getattr(b.state, field), f"{what}: {field}")


@pytest.mark.parametrize("name", ALL)
def test_compact_fit_matches_dense_both_planes(name):
    """Full DPMM.fit: compaction (2x-headroom pow2 slabs, lax.cond dense
    fallback, split/merge compact fold) is chain-neutral on the resident
    plane, and the tiled plane (per-iteration exact k_c, no cond) matches
    too."""
    x = _data(name, 2048, d=4)
    dense = DPMM(_cfg(name, compact=False)).fit(x)
    assert dense.k >= 2               # non-trivial chain: splits happened
    compact = DPMM(_cfg(name, compact=True)).fit(x)
    _assert_fit_bitwise(dense, compact, f"{name} resident")
    tiled = DPMM(_cfg(name, compact=True,
                      tile_size=STATS_BLOCK)).fit(x)
    _assert_fit_bitwise(dense, tiled, f"{name} tiled-compact")


def test_compact_fit_matches_dense_multichain():
    x = _data("gaussian", 2048, d=4)
    dense = DPMM(_cfg("gaussian", compact=False)).fit(x, n_chains=2)
    compact = DPMM(_cfg("gaussian", compact=True)).fit(x, n_chains=2)
    _assert_fit_bitwise(dense, compact, "multichain")


# ---------------------------------------------------------------------------
# the k_max >= 512 acceptance fit (ISSUE 6)
# ---------------------------------------------------------------------------
def _cfg512(**kw):
    # burnout == iters: no split/merge, so k stays at init_clusters and
    # the O(K^2) merge proposal never runs at K=512 (the sweep itself is
    # the object under test); init_clusters=6 keeps 6 live clusters under
    # the 512-slot slab -> compact slab = 16 pow2 rows
    return DPMMConfig(component="gaussian", alpha=10.0, iters=6,
                      k_max=512, init_clusters=6, burnout=6, log_every=3,
                      **kw)


def test_kmax_512_compact_jnp_matches_dense_bitwise():
    """Under a 512-slot slab, the compacted jnp fit is bitwise the dense
    jnp fit at every iteration (history rows) and in the final state."""
    x = _data("gaussian", 1024, d=4)
    dense = DPMM(_cfg512(compact=False)).fit(x)
    compact = DPMM(_cfg512(compact=True)).fit(x)
    _assert_fit_bitwise(dense, compact, "k_max=512 jnp")


def test_kmax_512_megakernel_matches_dense_reference():
    """The acceptance fit: k_max=512 through the compacted K-blocked
    megakernel (interpret mode on CPU) vs the dense-slab jnp reference.
    Labels and the k/cluster-size history match bitwise at every
    iteration; the 'score' trace — a float function of differently-
    associated stat sums — matches to the repo's cross-path tolerance."""
    x = _data("gaussian", 1024, d=4)
    dense = DPMM(_cfg512(compact=False, use_pallas=False)).fit(x)
    fused = DPMM(_cfg512(compact=True, use_pallas=True)).fit(x)
    assert np.array_equal(fused.labels, dense.labels)
    for key in ("k", "max_cluster", "min_cluster"):
        assert np.array_equal(fused.history[key], dense.history[key]), key
    np.testing.assert_allclose(fused.history["score"],
                               dense.history["score"], rtol=1e-3, atol=1.0)


# ---------------------------------------------------------------------------
# structural: the megakernel streams (k_block, ...) cluster tiles
# ---------------------------------------------------------------------------
def _find_pallas_calls(jaxpr, out):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            out.append(eqn)
        for p in eqn.params.values():
            for q in (p if isinstance(p, (list, tuple)) else (p,)):
                if isinstance(q, jax.core.ClosedJaxpr):
                    _find_pallas_calls(q.jaxpr, out)
                elif isinstance(q, jax.core.Jaxpr):
                    _find_pallas_calls(q, out)
    return out


@pytest.mark.parametrize("name", ("gaussian", "multinomial"))
def test_megakernel_params_are_k_block_tiled(name):
    """The pallas_call grid carries a K-block axis and NO operand block
    is (k_max, ...)-resident: every block dim is <= max(bn, 2 * k_max //
    gk) — VMEM per grid step is O(bn + bk), independent of k_max. This is
    what removes the all-K SUB_PARAMS_VMEM ceiling."""
    k_max, bk = 512, 8
    fam, x, model, point, _ = _state(name, 256, d=4, k_max=k_max,
                                     init_clusters=6)
    gidx = jnp.arange(x.shape[0], dtype=jnp.uint32)
    acc = gibbs.empty_substats(fam, k_max, x.shape[1])
    jaxpr = jax.make_jaxpr(
        lambda m, xx, p, g, a: gibbs.sweep_tile(
            m, xx, p, g, a, fam, use_pallas=True, k_block=bk))(
        model, x, point, gidx, acc)
    calls = _find_pallas_calls(jaxpr.jaxpr, [])
    assert len(calls) == 1, "sweep must be ONE megakernel"
    gm = calls[0].params["grid_mapping"]
    grid = tuple(gm.grid)
    assert len(grid) == 3 and grid[1] == 2 and grid[2] == k_max // bk, (
        f"expected (gn, 2, {k_max // bk}) grid, got {grid}")
    for bm in gm.block_mappings:
        dims = [d for d in bm.block_shape if isinstance(d, int)]
        assert k_max not in dims, (
            f"(k_max, ...)-resident block {bm.block_shape}: the kernel "
            "must stream K-blocks, not hold the full slab in VMEM")


# ---------------------------------------------------------------------------
# k_max='auto': the slab is a discovered high-water mark
# ---------------------------------------------------------------------------
def test_auto_k_max_grows_and_clusters():
    x, gt = generate_gmm(4096, 4, 5, seed=0, sep=10.0)
    cfg = DPMMConfig(alpha=10.0, iters=20, k_max="auto", k_max_cap=64,
                     init_clusters=1, burnout=5, log_every=4)
    r = DPMM(cfg).fit(x)
    # started at the 8-slot floor; the 5-cluster posterior forces growth
    assert r.state.active.shape[0] > 8
    assert r.state.active.shape[0] <= 64
    assert r.k >= 4 and r.nmi(gt) > 0.9


def test_auto_k_max_deterministic():
    """Same config -> same chain: growth points depend only on the chain,
    which depends only on (seed, schedule)."""
    x, _ = generate_gmm(2048, 3, 4, seed=1, sep=10.0)
    cfg = DPMMConfig(alpha=10.0, iters=14, k_max="auto", k_max_cap=32,
                     burnout=4, log_every=5)
    a, b = DPMM(cfg).fit(x), DPMM(cfg).fit(x)
    assert np.array_equal(a.labels, b.labels)
    for key in a.history:
        assert np.array_equal(a.history[key], b.history[key])


def test_auto_k_max_config_validation():
    with pytest.raises(ValueError, match="resident"):
        DPMMConfig(k_max="auto", tile_size=1024)
    with pytest.raises(ValueError, match="k_max_cap"):
        DPMMConfig(k_max="auto", k_max_cap=0)
    with pytest.raises(ValueError, match="k_block"):
        DPMMConfig(k_block=0)
    with pytest.raises(ValueError, match="k_max"):
        DPMMConfig(k_max=0)


def test_auto_k_max_rejected_on_tiled_source(tmp_path):
    """A non-resident DataSource forces the tiled driver even with
    tile_size=None — 'auto' must fail loudly there, not mis-run."""
    from repro.data.source import HostTiledSource
    x, _ = generate_gmm(1024, 3, 3, seed=0, sep=10.0)
    path = tmp_path / "x.npy"
    np.save(path, x.astype(np.float32))
    src = HostTiledSource.from_npy(str(path))
    with pytest.raises(ValueError, match="resident"):
        DPMM(DPMMConfig(k_max="auto", iters=2)).fit(src)


# ---------------------------------------------------------------------------
# compacted serving engine: bitwise the dense engine math
# ---------------------------------------------------------------------------
def test_serve_engine_compacts_and_matches_dense_math():
    from repro.core.family import NEG_INF
    from repro.serve.dpmm import DPMMEngine, ServeConfig

    x, _ = generate_gmm(2048, 3, 4, seed=2, sep=10.0)
    st = DPMM(_cfg("gaussian")).fit(x).state
    eng = DPMMEngine(st, "gaussian", ServeConfig(batch_sizes=(128,)))
    assert eng.k_active == int(np.asarray(st.active).sum())
    assert eng.k_active < eng.k_max       # compaction actually engaged
    q = np.asarray(x[:300])
    res = eng.query(q)
    # dense reference math over the full slab
    fam = eng.family
    logw = jnp.where(st.active, st.logweights, NEG_INF)
    logw = (logw - jax.scipy.special.logsumexp(
        jnp.where(st.active, logw, -jnp.inf))).astype(jnp.float32)
    ll = fam.loglik(jnp.asarray(q), st.params)
    logits = jnp.where(st.active[None, :], ll + logw[None, :], NEG_INF)
    logpred = jax.scipy.special.logsumexp(logits, axis=-1)
    np.testing.assert_array_equal(
        res.labels, np.asarray(jnp.argmax(logits, -1), np.int32))
    np.testing.assert_array_equal(res.log_predictive, np.asarray(logpred))
    np.testing.assert_array_equal(
        res.logprobs, np.asarray(logits - logpred[:, None]))
    # sampled draws live on active slots and reproduce under a pinned seed
    s = eng.sample(q, seed=3)
    np.testing.assert_array_equal(s, eng.sample(q, seed=3))
    assert set(np.unique(s)).issubset(set(eng.slots.tolist()))
