"""DPMM serving path (ISSUE 5): ``DPMMEngine`` answers must be exactly
the sampler's math — soft assignment log-probs match ``family.loglik`` +
renormalized log-weights to f32 ULPs, hard labels are their argmax, the
sampled assignment is the sweep's counter-based Gumbel argmax — and the
fixed-batch precompiled step must make batching invisible (padding never
leaks into answers)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.scipy.special import logsumexp

from repro.configs import DPMMConfig
from repro.core.checkpoint import save_model
from repro.core.family import NEG_INF, get_family
from repro.core.sampler import DPMM
from repro.data.synthetic import generate_gmm
from repro.kernels import prng
from repro.serve import DPMMEngine, ServeConfig

N, D, K = 3000, 4, 4


@pytest.fixture(scope="module")
def fitted():
    # one draw from one mixture; the tail 1200 rows are held out of the
    # fit and served as queries (same components, unseen points)
    x_all, gt_all = generate_gmm(N + 1200, D, K, seed=0, sep=10.0)
    cfg = DPMMConfig(alpha=10.0, iters=16, k_max=16, burnout=4)
    result = DPMM(cfg).fit(x_all[:N], n_chains=2).select_best()
    return result, np.asarray(x_all[N:]), np.asarray(gt_all[N:])


def test_soft_assignment_matches_family_loglik(fitted):
    """The acceptance contract: engine soft-assignment == the assignment
    log-probs computed straight from family.loglik, to f32 ULPs."""
    result, xq, _ = fitted
    engine = DPMMEngine(result.state, "gaussian", ServeConfig(batch_sizes=(512,)))
    res = engine.query(xq)
    fam = get_family("gaussian")
    ll = fam.loglik(jnp.asarray(xq), result.state.params)
    logits = jnp.where(result.state.active[None, :],
                       ll + engine.logweights[None, :], NEG_INF)
    expect = np.asarray(logits - logsumexp(logits, axis=-1,
                                           keepdims=True))
    finite = np.isfinite(expect)
    np.testing.assert_allclose(res.logprobs[finite], expect[finite],
                               rtol=1e-5, atol=1e-5)
    assert np.array_equal(res.labels, np.asarray(logits).argmax(axis=1))
    # log-predictive is the logsumexp of the same logits, and soft
    # probs are normalized
    np.testing.assert_allclose(
        res.log_predictive, np.asarray(logsumexp(logits, axis=-1)),
        rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.exp(res.logprobs).sum(axis=1), 1.0, rtol=1e-4)


def test_batching_is_invisible(fitted):
    """Ragged tails are padded to the fixed compiled batch shape; the
    padding must never leak — any batch size gives the same answers."""
    result, xq, _ = fitted
    engines = [DPMMEngine(result.state, "gaussian", ServeConfig(batch_sizes=(b,)))
               for b in (256, 1200, 4096)]   # 1200 = exact, others ragged
    results = [e.query(xq) for e in engines]
    for other in results[1:]:
        assert np.array_equal(results[0].labels, other.labels)
        np.testing.assert_allclose(results[0].logprobs, other.logprobs,
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(results[0].log_predictive,
                                   other.log_predictive,
                                   rtol=1e-5, atol=1e-5)


def test_predict_quality_and_outlier_scoring(fitted):
    """Served hard labels recover the generating clusters on held-out
    data; far-away points score lower predictive density."""
    result, xq, gtq = fitted
    engine = DPMMEngine(result.state, "gaussian", ServeConfig(batch_sizes=(512,)))
    from repro.core.metrics import nmi
    served_nmi = float(nmi(jnp.asarray(gtq),
                           jnp.asarray(engine.predict(xq)), K, 16))
    assert served_nmi > 0.9
    outliers = np.full((64, D), 1e3, np.float32)
    assert (engine.log_predictive(outliers).max()
            < engine.log_predictive(xq).min())


def test_checkpoint_engine_identical(fitted, tmp_path):
    """from_checkpoint must serve the EXACT model: same compiled shapes,
    bitwise-equal answers to the in-memory engine."""
    result, xq, _ = fitted
    path = str(tmp_path / "m.npz")
    save_model(path, result.state, "gaussian")
    mem = DPMMEngine(result.state, "gaussian", ServeConfig(batch_sizes=(512,)))
    ckpt = DPMMEngine.from_checkpoint(path, ServeConfig(batch_sizes=(512,)))
    a, b = mem.query(xq), ckpt.query(xq)
    assert np.array_equal(a.labels, b.labels)
    assert np.array_equal(a.logprobs, b.logprobs)
    assert np.array_equal(a.log_predictive, b.log_predictive)


def test_sample_reuses_sweep_assignment(fitted):
    """engine.sample is the sweep's step (e) verbatim: counter-based
    Gumbel argmax through family.assign with gidx = query row index."""
    result, xq, _ = fitted
    engine = DPMMEngine(result.state, "gaussian",
                        ServeConfig(batch_sizes=(int(xq.shape[0]),)))
    drawn = engine.sample(xq, seed=3)
    fam = get_family("gaussian")
    gidx = jnp.arange(xq.shape[0], dtype=jnp.uint32)
    expect = fam.assign(jnp.asarray(xq), result.state.params,
                        engine.logweights, result.state.active, gidx,
                        prng.key_words(jax.random.key(3)))
    assert np.array_equal(drawn, np.asarray(expect))
    # pinned seed is reproducible
    assert np.array_equal(drawn, engine.sample(xq, seed=3))
    # on AMBIGUOUS queries the draw genuinely samples (well-separated
    # points essentially never flip). Find a point on the decision
    # boundary between the two biggest clusters by line search on the
    # engine's own log-probs, then repeat it 512x: i.i.d. counter-based
    # draws per row must produce both labels, and the unpinned engine
    # key advances between calls.
    means = np.asarray(fam.cluster_means(result.state.stats))
    n_k = np.where(np.asarray(result.state.active),
                   np.asarray(result.state.stats.n), 0.0)
    a, b = np.argsort(n_k)[-2:]
    ts = np.linspace(0.0, 1.0, 2001)[:, None].astype(np.float32)
    seg = (1 - ts) * means[a] + ts * means[b]
    lp = engine.predict_logprobs(seg)
    top2 = np.sort(lp, axis=1)[:, -2:]
    boundary = seg[np.argmin(top2[:, 1] - top2[:, 0])]
    assert (top2[:, 1] - top2[:, 0]).min() < 2.0, "no ambiguous point"
    ambiguous = np.tile(boundary, (512, 1)).astype(np.float32)
    s1, s2 = engine.sample(ambiguous), engine.sample(ambiguous)
    assert len(np.unique(s1)) >= 2
    assert not np.array_equal(s1, s2)


def test_engine_guardrails(fitted):
    result, xq, _ = fitted
    multi = jax.tree.map(lambda v: v[None], result.state)
    with pytest.raises(ValueError, match="single-chain"):
        DPMMEngine(multi, "gaussian")
    with pytest.raises(ValueError, match="batch_size"):
        DPMMEngine(result.state, "gaussian",
                   ServeConfig(batch_sizes=(0,)))
    engine = DPMMEngine(result.state, "gaussian", ServeConfig(batch_sizes=(64,)))
    with pytest.raises(ValueError, match="queries must be"):
        engine.predict(np.zeros((10, D + 1), np.float32))


def test_serve_cli_roundtrip(fitted, tmp_path, capsys):
    """launch/serve_dpmm drives the engine off a real checkpoint file."""
    import json

    from repro.launch import serve_dpmm

    result, xq, _ = fitted
    ckpt = str(tmp_path / "cli.npz")
    save_model(ckpt, result.state, "gaussian")
    qpath = str(tmp_path / "q.npy")
    np.save(qpath, xq[:200])
    out = str(tmp_path / "out.json")
    serve_dpmm.main(["--checkpoint", ckpt, "--queries", qpath,
                     "--batch-sizes", "128", "--result-path", out])
    with open(out) as f:
        payload = json.load(f)
    assert len(payload["labels"]) == 200
    assert payload["family"] == "gaussian"
    engine = DPMMEngine(result.state, "gaussian", ServeConfig(batch_sizes=(128,)))
    assert np.array_equal(np.asarray(payload["labels"], np.int32),
                          engine.predict(xq[:200]))
