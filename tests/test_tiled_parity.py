"""Tiled data plane (ISSUE 3): streaming points through host tiles is a
pure performance knob. Full ``DPMM.fit`` with ``HostTiledSource`` /
``cfg.tile_size`` must produce labels, history, and sufficient statistics
*bitwise* identical to the resident plane (params to float32-ULP — see
``_assert_bitwise``), for every registered family, at multiple tile
sizes, with and without data sharding.

Why bitwise is achievable: per-point draws are counter-based on the global
point index (kernels/prng.py) and suff-stats fold in fixed
STATS_BLOCK-aligned blocks in global point order (core/gibbs.py), so the
float addition sequence is identical no matter how points are tiled."""
import numpy as np
import pytest

import jax

from repro.configs import DPMMConfig
from repro.core.distributed import make_data_mesh, tile_plan
from repro.core.gibbs import STATS_BLOCK
from repro.core.sampler import DPMM
from repro.data.source import HostTiledSource, ResidentSource, as_source
from repro.data.synthetic import generate_gmm, generate_mnmm, generate_pmm

ALL = ("gaussian", "diag_gaussian", "multinomial", "poisson")
# two tile sizes, both exercising multiple tiles at N=3000 on one shard
TILES = (STATS_BLOCK, 2 * STATS_BLOCK)


def _data(name, n=3000, d=4, k=4):
    if name in ("gaussian", "diag_gaussian"):
        return generate_gmm(n, d, k, seed=0, sep=10.0)
    if name == "poisson":
        return generate_pmm(n, d, k, seed=0)
    return generate_mnmm(n, 16, k, seed=0)


def _cfg(name, **kw):
    return DPMMConfig(component=name, alpha=10.0, iters=18, k_max=16,
                      burnout=4, **kw)


def _assert_bitwise(a, b, what):
    """Labels, history, and sufficient statistics must match BITWISE:
    they are folds of per-point work whose addition order the tiled plane
    reproduces exactly. Model params are a deterministic function of
    (stats, key) — same draws from the same bits — but the O(K) posterior
    sampling (cholesky/gamma/normal transforms) is compiled into different
    executables on the two planes, and XLA's fusion/FMA choices are not
    bit-stable across program contexts; they are checked to float32 ULP
    tolerance instead."""
    assert np.array_equal(a.labels, b.labels), f"{what}: labels differ"
    for key in a.history:
        assert np.array_equal(a.history[key], b.history[key]), (
            f"{what}: history[{key}] differs")
    for name in ("stats", "substats"):
        for la, lb in zip(jax.tree_util.tree_leaves(getattr(a.state, name)),
                          jax.tree_util.tree_leaves(getattr(b.state, name))):
            assert np.array_equal(np.asarray(la), np.asarray(lb)), (
                f"{what}: {name} differ")
    for la, lb in zip(jax.tree_util.tree_leaves(a.state.params),
                      jax.tree_util.tree_leaves(b.state.params)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"{what}: params diverged "
                                           "beyond compilation-level ULPs")


@pytest.mark.parametrize("name", ALL)
def test_tiled_matches_resident_all_families(name):
    """Resident vs two tile sizes, single data shard: bitwise identical."""
    x, gt = _data(name)
    resident = DPMM(_cfg(name)).fit(x)
    assert resident.k >= 2            # a non-trivial chain: splits happened
    for tile in TILES:
        tiled = DPMM(_cfg(name, tile_size=tile)).fit(x)
        _assert_bitwise(resident, tiled, f"{name} tile={tile}")


@pytest.mark.parametrize("name", ("gaussian", "multinomial"))
def test_tiled_matches_resident_sharded(name):
    """Same with the data sharded across all devices: tiles stream per
    shard, the psum-folded stats and chains still match bitwise."""
    if jax.device_count() < 2:
        pytest.skip("needs >1 device (conftest sets 4 virtual CPU devices)")
    x, _ = _data(name)
    mesh = make_data_mesh(jax.device_count())
    resident = DPMM(_cfg(name), mesh=mesh).fit(x)
    for tile in TILES:
        tiled = DPMM(_cfg(name, tile_size=tile), mesh=mesh).fit(x)
        _assert_bitwise(resident, tiled, f"{name} sharded tile={tile}")
    # and across planes AND meshes at once: 1-dev resident == N-dev tiled
    # on labels/history (the chain). Stats/params — and the "score" trace,
    # a float function of the psum'd stats — may differ in final ULPs
    # across MESH sizes: a psum over 4 devices reduces in a different
    # order than over 1, which is the pre-existing cross-mesh contract;
    # the bitwise-everything guarantee is per-mesh across planes.
    single = DPMM(_cfg(name), mesh=make_data_mesh(1)).fit(x)
    tiled = DPMM(_cfg(name, tile_size=TILES[0]), mesh=mesh).fit(x)
    assert np.array_equal(single.labels, tiled.labels)
    for key in single.history:
        if key == "score":
            # f32 log-marginal sums amplify the psum-order ULPs through
            # gammaln/cholesky: ~2e-4 relative across mesh sizes
            np.testing.assert_allclose(single.history[key],
                                       tiled.history[key],
                                       rtol=1e-3, atol=1.0)
        else:
            assert np.array_equal(single.history[key], tiled.history[key])


def test_memmap_source_out_of_core(tmp_path):
    """HostTiledSource over an np.memmap: the array is never materialized
    in one piece, and the chain matches the resident fit bitwise."""
    x, gt = generate_gmm(4000, 3, 5, seed=1, sep=10.0)
    path = tmp_path / "points.npy"
    np.save(path, x.astype(np.float32))
    source = HostTiledSource.from_npy(str(path))
    assert isinstance(source._x, np.memmap)
    mesh = make_data_mesh(1)    # one shard so tiles are genuinely partial
    tiled = DPMM(_cfg("gaussian", tile_size=STATS_BLOCK),
                 mesh=mesh).fit(source)
    resident = DPMM(_cfg("gaussian"), mesh=mesh).fit(x)
    _assert_bitwise(resident, tiled, "memmap")
    assert tiled.nmi(gt) > 0.9
    assert tiled.device_bytes["mode"] == "tiled"
    # the out-of-core promise at test scale: the tiled fit's persistent
    # device footprint stays below the resident plane's
    assert (tiled.device_bytes["est_peak_bytes"]
            < resident.device_bytes["est_peak_bytes"])


def test_tiled_feature_sharded_identical():
    """Tiling composes with feature sharding (2x2 mesh): x tiles are
    sharded on both axes, stats gather along features — still bitwise."""
    from jax.sharding import Mesh

    if jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    x, _ = generate_mnmm(2000, 32, 5, seed=1)
    mesh22 = Mesh(np.array(jax.devices()[:4]).reshape(2, 2),
                  ("data", "model"))
    cfg = _cfg("multinomial", shard_features=True)
    resident = DPMM(cfg, mesh=mesh22).fit(x)
    tiled = DPMM(_cfg("multinomial", shard_features=True,
                      tile_size=STATS_BLOCK // 2), mesh=mesh22).fit(x)
    _assert_bitwise(resident, tiled, "feature-sharded tiled")


def test_tile_plan_alignment():
    """Tiles are STATS_BLOCK-aligned with one ragged shard tail; layout
    (n_local) is the resident padded layout regardless of tile size."""
    n_local, tiles = tile_plan(5000, 1, STATS_BLOCK)
    assert n_local == 5000
    assert tiles[:-1] == [(i * STATS_BLOCK, STATS_BLOCK)
                          for i in range(len(tiles) - 1)]
    off, length = tiles[-1]
    assert off % STATS_BLOCK == 0 and off + length == n_local
    # tile_size rounds UP to the alignment so block boundaries never move
    n_local2, tiles2 = tile_plan(5000, 1, STATS_BLOCK + 1)
    assert n_local2 == n_local
    assert tiles2[0] == (0, 2 * STATS_BLOCK)
    # sharded: every shard holds ceil(n / shards) rows, like shard_points
    n_local4, tiles4 = tile_plan(5000, 4, STATS_BLOCK)
    assert n_local4 == 1250
    assert tiles4 == [(0, STATS_BLOCK), (STATS_BLOCK, 1250 - STATS_BLOCK)]
    # tiles larger than the shard clip to a single whole-shard tile
    assert tile_plan(5000, 4, 10 * STATS_BLOCK)[1] == [(0, 1250)]


def test_resident_source_roundtrip():
    x = np.arange(12, dtype=np.float32).reshape(6, 2)
    src = as_source(x)
    assert isinstance(src, ResidentSource)
    assert src.resident() is not None
    # read_block pads rows past N with zeros (the sharded layout's tail)
    block = src.read_block(4, 8)
    assert block.shape == (4, 2)
    assert np.array_equal(block[:2], x[4:])
    assert (block[2:] == 0).all()
    assert np.allclose(src.column_mean(), x.mean(axis=0))
