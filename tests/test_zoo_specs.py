"""Param-tree / spec-tree congruence for every architecture: param_specs
must mirror init_params' structure exactly, and cache_specs the cache's —
the invariant the 512-chip lowering relies on."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models import decode, transformer
from repro.models.common import ShardingPolicy

POLICY = ShardingPolicy(batch_sharded=True, seq_shard=False)


def _strip(tree):
    return jax.tree.structure(
        jax.tree.map(lambda _: 0, tree,
                     is_leaf=lambda s: isinstance(s, P)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_match_structure_smoke(arch):
    cfg = smoke_config(arch)
    params = jax.eval_shape(
        lambda k: transformer.init_params(k, cfg, jnp.bfloat16),
        jax.random.key(0))
    specs = transformer.param_specs(cfg)
    assert _strip(params) == _strip(specs)
    # every spec's rank <= its param's rank
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
    assert len(flat_p) == len(flat_s)
    for p, s in zip(flat_p, flat_s):
        assert len(s) <= len(p.shape), (p.shape, s)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_match_structure_full(arch):
    """The FULL configs too (pure eval_shape — no allocation)."""
    cfg = get_config(arch)
    params = jax.eval_shape(
        lambda k: transformer.init_params(k, cfg, jnp.bfloat16),
        jax.random.key(0))
    specs = transformer.param_specs(cfg)
    assert _strip(params) == _strip(specs)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cache_specs_match_structure(arch):
    cfg = smoke_config(arch)
    cache = jax.eval_shape(
        lambda: decode.init_cache(cfg, 4, 64, jnp.bfloat16))
    specs = decode.cache_specs(cfg, POLICY)
    assert _strip(cache) == _strip(specs)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_param_counts(arch):
    """Full-config parameter totals are in the advertised ballpark."""
    import numpy as np
    cfg = get_config(arch)
    params = jax.eval_shape(
        lambda k: transformer.init_params(k, cfg, jnp.bfloat16),
        jax.random.key(0))
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    expected = {
        "granite-8b": (7e9, 10e9),
        "starcoder2-7b": (6e9, 9e9),
        "falcon-mamba-7b": (6e9, 9e9),
        "llama-3.2-vision-11b": (8e9, 12e9),   # backbone (no ViT stub)
        "qwen2-moe-a2.7b": (12e9, 17e9),       # total (A2.7b = active)
        "recurrentgemma-2b": (2e9, 3.5e9),
        "mistral-large-123b": (110e9, 130e9),
        "whisper-medium": (0.5e9, 1.2e9),
        "gemma2-9b": (8e9, 11e9),
        "deepseek-v2-lite-16b": (13e9, 18e9),
    }[arch]
    assert expected[0] < total < expected[1], f"{arch}: {total/1e9:.2f}B"
