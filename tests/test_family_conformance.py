"""Registry conformance: every ComponentFamily passes the same contract.

One parametrized suite over ``repro.core.family.available_families()`` so a
newly registered family is automatically held to the sampler's interface:
stats additivity, scipy-referenced log-likelihoods, marginal chain rule,
posterior-sample shapes/dtypes, Pallas fast-path agreement, and (for
``feature_shardable`` families) sliced-vs-replicated loglik equality.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
import scipy.stats

from repro.configs import DPMMConfig
from repro.core import family as family_mod
from repro.core.family import available_families, get_family

ALL = available_families()
SHARDABLE = [n for n in ALL if get_family(n).feature_shardable]

N, D, B = 40, 6, 3


def _data(name, n=N, d=D):
    rng = np.random.default_rng(0)
    if name in ("gaussian", "diag_gaussian"):
        return rng.normal(2.0, 1.5, size=(n, d)).astype(np.float32)
    if name == "poisson":
        return rng.poisson(4.0, size=(n, d)).astype(np.float32)
    return rng.multinomial(30, np.ones(d) / d, size=n).astype(np.float32)


def _prior(fam, x):
    return fam.build_prior(DPMMConfig(component=fam.name), x)


def _hard_resp(n, b, seed=0):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, b, size=n)
    return np.eye(b, dtype=np.float32)[labels]


def _params(fam, x, seed=0):
    resp = _hard_resp(x.shape[0], B)
    stats = fam.stats_from_points(jnp.asarray(x), jnp.asarray(resp))
    return fam.sample_posterior(jax.random.key(seed), _prior(fam, x), stats)


@pytest.mark.parametrize("name", ALL)
def test_registry_exposes_structs(name):
    fam = get_family(name)
    p_leaves = jax.tree_util.tree_leaves(fam.param_struct())
    s_leaves = jax.tree_util.tree_leaves(fam.stats_struct())
    assert p_leaves and s_leaves
    x = _data(name)
    stats = fam.stats_from_points(
        jnp.asarray(x), jnp.ones((x.shape[0], 1), jnp.float32))
    # stats_struct template must mirror the real stats pytree structure
    assert (jax.tree_util.tree_structure(fam.stats_struct())
            == jax.tree_util.tree_structure(stats))


@pytest.mark.parametrize("name", ALL)
def test_stats_roundtrip_under_add(name):
    """stats(x1) (+) stats(x2) == stats(x1 ++ x2) for add_stats."""
    fam = get_family(name)
    x = _data(name)
    half = x.shape[0] // 2
    ones = lambda v: jnp.ones((v.shape[0], 1), jnp.float32)
    s1 = fam.stats_from_points(jnp.asarray(x[:half]), ones(x[:half]))
    s2 = fam.stats_from_points(jnp.asarray(x[half:]), ones(x[half:]))
    s_all = fam.stats_from_points(jnp.asarray(x), ones(x))
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                                rtol=1e-5, atol=1e-4),
        fam.add_stats(s1, s2), s_all)


@pytest.mark.parametrize("name", ALL)
def test_loglik_matches_scipy_reference(name):
    """Family loglik == scipy logpdf/logpmf (up to the documented dropped
    label-independent constants)."""
    fam = get_family(name)
    x = _data(name, n=10)
    params = _params(fam, x)
    got = np.asarray(fam.loglik(jnp.asarray(x), params))
    assert got.shape == (x.shape[0], B)

    want = np.zeros_like(got)
    for b in range(B):
        if name == "gaussian":
            f = np.asarray(params.chol_prec[b])
            cov = np.linalg.inv(f @ f.T)
            want[:, b] = scipy.stats.multivariate_normal.logpdf(
                x, mean=np.asarray(params.mu[b]), cov=cov)
        elif name == "diag_gaussian":
            var = np.exp(-np.asarray(params.log_prec[b]))
            want[:, b] = scipy.stats.norm.logpdf(
                x, loc=np.asarray(params.mu[b]),
                scale=np.sqrt(var)).sum(axis=-1)
        elif name == "poisson":
            rate = np.exp(np.asarray(params.log_rate[b]))
            # we drop the label-independent log(x!) term; add it back
            want[:, b] = (scipy.stats.poisson.logpmf(x, rate).sum(axis=-1)
                          + scipy.special.gammaln(x + 1).sum(axis=-1))
        else:  # multinomial: coefficient dropped -> plain x @ log(theta)
            want[:, b] = x @ np.asarray(params.logtheta[b])
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)


@pytest.mark.parametrize("name", ALL)
def test_log_marginal_chain_rule(name):
    """m(C) at once == sequential posterior-predictive chain (the identity
    underlying the split/merge Hastings ratios)."""
    fam = get_family(name)
    x = _data(name, n=7)
    prior = _prior(fam, x)
    ones = lambda v: jnp.ones((v.shape[0], 1), jnp.float32)
    stats_of = lambda v: (fam.stats_from_points(jnp.asarray(v), ones(v))
                          if v.shape[0] else fam.empty_stats((1,), x.shape[1]))
    total = float(fam.log_marginal(prior, stats_of(x))[0])
    seq = sum(float((fam.log_marginal(prior, stats_of(x[:i + 1]))
                     - fam.log_marginal(prior, stats_of(x[:i])))[0])
              for i in range(x.shape[0]))
    assert np.isclose(total, seq, rtol=1e-4), (name, total, seq)


@pytest.mark.parametrize("name", ALL)
def test_sample_posterior_shapes_and_dtypes(name):
    """Cluster (K,) and sub-cluster (K, 2) batches both sample, float32."""
    fam = get_family(name)
    x = _data(name)
    prior = _prior(fam, x)
    for bshape in [(B,), (B, 2)]:
        resp = _hard_resp(x.shape[0], B)
        if len(bshape) == 2:
            bits = _hard_resp(x.shape[0], 2, seed=1)
            resp = resp[:, :, None] * bits[:, None, :]
        stats = fam.stats_from_points(jnp.asarray(x), jnp.asarray(resp))
        params = fam.sample_posterior(jax.random.key(0), prior, stats)
        for leaf in jax.tree_util.tree_leaves(params):
            assert leaf.shape[:len(bshape)] == bshape, (name, leaf.shape)
            assert leaf.dtype == jnp.float32, (name, leaf.dtype)
            assert bool(jnp.all(jnp.isfinite(leaf))), name
        ll = fam.loglik(jnp.asarray(x), params)
        assert ll.shape == (x.shape[0],) + bshape


@pytest.mark.parametrize("name", ALL)
def test_fast_path_matches_reference(name):
    """loglik(use_pallas=True) must agree with the jnp reference (families
    without a fast path fall through to the reference by construction)."""
    fam = get_family(name)
    x = _data(name, n=16)
    params = _params(fam, x)
    ref = np.asarray(fam.loglik(jnp.asarray(x), params, use_pallas=False))
    fast = np.asarray(fam.loglik(jnp.asarray(x), params, use_pallas=True))
    np.testing.assert_allclose(fast, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("name", SHARDABLE)
def test_feature_sliced_loglik_equals_replicated(name):
    """The feature-sharding contract, checked without a mesh: summing the
    loglik of slice_params'd feature blocks == full loglik (this is exactly
    what loglik_sharded's psum computes across shards)."""
    fam = get_family(name)
    x = _data(name)
    params = _params(fam, x)
    full = np.asarray(fam.loglik(jnp.asarray(x), params))
    dl = D // 2
    parts = sum(
        np.asarray(fam.loglik_ref(jnp.asarray(x[:, s:s + dl]),
                                  fam.slice_params(params, s, dl)))
        for s in (0, dl))
    np.testing.assert_allclose(parts, full, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("name", SHARDABLE)
def test_gather_feature_stats_fields_exist(name):
    fam = get_family(name)
    x = _data(name)
    stats = fam.stats_from_points(
        jnp.asarray(x), jnp.ones((x.shape[0], 1), jnp.float32))
    for f in fam.feature_stat_fields:
        assert getattr(stats, f).shape[-1] == D, (name, f)


def test_non_shardable_family_raises():
    fam = get_family("gaussian")
    with pytest.raises(ValueError, match="not feature-separable"):
        fam.loglik_sharded(jnp.zeros((4, 2)), None, "model")


def test_unknown_family_error_lists_registry():
    with pytest.raises(ValueError, match="gaussian"):
        get_family("nope")


def test_register_rejects_duplicates():
    with pytest.raises(ValueError, match="already registered"):
        family_mod.register_family(family_mod.GAUSSIAN)


def test_diag_gaussian_fits_blobs_end_to_end():
    """Acceptance: the new family reaches NMI >= 0.9 on synthetic blobs
    through the same DPMM.fit entry point as every other family."""
    from repro.core.sampler import DPMM
    from repro.data.synthetic import generate_gmm
    x, gt = generate_gmm(3000, 2, 5, seed=1, sep=12.0)
    cfg = DPMMConfig(component="diag_gaussian", alpha=10.0, iters=60,
                     k_max=32, burnout=5)
    r = DPMM(cfg).fit(x)
    assert r.nmi(gt) >= 0.9, (r.k, r.nmi(gt))


def test_fit_host_syncs_bounded_by_log_every():
    """The scan driver blocks the host at most ceil(iters/log_every) times:
    chunk boundaries are the only device_get sites, so iter_times_s holds
    at most that many *distinct* per-chunk timings."""
    from repro.core.sampler import DPMM
    from repro.data.synthetic import generate_gmm
    x, _ = generate_gmm(512, 2, 3, seed=0, sep=10.0)
    iters, log_every = 25, 10
    cfg = DPMMConfig(alpha=10.0, iters=iters, k_max=8, burnout=5,
                     log_every=log_every)
    r = DPMM(cfg).fit(x)
    assert len(r.iter_times_s) == iters
    assert len(r.history["k"]) == iters
    n_chunks = -(-iters // log_every)
    assert len(set(r.iter_times_s)) <= n_chunks
