"""End-to-end behaviour of the public API surface (paper §3.4 analogues)."""
import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import DPMMConfig, INPUT_SHAPES, smoke_config
from repro.core.sampler import DPMM
from repro.data.synthetic import generate_gmm


def test_fit_api_shapes_and_history():
    x, gt = generate_gmm(2048, 3, 4, seed=0, sep=8.0)
    r = DPMM(DPMMConfig(alpha=10., iters=20, k_max=16, burnout=5)).fit(x)
    assert r.labels.shape == (2048,)
    assert r.labels.dtype == np.int32
    assert len(r.iter_times_s) == 20
    assert r.history["k"].shape == (20,)
    assert 0.0 <= r.nmi(gt) <= 1.0
    assert -0.5 <= r.ari(gt) <= 1.0


def _run_cli(args, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.sample_dpmm"] + args,
        capture_output=True, text=True, env=env, cwd="/root/repo",
        timeout=timeout)


def test_cli_sample_dpmm(tmp_path):
    """The paper's §3.4.3 command-line entry point produces the documented
    result JSON (labels, weights, NMI, iteration times)."""
    out = tmp_path / "result.json"
    res = _run_cli(["--n", "2000", "--d", "2", "--k", "5", "--iters", "20",
                    "--result-path", str(out)])
    assert res.returncode == 0, res.stderr[-2000:]
    payload = json.loads(out.read_text())
    assert len(payload["labels"]) == 2000
    assert len(payload["weights"]) == payload["k"]
    assert len(payload["iter_times_s"]) == 20
    assert 0.0 <= payload["nmi"] <= 1.0


def test_params_path_override(tmp_path):
    params = tmp_path / "params.json"
    params.write_text(json.dumps({"alpha": 5.0, "iters": 5, "k_max": 8}))
    out = tmp_path / "result.json"
    res = _run_cli(["--n", "500", "--d", "2", "--k", "3",
                    "--params-path", str(params),
                    "--result-path", str(out)])
    assert res.returncode == 0, res.stderr[-2000:]
    payload = json.loads(out.read_text())
    assert payload["config"]["alpha"] == 5.0
    assert payload["config"]["iters"] == 5


def test_cli_tiled_out_of_core(tmp_path):
    """--tile-size streams a memory-mapped .npy through the tiled data
    plane; the result JSON records the device-memory accounting."""
    rng = np.random.default_rng(0)
    pts = np.concatenate([rng.normal(-8, 1, (1500, 2)),
                          rng.normal(8, 1, (1500, 2))]).astype(np.float32)
    data = tmp_path / "points.npy"
    np.save(data, pts)
    out = tmp_path / "result.json"
    res = _run_cli(["--data-path", str(data), "--tile-size", "1024",
                    "--iters", "12", "--result-path", str(out)])
    assert res.returncode == 0, res.stderr[-2000:]
    payload = json.loads(out.read_text())
    assert len(payload["labels"]) == 3000
    assert payload["config"]["tile_size"] == 1024
    assert payload["device_bytes"]["mode"] == "tiled"
    assert payload["device_bytes"]["est_peak_bytes"] > 0


def test_dpmm_config_validation():
    """Bad knobs fail loudly at construction, not deep inside a trace."""
    with pytest.raises(ValueError, match="tile_size"):
        DPMMConfig(tile_size=0)
    with pytest.raises(ValueError, match="tile_size"):
        DPMMConfig(tile_size=-5)
    with pytest.raises(ValueError, match="log_every"):
        DPMMConfig(log_every=0)
    with pytest.raises(ValueError, match="init_clusters"):
        DPMMConfig(init_clusters=0)
    with pytest.raises(ValueError, match="k_max"):
        DPMMConfig(init_clusters=9, k_max=8)
    with pytest.raises(ValueError, match="iters"):
        DPMMConfig(iters=-1)
    # the defaults and a valid tiled config construct fine
    DPMMConfig()
    DPMMConfig(tile_size=4096, log_every=1, init_clusters=3)


def test_serve_generator_runs():
    """Batched generation through the serving engine (decode path)."""
    import dataclasses

    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer
    from repro.serve.engine import Generator

    cfg = smoke_config("granite-8b")
    shape = dataclasses.replace(INPUT_SHAPES["decode_32k"], seq_len=64,
                                global_batch=2)
    mesh = make_host_mesh(data=1, model=1)
    params = transformer.init_params(jax.random.key(0), cfg)
    gen = Generator(mesh, cfg, shape, params, temperature=0.0)
    prompts = jax.random.randint(jax.random.key(1), (2, 5), 0,
                                 cfg.vocab_size)
    out = gen.generate(prompts, steps=8)
    assert out.shape == (2, 13)
    assert bool((out[:, :5] == prompts).all())
    # greedy decoding is deterministic
    out2 = gen.generate(prompts, steps=8)
    assert bool((out == out2).all())
