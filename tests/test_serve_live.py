"""Live serving (ISSUE 10): the ladder/step-table engine, hot model
swap, and online refinement.

 - Ragged dispatch parity: a mixed-size request stream through the AOT
   ladder is BITWISE the fixed-batch engine per routed segment — the
   ladder engine and a ``batch_sizes=(b,)`` engine literally run the
   same compiled executable.
 - Swap atomicity/staleness: queries concurrent with a swap see exactly
   the old model's bits or the new model's bits, never a blend.
 - Refinement: disabled it is chain-neutral (bit-for-bit the static
   engine); enabled it folds traffic through the real micro-batch sweep,
   publishes through the swap path, and the ``model_health`` gate keeps
   a poisoned batch out of production.
 - The ServeConfig surface: validated construction, deprecation-shim
   equivalence, CLI/API schema agreement.
"""
import dataclasses
import json
import threading

import numpy as np
import pytest

import jax

from repro.configs import DPMMConfig
from repro.core.checkpoint import resolve_model, save_checkpoint, save_model
from repro.core.sampler import DPMM
from repro.data.synthetic import generate_gmm
from repro.serve import (DPMMEngine, InvalidQueryError, PublishRejected,
                         ServeConfig, ServeResult)

N, D, K = 1800, 3, 3


@pytest.fixture(scope="module")
def models():
    """Two different fitted models (A, B) over one mixture + held-out
    query rows."""
    x, _ = generate_gmm(N + 600, D, K, seed=0, sep=9.0)
    cfg = DPMMConfig(alpha=10.0, iters=8, k_max=16, burnout=3)
    a = DPMM(cfg).fit(x[:N]).state
    b = DPMM(dataclasses.replace(cfg, seed=1)).fit(x[:N]).state
    return a, b, np.asarray(x[N:], np.float32)


def _same_bits(r1, r2):
    return (np.array_equal(r1.labels, r2.labels)
            and np.array_equal(r1.logprobs, r2.logprobs)
            and np.array_equal(r1.log_predictive, r2.log_predictive))


# ---------------------------------------------------------------------------
# ragged dispatch through the AOT ladder
# ---------------------------------------------------------------------------
def test_ragged_dispatch_routes_to_smallest_covering_step(models):
    a, _, _ = models
    eng = DPMMEngine(a, "gaussian", ServeConfig(batch_sizes=(64, 256)))
    # one dispatch at the smallest covering size for requests <= max
    assert eng.plan_route(1) == [(0, 1, 64)]
    assert eng.plan_route(64) == [(0, 64, 64)]
    assert eng.plan_route(65) == [(0, 65, 256)]
    assert eng.plan_route(256) == [(0, 256, 256)]
    # oversize requests chunk at the largest step, covering tail
    assert eng.plan_route(600) == [(0, 256, 256), (256, 256, 256),
                                   (512, 88, 256)]
    assert eng.plan_route(0) == []


def test_mixed_size_stream_is_bitwise_the_fixed_batch_engine(models):
    a, _, xq = models
    ladder = DPMMEngine(a, "gaussian", ServeConfig(batch_sizes=(64, 256)))
    fixed = {b: DPMMEngine(a, "gaussian", ServeConfig(batch_sizes=(b,)))
             for b in (64, 256)}
    for n in (1, 63, 64, 65, 200, 256, 300, 600):
        q = xq[:n]
        res = ladder.query(q)
        segs = ladder.plan_route(n)
        assert sum(u for _, u, _ in segs) == n
        for s, u, b in segs:
            ref = fixed[b].query(q[s:s + u])
            assert np.array_equal(res.labels[s:s + u], ref.labels)
            assert np.array_equal(res.logprobs[s:s + u], ref.logprobs)
            assert np.array_equal(res.log_predictive[s:s + u],
                                  ref.log_predictive)
        # sampled draws are counter-based on the request row index, so
        # they too are invariant to the ladder decomposition
        assert np.array_equal(ladder.sample(q, seed=7),
                              fixed[256].sample(q, seed=7))
    empty = ladder.query(xq[:0])
    assert empty.n == 0 and empty.logprobs.shape == (0, ladder.k_max)


# ---------------------------------------------------------------------------
# hot model swap
# ---------------------------------------------------------------------------
def test_swap_staleness_is_bitwise(models, tmp_path):
    a, b, xq = models
    pa = save_model(str(tmp_path / "a"), a, "gaussian")
    pb = save_model(str(tmp_path / "b"), b, "gaussian")
    cfg = ServeConfig(batch_sizes=(256,))
    eng = DPMMEngine.from_checkpoint(pa, cfg)
    refA = DPMMEngine(a, "gaussian", cfg)
    refB = DPMMEngine(b, "gaussian", cfg)
    q = xq[:300]
    pre = eng.query(q)
    assert _same_bits(pre, refA.query(q))
    epoch = eng.swap(pb)
    post = eng.query(q)
    assert _same_bits(post, refB.query(q))
    assert post.model_epoch == epoch == pre.model_epoch + 1
    assert not np.array_equal(pre.logprobs, post.logprobs)
    assert [e["kind"] for e in eng.events] == ["model_swap"]


def test_concurrent_queries_see_old_or_new_never_a_blend(models, tmp_path):
    a, b, xq = models
    pa = save_model(str(tmp_path / "a"), a, "gaussian")
    pb = save_model(str(tmp_path / "b"), b, "gaussian")
    cfg = ServeConfig(batch_sizes=(64,))
    eng = DPMMEngine.from_checkpoint(pa, cfg)
    q = xq[:200]     # 4 ladder dispatches per request: a blend would show
    A = DPMMEngine(a, "gaussian", cfg).query(q)
    B = DPMMEngine(b, "gaussian", cfg).query(q)
    results, errors, stop = [], [], threading.Event()

    def hammer():
        try:
            while not stop.is_set():
                results.append(eng.query(q))
        except Exception as e:        # pragma: no cover - fail loudly
            errors.append(e)

    threads = [threading.Thread(target=hammer) for _ in range(2)]
    for t in threads:
        t.start()
    eng.swap(pb)
    # let some post-swap queries land before stopping
    deadline = 200
    while len(results) < 6 and not errors and deadline > 0:
        threading.Event().wait(0.05)
        deadline -= 1
    stop.set()
    for t in threads:
        t.join()
    assert not errors, errors
    results.append(eng.query(q))
    whole = [("A" if _same_bits(r, A) else
              "B" if _same_bits(r, B) else "blend") for r in results]
    assert "blend" not in whole, whole
    assert whole[-1] == "B"


def test_swap_defaults_to_checkpoint_prefix_rotation(models, tmp_path):
    a, b, xq = models
    pref = str(tmp_path / "rot")
    save_checkpoint(pref, a, "gaussian", it=4)
    cfg = ServeConfig(batch_sizes=(256,))
    eng = DPMMEngine.from_checkpoint(pref, cfg)
    assert eng.cfg.checkpoint_prefix == pref
    q = xq[:100]
    assert _same_bits(eng.query(q), DPMMEngine(a, "gaussian", cfg).query(q))
    # the fit keeps checkpointing; a bare swap() picks up the newest
    save_checkpoint(pref, b, "gaussian", it=8)
    eng.swap()
    assert _same_bits(eng.query(q), DPMMEngine(b, "gaussian", cfg).query(q))
    # resolve_model agrees on what was served
    _, _, resolved, it = resolve_model(pref)
    assert it == 8 and resolved.endswith("-00000008.npz")
    with pytest.raises(ValueError, match="checkpoint_prefix"):
        DPMMEngine(a, "gaussian", cfg).swap()


def test_swap_health_gate_rejects_poisoned_checkpoint(models, tmp_path):
    a, _, xq = models
    bad = a._replace(logweights=a.logweights.at[0].set(np.nan))
    pbad = save_model(str(tmp_path / "bad"), bad, "gaussian")
    eng = DPMMEngine(a, "gaussian", ServeConfig(batch_sizes=(64,)))
    before = eng.query(xq[:64])
    with pytest.raises(PublishRejected):
        eng.swap(pbad)
    after = eng.query(xq[:64])
    assert _same_bits(before, after) and after.model_epoch == 0
    assert eng.events[-1]["kind"] == "model_swap_rejected"
    # guardrails off: the operator owns the risk
    lax = DPMMEngine(a, "gaussian",
                     ServeConfig(batch_sizes=(64,), guardrails=False))
    assert lax.swap(pbad) == 1


# ---------------------------------------------------------------------------
# online refinement
# ---------------------------------------------------------------------------
def test_refinement_disabled_is_chain_neutral(models):
    a, _, xq = models
    plain = DPMMEngine(a, "gaussian", ServeConfig(batch_sizes=(256,)))
    armed = DPMMEngine(a, "gaussian",
                       ServeConfig(batch_sizes=(256,), refine=True))
    q = xq[:400]
    # an armed-but-never-refined engine serves bit-for-bit the static one
    assert _same_bits(plain.query(q), armed.query(q))
    assert _same_bits(plain.query(q), armed.query(q))   # and stays put
    with pytest.raises(ValueError, match="refine=True"):
        plain.refine()


def test_refinement_publishes_through_the_swap_path(models):
    a, _, xq = models
    cfg = ServeConfig(batch_sizes=(256,), refine=True, refine_batch=256,
                      refine_publish_every=1)
    eng = DPMMEngine(a, "gaussian", cfg)
    r0 = eng.query(xq[:512])       # also buffers the traffic
    out = eng.refine()
    assert out["sweeps"] == 2 and out["rows"] == 512
    assert out["published"] == 2 and out["rejected"] == 0
    r1 = eng.query(xq[:512])
    assert r1.model_epoch == r0.model_epoch + 2
    assert not np.array_equal(r0.logprobs, r1.logprobs)
    # the refined model is still a proper mixture over the active set
    np.testing.assert_allclose(np.exp(r1.logprobs).sum(axis=1), 1.0,
                               rtol=1e-4)
    assert set(np.unique(r1.labels)).issubset(set(eng.slots.tolist()))
    # r1 re-buffered its own traffic; after draining it the buffer is
    # empty and a further refine is a no-op
    assert eng.refine()["sweeps"] == 2
    assert eng.refine()["sweeps"] == 0


def test_refinement_health_gate_blocks_poisoned_traffic(models):
    a, _, xq = models
    cfg = ServeConfig(batch_sizes=(64,), refine=True, refine_batch=64)
    eng = DPMMEngine(a, "gaussian", cfg)
    before = eng.query(xq[:64])
    # 1e30^2 overflows the f32 sxx stat -> model_health fails the sweep
    out = eng.refine(x=np.full((64, D), 1e30, np.float32))
    assert out == {"sweeps": 0, "rows": 0, "rejected": 1, "published": 0,
                   "epoch": 0}
    assert eng.events[-1]["kind"] == "refine_rejected"
    after = eng.query(xq[:64])
    assert _same_bits(before, after) and after.model_epoch == 0
    # and the engine still refines cleanly afterwards
    assert eng.refine(x=xq[:64])["published"] == 1


def test_refine_buffer_is_bounded(models):
    a, _, xq = models
    cfg = ServeConfig(batch_sizes=(64,), refine=True, refine_batch=64,
                      refine_buffer=128)
    eng = DPMMEngine(a, "gaussian", cfg)
    for i in range(8):
        eng.query(xq[i * 64:(i + 1) * 64])
    out = eng.refine(publish=False)
    assert out["rows"] <= 128 and out["sweeps"] <= 2


# ---------------------------------------------------------------------------
# the ServeConfig surface
# ---------------------------------------------------------------------------
def test_serve_config_validates_at_construction():
    assert ServeConfig(batch_sizes=[64, 256]).batch_sizes == (64, 256)
    with pytest.raises(ValueError, match="ascending"):
        ServeConfig(batch_sizes=(256, 64))
    with pytest.raises(ValueError, match="ascending"):
        ServeConfig(batch_sizes=(64, 64))
    with pytest.raises(ValueError, match="at least one"):
        ServeConfig(batch_sizes=())
    with pytest.raises(ValueError, match="positive"):
        ServeConfig(batch_sizes=(0,))
    with pytest.raises(ValueError, match="positive"):
        ServeConfig(batch_sizes=(True, 4))
    with pytest.raises(ValueError, match="refine_decay"):
        ServeConfig(refine_decay=1.0)
    with pytest.raises(ValueError, match="refine_batch"):
        ServeConfig(refine_batch=0)
    with pytest.raises(ValueError, match="refine_buffer"):
        ServeConfig(refine_batch=64, refine_buffer=32)
    with pytest.raises(ValueError, match="checkpoint_prefix"):
        ServeConfig(checkpoint_prefix=7)


def test_deprecation_shims_map_onto_serve_config(models, tmp_path):
    a, _, xq = models
    q = xq[:100]
    new = DPMMEngine(a, "gaussian",
                     ServeConfig(batch_sizes=(128,), seed=0))
    with pytest.warns(DeprecationWarning, match="batch_size"):
        old = DPMMEngine(a, "gaussian", batch_size=128, seed=0)
    assert old.cfg == new.cfg
    assert _same_bits(old.query(q), new.query(q))
    assert np.array_equal(old.sample(q, seed=3), new.sample(q, seed=3))
    path = save_model(str(tmp_path / "m"), a, "gaussian")
    with pytest.warns(DeprecationWarning):
        oldc = DPMMEngine.from_checkpoint(path, batch_size=128)
    assert _same_bits(oldc.query(q), new.query(q))
    with pytest.raises(TypeError, match="both"):
        DPMMEngine(a, "gaussian", ServeConfig(), batch_size=128)
    with pytest.raises(TypeError, match="unexpected"):
        DPMMEngine(a, "gaussian", nonsense=1)


def test_cli_and_api_agree_on_the_result_schema(models, tmp_path):
    from repro.launch import serve_dpmm

    a, _, xq = models
    ckpt = save_model(str(tmp_path / "cli"), a, "gaussian")
    np.save(str(tmp_path / "q.npy"), xq[:150])
    out = str(tmp_path / "out.json")
    serve_dpmm.main(["--checkpoint", ckpt, "--queries",
                     str(tmp_path / "q.npy"), "--batch-sizes", "64,256",
                     "--sample", "--seed", "5", "--result-path", out])
    with open(out) as f:
        payload = json.load(f)
    eng = DPMMEngine(a, "gaussian",
                     ServeConfig(batch_sizes=(64, 256), seed=5))
    res = eng.query(xq[:150], sample=True, seed=5)
    assert isinstance(res, ServeResult)
    api = json.loads(json.dumps(res.to_json()))   # same wire round-trip
    assert payload == api
    assert sorted(payload) == ["cluster_counts", "family", "k_max",
                               "labels", "log_predictive", "model_epoch",
                               "n", "sampled_labels"]


def test_multi_chain_state_still_rejected(models):
    a, _, _ = models
    multi = jax.tree.map(lambda v: v[None], a)
    with pytest.raises(ValueError, match="single-chain"):
        DPMMEngine(multi, "gaussian", ServeConfig())
