"""One-read fused sweep (ISSUE 4): steps (e) + (f) + the suff-stat fold
run in a single pass over x — and the fusion is a pure performance change.

 - tile-level parity: ``gibbs.sweep_tile`` fused vs the pre-PR three-pass
   body, BITWISE (labels, sublabels, folded substats) for all 4 families
   on aligned, ragged and sub-block tile lengths, on both the jnp
   reference path and the Pallas megakernel (interpret) path;
 - full-fit parity: fused chains (labels, history, stats, substats)
   bitwise identical to three-pass chains on the resident, tiled,
   data-sharded and feature-sharded planes, at two tile sizes;
 - the structural one-read guarantee: the reference sweep's jaxpr
   consumes x in exactly ONE (blocked) scan, and the Pallas sweep's jaxpr
   contains exactly ONE pallas_call — nothing re-reads x;
 - the fused split/merge apply matches its three-pass form bitwise.
"""
import functools

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import DPMMConfig
from repro.core import gibbs, splitmerge
from repro.core.family import available_families, get_family
from repro.core.gibbs import STATS_BLOCK
from repro.core.sampler import DPMM, _init_local, _move_key
from repro.data.synthetic import generate_gmm, generate_mnmm, generate_pmm

ALL = available_families()
SHARDABLE = [n for n in ALL if get_family(n).feature_shardable]
# aligned (2 blocks), ragged (2 blocks + tail), sub-block (tail only)
TILE_NS = (2 * STATS_BLOCK, 2 * STATS_BLOCK + 452, 700)


def _data(name, n, d=5, k=4):
    if name in ("gaussian", "diag_gaussian"):
        return generate_gmm(n, d, k, seed=0, sep=8.0)[0]
    if name == "poisson":
        return generate_pmm(n, d, k, seed=0)[0]
    return generate_mnmm(n, max(d, k), k, seed=0)[0]


def _state(name, n, d=5, k_max=12):
    fam = get_family(name)
    x = jnp.asarray(_data(name, n, d))
    valid = jnp.ones((n,), jnp.float32)
    cfg = DPMMConfig(component=name, init_clusters=4, k_max=k_max)
    prior = fam.build_prior(cfg, x)
    model, point = _init_local(jax.random.key(0), x, valid, prior=prior,
                               family=fam, cfg=cfg, axes=(), k_max=k_max)
    return fam, x, model, point, prior


def _run_tile(fam, x, model, point, fused, use_pallas):
    k_max = model.active.shape[0]
    gidx = jnp.arange(x.shape[0], dtype=jnp.uint32)
    acc = gibbs.empty_substats(fam, k_max, x.shape[1])
    fn = jax.jit(lambda m, xx, p, g, a: gibbs.sweep_tile(
        m, xx, p, g, a, fam, fused=fused, use_pallas=use_pallas))
    return jax.tree.map(np.asarray, fn(model, x, point, gidx, acc))


def _assert_tree_equal(a, b, what):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert np.array_equal(np.asarray(la), np.asarray(lb)), (
            f"{what}: stat leaves differ")


# ---------------------------------------------------------------------------
# tile-level: fused == three-pass, bitwise, per path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", TILE_NS)
@pytest.mark.parametrize("name", ALL)
def test_sweep_tile_fused_matches_three_pass(name, n):
    fam, x, model, point, _ = _state(name, n)
    p3, a3 = _run_tile(fam, x, model, point, fused=False, use_pallas=False)
    pf, af = _run_tile(fam, x, model, point, fused=True, use_pallas=False)
    np.testing.assert_array_equal(pf.labels, p3.labels)
    np.testing.assert_array_equal(pf.sublabels, p3.sublabels)
    _assert_tree_equal(af, a3, f"{name} n={n} reference")


@pytest.mark.parametrize("n", TILE_NS)
@pytest.mark.parametrize("name", ALL)
def test_sweep_tile_fused_pallas_matches_three_pass_pallas(name, n):
    """The megakernel (interpret mode) reproduces the three-pass Pallas
    chain bitwise — assignment, sub-assignment AND the stat fold."""
    fam, x, model, point, _ = _state(name, n)
    p3, a3 = _run_tile(fam, x, model, point, fused=False, use_pallas=True)
    pf, af = _run_tile(fam, x, model, point, fused=True, use_pallas=True)
    np.testing.assert_array_equal(pf.labels, p3.labels)
    np.testing.assert_array_equal(pf.sublabels, p3.sublabels)
    _assert_tree_equal(af, a3, f"{name} n={n} pallas")


@pytest.mark.parametrize("name", ALL)
def test_sweep_megakernel_labels_match_reference(name):
    """Cross-path: megakernel labels/sublabels equal the jnp reference's
    (same counter-based noise); stats agree to float tolerance (the two
    paths associate the per-block sums differently — pre-existing)."""
    fam, x, model, point, _ = _state(name, 2 * STATS_BLOCK + 452)
    pr, ar = _run_tile(fam, x, model, point, fused=True, use_pallas=False)
    pp, ap = _run_tile(fam, x, model, point, fused=True, use_pallas=True)
    np.testing.assert_array_equal(pp.labels, pr.labels)
    np.testing.assert_array_equal(pp.sublabels, pr.sublabels)
    for la, lb in zip(jax.tree_util.tree_leaves(ar),
                      jax.tree_util.tree_leaves(ap)):
        np.testing.assert_allclose(la, lb, rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# fused split/merge apply == three-pass apply
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", TILE_NS)
@pytest.mark.parametrize("name", ("gaussian", "multinomial"))
def test_split_merge_tile_fused_matches_three_pass(name, n):
    fam, x, model, point, prior = _state(name, n)
    k_max = model.active.shape[0]
    plan = splitmerge.plan_split_merge(_move_key(model), model, prior, fam,
                                       10.0, 10)

    def run(fused):
        acc = gibbs.empty_substats(fam, k_max, x.shape[1])
        fn = jax.jit(lambda pl_, xx, p, a: splitmerge.split_merge_tile(
            pl_, xx, p, a, fam, fused=fused))
        return jax.tree.map(np.asarray, fn(plan, x, point, acc))

    p3, a3 = run(False)
    pf, af = run(True)
    np.testing.assert_array_equal(pf.labels, p3.labels)
    np.testing.assert_array_equal(pf.sublabels, p3.sublabels)
    _assert_tree_equal(af, a3, f"{name} n={n} split_merge")


# ---------------------------------------------------------------------------
# full-fit parity across planes: fused chains == three-pass chains
# ---------------------------------------------------------------------------
def _cfg(name, **kw):
    return DPMMConfig(component=name, alpha=10.0, iters=14, k_max=16,
                      burnout=4, **kw)


def _fit_data(name):
    if name in ("gaussian", "diag_gaussian"):
        return generate_gmm(2 * STATS_BLOCK + 600, 4, 4, seed=0, sep=10.0)
    if name == "poisson":
        return generate_pmm(2 * STATS_BLOCK + 600, 4, 4, seed=0)
    return generate_mnmm(2 * STATS_BLOCK + 600, 16, 4, seed=0)


def _assert_fit_bitwise(a, b, what):
    assert np.array_equal(a.labels, b.labels), f"{what}: labels differ"
    for key in a.history:
        assert np.array_equal(a.history[key], b.history[key]), (
            f"{what}: history[{key}] differs")
    for stat in ("stats", "substats"):
        _assert_tree_equal(getattr(a.state, stat), getattr(b.state, stat),
                           f"{what}: {stat}")


@pytest.mark.parametrize("name", ALL)
def test_fit_fused_matches_three_pass_chains(name):
    """Run the three-pass fit inside a local patch, the fused fits
    outside, and require bitwise-identical chains — resident plane plus
    the tiled plane at two tile sizes."""
    x, _ = _fit_data(name)
    fused = DPMM(_cfg(name)).fit(x)
    assert fused.k >= 2                     # a non-trivial chain
    orig_sweep, orig_sm = gibbs.sweep_tile, splitmerge.split_merge_tile
    gibbs.sweep_tile = functools.partial(orig_sweep, fused=False)
    splitmerge.split_merge_tile = functools.partial(orig_sm, fused=False)
    try:
        three = DPMM(_cfg(name)).fit(x)
    finally:
        gibbs.sweep_tile, splitmerge.split_merge_tile = orig_sweep, orig_sm
    _assert_fit_bitwise(fused, three, f"{name} resident")
    for tile in (STATS_BLOCK, 2 * STATS_BLOCK):
        fused_tiled = DPMM(_cfg(name, tile_size=tile)).fit(x)
        _assert_fit_bitwise(fused_tiled, three, f"{name} tiled={tile}")


def test_fit_fused_matches_three_pass_sharded():
    """Data-sharded plane (all devices): fused == three-pass bitwise."""
    from repro.core.distributed import make_data_mesh
    if jax.device_count() < 2:
        pytest.skip("needs >1 device (conftest sets 4 virtual devices)")
    x, _ = _fit_data("gaussian")
    mesh = make_data_mesh(jax.device_count())
    fused = DPMM(_cfg("gaussian"), mesh=mesh).fit(x)
    orig_sweep, orig_sm = gibbs.sweep_tile, splitmerge.split_merge_tile
    gibbs.sweep_tile = functools.partial(orig_sweep, fused=False)
    splitmerge.split_merge_tile = functools.partial(orig_sm, fused=False)
    try:
        three = DPMM(_cfg("gaussian"), mesh=mesh).fit(x)
    finally:
        gibbs.sweep_tile, splitmerge.split_merge_tile = orig_sweep, orig_sm
    _assert_fit_bitwise(fused, three, "gaussian sharded")


def test_fit_fused_matches_three_pass_feature_sharded():
    """Feature-sharded plane (2x2 mesh): the blocked one-read pass psums
    its per-block likelihood partials; chains still match bitwise."""
    from jax.sharding import Mesh
    if jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    x, _ = generate_mnmm(2000, 32, 5, seed=1)
    mesh22 = Mesh(np.array(jax.devices()[:4]).reshape(2, 2),
                  ("data", "model"))
    cfg = _cfg("multinomial", shard_features=True)
    fused = DPMM(cfg, mesh=mesh22).fit(x)
    orig_sweep, orig_sm = gibbs.sweep_tile, splitmerge.split_merge_tile
    gibbs.sweep_tile = functools.partial(orig_sweep, fused=False)
    splitmerge.split_merge_tile = functools.partial(orig_sm, fused=False)
    try:
        three = DPMM(cfg, mesh=mesh22).fit(x)
    finally:
        gibbs.sweep_tile, splitmerge.split_merge_tile = orig_sweep, orig_sm
    _assert_fit_bitwise(fused, three, "multinomial feature-sharded")


def test_fit_fused_pallas_matches_three_pass_pallas():
    """Full fits through the megakernel (interpret) reproduce the
    three-pass Pallas chain bitwise."""
    x, _ = generate_gmm(STATS_BLOCK + 600, 3, 4, seed=0, sep=10.0)
    cfg = _cfg("gaussian", use_pallas=True)
    fused = DPMM(cfg).fit(x)
    orig_sweep, orig_sm = gibbs.sweep_tile, splitmerge.split_merge_tile
    gibbs.sweep_tile = functools.partial(orig_sweep, fused=False)
    splitmerge.split_merge_tile = functools.partial(orig_sm, fused=False)
    try:
        three = DPMM(cfg).fit(x)
    finally:
        gibbs.sweep_tile, splitmerge.split_merge_tile = orig_sweep, orig_sm
    _assert_fit_bitwise(fused, three, "gaussian pallas")


# ---------------------------------------------------------------------------
# the structural one-read guarantee (jaxpr/HLO inspection)
# ---------------------------------------------------------------------------
def _sweep_jaxpr(name, n, use_pallas):
    fam, x, model, point, prior = _state(name, n)
    jaxpr = jax.make_jaxpr(
        lambda m, p, xx: gibbs.sweep(m, p, xx, prior, fam, 10.0, (),
                                     use_pallas=use_pallas))(model, point, x)
    x_var = jaxpr.jaxpr.invars[-1]
    return jaxpr.jaxpr, x_var


def _consumers(jaxpr, var):
    return [eqn for eqn in jaxpr.eqns if any(v is var for v in eqn.invars)]


@pytest.mark.parametrize("name", ALL)
def test_reference_sweep_reads_x_once(name):
    """The fused reference sweep consumes x in exactly one place: the
    block reshape feeding a single scan (e + f + stat fold per block) —
    the one-read structure, provable from the jaxpr."""
    jaxpr, x_var = _sweep_jaxpr(name, 2 * STATS_BLOCK, use_pallas=False)
    direct = _consumers(jaxpr, x_var)
    assert len(direct) == 1, (
        f"x is consumed by {len(direct)} top-level eqns "
        f"({[e.primitive.name for e in direct]}); expected the single "
        "block reshape of the one-read scan")
    assert direct[0].primitive.name == "reshape"
    blocked = direct[0].outvars[0]
    scans = _consumers(jaxpr, blocked)
    assert len(scans) == 1 and scans[0].primitive.name == "scan", (
        f"blocked x feeds {[e.primitive.name for e in scans]}; expected "
        "exactly one scan")


def _count_pallas_calls(jaxpr):
    count = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            count += 1
        for p in eqn.params.values():
            count += _count_pallas_param(p)
    return count


def _count_pallas_param(p):
    if isinstance(p, jax.core.ClosedJaxpr):
        return _count_pallas_calls(p.jaxpr)
    if isinstance(p, jax.core.Jaxpr):
        return _count_pallas_calls(p)
    if isinstance(p, (list, tuple)):
        return sum(_count_pallas_param(q) for q in p)
    return 0


@pytest.mark.parametrize("name", ALL)
def test_pallas_sweep_is_one_megakernel(name):
    """With use_pallas the whole sweep is ONE pallas_call (the megakernel
    carries e + f + the stat fold); the three-pass body needs several."""
    jaxpr, x_var = _sweep_jaxpr(name, 2 * STATS_BLOCK, use_pallas=True)
    assert _count_pallas_calls(jaxpr) == 1
    if name != "diag_gaussian":     # diag packs [x, x^2] before the call
        direct = _consumers(jaxpr, x_var)
        assert len(direct) == 1, (
            f"x is consumed by {len(direct)} eqns "
            f"({[e.primitive.name for e in direct]}); expected only the "
            "megakernel call")
        # the single consumer is the (jit-wrapped) megakernel call itself
        assert direct[0].primitive.name in ("pallas_call", "pjit")
        assert _count_pallas_param(list(direct[0].params.values())) == 1


def test_three_pass_sweep_reads_x_many_times():
    """The contrast that makes the one-read claim meaningful: the pre-PR
    three-pass body consumes x from more than one top-level eqn."""
    fam, x, model, point, prior = _state("gaussian", 2 * STATS_BLOCK)
    gidx = jnp.arange(x.shape[0], dtype=jnp.uint32)
    acc = gibbs.empty_substats(fam, model.active.shape[0], x.shape[1])
    jaxpr = jax.make_jaxpr(
        lambda m, xx, p, g, a: gibbs.sweep_tile(
            m, xx, p, g, a, fam, fused=False))(model, x, point, gidx, acc)
    x_vars = [v for v in jaxpr.jaxpr.invars
              if getattr(v.aval, "shape", None) == x.shape]
    assert len(x_vars) == 1
    assert len(_consumers(jaxpr.jaxpr, x_vars[0])) >= 3
