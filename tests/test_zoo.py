"""Per-architecture smoke tests (deliverable f): each assigned arch's
REDUCED variant (2-layer-scale, d_model<=512, <=4 experts) runs one forward
and one train step on CPU with correct shapes and no NaNs; decode matches
the full-sequence forward."""
import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, TrainConfig, get_config, smoke_config
from repro.models import decode, transformer
from repro.models.common import ShardingPolicy
from repro.train import init_train_state, train_step

POLICY = ShardingPolicy(batch_sharded=False, seq_shard=False)


def _inputs(cfg, b=2, s=16, seed=1):
    toks = jax.random.randint(jax.random.key(seed), (b, s), 0,
                              cfg.vocab_size)
    memory = None
    frames = None
    if cfg.vision_tokens:
        memory = jax.random.normal(
            jax.random.key(2), (b, cfg.vision_tokens, cfg.d_model)) * 0.02
    if cfg.encoder_layers:
        frames = jax.random.normal(
            jax.random.key(2), (b, cfg.encoder_seq, cfg.d_model)) * 0.02
    return toks, memory, frames


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = smoke_config(arch)
    params = transformer.init_params(jax.random.key(0), cfg)
    toks, memory, frames = _inputs(cfg)
    if frames is not None:
        memory = transformer.encode(params, frames, cfg, POLICY)
    logits, aux = transformer.forward(params, toks, cfg, POLICY,
                                      memory=memory)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = smoke_config(arch)
    tcfg = TrainConfig(lr=1e-3, warmup_steps=2, total_steps=10,
                       loss_chunk=16)
    state = init_train_state(jax.random.key(0), cfg)
    toks, memory, frames = _inputs(cfg, s=32)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1)}
    if memory is not None:
        batch["memory"] = memory
    if frames is not None:
        batch["frames"] = frames
    step = jax.jit(functools.partial(train_step, cfg=cfg, tcfg=tcfg,
                                     policy=POLICY))
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(new_state.params),
                                jax.tree.leaves(state.params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_matches_forward(arch):
    """Teacher-forced step-by-step decode == full forward (<=1e-4 rel).
    MoE archs use a high capacity factor (capacity dropping is batch-
    dependent by design)."""
    cfg = smoke_config(arch)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    s = 10
    params = transformer.init_params(jax.random.key(0), cfg)
    toks, memory, frames = _inputs(cfg, s=s)
    if frames is not None:
        memory = transformer.encode(params, frames, cfg, POLICY)
    full, _ = transformer.forward(params, toks, cfg, POLICY, memory=memory,
                                  remat=False)
    cache = decode.init_cache(cfg, 2, s, jnp.float32)
    if memory is not None:
        cache = decode.prefill_cross(params, cache, memory, cfg)
    outs = []
    for t in range(s):
        lg, cache = decode.decode_step(params, cache, toks[:, t:t + 1], cfg,
                                       POLICY, cache_len=s)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    rel = float(jnp.max(jnp.abs(dec - full))) / (
        float(jnp.max(jnp.abs(full))) + 1e-9)
    assert rel < 1e-4, rel


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_validates(arch):
    cfg = get_config(arch)
    cfg.validate()
    assert len(cfg.layer_kinds) == cfg.num_layers


def test_sliding_window_ring_decode():
    """Decode past the window with a ring cache == full-cache decode
    restricted to the window (the long_500k serving mechanism)."""
    cfg = dataclasses.replace(smoke_config("gemma2-9b"), sliding_window=8)
    s = 20
    params = transformer.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (1, s), 0, cfg.vocab_size)
    # reference: full cache, window masking in blockwise attention
    full, _ = transformer.forward(params, toks, cfg, POLICY, remat=False)
    # ring: cache_len=s but window layers get ring buffers of 8
    cache = decode.init_cache(cfg, 1, s, jnp.float32)
    outs = []
    for t in range(s):
        lg, cache = decode.decode_step(params, cache, toks[:, t:t + 1],
                                       cfg, POLICY, cache_len=s)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    rel = float(jnp.max(jnp.abs(dec - full))) / float(jnp.max(jnp.abs(full)))
    assert rel < 1e-4, rel
