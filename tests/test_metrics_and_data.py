"""Metrics (NMI/ARI vs brute force) + synthetic generators + token pipeline."""
import numpy as np
import jax.numpy as jnp
import pytest

# property tests need hypothesis (requirements-dev.txt)
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.metrics import ari, nmi
from repro.data.pipeline import TokenPipeline, lm_batches
from repro.data.synthetic import generate_gmm, generate_mnmm


def test_nmi_perfect_and_independent():
    t = jnp.asarray(np.repeat([0, 1, 2], 50))
    assert float(nmi(t, t, 3, 3)) == pytest.approx(1.0, abs=1e-5)
    # a permutation relabel is still perfect
    p = (t + 1) % 3
    assert float(nmi(t, p, 3, 3)) == pytest.approx(1.0, abs=1e-5)
    # constant prediction carries zero information
    c = jnp.zeros_like(t)
    assert float(nmi(t, c, 3, 3)) == pytest.approx(0.0, abs=1e-5)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(10, 200), kt=st.integers(2, 5), kp=st.integers(2, 5),
       seed=st.integers(0, 99))
def test_nmi_ari_bounds_and_symmetry(n, kt, kp, seed):
    rng = np.random.default_rng(seed)
    t = jnp.asarray(rng.integers(0, kt, n))
    p = jnp.asarray(rng.integers(0, kp, n))
    v = float(nmi(t, p, kt, kp))
    assert -1e-6 <= v <= 1.0 + 1e-6
    assert v == pytest.approx(float(nmi(p, t, kp, kt)), abs=1e-5)
    a = float(ari(t, p, kt, kp))
    assert -0.5 - 1e-6 <= a <= 1.0 + 1e-6
    assert float(ari(t, t, kt, kt)) == pytest.approx(1.0, abs=1e-5)


def test_ari_matches_bruteforce_pairs():
    rng = np.random.default_rng(0)
    n = 60
    t = rng.integers(0, 3, n)
    p = rng.integers(0, 4, n)
    got = float(ari(jnp.asarray(t), jnp.asarray(p), 3, 4))
    # brute-force pair counting
    same_t = t[:, None] == t[None, :]
    same_p = p[:, None] == p[None, :]
    iu = np.triu_indices(n, 1)
    a = np.sum(same_t[iu] & same_p[iu])
    b = np.sum(same_t[iu])
    c = np.sum(same_p[iu])
    tot = len(iu[0])
    expected_idx = b * c / tot
    want = (a - expected_idx) / (0.5 * (b + c) - expected_idx)
    assert got == pytest.approx(want, rel=1e-4)


def test_generate_gmm_structure():
    x, labels = generate_gmm(1000, 3, 4, seed=0)
    assert x.shape == (1000, 3) and labels.shape == (1000,)
    assert x.dtype == np.float32
    assert set(np.unique(labels)) <= set(range(4))
    # same seed => identical data (determinism)
    x2, l2 = generate_gmm(1000, 3, 4, seed=0)
    np.testing.assert_array_equal(x, x2)


def test_generate_mnmm_counts():
    x, labels = generate_mnmm(500, 8, 3, seed=1, trials=30)
    assert x.shape == (500, 8)
    np.testing.assert_array_equal(x.sum(axis=1), np.full(500, 30.0))
    assert (x >= 0).all()


def test_token_pipeline_deterministic_and_in_vocab():
    a = TokenPipeline(100, seed=3).sample(500)
    b = TokenPipeline(100, seed=3).sample(500)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < 100


def test_lm_batches_shapes_and_shift():
    gen = lm_batches(50, batch=4, seq=32, seed=0)
    toks, tgts = next(gen)
    assert toks.shape == (4, 32) and tgts.shape == (4, 32)
    # targets are the next-token shift of a common stream
    np.testing.assert_array_equal(toks[:, 1:], tgts[:, :-1])
