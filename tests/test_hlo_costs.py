"""The trip-count-aware HLO analyzer (roofline source) against ground truth:
scanned programs must report the same flops as their unrolled forms."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.roofline.hlo_costs import analyze_hlo


def _costs(fn, *args):
    return analyze_hlo(jax.jit(fn).lower(*args).compile().as_text())


def test_scan_flops_match_unrolled():
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((12, 256, 256), jnp.float32)

    def scanned(x, w):
        y, _ = jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)
        return y

    def unrolled(x, w):
        for i in range(12):
            x = x @ w[i]
        return x

    f_scan = _costs(scanned, x, w).flops
    f_unr = _costs(unrolled, x, w).flops
    expected = 2 * 12 * 256 ** 3
    assert f_scan == pytest.approx(expected, rel=0.01)
    assert f_unr == pytest.approx(expected, rel=0.01)


def test_nested_scan_multiplies():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def nested(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=4)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    got = _costs(nested, x, w).flops
    assert got == pytest.approx(2 * 20 * 128 ** 3, rel=0.01)


def test_batched_dot_flops():
    a = jax.ShapeDtypeStruct((8, 64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((8, 32, 16), jnp.float32)

    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    got = _costs(f, a, b).flops
    assert got == pytest.approx(2 * 8 * 64 * 32 * 16, rel=0.01)


def test_grad_flops_about_triple():
    """Backward of y = sum(x@w) costs ~2 extra matmuls."""
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def fwd(x, w):
        return jnp.sum(x @ w)

    f_fwd = _costs(fwd, x, w).flops
    f_grad = _costs(jax.grad(fwd, argnums=(0, 1)), x, w).flops
    assert 1.8 * f_fwd < f_grad < 3.2 * f_fwd


def test_collective_bytes_counted():
    import os
    if jax.device_count() < 2:
        pytest.skip("needs >1 device")
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    import numpy as np_
    mesh = Mesh(np_.array(jax.devices()[:2]), ("d",))
    x = jax.ShapeDtypeStruct(
        (128, 128), jnp.float32,
        sharding=NamedSharding(mesh, P("d", None)))

    def f(x):
        return jnp.sum(x) * jnp.ones_like(x)     # all-reduce of partials

    hlo = jax.jit(
        f, in_shardings=NamedSharding(mesh, P("d", None)),
        out_shardings=NamedSharding(mesh, P("d", None))).lower(x) \
        .compile().as_text()
    mc = analyze_hlo(hlo)
    assert sum(mc.coll.values()) > 0


def test_transcendentals_counted():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    mc = _costs(lambda x: jnp.tanh(x), x)
    assert mc.transcendentals >= 64 * 64
