"""Chaos suite (ISSUE 7): fault injection, checkpoint corruption, and
recovery must all be *exercised*, not just implemented.

Covers the resilience layer end to end:

 - checkpoint atomicity/verification (core/checkpoint.py): path-suffix
   normalization, truncated archives, bit-flips caught by CRC, rotation
   + latest-valid fallback, leaf-shape validation;
 - the fault-injection harness (data/faults.py) and the tiled driver's
   bounded retry (core/resilience.py): transient IOError / NaN-tile /
   short-read faults leave the chain BITWISE clean; persistent faults
   raise ``TileReadError`` with tile provenance;
 - NaN/divergence guardrails on both drivers: clean fits are bitwise
   unchanged by the checks; persistent divergence raises
   ``DivergenceError`` after ``max_recoveries`` rollbacks; transient
   divergence rolls back and recovers with the event logged;
 - auto-checkpointing + ``fit(resume=True)``: a killed fit (including a
   real SIGKILL in a subprocess) resumes to the bitwise-identical final
   chain, falling back through the rotation when the newest member is
   corrupt;
 - serving hardening: checksum-verified loads, rotation-prefix loads,
   typed query validation.
"""
import io
import os
import signal
import subprocess
import sys
import textwrap
import zipfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DPMMConfig
from repro.core import checkpoint as ckpt
from repro.core.checkpoint import (CheckpointCorrupt, CheckpointNotFound,
                                   load_model, save_model)
from repro.core.resilience import (DivergenceError, RetryPolicy,
                                   TileReadError, model_health,
                                   read_block_checked)
from repro.core.sampler import DPMM
from repro.data.faults import FaultInjectingSource
from repro.data.source import HostTiledSource
from repro.serve.dpmm import (DPMMEngine, InvalidQueryError,
                              ServeConfig)

N, D, K_MAX = 384, 4, 16


@pytest.fixture(scope="module")
def x():
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(4, D)) * 8.0
    return (centers[rng.integers(0, 4, N)]
            + rng.normal(size=(N, D))).astype(np.float32)


def _cfg(**kw):
    base = dict(alpha=2.0, iters=12, k_max=K_MAX, burnout=3, log_every=4)
    base.update(kw)
    return DPMMConfig(**base)


def _raw(leaf):
    if jnp.issubdtype(jnp.asarray(leaf).dtype, jax.dtypes.prng_key):
        return np.asarray(jax.random.key_data(leaf))
    return np.asarray(leaf)


def _assert_same_state(a, b):
    la, lb = (jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    assert len(la) == len(lb)
    for x_, y_ in zip(la, lb):
        np.testing.assert_array_equal(_raw(x_), _raw(y_))


def _assert_same_chain(ra, rb):
    np.testing.assert_array_equal(ra.labels, rb.labels)
    _assert_same_state(ra.state, rb.state)


# ---------------------------------------------------------------------------
# checkpoint durability + verification
# ---------------------------------------------------------------------------
def test_save_model_path_suffix_normalized(tmp_path, x):
    r = DPMM(_cfg(iters=4)).fit(x)
    bare = str(tmp_path / "ckpt")            # np.savez's .npz footgun
    final = save_model(bare, r.state, "gaussian")
    assert final == bare + ".npz" and os.path.exists(final)
    # BOTH spellings load the same file
    for spelling in (bare, bare + ".npz"):
        m, fam = load_model(spelling)
        assert fam.name == "gaussian"
        _assert_same_state(m, r.state)
    # and saving the suffixed spelling writes the same single file
    assert save_model(bare + ".npz", r.state, "gaussian") == final
    assert sorted(p.name for p in tmp_path.iterdir()) == ["ckpt.npz"]


def test_atomic_write_leaves_no_tmp(tmp_path, x):
    r = DPMM(_cfg(iters=4)).fit(x)
    final = save_model(str(tmp_path / "m"), r.state, "gaussian")
    assert [p.name for p in tmp_path.iterdir()] == [os.path.basename(final)]


def test_missing_checkpoint_raises_not_found(tmp_path):
    with pytest.raises(CheckpointNotFound):
        load_model(str(tmp_path / "nope.npz"))


def test_truncated_npz_raises_corrupt(tmp_path, x):
    r = DPMM(_cfg(iters=4)).fit(x)
    path = save_model(str(tmp_path / "m"), r.state, "gaussian")
    blob = open(path, "rb").read()
    for frac in (0.15, 0.6, 0.95):           # several torn-write points
        open(path, "wb").write(blob[:int(len(blob) * frac)])
        with pytest.raises(CheckpointCorrupt):
            load_model(path)


def test_bit_flip_caught_by_crc(tmp_path, x):
    r = DPMM(_cfg(iters=4)).fit(x)
    path = save_model(str(tmp_path / "m"), r.state, "gaussian")
    # flip one byte INSIDE a stored leaf's raw data (not the zip header,
    # which zipfile's own CRC would catch) — rewrite the member with the
    # flip so only our per-leaf CRC can notice
    with zipfile.ZipFile(path) as z:
        names = [n for n in z.namelist() if n.startswith("leaf_")]
        victim = names[len(names) // 2]
        payloads = {n: z.read(n) for n in z.namelist()}
    body = bytearray(payloads[victim])
    body[-5] ^= 0x40                          # inside the array bytes
    payloads[victim] = bytes(body)
    with zipfile.ZipFile(path, "w") as z:
        for n, b in payloads.items():
            z.writestr(n, b)
    with pytest.raises(CheckpointCorrupt, match="CRC mismatch"):
        load_model(path)


def test_shape_mismatch_fails_clearly(tmp_path, x):
    r = DPMM(_cfg(iters=4)).fit(x)
    bad = r.state._replace(it=jnp.zeros((3,), jnp.int32))  # chain-axis lie
    path = save_model(str(tmp_path / "bad"), bad, "gaussian")
    with pytest.raises(CheckpointCorrupt, match="multi-chain mismatch"):
        load_model(path)


def test_file_object_roundtrip_still_works(x):
    r = DPMM(_cfg(iters=4)).fit(x)
    buf = io.BytesIO()
    assert save_model(buf, r.state, "gaussian") is None
    buf.seek(0)
    m, fam = load_model(buf)
    _assert_same_state(m, r.state)


def test_rotation_keep_and_latest_valid(tmp_path, x):
    r = DPMM(_cfg(iters=4)).fit(x)
    pref = str(tmp_path / "rot")
    for it in (4, 8, 12, 16):
        ckpt.save_checkpoint(pref, r.state, "gaussian", it, keep=3)
    listed = ckpt.list_checkpoints(pref)
    assert [it for it, _ in listed] == [16, 12, 8]   # oldest pruned
    model, fam, path, it = ckpt.latest_valid(pref)
    assert it == 16 and path.endswith("-00000016.npz")
    # corrupt the newest: latest_valid falls back one interval
    open(path, "wb").write(b"not an npz")
    model, fam, path2, it2 = ckpt.latest_valid(pref)
    assert it2 == 12
    # corrupt everything: typed not-found with the corruption details
    for _, p in ckpt.list_checkpoints(pref):
        open(p, "wb").write(b"junk")
    with pytest.raises(CheckpointNotFound, match="failed verification"):
        ckpt.latest_valid(pref)


# ---------------------------------------------------------------------------
# fault injection + tiled retry
# ---------------------------------------------------------------------------
def test_fault_source_is_deterministic(x):
    def injected(seed):
        src = FaultInjectingSource(HostTiledSource(x), seed=seed,
                                   p_io=0.2, p_nan=0.1, p_short=0.1)
        for call in range(30):
            try:
                src.read_block(0, 64)
            except IOError:
                pass
        return [(e["call"], e["kind"]) for e in src.injected]

    a, b = injected(5), injected(5)
    assert a and a == b
    assert injected(6) != a                  # schedule follows the seed


def test_fault_source_rejects_bad_args(x):
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultInjectingSource(HostTiledSource(x), schedule={0: "meteor"})
    with pytest.raises(ValueError, match="sum to <= 1"):
        FaultInjectingSource(HostTiledSource(x), p_io=0.9, p_nan=0.9)


def test_read_block_checked_retries_and_reports():
    events = []
    src = FaultInjectingSource(HostTiledSource(np.ones((64, 2), np.float32)),
                               schedule={0: "io", 1: "short"})
    rows = read_block_checked(src, 0, 32,
                              RetryPolicy(max_retries=3, backoff_s=0.0),
                              on_event=events.append)
    assert rows.shape == (32, 2)
    # two per-attempt fault events, then one recovered-read summary event
    # (io_retry) with the total attempt count for the range
    assert [e["kind"] for e in events] == ["tile_read_fault",
                                           "tile_read_fault", "io_retry"]
    # IOError is an alias of OSError on py3 — the report says OSError
    assert [e["detail"].split(":")[0]
            for e in events
            if e["kind"] == "tile_read_fault"] == ["OSError", "short read"]
    assert events[-1]["rows"] == [0, 32]
    assert events[-1]["attempts"] == 3


def test_retry_exhaustion_has_tile_provenance(x):
    src = FaultInjectingSource(HostTiledSource(x),
                               schedule=dict.fromkeys(range(500), "io"))
    cfg = _cfg(tile_size=128, io_retries=2, io_backoff_s=0.0)
    # per-shard reads are n/shards rows here, so don't pin the row count
    with pytest.raises(TileReadError, match=r"rows \[0, \d+\).*3 attempt"):
        DPMM(cfg).fit(src)


def test_persistent_nan_tile_fails_loudly(x):
    src = FaultInjectingSource(HostTiledSource(x),
                               schedule=dict.fromkeys(range(500), "nan"))
    cfg = _cfg(tile_size=128, io_retries=2, io_backoff_s=0.0)
    with pytest.raises(TileReadError, match="non-finite"):
        DPMM(cfg).fit(src)


def test_transient_faults_leave_tiled_chain_bitwise(x):
    cfg = _cfg(iters=8, tile_size=128, io_backoff_s=0.0)
    clean = DPMM(cfg).fit(HostTiledSource(x))
    src = FaultInjectingSource(HostTiledSource(x), seed=11, p_io=0.06,
                               p_nan=0.05, p_short=0.05)
    faulted = DPMM(cfg).fit(src)
    assert src.injected, "schedule injected nothing — raise probabilities"
    kinds = {e["kind"] for e in faulted.recoveries}
    assert faulted.recoveries and kinds <= {"tile_read_fault", "io_retry"}
    # every recovered read logs an io_retry summary alongside the
    # per-attempt events
    assert "io_retry" in kinds
    _assert_same_chain(clean, faulted)
    assert clean.recoveries == []


# ---------------------------------------------------------------------------
# guardrails + divergence rollback
# ---------------------------------------------------------------------------
def test_model_health_verdicts(x):
    r = DPMM(_cfg(iters=4)).fit(x)
    assert bool(model_health(r.state))
    sick = r.state._replace(stats=r.state.stats._replace(
        n=r.state.stats.n.at[0].set(jnp.nan)))
    assert not bool(model_health(sick))
    # degenerate: negative count on an ACTIVE slot only
    neg = r.state._replace(stats=r.state.stats._replace(
        n=r.state.stats.n.at[0].set(-1.0)))
    assert not bool(model_health(neg))
    inact = r.state._replace(active=r.state.active.at[0].set(False))
    assert bool(model_health(inact._replace(stats=inact.stats._replace(
        n=inact.stats.n.at[0].set(jnp.nan)))))


@pytest.mark.parametrize("plane", ["resident", "tiled"])
def test_guardrails_are_chain_neutral(x, plane):
    kw = {} if plane == "resident" else {"tile_size": 128}
    on = DPMM(_cfg(guardrails=True, **kw)).fit(x)
    off = DPMM(_cfg(guardrails=False, **kw)).fit(x)
    _assert_same_chain(on, off)
    for key in on.history:
        np.testing.assert_array_equal(on.history[key], off.history[key])
    assert on.recoveries == [] == off.recoveries


def test_resident_nan_data_raises_divergence(x):
    xbad = x.copy()
    xbad[5] = np.inf                         # persistent: rollback is futile
    with pytest.raises(DivergenceError) as ei:
        DPMM(_cfg(max_recoveries=2)).fit(xbad)
    assert len(ei.value.recoveries) == 3     # max_recoveries + final straw
    assert all(e["kind"] == "divergence_rollback"
               for e in ei.value.recoveries)


def test_tiled_transient_divergence_rolls_back_and_recovers(x):
    # guard_tiles=False lets ONE NaN tile reach the device; the on-device
    # health check catches it at the iteration boundary, rolls back to
    # the last healthy model with an advanced key, and the replay re-reads
    # the (transient) tile clean — the fit completes with the event logged.
    # Call index 9 lands inside the iteration loop on both 1- and 4-device
    # meshes (the two init passes consume the first 6-8 read calls; a NaN
    # there is harmless anyway, since the first sweep refolds stats from
    # clean re-reads).
    src = FaultInjectingSource(HostTiledSource(x), schedule={9: "nan"})
    cfg = _cfg(iters=6, tile_size=128, guard_tiles=False, max_recoveries=3)
    r = DPMM(cfg).fit(src)
    rollbacks = [e for e in r.recoveries
                 if e["kind"] == "divergence_rollback"]
    assert len(rollbacks) == 1
    assert len(r.history["k"]) == 6          # full-length healthy history
    assert bool(model_health(r.state))


# ---------------------------------------------------------------------------
# auto-checkpointing + resume
# ---------------------------------------------------------------------------
def test_config_validates_checkpoint_knobs():
    with pytest.raises(ValueError, match="checkpoint_path"):
        _cfg(checkpoint_every=4)
    with pytest.raises(ValueError, match="checkpoint_every"):
        _cfg(checkpoint_path="p", checkpoint_every=0)
    with pytest.raises(ValueError, match="max_recoveries"):
        _cfg(max_recoveries=-1)


def test_resume_requires_checkpoint_path(x):
    with pytest.raises(ValueError, match="checkpoint_path"):
        DPMM(_cfg()).fit(x, resume=True)
    cfg = _cfg(checkpoint_path="p", checkpoint_every=4)
    with pytest.raises(ValueError, match="not both"):
        DPMM(cfg).fit(x, resume=True,
                      init_state=DPMM(_cfg(iters=1)).fit(x).state)


@pytest.mark.parametrize("plane", ["resident", "tiled"])
def test_auto_checkpoint_resume_is_bitwise(tmp_path, x, plane):
    kw = {} if plane == "resident" else {"tile_size": 128}
    pref = str(tmp_path / f"ck_{plane}")
    cfg = _cfg(checkpoint_path=pref, checkpoint_every=4, **kw)
    m = DPMM(cfg)
    m.fit(x, iters=8)                        # "killed" after 8 iterations
    assert ckpt.list_checkpoints(pref)
    resumed = m.fit(x, iters=16, resume=True)    # total target: 16
    full = DPMM(_cfg(iters=16, **kw)).fit(x)
    _assert_same_chain(resumed, full)


def test_resume_with_no_checkpoint_is_fresh_fit(tmp_path, x):
    cfg = _cfg(checkpoint_path=str(tmp_path / "empty"), checkpoint_every=4)
    r = DPMM(cfg).fit(x, iters=8, resume=True)
    _assert_same_chain(r, DPMM(_cfg(iters=8)).fit(x))


def test_resume_falls_back_past_corrupt_member(tmp_path, x):
    pref = str(tmp_path / "ck")
    cfg = _cfg(checkpoint_path=pref, checkpoint_every=4)
    DPMM(cfg).fit(x, iters=8)
    newest = ckpt.list_checkpoints(pref)[0][1]
    blob = open(newest, "rb").read()
    open(newest, "wb").write(blob[:len(blob) // 2])  # torn write
    resumed = DPMM(cfg).fit(x, iters=16, resume=True)  # resumes from it=4
    _assert_same_chain(resumed, DPMM(_cfg(iters=16)).fit(x))


def test_multichain_auto_checkpoint_resume(tmp_path, x):
    pref = str(tmp_path / "mc")
    cfg = _cfg(checkpoint_path=pref, checkpoint_every=4)
    m = DPMM(cfg)
    m.fit(x, iters=8, n_chains=2)
    resumed = m.fit(x, iters=12, n_chains=2, resume=True)
    full = DPMM(_cfg(iters=12)).fit(x, n_chains=2)
    np.testing.assert_array_equal(resumed.labels, full.labels)
    _assert_same_state(resumed.state, full.state)


def test_sigkill_mid_fit_then_resume_is_bitwise(tmp_path, x):
    """The acceptance test: a fit hard-killed (SIGKILL — no cleanup, no
    atexit) mid-run resumes from the rotation to the bitwise-identical
    final chain. The child monkeypatches save_checkpoint to SIGKILL
    itself right AFTER the second rotation write returns — the moment of
    maximum exposure for a non-atomic writer."""
    xpath = str(tmp_path / "x.npy")
    np.save(xpath, x)
    pref = str(tmp_path / "kill")
    child = textwrap.dedent(f"""
        import os, signal
        import numpy as np
        from repro.configs import DPMMConfig
        from repro.core import checkpoint
        from repro.core.sampler import DPMM

        saves = [0]
        real = checkpoint.save_checkpoint
        def dying_save(*a, **kw):
            path = real(*a, **kw)
            saves[0] += 1
            if saves[0] == 2:
                os.kill(os.getpid(), signal.SIGKILL)
            return path
        checkpoint.save_checkpoint = dying_save

        x = np.load({xpath!r})
        cfg = DPMMConfig(alpha=2.0, iters=16, k_max={K_MAX}, burnout=3,
                         log_every=4, checkpoint_path={pref!r},
                         checkpoint_every=4)
        DPMM(cfg).fit(x)
        raise SystemExit("fit survived the SIGKILL — test is broken")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in ("src", env.get("PYTHONPATH", "")) if p])
    if "host_platform_device_count" not in env.get("XLA_FLAGS", ""):
        # match conftest's 4 virtual devices so the child's chain is the
        # parent's chain (shard count is chain-neutral, but stay exact)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=4"
                            ).strip()
    proc = subprocess.run([sys.executable, "-c", child], env=env,
                          capture_output=True, text=True,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == -signal.SIGKILL, (proc.returncode,
                                                proc.stdout, proc.stderr)
    members = ckpt.list_checkpoints(pref)
    assert members and members[0][0] == 8    # died right after saving it=8
    cfg = _cfg(checkpoint_path=pref, checkpoint_every=4)
    resumed = DPMM(cfg).fit(x, iters=16, resume=True)
    full = DPMM(_cfg(iters=16)).fit(x)
    _assert_same_chain(resumed, full)


# ---------------------------------------------------------------------------
# serving hardening
# ---------------------------------------------------------------------------
def test_engine_validates_queries(tmp_path, x):
    r = DPMM(_cfg(iters=4)).fit(x)
    eng = DPMMEngine(r.state, "gaussian", ServeConfig(batch_sizes=(64,)))
    q = x[:8].copy()
    assert eng.predict(q).shape == (8,)
    q[3, 1] = np.nan
    with pytest.raises(InvalidQueryError, match="row 3"):
        eng.predict(q)
    with pytest.raises(InvalidQueryError, match="queries must be"):
        eng.predict(np.zeros((4, D + 1), np.float32))
    # InvalidQueryError is a ValueError: existing callers keep working
    assert issubclass(InvalidQueryError, ValueError)
    # opt-out for trusted pipelines
    lax = DPMMEngine(r.state, "gaussian",
                     ServeConfig(batch_sizes=(64,),
                                 validate_queries=False))
    assert np.isnan(lax.log_predictive(q)[3])


def test_engine_refuses_corrupt_checkpoint(tmp_path, x):
    r = DPMM(_cfg(iters=4)).fit(x)
    path = save_model(str(tmp_path / "m"), r.state, "gaussian")
    blob = open(path, "rb").read()
    open(path, "wb").write(blob[: len(blob) // 3])
    with pytest.raises(CheckpointCorrupt):
        DPMMEngine.from_checkpoint(path)


def test_engine_loads_from_rotation_prefix(tmp_path, x):
    pref = str(tmp_path / "serve")
    cfg = _cfg(checkpoint_path=pref, checkpoint_every=4)
    r = DPMM(cfg).fit(x, iters=8)
    eng = DPMMEngine.from_checkpoint(pref, ServeConfig(batch_sizes=(64,)))
    direct = DPMMEngine(r.state, "gaussian", ServeConfig(batch_sizes=(64,)))
    np.testing.assert_array_equal(eng.predict(x[:32]),
                                  direct.predict(x[:32]))
    # newest member corrupt -> serves the previous one, not garbage
    newest = ckpt.list_checkpoints(pref)[0][1]
    open(newest, "wb").write(b"garbage")
    eng2 = DPMMEngine.from_checkpoint(pref, ServeConfig(batch_sizes=(64,)))
    assert eng2.predict(x[:32]).shape == (32,)
    with pytest.raises(CheckpointNotFound):
        DPMMEngine.from_checkpoint(str(tmp_path / "missing"))
