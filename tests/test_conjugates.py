"""Unit + property tests for the NIW and Dirichlet-Multinomial conjugates —
the math under the split/merge Hastings ratios (paper eqs. 12, 20, 21)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

# property tests need hypothesis (requirements-dev.txt); plain unit tests in
# this module still run without it
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import multinomial, niw


def _stats_of(x):
    return niw.stats_from_points(jnp.asarray(x, jnp.float32),
                                 jnp.ones((x.shape[0], 1), jnp.float32))


def _prior(d, kappa=1.0, nu_extra=3.0):
    return niw.default_prior(jnp.zeros(d), jnp.ones(d), kappa, d + nu_extra)


def test_log_marginal_additivity_vs_chain_rule():
    """m(C) computed at once == sequential posterior-predictive chain:
    log m(x_1..x_n) = sum_i log p(x_i | x_<i)."""
    rng = np.random.default_rng(0)
    d = 3
    x = rng.normal(size=(6, d))
    prior = _prior(d)
    total = float(niw.log_marginal(prior, _stats_of(x))[0])
    seq = 0.0
    for i in range(x.shape[0]):
        s_prev = _stats_of(x[:i]) if i else niw.empty_stats((1,), d)
        s_cur = _stats_of(x[:i + 1])
        seq += float((niw.log_marginal(prior, s_cur)
                      - niw.log_marginal(prior, s_prev))[0])
    assert np.isclose(total, seq, rtol=1e-5)


def test_log_marginal_1d_analytic():
    """d=1 NIW == Normal-Inverse-Gamma marginal (student-t products)."""
    rng = np.random.default_rng(1)
    x = rng.normal(size=(10, 1)).astype(np.float32)
    prior = _prior(1)
    got = float(niw.log_marginal(prior, _stats_of(x))[0])
    # brute-force via the chain rule with scipy-free student-t logpdf
    from jax.scipy.special import gammaln

    def log_t(v, mean, scale2, df):
        z = (v - mean) ** 2 / (df * scale2)
        return float(gammaln((df + 1) / 2) - gammaln(df / 2)
                     - 0.5 * np.log(df * np.pi * scale2)
                     - (df + 1) / 2 * np.log1p(z))

    m, psi = 0.0, 1.0
    kappa, nu = 1.0, 1.0 + 3.0
    want = 0.0
    for v in x[:, 0]:
        df = nu
        scale2 = psi * (kappa + 1) / (kappa * df)
        want += log_t(float(v), m, scale2, df)
        # posterior update
        kappa_n = kappa + 1
        m_n = (kappa * m + v) / kappa_n
        psi = psi + kappa / kappa_n * (v - m) ** 2
        m, kappa, nu = m_n, kappa_n, nu + 1
    assert np.isclose(got, want, rtol=1e-4), (got, want)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 40), d=st.integers(1, 8), seed=st.integers(0, 99))
def test_posterior_concentrates(n, d, seed):
    """Posterior parameters move toward the sample mean as n grows."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)) + 5.0
    prior = _prior(d)
    m_n, psi_n, kappa_n, nu_n = niw.posterior(prior, _stats_of(x))
    assert float(kappa_n[0]) == pytest.approx(1.0 + n)
    assert float(nu_n[0]) == pytest.approx(d + 3.0 + n)
    # m_n between prior mean (0) and sample mean, near sample mean
    w = n / (1.0 + n)
    np.testing.assert_allclose(np.asarray(m_n[0]), w * x.mean(0), rtol=1e-4,
                               atol=1e-4)
    # psi_n stays SPD
    eigs = np.linalg.eigvalsh(np.asarray(psi_n[0]))
    assert eigs.min() > 0


def test_sample_posterior_statistics():
    """Monte-Carlo check: sampled (mu, Sigma) concentrate on the truth."""
    rng = np.random.default_rng(2)
    d = 2
    true_mu = np.array([3.0, -1.0])
    a = rng.normal(size=(4000, d)) @ np.diag([1.0, 0.5]) + true_mu
    prior = _prior(d)
    stats = _stats_of(a)
    mus, sigmas = [], []
    for i in range(20):
        p = niw.sample_posterior(jax.random.key(i), prior, stats)
        f = np.asarray(p.chol_prec[0])
        sigmas.append(np.linalg.inv(f @ f.T))
        mus.append(np.asarray(p.mu[0]))
        # logdet_prec consistency with the factor itself
        got_ld = float(p.logdet_prec[0])
        want_ld = float(np.linalg.slogdet(f @ f.T)[1])
        assert np.isclose(got_ld, want_ld, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.mean(mus, 0), true_mu, atol=0.1)
    np.testing.assert_allclose(np.mean(sigmas, 0),
                               np.cov(a.T), rtol=0.15, atol=0.05)


def test_multinomial_marginal_chain_rule():
    rng = np.random.default_rng(3)
    d = 5
    x = rng.multinomial(20, np.ones(d) / d, size=6).astype(np.float32)
    prior = multinomial.default_prior(d, 0.7)

    def stats_of(v):
        if v.shape[0] == 0:
            return multinomial.empty_stats((1,), d)
        return multinomial.stats_from_points(
            jnp.asarray(v), jnp.ones((v.shape[0], 1), jnp.float32))

    total = float(multinomial.log_marginal(prior, stats_of(x))[0])
    seq = sum(float((multinomial.log_marginal(prior, stats_of(x[:i + 1]))
                     - multinomial.log_marginal(prior, stats_of(x[:i])))[0])
              for i in range(x.shape[0]))
    assert np.isclose(total, seq, rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 50))
def test_split_merge_hastings_antisymmetry(seed):
    """log H_merge(A, B) == -log H_split(A+B into A, B) up to the alpha
    bookkeeping terms — eq. 21 is the reciprocal move of eq. 20 with the
    same marginals. We verify the shared marginal-likelihood core."""
    from repro.core import splitmerge
    from repro.core.family import get_family
    gauss = get_family("gaussian")
    rng = np.random.default_rng(seed)
    d = 2
    a = rng.normal(size=(30, d)) + [4, 0]
    b = rng.normal(size=(25, d)) - [4, 0]
    prior = _prior(d)
    sa, sb = _stats_of(a), _stats_of(b)
    sab = niw.add_stats(sa, sb)
    sub = jax.tree.map(lambda u, v: jnp.stack([u, v], 1), sa, sb)
    alpha = 10.0
    log_h_split = float(splitmerge.log_hastings_split(
        prior, gauss, sab, sub, alpha)[0])
    log_h_merge = float(splitmerge.log_hastings_merge(
        prior, gauss, sa, sb, alpha)[0])
    # marginal-likelihood core must be exactly opposite
    core_split = (float(niw.log_marginal(prior, sa)[0])
                  + float(niw.log_marginal(prior, sb)[0])
                  - float(niw.log_marginal(prior, sab)[0]))
    assert np.isclose(log_h_split - core_split
                      - (np.log(alpha)
                         + float(jax.scipy.special.gammaln(30.0))
                         + float(jax.scipy.special.gammaln(25.0))
                         - float(jax.scipy.special.gammaln(55.0))), 0.0,
                      atol=1e-3)
    # and a well-separated configuration must favor the split
    assert log_h_split > 0 > log_h_merge


def test_poisson_marginal_chain_rule():
    """Gamma-Poisson marginal == sequential negative-binomial chain."""
    from repro.core import poisson
    rng = np.random.default_rng(5)
    d = 4
    x = rng.poisson(6.0, size=(7, d)).astype(np.float32)
    prior = poisson.default_prior(d, 1.5, 0.8)

    def stats_of(v):
        if v.shape[0] == 0:
            return poisson.empty_stats((1,), d)
        return poisson.stats_from_points(
            jnp.asarray(v), jnp.ones((v.shape[0], 1), jnp.float32))

    total = float(poisson.log_marginal(prior, stats_of(x))[0])
    seq = sum(float((poisson.log_marginal(prior, stats_of(x[:i + 1]))
                     - poisson.log_marginal(prior, stats_of(x[:i])))[0])
              for i in range(x.shape[0]))
    assert np.isclose(total, seq, rtol=1e-5)


def test_poisson_posterior_concentrates():
    from repro.core import poisson
    rng = np.random.default_rng(6)
    true_rate = np.array([3.0, 11.0])
    x = rng.poisson(true_rate, size=(4000, 2)).astype(np.float32)
    prior = poisson.default_prior(2)
    stats = poisson.stats_from_points(
        jnp.asarray(x), jnp.ones((4000, 1), jnp.float32))
    p = poisson.expected_params(prior, stats)
    np.testing.assert_allclose(np.exp(np.asarray(p.log_rate[0])),
                               true_rate, rtol=0.05)


def test_poisson_dpmm_end_to_end():
    """The paper's suggested exponential-family extension, fit end-to-end."""
    from repro.configs import DPMMConfig
    from repro.core.sampler import DPMM
    from repro.data.synthetic import generate_pmm
    x, gt = generate_pmm(3000, 8, 5, seed=0)
    cfg = DPMMConfig(component="poisson", alpha=10.0, iters=60, k_max=32,
                     burnout=5)
    r = DPMM(cfg).fit(x)
    assert r.nmi(gt) > 0.85, (r.k, r.nmi(gt))
