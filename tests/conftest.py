"""Test env: 4 virtual CPU devices (NOT 512 — that is dry-run-only; see
launch/dryrun.py) so the distributed DPMM tests exercise real cross-device
psums while smoke tests stay fast.

Also registers ``--update-goldens`` for the golden-chain fingerprint suite
(tests/test_golden_chains.py): regenerate tests/goldens/*.json instead of
comparing against them."""
import os

os.environ.setdefault(
    "XLA_FLAGS",
    (os.environ.get("XLA_FLAGS", "")
     + " --xla_force_host_platform_device_count=4").strip())


def pytest_addoption(parser):
    parser.addoption(
        "--update-goldens", action="store_true", default=False,
        help="rewrite tests/goldens/*.json from this run's chains "
             "(commit the diff deliberately — it means chains changed)")
