"""Test env: 4 virtual CPU devices (NOT 512 — that is dry-run-only; see
launch/dryrun.py) so the distributed DPMM tests exercise real cross-device
psums while smoke tests stay fast."""
import os

os.environ.setdefault(
    "XLA_FLAGS",
    (os.environ.get("XLA_FLAGS", "")
     + " --xla_force_host_platform_device_count=4").strip())
