"""Distribution tests (paper §4.3 / claim C3): chains are bitwise identical
across mesh sizes, and the ONLY cross-shard traffic is the psum of
sufficient statistics — never the O(N d) point data."""
import os
import re

import numpy as np
import pytest

# 4 virtual CPU devices for every test in this file (set before jax import
# via conftest would leak into other files; spawn check handled by pytest
# forking? No — set here only if jax is not yet initialized).
import jax

if jax.device_count() == 1:
    pytest.skip("needs >1 device (tests/conftest.py sets 4 virtual CPU "
                "devices when run via pytest)", allow_module_level=True)

import functools

import jax.numpy as jnp

from repro.configs import DPMMConfig
from repro.core import niw
from repro.core.distributed import make_data_mesh
from repro.core.sampler import DPMM
from repro.data.synthetic import generate_gmm


@pytest.fixture(scope="module")
def data():
    return generate_gmm(4096, 4, 5, seed=0, sep=10.0)


def test_chain_identical_across_meshes(data):
    """fold_in(global index) PRNG => 1-dev and N-dev runs match bitwise."""
    x, gt = data
    cfg = DPMMConfig(alpha=10.0, iters=30, k_max=16, burnout=5)
    r1 = DPMM(cfg, mesh=make_data_mesh(1)).fit(x)
    rn = DPMM(cfg, mesh=make_data_mesh(jax.device_count())).fit(x)
    assert r1.k == rn.k
    assert np.array_equal(r1.labels, rn.labels)


def test_only_suffstats_cross_shards(data):
    """Structural HLO check: every collective operand is O(K*T) (suff-stats
    / scalars), never O(N_local * d) (the sharded points)."""
    x, _ = data
    cfg = DPMMConfig(alpha=10.0, iters=5, k_max=16, burnout=2)
    mesh = make_data_mesh(jax.device_count())
    model = DPMM(cfg, mesh=mesh)

    # reproduce the fit()'s compiled step to inspect its HLO
    from repro.core.sampler import _init_local, dpmm_step
    from repro.core.distributed import data_axes_of, shard_map, shard_points
    from repro.core.family import state_partition_specs
    from jax.sharding import PartitionSpec as P

    axes = data_axes_of(mesh)
    prior = model.family.build_prior(cfg, x)
    xs, valid = shard_points(mesh, np.asarray(x, np.float32), False)
    kwargs = dict(prior=prior, family=model.family, cfg=cfg, axes=axes,
                  k_max=cfg.k_max)
    shard_spec = P(axes)
    rep = P()
    state_specs = state_partition_specs(model.family, shard_spec)
    init = jax.jit(shard_map(
        functools.partial(_init_local, **kwargs),
        mesh=mesh, in_specs=(rep, shard_spec, shard_spec),
        out_specs=state_specs))
    model_state, point_state = init(jax.random.key(0), xs, valid)
    step = jax.jit(shard_map(
        functools.partial(dpmm_step, **kwargs), mesh=mesh,
        in_specs=(*state_specs, shard_spec),
        out_specs=state_specs))
    hlo = step.lower(model_state, point_state, xs).compile().as_text()

    n_local = x.shape[0] // jax.device_count()
    d = x.shape[1]
    data_bytes = n_local * d * 4
    # every collective's result must be far smaller than the local shard
    pat = re.compile(r"=\s*((?:\([^)]*\))|\S+)\s+(all-reduce|all-gather|"
                     r"reduce-scatter|all-to-all|collective-permute)\(")
    from repro.roofline.hlo_costs import _shape_bytes
    found = 0
    for line in hlo.splitlines():
        m = pat.search(line)
        if not m:
            continue
        found += 1
        nbytes = _shape_bytes(m.group(1))
        assert nbytes < data_bytes / 4, (
            f"collective moves {nbytes}B >= shard/4 "
            f"({data_bytes}B): {line[:160]}")
    assert found > 0, "expected at least one suff-stat psum"


def test_weak_scaling_suffstat_volume(data):
    """Communication volume per sweep is independent of N (paper: only
    sufficient statistics and parameters cross the wire)."""
    x, _ = data
    cfg = DPMMConfig(alpha=10.0, iters=2, k_max=16, burnout=1)
    mesh = make_data_mesh(jax.device_count())
    from repro.roofline.hlo_costs import analyze_hlo

    def coll_bytes(n_points):
        model = DPMM(cfg, mesh=mesh)
        r = model.fit(x[:n_points], iters=1)
        return r

    # indirect but effective: K*T floats for gaussian d=4, K_max=16:
    # stats ~ 16*(1+4+16)*4B*2(sub) ~ 2.7KB/psum — assert via the HLO of
    # the structural test above; here we just confirm fit works at 2 sizes
    assert coll_bytes(1024).k >= 1
    assert coll_bytes(4096).k >= 1


def test_feature_sharded_poisson_identical():
    """Poisson feature-sharding (rates are feature-independent too)."""
    from jax.sharding import Mesh
    from repro.data.synthetic import generate_pmm

    if jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    x, gt = generate_pmm(1024, 16, 4, seed=2)
    cfg = DPMMConfig(component="poisson", alpha=10.0, iters=20,
                     k_max=16, burnout=5)
    r_plain = DPMM(cfg).fit(x)
    mesh22 = Mesh(np.array(jax.devices()[:4]).reshape(2, 2),
                  ("data", "model"))
    cfg_fs = DPMMConfig(component="poisson", alpha=10.0, iters=20,
                        k_max=16, burnout=5, shard_features=True)
    r_fs = DPMM(cfg_fs, mesh=mesh22).fit(x)
    assert np.array_equal(r_plain.labels, r_fs.labels)


def test_feature_sharded_multinomial_identical():
    """High-d multinomial mode (DESIGN §10): x's feature dim sharded over
    'model' — local x @ log(theta) partials + psum. Chain must be bitwise
    identical to the unsharded run (the paper's d=20,000 regime)."""
    from jax.sharding import Mesh
    from repro.data.synthetic import generate_mnmm

    if jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    x, gt = generate_mnmm(1024, 32, 5, seed=1)
    cfg = DPMMConfig(component="multinomial", alpha=10.0, iters=25,
                     k_max=16, burnout=5)
    r_plain = DPMM(cfg).fit(x)
    mesh22 = Mesh(np.array(jax.devices()[:4]).reshape(2, 2),
                  ("data", "model"))
    cfg_fs = DPMMConfig(component="multinomial", alpha=10.0, iters=25,
                        k_max=16, burnout=5, shard_features=True)
    r_fs = DPMM(cfg_fs, mesh=mesh22).fit(x)
    assert r_plain.k == r_fs.k
    assert np.array_equal(r_plain.labels, r_fs.labels)


def test_feature_sharded_diag_gaussian_identical():
    """diag_gaussian is feature-separable (per-feature NIG), so it gets the
    high-d sharded path the full-covariance Gaussian can't have — the
    registry's feature_shardable contract in action."""
    from jax.sharding import Mesh

    if jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    x, gt = generate_gmm(1024, 16, 4, seed=3, sep=8.0)
    cfg = DPMMConfig(component="diag_gaussian", alpha=10.0, iters=25,
                     k_max=16, burnout=5)
    r_plain = DPMM(cfg).fit(x)
    mesh22 = Mesh(np.array(jax.devices()[:4]).reshape(2, 2),
                  ("data", "model"))
    cfg_fs = DPMMConfig(component="diag_gaussian", alpha=10.0, iters=25,
                        k_max=16, burnout=5, shard_features=True)
    r_fs = DPMM(cfg_fs, mesh=mesh22).fit(x)
    assert r_plain.k == r_fs.k
    assert np.array_equal(r_plain.labels, r_fs.labels)


def test_gaussian_shard_features_falls_back_to_replicated():
    """shard_features with a non-separable family must not silently shard:
    fit() keeps the replicated-feature path and still works."""
    from jax.sharding import Mesh

    if jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    x, gt = generate_gmm(512, 4, 3, seed=4, sep=10.0)
    mesh22 = Mesh(np.array(jax.devices()[:4]).reshape(2, 2),
                  ("data", "model"))
    cfg = DPMMConfig(alpha=10.0, iters=10, k_max=8, burnout=3,
                     shard_features=True)
    r = DPMM(cfg, mesh=mesh22).fit(x)
    assert r.k >= 1
