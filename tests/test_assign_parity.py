"""Interpret-mode parity for the fused sweep hot path (kernels/assign.py,
kernels/suffstats.py, kernels/prng.py) against the jnp reference path:

 - fused assignment labels IDENTICAL to the reference argmax, and fused
   sub-assignment labels identical to the chunked own-cluster gather, for
   every registered family, on both MXU-aligned and ragged (N, K) shapes;
 - label-indexed suff-stats (segment-sum / one-hot reference AND Pallas
   kernels) allclose to the dense stats_from_points oracle;
 - feature-sharded assignment/sub-assignment bitwise equal to replicated;
 - the structural guarantee behind the perf claim: the reference sweep's
   jaxpr contains NO (N, K, 2) intermediate — step (f) evaluates only each
   point's own cluster, on every path.
"""
import functools

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import DPMMConfig
from repro.core import gibbs
from repro.core.family import available_families, get_family
from repro.kernels import prng

ALL = available_families()
SHARDABLE = [n for n in ALL if get_family(n).feature_shardable]

# (N, K, d): one MXU-aligned problem, one ragged one that exercises the
# kernels' padding of both the point and cluster axes
SHAPES = [(128, 8, 4), (130, 7, 5)]


def _data(name, n, d, rng):
    if name in ("gaussian", "diag_gaussian"):
        return rng.normal(2.0, 1.5, size=(n, d)).astype(np.float32)
    if name == "poisson":
        return rng.poisson(4.0, size=(n, d)).astype(np.float32)
    return rng.multinomial(30, np.ones(d) / d, size=n).astype(np.float32)


def _setup(name, n, k, d, seed=0):
    """Params/weights for k slots with the last slot inactive (tests the
    kernels' active-mask handling next to real clusters)."""
    fam = get_family(name)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(_data(name, n, d, rng))
    prior = fam.build_prior(DPMMConfig(component=name), x)
    labels0 = jnp.asarray(rng.integers(0, max(k - 1, 1), n), jnp.int32)
    bits0 = jnp.asarray(rng.integers(0, 2, n), jnp.int32)
    valid = jnp.ones((n,), bool)
    substats = fam.stats_from_labels(x, valid, labels0, bits0, k)
    stats = jax.tree.map(lambda a: jnp.sum(a, axis=1), substats)
    params = fam.sample_posterior(jax.random.key(seed), prior, stats)
    subparams = fam.sample_posterior(jax.random.key(seed + 1), prior,
                                     substats)
    active = jnp.arange(k) < (k - 1 if k > 1 else 1)
    logw = jnp.where(active, jnp.asarray(
        rng.normal(-1.5, 0.3, k), jnp.float32), gibbs.NEG_INF)
    sublogw = jnp.asarray(rng.normal(-0.7, 0.1, (k, 2)), jnp.float32)
    gidx = jnp.arange(n, dtype=jnp.uint32)
    key_data = prng.key_words(jax.random.key(seed + 2))
    return fam, x, valid, params, subparams, active, logw, sublogw, \
        gidx, key_data


# ---------------------------------------------------------------------------
# threefry / gumbel
# ---------------------------------------------------------------------------
def test_threefry_matches_jax_prng():
    """Our counter-based Threefry-2x32 is bit-for-bit JAX's own."""
    try:
        from jax._src.prng import threefry_2x32
    except ImportError:
        pytest.skip("jax internal threefry not importable")
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.integers(0, 2**32, 2), jnp.uint32)
    c = jnp.asarray(rng.integers(0, 2**32, (2, 64)), jnp.uint32)
    y0, y1 = prng.threefry2x32(k[0], k[1], c[0], c[1])
    want = np.asarray(threefry_2x32(k, jnp.concatenate([c[0], c[1]])))
    assert np.array_equal(np.concatenate([y0, y1]), want)


def test_gumbel_moments():
    g = prng.gumbel(prng.key_words(jax.random.key(0)),
                    jnp.arange(200_000, dtype=jnp.uint32)[:, None],
                    jnp.arange(2, dtype=jnp.uint32)[None, :])
    assert bool(jnp.isfinite(g).all())
    assert abs(float(g.mean()) - 0.5772) < 0.01      # Euler-Mascheroni
    assert abs(float(g.var()) - 1.6449) < 0.02       # pi^2 / 6


# ---------------------------------------------------------------------------
# step (e): fused assignment vs reference argmax
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,k,d", SHAPES)
@pytest.mark.parametrize("name", ALL)
def test_assign_fused_labels_identical(name, n, k, d):
    fam, x, _, params, _, active, logw, _, gidx, key_data = _setup(
        name, n, k, d)
    fused = fam._assign_fused(x, params, logw, active, gidx, key_data)
    assert fused is not None, "fused path unexpectedly guarded out"
    ref = fam.assign(x, params, logw, active, gidx, key_data,
                     use_pallas=False)
    assert fused.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(ref))
    # labels only ever point at active clusters
    assert bool(active[np.asarray(ref)].all())


# ---------------------------------------------------------------------------
# step (f): fused own-cluster sub-assignment vs chunked-gather reference
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,k,d", SHAPES)
@pytest.mark.parametrize("name", ALL)
def test_sub_assign_fused_labels_identical(name, n, k, d):
    fam, x, _, params, subparams, active, logw, sublogw, gidx, key_data = \
        _setup(name, n, k, d)
    labels = fam.assign(x, params, logw, active, gidx, key_data)
    fused = fam._sub_assign_fused(x, subparams, sublogw, labels, gidx,
                                  key_data)
    assert fused is not None, "fused path unexpectedly guarded out"
    ref = fam.sub_assign(x, subparams, sublogw, labels, gidx, key_data,
                         use_pallas=False, chunk=64)   # force >1 map step
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(ref))
    assert set(np.unique(np.asarray(ref))) <= {0, 1}


def test_sub_assign_reference_chunking_invariant():
    """The chunk size is a pure performance knob."""
    fam, x, _, params, subparams, active, logw, sublogw, gidx, key_data = \
        _setup("gaussian", 130, 7, 5)
    labels = fam.assign(x, params, logw, active, gidx, key_data)
    outs = [np.asarray(fam.sub_assign(x, subparams, sublogw, labels, gidx,
                                      key_data, chunk=c))
            for c in (1000, 64, 13)]
    assert np.array_equal(outs[0], outs[1])
    assert np.array_equal(outs[0], outs[2])


# ---------------------------------------------------------------------------
# label-indexed suff-stats: reference AND Pallas vs the dense oracle
# ---------------------------------------------------------------------------
def _dense_oracle(fam, x, valid, labels, sublabels, k):
    """The pre-fusion formulation: dense resp x subresp matmuls."""
    resp = jax.nn.one_hot(labels, k, dtype=x.dtype) * valid[:, None]
    sub = jax.nn.one_hot(sublabels, 2, dtype=x.dtype)
    subresp = resp[:, :, None] * sub[:, None, :]
    return fam.stats_from_points(x, subresp)


@pytest.mark.parametrize("n,k,d", SHAPES)
@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["reference", "pallas"])
@pytest.mark.parametrize("name", ALL)
def test_stats_from_labels_matches_dense_oracle(name, use_pallas, n, k, d):
    fam = get_family(name)
    rng = np.random.default_rng(n + k + d)
    x = jnp.asarray(_data(name, n, d, rng))
    labels = jnp.asarray(rng.integers(0, k, n), jnp.int32)
    sublabels = jnp.asarray(rng.integers(0, 2, n), jnp.int32)
    valid = jnp.asarray(rng.random(n) < 0.9)        # exercise padding mask
    got = fam.stats_from_labels(x, valid, labels, sublabels, k,
                                use_pallas=use_pallas)
    want = _dense_oracle(fam, x, valid.astype(x.dtype), labels, sublabels, k)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                                rtol=1e-4, atol=1e-3),
        got, want)
    # cluster stats are the exact fold over the sub axis
    folded = jax.tree.map(lambda a: jnp.sum(a, axis=1), got)
    resp = jax.nn.one_hot(labels, k, dtype=x.dtype) \
        * valid.astype(x.dtype)[:, None]
    full = fam.stats_from_points(x, resp)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                                rtol=1e-4, atol=1e-3),
        folded, full)


# ---------------------------------------------------------------------------
# feature-sharded parity (the high-d regime, DESIGN §10)
# ---------------------------------------------------------------------------
def _feat_mesh():
    from jax.sharding import Mesh
    if jax.device_count() < 4:
        pytest.skip("needs 4 devices (tests/conftest.py sets 4)")
    return Mesh(np.array(jax.devices()[:4]).reshape(2, 2),
                ("data", "model"))


@pytest.mark.parametrize("name", SHARDABLE)
def test_assign_feature_sharded_identical(name):
    from jax.sharding import PartitionSpec as P
    from repro.core.distributed import shard_map
    mesh = _feat_mesh()
    n, k, d = 128, 8, 8
    fam, x, _, params, subparams, active, logw, sublogw, _, key_data = \
        _setup(name, n, k, d)
    gidx = jnp.arange(n, dtype=jnp.uint32)
    plain = fam.assign(x, params, logw, active, gidx, key_data)
    sub_plain = fam.sub_assign(x, subparams, sublogw, plain, gidx, key_data)

    def f(xs, params, subparams, logw, sublogw, active, key_data):
        gi = gibbs.global_indices(xs.shape[0], ("data",))
        lab = fam.assign(xs, params, logw, active, gi, key_data,
                         feat_axis="model")
        sub = fam.sub_assign(xs, subparams, sublogw, lab, gi, key_data,
                             feat_axis="model", chunk=16)
        return lab, sub

    rep = jax.tree.map(lambda _: P(), (params, subparams))
    got, sub_got = jax.jit(shard_map(
        f, mesh=mesh,
        in_specs=(P("data", "model"), rep[0], rep[1], P(), P(), P(), P()),
        out_specs=(P("data"), P("data"))))(
            x, params, subparams, logw, sublogw, active, key_data)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(plain))
    np.testing.assert_array_equal(np.asarray(sub_got), np.asarray(sub_plain))


# ---------------------------------------------------------------------------
# structural guarantee: no (N, K, 2) intermediate anywhere in the sweep
# ---------------------------------------------------------------------------
def _walk_avals(jaxpr):
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            yield v.aval
        for p in eqn.params.values():
            yield from _walk_param(p)


def _walk_param(p):
    if isinstance(p, jax.core.ClosedJaxpr):
        yield from _walk_avals(p.jaxpr)
    elif isinstance(p, jax.core.Jaxpr):
        yield from _walk_avals(p)
    elif isinstance(p, (list, tuple)):
        for q in p:
            yield from _walk_param(q)


@pytest.mark.parametrize("name", ALL)
def test_sweep_jaxpr_has_no_all_k_subcluster_loglik(name):
    """Step (f) must not evaluate all K clusters' sub-logliks: the sweep's
    jaxpr (reference path — kernels are opaque anyway) contains no
    (N, k_max, 2) intermediate at all."""
    from repro.core.sampler import _init_local
    n, k_max, d = 96, 8, 3
    fam = get_family(name)
    rng = np.random.default_rng(0)
    x = jnp.asarray(_data(name, n, d, rng))
    valid = jnp.ones((n,), bool)
    cfg = DPMMConfig(component=name, init_clusters=3, k_max=k_max)
    prior = fam.build_prior(cfg, x)
    model, point = _init_local(jax.random.key(0), x, valid, prior=prior,
                               family=fam, cfg=cfg, axes=(), k_max=k_max)
    jaxpr = jax.make_jaxpr(
        lambda m, p, xx: gibbs.sweep(m, p, xx, prior, fam, 10.0, ()))(
            model, point, x)
    shapes = {tuple(a.shape) for a in _walk_avals(jaxpr.jaxpr)
              if hasattr(a, "shape")}
    assert (n, k_max, 2) not in shapes, (
        "found an (N, K, 2) intermediate: step (f) is evaluating all-K "
        "sub-cluster logliks again")
