"""Golden-chain fingerprints: unintended chain drift becomes EXPLICIT.

The repo's parity suites prove invariances *within* a run (tiled ==
resident, fused == three-pass, chains == single-chain fits), but nothing
pins the chain itself: a change like PR 3's ``fold_in`` normalization
silently re-rolled every chain and only a careful reader of CHANGES.md
would know. This suite hashes the labels and full history of a
fixed-seed 30-iteration fit per family on BOTH data planes against
``tests/goldens/chains.json``; any drift fails a dedicated CI job.

When a chain change is *intended* (a key-derivation fix, a new fold
order), regenerate and commit the goldens deliberately:

    PYTHONPATH=src python -m pytest tests/test_golden_chains.py -q \
        --update-goldens

Environment contract: fingerprints are taken on the pinned CI jax
version with the conftest's 4 virtual CPU devices — that is the
environment the golden job provides. The latest-stable matrix leg does
NOT run this suite (XLA codegen may legitimately differ across
versions).
"""
import hashlib
import json
import os

import numpy as np
import pytest

from repro.configs import DPMMConfig
from repro.core.gibbs import STATS_BLOCK
from repro.core.sampler import DPMM
from repro.data.synthetic import generate_gmm, generate_mnmm, generate_pmm

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "goldens",
                           "chains.json")
FAMILIES = ("gaussian", "diag_gaussian", "multinomial", "poisson")
PLANES = ("resident", "tiled")
ITERS = 30


def _data(name):
    if name in ("gaussian", "diag_gaussian"):
        return generate_gmm(2400, 4, 4, seed=0, sep=10.0)[0]
    if name == "poisson":
        return generate_pmm(2400, 4, 4, seed=0)[0]
    return generate_mnmm(2400, 16, 4, seed=0)[0]


def _hash(arr) -> str:
    a = np.ascontiguousarray(np.asarray(arr))
    h = hashlib.sha256()
    h.update(str(a.dtype).encode())
    h.update(str(a.shape).encode())
    h.update(a.tobytes())
    return h.hexdigest()[:16]


def _fingerprint(result) -> dict:
    return {
        "labels": _hash(result.labels),
        "k": int(result.k),
        "history": {k: _hash(v) for k, v in sorted(result.history.items())},
    }


def _fit(family: str, plane: str):
    cfg = DPMMConfig(
        component=family, alpha=10.0, iters=ITERS, k_max=16, burnout=4,
        tile_size=(STATS_BLOCK if plane == "tiled" else None))
    return DPMM(cfg).fit(_data(family))


def test_golden_chains(request):
    """One fixed-seed fit per (family, plane); all 8 fingerprints must
    match the committed goldens bit for bit."""
    update = request.config.getoption("--update-goldens")
    fresh = {}
    for family in FAMILIES:
        for plane in PLANES:
            fresh[f"{family}/{plane}"] = _fingerprint(_fit(family, plane))

    # internal sanity: the two planes are the SAME chain (the tiled-parity
    # contract) — if this trips, the golden diff is a plane bug, not drift
    for family in FAMILIES:
        assert (fresh[f"{family}/resident"] == fresh[f"{family}/tiled"]), (
            f"{family}: resident and tiled fingerprints diverged — "
            "tiled-parity violation, not ordinary chain drift")

    if update:
        os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
        with open(GOLDEN_PATH, "w") as f:
            json.dump(fresh, f, indent=2, sort_keys=True)
            f.write("\n")
        pytest.skip(f"goldens rewritten at {GOLDEN_PATH}; commit the diff")

    assert os.path.exists(GOLDEN_PATH), (
        f"no goldens at {GOLDEN_PATH}; generate with --update-goldens")
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)

    drifted = []
    for key, fp in fresh.items():
        if key not in golden:
            drifted.append(f"{key}: missing from goldens")
            continue
        for field, value in fp.items():
            if golden[key].get(field) != value:
                drifted.append(
                    f"{key}.{field}: golden {golden[key].get(field)!r} "
                    f"!= fresh {value!r}")
    assert not drifted, (
        "golden chain drift — chains changed for the same seed. If "
        "intended (key-derivation/fold-order change), regenerate with "
        "--update-goldens and commit; otherwise find the unintended "
        "float/PRNG change:\n  " + "\n  ".join(drifted))


def test_hash_is_content_sensitive():
    """The fingerprint distinguishes values, dtype, and shape."""
    a = np.arange(6, dtype=np.int32)
    assert _hash(a) == _hash(a.copy())
    assert _hash(a) != _hash(a.astype(np.float32))
    assert _hash(a) != _hash(a.reshape(2, 3))
    b = a.copy()
    b[3] += 1
    assert _hash(a) != _hash(b)
