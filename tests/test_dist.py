"""Distributed sampling chaos suite (ISSUE 9): the elastic
coordinator/worker driver (repro.dist) must be *bitwise* the
single-process tiled driver, and must stay so under real failures.

Covers:

 - the wire protocol (repro.dist.proto): lossless roundtrip; truncated /
   bit-flipped / bad-magic / oversize frames raise ``ProtocolError`` and
   never deadlock;
 - 2-worker fits bitwise identical (labels, full history, stats,
   substats; params to f32 ULPs) to the single-process tiled fit, for
   every registered family;
 - failover: a worker SIGKILL'd mid-fit and a worker hung on an injected
   I/O hang both fail over (range reassigned to survivors, respawn
   within budget) and the fit completes **bitwise identical** to the
   clean run with a ``worker_failover`` recovery event;
 - straggler tolerance: injected ``slow_read`` latency never trips a
   failover and leaves the chain bitwise clean;
 - typed exhaustion: with no survivors and the respawn budget spent the
   fit raises ``WorkerLostError`` carrying the failover log;
 - coordinator death: a distributed fit SIGKILL'd mid-run resumes from
   its auto-checkpoint rotation — still distributed — to the bitwise
   chain;
 - config/CLI plumbing: cfg.workers validation, --workers end to end.

The comparisons pin ``mesh=make_data_mesh(1)`` and
``tile_size=STATS_BLOCK``: the distributed driver runs on a 1-device
mesh by contract (its fold replay is the sequential 1-shard fold), and
tile size is already proven bitwise-neutral (test_tiled_parity).
"""
import json
import os
import signal
import socket
import struct
import subprocess
import sys
import textwrap
import threading
import zlib

import numpy as np
import pytest

import jax

from repro.configs import DPMMConfig
from repro.core import checkpoint as ckpt
from repro.core.distributed import make_data_mesh
from repro.core.gibbs import STATS_BLOCK
from repro.core.resilience import WorkerLostError
from repro.core.sampler import DPMM
from repro.dist import DistHooks, proto
from repro.dist.coordinator import shard_ranges
from repro.dist.proto import ProtocolError
from repro.data.synthetic import generate_gmm, generate_mnmm, generate_pmm

ALL = ("gaussian", "diag_gaussian", "multinomial", "poisson")
N, D, K_MAX = 3000, 4, 16          # 3 STATS_BLOCK blocks: 2 ranges @ W=2


def _data(name, n=N, d=D, k=4):
    if name in ("gaussian", "diag_gaussian"):
        return generate_gmm(n, d, k, seed=0, sep=10.0)
    if name == "poisson":
        return generate_pmm(n, d, k, seed=0)
    return generate_mnmm(n, 16, k, seed=0)


def _cfg(name="gaussian", **kw):
    base = dict(component=name, alpha=10.0, iters=6, k_max=K_MAX,
                burnout=2, tile_size=STATS_BLOCK)
    base.update(kw)
    return DPMMConfig(**base)


def _single(name, x, **kw):
    return DPMM(_cfg(name, **kw), mesh=make_data_mesh(1)).fit(x)


def _assert_bitwise(a, b, what):
    assert np.array_equal(a.labels, b.labels), f"{what}: labels differ"
    for key in a.history:
        assert np.array_equal(a.history[key], b.history[key]), (
            f"{what}: history[{key}] differs")
    for name in ("stats", "substats"):
        for la, lb in zip(jax.tree_util.tree_leaves(getattr(a.state, name)),
                          jax.tree_util.tree_leaves(getattr(b.state, name))):
            assert np.array_equal(np.asarray(la), np.asarray(lb)), (
                f"{what}: {name} differ")
    for la, lb in zip(jax.tree_util.tree_leaves(a.state.params),
                      jax.tree_util.tree_leaves(b.state.params)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"{what}: params diverged "
                                           "beyond compilation-level ULPs")


# ---------------------------------------------------------------------------
# wire protocol: typed failure, no deadlock
# ---------------------------------------------------------------------------
def _pair():
    a, b = socket.socketpair()
    a.settimeout(5.0)
    b.settimeout(5.0)             # any hang surfaces as socket.timeout
    return a, b


def test_proto_roundtrip_lossless():
    a, b = _pair()
    arrays = {"x": np.arange(12, dtype=np.float32).reshape(3, 4),
              "lab": np.array([1, 2, 3], np.int32)}
    proto.send_msg(a, "work", {"lo": 0, "hi": 3}, arrays)
    kind, meta, got = proto.recv_msg(b)
    assert kind == "work" and meta == {"lo": 0, "hi": 3}
    for k, v in arrays.items():
        assert got[k].dtype == v.dtype
        np.testing.assert_array_equal(got[k], v)
    a.close(), b.close()


def test_proto_tree_roundtrip():
    from repro.dist.worker import plan_template
    tpl = plan_template(K_MAX, D)
    packed = proto.pack_tree(tpl, "plan")
    rebuilt = proto.unpack_tree(tpl, packed, "plan")
    for la, lb in zip(jax.tree_util.tree_leaves(tpl),
                      jax.tree_util.tree_leaves(rebuilt)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    with pytest.raises(ProtocolError, match="missing pytree leaf"):
        proto.unpack_tree(tpl, dict(list(packed.items())[:-1]), "plan")


def _frame(kind="work", meta=None, arrays=None):
    """A valid wire frame, captured for mutation."""
    a, b = _pair()
    proto.send_msg(a, kind, meta, arrays)
    chunks = []
    a.close()
    while True:
        c = b.recv(1 << 20)
        if not c:
            break
        chunks.append(c)
    b.close()
    return b"".join(chunks)


def _recv_of(raw):
    """Feed raw bytes then EOF to a recv_msg call (bounded by timeout)."""
    a, b = _pair()
    a.sendall(raw)
    a.close()
    return proto.recv_msg(b)


def test_proto_truncated_frame_raises():
    raw = _frame(arrays={"x": np.ones((8, 8), np.float32)})
    for cut in (3, proto._HEADER.size - 1, proto._HEADER.size + 10,
                len(raw) - 1):
        with pytest.raises(ProtocolError, match="mid-frame"):
            _recv_of(raw[:cut])


def test_proto_bitflip_raises_crc():
    raw = bytearray(_frame(arrays={"x": np.ones((8, 8), np.float32)}))
    raw[proto._HEADER.size + 40] ^= 0x10       # flip one payload bit
    with pytest.raises(ProtocolError, match="CRC mismatch"):
        _recv_of(bytes(raw))


def test_proto_bad_magic_raises():
    raw = bytearray(_frame())
    raw[:4] = b"HTTP"
    with pytest.raises(ProtocolError, match="bad frame magic"):
        _recv_of(bytes(raw))


def test_proto_oversize_length_rejected_before_alloc():
    hdr = proto._HEADER.pack(proto.MAGIC, 0, proto.MAX_FRAME_BYTES + 1)
    with pytest.raises(ProtocolError, match="exceeds cap"):
        _recv_of(hdr)


def test_proto_garbage_payload_raises():
    payload = b"not an npz archive at all"
    raw = proto._HEADER.pack(proto.MAGIC, zlib.crc32(payload),
                             len(payload)) + payload
    with pytest.raises(ProtocolError, match="unparseable"):
        _recv_of(raw)


# ---------------------------------------------------------------------------
# shard layout
# ---------------------------------------------------------------------------
def test_shard_ranges_block_aligned_cover():
    for n, w in [(3000, 2), (3000, 3), (1024, 4), (100, 2), (5000, 1)]:
        r = shard_ranges(n, w, STATS_BLOCK)
        assert r[0][0] == 0 and r[-1][1] == n
        for (l0, h0, _), (l1, _h1, _2) in zip(r, r[1:]):
            assert h0 == l1                      # contiguous cover
            assert h0 % STATS_BLOCK == 0         # cut on the block grid
    # more workers than blocks: extras get no range (failover capacity)
    assert len(shard_ranges(100, 4, STATS_BLOCK)) == 1


# ---------------------------------------------------------------------------
# bitwise parity: distributed == single-process tiled, every family
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ALL)
def test_two_worker_fit_bitwise_all_families(name):
    x, _ = _data(name)
    single = _single(name, x)
    dist = DPMM(_cfg(name, workers=2)).fit(x)
    _assert_bitwise(single, dist, f"{name} workers=2")
    assert dist.dist["workers"] == 2
    assert dist.dist["shard_ranges"][0][0] == 0
    assert dist.dist["shard_ranges"][-1][1] == len(dist.labels)
    assert dist.dist["reassignments"] == 0 and dist.recoveries == []


# ---------------------------------------------------------------------------
# failover: SIGKILL, hang, straggler, exhaustion
# ---------------------------------------------------------------------------
def test_sigkill_failover_bitwise():
    """Kill worker 0 mid-fit: its range is reassigned to the survivor,
    the slot respawns, and the chain is bitwise the clean run's."""
    x, _ = _data("gaussian")
    single = _single("gaussian", x)
    killed = []

    def killer(it, coord):
        if it == 2 and not killed:
            pid = coord.worker_pids()[0]
            os.kill(pid, signal.SIGKILL)
            killed.append(pid)

    dist = DPMM(_cfg("gaussian", workers=2)).fit(
        x, dist_hooks=DistHooks(on_iteration=killer))
    assert killed, "hook never fired"
    _assert_bitwise(single, dist, "sigkill failover")
    ev = [e for e in dist.recoveries if e["kind"] == "worker_failover"]
    assert ev and ev[0]["worker"] == 0 and ev[0]["respawn"]
    assert dist.dist["reassignments"] >= 1
    assert dist.dist["respawns"] >= 1


def test_hang_failover_bitwise():
    """Worker 0's first shard read hangs (injected wedge, far beyond the
    deadline): heartbeats keep flowing, so only the per-work deadline
    can catch it. The coordinator kills the hung process, the survivor
    absorbs the range, and the chain stays bitwise clean."""
    x, _ = _data("gaussian")
    single = _single("gaussian", x)
    hooks = DistHooks(worker_faults={
        0: {"schedule": {0: "hang"}, "hang_s": 600.0}})
    dist = DPMM(_cfg("gaussian", workers=2, worker_deadline_s=20.0,
                     max_worker_retries=0)).fit(x, dist_hooks=hooks)
    _assert_bitwise(single, dist, "hang failover")
    ev = [e for e in dist.recoveries if e["kind"] == "worker_failover"]
    assert ev and ev[0]["worker"] == 0
    assert "deadline" in ev[0]["detail"]
    assert not ev[0]["respawn"]                  # budget was zero
    assert dist.dist["reassignments"] >= 1


def test_slow_read_is_not_a_failure():
    """Injected straggler latency (well under the deadline) must neither
    trip a failover nor perturb the chain."""
    x, _ = _data("gaussian")
    single = _single("gaussian", x)
    hooks = DistHooks(worker_faults={
        0: {"p_slow_read": 1.0, "slow_read_s": 0.01}})
    dist = DPMM(_cfg("gaussian", workers=2)).fit(x, dist_hooks=hooks)
    _assert_bitwise(single, dist, "slow_read")
    assert [e for e in dist.recoveries
            if e["kind"] == "worker_failover"] == []
    assert dist.dist["reassignments"] == 0


def test_worker_lost_error_when_no_survivors():
    """One worker, it hangs on every read, zero respawn budget: the fit
    must fail with the typed error carrying the failover log — not hang,
    not return garbage."""
    x, _ = _data("gaussian", n=1024)
    hooks = DistHooks(worker_faults={
        0: {"schedule": dict.fromkeys(range(100), "hang"),
            "hang_s": 600.0}})
    with pytest.raises(WorkerLostError, match="no live workers") as ei:
        DPMM(_cfg("gaussian", workers=1, worker_deadline_s=5.0,
                  max_worker_retries=0)).fit(x, dist_hooks=hooks)
    assert any(e["kind"] == "worker_failover"
               for e in ei.value.recoveries)


# ---------------------------------------------------------------------------
# coordinator death + resume
# ---------------------------------------------------------------------------
def test_coordinator_sigkill_then_resume_bitwise(tmp_path):
    """SIGKILL the *coordinator* mid-distributed-fit (right after a
    rotation save — workers die with it via EOF), then resume with
    --workers still on: the completed chain is bitwise the clean
    single-process run."""
    x, _ = _data("gaussian")
    xpath = str(tmp_path / "x.npy")
    np.save(xpath, x)
    pref = str(tmp_path / "kill")
    child = textwrap.dedent(f"""
        import os, signal
        import numpy as np
        from repro.configs import DPMMConfig
        from repro.core import checkpoint
        from repro.core.sampler import DPMM

        saves = [0]
        real = checkpoint.save_checkpoint
        def dying_save(*a, **kw):
            path = real(*a, **kw)
            saves[0] += 1
            if saves[0] == 2:
                os.kill(os.getpid(), signal.SIGKILL)
            return path
        checkpoint.save_checkpoint = dying_save

        x = np.load({xpath!r}, mmap_mode="r")
        cfg = DPMMConfig(component="gaussian", alpha=10.0, iters=8,
                         k_max={K_MAX}, burnout=2, workers=2,
                         tile_size={STATS_BLOCK},
                         checkpoint_path={pref!r}, checkpoint_every=2)
        DPMM(cfg).fit(x)
        raise SystemExit("fit survived the SIGKILL - test is broken")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in ("src", env.get("PYTHONPATH", "")) if p])
    if "host_platform_device_count" not in env.get("XLA_FLAGS", ""):
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=4"
                            ).strip()
    proc = subprocess.run([sys.executable, "-c", child], env=env,
                          capture_output=True, text=True,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == -signal.SIGKILL, (proc.returncode,
                                                proc.stdout, proc.stderr)
    members = ckpt.list_checkpoints(pref)
    assert members and members[0][0] == 4    # died right after saving it=4
    resumed = DPMM(_cfg("gaussian", workers=2, checkpoint_path=pref,
                        checkpoint_every=2)).fit(x, iters=8, resume=True)
    full = _single("gaussian", x, iters=8)
    # resume contract (same as tests/test_resilience.py): labels + final
    # state are bitwise the uninterrupted chain; the resumed history only
    # covers the REMAINING iterations, so it must equal the clean tail
    assert np.array_equal(resumed.labels, full.labels)
    for key in full.history:
        n_resumed = len(resumed.history[key])
        assert np.array_equal(resumed.history[key],
                              full.history[key][-n_resumed:]), (
            f"resumed history[{key}] != clean tail")
    for name in ("stats", "substats"):
        for la, lb in zip(
                jax.tree_util.tree_leaves(getattr(resumed.state, name)),
                jax.tree_util.tree_leaves(getattr(full.state, name))):
            assert np.array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# config + CLI plumbing
# ---------------------------------------------------------------------------
def test_workers_config_validation():
    with pytest.raises(ValueError, match="workers"):
        DPMMConfig(workers=0)
    with pytest.raises(ValueError, match="k_max"):
        DPMMConfig(workers=2, k_max="auto")
    with pytest.raises(ValueError, match="shard_features"):
        DPMMConfig(workers=2, shard_features=True)
    with pytest.raises(ValueError, match="worker_deadline_s"):
        DPMMConfig(workers=2, worker_deadline_s=0.0)
    with pytest.raises(ValueError, match="max_worker_retries"):
        DPMMConfig(workers=2, max_worker_retries=-1)


def test_workers_rejects_multichain():
    x, _ = _data("gaussian", n=1024)
    with pytest.raises(ValueError, match="n_chains"):
        DPMM(_cfg("gaussian", workers=2)).fit(x, n_chains=2)


def test_cli_workers_end_to_end(tmp_path):
    from repro.launch import sample_dpmm
    xpath = str(tmp_path / "x.npy")
    x, _ = _data("gaussian")
    np.save(xpath, x)
    params = str(tmp_path / "params.json")
    with open(params, "w") as f:
        json.dump({"k_max": K_MAX, "burnout": 2, "iters": 3,
                   "alpha": 10.0}, f)
    out = str(tmp_path / "result.json")
    sample_dpmm.main(["--data-path", xpath, "--workers", "2",
                      "--tile-size", str(STATS_BLOCK),
                      "--params-path", params, "--result-path", out])
    with open(out) as f:
        res = json.load(f)
    assert res["dist"]["workers"] == 2
    assert res["dist"]["shard_ranges"][0][0] == 0
    assert res["dist"]["shard_ranges"][-1][1] == N
    assert res["recoveries"] == []
    assert len(res["labels"]) == N
