"""Training substrate: optimizer math, loss chunking invariance, LR
schedule, checkpoint round-trip, end-to-end loss decrease."""
import functools
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import TrainConfig, smoke_config
from repro.data.pipeline import lm_batches
from repro.models import transformer
from repro.models.common import ShardingPolicy
from repro.train import checkpoint, init_train_state, train_step
from repro.train.loss import chunked_ce_loss
from repro.train.optimizer import (adamw_update, global_norm,
                                   init_opt_state, lr_schedule)

POLICY = ShardingPolicy(batch_sharded=False, seq_shard=False)


def test_lr_schedule_shape():
    cfg = TrainConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(jnp.asarray(s), cfg)) for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert np.argmax(lrs) <= 3                      # peak right after warmup
    assert lrs[-1] < 0.2 * max(lrs)                 # decays
    assert lrs[-1] > 0.05 * max(lrs)                # but not to zero


def test_adamw_matches_reference_scalar():
    """One AdamW step on a scalar matches the closed-form update."""
    cfg = TrainConfig(lr=0.1, warmup_steps=0, total_steps=10,
                      weight_decay=0.0, grad_clip=1e9)
    p = {"w": jnp.asarray([[2.0]])}
    g = {"w": jnp.asarray([[0.5]])}
    opt = init_opt_state(p)
    new_p, new_opt, _ = adamw_update(g, opt, p, cfg)
    # step 1: mhat = g, vhat = g^2 => delta = g/(|g|+eps) = 1.0
    lr1 = float(lr_schedule(jnp.asarray(1), cfg))
    assert np.isclose(float(new_p["w"][0, 0]), 2.0 - lr1 * 1.0, atol=1e-5)
    assert int(new_opt.step) == 1


def test_grad_clip_scales():
    cfg = TrainConfig(lr=0.0, grad_clip=1.0, warmup_steps=0, total_steps=1)
    g = {"w": jnp.full((10,), 10.0)}
    assert float(global_norm(g)) > 1.0
    p = {"w": jnp.zeros((10,))}
    _, opt, metrics = adamw_update(g, init_opt_state(p), p, cfg)
    # moments saw the clipped gradient: ||m|| = (1-b1) * clip * unit
    m = opt.mu["w"]
    np.testing.assert_allclose(float(jnp.linalg.norm(m / 0.1)), 1.0,
                               rtol=1e-4)


def test_weight_decay_only_on_matrices():
    cfg = TrainConfig(lr=0.1, weight_decay=0.5, warmup_steps=0,
                      total_steps=10, grad_clip=1e9)
    p = {"mat": jnp.ones((2, 2)), "vec": jnp.ones((2,))}
    g = jax.tree.map(jnp.zeros_like, p)
    new_p, _, _ = adamw_update(g, init_opt_state(p), p, cfg)
    assert float(new_p["mat"][0, 0]) < 1.0          # decayed
    assert float(new_p["vec"][0]) == 1.0            # not decayed


@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_ce_loss_chunk_invariance(chunk):
    """The chunked CE is exactly the full CE for any chunk size."""
    cfg = smoke_config("granite-8b")
    params = transformer.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 64), 0, cfg.vocab_size)
    hidden, _ = transformer.hidden_forward(params, toks, cfg, POLICY,
                                           remat=False)
    tgts = jnp.roll(toks, -1, axis=1)
    loss_c, _ = chunked_ce_loss(hidden, tgts, params["embed"], cfg, chunk)
    # reference: full softmax CE
    from repro.models import common
    logits = common.unembed(hidden, params["embed"], cfg.final_softcap)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, tgts[..., None], axis=-1)[..., 0]
    want = float(jnp.mean(logz - tgt))
    assert np.isclose(float(loss_c), want, rtol=1e-5)


def test_ce_loss_masking():
    cfg = smoke_config("granite-8b")
    params = transformer.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (1, 32), 0, cfg.vocab_size)
    hidden, _ = transformer.hidden_forward(params, toks, cfg, POLICY,
                                           remat=False)
    tgts = jnp.roll(toks, -1, axis=1)
    masked = tgts.at[:, 16:].set(-1)
    full, m_full = chunked_ce_loss(hidden, tgts, params["embed"], cfg, 8)
    half, m_half = chunked_ce_loss(hidden, masked, params["embed"], cfg, 8)
    assert float(m_half["tokens"]) == 16
    assert float(m_full["tokens"]) == 32
    assert not np.isclose(float(full), float(half))


def test_remat_matches_no_remat():
    """jax.checkpoint changes memory, never values."""
    cfg = smoke_config("gemma2-9b")
    params = transformer.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    a, _ = transformer.forward(params, toks, cfg, POLICY, remat=True)
    b, _ = transformer.forward(params, toks, cfg, POLICY, remat=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_loss_decreases_end_to_end():
    cfg = smoke_config("starcoder2-7b")
    tcfg = TrainConfig(lr=1e-3, warmup_steps=5, total_steps=40,
                       loss_chunk=32)
    state = init_train_state(jax.random.key(0), cfg)
    step = jax.jit(functools.partial(train_step, cfg=cfg, tcfg=tcfg,
                                     policy=POLICY))
    gen = lm_batches(cfg.vocab_size, 4, 64, seed=0)
    losses = []
    for _ in range(25):
        toks, tgts = next(gen)
        state, m = step(state, {"tokens": jnp.asarray(toks),
                                "targets": jnp.asarray(tgts)})
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.15, losses


def test_checkpoint_roundtrip(tmp_path):
    cfg = smoke_config("granite-8b")
    state = init_train_state(jax.random.key(0), cfg)
    path = os.path.join(tmp_path, "ckpt.npz")
    checkpoint.save(path, state.params)
    restored = checkpoint.restore(path, state.params)
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    cfg = smoke_config("granite-8b")
    state = init_train_state(jax.random.key(0), cfg)
    path = os.path.join(tmp_path, "ckpt.npz")
    checkpoint.save(path, state.params)
    import dataclasses
    bigger = transformer.init_params(
        jax.random.key(1), dataclasses.replace(cfg, d_model=512,
                                               head_dim=128))
    with pytest.raises((ValueError, KeyError)):
        checkpoint.restore(path, bigger)
