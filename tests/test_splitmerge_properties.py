"""Property tests for the split/merge decision math (paper §4.1/§4.3).

``propose_merges`` thins all-pairs MH acceptances to a *disjoint matching*
by descending log-H priority (no three clusters may merge in one step).
These tests verify the thinning against an independent numpy greedy oracle
on randomized stats/masks, and that the decision fields are mutually
consistent.

Chain-regression note: ``propose_splits`` now derives its uniform draws
via ``jax.random.fold_in(key, 0)`` instead of the old one-way
``jax.random.split(key, 1)`` — the only split() oddity in otherwise
fold_in-based key plumbing. Chains therefore differ from pre-tiled-data-
plane versions at the same seed. No test in this repo pins golden labels
(they assert run-vs-run equality or NMI/K ranges), so no goldens needed
updating; if you bisect a chain change to that commit, this is why.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import DPMMConfig
from repro.core import splitmerge
from repro.core.family import get_family
from repro.core.splitmerge import _pair_log_h, propose_merges


def _random_case(seed, k_max=12, d=3):
    """Random stats with overlapping clusters (so merges actually fire)
    and a random active mask."""
    rng = np.random.default_rng(seed)
    fam = get_family("gaussian")
    n = 600
    # overlapping blobs: many pairs have log_H_merge > 0
    centers = rng.normal(0, 1.0, (k_max, d))
    labels = rng.integers(0, k_max, n)
    x = jnp.asarray(centers[labels] + rng.normal(0, 1.0, (n, d)),
                    jnp.float32)
    resp = jax.nn.one_hot(jnp.asarray(labels), k_max, dtype=jnp.float32)
    active = jnp.asarray(rng.random(k_max) < 0.7)
    # inactive clusters keep junk stats on purpose: decisions must mask them
    stats = fam.stats_from_points(x, resp)
    prior = fam.build_prior(DPMMConfig(), x)
    return fam, prior, stats, active


def _recompute_acceptance(key, fam, prior, stats, active, alpha):
    """The pre-thinning acceptance set, recomputed exactly as
    propose_merges draws it (same key, same order)."""
    k_max = active.shape[0]
    iu, ju = np.triu_indices(k_max, k=1)
    log_h = np.asarray(_pair_log_h(prior, fam, stats, alpha,
                                   jnp.asarray(iu), jnp.asarray(ju)))
    u = np.asarray(jax.random.uniform(key, iu.shape, minval=1e-12))
    pair_valid = np.asarray(active)[iu] & np.asarray(active)[ju]
    accept = pair_valid & (np.log(u) < log_h)
    return iu, ju, log_h, accept


def _greedy_matching(iu, ju, log_h, accept, k_max):
    """Independent oracle: keep accepted pairs in descending log_h, skip
    any pair with an already-claimed endpoint."""
    taken = np.zeros(k_max, bool)
    keep = np.zeros(len(iu), bool)
    for p in np.argsort(np.where(accept, -log_h, np.inf), kind="stable"):
        if not accept[p]:
            continue
        a, b = iu[p], ju[p]
        if not taken[a] and not taken[b]:
            taken[a] = taken[b] = True
            keep[p] = True
    return keep


ALPHA = 10.0
SEEDS = list(range(8))


@pytest.mark.parametrize("seed", SEEDS)
def test_kept_set_is_a_matching(seed):
    """No cluster participates in two merges (paper §4.3: at most two
    clusters merge into one per step)."""
    fam, prior, stats, active = _random_case(seed)
    key = jax.random.key(100 + seed)
    dec = propose_merges(key, active, stats, prior, fam, ALPHA)
    merged = np.asarray(dec.merged)
    into = np.asarray(dec.into)
    side = np.asarray(dec.side)
    k_max = merged.shape[0]
    # every absorbed cluster names a distinct kept partner, and that
    # partner is merged with side 0 and absorbs exactly one cluster
    absorbed = np.where(side == 1)[0]
    kept = into[absorbed]
    assert len(set(kept)) == len(kept), "a cluster absorbed two others"
    assert not np.isin(kept, absorbed).any(), "an absorbed cluster absorbs"
    for b in absorbed:
        assert merged[b] and merged[into[b]] and side[into[b]] == 0
        assert into[into[b]] == into[b], "kept cluster must map to itself"
    # merged is exactly the union of kept and absorbed endpoints
    assert set(np.where(merged)[0]) == set(absorbed) | set(kept)


@pytest.mark.parametrize("seed", SEEDS)
def test_thinning_matches_descending_logh_oracle(seed):
    """The kept matching equals the greedy descending-log-H oracle —
    priority order is respected, not just any maximal matching."""
    fam, prior, stats, active = _random_case(seed)
    key = jax.random.key(100 + seed)
    dec = propose_merges(key, active, stats, prior, fam, ALPHA)
    k_max = np.asarray(active).shape[0]
    iu, ju, log_h, accept = _recompute_acceptance(
        key, fam, prior, stats, active, ALPHA)
    assert accept.any(), "degenerate case: no accepted pairs at all"
    keep = _greedy_matching(iu, ju, log_h, accept, k_max)
    exp_into = np.arange(k_max)
    exp_into[ju[keep]] = iu[keep]
    exp_side = np.zeros(k_max, np.int32)
    exp_side[ju[keep]] = 1
    exp_merged = np.zeros(k_max, bool)
    exp_merged[iu[keep]] = True
    exp_merged[ju[keep]] = True
    assert np.array_equal(np.asarray(dec.merged), exp_merged)
    assert np.array_equal(np.asarray(dec.into), exp_into)
    assert np.array_equal(np.asarray(dec.side), exp_side)


@pytest.mark.parametrize("seed", SEEDS)
def test_new_active_into_side_consistent(seed):
    """new_active = active minus absorbed; into is identity off the
    matching and endpoint-consistent on it; inactive clusters never
    participate."""
    fam, prior, stats, active = _random_case(seed)
    key = jax.random.key(100 + seed)
    dec = propose_merges(key, active, stats, prior, fam, ALPHA)
    active = np.asarray(active)
    merged = np.asarray(dec.merged)
    into = np.asarray(dec.into)
    side = np.asarray(dec.side)
    new_active = np.asarray(dec.new_active)
    absorbed = side == 1
    assert np.array_equal(new_active, active & ~absorbed)
    assert not merged[~active].any(), "inactive cluster merged"
    assert np.array_equal(into[~merged], np.arange(len(into))[~merged])
    assert (side[~merged] == 0).all()
    # labels relabeled through the decision stay on active clusters
    labels = jnp.asarray(np.where(active)[0][
        np.random.default_rng(seed).integers(0, active.sum(), 200)],
        dtype=jnp.int32)
    sublabels = jnp.zeros_like(labels)
    z, zb = splitmerge.relabel_after_merge(labels, sublabels, dec)
    assert new_active[np.asarray(z)].all()
    # absorbed points land on side 1, kept points on side 0
    was = merged[np.asarray(labels)]
    assert np.array_equal(np.asarray(zb)[was],
                          side[np.asarray(labels)[was]])


@pytest.mark.parametrize("n_active", [0, 1])
def test_no_valid_pairs_is_identity(n_active):
    """With fewer than two active clusters there is no valid pair, so the
    decision must be the exact identity on the active mask — junk stats in
    inactive slots must not leak through."""
    rng = np.random.default_rng(0)
    fam = get_family("gaussian")
    k_max, d = 8, 2
    x = jnp.asarray(rng.normal(0, 1, (400, d)), jnp.float32)
    resp = jax.nn.one_hot(
        jnp.asarray(rng.integers(0, k_max, 400)), k_max, dtype=jnp.float32)
    stats = fam.stats_from_points(x, resp)
    prior = fam.build_prior(DPMMConfig(), x)
    active = jnp.arange(k_max) < n_active
    dec = propose_merges(jax.random.key(1), active, stats, prior, fam,
                         ALPHA)
    assert not np.asarray(dec.merged).any()
    assert np.array_equal(np.asarray(dec.into), np.arange(k_max))
    assert (np.asarray(dec.side) == 0).all()
    assert np.array_equal(np.asarray(dec.new_active), np.asarray(active))
