"""Per-kernel allclose vs the pure-jnp oracles (ref.py), with shape/dtype
sweeps + hypothesis property tests. Kernels run interpret=True on CPU."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

# property tests need hypothesis (requirements-dev.txt)
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# matmul ('Kernel #1')
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n", [
    (1, 1, 1), (7, 3, 5), (37, 65, 129), (128, 128, 128),
    (256, 64, 512), (130, 200, 50), (128, 1, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_shapes(m, k, n, dtype):
    a = jnp.asarray(RNG.normal(size=(m, k)), dtype)
    b = jnp.asarray(RNG.normal(size=(k, n)), dtype)
    got = ops.matmul_pallas(a, b)
    want = ref.matmul(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 200), k=st.integers(1, 200), n=st.integers(1, 200),
       bm=st.sampled_from([32, 128]), bn=st.sampled_from([32, 128]),
       bk=st.sampled_from([32, 128]))
def test_matmul_property(m, k, n, bm, bn, bk):
    """Any (shape, block) combination matches XLA dot."""
    rng = np.random.default_rng(m * 7919 + k * 31 + n)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    got = ops.matmul_pallas(a, b, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, ref.matmul(a, b), rtol=1e-4, atol=1e-4)


def test_matmul_auto_dispatch():
    """Below/above the crossover both dispatch paths agree (the paper's
    auto-selection is a pure performance choice, never a numerics one)."""
    a_small = jnp.asarray(RNG.normal(size=(100, 100)), jnp.float32)
    b_small = jnp.asarray(RNG.normal(size=(100, 100)), jnp.float32)
    a_big = jnp.asarray(RNG.normal(size=(1000, 1000)), jnp.float32)
    b_big = jnp.asarray(RNG.normal(size=(1000, 1000)), jnp.float32)
    np.testing.assert_allclose(ops.matmul_auto(a_small, b_small),
                               ref.matmul(a_small, b_small), rtol=1e-5)
    np.testing.assert_allclose(ops.matmul_auto(a_big, b_big),
                               ref.matmul(a_big, b_big), rtol=1e-5)


# ---------------------------------------------------------------------------
# loglik (`dcolwise_dot_all`)
# ---------------------------------------------------------------------------
def _gauss_inputs(n, k, d, rng):
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    mu = jnp.asarray(rng.normal(size=(k, d)), jnp.float32)
    f = jnp.asarray(rng.normal(size=(k, d, d)) * 0.3
                    + np.eye(d), jnp.float32)
    ld = jnp.asarray(rng.normal(size=(k,)), jnp.float32)
    return x, mu, f, ld


@pytest.mark.parametrize("n,k,d", [
    (1, 1, 1), (100, 7, 3), (256, 16, 32), (33, 5, 64),
    (128, 64, 2), (500, 3, 128),
])
def test_loglik_shapes(n, k, d):
    x, mu, f, ld = _gauss_inputs(n, k, d, np.random.default_rng(n + k + d))
    got = ops.loglik_pallas(x, mu, f, ld)
    want = ref.loglik(x, mu, f, ld)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 300), k=st.integers(1, 40), d=st.integers(1, 48))
def test_loglik_property(n, k, d):
    x, mu, f, ld = _gauss_inputs(n, k, d, np.random.default_rng(n * k + d))
    got = ops.loglik_pallas(x, mu, f, ld)
    want = ref.loglik(x, mu, f, ld)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_loglik_matches_niw_module():
    """Kernel oracle == the sampler's own likelihood (core/niw.py)."""
    from repro.core import niw
    rng = np.random.default_rng(3)
    x, mu, f, ld = _gauss_inputs(64, 8, 4, rng)
    params = niw.GaussParams(mu=mu, chol_prec=f, logdet_prec=ld)
    np.testing.assert_allclose(ref.loglik(x, mu, f, ld),
                               niw.loglik(x, params), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# suffstats
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,k,d", [
    (1, 1, 1), (100, 7, 3), (300, 16, 32), (257, 9, 17), (128, 33, 64),
])
def test_suffstats_shapes(n, k, d):
    rng = np.random.default_rng(n + 13 * k + d)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    labels = rng.integers(0, k, n)
    resp = jnp.asarray(np.eye(k)[labels], jnp.float32)
    got = ops.suffstats_pallas(x, resp)
    want = ref.suffstats(x, resp)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 300), k=st.integers(1, 32), d=st.integers(1, 32))
def test_suffstats_property_conservation(n, k, d):
    """Invariants: sum_k n_k == N; sum_k sx_k == sum_i x_i; sxx PSD-ish."""
    rng = np.random.default_rng(n * 31 + k * 7 + d)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    labels = rng.integers(0, k, n)
    resp = jnp.asarray(np.eye(k)[labels], jnp.float32)
    n_k, sx, sxx = ops.suffstats_pallas(x, resp)
    assert np.isclose(float(jnp.sum(n_k)), n, rtol=1e-6)
    np.testing.assert_allclose(jnp.sum(sx, axis=0), jnp.sum(x, axis=0),
                               rtol=1e-3, atol=1e-3)
    # each sxx_k is symmetric PSD (sum of outer products)
    sym_err = float(jnp.max(jnp.abs(sxx - jnp.swapaxes(sxx, -1, -2))))
    assert sym_err < 1e-3
    eigs = np.linalg.eigvalsh(np.asarray(sxx) + 1e-4 * np.eye(d))
    assert eigs.min() > -1e-2
