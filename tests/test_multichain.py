"""Multi-chain fits (ISSUE 5): ``fit(n_chains=C)`` is C *independent*
chains sharing one copy of x — chain c must be BITWISE identical to a
single-chain fit with ``key=fold_in(key(seed), c)``, on both data planes,
for every registered family (labels, history, stats, substats — and on
the same mesh even params, since lax.map re-traces the exact unbatched
body per chain). Plus the cross-chain diagnostics (rhat / select_best /
chain views) and the checkpoint/resume contract (core/checkpoint.py):
save → load → ``fit(init_state=...)`` continues the chain bit for bit.
"""
import io

import numpy as np
import pytest

import jax

from repro.configs import DPMMConfig
from repro.core.checkpoint import load_model, save_model
from repro.core.distributed import make_data_mesh
from repro.core.gibbs import STATS_BLOCK
from repro.core.sampler import DPMM
from repro.data.synthetic import generate_gmm, generate_mnmm, generate_pmm

ALL = ("gaussian", "diag_gaussian", "multinomial", "poisson")
C = 2
ITERS = 12


def _data(name, n=2000):
    if name in ("gaussian", "diag_gaussian"):
        return generate_gmm(n, 4, 4, seed=0, sep=10.0)[0]
    if name == "poisson":
        return generate_pmm(n, 4, 4, seed=0)[0]
    return generate_mnmm(n, 16, 4, seed=0)[0]


def _cfg(name, **kw):
    return DPMMConfig(component=name, alpha=10.0, iters=ITERS, k_max=16,
                      burnout=4, **kw)


def _leaves(tree):
    return [np.asarray(leaf) for leaf in jax.tree_util.tree_leaves(tree)]


def _assert_chain_bitwise(single, multi_chain_view, what):
    assert np.array_equal(single.labels, multi_chain_view.labels), (
        f"{what}: labels differ")
    for key in single.history:
        assert np.array_equal(single.history[key],
                              multi_chain_view.history[key]), (
            f"{what}: history[{key}] differs")
    for name in ("stats", "substats", "params"):
        for la, lb in zip(_leaves(getattr(single.state, name)),
                          _leaves(getattr(multi_chain_view.state, name))):
            assert np.array_equal(la, lb), f"{what}: {name} leaf differs"


@pytest.mark.parametrize("name", ALL)
def test_chains_match_independent_fits(name):
    """Resident + tiled: every chain of an n_chains=C fit is bitwise the
    independent single-chain fit with the corresponding folded key."""
    x = _data(name)
    base = jax.random.key(0)
    singles = [DPMM(_cfg(name)).fit(x, key=jax.random.fold_in(base, c))
               for c in range(C)]
    for plane, cfg in (("resident", _cfg(name)),
                       ("tiled", _cfg(name, tile_size=STATS_BLOCK))):
        multi = DPMM(cfg).fit(x, n_chains=C)
        assert multi.n_chains == C
        assert multi.labels.shape == (C, x.shape[0])
        assert multi.history["k"].shape == (C, ITERS)
        for c in range(C):
            _assert_chain_bitwise(singles[c], multi.chain(c),
                                  f"{name}/{plane} chain {c}")


def test_tiled_chains_partial_tiles():
    """Multi-chain streaming with genuinely partial tiles (1-device mesh,
    several tiles per sweep): the chain — labels and history — still
    matches the resident single-chain fits."""
    x = _data("gaussian", n=3000)
    mesh = make_data_mesh(1)
    base = jax.random.key(0)
    singles = [DPMM(_cfg("gaussian"), mesh=mesh).fit(
        x, key=jax.random.fold_in(base, c)) for c in range(C)]
    multi = DPMM(_cfg("gaussian", tile_size=STATS_BLOCK),
                 mesh=mesh).fit(x, n_chains=C)
    for c in range(C):
        mc = multi.chain(c)
        assert np.array_equal(singles[c].labels, mc.labels)
        for key in mc.history:
            assert np.array_equal(singles[c].history[key],
                                  mc.history[key])


def test_diagnostics_and_views():
    x = _data("gaussian")
    multi = DPMM(_cfg("gaussian")).fit(x, n_chains=3)
    # score ranks chains; select_best is the argmax chain
    assert multi.score.shape == (3,)
    best = multi.select_best()
    assert best.n_chains == 1
    assert float(best.score) == float(np.max(multi.score))
    assert best.k == int(np.asarray(best.state.active).sum())
    # rhat: defined on multi-chain traces only, finite and positive here
    for key in ("k", "score"):
        r = multi.rhat(key)
        assert np.isfinite(r) and r > 0
    assert set(multi.rhats()) == {"k", "score"}
    with pytest.raises(ValueError):
        best.rhat("score")
    # chain views are self-consistent
    c1 = multi.chain(1)
    assert np.array_equal(c1.labels, multi.labels[1])
    with pytest.raises(IndexError):
        best.chain(2)
    # nmi on the multi-chain result silently scores the best chain
    gt = generate_gmm(2000, 4, 4, seed=0, sep=10.0)[1]
    assert multi.nmi(gt) == best.nmi(gt)


def test_history_score_tracks_final_state():
    from repro.core.sampler import chain_score

    x, _ = generate_gmm(2000, 4, 4, seed=0, sep=10.0)
    r = DPMM(_cfg("gaussian")).fit(x)
    assert r.history["score"].shape == (ITERS,)
    fam = DPMM(_cfg("gaussian")).family
    prior = fam.build_prior(_cfg("gaussian"), x.mean(0, keepdims=True))
    recomputed = float(chain_score(r.state, prior, fam, 10.0))
    np.testing.assert_allclose(r.history["score"][-1], recomputed,
                               rtol=1e-5, atol=1e-3)


# ---------------------------------------------------------------------------
# Checkpoint round-trip + bitwise resume
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_bitwise(tmp_path):
    x = _data("gaussian")
    r = DPMM(_cfg("gaussian")).fit(x)
    path = str(tmp_path / "m.npz")
    save_model(path, r.state, "gaussian")
    loaded, family = load_model(path)
    assert family.name == "gaussian"
    raw = lambda m: m._replace(key=jax.random.key_data(m.key))
    for la, lb in zip(_leaves(raw(r.state)), _leaves(raw(loaded))):
        assert la.dtype == lb.dtype and np.array_equal(la, lb)


@pytest.mark.parametrize("tile", (None, STATS_BLOCK))
def test_resume_is_bitwise(tmp_path, tile):
    """fit(16) == fit(8) -> save -> load -> fit(8 more), bit for bit —
    on both planes (the checkpointed ModelState IS the chain state)."""
    x = _data("gaussian")
    cfg = _cfg("gaussian", **({"tile_size": tile} if tile else {}))
    full = DPMM(cfg).fit(x, iters=16)
    half = DPMM(cfg).fit(x, iters=8)
    buf = io.BytesIO()
    save_model(buf, half.state, "gaussian")
    buf.seek(0)
    loaded, _ = load_model(buf)
    resumed = DPMM(cfg).fit(x, iters=8, init_state=loaded)
    assert np.array_equal(full.labels, resumed.labels)
    for key in full.history:
        assert np.array_equal(full.history[key][8:], resumed.history[key])
    for name in ("stats", "substats", "params"):
        for la, lb in zip(_leaves(getattr(full.state, name)),
                          _leaves(getattr(resumed.state, name))):
            assert np.array_equal(la, lb), f"resume {name} differs"
    # resuming TWICE from the same loaded state must not crash (the
    # drivers copy init_state before donating buffers) and must agree
    again = DPMM(cfg).fit(x, iters=8, init_state=loaded)
    assert np.array_equal(resumed.labels, again.labels)


def test_multichain_checkpoint_resume(tmp_path):
    x = _data("gaussian")
    cfg = _cfg("gaussian")
    full = DPMM(cfg).fit(x, iters=16, n_chains=C)
    half = DPMM(cfg).fit(x, iters=8, n_chains=C)
    path = str(tmp_path / "mc.npz")
    save_model(path, half.state, "gaussian")
    loaded, _ = load_model(path)
    resumed = DPMM(cfg).fit(x, iters=8, n_chains=C, init_state=loaded)
    assert np.array_equal(full.labels, resumed.labels)
    for key in full.history:
        assert np.array_equal(full.history[key][:, 8:],
                              resumed.history[key])


def test_checkpoint_and_fit_guardrails(tmp_path):
    x = _data("gaussian")
    r = DPMM(_cfg("gaussian")).fit(x, iters=2)
    path = str(tmp_path / "m.npz")
    save_model(path, r.state, "gaussian")
    loaded, _ = load_model(path)
    with pytest.raises(ValueError, match="unknown component family"):
        save_model(str(tmp_path / "bad.npz"), r.state, "not_a_family")
    with pytest.raises(ValueError, match="n_chains"):
        DPMM(_cfg("gaussian")).fit(x, n_chains=0)
    # init_state shape vs n_chains/k_max mismatch fails loudly
    with pytest.raises(ValueError, match="init_state"):
        DPMM(_cfg("gaussian")).fit(x, iters=2, n_chains=2,
                                   init_state=loaded)
    with pytest.raises(ValueError, match="init_state"):
        DPMM(DPMMConfig(component="gaussian", k_max=32)).fit(
            x, iters=2, init_state=loaded)
